//! The paper's headline experiment in one binary: on the highly
//! heterogeneous BUJARUELO platform, compare the best *homogeneous*
//! (uniform-tile) schedule against the *heterogeneous* partition found by
//! the iterative scheduler-partitioner (§3.2, Table 1's PL/EFT-P row).
//!
//! ```text
//! cargo run --release --example heterogeneous_cholesky [-- --n 32768 --iters 250]
//! ```

use std::collections::BTreeMap;

use hesp::config::Platform;
use hesp::coordinator::energy::Objective;
use hesp::coordinator::engine::SimConfig;
use hesp::coordinator::metrics::report;
use hesp::coordinator::partitioners::PartitionerSet;
use hesp::coordinator::policies::{Ordering, ProcSelect, SchedConfig};
use hesp::coordinator::solver::{best_homogeneous, solve, SolverConfig};
use hesp::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.usize_or("n", 32_768) as u32;
    let iters = args.usize_or("iters", 250);
    let tiles: Vec<u32> = args.usize_list("tiles", &[512, 1024, 2048, 4096]).into_iter().map(|x| x as u32).collect();

    let p = Platform::from_file("configs/bujaruelo.toml")?;
    let sim = SimConfig::new(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish))
        .with_elem_bytes(p.elem_bytes);

    println!("== best homogeneous tiling (the static baseline) ==");
    let (hb, hdag, hsched) = best_homogeneous(n, &tiles, &p.machine, &p.db, sim, Objective::Makespan)
        .expect("a legal tile size");
    let hr = report(&hdag, &hsched);
    println!("b={hb}: {:.2} GFLOPS, load {:.1}%, depth {}", hr.gflops, hr.avg_load_pct, hr.dag_depth);

    println!("\n== iterative scheduler-partitioner (All/Soft, {iters} iters) ==");
    let cfg = SolverConfig::all_soft(sim, iters, 128);
    let res = solve(hdag, &p.machine, &p.db, &PartitionerSet::standard(), cfg);
    let er = report(&res.best_dag, &res.best_schedule);
    println!(
        "found at iter {}: {:.2} GFLOPS, load {:.1}%, avg block {:.1}, depth {}",
        res.best_iter, er.gflops, er.avg_load_pct, er.avg_block_size, er.dag_depth
    );
    println!("improvement over best homogeneous: {:+.2}%", 100.0 * (er.gflops - hr.gflops) / hr.gflops);

    // task-granularity histogram of the found heterogeneous partition —
    // the textual version of Fig. 6's granularity gradient
    let mut hist: BTreeMap<u32, usize> = BTreeMap::new();
    for t in res.best_dag.frontier() {
        *hist.entry(res.best_dag.task(t).char_edge().round() as u32).or_insert(0) += 1;
    }
    println!("\ntile-edge histogram of the heterogeneous partition:");
    for (edge, count) in hist {
        println!("  {edge:>5}: {count:>6} tasks");
    }

    // where did the makespan go? per-proc-type busy shares
    let mut busy: BTreeMap<&str, f64> = BTreeMap::new();
    for proc in &p.machine.procs {
        *busy.entry(p.machine.proc_types[proc.ptype].name.as_str()).or_insert(0.0) +=
            res.best_schedule.proc_busy[proc.id];
    }
    println!("\nbusy seconds by processor type:");
    for (ty, b) in busy {
        println!("  {ty:>8}: {b:.3}s");
    }
    Ok(())
}
