//! Energy-aware scheduling-partitioning (paper §2: "energy consumption
//! minimization is also supported" and §4 future work): run the iterative
//! solver under the makespan, energy and EDP objectives on the low-power
//! ODROID platform and report the resulting performance/energy frontier.
//!
//! ```text
//! cargo run --release --example energy_frontier [-- --n 4096 --iters 150]
//! ```

use hesp::config::Platform;
use hesp::coordinator::energy::{energy, Objective, DEFAULT_J_PER_BYTE};
use hesp::coordinator::engine::SimConfig;
use hesp::coordinator::metrics::report;
use hesp::coordinator::partitioners::PartitionerSet;
use hesp::coordinator::policies::{Ordering, ProcSelect, SchedConfig};
use hesp::coordinator::solver::{best_homogeneous, solve, SolverConfig};
use hesp::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.usize_or("n", 4_096) as u32;
    let iters = args.usize_or("iters", 150);
    let tiles: Vec<u32> = args.usize_list("tiles", &[128, 256, 512, 1024]).into_iter().map(|x| x as u32).collect();

    let p = Platform::from_file("configs/odroid.toml")?;
    let sim = SimConfig::new(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish))
        .with_elem_bytes(p.elem_bytes);
    let parts = PartitionerSet::standard();

    println!("{:>10} {:>12} {:>10} {:>10} {:>10} {:>8}", "objective", "makespan s", "GFLOPS", "energy J", "EDP", "depth");
    for obj in [Objective::Makespan, Objective::Energy, Objective::Edp] {
        let (_, hdag, _) = best_homogeneous(n, &tiles, &p.machine, &p.db, sim, obj).unwrap();
        let mut cfg = SolverConfig::all_soft(sim, iters, 64);
        cfg.objective = obj;
        let res = solve(hdag, &p.machine, &p.db, &parts, cfg);
        let r = report(&res.best_dag, &res.best_schedule);
        let e = energy(&res.best_schedule, &p.machine, DEFAULT_J_PER_BYTE);
        println!(
            "{:>10} {:>12.4} {:>10.2} {:>10.3} {:>10.3} {:>8}",
            format!("{obj:?}"),
            r.makespan,
            r.gflops,
            e.total(),
            e.edp(r.makespan),
            r.dag_depth
        );
    }
    println!("\nExpected frontier: the energy objective trades makespan for lower");
    println!("total joules (favoring the A7 cluster and coarser tiles); EDP sits");
    println!("between the two.");
    Ok(())
}
