//! Serving a stream of jobs: the simulator as a cluster.
//!
//! `hesp serve` (and the [`hesp::coordinator::service`] API below) turns
//! the single-DAG simulator into a service model: jobs arrive over time,
//! pass admission control, and are co-scheduled on the shared machine —
//! queueing delay emerges from contention on the same processor and link
//! timelines, nothing is modeled separately. This example builds a small
//! heterogeneous platform, replays the same bursty arrival stream under a
//! job-oblivious baseline and the two job-aware policies, and prints the
//! service-level objectives side by side.
//!
//! ```text
//! cargo run --release --example serve
//! ```

use hesp::coordinator::coherence::CachePolicy;
use hesp::coordinator::perfmodel::{PerfCurve, PerfDb};
use hesp::coordinator::platform::{Machine, MachineBuilder};
use hesp::coordinator::policy::policy_by_name;
use hesp::coordinator::service::{
    parse_trace, scenario_seed, simulate_stream, summarize, ArrivalSpec, Admission, ServeConfig,
};

/// 4 fast + 4 slow CPUs in one memory space — an ODROID-like asymmetric
/// multicore, where co-scheduled jobs genuinely fight for the big cores.
fn asymmetric_platform() -> (Machine, PerfDb) {
    let mut b = MachineBuilder::new("asym8");
    let h = b.space("dram", u64::MAX);
    b.main(h);
    let big = b.proc_type("big", 2.0, 0.5);
    let little = b.proc_type("little", 0.6, 0.15);
    b.processors(4, "b", big, h);
    b.processors(4, "l", little, h);
    let m = b.build();
    let mut db = PerfDb::new();
    db.set_fallback(0, PerfCurve::Saturating { peak: 2.8, half: 40.0, exponent: 1.7 });
    db.set_fallback(1, PerfCurve::Saturating { peak: 0.6, half: 40.0, exponent: 1.7 });
    (m, db)
}

fn main() -> anyhow::Result<()> {
    let (machine, db) = asymmetric_platform();

    // one bursty stream, shared verbatim by every policy: quiet spells at
    // 3 jobs/s, bursts at 25 jobs/s, state dwell ~150 ms
    let arrivals = ArrivalSpec::Bursty { lo: 3.0, hi: 25.0, dwell: 0.15 };
    let duration = 3.0;
    let seed = 0;
    let stream = arrivals.generate(duration, seed)?;
    println!(
        "stream '{}': {} jobs over {duration}s (then drain to empty)\n",
        arrivals.label(),
        stream.len()
    );

    println!(
        "{:>10} | {:>5} {:>9} {:>9} {:>9} {:>7} {:>6}",
        "policy", "jobs", "p50 soj", "p99 soj", "mean slow", "miss %", "fair"
    );
    for name in ["pl/eft-p", "pl/edf-p", "pl/sjf-p"] {
        let mut pol = policy_by_name(name).expect("registered");
        let cfg = ServeConfig {
            queue_cap: 64,
            admission: Admission::Defer,
            cache: CachePolicy::WriteBack,
            elem_bytes: 8,
            job_seed: seed,
            rng_seed: scenario_seed(&machine.name, &arrivals.label(), name, seed),
        };
        let outcome = simulate_stream(&machine, &db, pol.as_mut(), &stream, &cfg);
        let r = summarize(&machine.name, &arrivals.label(), name, seed, cfg.rng_seed, duration, &outcome);
        println!(
            "{:>10} | {:>5} {:>8.3}s {:>8.3}s {:>9.2} {:>7.1} {:>6.3}",
            name, r.completed, r.p50_sojourn, r.p99_sojourn, r.mean_slowdown, r.deadline_miss_pct, r.fairness
        );
    }
    println!(
        "\nUnder a job stream, pl/eft-p's critical-time ordering acts like\n\
         longest-job-first: big DAGs starve small ones and the p99 sojourn\n\
         blows up. pl/edf-p (earliest deadline) and pl/sjf-p (smallest\n\
         lower bound) order by job-level urgency instead."
    );

    // Streams don't have to be synthetic: any JSONL file with one job per
    // line replays verbatim (same file as `hesp serve --arrivals trace:...`).
    let trace_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/serve_trace.jsonl");
    let trace = parse_trace(&std::fs::read_to_string(trace_path)?)?;
    let mut pol = policy_by_name("pl/edf-p").expect("registered");
    let cfg = ServeConfig {
        queue_cap: 64,
        admission: Admission::Defer,
        cache: CachePolicy::WriteBack,
        elem_bytes: 8,
        job_seed: seed,
        rng_seed: scenario_seed(&machine.name, "trace:serve_trace.jsonl", "pl/edf-p", seed),
    };
    let outcome = simulate_stream(&machine, &db, pol.as_mut(), &trace, &cfg);
    println!("\ntrace replay ({} jobs from serve_trace.jsonl under pl/edf-p):", trace.len());
    for j in &outcome.jobs {
        println!(
            "  job {:>2} {:<12} tile {:>4}  arrive {:>5.2}s  done {:>5.2}s  sojourn {:>5.2}s{}",
            j.id,
            j.workload,
            j.tile,
            j.t_arrival,
            j.finished,
            j.sojourn,
            if j.missed { "  DEADLINE MISSED" } else { "" }
        );
    }
    Ok(())
}
