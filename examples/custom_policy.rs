//! Writing your own scheduling policy.
//!
//! HeSP's scheduler is an open trait API: implement
//! [`hesp::coordinator::policy::SchedPolicy`], register it under a name,
//! and every execution path (engine, iterative solver, constructive
//! online scheduler) can drive it. This example builds a *bounded-penalty
//! locality* policy: run a task where its data lives, unless the fastest
//! processor would finish it `threshold`x sooner — a middle ground between
//! the built-in `pl/eft-p` (ignores locality beyond transfer time) and
//! `pl/affinity` (locality at any cost).
//!
//! ```text
//! cargo run --release --example custom_policy
//! ```

use hesp::coordinator::engine::{simulate_policy, SimConfig};
use hesp::coordinator::metrics::report;
use hesp::coordinator::partitioners::cholesky;
use hesp::coordinator::perfmodel::{PerfCurve, PerfDb};
use hesp::coordinator::platform::{Machine, MachineBuilder, ProcId};
use hesp::coordinator::policies::{Ordering, ProcSelect, SchedConfig};
use hesp::coordinator::policy::{PolicyRegistry, SchedContext, SchedPolicy};
use hesp::coordinator::task::Task;

/// Locality-first selection with a bounded slowdown: among processors
/// whose memory space needs the fewest bytes moved, take the earliest
/// finisher — but if some *other* processor finishes `threshold`x sooner
/// than the best local candidate, take that one instead.
struct BoundedLocality {
    threshold: f64,
}

impl SchedPolicy for BoundedLocality {
    fn name(&self) -> &str {
        "example/bounded-locality"
    }

    // order by critical times (priority-list), like the PL built-ins
    fn wants_critical_times(&self) -> bool {
        true
    }

    fn order(&mut self, _ctx: &mut SchedContext<'_>, _task: &Task, _release: f64, critical_time: f64) -> f64 {
        critical_time
    }

    fn select(&mut self, ctx: &mut SchedContext<'_>, task: &Task, release: f64) -> ProcId {
        // one memoized scan yields (proc, finish time, bytes to move)
        let mut best_local: Option<(u64, f64, ProcId)> = None;
        let mut best_global: Option<(f64, ProcId)> = None;
        for (p, fin, bytes) in ctx.placement_estimates(task, release) {
            if best_global.map(|(f, _)| fin < f).unwrap_or(true) {
                best_global = Some((fin, p));
            }
            let better_local = match best_local {
                None => true,
                Some((bb, bf, _)) => bytes < bb || (bytes == bb && fin < bf),
            };
            if better_local {
                best_local = Some((bytes, fin, p));
            }
        }
        let (_, local_fin, local_p) = best_local.expect("machines have processors");
        let (global_fin, global_p) = best_global.expect("machines have processors");
        // keep locality unless breaking it is a big win
        if global_fin * self.threshold < local_fin {
            global_p
        } else {
            local_p
        }
    }
}

/// Host with 4 CPUs + 2 fast GPUs in their own memory spaces.
fn toy_platform() -> (Machine, PerfDb) {
    let mut b = MachineBuilder::new("toy");
    let host = b.space("host", u64::MAX);
    let g0 = b.space("gpu0_mem", 4 << 30);
    let g1 = b.space("gpu1_mem", 4 << 30);
    b.main(host);
    b.connect(host, g0, 10e-6, 12e9);
    b.connect(host, g1, 10e-6, 12e9);
    let cpu = b.proc_type("cpu", 20.0, 5.0);
    let gpu = b.proc_type("gpu", 180.0, 30.0);
    b.processors(4, "cpu", cpu, host);
    b.processors(1, "gpu_a", gpu, g0);
    b.processors(1, "gpu_b", gpu, g1);
    let m = b.build();
    let mut db = PerfDb::new();
    db.set_fallback(0, PerfCurve::Saturating { peak: 30.0, half: 64.0, exponent: 1.7 });
    db.set_fallback(1, PerfCurve::Saturating { peak: 1500.0, half: 900.0, exponent: 2.0 });
    (m, db)
}

fn main() {
    // 1. register the custom policy next to the built-ins
    let mut reg = PolicyRegistry::standard();
    reg.register("example/bounded-locality", || {
        Box::new(BoundedLocality { threshold: 3.0 }) as Box<dyn SchedPolicy>
    });

    // 2. a transfer-heavy workload: 4096^2 Cholesky at 512^2 tiles
    let mut dag = cholesky::root(4096);
    cholesky::partition_uniform(&mut dag, 512);
    let (machine, db) = toy_platform();
    let sim = SimConfig::new(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish));

    // 3. run the custom policy against the relevant built-ins
    println!("policy comparison on {} ({} tasks):\n", machine.name, dag.frontier().len());
    for name in ["pl/eft-p", "pl/affinity", "pl/lookahead", "example/bounded-locality"] {
        let mut pol = reg.get(name).expect("registered");
        let sched = simulate_policy(&dag, &machine, &db, sim, pol.as_mut());
        let r = report(&dag, &sched);
        println!(
            "{:>26}: makespan {:.4}s  {:>8.2} GFLOPS  load {:>5.1}%  moved {:>7.1} MB",
            name,
            r.makespan,
            r.gflops,
            r.avg_load_pct,
            r.transfer_bytes as f64 / 1e6
        );
    }
    println!("\n(bounded-locality should land between pl/eft-p's speed and pl/affinity's traffic)");
}
