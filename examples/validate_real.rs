//! END-TO-END driver (Fig. 5 left analog): execute the blocked Cholesky
//! factorization FOR REAL through all three layers —
//!
//!   L1  Pallas tile kernels (GEMM/SYRK/TRSM, interpret-mode)   [python, AOT]
//!   L2  blocked-POTRF jax composition                          [python, AOT]
//!   L3  rust coordinator replaying the partitioner's task DAG on the
//!       CPU PJRT client via artifacts/*.hlo.txt
//!
//! — verify the numerics (max |L L^T - A|), then compare the *measured*
//! makespan against HeSP's simulated one with the analytic performance
//! model (HESP-REPLICA-PM) and with models measured from the same kernels
//! (HESP-REPLICA-RD). The gap structure is the paper's validation story:
//! RD tracks reality closely; PM deviates by model error only.
//!
//! Requires `make artifacts`. Run:
//!
//! ```text
//! cargo run --release --example validate_real [-- --n 512 --tiles 64,128 --reps 3]
//! ```

use hesp::config::Platform;
use hesp::coordinator::engine::{simulate_mapped, SimConfig};
use hesp::coordinator::partitioners::cholesky;
use hesp::coordinator::policies::{Ordering, ProcSelect, SchedConfig};
use hesp::runtime::executor;
use hesp::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.usize_or("n", 512) as u32;
    let tiles: Vec<u32> = args.usize_list("tiles", &[64, 128]).into_iter().map(|x| x as u32).collect();
    let reps = args.usize_or("reps", 3);

    if !executor::artifacts_available() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(2);
    }
    println!("loading + compiling f32 kernels for tiles {tiles:?} ...");
    let rt = executor::load_f32_runtime(&tiles)?;
    println!("available kernels: {:?}", rt.available().len());

    let local = Platform::from_file("configs/local.toml")?;
    let sim = SimConfig::new(SchedConfig::new(Ordering::Fcfs, ProcSelect::EarliestIdle));

    println!("\n{:>6} {:>10} {:>12} {:>12} {:>12} {:>9} {:>9} {:>12}", "b", "tasks", "real s", "sim-PM s", "sim-RD s", "PM err%", "RD err%", "max|LLt-A|");
    for &b in &tiles {
        if n % b != 0 || n / b < 2 {
            continue;
        }
        // --- real execution through the PJRT runtime ---
        let real = executor::run_cholesky(&rt, n, b, 42)?;
        anyhow::ensure!(real.max_err < 1e-2, "NUMERICS FAILED: {}", real.max_err);

        // --- measured (RD) models from the same kernels ---
        let measures = executor::measure_models(&rt, &[b], reps, 7)?;
        let rd_db = executor::measured_perfdb(&measures);

        // --- replay the same task stream in the simulator ---
        let mut dag = cholesky::root(n);
        cholesky::partition_uniform(&mut dag, b);
        let mapping = vec![0usize; dag.frontier().len()]; // the single local proc
        let pm = simulate_mapped(&dag, &local.machine, &local.db, sim, &mapping);
        let rd = simulate_mapped(&dag, &local.machine, &rd_db, sim, &mapping);

        println!(
            "{:>6} {:>10} {:>12.3} {:>12.3} {:>12.3} {:>+9.1} {:>+9.1} {:>12.2e}",
            b,
            dag.frontier().len(),
            real.total_s,
            pm.makespan,
            rd.makespan,
            100.0 * (pm.makespan - real.total_s) / real.total_s,
            100.0 * (rd.makespan - real.total_s) / real.total_s,
            real.max_err,
        );
        println!(
            "        real throughput: {:.3} GFLOPS over {} tile tasks",
            real.gflops(),
            real.timings.len()
        );
    }
    println!("\nvalidation semantics: RD (measured delays) should track reality within");
    println!("measurement noise; PM error is the analytic-model gap (paper §3.1).");
    Ok(())
}
