//! Regenerate the paper's trace figures: Fig. 2b (compute-load trace) and
//! Fig. 6 (homogeneous vs heterogeneous execution traces) as Paraver
//! `.prv/.pcf/.row` bundles plus CSVs, for both platforms.
//!
//! ```text
//! cargo run --release --example traces [-- --out traces --iters 200]
//! ```

use hesp::config::Platform;
use hesp::coordinator::engine::{simulate, SimConfig};
use hesp::coordinator::metrics::report;
use hesp::coordinator::partitioners::{cholesky, PartitionerSet};
use hesp::coordinator::policies::{Ordering, ProcSelect, SchedConfig};
use hesp::coordinator::solver::{solve, SolverConfig};
use hesp::coordinator::trace::write_bundle;
use hesp::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let out = std::path::PathBuf::from(args.str_or("out", "traces"));
    let iters = args.usize_or("iters", 200);

    // Fig. 6 uses PL/EFT-P on both platforms; Fig. 2b is the BUJARUELO
    // load trace at n=16384, b=1024.
    for (config, n, b, min_edge) in [
        ("configs/bujaruelo.toml", 32_768u32, 2_048u32, 128u32),
        ("configs/odroid.toml", 8_192, 512, 64),
    ] {
        let p = Platform::from_file(config)?;
        let sim = SimConfig::new(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish))
            .with_elem_bytes(p.elem_bytes);

        let mut dag = cholesky::root(n);
        cholesky::partition_uniform(&mut dag, b);
        let hsched = simulate(&dag, &p.machine, &p.db, sim);
        let hr = report(&dag, &hsched);
        write_bundle(&out, &format!("{}_homog", p.machine.name), &dag, &hsched, &p.machine)?;

        let res = solve(dag, &p.machine, &p.db, &PartitionerSet::standard(), SolverConfig::all_soft(sim, iters, min_edge));
        let er = report(&res.best_dag, &res.best_schedule);
        write_bundle(&out, &format!("{}_heterog", p.machine.name), &res.best_dag, &res.best_schedule, &p.machine)?;

        println!(
            "{}: homog {:.2} GFLOPS (load {:.1}%) -> heterog {:.2} GFLOPS (load {:.1}%)",
            p.machine.name, hr.gflops, hr.avg_load_pct, er.gflops, er.avg_load_pct
        );
        println!("\nheterogeneous schedule (ASCII Gantt):");
        print!(
            "{}",
            hesp::coordinator::trace::ascii_gantt(&res.best_dag, &res.best_schedule, &p.machine, 100)
        );
    }

    // Fig. 2b companion: the 16384/1024 load trace of the motivation section.
    let p = Platform::from_file("configs/bujaruelo.toml")?;
    let sim = SimConfig::new(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish))
        .with_elem_bytes(p.elem_bytes);
    let mut dag = cholesky::root(16_384);
    cholesky::partition_uniform(&mut dag, 1_024);
    let sched = simulate(&dag, &p.machine, &p.db, sim);
    write_bundle(&out, "fig2b_load", &dag, &sched, &p.machine)?;

    println!("trace bundles written to {}/", out.display());
    println!("open the .prv files with Paraver (https://tools.bsc.es/paraver)");
    Ok(())
}
