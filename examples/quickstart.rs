//! Quickstart: simulate a blocked Cholesky factorization on the paper's
//! BUJARUELO platform (28 Xeon cores + 3 GPUs) and print the schedule
//! report — the 60-second tour of the HeSP API.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use hesp::config::Platform;
use hesp::coordinator::engine::{simulate, SimConfig};
use hesp::coordinator::metrics::{load_trace, report};
use hesp::coordinator::partitioners::cholesky;
use hesp::coordinator::policies::{Ordering, ProcSelect, SchedConfig};

fn main() -> anyhow::Result<()> {
    // 1. A platform = machine topology + per-(proc, task, size) perf models.
    let platform = Platform::from_file("configs/bujaruelo.toml")?;

    // 2. A workload = one root task, recursively partitionable. Here: the
    //    paper's Fig. 2 example, a 16384^2 Cholesky at 1024^2 tiles.
    let (n, b) = (16_384, 1_024);
    let mut dag = cholesky::root(n);
    cholesky::partition_uniform(&mut dag, b);
    let flat = dag.flat_dag();
    println!(
        "task DAG: {} tasks, {} dependence edges, width {}, longest path {}",
        flat.len(),
        flat.edge_count(),
        flat.width(),
        flat.longest_path_len()
    );

    // 3. Simulate under a scheduling policy (PL/EFT-P ~= HEFT).
    let cfg = SimConfig::new(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish))
        .with_elem_bytes(platform.elem_bytes);
    let sched = simulate(&dag, &platform.machine, &platform.db, cfg);

    // 4. Inspect the result.
    let r = report(&dag, &sched);
    println!(
        "PL/EFT-P on {}: {:.2} GFLOPS, makespan {:.4}s, avg load {:.1}%, {:.1} MB moved",
        platform.machine.name,
        r.gflops,
        r.makespan,
        r.avg_load_pct,
        r.transfer_bytes as f64 / 1e6
    );

    // 5. The Fig. 2b-style compute-load timeline.
    println!("\ncompute load (active processors over time):");
    for (t, active) in load_trace(&sched, 20) {
        println!("  t={t:7.4}s  {}", "#".repeat(active));
    }
    Ok(())
}
