//! HeSP on the low-power asymmetric ODROID big.LITTLE platform
//! (4x Cortex-A7 + 4x Cortex-A15, double precision): the second half of
//! Table 1, plus the LU and QR extension workloads on the same machine.
//!
//! ```text
//! cargo run --release --example odroid_asymmetric [-- --n 8192 --iters 200]
//! ```

use hesp::config::Platform;
use hesp::coordinator::energy::Objective;
use hesp::coordinator::engine::{simulate, SimConfig};
use hesp::coordinator::metrics::report;
use hesp::coordinator::partitioners::{lu, qr, PartitionerSet};
use hesp::coordinator::policies::{Ordering, ProcSelect, SchedConfig};
use hesp::coordinator::solver::{best_homogeneous, solve, SolverConfig};
use hesp::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.usize_or("n", 8_192) as u32;
    let iters = args.usize_or("iters", 200);
    let tiles: Vec<u32> = args.usize_list("tiles", &[128, 256, 512, 1024]).into_iter().map(|x| x as u32).collect();

    let p = Platform::from_file("configs/odroid.toml")?;
    let parts = PartitionerSet::standard();

    println!("== Cholesky (Table 1, ODROID half) ==");
    for (o, s) in [
        (Ordering::Fcfs, ProcSelect::Random),
        (Ordering::Fcfs, ProcSelect::EarliestIdle),
        (Ordering::PriorityList, ProcSelect::EarliestFinish),
    ] {
        let sim = SimConfig::new(SchedConfig::new(o, s)).with_elem_bytes(p.elem_bytes);
        let (hb, hdag, hsched) = best_homogeneous(n, &tiles, &p.machine, &p.db, sim, Objective::Makespan).unwrap();
        let hr = report(&hdag, &hsched);
        let cfg = SolverConfig::all_soft(sim, iters, 64);
        let res = solve(hdag, &p.machine, &p.db, &parts, cfg);
        let er = report(&res.best_dag, &res.best_schedule);
        println!(
            "{:>12}: homog b={hb} {:.2} GFLOPS (load {:.1}%) -> heterog {:.2} GFLOPS (load {:.1}%, avg b {:.0}, depth {}) {:+.2}%",
            SchedConfig::new(o, s).name(),
            hr.gflops,
            hr.avg_load_pct,
            er.gflops,
            er.avg_load_pct,
            er.avg_block_size,
            er.dag_depth,
            100.0 * (er.gflops - hr.gflops) / hr.gflops,
        );
    }

    // Generality beyond the paper's driving example: the same machinery
    // schedules LU and tile-QR DAGs (paper §4: "easily applied to other
    // irregular task-parallel implementations").
    println!("\n== extension workloads (uniform b=512 vs solver) ==");
    let sim = SimConfig::new(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish))
        .with_elem_bytes(p.elem_bytes);
    for (name, mut dag) in [("LU", lu::root(4096)), ("QR", qr::root(4096))] {
        parts.apply(&mut dag, 0, 512).expect("uniform blocking");
        let hsched = simulate(&dag, &p.machine, &p.db, sim);
        let hr = report(&dag, &hsched);
        let res = solve(dag, &p.machine, &p.db, &parts, SolverConfig::all_soft(sim, iters / 2, 64));
        let er = report(&res.best_dag, &res.best_schedule);
        println!(
            "{name}: homog {:.2} GFLOPS (load {:.1}%) -> heterog {:.2} GFLOPS (load {:.1}%, depth {}) {:+.2}%",
            hr.gflops,
            hr.avg_load_pct,
            er.gflops,
            er.avg_load_pct,
            er.dag_depth,
            100.0 * (er.gflops - hr.gflops) / hr.gflops,
        );
    }
    Ok(())
}
