//! Offline shim of the `anyhow` crate: the API subset HeSP uses
//! (`anyhow!`, `bail!`, `ensure!`, `Context`, `Result`), backed by a plain
//! message-chain error type. The build must work without a crates.io
//! registry, so this vendored stand-in replaces the real dependency; it is
//! drop-in for the call sites in this repository, not a general clone.

use std::fmt;

/// A message-chain error: the innermost message plus any context frames
/// added via [`Context`], outermost last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Push an outer context frame (what `Context::context` does).
    pub fn push_context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.push(c.to_string());
        self
    }

    /// Context frames from outermost to innermost (anyhow's `chain()`).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().rev().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the full cause chain, outermost first.
            let full: Vec<&str> = self.chain.iter().rev().map(|s| s.as_str()).collect();
            write!(f, "{}", full.join(": "))
        } else {
            // `{}` prints the outermost message only.
            write!(f, "{}", self.chain.last().map(|s| s.as_str()).unwrap_or("unknown error"))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Debug (what `.unwrap()` prints) shows the full chain.
        write!(f, "{self:#}")
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// is what makes the blanket conversion below coherent (same trick as the
// real anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with [`Error`] default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach lazy context to `Result`/`Option` errors.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        // `{:#}` so an inner Error's full context chain survives wrapping
        self.map_err(|e| Error::msg(format_args!("{e:#}")).push_context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format_args!("{e:#}")).push_context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string or a displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/file").with_context(|| "reading config")?;
        Ok(())
    }

    #[test]
    fn context_chain_renders() {
        let e = io_fail().unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        let full = format!("{e:#}");
        assert!(full.starts_with("reading config: "), "{full}");
    }

    #[test]
    fn macros_build_errors() {
        let x = 3;
        let e = anyhow!("bad value {x}");
        assert_eq!(e.to_string(), "bad value 3");
        let s = String::from("plain string error");
        let e2 = anyhow!(s);
        assert_eq!(e2.to_string(), "plain string error");
        fn f(v: usize) -> Result<usize> {
            ensure!(v < 10, "v too big: {v}");
            if v == 5 {
                bail!("five is right out");
            }
            Ok(v)
        }
        assert!(f(3).is_ok());
        assert!(f(11).unwrap_err().to_string().contains("too big"));
        assert!(f(5).is_err());
    }

    #[test]
    fn bare_ensure_and_option_context() {
        fn g(ok: bool) -> Result<()> {
            ensure!(ok);
            Ok(())
        }
        assert!(g(true).is_ok());
        assert!(g(false).unwrap_err().to_string().contains("condition failed"));
        let none: Option<u32> = None;
        assert!(none.context("missing").is_err());
        assert_eq!(Some(7u32).context("missing").unwrap(), 7);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn h() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(h().is_err());
    }
}
