//! Stub of the `xla-rs` API surface used by `hesp::runtime`.
//!
//! The real XLA/PJRT bindings need a compiled libxla, which the offline
//! build environment does not ship. This crate keeps the runtime layer
//! *compiling* with identical signatures; every operation that would need
//! the native backend returns [`Error`] with a clear message instead.
//!
//! The runtime integration tests gate on `artifacts/manifest.json`
//! existing and skip politely when it does not, so the stub paths are
//! never hit by `cargo test` in a fresh checkout. To run real kernels,
//! replace this path dependency with the actual `xla` crate — the HeSP
//! code does not change.

use std::fmt;

/// Error type mirroring xla-rs (implements `std::error::Error` so `?`
/// converts into `anyhow::Error` at call sites).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: XLA/PJRT backend unavailable (vendored stub build — swap rust/vendor/xla for the real xla crate to execute kernels)"
    ))
}

/// Element types a [`Literal`] can carry.
pub trait NativeType: Copy + 'static {
    const NAME: &'static str;
}

impl NativeType for f32 {
    const NAME: &'static str = "f32";
}

impl NativeType for f64 {
    const NAME: &'static str = "f64";
}

/// A host-side tensor literal. The stub records shape/element-count only;
/// values never materialize because execution is unavailable.
#[derive(Debug, Clone)]
pub struct Literal {
    elems: usize,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { elems: data.len(), dims: vec![data.len() as i64] }
    }

    /// Reshape to `dims`; errors when the element count does not match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n < 0 || n as usize != self.elems {
            return Err(Error(format!("reshape: {} elements into {dims:?}", self.elems)));
        }
        Ok(Literal { elems: self.elems, dims: dims.to_vec() })
    }

    /// Copy out as a host vector — needs the real backend.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    /// Unwrap a 1-tuple result — needs the real backend.
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (stub: parsing requires the backend).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-side buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shape_bookkeeping() {
        let l = Literal::vec1(&[0f32; 16]);
        assert_eq!(l.dims(), &[16]);
        let r = l.reshape(&[4, 4]).unwrap();
        assert_eq!(r.dims(), &[4, 4]);
        assert!(l.reshape(&[5, 5]).is_err());
    }

    #[test]
    fn backend_calls_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let l = Literal::vec1(&[0f64; 4]);
        assert!(l.to_vec::<f64>().is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("stub"), "{msg}");
    }
}
