//! FIG2 regenerator — (a) the blocked-Cholesky task DAG for n=16384,
//! b=1024 (task/edge counts, width, depth; DOT export) and (b) the
//! compute-load trace on BUJARUELO. Also times DAG construction and
//! dependence derivation (an engine hot path).

use hesp::bench::{Bench, Table};
use hesp::config::Platform;
use hesp::coordinator::engine::{simulate, SimConfig};
use hesp::coordinator::metrics::load_trace;
use hesp::coordinator::partitioners::cholesky;
use hesp::coordinator::policies::{Ordering, ProcSelect, SchedConfig};

fn main() {
    let (n, b) = (16_384u32, 1_024u32);
    println!("== FIG 2a: task DAG of the blocked Cholesky (n={n}, b={b}) ==");
    let mut dag = cholesky::root(n);
    cholesky::partition_uniform(&mut dag, b);
    let flat = dag.flat_dag();
    let mut t = Table::new(&["tasks", "edges", "width", "longest path", "depth"]);
    t.row(&[
        flat.len().to_string(),
        flat.edge_count().to_string(),
        flat.width().to_string(),
        flat.longest_path_len().to_string(),
        dag.depth().to_string(),
    ]);
    t.print();
    let s = n / b;
    assert_eq!(flat.len() as u64, cholesky::task_count(s as u64));
    let dot = dag.to_dot();
    std::fs::create_dir_all("bench_out").ok();
    std::fs::write("bench_out/fig2a_dag.dot", &dot).ok();
    println!("DOT ({} bytes) -> bench_out/fig2a_dag.dot", dot.len());

    println!("\n== FIG 2b: compute-load trace on BUJARUELO ==");
    let p = Platform::from_file("configs/bujaruelo.toml").expect("config");
    let sim = SimConfig::new(SchedConfig::new(Ordering::Fcfs, ProcSelect::EarliestIdle)).with_elem_bytes(p.elem_bytes);
    let sched = simulate(&dag, &p.machine, &p.db, sim);
    let trace = load_trace(&sched, 60);
    for (tt, active) in &trace {
        println!("  t={tt:7.4}s |{}", "#".repeat(*active));
    }
    let csv: String = std::iter::once("time_s,active\n".to_string())
        .chain(trace.iter().map(|(t, a)| format!("{t:.6},{a}\n")))
        .collect();
    std::fs::write("bench_out/fig2b_load.csv", csv).ok();
    println!("CSV -> bench_out/fig2b_load.csv");

    println!("\n== hot-path timings ==");
    Bench::new("partition_uniform(16384/1024)").samples(10).run(|| {
        let mut d = cholesky::root(n);
        cholesky::partition_uniform(&mut d, b);
        d
    });
    Bench::new("flat_dag(680 tasks)").samples(10).run(|| dag.flat_dag());
    let mut big = cholesky::root(32_768);
    cholesky::partition_uniform(&mut big, 512);
    Bench::new("flat_dag(45760 tasks)").samples(5).run(|| big.flat_dag());
}
