//! Engine microbenchmarks — the §Perf hot paths: dependence derivation,
//! simulation throughput per policy, coherence closure queries, and the
//! solver's candidate collection. Used before/after every optimization in
//! EXPERIMENTS.md §Perf.

use hesp::bench::Bench;
use hesp::config::Platform;
use hesp::coordinator::coherence::{CachePolicy, Coherence};
use hesp::coordinator::engine::{simulate, SimConfig};
use hesp::coordinator::partitioners::{cholesky, PartitionerSet};
use hesp::coordinator::policies::{Ordering, ProcSelect, SchedConfig};
use hesp::coordinator::region::Region;
use hesp::coordinator::solver::{solve, SolverConfig};

fn main() {
    let p = Platform::from_file("configs/bujaruelo.toml").expect("config");

    // -- dependence derivation at three scales --
    for (n, b) in [(16_384u32, 1_024u32), (32_768, 1_024), (32_768, 512)] {
        let mut dag = cholesky::root(n);
        cholesky::partition_uniform(&mut dag, b);
        let tasks = dag.frontier().len();
        Bench::new(&format!("flat_dag n={n} b={b} ({tasks} tasks)")).samples(5).run(|| dag.flat_dag());
    }

    // -- simulation throughput per policy (n=32768, b=1024: 5984 tasks) --
    let mut dag = cholesky::root(32_768);
    cholesky::partition_uniform(&mut dag, 1_024);
    for (o, s, label) in [
        (Ordering::Fcfs, ProcSelect::EarliestIdle, "FCFS/EIT-P"),
        (Ordering::Fcfs, ProcSelect::Random, "FCFS/R-P"),
        (Ordering::PriorityList, ProcSelect::EarliestFinish, "PL/EFT-P"),
    ] {
        let sim = SimConfig::new(SchedConfig::new(o, s)).with_elem_bytes(p.elem_bytes);
        Bench::new(&format!("simulate 5984 tasks {label}")).samples(5).run(|| simulate(&dag, &p.machine, &p.db, sim));
    }

    // -- coherence closure under deep nesting --
    Bench::new("coherence write closure (4-level nest)").samples(10).run(|| {
        let mut coh = Coherence::new(4, 0, CachePolicy::WriteBack, vec![u64::MAX; 4], 4);
        let mut blocks = Vec::new();
        for level in [4096u32, 1024, 256, 64] {
            for i in 0..(4096 / level).min(8) {
                blocks.push(coh.register(Region::new(0, i * level, (i + 1) * level, 0, level)));
            }
        }
        for (k, &b) in blocks.iter().enumerate() {
            coh.complete_write(b, k % 4);
        }
        coh
    });

    // -- one full solver iteration loop (collect+apply dominated) --
    let sim = SimConfig::new(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish))
        .with_elem_bytes(p.elem_bytes);
    let mut small = cholesky::root(16_384);
    cholesky::partition_uniform(&mut small, 2_048);
    let parts = PartitionerSet::standard();
    Bench::new("solver 20 iterations (16384/2048 start)").samples(3).run(|| {
        solve(small.clone(), &p.machine, &p.db, &parts, SolverConfig::all_soft(sim, 20, 128))
    });
}
