//! FIG5-left regenerator: framework validation against a *real* runtime.
//!
//! The paper compared HeSP's replicated schedules (HESP-REPLICA-PM with
//! analytic models, HESP-REPLICA-RD with measured task delays) against the
//! best of 20 OmpSs runs per grain size. Our real runtime is the PJRT CPU
//! client executing the AOT JAX/Pallas kernels (runtime::executor); the
//! same three-way comparison is reported per tile size.
//!
//! Skips politely when `make artifacts` has not been run.

use hesp::bench::Table;
use hesp::config::Platform;
use hesp::coordinator::engine::{simulate_mapped, SimConfig};
use hesp::coordinator::partitioners::cholesky;
use hesp::coordinator::policies::{Ordering, ProcSelect, SchedConfig};
use hesp::runtime::executor;
use hesp::util::cli::Args;

fn main() {
    if !executor::artifacts_available() {
        eprintln!("SKIP fig5_validation: artifacts/ missing — run `make artifacts`");
        return;
    }
    let args = Args::from_env();
    let n = args.usize_or("n", 512) as u32;
    let tiles: Vec<u32> = args.usize_list("tiles", &[32, 64, 128]).into_iter().map(|x| x as u32).collect();
    let reps = args.usize_or("reps", 3);

    println!("== FIG 5 (left): real PJRT execution vs HESP-REPLICA (n={n}) ==");
    let rt = executor::load_f32_runtime(&tiles).expect("artifacts");
    let local = Platform::from_file("configs/local.toml").expect("config");
    let sim = SimConfig::new(SchedConfig::new(Ordering::Fcfs, ProcSelect::EarliestIdle));

    let mut table = Table::new(&["b", "tasks", "real s", "real GFLOPS", "PM s", "RD s", "PM err %", "RD err %", "max err"]);
    let mut csv = String::from("b,real_s,pm_s,rd_s\n");
    for &b in &tiles {
        if n % b != 0 || n / b < 2 {
            continue;
        }
        let real = executor::run_cholesky(&rt, n, b, 42).expect("execution");
        assert!(real.max_err < 1e-2, "numerics check failed: {}", real.max_err);
        let measures = executor::measure_models(&rt, &[b], reps, 7).expect("measure");
        let rd_db = executor::measured_perfdb(&measures);

        let mut dag = cholesky::root(n);
        cholesky::partition_uniform(&mut dag, b);
        let mapping = vec![0usize; dag.frontier().len()];
        let pm = simulate_mapped(&dag, &local.machine, &local.db, sim, &mapping);
        let rd = simulate_mapped(&dag, &local.machine, &rd_db, sim, &mapping);

        table.row(&[
            b.to_string(),
            dag.frontier().len().to_string(),
            format!("{:.3}", real.total_s),
            format!("{:.3}", real.gflops()),
            format!("{:.3}", pm.makespan),
            format!("{:.3}", rd.makespan),
            format!("{:+.1}", 100.0 * (pm.makespan - real.total_s) / real.total_s),
            format!("{:+.1}", 100.0 * (rd.makespan - real.total_s) / real.total_s),
            format!("{:.1e}", real.max_err),
        ]);
        csv.push_str(&format!("{b},{:.6},{:.6},{:.6}\n", real.total_s, pm.makespan, rd.makespan));
    }
    table.print();
    std::fs::create_dir_all("bench_out").ok();
    std::fs::write("bench_out/fig5_left.csv", csv).ok();
    println!("\nsemantics: RD (measured delays) tracks reality within noise; the");
    println!("PM-RD gap is model error; the RD-real gap is runtime overhead (§3.1).");
    println!("CSV -> bench_out/fig5_left.csv");
}
