//! TABLE 1 regenerator, on the parallel sweep harness: for every
//! registered scheduling policy — the 8 paper configurations (`fcfs/r-p`
//! ... `pl/eft-p`) plus the two policy extensions (`pl/affinity`,
//! `pl/lookahead`) — on BUJARUELO (n=32768 f32) and ODROID (n=8192 f64),
//! the best homogeneous tiling vs the heterogeneous partition found by
//! the iterative scheduler-partitioner (All/Soft), with the paper's
//! companion metrics: average load, optimal/average block size proxy, DAG
//! depth, and bytes moved (the column where `pl/affinity` earns its
//! keep).
//!
//! Two sweep phases per platform, both executed across worker threads:
//! phase 1 simulates the full policy x tile grid, phase 2 runs one solver
//! cell per policy from its best homogeneous tile. Phase 2's cells run
//! the portfolio solver (`--lanes M --batch K`, default the classic 1x1):
//! with 10 solve cells and T threads the leftover budget T/10 flows into
//! each cell's portfolio automatically.
//!
//! Flags: --iters N (default 250), --threads T, --lanes M, --batch K,
//! --quick (smaller problems for CI).

use hesp::bench::Table;
use hesp::coordinator::coherence::CachePolicy;
use hesp::coordinator::delta::DeltaMode;
use hesp::coordinator::policy::PolicyRegistry;
use hesp::coordinator::sweep::{self, CellMode, SweepCell, SweepGrid, SweepPlatform, Workload};
use hesp::util::cli::Args;

#[allow(clippy::too_many_arguments)]
fn run_platform(
    config: &str,
    n: u32,
    tiles: &[u32],
    min_edge: u32,
    iters: usize,
    threads: usize,
    portfolio: (usize, usize),
    csv: &mut String,
) {
    let platform = SweepPlatform::from_file(config).expect("config");
    let machine_name = platform.name.clone();
    let policies: Vec<String> = PolicyRegistry::standard().names().iter().map(|s| s.to_string()).collect();
    println!("\n== TABLE 1 — {machine_name} ({n}x{n} Cholesky) ==");

    // phase 1: the homogeneous policy x tile grid, in parallel
    let grid = SweepGrid {
        platforms: vec![platform],
        workloads: vec![Workload::Cholesky { n }],
        policies: policies.clone(),
        tiles: tiles.to_vec(),
        modes: vec![CellMode::Simulate],
        seeds: vec![0],
        cache: CachePolicy::WriteBack,
        solve_lanes: portfolio.0,
        solve_batch: portfolio.1,
        delta: DeltaMode::Auto,
        faults: vec![None],
        fault_members: 3,
    };
    let hom = sweep::run_sweep(&grid, threads);

    // phase 2: per policy, solve from the best homogeneous tile
    let best_hom: Vec<&sweep::CellResult> = policies
        .iter()
        .map(|pol| {
            hom.iter()
                .filter(|r| &r.policy == pol)
                .min_by(|a, b| a.makespan.total_cmp(&b.makespan))
                .expect("legal tiles")
        })
        .collect();
    let cells: Vec<SweepCell> = best_hom
        .iter()
        .map(|best| SweepCell {
            platform: 0,
            workload: Workload::Cholesky { n },
            policy: best.policy.clone(),
            tile: best.tile,
            mode: CellMode::Solve { iters, min_edge },
            seed: 0,
        })
        .collect();
    let het = sweep::run_cells(&grid, &cells, threads);

    let mut table = Table::new(&[
        "Policy", "Hom GFLOPS", "Hom block", "Het GFLOPS", "Improve %", "Het load %", "Depth",
        "Het xfer MB", "Failed moves",
    ]);
    for (best, r) in best_hom.iter().zip(&het) {
        // Hom columns come from the phase-1 sim that actually selected the
        // tile (the solve cell's own mode-keyed seed gives seed-sensitive
        // r-p policies a different baseline draw); the never-lose
        // assertion below uses the solve cell's internal baseline, which
        // shares the solver's seed and is therefore exact.
        let improve = if best.gflops > 0.0 { 100.0 * (r.gflops - best.gflops) / best.gflops } else { 0.0 };
        table.row(&[
            r.policy.clone(),
            format!("{:.2}", best.gflops),
            best.tile.to_string(),
            format!("{:.2}", r.gflops),
            format!("{improve:.2}"),
            format!("{:.1}", r.avg_load_pct),
            r.dag_depth.to_string(),
            format!("{:.1}", r.transfer_bytes as f64 / 1e6),
            r.failed_moves.to_string(),
        ]);
        csv.push_str(&format!(
            "{},{},{:.2},{},{:.2},{improve:.2},{:.1},{},{}\n",
            machine_name,
            r.policy,
            best.gflops,
            best.tile,
            r.gflops,
            r.avg_load_pct,
            r.dag_depth,
            r.transfer_bytes
        ));
        // paper invariant: heterogeneous never loses (the solver keeps the
        // best state seen, and the initial state IS the homogeneous one)
        assert!(r.gflops >= r.hom_gflops * 0.999, "{}: heterog must not lose", r.policy);
    }
    table.print();
}

fn main() {
    let args = Args::from_env();
    let iters = args.usize_or("iters", 250);
    let threads = args.usize_or("threads", sweep::default_threads());
    let portfolio = (args.usize_or("lanes", 1).max(1), args.usize_or("batch", 1).max(1));
    let quick = args.has("quick");
    let mut csv = String::from(
        "platform,policy,hom_gflops,hom_block,het_gflops,improve_pct,het_load,depth,het_transfer_bytes\n",
    );
    if quick {
        run_platform("configs/bujaruelo.toml", 16_384, &[512, 1024, 2048, 4096], 128, iters.min(120), threads, portfolio, &mut csv);
        run_platform("configs/odroid.toml", 4_096, &[128, 256, 512, 1024], 64, iters.min(120), threads, portfolio, &mut csv);
    } else {
        run_platform("configs/bujaruelo.toml", 32_768, &[512, 1024, 2048, 4096], 128, iters, threads, portfolio, &mut csv);
        run_platform("configs/odroid.toml", 8_192, &[128, 256, 512, 1024], 64, iters, threads, portfolio, &mut csv);
    }
    std::fs::create_dir_all("bench_out").ok();
    std::fs::write("bench_out/table1.csv", csv).ok();
    println!("\nCSV -> bench_out/table1.csv");
}
