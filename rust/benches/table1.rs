//! TABLE 1 regenerator: for each of the 8 scheduling configurations on
//! BUJARUELO (n=32768 f32) and ODROID (n=8192 f64), the best homogeneous
//! tiling vs the heterogeneous partition found by the iterative
//! scheduler-partitioner (All/Soft), with the paper's companion metrics:
//! average load, optimal/average block size and DAG depth.
//!
//! Flags: --iters N (default 250), --quick (smaller problems for CI).

use hesp::bench::Table;
use hesp::config::Platform;
use hesp::coordinator::energy::Objective;
use hesp::coordinator::engine::SimConfig;
use hesp::coordinator::metrics::report;
use hesp::coordinator::partitioners::PartitionerSet;
use hesp::coordinator::policies::SchedConfig;
use hesp::coordinator::solver::{best_homogeneous, solve, SolverConfig};
use hesp::util::cli::Args;

fn run_platform(config: &str, n: u32, tiles: &[u32], min_edge: u32, iters: usize, csv: &mut String) {
    let p = Platform::from_file(config).expect("config");
    println!(
        "\n== TABLE 1 — {} ({}x{} Cholesky, f{}) ==",
        p.machine.name,
        n,
        n,
        p.elem_bytes * 8
    );
    let mut table = Table::new(&[
        "Config", "Hom GFLOPS", "Hom load %", "Hom block", "Het GFLOPS", "Improve %", "Het load %", "Het avg blk", "Depth",
    ]);
    let parts = PartitionerSet::standard();
    for row in SchedConfig::table1_rows() {
        let sim = SimConfig::new(row).with_elem_bytes(p.elem_bytes);
        let (hb, hdag, hsched) =
            best_homogeneous(n, tiles, &p.machine, &p.db, sim, Objective::Makespan).expect("legal tiles");
        let hr = report(&hdag, &hsched);
        let cfg = SolverConfig::all_soft(sim, iters, min_edge);
        let res = solve(hdag, &p.machine, &p.db, &parts, cfg);
        let er = report(&res.best_dag, &res.best_schedule);
        let improve = 100.0 * (er.gflops - hr.gflops) / hr.gflops;
        table.row(&[
            row.name(),
            format!("{:.2}", hr.gflops),
            format!("{:.1}", hr.avg_load_pct),
            hb.to_string(),
            format!("{:.2}", er.gflops),
            format!("{:.2}", improve),
            format!("{:.1}", er.avg_load_pct),
            format!("{:.1}", er.avg_block_size),
            er.dag_depth.to_string(),
        ]);
        csv.push_str(&format!(
            "{},{},{:.2},{:.1},{},{:.2},{:.2},{:.1},{:.1},{}\n",
            p.machine.name, row.name(), hr.gflops, hr.avg_load_pct, hb, er.gflops, improve, er.avg_load_pct, er.avg_block_size, er.dag_depth
        ));
        // paper invariant: heterogeneous never loses
        assert!(er.gflops >= hr.gflops * 0.999, "{}: heterog must not lose", row.name());
    }
    table.print();
}

fn main() {
    let args = Args::from_env();
    let iters = args.usize_or("iters", 250);
    let quick = args.has("quick");
    let mut csv = String::from("platform,config,hom_gflops,hom_load,hom_block,het_gflops,improve_pct,het_load,het_avg_block,depth\n");
    if quick {
        run_platform("configs/bujaruelo.toml", 16_384, &[512, 1024, 2048, 4096], 128, iters.min(120), &mut csv);
        run_platform("configs/odroid.toml", 4_096, &[128, 256, 512, 1024], 64, iters.min(120), &mut csv);
    } else {
        run_platform("configs/bujaruelo.toml", 32_768, &[512, 1024, 2048, 4096], 128, iters, &mut csv);
        run_platform("configs/odroid.toml", 8_192, &[128, 256, 512, 1024], 64, iters, &mut csv);
    }
    std::fs::create_dir_all("bench_out").ok();
    std::fs::write("bench_out/table1.csv", csv).ok();
    println!("\nCSV -> bench_out/table1.csv");
}
