//! TABLE 1 regenerator: for every registered scheduling policy — the 8
//! paper configurations (`fcfs/r-p` ... `pl/eft-p`) plus the two policy
//! extensions (`pl/affinity`, `pl/lookahead`) — on BUJARUELO (n=32768
//! f32) and ODROID (n=8192 f64), the best homogeneous tiling vs the
//! heterogeneous partition found by the iterative scheduler-partitioner
//! (All/Soft), with the paper's companion metrics: average load,
//! optimal/average block size, DAG depth, and bytes moved (the column
//! where `pl/affinity` earns its keep).
//!
//! Flags: --iters N (default 250), --quick (smaller problems for CI).

use hesp::bench::Table;
use hesp::config::Platform;
use hesp::coordinator::energy::Objective;
use hesp::coordinator::engine::SimConfig;
use hesp::coordinator::metrics::report;
use hesp::coordinator::partitioners::PartitionerSet;
use hesp::coordinator::policies::{Ordering, ProcSelect, SchedConfig};
use hesp::coordinator::policy::PolicyRegistry;
use hesp::coordinator::solver::{best_homogeneous_with, solve_with, SolverConfig};
use hesp::util::cli::Args;

fn run_platform(config: &str, n: u32, tiles: &[u32], min_edge: u32, iters: usize, csv: &mut String) {
    let p = Platform::from_file(config).expect("config");
    println!(
        "\n== TABLE 1 — {} ({}x{} Cholesky, f{}) ==",
        p.machine.name,
        n,
        n,
        p.elem_bytes * 8
    );
    let mut table = Table::new(&[
        "Policy", "Hom GFLOPS", "Hom load %", "Hom block", "Het GFLOPS", "Improve %", "Het load %", "Het avg blk", "Depth", "Het xfer MB",
    ]);
    let parts = PartitionerSet::standard();
    let reg = PolicyRegistry::standard();
    // shim fields are ignored by the `_with` paths; cache/elem/seed matter
    let sim = SimConfig::new(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish))
        .with_elem_bytes(p.elem_bytes);
    for name in reg.names() {
        let mut pol = reg.get(name).expect("registered policy constructs");
        let (hb, hdag, hsched) =
            best_homogeneous_with(n, tiles, &p.machine, &p.db, sim, Objective::Makespan, pol.as_mut())
                .expect("legal tiles");
        let hr = report(&hdag, &hsched);
        let cfg = SolverConfig::all_soft(sim, iters, min_edge);
        let res = solve_with(hdag, &p.machine, &p.db, &parts, cfg, pol.as_mut());
        let er = report(&res.best_dag, &res.best_schedule);
        let improve = 100.0 * (er.gflops - hr.gflops) / hr.gflops;
        table.row(&[
            name.to_string(),
            format!("{:.2}", hr.gflops),
            format!("{:.1}", hr.avg_load_pct),
            hb.to_string(),
            format!("{:.2}", er.gflops),
            format!("{:.2}", improve),
            format!("{:.1}", er.avg_load_pct),
            format!("{:.1}", er.avg_block_size),
            er.dag_depth.to_string(),
            format!("{:.1}", er.transfer_bytes as f64 / 1e6),
        ]);
        csv.push_str(&format!(
            "{},{},{:.2},{:.1},{},{:.2},{:.2},{:.1},{:.1},{},{}\n",
            p.machine.name,
            name,
            hr.gflops,
            hr.avg_load_pct,
            hb,
            er.gflops,
            improve,
            er.avg_load_pct,
            er.avg_block_size,
            er.dag_depth,
            er.transfer_bytes
        ));
        // paper invariant: heterogeneous never loses (the solver keeps the
        // best state seen, and the initial state IS the homogeneous one)
        assert!(er.gflops >= hr.gflops * 0.999, "{name}: heterog must not lose");
    }
    table.print();
}

fn main() {
    let args = Args::from_env();
    let iters = args.usize_or("iters", 250);
    let quick = args.has("quick");
    let mut csv = String::from(
        "platform,policy,hom_gflops,hom_load,hom_block,het_gflops,improve_pct,het_load,het_avg_block,depth,het_transfer_bytes\n",
    );
    if quick {
        run_platform("configs/bujaruelo.toml", 16_384, &[512, 1024, 2048, 4096], 128, iters.min(120), &mut csv);
        run_platform("configs/odroid.toml", 4_096, &[128, 256, 512, 1024], 64, iters.min(120), &mut csv);
    } else {
        run_platform("configs/bujaruelo.toml", 32_768, &[512, 1024, 2048, 4096], 128, iters, &mut csv);
        run_platform("configs/odroid.toml", 8_192, &[128, 256, 512, 1024], 64, iters, &mut csv);
    }
    std::fs::create_dir_all("bench_out").ok();
    std::fs::write("bench_out/table1.csv", csv).ok();
    println!("\nCSV -> bench_out/table1.csv");
}
