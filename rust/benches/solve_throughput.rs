//! Solve-throughput bench: candidate-evaluation rate of the portfolio
//! solver with incremental re-simulation (`--delta on`) vs full
//! re-simulation, on a 1000+-task Cholesky frontier. The two runs must
//! produce byte-identical canonical solver JSON — the bench doubles as an
//! equivalence gate — and the wall-clock ratio plus the replay counters
//! land in `bench_out/BENCH_solve.json` for the perf trajectory.
//!
//! Flags: --n N --tile B --iters K --batch K --threads T
//!        --quick (CI-sized problem) --out FILE.json

use hesp::config::Platform;
use hesp::coordinator::delta::DeltaMode;
use hesp::coordinator::engine::SimConfig;
use hesp::coordinator::partitioners::{cholesky, PartitionerSet};
use hesp::coordinator::policies::{Ordering, ProcSelect, SchedConfig};
use hesp::coordinator::policy::PolicyRegistry;
use hesp::coordinator::solver::{result_json, solve_portfolio, PortfolioConfig, SolveResult, SolverConfig};
use hesp::util::cli::Args;
use hesp::util::json::Json;

fn main() {
    let args = Args::from_env();
    let quick = args.has("quick");
    // n/b = 18 tiles -> 1140 frontier tasks, the issue's 1000+-task scale;
    // --quick shrinks to 8 tiles (120 tasks) for CI wall-clock
    let n = args.usize_or("n", if quick { 4096 } else { 18_432 }) as u32;
    let b = args.usize_or("tile", if quick { 512 } else { 1024 }) as u32;
    let iters = args.usize_or("iters", if quick { 10 } else { 40 });
    let batch = args.usize_or("batch", 8);
    let threads = args.usize_or("threads", 1);
    let p = Platform::from_file("configs/bujaruelo.toml").expect("config");
    let mut dag = cholesky::root(n);
    cholesky::partition_uniform(&mut dag, b);
    let n_tasks = dag.frontier().len();
    println!(
        "solve-throughput: cholesky n={n} b={b} ({n_tasks} frontier tasks), \
         {iters} iters x {batch}-candidate batches, {threads} threads"
    );

    let parts = PartitionerSet::standard();
    let reg = PolicyRegistry::standard();
    let sim = SimConfig::new(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish))
        .with_elem_bytes(p.elem_bytes);
    let base = SolverConfig::all_soft(sim, iters, b / 4);

    let run = |delta: DeltaMode| -> (SolveResult, f64) {
        let mut pcfg = PortfolioConfig::new(base);
        pcfg.batch = batch;
        pcfg.threads = threads;
        pcfg.delta = delta;
        let t0 = std::time::Instant::now();
        let res = solve_portfolio(&dag, &p.machine, &p.db, &parts, &reg, "pl/eft-p", &pcfg);
        (res, t0.elapsed().as_secs_f64())
    };

    let (r_off, t_off) = run(DeltaMode::Off);
    let (r_on, t_on) = run(DeltaMode::On);
    // the gate half of the bench: both modes walked the same trajectory
    assert_eq!(
        result_json(&r_off),
        result_json(&r_on),
        "delta changed the canonical solve bytes"
    );

    let evals: usize = r_off.history.iter().map(|h| h.evaluated).sum();
    let rate_off = evals as f64 / t_off.max(1e-9);
    let rate_on = evals as f64 / t_on.max(1e-9);
    let speedup = t_off / t_on.max(1e-9);
    let st = r_on.replay_stats();
    println!(
        "full:  {evals} candidate evals in {t_off:.3}s  ({rate_off:.1} evals/s)\n\
         delta: {evals} candidate evals in {t_on:.3}s  ({rate_on:.1} evals/s)\n\
         speedup {speedup:.2}x  replay_frac {:.3}  ({}/{} events, {} cache hits, {} full fallbacks)",
        st.replay_fraction(),
        st.events_replayed,
        st.events_total,
        st.cache_hits,
        st.full_fallbacks
    );

    let mut o = std::collections::BTreeMap::new();
    o.insert("name".to_string(), Json::Str("solve_throughput".into()));
    o.insert("n".to_string(), Json::Num(n as f64));
    o.insert("tile".to_string(), Json::Num(b as f64));
    o.insert("n_tasks".to_string(), Json::Num(n_tasks as f64));
    o.insert("iters".to_string(), Json::Num(iters as f64));
    o.insert("batch".to_string(), Json::Num(batch as f64));
    o.insert("threads".to_string(), Json::Num(threads as f64));
    o.insert("candidate_evals".to_string(), Json::Num(evals as f64));
    o.insert("wall_full_s".to_string(), Json::Num(t_off));
    o.insert("wall_delta_s".to_string(), Json::Num(t_on));
    o.insert("evals_per_s_full".to_string(), Json::Num(rate_off));
    o.insert("evals_per_s_delta".to_string(), Json::Num(rate_on));
    o.insert("speedup".to_string(), Json::Num(speedup));
    o.insert("replay_frac".to_string(), Json::Num(st.replay_fraction()));
    o.insert("events_replayed".to_string(), Json::Num(st.events_replayed as f64));
    o.insert("events_total".to_string(), Json::Num(st.events_total as f64));
    o.insert("cache_hits".to_string(), Json::Num(st.cache_hits as f64));
    o.insert("full_fallbacks".to_string(), Json::Num(st.full_fallbacks as f64));
    let out = std::path::PathBuf::from(args.str_or("out", "bench_out/BENCH_solve.json"));
    if let Some(dir) = out.parent().filter(|d| !d.as_os_str().is_empty()) {
        std::fs::create_dir_all(dir).expect("create bench_out");
    }
    std::fs::write(&out, Json::Obj(o).to_string()).expect("write bench json");
    println!("bench record -> {}", out.display());
}
