//! Classic-scheduler gauntlet: HeSP's solve mode (joint
//! scheduling-partitioning) against tuned classic list schedulers —
//! HEFT (comm-aware upward ranks), PEFT (optimistic cost table) and DLS
//! (dynamic levels) — plus the paper's own PL/EFT-P row, on both
//! reference platforms (BUJARUELO CPU-GPU, ODROID big.LITTLE). The
//! figure of merit is `makespan / lower_bound` per policy: the classic
//! rows get their best homogeneous tile (phase 1 tunes each policy over
//! the tile axis), then every policy also runs one solve-mode cell from
//! that tile, so the table separates what a better *schedule* buys from
//! what a better *partition* buys (the paper's Table 1 / Fig 5 axis).
//!
//! The bench doubles as a determinism gate: both phases are re-run
//! single-threaded and must reproduce the parallel run's CSV bytes.
//!
//! Flags: --iters N (default 200), --threads T, --lanes M, --batch K,
//! --quick (smaller problems for CI), --out FILE.json

use std::collections::BTreeMap;

use hesp::bench::Table;
use hesp::coordinator::coherence::CachePolicy;
use hesp::coordinator::delta::DeltaMode;
use hesp::coordinator::sweep::{self, CellMode, SweepCell, SweepGrid, SweepPlatform, Workload};
use hesp::util::cli::Args;
use hesp::util::json::Json;

/// The gauntlet lineup: the paper's best list heuristic, then the three
/// classic baselines.
const POLICIES: [&str; 4] = ["pl/eft-p", "cls/heft", "cls/peft", "cls/dls"];

#[allow(clippy::too_many_arguments)]
fn run_platform(
    config: &str,
    n: u32,
    tiles: &[u32],
    min_edge: u32,
    iters: usize,
    threads: usize,
    portfolio: (usize, usize),
    record: &mut BTreeMap<String, Json>,
) {
    let platform = SweepPlatform::from_file(config).expect("config");
    let machine_name = platform.name.clone();
    println!("\n== GAUNTLET — {machine_name} ({n}x{n} Cholesky) ==");

    // phase 1: tune each policy's tile on the homogeneous grid
    let grid = SweepGrid {
        platforms: vec![platform],
        workloads: vec![Workload::Cholesky { n }],
        policies: POLICIES.iter().map(|s| s.to_string()).collect(),
        tiles: tiles.to_vec(),
        modes: vec![CellMode::Simulate],
        seeds: vec![0],
        cache: CachePolicy::WriteBack,
        solve_lanes: portfolio.0,
        solve_batch: portfolio.1,
        delta: DeltaMode::Auto,
        faults: vec![None],
        fault_members: 3,
    };
    let hom = sweep::run_sweep(&grid, threads);
    assert_eq!(
        sweep::to_csv(&hom),
        sweep::to_csv(&sweep::run_sweep(&grid, 1)),
        "{machine_name}: hom grid must not depend on the thread count"
    );

    // phase 2: per policy, one solve cell from its best homogeneous tile
    let best_hom: Vec<&sweep::CellResult> = POLICIES
        .iter()
        .map(|pol| {
            hom.iter()
                .filter(|r| r.policy == *pol)
                .min_by(|a, b| a.makespan.total_cmp(&b.makespan))
                .expect("legal tiles")
        })
        .collect();
    let cells: Vec<SweepCell> = best_hom
        .iter()
        .map(|best| SweepCell {
            platform: 0,
            workload: Workload::Cholesky { n },
            policy: best.policy.clone(),
            tile: best.tile,
            mode: CellMode::Solve { iters, min_edge },
            seed: 0,
        })
        .collect();
    let het = sweep::run_cells(&grid, &cells, threads);
    assert_eq!(
        sweep::to_csv(&het),
        sweep::to_csv(&sweep::run_cells(&grid, &cells, 1)),
        "{machine_name}: solve cells must not depend on the thread count"
    );

    let mut table = Table::new(&[
        "Policy", "Tile", "Hom mk/LB", "Hom GFLOPS", "Solve mk/LB", "Solve GFLOPS", "Improve %",
    ]);
    for (best, r) in best_hom.iter().zip(&het) {
        let improve = if best.gflops > 0.0 { 100.0 * (r.gflops - best.gflops) / best.gflops } else { 0.0 };
        table.row(&[
            r.policy.clone(),
            best.tile.to_string(),
            format!("{:.3}", best.makespan_over_lb),
            format!("{:.2}", best.gflops),
            format!("{:.3}", r.makespan_over_lb),
            format!("{:.2}", r.gflops),
            format!("{improve:.2}"),
        ]);
        // the solver keeps the best state seen, and it starts from the
        // homogeneous tiling — solve mode must never lose to its baseline
        assert!(r.gflops >= r.hom_gflops * 0.999, "{}: solve must not lose", r.policy);
        let mut row = BTreeMap::new();
        row.insert("tile".to_string(), Json::Num(best.tile as f64));
        row.insert("hom_makespan_over_lb".to_string(), Json::Num(best.makespan_over_lb));
        row.insert("hom_gflops".to_string(), Json::Num(best.gflops));
        row.insert("solve_makespan_over_lb".to_string(), Json::Num(r.makespan_over_lb));
        row.insert("solve_gflops".to_string(), Json::Num(r.gflops));
        record.insert(format!("{machine_name}/{}", r.policy), Json::Obj(row));
    }
    table.print();
}

fn main() {
    let args = Args::from_env();
    let quick = args.has("quick");
    let iters = {
        let i = args.usize_or("iters", 200);
        if quick {
            i.min(60)
        } else {
            i
        }
    };
    let threads = args.usize_or("threads", sweep::default_threads());
    let portfolio = (args.usize_or("lanes", 1).max(1), args.usize_or("batch", 1).max(1));
    let mut record = BTreeMap::new();
    record.insert("name".to_string(), Json::Str("gauntlet".into()));
    record.insert("iters".to_string(), Json::Num(iters as f64));
    if quick {
        run_platform("configs/bujaruelo.toml", 16_384, &[512, 1024, 2048, 4096], 128, iters, threads, portfolio, &mut record);
        run_platform("configs/odroid.toml", 4_096, &[128, 256, 512, 1024], 64, iters, threads, portfolio, &mut record);
    } else {
        run_platform("configs/bujaruelo.toml", 32_768, &[512, 1024, 2048, 4096], 128, iters, threads, portfolio, &mut record);
        run_platform("configs/odroid.toml", 8_192, &[128, 256, 512, 1024], 64, iters, threads, portfolio, &mut record);
    }
    let out = std::path::PathBuf::from(args.str_or("out", "bench_out/BENCH_gauntlet.json"));
    if let Some(dir) = out.parent().filter(|d| !d.as_os_str().is_empty()) {
        std::fs::create_dir_all(dir).expect("create bench_out");
    }
    std::fs::write(&out, Json::Obj(record).to_string()).expect("write bench json");
    println!("\nbench record -> {}", out.display());
}
