//! FIG5-right regenerator, on the parallel sweep harness: performance of
//! every *registered* scheduling policy (the 8 paper rows plus
//! `pl/affinity` and `pl/lookahead`) across homogeneous tile sizes on
//! BUJARUELO (n=32768, f32). The paper's three observations are checked
//! in-line: (1) the optimal tile depends on the policy, (2) each curve
//! peaks at an interior trade-off tile, (3) policy choice matters more at
//! large tiles.
//!
//! Flags: --n N, --tiles A,B,..., --threads T.

use hesp::bench::Table;
use hesp::coordinator::coherence::CachePolicy;
use hesp::coordinator::delta::DeltaMode;
use hesp::coordinator::policy::PolicyRegistry;
use hesp::coordinator::sweep::{self, CellMode, SweepGrid, SweepPlatform, Workload};
use hesp::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let n = args.usize_or("n", 32_768) as u32;
    let tiles: Vec<u32> =
        args.usize_list("tiles", &[512, 1024, 2048, 4096]).into_iter().map(|x| x as u32).collect();
    let threads = args.usize_or("threads", sweep::default_threads());
    let platform = SweepPlatform::from_file("configs/bujaruelo.toml").expect("config");
    let machine_name = platform.name.clone();
    println!("== FIG 5 (right): policies x tile size, {machine_name} n={n} ==");

    let policies: Vec<String> = PolicyRegistry::standard().names().iter().map(|s| s.to_string()).collect();
    let grid = SweepGrid {
        platforms: vec![platform],
        workloads: vec![Workload::Cholesky { n }],
        policies: policies.clone(),
        tiles,
        modes: vec![CellMode::Simulate],
        seeds: vec![0],
        cache: CachePolicy::WriteBack,
        solve_lanes: 1,
        solve_batch: 1,
        delta: DeltaMode::Off,
        faults: vec![None],
        fault_members: 3,
    };
    let results = sweep::run_sweep(&grid, threads);

    let mut table = Table::new(&["policy", "tile", "GFLOPS", "load %", "makespan s", "xfer MB"]);
    let mut series: Vec<(String, Vec<(u32, f64)>)> = Vec::new();
    for name in &policies {
        let mut pts = Vec::new();
        for r in results.iter().filter(|r| &r.policy == name) {
            table.row(&[
                r.policy.clone(),
                r.tile.to_string(),
                format!("{:.1}", r.gflops),
                format!("{:.1}", r.avg_load_pct),
                format!("{:.4}", r.makespan),
                format!("{:.1}", r.transfer_bytes as f64 / 1e6),
            ]);
            pts.push((r.tile, r.gflops));
        }
        series.push((name.clone(), pts));
    }
    table.print();

    // paper fact 1: optimal tile differs between policies
    let optima: Vec<(String, u32)> = series
        .iter()
        .map(|(name, pts)| {
            let best = pts.iter().max_by(|a, b| a.1.total_cmp(&b.1)).unwrap();
            (name.clone(), best.0)
        })
        .collect();
    println!("\nper-policy optimal tiles: {optima:?}");
    let distinct: std::collections::BTreeSet<u32> = optima.iter().map(|x| x.1).collect();
    println!("distinct optima across policies: {distinct:?} (paper: optimum depends on policy)");

    // paper fact 3: spread between best and worst policy grows with tile
    for &b in &grid.tiles {
        let vals: Vec<f64> =
            series.iter().filter_map(|(_, pts)| pts.iter().find(|x| x.0 == b).map(|x| x.1)).collect();
        let (min, max) =
            (vals.iter().cloned().fold(f64::INFINITY, f64::min), vals.iter().cloned().fold(0.0, f64::max));
        println!("tile {b:>5}: policy spread {:.2}x", max / min);
    }

    std::fs::create_dir_all("bench_out").ok();
    let mut csv = String::from("config,tile,gflops\n");
    for (name, pts) in &series {
        for (b, g) in pts {
            csv.push_str(&format!("{name},{b},{g:.2}\n"));
        }
    }
    std::fs::write("bench_out/fig5_right.csv", csv).ok();
    // the full per-cell bundle rides along for the perf trajectory, under
    // fig5-specific names so it cannot clobber `hesp sweep`'s sweep.csv
    std::fs::write("bench_out/fig5_cells.csv", sweep::to_csv(&results)).ok();
    std::fs::write("bench_out/fig5_cells.json", sweep::to_json(&results)).ok();
    println!("CSV -> bench_out/fig5_right.csv (+ fig5_cells.csv / fig5_cells.json per-cell bundle)");
}
