//! Ablations over HeSP's design choices (DESIGN.md §Key design decisions):
//!
//! * candidate selection (All vs CP vs Shallow) x sampling (Hard vs Soft)
//!   — the paper's §2.1 partition-stage knobs;
//! * merge/re-partition moves on vs off;
//! * caching policy (WB vs WT vs WA) impact on makespan + traffic;
//! * iterative (offline bound-explorer) vs constructive (online, §4);
//! * iteration budget sensitivity;
//! * portfolio width: restart lanes x candidate-batch size x threads —
//!   search quality and wall-clock of the parallel portfolio solver.

use hesp::bench::Table;
use hesp::config::Platform;
use hesp::coordinator::coherence::CachePolicy;
use hesp::coordinator::energy::Objective;
use hesp::coordinator::engine::{simulate, SimConfig};
use hesp::coordinator::metrics::report;
use hesp::coordinator::partitioners::{cholesky, PartitionerSet};
use hesp::coordinator::policies::{Ordering, ProcSelect, SchedConfig};
use hesp::coordinator::delta::DeltaMode;
use hesp::coordinator::policy::PolicyRegistry;
use hesp::coordinator::solver::{
    best_homogeneous, solve, solve_portfolio, CandidateSelect, PortfolioConfig, Sampling, SolverConfig,
};
use hesp::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let n = args.usize_or("n", 16_384) as u32;
    let iters = args.usize_or("iters", 120);
    let tiles = [512u32, 1024, 2048, 4096];
    let p = Platform::from_file("configs/bujaruelo.toml").expect("config");
    let sim = SimConfig::new(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish))
        .with_elem_bytes(p.elem_bytes);
    let parts = PartitionerSet::standard();
    let (_, hdag, hsched) = best_homogeneous(n, &tiles, &p.machine, &p.db, sim, Objective::Makespan).unwrap();
    let base = hsched.makespan;
    println!("baseline: best homogeneous makespan {base:.4}s (n={n})");

    println!("\n== ablation 1: candidate selection x sampling ==");
    let mut t = Table::new(&["candidates", "sampling", "best makespan s", "improve %", "iters to best"]);
    for cs in [CandidateSelect::All, CandidateSelect::CriticalPath, CandidateSelect::Shallow] {
        for sm in [Sampling::Hard, Sampling::Soft] {
            let mut cfg = SolverConfig::all_soft(sim, iters, 128);
            cfg.candidates = cs;
            cfg.sampling = sm;
            let res = solve(hdag.clone(), &p.machine, &p.db, &parts, cfg);
            t.row(&[
                cs.name().to_string(),
                sm.name().to_string(),
                format!("{:.4}", res.best_cost),
                format!("{:.2}", 100.0 * (base - res.best_cost) / res.best_cost),
                res.best_iter.to_string(),
            ]);
        }
    }
    t.print();

    println!("\n== ablation 2: merge/re-partition moves ==");
    let mut t = Table::new(&["allow_merge", "best makespan s", "improve %"]);
    for merge in [true, false] {
        let mut cfg = SolverConfig::all_soft(sim, iters, 128);
        cfg.allow_merge = merge;
        let res = solve(hdag.clone(), &p.machine, &p.db, &parts, cfg);
        t.row(&[
            merge.to_string(),
            format!("{:.4}", res.best_cost),
            format!("{:.2}", 100.0 * (base - res.best_cost) / res.best_cost),
        ]);
    }
    t.print();

    println!("\n== ablation 3: caching policy (homogeneous b=1024) ==");
    let mut t = Table::new(&["policy", "makespan s", "GFLOPS", "transferred MB"]);
    let mut dag = cholesky::root(n);
    cholesky::partition_uniform(&mut dag, 1024);
    for cp in [CachePolicy::WriteBack, CachePolicy::WriteThrough, CachePolicy::WriteAround] {
        let sched = simulate(&dag, &p.machine, &p.db, sim.with_cache(cp));
        let r = report(&dag, &sched);
        t.row(&[
            cp.name().to_string(),
            format!("{:.4}", r.makespan),
            format!("{:.1}", r.gflops),
            format!("{:.1}", r.transfer_bytes as f64 / 1e6),
        ]);
    }
    t.print();

    println!("\n== ablation 4: iterative (offline) vs constructive (online, paper §4) ==");
    {
        use hesp::coordinator::constructive::{schedule_online, OnlineConfig};
        use std::time::Instant;
        let mut t = Table::new(&["scheme", "makespan s", "improve %", "decision time"]);
        let t0 = Instant::now();
        let res = solve(hdag.clone(), &p.machine, &p.db, &parts, SolverConfig::all_soft(sim, iters, 128));
        let iter_time = t0.elapsed().as_secs_f64();
        t.row(&[
            format!("iterative({iters})"),
            format!("{:.4}", res.best_cost),
            format!("{:.2}", 100.0 * (base - res.best_cost) / res.best_cost),
            format!("{iter_time:.2}s"),
        ]);
        let t0 = Instant::now();
        let on = schedule_online(&hdag, &p.machine, &p.db, &parts, OnlineConfig::new(sim, 128));
        let on_time = t0.elapsed().as_secs_f64();
        t.row(&[
            format!("constructive({} splits)", on.splits),
            format!("{:.4}", on.schedule.makespan),
            format!("{:.2}", 100.0 * (base - on.schedule.makespan) / on.schedule.makespan),
            format!("{on_time:.2}s"),
        ]);
        t.print();
        println!("(the paper positions the iterative solver as the bound-explorer and");
        println!(" the constructive one as what a real runtime would implement)");
    }

    println!("\n== ablation 5: iteration budget ==");
    let mut t = Table::new(&["iters", "best makespan s", "improve %"]);
    for it in [10usize, 40, 120, 300] {
        let cfg = SolverConfig::all_soft(sim, it, 128);
        let res = solve(hdag.clone(), &p.machine, &p.db, &parts, cfg);
        t.row(&[
            it.to_string(),
            format!("{:.4}", res.best_cost),
            format!("{:.2}", 100.0 * (base - res.best_cost) / res.best_cost),
        ]);
    }
    t.print();

    let threads = args.usize_or("threads", 4);
    println!("\n== ablation 6: portfolio width (lanes x batch, {threads} threads) ==");
    let reg = PolicyRegistry::standard();
    let mut t = Table::new(&["lanes", "batch", "best makespan s", "improve %", "winning lane", "wall s"]);
    for lanes in [1usize, 2, 4] {
        for batch in [1usize, 4] {
            let cfg = SolverConfig::all_soft(sim, iters, 128);
            let pcfg = PortfolioConfig {
                base: cfg,
                batch,
                lanes,
                threads,
                lane_specs: Vec::new(),
                delta: DeltaMode::Auto,
                faults: None,
            };
            let t0 = std::time::Instant::now();
            let res = solve_portfolio(&hdag, &p.machine, &p.db, &parts, &reg, "pl/eft-p", &pcfg);
            let dt = t0.elapsed().as_secs_f64();
            t.row(&[
                lanes.to_string(),
                batch.to_string(),
                format!("{:.4}", res.best_cost),
                format!("{:.2}", 100.0 * (base - res.best_cost) / res.best_cost),
                res.lane.to_string(),
                format!("{dt:.2}"),
            ]);
            // a wider portfolio can only match or beat its own lane 0
            assert!(res.best_cost <= res.lane_costs[0] + 1e-12, "portfolio lost to lane 0");
        }
    }
    t.print();
    println!("(same seeds at any --threads count: the portfolio is thread-count-invariant,");
    println!(" so this table ablates search quality while threads only move the wall-clock)");
}
