//! Fault-injection bench (BENCH_faults.json): what deterministic faults
//! cost, and what failure-aware solving buys back, on both reference
//! platforms (BUJARUELO CPU-GPU, ODROID big.LITTLE).
//!
//! Three measurements:
//!
//! 1. **degradation curve** — expected makespan (ensemble mean) vs the
//!    transient fault rate for `pl/eft-p` on a fixed tiling, with the
//!    mean task recovery latency (fault -> next retry start)
//!    reconstructed from the event log;
//! 2. **headline** — a fault-*oblivious* portfolio solve priced after
//!    the fact against the shipped `configs/faults_quick.toml` ensemble,
//!    vs a fault-*aware* solve (`PortfolioConfig::faults`) warm-started
//!    from the oblivious winner. The aware run's incumbent starts at the
//!    oblivious winner's expected cost and only ever improves, so
//!    `aware <= oblivious` is a construction invariant this bench
//!    asserts, not a hope;
//! 3. **determinism gate** — a fault-axis sweep re-run single-threaded
//!    must reproduce the 4-thread run's CSV bytes.
//!
//! Flags: --iters N (default 120), --threads T, --members M (default 5),
//! --quick (smaller problems for CI), --out FILE.json

use std::collections::BTreeMap;

use hesp::bench::Table;
use hesp::coordinator::coherence::CachePolicy;
use hesp::coordinator::delta::DeltaMode;
use hesp::coordinator::engine::{simulate_flat_faults, EventKind, Schedule, SimConfig};
use hesp::coordinator::faults::{FaultEnsemble, FaultPlan, FaultSpec};
use hesp::coordinator::partitioners::{cholesky, PartitionerSet};
use hesp::coordinator::policies::{Ordering, ProcSelect, SchedConfig};
use hesp::coordinator::policy::PolicyRegistry;
use hesp::coordinator::solver::{solve_portfolio, PortfolioConfig, SolverConfig};
use hesp::coordinator::sweep::{self, CellMode, SweepGrid, SweepPlatform, Workload};
use hesp::coordinator::taskdag::TaskDag;
use hesp::util::cli::Args;
use hesp::util::json::Json;

const SPEC_FILE: &str = "configs/faults_quick.toml";

/// Mean fault->retry-start latency over every recovered attempt in the
/// log (a task's fault is "recovered" at its next start), plus the
/// number of faults injected.
fn recovery_stats(s: &Schedule) -> (f64, usize) {
    let mut pending: Vec<(usize, f64)> = Vec::new(); // (task, fault time)
    let mut total = 0.0;
    let mut recovered = 0usize;
    let mut faults = 0usize;
    for e in &s.events {
        match e.kind {
            EventKind::TaskFault { task, .. } => {
                faults += 1;
                pending.push((task, e.time));
            }
            EventKind::TaskStart { task, .. } => {
                if let Some(i) = pending.iter().position(|&(t, _)| t == task) {
                    let (_, at) = pending.swap_remove(i);
                    total += e.time - at;
                    recovered += 1;
                }
            }
            _ => {}
        }
    }
    (if recovered > 0 { total / recovered as f64 } else { 0.0 }, faults)
}

/// Expected makespan of `dag` over the ensemble (mean over members, as
/// the solver prices it: any exhausted member poisons the whole mean),
/// plus aggregate recovery stats of the finite members.
fn ensemble_price(
    dag: &TaskDag,
    p: &SweepPlatform,
    sim: SimConfig,
    reg: &PolicyRegistry,
    spec: &FaultSpec,
    members: u64,
) -> (f64, f64, usize, usize) {
    let flat = dag.flat_dag();
    // an empty spec draws identical members, but a k-member mean would
    // re-associate the float sum ((m+m+..)/k != m bitwise) — collapse to
    // one member, exactly as the solver normalizes empty ensembles away
    let members = if spec.is_empty() { 1 } else { members };
    let mut sum = 0.0;
    let mut poisoned = false;
    let mut lat_sum = 0.0;
    let mut lat_n = 0usize;
    let mut faults = 0usize;
    let mut exhausted = 0usize;
    for member in 0..members {
        let plan = FaultPlan::new(spec, member);
        let mut pol = reg.get("pl/eft-p").expect("registry policy");
        let s = simulate_flat_faults(dag, &flat, &p.machine, &p.db, sim, pol.as_mut(), &plan);
        if s.makespan.is_finite() {
            sum += s.makespan;
            let (lat, f) = recovery_stats(&s);
            if f > 0 {
                lat_sum += lat;
                lat_n += 1;
            }
            faults += f;
        } else {
            poisoned = true;
            exhausted += 1;
        }
    }
    let expected = if poisoned { f64::INFINITY } else { sum / members as f64 };
    (expected, if lat_n > 0 { lat_sum / lat_n as f64 } else { 0.0 }, faults, exhausted)
}

fn run_platform(
    config: &str,
    n: u32,
    tile: u32,
    min_edge: u32,
    iters: usize,
    threads: usize,
    members: u64,
    record: &mut BTreeMap<String, Json>,
) {
    let p = SweepPlatform::from_file(config).expect("config");
    let reg = PolicyRegistry::standard();
    let machine_name = p.name.clone();
    let sim = SimConfig::new(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish))
        .with_elem_bytes(p.elem_bytes);
    println!("\n== FAULTS — {machine_name} ({n}x{n} Cholesky, tile {tile}, {members}-member ensembles) ==");

    let mut dag = cholesky::root(n);
    cholesky::partition_uniform(&mut dag, tile);

    // phase 1: degradation vs transient fault rate
    let mut t = Table::new(&[
        "rate",
        "E[makespan] s",
        "vs nominal",
        "faults",
        "mean recovery s",
        "exhausted",
    ]);
    let (nominal, _, _, _) = ensemble_price(&dag, &p, sim, &reg, &FaultSpec::named("off"), 1);
    let mut curve = Vec::new();
    for rate in [0.0, 0.02, 0.05, 0.1, 0.2] {
        let mut spec = FaultSpec::named("curve");
        spec.seed = 23;
        spec.transient_rate = rate;
        spec.max_attempts = 8;
        let (expected, recovery, faults, exhausted) =
            ensemble_price(&dag, &p, sim, &reg, &spec, members);
        let vs = if expected.is_finite() { expected / nominal } else { f64::INFINITY };
        t.row(&[
            format!("{rate:.2}"),
            format!("{expected:.4}"),
            format!("{vs:.3}x"),
            faults.to_string(),
            format!("{recovery:.6}"),
            exhausted.to_string(),
        ]);
        let mut row = BTreeMap::new();
        row.insert("rate".to_string(), Json::Num(rate));
        row.insert("expected_makespan".to_string(), Json::Num(expected));
        row.insert("vs_nominal".to_string(), Json::Num(vs));
        row.insert("faults_injected".to_string(), Json::Num(faults as f64));
        row.insert("mean_recovery_s".to_string(), Json::Num(recovery));
        row.insert("exhausted_members".to_string(), Json::Num(exhausted as f64));
        curve.push(Json::Obj(row));
        // rate 0 must price exactly nominal: the ensemble mean of one
        // deterministic fault-free run per member
        if rate == 0.0 {
            assert_eq!(
                expected.to_bits(),
                nominal.to_bits(),
                "{machine_name}: empty plan must be the identity"
            );
        }
    }
    t.print();
    record.insert(format!("{machine_name}/degradation"), Json::Arr(curve));

    // phase 2: the headline — oblivious vs fault-aware solve under the
    // shipped quick spec
    let spec = FaultSpec::from_file(SPEC_FILE).expect("shipped fault spec");
    let base = SolverConfig::all_soft(sim, iters, min_edge);
    let mut pcfg = PortfolioConfig::new(base);
    pcfg.threads = threads;
    pcfg.lanes = 2;

    let t0 = std::time::Instant::now();
    let oblivious = solve_portfolio(
        &dag,
        &p.machine,
        &p.db,
        &PartitionerSet::standard(),
        &reg,
        "pl/eft-p",
        &pcfg,
    );
    let (obl_expected, _, _, _) =
        ensemble_price(&oblivious.best_dag, &p, sim, &reg, &spec, members);

    let mut aware_cfg = pcfg.clone();
    aware_cfg.faults = Some(FaultEnsemble::new(spec.clone(), members));
    // warm start from the oblivious winner: the aware incumbent begins at
    // obl_expected and is monotone, so aware <= oblivious by construction
    let aware = solve_portfolio(
        &oblivious.best_dag,
        &p.machine,
        &p.db,
        &PartitionerSet::standard(),
        &reg,
        "pl/eft-p",
        &aware_cfg,
    );
    let dt = t0.elapsed().as_secs_f64();

    let recovered = if obl_expected.is_finite() && obl_expected > 0.0 {
        100.0 * (obl_expected - aware.best_cost) / obl_expected
    } else {
        0.0
    };
    println!(
        "headline: oblivious solve E[makespan] {obl_expected:.4}s -> fault-aware {:.4}s ({recovered:.2}% recovered, {dt:.1}s)",
        aware.best_cost
    );
    assert!(
        aware.best_cost <= obl_expected * (1.0 + 1e-9) || obl_expected.is_infinite(),
        "{machine_name}: the aware incumbent starts at the oblivious winner and only improves"
    );
    let mut head = BTreeMap::new();
    head.insert(
        "oblivious_nominal_makespan".to_string(),
        Json::Num(oblivious.best_schedule.makespan),
    );
    head.insert("oblivious_expected_makespan".to_string(), Json::Num(obl_expected));
    head.insert("aware_expected_makespan".to_string(), Json::Num(aware.best_cost));
    head.insert("aware_nominal_makespan".to_string(), Json::Num(aware.best_schedule.makespan));
    head.insert("recovered_pct".to_string(), Json::Num(recovered));
    head.insert("members".to_string(), Json::Num(members as f64));
    record.insert(format!("{machine_name}/headline"), Json::Obj(head));
}

/// The determinism gate: a fault-axis sweep over both reference
/// platforms must emit identical bytes at 1 and 4 worker threads.
fn determinism_gate(n: u32, tiles: &[u32], members: u64) {
    let spec = FaultSpec::from_file(SPEC_FILE).expect("shipped fault spec");
    let grid = SweepGrid {
        platforms: vec![
            SweepPlatform::from_file("configs/bujaruelo.toml").expect("config"),
            SweepPlatform::from_file("configs/odroid.toml").expect("config"),
        ],
        workloads: vec![Workload::Cholesky { n }],
        policies: vec!["pl/eft-p".into(), "cls/heft".into()],
        tiles: tiles.to_vec(),
        modes: vec![CellMode::Simulate],
        seeds: vec![0],
        cache: CachePolicy::WriteBack,
        solve_lanes: 1,
        solve_batch: 1,
        delta: DeltaMode::Off,
        faults: vec![None, Some(spec)],
        fault_members: members,
    };
    let parallel = sweep::run_sweep(&grid, 4);
    let serial = sweep::run_sweep(&grid, 1);
    assert_eq!(
        sweep::to_csv(&serial),
        sweep::to_csv(&parallel),
        "fault sweep must not depend on the thread count"
    );
    assert_eq!(sweep::to_json(&serial), sweep::to_json(&parallel));
    println!(
        "\ndeterminism gate: {} fault-axis cells byte-identical at 1 and 4 threads",
        serial.len()
    );
}

fn main() {
    let args = Args::from_env();
    let quick = args.has("quick");
    let iters = {
        let i = args.usize_or("iters", 120);
        if quick {
            i.min(40)
        } else {
            i
        }
    };
    let threads = args.usize_or("threads", sweep::default_threads());
    let members = args.usize_or("members", 5).max(1) as u64;
    let mut record = BTreeMap::new();
    record.insert("name".to_string(), Json::Str("faults".into()));
    record.insert("iters".to_string(), Json::Num(iters as f64));
    record.insert("spec".to_string(), Json::Str(SPEC_FILE.into()));
    let r = &mut record;
    if quick {
        run_platform("configs/bujaruelo.toml", 8_192, 1024, 128, iters, threads, members, r);
        run_platform("configs/odroid.toml", 2_048, 256, 64, iters, threads, members, r);
        determinism_gate(2_048, &[256, 512], members);
    } else {
        run_platform("configs/bujaruelo.toml", 16_384, 1024, 128, iters, threads, members, r);
        run_platform("configs/odroid.toml", 4_096, 256, 64, iters, threads, members, r);
        determinism_gate(4_096, &[256, 512], members);
    }
    let out = std::path::PathBuf::from(args.str_or("out", "bench_out/BENCH_faults.json"));
    if let Some(dir) = out.parent().filter(|d| !d.as_os_str().is_empty()) {
        std::fs::create_dir_all(dir).expect("create bench_out");
    }
    std::fs::write(&out, Json::Obj(record).to_string()).expect("write bench json");
    println!("bench record -> {}", out.display());
}
