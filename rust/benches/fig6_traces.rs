//! FIG6 regenerator: execution traces of the best-performing PL/EFT-P
//! configurations on both platforms, homogeneous vs heterogeneous
//! partitioning — Paraver bundles plus an ASCII gap summary showing where
//! the heterogeneous schedule fills idle time with finer tasks.

use hesp::config::Platform;
use hesp::coordinator::engine::{simulate, Schedule, SimConfig};
use hesp::coordinator::metrics::{load_trace, report};
use hesp::coordinator::partitioners::{cholesky, PartitionerSet};
use hesp::coordinator::policies::{Ordering, ProcSelect, SchedConfig};
use hesp::coordinator::solver::{solve, SolverConfig};
use hesp::coordinator::taskdag::TaskDag;
use hesp::coordinator::trace::write_bundle;
use hesp::util::cli::Args;

fn phase_loads(sched: &Schedule, phases: usize) -> Vec<f64> {
    let trace = load_trace(sched, phases * 10);
    (0..phases)
        .map(|p| {
            let seg = &trace[p * 10..(p + 1) * 10];
            seg.iter().map(|&(_, a)| a as f64).sum::<f64>() / 10.0
        })
        .collect()
}

fn granularity_profile(dag: &TaskDag, sched: &Schedule, phases: usize) -> Vec<f64> {
    // flops-weighted mean tile edge per execution phase (the paper's
    // light-green/dark-blue granularity gradient, numerically)
    let mk = sched.makespan;
    let mut acc = vec![(0.0f64, 0.0f64); phases];
    for a in &sched.assignments {
        let t = dag.task(a.task);
        let phase = (((a.start + a.end) / 2.0 / mk) * phases as f64).min(phases as f64 - 1.0) as usize;
        acc[phase].0 += t.flops * t.char_edge();
        acc[phase].1 += t.flops;
    }
    acc.iter().map(|&(w, f)| if f > 0.0 { w / f } else { 0.0 }).collect()
}

fn main() {
    let args = Args::from_env();
    let iters = args.usize_or("iters", 250);
    let out = std::path::PathBuf::from(args.str_or("out", "bench_out/fig6"));

    for (config, n, b, min_edge) in [
        ("configs/bujaruelo.toml", 32_768u32, 2_048u32, 128u32),
        ("configs/odroid.toml", 8_192, 512, 64),
    ] {
        let p = Platform::from_file(config).expect("config");
        let sim = SimConfig::new(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish))
            .with_elem_bytes(p.elem_bytes);
        println!("\n== FIG 6 — {} (PL/EFT-P, n={n}) ==", p.machine.name);

        let mut dag = cholesky::root(n);
        cholesky::partition_uniform(&mut dag, b);
        let hsched = simulate(&dag, &p.machine, &p.db, sim);
        let hr = report(&dag, &hsched);
        write_bundle(&out, &format!("{}_homog", p.machine.name), &dag, &hsched, &p.machine).ok();

        let res = solve(dag.clone(), &p.machine, &p.db, &PartitionerSet::standard(), SolverConfig::all_soft(sim, iters, min_edge));
        let er = report(&res.best_dag, &res.best_schedule);
        write_bundle(&out, &format!("{}_heterog", p.machine.name), &res.best_dag, &res.best_schedule, &p.machine).ok();

        println!("homogeneous  b={b}: {:.2} GFLOPS, load {:.1}%", hr.gflops, hr.avg_load_pct);
        println!("heterogeneous    : {:.2} GFLOPS, load {:.1}%, depth {}", er.gflops, er.avg_load_pct, er.dag_depth);

        // phase-by-phase comparison: heterogeneous fills the early/late
        // gaps with finer tasks (the paper's key trace observation)
        let phases = 10;
        let (hl, el) = (phase_loads(&hsched, phases), phase_loads(&res.best_schedule, phases));
        let (hg, eg) = (granularity_profile(&dag, &hsched, phases), granularity_profile(&res.best_dag, &res.best_schedule, phases));
        println!("{:>6} {:>12} {:>12} {:>12} {:>12}", "phase", "hom load", "het load", "hom grain", "het grain");
        for i in 0..phases {
            println!("{:>6} {:>12.1} {:>12.1} {:>12.0} {:>12.0}", i, hl[i], el[i], hg[i], eg[i]);
        }
        // in the final phase the heterogeneous grain should be no coarser
        let last = phases - 1;
        println!(
            "tail grain: hom {:.0} -> het {:.0} ({})",
            hg[last],
            eg[last],
            if eg[last] <= hg[last] { "refined, as in the paper" } else { "unchanged" }
        );
    }
    println!("\nParaver bundles -> bench_out/fig6/");
}
