//! Fault-injection contracts, end to end (ISSUE 10).
//!
//! Three properties ride here:
//!
//! * **off = absent**: simulating under an *empty* fault plan is
//!   byte-identical to the fault-free engine for every policy the
//!   registry knows, on both reference platforms — `--faults off` can
//!   never change golden artifacts.
//! * **the oracle is independent**: `validate_schedule_faults` accepts
//!   what the engine produced and rejects tampered schedules — it
//!   re-derives attempt accounting from the event log instead of
//!   trusting the engine's own arithmetic.
//! * **thread-count identity**: a sweep with the fault axis *on* emits
//!   byte-identical CSV/JSON bundles at any worker count, and its
//!   fault-free rows match the all-off grid bit for bit.

use hesp::coordinator::coherence::CachePolicy;
use hesp::coordinator::delta::DeltaMode;
use hesp::coordinator::engine::{
    simulate_flat_faults, simulate_flat_policy, EventKind, SimConfig, SimEvent,
};
use hesp::coordinator::faults::{FailStop, FaultPlan, FaultSpec, ThrottleWindow};
use hesp::coordinator::partitioners::cholesky;
use hesp::coordinator::perfmodel::{PerfCurve, PerfDb};
use hesp::coordinator::platform::MachineBuilder;
use hesp::coordinator::policies::{Ordering, ProcSelect, SchedConfig};
use hesp::coordinator::policy::PolicyRegistry;
use hesp::coordinator::sweep::{self, CellMode, SweepGrid, SweepPlatform, Workload};
use hesp::coordinator::validate::validate_schedule_faults;

fn reference_platform(file: &str) -> SweepPlatform {
    let path = format!("{}/configs/{file}", env!("CARGO_MANIFEST_DIR"));
    SweepPlatform::from_file(&path).expect("reference platform config")
}

/// A small in-memory platform (no config files needed).
fn platform(name: &str, ncpu: usize, peak: f64) -> SweepPlatform {
    let mut b = MachineBuilder::new(name);
    let h = b.space("host", u64::MAX);
    b.main(h);
    let t = b.proc_type("cpu", 1.0, 0.1);
    b.processors(ncpu, "c", t, h);
    let mut db = PerfDb::new();
    db.set_fallback(0, PerfCurve::Saturating { peak, half: 64.0, exponent: 2.0 });
    SweepPlatform::new(name, b.build(), db, 8)
}

#[test]
fn empty_plan_is_byte_identical_for_every_registry_policy_on_both_reference_machines() {
    let reg = PolicyRegistry::standard();
    let names = reg.names();
    assert!(names.len() >= 15, "registry shrank to {} policies", names.len());
    let off = FaultSpec::named("off");
    assert!(off.is_empty());
    for file in ["bujaruelo.toml", "odroid.toml"] {
        let p = reference_platform(file);
        let mut dag = cholesky::root(1024);
        cholesky::partition_uniform(&mut dag, 256);
        let flat = dag.flat_dag();
        let cfg = SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish);
        let sim = SimConfig::new(cfg).with_elem_bytes(p.elem_bytes);
        for name in &names {
            let mut a = reg.get(name).expect("registry policy");
            let mut b = reg.get(name).expect("registry policy");
            let base = simulate_flat_policy(&dag, &flat, &p.machine, &p.db, sim, a.as_mut());
            let plan = FaultPlan::new(&off, 0);
            let faulted =
                simulate_flat_faults(&dag, &flat, &p.machine, &p.db, sim, b.as_mut(), &plan);
            assert_eq!(base.makespan.to_bits(), faulted.makespan.to_bits(), "{file}/{name}");
            assert_eq!(base.events, faulted.events, "{file}/{name}");
            // Debug rendering of f64 is shortest-roundtrip, so equal
            // strings here means the whole result is bit-identical
            assert_eq!(format!("{base:?}"), format!("{faulted:?}"), "{file}/{name}");
        }
    }
}

#[test]
fn oracle_accepts_engine_output_and_rejects_tampering() {
    let p = platform("flat", 4, 20.0);
    let mut dag = cholesky::root(512);
    cholesky::partition_uniform(&mut dag, 128);
    let flat = dag.flat_dag();
    let sim = SimConfig::new(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish))
        .with_elem_bytes(p.elem_bytes);

    // death + repair + a throttle window, but no transient faults: with
    // three healthy processors left, completion (a finite makespan) is
    // guaranteed, so the oracle must have something to validate
    let mut spec = FaultSpec::named("storm");
    spec.fail_stop.push(FailStop { proc: 1, at: 1e-4, restore: Some(8e-4) });
    spec.throttle.push(ThrottleWindow { proc: 0, from: 0.0, to: 1e-2, factor: 0.5 });
    let plan = FaultPlan::new(&spec, 0);
    let mut pol = PolicyRegistry::standard().get("pl/eft-p").unwrap();
    let sched = simulate_flat_faults(&dag, &flat, &p.machine, &p.db, sim, pol.as_mut(), &plan);
    assert!(sched.makespan.is_finite(), "no fault source can exhaust this run");
    validate_schedule_faults(&dag, &flat, &p.machine, &sched, &plan)
        .expect("engine output must satisfy the oracle");

    // tamper 1: an out-of-range processor id
    let mut bad = sched.clone();
    bad.assignments[0].proc = p.machine.n_procs();
    assert!(validate_schedule_faults(&dag, &flat, &p.machine, &bad, &plan).is_err());

    // tamper 2: a TaskEnd with no matching TaskStart — the attempt
    // reconstruction walks the log itself, so a forged completion trips it
    let mut bad = sched.clone();
    let a = bad.assignments[0];
    let forged = EventKind::TaskEnd { task: a.task, proc: a.proc };
    bad.events.push(SimEvent { time: a.end, kind: forged });
    assert!(validate_schedule_faults(&dag, &flat, &p.machine, &bad, &plan).is_err());

    // tamper 3: inflated busy seconds must break attempt accounting
    let mut bad = sched.clone();
    bad.proc_busy[0] += 1.0;
    assert!(validate_schedule_faults(&dag, &flat, &p.machine, &bad, &plan).is_err());

    // an exhausted run (every attempt faults, budget 1) is not validatable
    let mut doom = FaultSpec::named("doom");
    doom.transient_rate = 1.0;
    doom.max_attempts = 1;
    let doom_plan = FaultPlan::new(&doom, 0);
    let mut pol = PolicyRegistry::standard().get("pl/eft-p").unwrap();
    let dead = simulate_flat_faults(&dag, &flat, &p.machine, &p.db, sim, pol.as_mut(), &doom_plan);
    assert!(dead.makespan.is_infinite(), "rate-1.0 faults with budget 1 can never finish");
    assert!(validate_schedule_faults(&dag, &flat, &p.machine, &dead, &doom_plan).is_err());
}

fn fault_grid(faults: Vec<Option<FaultSpec>>) -> SweepGrid {
    SweepGrid {
        platforms: vec![platform("alpha", 4, 20.0), platform("beta", 2, 35.0)],
        workloads: vec![Workload::Cholesky { n: 128 }, Workload::Stencil { cells: 4, steps: 3 }],
        policies: vec!["pl/eft-p".into(), "fcfs/eft-p".into()],
        tiles: vec![32],
        modes: vec![CellMode::Simulate],
        seeds: vec![0, 1],
        cache: CachePolicy::WriteBack,
        solve_lanes: 1,
        solve_batch: 1,
        delta: DeltaMode::Off,
        faults,
        fault_members: 2,
    }
}

fn storm_spec() -> FaultSpec {
    let mut spec = FaultSpec::named("storm");
    spec.seed = 5;
    spec.transient_rate = 0.05;
    spec.max_attempts = 6;
    spec.fail_stop.push(FailStop { proc: 1, at: 1e-4, restore: Some(5e-4) });
    spec.throttle.push(ThrottleWindow { proc: 0, from: 0.0, to: 1e-3, factor: 0.5 });
    spec
}

#[test]
fn fault_sweep_bundle_is_byte_identical_across_thread_counts() {
    let grid = fault_grid(vec![None, Some(storm_spec())]);
    let serial = sweep::run_sweep(&grid, 1);
    let parallel = sweep::run_sweep(&grid, 4);
    assert_eq!(serial.len(), grid.expand().len());
    let csv = sweep::to_csv(&serial);
    assert_eq!(csv, sweep::to_csv(&parallel), "fault axis must not change with the thread count");
    assert_eq!(sweep::to_json(&serial), sweep::to_json(&parallel));
    // a non-off axis entry switches the bundle to the extended schema
    assert!(csv.lines().next().unwrap().ends_with(",faults"), "{csv}");
    assert!(serial.iter().any(|r| r.fault == "storm"));
    assert!(serial.iter().any(|r| r.fault == "off"));
}

#[test]
fn off_rows_of_a_faulted_grid_match_the_all_off_grid_bit_for_bit() {
    // the fault axis must be *paired*: scheduler seeds ignore the fault
    // coordinate, so the off rows of a mixed grid are the all-off grid
    let mixed = sweep::run_sweep(&fault_grid(vec![None, Some(storm_spec())]), 2);
    let plain = sweep::run_sweep(&fault_grid(vec![None]), 2);
    let off_rows: Vec<_> = mixed.iter().filter(|r| r.fault == "off").collect();
    assert_eq!(off_rows.len(), plain.len());
    for (m, p) in off_rows.iter().zip(&plain) {
        assert_eq!(
            (&m.platform, &m.workload, &m.policy, m.tile, m.seed),
            (&p.platform, &p.workload, &p.policy, p.tile, p.seed)
        );
        assert_eq!(
            m.makespan.to_bits(),
            p.makespan.to_bits(),
            "{}/{}/{}",
            m.platform,
            m.workload,
            m.policy
        );
        assert_eq!(m.transfer_bytes, p.transfer_bytes);
    }
    // and an all-off grid never grows the faults column
    assert!(!sweep::to_csv(&plain).lines().next().unwrap().contains("faults"));
}
