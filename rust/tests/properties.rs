//! Property-based tests over the coordinator's core invariants, using the
//! in-tree seeded property harness (`hesp::proptest`).

use hesp::coordinator::coherence::{CachePolicy, Coherence};
use hesp::coordinator::datadag::{DataDag, GrainIndex};
use hesp::coordinator::engine::{simulate, SimConfig};
use hesp::coordinator::partitioners::{cholesky, legal_sub_edges, PartitionerSet};
use hesp::coordinator::perfmodel::{PerfCurve, PerfDb};
use hesp::coordinator::platform::{Machine, MachineBuilder};
use hesp::coordinator::policies::{Ordering, ProcSelect, SchedConfig};
use hesp::coordinator::region::Region;
use hesp::coordinator::task::{TaskKind, TaskSpec};
use hesp::coordinator::taskdag::TaskDag;
use hesp::proptest::{forall, gen};
use hesp::util::rng::Rng;

/// Random small task stream over aligned tiles of one matrix.
fn random_stream(rng: &mut Rng, n_tasks: usize) -> TaskDag {
    let root = Region::new(0, 0, 64, 0, 64);
    let mut dag = TaskDag::new(TaskSpec::new(TaskKind::Potrf, vec![root], vec![root]));
    let mut specs = Vec::new();
    for _ in 0..n_tasks {
        let nreads = rng.below(3);
        let reads: Vec<Region> = (0..nreads).map(|_| gen::square_tile(rng, 0, 6)).collect();
        let writes = vec![gen::square_tile(rng, 0, 6)];
        specs.push(TaskSpec::new(TaskKind::Gemm, reads, writes));
    }
    dag.partition(0, specs, 8);
    dag
}

fn reachable(flat: &hesp::coordinator::taskdag::FlatDag, from: usize, to: usize) -> bool {
    let mut seen = vec![false; flat.len()];
    let mut stack = vec![from];
    while let Some(x) = stack.pop() {
        if x == to {
            return true;
        }
        for &s in &flat.succs[x] {
            if !seen[s] {
                seen[s] = true;
                stack.push(s);
            }
        }
    }
    false
}

#[test]
fn prop_dependences_respect_sequential_semantics() {
    // Every conflicting pair (overlapping access, at least one write) must
    // be ordered by a dependence path in program order.
    forall(60, 0xDA6, |rng| {
        let dag = random_stream(rng, 14);
        let flat = dag.flat_dag();
        let n = flat.len();
        for i in 0..n {
            let ti = dag.task(flat.tasks[i]);
            for j in i + 1..n {
                let tj = dag.task(flat.tasks[j]);
                let conflict = ti.writes.iter().any(|w| {
                    tj.reads.iter().chain(tj.writes.iter()).any(|r| w.intersects(r))
                }) || tj.writes.iter().any(|w| ti.reads.iter().any(|r| w.intersects(r)));
                if conflict {
                    assert!(reachable(&flat, i, j), "conflicting pair ({i},{j}) unordered");
                }
            }
        }
    });
}

#[test]
fn prop_flat_dag_is_acyclic_topological() {
    // preds always point backwards in program order (an inductive proof of
    // acyclicity), and indegrees are consistent with succs.
    forall(80, 0xACE, |rng| {
        let dag = random_stream(rng, 20);
        let flat = dag.flat_dag();
        for (i, ps) in flat.preds.iter().enumerate() {
            for &p in ps {
                assert!(p < i, "pred {p} not before {i}");
                assert!(flat.succs[p].contains(&i));
            }
        }
    });
}

fn random_machine(rng: &mut Rng) -> (Machine, PerfDb) {
    let mut b = MachineBuilder::new("rand");
    let host = b.space("host", u64::MAX);
    b.main(host);
    let n_spaces = 1 + rng.below(3);
    let mut spaces = vec![host];
    for i in 1..n_spaces {
        let s = b.space(&format!("dev{i}"), 1 << 30);
        b.connect(host, s, 1e-6 * (1 + rng.below(20)) as f64, 1e9 * (1 + rng.below(20)) as f64);
        spaces.push(s);
    }
    let mut db = PerfDb::new();
    let n_types = 1 + rng.below(3);
    for t in 0..n_types {
        let ty = b.proc_type(&format!("ty{t}"), 10.0, 1.0);
        db.set_fallback(
            ty,
            PerfCurve::Saturating { peak: 1.0 + rng.next_f64() * 100.0, half: 8.0 + rng.next_f64() * 64.0, exponent: 1.5 },
        );
        let space = spaces[rng.below(spaces.len())];
        b.processors(1 + rng.below(4), &format!("p{t}_"), ty, space);
    }
    (b.build(), db)
}

#[test]
fn prop_schedule_is_valid_under_all_policies() {
    forall(40, 0x5CED, |rng| {
        let dag = random_stream(rng, 16);
        let (m, db) = random_machine(rng);
        let ordering = *rng.choose(&[Ordering::Fcfs, Ordering::PriorityList]);
        let select = *rng.choose(&ProcSelect::ALL);
        let cache = *rng.choose(&[CachePolicy::WriteBack, CachePolicy::WriteThrough, CachePolicy::WriteAround]);
        let cfg = SimConfig::new(SchedConfig::new(ordering, select)).with_cache(cache).with_seed(rng.next_u64());
        let sched = simulate(&dag, &m, &db, cfg);
        let flat = dag.flat_dag();

        // every task scheduled exactly once, on a real processor
        assert_eq!(sched.assignments.len(), flat.len());
        for a in &sched.assignments {
            assert!(a.proc < m.n_procs());
            assert!(a.end >= a.start && a.start >= a.release - 1e-12);
        }
        // dependence order respected
        for (i, ps) in flat.preds.iter().enumerate() {
            for &p in ps {
                assert!(
                    sched.assignments[i].start >= sched.assignments[p].end - 1e-9,
                    "task {i} starts before pred {p} ends"
                );
            }
        }
        // no processor runs two tasks at once
        let mut per_proc: Vec<Vec<(f64, f64)>> = vec![Vec::new(); m.n_procs()];
        for a in &sched.assignments {
            per_proc[a.proc].push((a.start, a.end));
        }
        for iv in &mut per_proc {
            iv.sort_by(|a, b| a.0.total_cmp(&b.0));
            for w in iv.windows(2) {
                assert!(w[1].0 >= w[0].1 - 1e-9, "overlap on a processor");
            }
        }
        // makespan covers everything
        for a in &sched.assignments {
            assert!(a.end <= sched.makespan + 1e-9);
        }
    });
}

#[test]
fn prop_grain_index_matches_naive_scan() {
    forall(150, 0x16D, |rng| {
        let mut idx = GrainIndex::new();
        let mut all: Vec<(Region, usize)> = Vec::new();
        let n = 1 + rng.below(20);
        for i in 0..n {
            // mix of aligned tiles and arbitrary rectangles
            let r = if rng.below(2) == 0 {
                gen::square_tile(rng, 0, 6)
            } else {
                gen::region(rng, 0, 64, 1)
            };
            if all.iter().any(|(x, _)| *x == r) {
                continue;
            }
            idx.insert(r, i);
            all.push((r, i));
        }
        let q = gen::region(rng, 0, 64, 1);
        let mut got: Vec<usize> = Vec::new();
        idx.visit_intersecting(&q, |i| got.push(i));
        got.sort_unstable();
        got.dedup();
        let mut want: Vec<usize> = all.iter().filter(|(r, _)| r.intersects(&q)).map(|&(_, i)| i).collect();
        want.sort_unstable();
        assert_eq!(got, want, "query {q}");
    });
}

#[test]
fn prop_datadag_relations_are_geometric() {
    forall(80, 0xDD, |rng| {
        let mut dag = DataDag::new();
        let mut regions = Vec::new();
        for _ in 0..8 {
            let r = gen::square_tile(rng, 0, 5);
            dag.insert(r);
            regions.push(r);
        }
        // node relations mirror geometry for every inserted pair
        for &r in &regions {
            let b = dag.lookup(&r).unwrap();
            for p in &dag.block(b).parents {
                assert!(dag.block(*p).region.contains(&r));
            }
            for c in &dag.block(b).children {
                assert!(r.contains(&dag.block(*c).region));
            }
        }
    });
}

#[test]
fn prop_coherence_no_stale_reads() {
    // Random read/write traffic across spaces: after any write, a read
    // plan from another space must source every fragment from somewhere
    // holding valid data, and reassembly must make the block readable.
    forall(60, 0xC0E, |rng| {
        let policy = *rng.choose(&[CachePolicy::WriteBack, CachePolicy::WriteThrough, CachePolicy::WriteAround]);
        let mut coh = Coherence::new(3, 0, policy, vec![u64::MAX; 3], 4);
        let mut blocks = Vec::new();
        for _ in 0..6 {
            blocks.push(coh.register(gen::square_tile(rng, 0, 5)));
        }
        for _ in 0..30 {
            let b = blocks[rng.below(blocks.len())];
            let s = rng.below(3);
            if rng.below(2) == 0 {
                // read: plan + apply
                let plan = coh.read_plan(b, s);
                for tr in &plan {
                    assert!(tr.to == s);
                    assert!(tr.bytes > 0);
                    // source must actually hold the block (or be main for
                    // the residual fetch)
                    assert!(
                        coh.is_valid(tr.block, tr.from) || tr.from == 0,
                        "transfer sourced from invalid space"
                    );
                }
                for tr in plan {
                    coh.complete_read(tr.block, tr.to);
                }
                coh.complete_read(b, s);
                assert!(coh.is_valid(b, s), "block unreadable after plan applied");
            } else {
                coh.complete_write(b, s);
                match policy {
                    CachePolicy::WriteBack => assert!(coh.is_valid(b, s)),
                    CachePolicy::WriteThrough => {
                        assert!(coh.is_valid(b, s) && coh.is_valid(b, 0))
                    }
                    CachePolicy::WriteAround => assert!(coh.is_valid(b, 0)),
                }
                // no *other* space may still hold an intersecting block
                for &ob in &blocks {
                    if coh.dag.block(ob).region.intersects(&coh.dag.block(b).region) {
                        for other in 0..3 {
                            let writer_space = if policy == CachePolicy::WriteAround { 0 } else { s };
                            if other != writer_space && other != 0 && other != s {
                                assert!(!coh.is_valid(ob, other), "stale copy survived a write");
                            }
                        }
                    }
                }
            }
        }
    });
}

#[test]
fn prop_partitioners_conserve_flops() {
    // POTRF/TRSM/SYRK/GEMM/GETRF blocked partitions redistribute exactly
    // the parent's flops (with the crate's full-block SYRK convention).
    forall(60, 0xF70, |rng| {
        let parts = PartitionerSet::standard();
        let edge = 1u32 << (4 + rng.below(4)); // 16..128
        let subs = legal_sub_edges(edge, 2);
        if subs.is_empty() {
            return;
        }
        let sub = subs[rng.below(subs.len())];
        let a = Region::new(0, 0, edge, 0, edge);
        let b = Region::new(1, 0, edge, 0, edge);
        let c = Region::new(2, 0, edge, 0, edge);
        let specs = [
            TaskSpec::new(TaskKind::Potrf, vec![a], vec![a]),
            TaskSpec::new(TaskKind::Trsm, vec![a, b], vec![b]),
            TaskSpec::new(TaskKind::Syrk, vec![a, b], vec![b]),
            TaskSpec::new(TaskKind::Gemm, vec![a, b, c], vec![c]),
            TaskSpec::new(TaskKind::Getrf, vec![a], vec![a]),
        ];
        for spec in specs {
            let parent_flops = spec.flops();
            let mut dag = TaskDag::new(spec);
            if parts.apply(&mut dag, 0, sub).is_some() {
                let total = dag.total_flops();
                assert!(
                    (total - parent_flops).abs() <= 1e-6 * parent_flops.max(1.0),
                    "flops not conserved: {total} vs {parent_flops} (edge {edge} sub {sub})"
                );
            }
        }
    });
}

#[test]
fn prop_merge_restores_exact_frontier() {
    // partition -> partition child -> merge child -> merge root returns
    // the DAG to its original single-task frontier, for random choices.
    forall(60, 0x3E6, |rng| {
        let parts = PartitionerSet::standard();
        let mut dag = cholesky::root(64);
        let subs = [8u32, 16, 32];
        let b = *rng.choose(&subs);
        parts.apply(&mut dag, 0, b).unwrap();
        let frontier1 = dag.frontier();
        // partition a random partitionable leaf one level deeper
        let leaf = frontier1[rng.below(frontier1.len())];
        let edge = dag.task(leaf).char_edge() as u32;
        if let Some(sub2) = legal_sub_edges(edge, 2).first().copied() {
            if parts.apply(&mut dag, leaf, sub2).is_some() {
                assert!(dag.frontier().len() > frontier1.len());
                dag.merge(leaf);
            }
        }
        assert_eq!(dag.frontier(), frontier1, "merge must restore the previous frontier");
        dag.merge(dag.root);
        assert_eq!(dag.frontier(), vec![dag.root]);
        assert_eq!(dag.live_count(), 1);
    });
}

#[test]
fn prop_simulation_is_deterministic() {
    forall(25, 0xDE7, |rng| {
        let dag = random_stream(rng, 12);
        let (m, db) = random_machine(rng);
        let seed = rng.next_u64();
        let cfg = SimConfig::new(SchedConfig::new(Ordering::Fcfs, ProcSelect::Random)).with_seed(seed);
        let a = simulate(&dag, &m, &db, cfg);
        let b = simulate(&dag, &m, &db, cfg);
        assert_eq!(a.mapping(), b.mapping());
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.transfer_bytes, b.transfer_bytes);
    });
}
