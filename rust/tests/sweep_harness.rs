//! Sweep-harness determinism contract: a parallel run is byte-identical
//! to the single-threaded run on the same grid, and per-cell seeds are a
//! function of grid *coordinates* (stable under axis reordering).

use hesp::coordinator::coherence::CachePolicy;
use hesp::coordinator::perfmodel::{PerfCurve, PerfDb};
use hesp::coordinator::platform::MachineBuilder;
use hesp::coordinator::sweep::{self, cell_seed, workload_seed, CellMode, SweepGrid, SweepPlatform, Workload};

/// A small in-memory platform (no config files in unit tests).
fn platform(name: &str, ncpu: usize, peak: f64) -> SweepPlatform {
    let mut b = MachineBuilder::new(name);
    let h = b.space("host", u64::MAX);
    b.main(h);
    let t = b.proc_type("cpu", 1.0, 0.1);
    b.processors(ncpu, "c", t, h);
    let mut db = PerfDb::new();
    db.set_fallback(0, PerfCurve::Saturating { peak, half: 64.0, exponent: 2.0 });
    SweepPlatform::new(name, b.build(), db, 8)
}

fn grid() -> SweepGrid {
    SweepGrid {
        platforms: vec![platform("alpha", 4, 20.0), platform("beta", 2, 35.0)],
        workloads: vec![
            Workload::Cholesky { n: 128 },
            Workload::Stencil { cells: 4, steps: 3 },
            Workload::Random { n: 16 },
        ],
        policies: vec!["fcfs/eit-p".into(), "pl/eft-p".into()],
        tiles: vec![32, 64],
        modes: vec![CellMode::Simulate, CellMode::Solve { iters: 2, min_edge: 16 }],
        seeds: vec![0, 1],
        cache: CachePolicy::WriteBack,
    }
}

/// The coordinate key that identifies a cell independent of grid order.
fn key(r: &sweep::CellResult) -> (String, String, String, u32, String, u64) {
    (r.platform.clone(), r.workload.clone(), r.policy.clone(), r.tile, r.mode.clone(), r.seed)
}

#[test]
fn parallel_run_is_byte_identical_to_serial() {
    let g = grid();
    let serial = sweep::run_sweep(&g, 1);
    let parallel = sweep::run_sweep(&g, 4);
    assert!(!serial.is_empty());
    assert_eq!(
        sweep::to_csv(&serial),
        sweep::to_csv(&parallel),
        "aggregate CSV must not depend on the thread count"
    );
    assert_eq!(sweep::to_json(&serial), sweep::to_json(&parallel));
}

#[test]
fn cell_seeds_are_stable_under_grid_reordering() {
    let g = grid();
    let forward = sweep::run_sweep(&g, 2);

    // reverse every axis: every cell keeps its identity, only its
    // position in the grid changes
    let mut rev = grid();
    rev.platforms.reverse();
    rev.workloads.reverse();
    rev.policies.reverse();
    rev.tiles.reverse();
    rev.modes.reverse();
    rev.seeds.reverse();
    let backward = sweep::run_sweep(&rev, 2);

    assert_eq!(forward.len(), backward.len());
    for f in &forward {
        let b = backward
            .iter()
            .find(|b| key(b) == key(f))
            .unwrap_or_else(|| panic!("cell {:?} missing from reordered run", key(f)));
        assert_eq!(f.cell_seed, b.cell_seed, "seed must derive from coordinates, not position");
        assert_eq!(f.makespan, b.makespan, "same cell, same trajectory: {:?}", key(f));
        assert_eq!(f.transfer_bytes, b.transfer_bytes);
    }
}

#[test]
fn infeasible_tiles_are_skipped_not_errors() {
    let mut g = grid();
    g.tiles = vec![32, 48]; // 48 does not divide 128
    let cells = g.expand();
    assert!(cells
        .iter()
        .all(|c| c.workload.feasible(c.tile)));
    // cholesky dropped tile 48; the synthetic shapes kept it
    assert!(cells.iter().any(|c| c.tile == 48));
    assert!(!cells
        .iter()
        .any(|c| c.tile == 48 && matches!(c.workload, Workload::Cholesky { .. })));
}

#[test]
fn solve_cells_never_lose_to_their_baseline() {
    let g = grid();
    let results = sweep::run_sweep(&g, 4);
    let mut solved = 0;
    for r in results.iter().filter(|r| r.mode.starts_with("solve")) {
        solved += 1;
        assert!(
            r.makespan <= r.hom_makespan * 1.0001,
            "{}/{}/{}: solver kept a worse state ({} > {})",
            r.platform,
            r.workload,
            r.policy,
            r.makespan,
            r.hom_makespan
        );
    }
    assert!(solved > 0, "the grid must contain solve cells");
}

#[test]
fn csv_rows_match_cells_and_header() {
    let g = grid();
    let results = sweep::run_sweep(&g, 2);
    let csv = sweep::to_csv(&results);
    let mut lines = csv.lines();
    let header = lines.next().unwrap();
    assert_eq!(header, sweep::CSV_HEADER);
    let n_fields = header.split(',').count();
    let mut rows = 0;
    for line in lines {
        assert_eq!(line.split(',').count(), n_fields, "{line}");
        rows += 1;
    }
    assert_eq!(rows, results.len());
    assert_eq!(results.len(), g.expand().len());
}

#[test]
fn explicit_cell_lists_run_in_order() {
    // two-phase usage (Table 1): pick winners from one sweep, run an
    // explicit follow-up cell list through the same executor
    let g = grid();
    let mut cells = g.expand();
    cells.truncate(6);
    let a = sweep::run_cells(&g, &cells, 1);
    let b = sweep::run_cells(&g, &cells, 3);
    assert_eq!(a.len(), 6);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(key(x), key(y), "results must come back in cell-list order");
        assert_eq!(x.makespan, y.makespan);
    }
}

#[test]
fn seed_axis_actually_varies_random_workloads() {
    // the DAG-structure seed is a function of (workload, tile, declared
    // seed) ONLY — the policy and mode axes must not enter, or every
    // policy would schedule a different random instance and cross-policy
    // comparisons would be meaningless
    let s0 = workload_seed("random:16", 32, 0);
    let s1 = workload_seed("random:16", 32, 1);
    assert_ne!(s0, s1, "the declared seed axis varies the instance");
    // … while the full cell seed (scheduler RNG) does key on policy/mode
    assert_ne!(
        cell_seed("alpha", "random:16", "pl/eft-p", 32, "sim", 0),
        cell_seed("alpha", "random:16", "fcfs/eit-p", 32, "sim", 0)
    );
    let d0 = Workload::Random { n: 16 }.build(32, s0).unwrap();
    let d1 = Workload::Random { n: 16 }.build(32, s1).unwrap();
    let (e0, e1) = (d0.flat_dag().edge_count(), d1.flat_dag().edge_count());
    // reproducible for the same seed
    let d0b = Workload::Random { n: 16 }.build(32, s0).unwrap();
    assert_eq!(e0, d0b.flat_dag().edge_count());
    // (edge counts *can* coincide by chance; the structural check above
    // is the reproducibility contract, the inequality below is a smoke
    // check on this specific pair of seeds)
    assert_ne!((s0, e0), (s1, e1));
}

#[test]
fn workload_structure_is_mode_independent() {
    // a solve cell's internal baseline and the sim cell at the same
    // (platform, workload, policy, tile, seed) coordinates must simulate
    // the SAME DAG instance. Both policies in this grid are deterministic
    // (no RNG draws), so the baseline makespans must agree exactly — for
    // the random workload this fails if the DAG-structure seed is keyed
    // on the mode label (the regression `workload_seed` guards against).
    let g = grid();
    let results = sweep::run_sweep(&g, 2);
    let mut checked = 0;
    for r in results.iter().filter(|r| r.mode.starts_with("solve")) {
        let twin = results
            .iter()
            .find(|o| {
                o.mode == "sim"
                    && o.platform == r.platform
                    && o.workload == r.workload
                    && o.policy == r.policy
                    && o.tile == r.tile
                    && o.seed == r.seed
            })
            .expect("every solve cell has a sim twin in this grid");
        assert_eq!(r.hom_makespan, twin.makespan, "same DAG, same policy, same baseline: {:?}", key(r));
        checked += 1;
    }
    assert!(checked > 0);
}
