//! Sweep-harness determinism contract: a parallel run is byte-identical
//! to the single-threaded run on the same grid, per-cell seeds are a
//! function of grid *coordinates* (stable under axis reordering), and the
//! portfolio solver inside solve-mode cells is thread-count-invariant.

use hesp::coordinator::coherence::CachePolicy;
use hesp::coordinator::delta::DeltaMode;
use hesp::coordinator::engine::SimConfig;
use hesp::coordinator::partitioners::PartitionerSet;
use hesp::coordinator::perfmodel::{PerfCurve, PerfDb};
use hesp::coordinator::platform::MachineBuilder;
use hesp::coordinator::policies::{Ordering, ProcSelect, SchedConfig};
use hesp::coordinator::policy::PolicyRegistry;
use hesp::coordinator::solver::{result_json, solve_portfolio, PortfolioConfig, SolverConfig};
use hesp::coordinator::sweep::{self, cell_seed, workload_seed, CellMode, SweepGrid, SweepPlatform, Workload};

/// A small in-memory platform (no config files in unit tests).
fn platform(name: &str, ncpu: usize, peak: f64) -> SweepPlatform {
    let mut b = MachineBuilder::new(name);
    let h = b.space("host", u64::MAX);
    b.main(h);
    let t = b.proc_type("cpu", 1.0, 0.1);
    b.processors(ncpu, "c", t, h);
    let mut db = PerfDb::new();
    db.set_fallback(0, PerfCurve::Saturating { peak, half: 64.0, exponent: 2.0 });
    SweepPlatform::new(name, b.build(), db, 8)
}

fn grid() -> SweepGrid {
    SweepGrid {
        platforms: vec![platform("alpha", 4, 20.0), platform("beta", 2, 35.0)],
        workloads: vec![
            Workload::Cholesky { n: 128 },
            Workload::Stencil { cells: 4, steps: 3 },
            Workload::Random { n: 16 },
        ],
        policies: vec!["fcfs/eit-p".into(), "pl/eft-p".into()],
        tiles: vec![32, 64],
        modes: vec![CellMode::Simulate, CellMode::Solve { iters: 2, min_edge: 16 }],
        seeds: vec![0, 1],
        cache: CachePolicy::WriteBack,
        solve_lanes: 1,
        solve_batch: 1,
        // Auto on purpose: every solve-mode determinism assertion in this
        // file then also pins "incremental re-simulation changes no bytes"
        delta: DeltaMode::Auto,
        faults: vec![None],
        fault_members: 3,
    }
}

/// The coordinate key that identifies a cell independent of grid order.
fn key(r: &sweep::CellResult) -> (String, String, String, u32, String, u64) {
    (r.platform.clone(), r.workload.clone(), r.policy.clone(), r.tile, r.mode.clone(), r.seed)
}

#[test]
fn parallel_run_is_byte_identical_to_serial() {
    let g = grid();
    let serial = sweep::run_sweep(&g, 1);
    let parallel = sweep::run_sweep(&g, 4);
    assert!(!serial.is_empty());
    assert_eq!(
        sweep::to_csv(&serial),
        sweep::to_csv(&parallel),
        "aggregate CSV must not depend on the thread count"
    );
    assert_eq!(sweep::to_json(&serial), sweep::to_json(&parallel));
}

#[test]
fn classic_policies_keep_thread_count_identity() {
    // the cls/ trio computes comm-aware ranks up front (HEFT/PEFT) or
    // re-keys the ready queue at every decision (DLS) — none of which may
    // depend on the worker count, in sim or solve cells
    let mut g = grid();
    g.policies = vec!["cls/heft".into(), "cls/peft".into(), "cls/dls".into()];
    let serial = sweep::run_sweep(&g, 1);
    let parallel = sweep::run_sweep(&g, 4);
    assert_eq!(serial.len(), g.expand().len());
    assert_eq!(
        sweep::to_csv(&serial),
        sweep::to_csv(&parallel),
        "classic-policy CSV must not depend on the thread count"
    );
    assert_eq!(sweep::to_json(&serial), sweep::to_json(&parallel));
}

#[test]
fn cell_seeds_are_stable_under_grid_reordering() {
    let g = grid();
    let forward = sweep::run_sweep(&g, 2);

    // reverse every axis: every cell keeps its identity, only its
    // position in the grid changes
    let mut rev = grid();
    rev.platforms.reverse();
    rev.workloads.reverse();
    rev.policies.reverse();
    rev.tiles.reverse();
    rev.modes.reverse();
    rev.seeds.reverse();
    let backward = sweep::run_sweep(&rev, 2);

    assert_eq!(forward.len(), backward.len());
    for f in &forward {
        let b = backward
            .iter()
            .find(|b| key(b) == key(f))
            .unwrap_or_else(|| panic!("cell {:?} missing from reordered run", key(f)));
        assert_eq!(f.cell_seed, b.cell_seed, "seed must derive from coordinates, not position");
        assert_eq!(f.makespan, b.makespan, "same cell, same trajectory: {:?}", key(f));
        assert_eq!(f.transfer_bytes, b.transfer_bytes);
    }
}

#[test]
fn infeasible_tiles_are_skipped_not_errors() {
    let mut g = grid();
    g.tiles = vec![32, 48]; // 48 does not divide 128
    let cells = g.expand();
    assert!(cells
        .iter()
        .all(|c| c.workload.feasible(c.tile)));
    // cholesky dropped tile 48; the synthetic shapes kept it
    assert!(cells.iter().any(|c| c.tile == 48));
    assert!(!cells
        .iter()
        .any(|c| c.tile == 48 && matches!(c.workload, Workload::Cholesky { .. })));
}

#[test]
fn solve_cells_never_lose_to_their_baseline() {
    let g = grid();
    let results = sweep::run_sweep(&g, 4);
    let mut solved = 0;
    for r in results.iter().filter(|r| r.mode.starts_with("solve")) {
        solved += 1;
        assert!(
            r.makespan <= r.hom_makespan * 1.0001,
            "{}/{}/{}: solver kept a worse state ({} > {})",
            r.platform,
            r.workload,
            r.policy,
            r.makespan,
            r.hom_makespan
        );
    }
    assert!(solved > 0, "the grid must contain solve cells");
}

#[test]
fn csv_rows_match_cells_and_header() {
    let g = grid();
    let results = sweep::run_sweep(&g, 2);
    let csv = sweep::to_csv(&results);
    let mut lines = csv.lines();
    let header = lines.next().unwrap();
    assert_eq!(header, sweep::CSV_HEADER);
    let n_fields = header.split(',').count();
    let mut rows = 0;
    for line in lines {
        assert_eq!(line.split(',').count(), n_fields, "{line}");
        rows += 1;
    }
    assert_eq!(rows, results.len());
    assert_eq!(results.len(), g.expand().len());
}

#[test]
fn explicit_cell_lists_run_in_order() {
    // two-phase usage (Table 1): pick winners from one sweep, run an
    // explicit follow-up cell list through the same executor
    let g = grid();
    let mut cells = g.expand();
    cells.truncate(6);
    let a = sweep::run_cells(&g, &cells, 1);
    let b = sweep::run_cells(&g, &cells, 3);
    assert_eq!(a.len(), 6);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(key(x), key(y), "results must come back in cell-list order");
        assert_eq!(x.makespan, y.makespan);
    }
}

#[test]
fn seed_axis_actually_varies_random_workloads() {
    // the DAG-structure seed is a function of (workload, tile, declared
    // seed) ONLY — the policy and mode axes must not enter, or every
    // policy would schedule a different random instance and cross-policy
    // comparisons would be meaningless
    let s0 = workload_seed("random:16", 32, 0);
    let s1 = workload_seed("random:16", 32, 1);
    assert_ne!(s0, s1, "the declared seed axis varies the instance");
    // … while the full cell seed (scheduler RNG) does key on policy/mode
    assert_ne!(
        cell_seed("alpha", "random:16", "pl/eft-p", 32, "sim", 0),
        cell_seed("alpha", "random:16", "fcfs/eit-p", 32, "sim", 0)
    );
    let d0 = Workload::Random { n: 16 }.build(32, s0).unwrap();
    let d1 = Workload::Random { n: 16 }.build(32, s1).unwrap();
    let (e0, e1) = (d0.flat_dag().edge_count(), d1.flat_dag().edge_count());
    // reproducible for the same seed
    let d0b = Workload::Random { n: 16 }.build(32, s0).unwrap();
    assert_eq!(e0, d0b.flat_dag().edge_count());
    // (edge counts *can* coincide by chance; the structural check above
    // is the reproducibility contract, the inequality below is a smoke
    // check on this specific pair of seeds)
    assert_ne!((s0, e0), (s1, e1));
}

/// ISSUE-4 property test: a portfolio solve at `--threads 1` and
/// `--threads 4` produces an identical `SolveResult` — cost, action log
/// and final DAG shape — across 16 seeded grid cells (2 platforms x 2
/// workloads x 2 policies x 2 seeds, 3 lanes x 2-candidate batches each).
#[test]
fn portfolio_solve_is_identical_at_1_and_4_threads_across_16_cells() {
    let parts = PartitionerSet::standard();
    let reg = PolicyRegistry::standard();
    let platforms = [platform("alpha", 4, 20.0), platform("beta", 2, 35.0)];
    let workloads = [Workload::Cholesky { n: 128 }, Workload::Stencil { cells: 4, steps: 3 }];
    let policies = ["pl/eft-p", "fcfs/eit-p"];
    let seeds = [0u64, 1];
    let mode = "solve:3:16";
    let mut checked = 0;
    for p in &platforms {
        for w in &workloads {
            for pol in policies {
                for &seed in &seeds {
                    let wl = w.label();
                    let cseed = cell_seed(&p.name, &wl, pol, 32, mode, seed);
                    let dag = w.build(32, workload_seed(&wl, 32, seed)).expect("feasible cell");
                    let sim = SimConfig::new(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish))
                        .with_elem_bytes(p.elem_bytes)
                        .with_seed(cseed);
                    let mut base = SolverConfig::all_soft(sim, 3, 16);
                    base.seed = cseed;
                    let mut p1 = PortfolioConfig::new(base);
                    p1.lanes = 3;
                    p1.batch = 2;
                    p1.threads = 1;
                    let mut p4 = p1.clone();
                    p4.threads = 4;
                    let r1 = solve_portfolio(&dag, &p.machine, &p.db, &parts, &reg, pol, &p1);
                    let r4 = solve_portfolio(&dag, &p.machine, &p.db, &parts, &reg, pol, &p4);
                    // cost, lane, per-lane costs, full action log: one
                    // canonical serialization covers them all, bit-exact
                    assert_eq!(
                        result_json(&r1),
                        result_json(&r4),
                        "{}/{}/{pol}/seed{seed}: threads changed the solve trajectory",
                        p.name,
                        wl
                    );
                    // final DAG shape
                    assert_eq!(r1.best_dag.frontier(), r4.best_dag.frontier());
                    assert_eq!(r1.best_dag.depth(), r4.best_dag.depth());
                    assert_eq!(r1.best_dag.live_count(), r4.best_dag.live_count());
                    checked += 1;
                }
            }
        }
    }
    assert_eq!(checked, 16);
}

#[test]
fn portfolio_grid_knobs_keep_parallel_serial_identity() {
    // a grid with real portfolio width in its solve cells must still obey
    // the harness byte-identity contract (and exercises the thread-budget
    // passthrough: 8 requested threads over few cells leaves spare budget
    // inside each cell's portfolio)
    fn small() -> SweepGrid {
        let mut g = grid();
        g.platforms.truncate(1);
        g.workloads.truncate(1);
        g.policies.truncate(1);
        g.seeds.truncate(1);
        g
    }
    let mut g = small();
    g.solve_lanes = 3;
    g.solve_batch = 2;
    // 4 cells, 8 requested threads: each cell's portfolio receives the
    // spare budget (8 / 4 = 2 inner workers) — and must not change bytes
    let serial = sweep::run_sweep(&g, 1);
    let parallel = sweep::run_sweep(&g, 8);
    assert!(!serial.is_empty());
    assert_eq!(sweep::to_csv(&serial), sweep::to_csv(&parallel));

    // never-lose is only an invariant at MATCHED batch width: extra lanes
    // can't hurt (lane 0 of a lanes=3/batch=1 run IS the lanes=1/batch=1
    // trajectory), but a different batch width changes lane 0's RNG walk
    // and has no ordering guarantee against it
    let mut g_lanes = small();
    g_lanes.solve_lanes = 3;
    let multi = sweep::run_sweep(&g_lanes, 2);
    let single = sweep::run_sweep(&small(), 2);
    let mut compared = 0;
    for (m, one) in multi.iter().zip(&single).filter(|(m, _)| m.mode.starts_with("solve")) {
        assert!(
            m.makespan <= one.makespan + 1e-12,
            "{}: a 3-lane portfolio lost to its own lane 0 ({} > {})",
            m.policy,
            m.makespan,
            one.makespan
        );
        compared += 1;
    }
    assert!(compared > 0);
}

#[test]
fn workload_structure_is_mode_independent() {
    // a solve cell's internal baseline and the sim cell at the same
    // (platform, workload, policy, tile, seed) coordinates must simulate
    // the SAME DAG instance. Both policies in this grid are deterministic
    // (no RNG draws), so the baseline makespans must agree exactly — for
    // the random workload this fails if the DAG-structure seed is keyed
    // on the mode label (the regression `workload_seed` guards against).
    let g = grid();
    let results = sweep::run_sweep(&g, 2);
    let mut checked = 0;
    for r in results.iter().filter(|r| r.mode.starts_with("solve")) {
        let twin = results
            .iter()
            .find(|o| {
                o.mode == "sim"
                    && o.platform == r.platform
                    && o.workload == r.workload
                    && o.policy == r.policy
                    && o.tile == r.tile
                    && o.seed == r.seed
            })
            .expect("every solve cell has a sim twin in this grid");
        assert_eq!(r.hom_makespan, twin.makespan, "same DAG, same policy, same baseline: {:?}", key(r));
        checked += 1;
    }
    assert!(checked > 0);
}
