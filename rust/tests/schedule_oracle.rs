//! The schedule-invariant oracle, driven over randomized DAGs for every
//! registry policy on heterogeneous machines (ISSUE 4 satellite).
//!
//! `validate_schedule` re-derives realizability from the schedule alone —
//! processor/link exclusivity, dependence and arrival-gate ordering,
//! makespan and busy accounting — so this suite is an end-to-end proof
//! that the event core books what it claims, under every policy the
//! registry knows, for regular and randomized workload shapes, in both
//! plain simulation and full portfolio solves. CI runs it under
//! `--release` too, so optimized-build arithmetic goes through the same
//! checks as the debug build.

use hesp::coordinator::coherence::CachePolicy;
use hesp::coordinator::engine::{simulate_policy, SimConfig};
use hesp::coordinator::partitioners::{cholesky, PartitionerSet};
use hesp::coordinator::perfmodel::{PerfCurve, PerfDb};
use hesp::coordinator::platform::{Machine, MachineBuilder};
use hesp::coordinator::policies::{Ordering, ProcSelect, SchedConfig};
use hesp::coordinator::policy::PolicyRegistry;
use hesp::coordinator::solver::{solve_portfolio, PortfolioConfig, SolverConfig};
use hesp::coordinator::taskdag::TaskDag;
use hesp::coordinator::validate::validate_schedule;
use hesp::coordinator::workloads;

/// 4 equal CPUs in one space: the contention-free baseline.
fn flat_machine() -> (Machine, PerfDb) {
    let mut b = MachineBuilder::new("flat");
    let h = b.space("host", u64::MAX);
    b.main(h);
    let t = b.proc_type("cpu", 1.0, 0.1);
    b.processors(4, "c", t, h);
    let mut db = PerfDb::new();
    db.set_fallback(0, PerfCurve::Saturating { peak: 20.0, half: 64.0, exponent: 2.0 });
    (b.build(), db)
}

/// CPU + 2 GPUs in separate spaces behind narrow links: transfers, link
/// contention and arrival gates all exercised.
fn het_machine() -> (Machine, PerfDb) {
    let mut b = MachineBuilder::new("het");
    let h = b.space("host", u64::MAX);
    let g0 = b.space("g0", u64::MAX);
    let g1 = b.space("g1", u64::MAX);
    b.main(h);
    b.connect(h, g0, 1e-6, 5e7);
    b.connect(h, g1, 1e-6, 5e7);
    let cpu = b.proc_type("cpu", 1.0, 0.1);
    let gpu = b.proc_type("gpu", 2.0, 0.2);
    b.processors(2, "c", cpu, h);
    b.processors(1, "a", gpu, g0);
    b.processors(1, "b", gpu, g1);
    let mut db = PerfDb::new();
    db.set_fallback(0, PerfCurve::Const { gflops: 2.0 });
    db.set_fallback(1, PerfCurve::Saturating { peak: 30.0, half: 48.0, exponent: 2.0 });
    (b.build(), db)
}

fn workload_set() -> Vec<(String, TaskDag)> {
    let mut out = Vec::new();
    let mut chol = cholesky::root(256);
    cholesky::partition_uniform(&mut chol, 64);
    out.push(("cholesky:256/64".to_string(), chol));
    out.push(("layered:4x6".to_string(), workloads::layered(4, 6, 32)));
    out.push(("stencil:6x4".to_string(), workloads::stencil(6, 4, 32)));
    for seed in 0..4u64 {
        out.push((format!("random:48#{seed}"), workloads::random_layered(48, 32, seed)));
    }
    out
}

#[test]
fn every_policy_emits_valid_schedules_on_every_workload() {
    let reg = PolicyRegistry::standard();
    let machines = [flat_machine(), het_machine()];
    let mut checked = 0usize;
    for (m, db) in &machines {
        for (label, dag) in workload_set() {
            let flat = dag.flat_dag();
            for name in reg.names() {
                for cache in [CachePolicy::WriteBack, CachePolicy::WriteThrough] {
                    let mut pol = reg.get(name).expect("registered policy constructs");
                    let sim = SimConfig::new(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish))
                        .with_cache(cache)
                        .with_seed(0xc0ffee ^ checked as u64);
                    let sched = simulate_policy(&dag, m, db, sim, pol.as_mut());
                    validate_schedule(&dag, &flat, m, &sched).unwrap_or_else(|e| {
                        panic!("{}/{label}/{name}/{}: invalid schedule:\n{e}", m.name, cache.name())
                    });
                    checked += 1;
                }
            }
        }
    }
    // 15 registry policies (incl. cls/heft, cls/peft, cls/dls) x 7
    // workloads x 2 machines x 2 cache policies
    assert!(checked >= 15 * 7 * 2 * 2, "coverage shrank: {checked} schedules checked");
}

#[test]
fn portfolio_solver_schedules_validate_end_to_end() {
    // the oracle over full solver output: every lane winner, the final
    // best schedule and the re-simulated best DAG must all validate
    let reg = PolicyRegistry::standard();
    let parts = PartitionerSet::standard();
    for (m, db) in [flat_machine(), het_machine()] {
        let dag = cholesky::root(512);
        let sim = SimConfig::new(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish))
            .with_seed(7);
        let mut base = SolverConfig::all_soft(sim, 10, 64);
        base.seed = 7;
        let mut pcfg = PortfolioConfig::new(base);
        pcfg.lanes = 2;
        pcfg.batch = 3;
        pcfg.threads = 4;
        let res = solve_portfolio(&dag, &m, &db, &parts, &reg, "pl/eft-p", &pcfg);
        let flat = res.best_dag.flat_dag();
        validate_schedule(&res.best_dag, &flat, &m, &res.best_schedule)
            .unwrap_or_else(|e| panic!("{}: solver kept an invalid schedule:\n{e}", m.name));
        assert!(res.best_cost.is_finite());
        // replaying the winning DAG through the engine reproduces a valid
        // schedule with the same makespan
        let mut pol = reg.get("pl/eft-p").unwrap();
        let replay = simulate_policy(&res.best_dag, &m, &db, sim, pol.as_mut());
        validate_schedule(&res.best_dag, &flat, &m, &replay)
            .unwrap_or_else(|e| panic!("{}: replay invalid:\n{e}", m.name));
        assert_eq!(replay.makespan.to_bits(), res.best_schedule.makespan.to_bits());
    }
}

#[test]
fn oracle_rejects_tampered_schedules() {
    // sensitivity: the oracle must reject what the engine would never emit
    let (m, db) = het_machine();
    let mut dag = cholesky::root(256);
    cholesky::partition_uniform(&mut dag, 64);
    let flat = dag.flat_dag();
    let sim = SimConfig::new(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish));
    let mut pol = PolicyRegistry::standard().get("pl/eft-p").unwrap();
    let good = simulate_policy(&dag, &m, &db, sim, pol.as_mut());
    validate_schedule(&dag, &flat, &m, &good).expect("baseline must validate");

    // (a) same-processor overlap
    let mut s = good.clone();
    let p0 = s.assignments[0].proc;
    s.assignments[1].proc = p0;
    s.assignments[1].start = s.assignments[0].start;
    s.assignments[1].end = s.assignments[0].end.max(s.assignments[1].end);
    assert!(validate_schedule(&dag, &flat, &m, &s).is_err());

    // (b) dependence inversion
    let mut s = good.clone();
    let pos = (0..flat.len()).find(|&i| !flat.preds[i].is_empty()).unwrap();
    s.assignments[pos].release = 0.0;
    s.assignments[pos].start = 0.0;
    assert!(validate_schedule(&dag, &flat, &m, &s).is_err());

    // (c) understated makespan
    let mut s = good.clone();
    s.makespan *= 0.9;
    assert!(validate_schedule(&dag, &flat, &m, &s).is_err());

    // (d) non-finite time
    let mut s = good.clone();
    s.transfers[0].end = f64::NAN;
    assert!(validate_schedule(&dag, &flat, &m, &s).is_err());
}
