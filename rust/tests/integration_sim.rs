//! Integration tests: full pipeline from shipped platform configs through
//! simulation and the iterative solver, asserting the *qualitative shapes*
//! the paper reports (who wins, where the trade-offs fall).

use hesp::config::Platform;
use hesp::coordinator::energy::{energy, Objective, DEFAULT_J_PER_BYTE};
use hesp::coordinator::engine::{simulate, simulate_mapped, SimConfig};
use hesp::coordinator::metrics::{load_trace, report};
use hesp::coordinator::partitioners::{cholesky, PartitionerSet};
use hesp::coordinator::policies::{Ordering, ProcSelect, SchedConfig};
use hesp::coordinator::solver::{best_homogeneous, homogeneous_sweep, solve, SolverConfig};

fn bujaruelo() -> Platform {
    Platform::from_file(concat!(env!("CARGO_MANIFEST_DIR"), "/configs/bujaruelo.toml")).unwrap()
}

fn odroid() -> Platform {
    Platform::from_file(concat!(env!("CARGO_MANIFEST_DIR"), "/configs/odroid.toml")).unwrap()
}

fn pl_eft(p: &Platform) -> SimConfig {
    SimConfig::new(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish)).with_elem_bytes(p.elem_bytes)
}

#[test]
fn bujaruelo_platform_shape() {
    let p = bujaruelo();
    assert_eq!(p.machine.n_procs(), 28, "25 CPUs + 3 GPUs");
    assert_eq!(p.machine.spaces.len(), 4);
    assert_eq!(p.elem_bytes, 4);
    // GPUs dominate at huge tiles, CPUs competitive at small ones
    let xeon = p.db.curve(0, hesp::coordinator::task::TaskKind::Gemm);
    let gtx = p.db.curve(1, hesp::coordinator::task::TaskKind::Gemm);
    assert!(gtx.gflops(4096.0) > 30.0 * xeon.gflops(4096.0));
    assert!(gtx.gflops(64.0) < 20.0 * xeon.gflops(64.0));
}

#[test]
fn fig5_right_policy_sweep_shapes() {
    // Fig. 5 (right): performance vs tile count per policy. Assertions:
    // (1) every policy has an interior optimum or clear trade-off,
    // (2) EFT-P beats EIT-P beats R-P at the optimum,
    // (3) the optimal tile size depends on the policy.
    let p = bujaruelo();
    let n = 16_384;
    let tiles = [512u32, 1024, 2048, 4096];
    let mut best = std::collections::HashMap::new();
    for row in SchedConfig::table1_rows() {
        let sim = SimConfig::new(row).with_elem_bytes(p.elem_bytes);
        let sweep = homogeneous_sweep(n, &tiles, &p.machine, &p.db, sim);
        assert_eq!(sweep.len(), tiles.len());
        let (b, _, sched) = sweep
            .into_iter()
            .min_by(|a, b| a.2.makespan.total_cmp(&b.2.makespan))
            .unwrap();
        best.insert(row.name(), (b, sched.makespan));
    }
    let mk = |name: &str| best[name].1;
    assert!(mk("PL/EFT-P") < mk("PL/EIT-P"), "EFT beats EIT");
    assert!(mk("PL/EIT-P") < mk("FCFS/R-P"), "EIT beats random");
    // the optimal tile size depends on the policy (paper §3.1, fact 1):
    // transfer-aware EFT prefers coarser tiles than load-greedy EIT
    assert!(best["PL/EFT-P"].0 >= best["PL/EIT-P"].0, "{best:?}");
}

#[test]
fn heterogeneous_beats_homogeneous_on_bujaruelo() {
    // Table 1's headline: the found heterogeneous partition improves on
    // the best homogeneous tiling, raises load, lowers avg block size.
    let p = bujaruelo();
    let sim = pl_eft(&p);
    let tiles = [1024u32, 2048, 4096];
    let n = 16_384;
    let (_, hdag, hsched) = best_homogeneous(n, &tiles, &p.machine, &p.db, sim, Objective::Makespan).unwrap();
    let hr = report(&hdag, &hsched);
    let res = solve(hdag, &p.machine, &p.db, &PartitionerSet::standard(), SolverConfig::all_soft(sim, 120, 128));
    let er = report(&res.best_dag, &res.best_schedule);
    assert!(er.makespan <= hr.makespan, "{} vs {}", er.makespan, hr.makespan);
    assert!(er.gflops >= hr.gflops);
    assert!(er.dag_depth >= 2, "heterogeneous partitions are nested (depth {})", er.dag_depth);
    assert!(er.avg_block_size <= hr.avg_block_size + 1e-9);
}

#[test]
fn odroid_high_occupancy_leaves_little_room() {
    // The paper's ODROID observation: EIT-P yields ~99% load, so the
    // improvement from heterogeneous partitioning is small (<5%).
    let p = odroid();
    let sim = SimConfig::new(SchedConfig::new(Ordering::Fcfs, ProcSelect::EarliestIdle)).with_elem_bytes(p.elem_bytes);
    let tiles = [128u32, 256, 512];
    let (_, hdag, hsched) = best_homogeneous(4096, &tiles, &p.machine, &p.db, sim, Objective::Makespan).unwrap();
    let hr = report(&hdag, &hsched);
    assert!(hr.avg_load_pct > 90.0, "EIT keeps the asymmetric CPUs busy ({}%)", hr.avg_load_pct);
    let res = solve(hdag, &p.machine, &p.db, &PartitionerSet::standard(), SolverConfig::all_soft(sim, 60, 64));
    let improve = 100.0 * (hr.makespan - res.best_schedule.makespan) / res.best_schedule.makespan;
    assert!(improve < 8.0, "little room for improvement at high load, got {improve}%");
}

#[test]
fn fp_piles_work_on_fast_processors() {
    // F-P's known failure mode (Table 1: lowest loads): everything queues
    // on the fastest processors while slow ones idle.
    // Compare each policy at its own best homogeneous tiling (as Table 1
    // does): F-P is the weakest informed policy — EFT-P clearly beats it,
    // and EIT-P beats it too (paper: 5650/6096 vs 2846/3381 GFLOPS).
    let p = bujaruelo();
    let tiles = [512u32, 1024, 2048, 4096];
    let best = |sel: ProcSelect| {
        let sim = SimConfig::new(SchedConfig::new(Ordering::Fcfs, sel)).with_elem_bytes(p.elem_bytes);
        best_homogeneous(16_384, &tiles, &p.machine, &p.db, sim, Objective::Makespan).unwrap().2.makespan
    };
    let (fp, eit, eft) = (best(ProcSelect::Fastest), best(ProcSelect::EarliestIdle), best(ProcSelect::EarliestFinish));
    assert!(eft < fp, "EFT {eft} vs F-P {fp}");
    assert!(eit < fp, "EIT {eit} vs F-P {fp}");
}

#[test]
fn fig2b_load_trace_shows_tail_starvation() {
    // Fig. 2b: the final stages of Cholesky starve the machine.
    let p = bujaruelo();
    let mut dag = cholesky::root(16_384);
    cholesky::partition_uniform(&mut dag, 1_024);
    // EIT-P spreads over all 28 processors (like the paper's Fig. 2b run)
    let sim = SimConfig::new(SchedConfig::new(Ordering::Fcfs, ProcSelect::EarliestIdle)).with_elem_bytes(p.elem_bytes);
    let sched = simulate(&dag, &p.machine, &p.db, sim);
    let trace = load_trace(&sched, 100);
    let peak = trace.iter().map(|&(_, a)| a).max().unwrap();
    let tail = trace[95..].iter().map(|&(_, a)| a).max().unwrap();
    assert!(peak >= 10, "mid-execution parallelism present (peak {peak})");
    assert!(tail <= peak / 2, "tail starvation visible (tail {tail} vs peak {peak})");
}

#[test]
fn replica_mapping_reproduces_schedule() {
    // HESP-REPLICA mechanism: replaying a simulated mapping yields the
    // same makespan under the same models.
    let p = odroid();
    let sim = pl_eft(&p);
    let mut dag = cholesky::root(2048);
    cholesky::partition_uniform(&mut dag, 256);
    let sched = simulate(&dag, &p.machine, &p.db, sim);
    let replay = simulate_mapped(&dag, &p.machine, &p.db, sim, &sched.mapping());
    assert!((sched.makespan - replay.makespan).abs() < 1e-9 * sched.makespan.max(1.0));
}

#[test]
fn energy_objective_prefers_lower_power_schedules() {
    let p = odroid();
    let sim = pl_eft(&p);
    let tiles = [128u32, 256, 512];
    let parts = PartitionerSet::standard();
    let (_, hdag, _) = best_homogeneous(2048, &tiles, &p.machine, &p.db, sim, Objective::Makespan).unwrap();
    let mut cfg_mk = SolverConfig::all_soft(sim, 40, 64);
    cfg_mk.objective = Objective::Makespan;
    let mut cfg_en = cfg_mk;
    cfg_en.objective = Objective::Energy;
    let r_mk = solve(hdag.clone(), &p.machine, &p.db, &parts, cfg_mk);
    let r_en = solve(hdag, &p.machine, &p.db, &parts, cfg_en);
    let e_mk = energy(&r_mk.best_schedule, &p.machine, DEFAULT_J_PER_BYTE).total();
    let e_en = energy(&r_en.best_schedule, &p.machine, DEFAULT_J_PER_BYTE).total();
    assert!(e_en <= e_mk * 1.001, "energy objective no worse in joules ({e_en} vs {e_mk})");
    assert!(r_mk.best_schedule.makespan <= r_en.best_schedule.makespan * 1.001);
}

#[test]
fn caching_policy_ordering_on_transfer_volume() {
    // WB <= WT in bytes moved (write-through adds backflow), WA >= WB.
    use hesp::coordinator::coherence::CachePolicy;
    let p = bujaruelo();
    let mut dag = cholesky::root(8192);
    cholesky::partition_uniform(&mut dag, 1024);
    let base = pl_eft(&p);
    let wb = simulate(&dag, &p.machine, &p.db, base.with_cache(CachePolicy::WriteBack));
    let wt = simulate(&dag, &p.machine, &p.db, base.with_cache(CachePolicy::WriteThrough));
    let wa = simulate(&dag, &p.machine, &p.db, base.with_cache(CachePolicy::WriteAround));
    assert!(wb.transfer_bytes <= wt.transfer_bytes);
    assert!(wb.transfer_bytes <= wa.transfer_bytes);
}

#[test]
fn solver_history_is_recorded_and_improves() {
    let p = odroid();
    let sim = pl_eft(&p);
    let mut dag = cholesky::root(2048);
    cholesky::partition_uniform(&mut dag, 512);
    let first = simulate(&dag, &p.machine, &p.db, sim).makespan;
    let res = solve(dag, &p.machine, &p.db, &PartitionerSet::standard(), SolverConfig::all_soft(sim, 60, 64));
    assert!(res.best_cost <= first * 1.0001);
    assert!(!res.history.is_empty());
    assert_eq!(res.history[0].cost, first);
}

#[test]
fn constructive_online_improves_coarse_start_on_bujaruelo() {
    use hesp::coordinator::constructive::{schedule_online, OnlineConfig};
    let p = bujaruelo();
    let sim = pl_eft(&p);
    let mut dag = cholesky::root(16_384);
    cholesky::partition_uniform(&mut dag, 2_048);
    let base = simulate(&dag, &p.machine, &p.db, sim);
    let res = schedule_online(&dag, &p.machine, &p.db, &PartitionerSet::standard(), OnlineConfig::new(sim, 128));
    assert!(res.splits > 0, "online splits taken");
    // local-information-only decisions can regress slightly vs the static
    // schedule (the paper positions the constructive variant as
    // runtime-practical, not bound-optimal) — but never catastrophically
    assert!(
        res.schedule.makespan <= base.makespan * 1.15,
        "online {} vs static {}",
        res.schedule.makespan,
        base.makespan
    );
    // online refinement produces a nested DAG
    assert!(res.dag.depth() >= 2);
}

#[test]
fn synthetic_workloads_schedule_on_real_platforms() {
    use hesp::coordinator::workloads;
    let p = odroid();
    let sim = pl_eft(&p);
    for dag in [workloads::layered(4, 6, 128), workloads::stencil(6, 5, 128), workloads::random_layered(40, 128, 3)] {
        let sched = simulate(&dag, &p.machine, &p.db, sim);
        assert_eq!(sched.assignments.len(), dag.frontier().len());
        assert!(sched.makespan > 0.0 && sched.makespan.is_finite());
        let r = report(&dag, &sched);
        assert!(r.avg_load_pct > 0.0);
    }
}

#[test]
fn ascii_gantt_renders_platform_schedule() {
    use hesp::coordinator::trace::ascii_gantt;
    let p = odroid();
    let mut dag = cholesky::root(2048);
    cholesky::partition_uniform(&mut dag, 256);
    let sched = simulate(&dag, &p.machine, &p.db, pl_eft(&p));
    let g = ascii_gantt(&dag, &sched, &p.machine, 80);
    assert_eq!(g.lines().count(), 9, "8 procs + legend");
    for glyph in ['P', 'T', 'S', 'G'] {
        assert!(g.contains(glyph), "missing {glyph}");
    }
}

#[test]
fn cross_platform_scale_sanity() {
    // BUJARUELO is ~500x the GFLOPS of ODROID on the same relative
    // workload (paper: thousands vs ~9 GFLOPS).
    let pb = bujaruelo();
    let po = odroid();
    let mut db_dag = cholesky::root(16_384);
    cholesky::partition_uniform(&mut db_dag, 1024);
    let rb = report(&db_dag, &simulate(&db_dag, &pb.machine, &pb.db, pl_eft(&pb)));
    let mut do_dag = cholesky::root(4096);
    cholesky::partition_uniform(&mut do_dag, 256);
    let ro = report(&do_dag, &simulate(&do_dag, &po.machine, &po.db, pl_eft(&po)));
    assert!(rb.gflops > 1000.0, "bujaruelo in the TFLOPS regime: {}", rb.gflops);
    assert!(ro.gflops > 2.0 && ro.gflops < 15.0, "odroid in the ~5-10 GFLOPS regime: {}", ro.gflops);
}
