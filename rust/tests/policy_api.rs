//! Integration tests for the pluggable scheduling-policy API: registry
//! round-trips, enum-shim vs trait-object determinism, the transfer
//! behavior of the affinity policy, and user-defined policy registration.

use hesp::coordinator::coherence::CachePolicy;
use hesp::coordinator::constructive::{schedule_online_with, OnlineConfig};
use hesp::coordinator::engine::{simulate, simulate_policy, SimConfig};
use hesp::coordinator::partitioners::{cholesky, PartitionerSet};
use hesp::coordinator::perfmodel::{PerfCurve, PerfDb};
use hesp::coordinator::platform::{Machine, MachineBuilder, ProcId};
use hesp::coordinator::policies::SchedConfig;
use hesp::coordinator::policy::{policy_by_name, PolicyRegistry, SchedContext, SchedPolicy};
use hesp::coordinator::solver::{solve_with, SolverConfig};
use hesp::coordinator::task::Task;
use hesp::coordinator::taskdag::TaskDag;

/// Host (2 CPUs) + 2 GPU memory spaces (1 fast GPU each) over PCIe-ish
/// links — transfers are real and the GPUs dominate on every kernel, so
/// EFT-P moves data while affinity can avoid it.
fn gpu_machine() -> (Machine, PerfDb) {
    let mut b = MachineBuilder::new("t");
    let host = b.space("host", u64::MAX);
    let g0 = b.space("gpu0", u64::MAX);
    let g1 = b.space("gpu1", u64::MAX);
    b.main(host);
    b.connect(host, g0, 1e-5, 1e9);
    b.connect(host, g1, 1e-5, 1e9);
    let cpu = b.proc_type("cpu", 10.0, 1.0);
    let gpu = b.proc_type("gpu", 100.0, 10.0);
    b.processors(2, "c", cpu, host);
    b.processors(1, "ga", gpu, g0);
    b.processors(1, "gb", gpu, g1);
    let m = b.build();
    let mut db = PerfDb::new();
    db.set_fallback(0, PerfCurve::Const { gflops: 1.0 });
    db.set_fallback(1, PerfCurve::Const { gflops: 50.0 });
    (m, db)
}

/// Single memory space, 2 slow + 2 fast CPUs with saturating curves.
fn cpu_machine() -> (Machine, PerfDb) {
    let mut b = MachineBuilder::new("c");
    let h = b.space("host", u64::MAX);
    b.main(h);
    let slow = b.proc_type("slow", 1.0, 0.1);
    let fast = b.proc_type("fast", 1.0, 0.1);
    b.processors(2, "s", slow, h);
    b.processors(2, "f", fast, h);
    let m = b.build();
    let mut db = PerfDb::new();
    db.set_fallback(0, PerfCurve::Saturating { peak: 5.0, half: 64.0, exponent: 2.0 });
    db.set_fallback(1, PerfCurve::Saturating { peak: 20.0, half: 64.0, exponent: 2.0 });
    (m, db)
}

fn chol(n: u32, b: u32) -> TaskDag {
    let mut dag = cholesky::root(n);
    cholesky::partition_uniform(&mut dag, b);
    dag
}

#[test]
fn registry_round_trips_every_name() {
    let reg = PolicyRegistry::standard();
    let names = reg.names();
    assert_eq!(names.len(), 15, "8 Table-1 rows + affinity + lookahead + edf + sjf + heft + peft + dls: {names:?}");
    for &name in &names {
        let p = reg.get(name).unwrap_or_else(|| panic!("'{name}' does not construct"));
        assert_eq!(p.name(), name, "name() must round-trip through the registry");
    }
    // every Table-1 row resolves under its canonical lowercase name
    for row in SchedConfig::table1_rows() {
        let canonical = row.name().to_ascii_lowercase();
        let p = reg.get(&canonical).unwrap_or_else(|| panic!("Table-1 '{canonical}' missing"));
        assert_eq!(p.name(), canonical);
    }
    for extra in ["pl/affinity", "pl/lookahead", "pl/edf-p", "pl/sjf-p", "cls/heft", "cls/peft", "cls/dls"] {
        assert!(names.contains(&extra), "{extra} not registered");
    }
}

#[test]
fn enum_shim_and_trait_object_are_bit_identical() {
    // Same seed + same policy must produce the identical schedule whether
    // the engine is entered through the legacy enum shim (`simulate`) or
    // through a registry-built trait object (`simulate_policy`).
    let (m, db) = gpu_machine();
    let dag = chol(512, 128);
    for row in SchedConfig::table1_rows() {
        for seed in [0u64, 7, 0xBEEF] {
            let cfg = SimConfig::new(row).with_seed(seed);
            let via_enum = simulate(&dag, &m, &db, cfg);
            let mut pol = policy_by_name(&row.name().to_ascii_lowercase()).unwrap();
            let via_trait = simulate_policy(&dag, &m, &db, cfg, pol.as_mut());
            assert_eq!(via_enum.mapping(), via_trait.mapping(), "{} seed {seed}", row.name());
            assert_eq!(via_enum.makespan, via_trait.makespan, "{} seed {seed}", row.name());
            assert_eq!(via_enum.transfer_bytes, via_trait.transfer_bytes, "{} seed {seed}", row.name());
        }
    }
}

#[test]
fn trait_objects_are_deterministic_per_seed() {
    let (m, db) = gpu_machine();
    let dag = chol(512, 128);
    let cfg = SimConfig::new(SchedConfig::table1_rows()[0]).with_seed(42); // fcfs/r-p
    let mut p1 = policy_by_name("fcfs/r-p").unwrap();
    let mut p2 = policy_by_name("fcfs/r-p").unwrap();
    let a = simulate_policy(&dag, &m, &db, cfg, p1.as_mut());
    let b = simulate_policy(&dag, &m, &db, cfg, p2.as_mut());
    assert_eq!(a.mapping(), b.mapping());
    assert_eq!(a.makespan, b.makespan);
}

#[test]
fn affinity_strictly_reduces_transfer_bytes_vs_eft() {
    // Transfer-heavy setup: the GPUs are 50x faster, so EFT-P ships tiles
    // to device memory all factorization long. The affinity policy keeps
    // tasks where their inputs already live (initially: main memory), so
    // it must move strictly fewer bytes on the same Cholesky DAG.
    let (m, db) = gpu_machine();
    let dag = chol(512, 128);
    let cfg = SimConfig::new(SchedConfig::table1_rows()[7]); // pl/eft-p shim fields
    let mut eft = policy_by_name("pl/eft-p").unwrap();
    let mut aff = policy_by_name("pl/affinity").unwrap();
    let s_eft = simulate_policy(&dag, &m, &db, cfg, eft.as_mut());
    let s_aff = simulate_policy(&dag, &m, &db, cfg, aff.as_mut());
    assert_eq!(s_aff.assignments.len(), dag.frontier().len());
    assert!(s_eft.transfer_bytes > 0, "EFT-P must be transfer-heavy here");
    assert!(
        s_aff.transfer_bytes < s_eft.transfer_bytes,
        "affinity {} bytes vs EFT {} bytes",
        s_aff.transfer_bytes,
        s_eft.transfer_bytes
    );
    // WriteBack + all inputs initially in main memory: full affinity means
    // no traffic at all
    assert_eq!(cfg.cache, CachePolicy::WriteBack);
    assert_eq!(s_aff.transfer_bytes, 0, "full-affinity run moves nothing");
}

#[test]
fn lookahead_schedules_everything_and_stays_sane() {
    let (m, db) = cpu_machine();
    let dag = chol(512, 64);
    let cfg = SimConfig::new(SchedConfig::table1_rows()[7]);
    let mut la = policy_by_name("pl/lookahead").unwrap();
    let mut eft = policy_by_name("pl/eft-p").unwrap();
    let s_la = simulate_policy(&dag, &m, &db, cfg, la.as_mut());
    let s_eft = simulate_policy(&dag, &m, &db, cfg, eft.as_mut());
    assert_eq!(s_la.assignments.len(), dag.frontier().len());
    assert!(s_la.makespan.is_finite() && s_la.makespan > 0.0);
    // one-step lookahead is a heuristic, not an oracle — but it must stay
    // in the same ballpark as plain EFT
    assert!(s_la.makespan <= s_eft.makespan * 1.5, "{} vs {}", s_la.makespan, s_eft.makespan);
    // dependence sanity under the new policy
    for a in &s_la.assignments {
        assert!(a.start >= a.release - 1e-12);
    }
}

/// A user-defined policy: everything on processor 0, FCFS order.
struct PinToZero;

impl SchedPolicy for PinToZero {
    fn name(&self) -> &str {
        "test/pin-zero"
    }

    fn order(&mut self, _ctx: &mut SchedContext<'_>, _task: &Task, release: f64, _critical: f64) -> f64 {
        -release
    }

    fn select(&mut self, _ctx: &mut SchedContext<'_>, _task: &Task, _release: f64) -> ProcId {
        0
    }
}

#[test]
fn user_policies_register_and_drive_the_engine() {
    let mut reg = PolicyRegistry::standard();
    reg.register("test/pin-zero", || Box::new(PinToZero) as Box<dyn SchedPolicy>);
    assert_eq!(reg.len(), 16);
    let mut pol = reg.get("test/pin-zero").unwrap();
    assert_eq!(pol.name(), "test/pin-zero");

    let (m, db) = cpu_machine();
    let dag = chol(256, 64);
    let cfg = SimConfig::new(SchedConfig::table1_rows()[0]);
    let sched = simulate_policy(&dag, &m, &db, cfg, pol.as_mut());
    assert_eq!(sched.assignments.len(), dag.frontier().len());
    assert!(sched.assignments.iter().all(|a| a.proc == 0), "user policy decides placement");
    // serialized on one proc: load concentrates there
    assert!(sched.proc_busy[0] > 0.0);
    assert_eq!(sched.proc_busy[1..].iter().copied().fold(0.0f64, f64::max), 0.0);
}

#[test]
fn solver_dispatches_through_trait_policies() {
    let (m, db) = cpu_machine();
    let dag = cholesky::root(1024);
    let base = {
        let mut eft = policy_by_name("pl/eft-p").unwrap();
        simulate_policy(&dag, &m, &db, SimConfig::new(SchedConfig::table1_rows()[7]), eft.as_mut())
    };
    for name in ["pl/affinity", "pl/lookahead", "cls/heft"] {
        let mut pol = policy_by_name(name).unwrap();
        let cfg = SolverConfig::all_soft(SimConfig::new(SchedConfig::table1_rows()[7]), 25, 64);
        let res = solve_with(dag.clone(), &m, &db, &PartitionerSet::standard(), cfg, pol.as_mut());
        assert!(res.best_cost.is_finite() && res.best_cost > 0.0, "{name}");
        // single-space machine: the solver must at least match the
        // unpartitioned root task it starts from
        assert!(res.best_cost <= base.makespan * 10.0, "{name}: {res_cost} vs {base}", res_cost = res.best_cost, base = base.makespan);
        assert!(!res.history.is_empty(), "{name}");
    }
}

#[test]
fn constructive_dispatches_through_trait_policies() {
    let (m, db) = cpu_machine();
    let dag = chol(512, 128);
    for name in ["pl/lookahead", "pl/affinity", "fcfs/eit-p", "cls/heft", "cls/peft", "cls/dls"] {
        let mut pol = policy_by_name(name).unwrap();
        let cfg = OnlineConfig::new(SimConfig::new(SchedConfig::table1_rows()[7]), 64);
        let res = schedule_online_with(&dag, &m, &db, &PartitionerSet::standard(), cfg, pol.as_mut());
        assert_eq!(res.schedule.assignments.len(), res.dag.frontier().len(), "{name}");
        assert!(res.schedule.makespan.is_finite() && res.schedule.makespan > 0.0, "{name}");
    }
}
