//! Integration tests for the detlint static-analysis pass (`hesp lint`)
//! and the static input sanitizer (`hesp check`).
//!
//! The two load-bearing assertions live here: the shipped tree is
//! lint-clean (every suppression carries a written reason), and every
//! shipped input file passes `hesp check` — the same invariants the
//! blocking CI `lint` job enforces by running the binary.

use std::path::Path;

use hesp::analysis::check::{check_file, check_text};
use hesp::analysis::{default_check_files, lint_files, lint_tree};

fn lint_one(path: &str, src: &str) -> hesp::analysis::LintReport {
    lint_files(&[(path.to_string(), src.to_string())])
}

#[test]
fn fixture_triggers_hashmap_iter_exactly_once() {
    let r = lint_one(
        "src/coordinator/fixture.rs",
        "fn f(m: &FxHashMap<u32, u32>) {\n    for k in m {\n        let _ = k;\n    }\n}\n",
    );
    assert_eq!(r.findings.len(), 1, "{:?}", r.findings);
    assert_eq!(r.findings[0].rule, "det/hashmap-iter");
    assert_eq!(r.findings[0].line, 2);
    assert_eq!(r.unsuppressed(), 1);
}

#[test]
fn fixture_triggers_checkpoint_hash_exactly_once() {
    // A per-process-keyed std hasher next to checkpoint/signature code
    // would make identical frontier states hash differently across runs.
    let r = lint_one(
        "src/coordinator/fixture.rs",
        "fn sig(xs: &[u64]) -> u64 {\n    let mut h = std::collections::hash_map::DefaultHasher::new();\n    for x in xs {\n        h.write_u64(*x);\n    }\n    h.finish()\n}\n",
    );
    assert_eq!(r.findings.len(), 1, "{:?}", r.findings);
    assert_eq!(r.findings[0].rule, "det/checkpoint-hash");
    assert_eq!(r.findings[0].line, 2);
    // The same code outside coordinator/ is out of scope for this rule.
    let out_of_scope = lint_one(
        "src/util/fixture.rs",
        "fn sig() -> std::collections::hash_map::DefaultHasher {\n    std::collections::hash_map::DefaultHasher::new()\n}\n",
    );
    assert_eq!(out_of_scope.findings.len(), 0, "{:?}", out_of_scope.findings);
}

#[test]
fn fixture_triggers_wall_clock_exactly_once() {
    let r = lint_one(
        "src/coordinator/fixture.rs",
        "fn f() -> std::time::Instant {\n    std::time::Instant::now()\n}\n",
    );
    assert_eq!(r.findings.len(), 1, "{:?}", r.findings);
    assert_eq!(r.findings[0].rule, "det/wall-clock");
}

#[test]
fn fixture_triggers_unseeded_rng_exactly_once() {
    let r = lint_one("src/util/fixture.rs", "fn f() -> Rng {\n    Rng::new(42)\n}\n");
    assert_eq!(r.findings.len(), 1, "{:?}", r.findings);
    assert_eq!(r.findings[0].rule, "det/unseeded-rng");
    // ...and a content-derived seed passes.
    let clean = lint_one(
        "src/util/fixture.rs",
        "fn f(s: &str) -> Rng {\n    Rng::new(content_seed(&[s], &[]))\n}\n",
    );
    assert_eq!(clean.findings.len(), 0, "{:?}", clean.findings);
}

#[test]
fn fixture_triggers_float_reduce_exactly_once() {
    // Outside coordinator/ so det/hashmap-iter stays quiet and the
    // float-reduce finding is the only one.
    let r = lint_one(
        "src/util/fixture.rs",
        "struct S { m: FxHashMap<u32, f64> }\nimpl S {\n    fn total(&self) -> f64 { self.m.values().sum() }\n}\n",
    );
    assert_eq!(r.findings.len(), 1, "{:?}", r.findings);
    assert_eq!(r.findings[0].rule, "det/float-reduce");
}

#[test]
fn fixture_triggers_panic_in_lib_exactly_once() {
    let r = lint_one(
        "src/util/cli.rs",
        "fn parse(s: &str) -> u32 {\n    s.parse().unwrap()\n}\n",
    );
    assert_eq!(r.findings.len(), 1, "{:?}", r.findings);
    assert_eq!(r.findings[0].rule, "safety/panic-in-lib");
    // The same code outside the input-parsing scope is fine.
    let out_of_scope = lint_one(
        "src/coordinator/solver.rs",
        "fn parse(s: &str) -> u32 {\n    s.parse().unwrap()\n}\n",
    );
    assert_eq!(out_of_scope.findings.len(), 0, "{:?}", out_of_scope.findings);
}

#[test]
fn suppression_round_trip() {
    let src = "fn f(m: &FxHashMap<u32, u32>) {\n    // detlint: allow(det/hashmap-iter) — keys are sorted before use\n    let mut ks: Vec<&u32> = m.keys().collect();\n    ks.sort();\n}\n";
    let r = lint_one("src/coordinator/fixture.rs", src);
    assert_eq!(r.findings.len(), 1, "{:?}", r.findings);
    assert!(r.findings[0].suppressed);
    assert_eq!(r.unsuppressed(), 0);
    assert_eq!(r.suppressed(), 1);

    // A pragma without a reason does NOT suppress — and is itself flagged.
    let bare = src.replace(" — keys are sorted before use", "");
    let r2 = lint_one("src/coordinator/fixture.rs", &bare);
    assert!(r2.findings.iter().any(|f| f.rule == "lint/bare-allow"));
    assert!(r2.findings.iter().any(|f| f.rule == "det/hashmap-iter" && !f.suppressed));

    // A pragma naming an unknown rule is flagged too.
    let r3 = lint_one(
        "src/fixture.rs",
        "// detlint: allow(det/no-such-rule) — reason\nfn f() {}\n",
    );
    assert_eq!(r3.findings.len(), 1);
    assert_eq!(r3.findings[0].rule, "lint/bare-allow");
    assert!(r3.findings[0].message.contains("unknown rule"));
}

/// The crate root (`rust/`), valid both under `cargo test` and in CI.
fn crate_root() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn shipped_tree_is_lint_clean() {
    let report = lint_tree(crate_root()).expect("lint_tree over the shipped tree");
    assert!(report.files_scanned > 40, "suspiciously few files: {}", report.files_scanned);
    let open: Vec<_> = report.findings.iter().filter(|f| !f.suppressed).collect();
    assert!(
        open.is_empty(),
        "shipped tree must be lint-clean; unsuppressed findings:\n{}",
        open.iter()
            .map(|f| format!("  {}:{}: {}: {}", f.file, f.line, f.rule, f.message))
            .collect::<Vec<_>>()
            .join("\n")
    );
    // Every suppression in the tree carries a written reason — a bare
    // pragma would surface as an unsuppressible lint/bare-allow above.
    assert!(report.suppressed() > 0, "the tree documents its known-safe suppressions");
}

#[test]
fn lint_json_is_byte_identical_across_runs() {
    let a = lint_tree(crate_root()).unwrap().to_json().to_string();
    let b = lint_tree(crate_root()).unwrap().to_json().to_string();
    assert_eq!(a, b);
    assert!(a.contains("\"unsuppressed\":0"), "clean-tree JSON: {a}");
    // The human report is byte-stable too.
    let ra = lint_tree(crate_root()).unwrap().render();
    let rb = lint_tree(crate_root()).unwrap().render();
    assert_eq!(ra, rb);
}

#[test]
fn every_shipped_input_passes_check() {
    let files = default_check_files(crate_root());
    assert!(
        files.iter().any(|f| f.ends_with("bujaruelo.toml")),
        "shipped configs discovered: {files:?}"
    );
    assert!(files.iter().any(|f| f.ends_with("serve_trace.jsonl")), "{files:?}");
    assert!(files.iter().any(|f| f.ends_with("sweep_grid.toml")), "{files:?}");
    for f in &files {
        let errors: Vec<_> = check_file(f).into_iter().filter(|d| d.error).collect();
        assert!(
            errors.is_empty(),
            "{f} must pass hesp check: {:?}",
            errors.iter().map(|d| d.render()).collect::<Vec<_>>()
        );
    }
}

#[test]
fn corrupt_platform_is_rejected_with_file_key_diagnostics() {
    let text = std::fs::read_to_string(crate_root().join("configs/bujaruelo.toml")).unwrap();
    // Cut every link out of the platform: the device spaces disconnect.
    let cut: String = {
        let mut out = String::new();
        let mut skip = false;
        for line in text.lines() {
            if line.trim() == "[[link]]" {
                skip = true;
            } else if line.starts_with('[') && line.trim() != "[[link]]" {
                skip = false;
            }
            if !skip {
                out.push_str(line);
                out.push('\n');
            }
        }
        out
    };
    let diags = check_text("bujaruelo.toml", &cut);
    assert!(
        diags.iter().any(|d| d.error && d.key.starts_with("memory.") && d.msg.contains("disconnected")),
        "{:?}",
        diags.iter().map(|d| d.render()).collect::<Vec<_>>()
    );
    // Diagnostics render as file:key: severity: message.
    let line = diags[0].render();
    assert!(line.starts_with("bujaruelo.toml:"), "{line}");
}

#[test]
fn corrupt_trace_is_rejected_with_line_diagnostics() {
    let text = concat!(
        "{\"t_arrival\": 0.0, \"workload\": \"cholesky:1024\", \"tile\": 256, \"id\": 9}\n",
        "{\"t_arrival\": 1.0, \"workload\": \"cholesky:1024\", \"tile\": 256, \"id\": 9}\n",
        "{\"t_arrival\": 2.0, \"workload\": \"cholesky:1024\", \"tile\": 256, \"deadline\": 1.0}\n",
        "{\"t_arrival\": -1.0, \"workload\": \"cholesky:1024\", \"tile\": 256}\n",
    );
    let diags = check_text("t.jsonl", text);
    assert!(diags.iter().any(|d| d.error && d.key == "line 2" && d.msg.contains("duplicate job id 9")));
    assert!(diags.iter().any(|d| d.error && d.key == "line 3" && d.msg.contains("precedes arrival")));
    assert!(diags.iter().any(|d| d.error && d.key == "line 4"), "{diags:?}");
}

#[test]
fn corrupt_grid_is_rejected() {
    // cholesky:1000 can never tile at 256 (n % b != 0), so the grid is empty.
    let grid = "platforms = [\"configs/bujaruelo.toml\"]\nworkloads = [\"cholesky:1000\"]\npolicies = [\"pl/eft-p\"]\ntiles = [256]\n";
    let diags = check_text("g.toml", grid);
    assert!(diags.iter().any(|d| d.error && d.key == "workloads.cholesky:1000"), "{diags:?}");
    assert!(diags.iter().any(|d| d.error && d.key == "grid"), "{diags:?}");
}
