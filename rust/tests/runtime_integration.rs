//! PJRT runtime integration: load the AOT artifacts, execute tile kernels
//! and full factorizations for real, and check the numerics against
//! pure-Rust references. Tests skip (with a notice) when `make artifacts`
//! has not been run.

use hesp::coordinator::task::TaskKind;
use hesp::runtime::executor::{self, artifacts_available, artifacts_dir, random_spd};
use hesp::runtime::{tile_literal_f32, tile_literal_f64, tile_to_vec_f32, DType, Runtime};
use hesp::util::rng::Rng;

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
            return;
        }
    };
}

fn load(tiles: &[u32], dtype: &str) -> Runtime {
    Runtime::load_filtered(artifacts_dir(), |e| e.dtype == dtype && tiles.contains(&e.tile)).unwrap()
}

fn rand_tile(rng: &mut Rng, b: u32) -> Vec<f32> {
    (0..b * b).map(|_| rng.normal() as f32).collect()
}

#[test]
fn manifest_covers_all_task_kinds_and_tiles() {
    require_artifacts!();
    let entries = hesp::runtime::artifacts::read_manifest(artifacts_dir()).unwrap();
    for task in ["potrf", "trsm", "syrk", "gemm"] {
        for dtype in ["f32", "f64"] {
            for tile in [32u32, 64, 128, 256] {
                assert!(
                    entries.iter().any(|e| e.task == task && e.dtype == dtype && e.tile == tile),
                    "missing {task}_{dtype}_{tile}"
                );
            }
        }
    }
}

#[test]
fn gemm_kernel_matches_rust_reference() {
    require_artifacts!();
    let rt = load(&[32], "f32");
    let k = rt.kernel(TaskKind::Gemm, DType::F32, 32).unwrap();
    let mut rng = Rng::new(1);
    let (c, a, b) = (rand_tile(&mut rng, 32), rand_tile(&mut rng, 32), rand_tile(&mut rng, 32));
    let out = k
        .execute(&[
            tile_literal_f32(&c, 32).unwrap(),
            tile_literal_f32(&a, 32).unwrap(),
            tile_literal_f32(&b, 32).unwrap(),
        ])
        .unwrap();
    let got = tile_to_vec_f32(&out).unwrap();
    // reference: C - A @ B^T
    for i in 0..32 {
        for j in 0..32 {
            let mut acc = c[i * 32 + j] as f64;
            for p in 0..32 {
                acc -= a[i * 32 + p] as f64 * b[j * 32 + p] as f64;
            }
            let err = (got[i * 32 + j] as f64 - acc).abs();
            assert!(err < 1e-3, "gemm mismatch at ({i},{j}): {err}");
        }
    }
}

#[test]
fn potrf_kernel_factorizes() {
    require_artifacts!();
    let rt = load(&[64], "f32");
    let k = rt.kernel(TaskKind::Potrf, DType::F32, 64).unwrap();
    let a = random_spd(64, 3);
    let out = k.execute(&[tile_literal_f32(&a, 64).unwrap()]).unwrap();
    let l = tile_to_vec_f32(&out).unwrap();
    // L is lower-triangular and L L^T == A
    let mut max_err = 0f64;
    for i in 0..64 {
        for j in 0..64 {
            if j > i {
                assert!(l[i * 64 + j].abs() < 1e-5, "upper triangle not zero");
            } else {
                let mut acc = 0f64;
                for p in 0..=j {
                    acc += l[i * 64 + p] as f64 * l[j * 64 + p] as f64;
                }
                max_err = max_err.max((acc - a[i * 64 + j] as f64).abs());
            }
        }
    }
    assert!(max_err < 1e-4, "reconstruction error {max_err}");
}

#[test]
fn trsm_kernel_solves() {
    require_artifacts!();
    let rt = load(&[32], "f32");
    let k = rt.kernel(TaskKind::Trsm, DType::F32, 32).unwrap();
    let mut rng = Rng::new(5);
    // well-conditioned lower-triangular L
    let mut l = vec![0f32; 32 * 32];
    for i in 0..32 {
        for j in 0..=i {
            l[i * 32 + j] = if i == j { 4.0 } else { rng.normal() as f32 * 0.2 };
        }
    }
    let b = rand_tile(&mut rng, 32);
    let out = k
        .execute(&[tile_literal_f32(&l, 32).unwrap(), tile_literal_f32(&b, 32).unwrap()])
        .unwrap();
    let x = tile_to_vec_f32(&out).unwrap();
    // check X L^T == B
    for i in 0..32 {
        for j in 0..32 {
            let mut acc = 0f64;
            for p in 0..32 {
                acc += x[i * 32 + p] as f64 * l[j * 32 + p] as f64;
            }
            assert!((acc - b[i * 32 + j] as f64).abs() < 1e-3);
        }
    }
}

#[test]
fn f64_kernels_execute() {
    require_artifacts!();
    let rt = load(&[32], "f64");
    let k = rt.kernel(TaskKind::Syrk, DType::F64, 32).unwrap();
    let c: Vec<f64> = (0..32 * 32).map(|i| i as f64 * 0.001).collect();
    let a: Vec<f64> = (0..32 * 32).map(|i| (i % 7) as f64 * 0.01).collect();
    let out = k
        .execute(&[tile_literal_f64(&c, 32).unwrap(), tile_literal_f64(&a, 32).unwrap()])
        .unwrap();
    let got = out.to_vec::<f64>().unwrap();
    for i in 0..32 {
        for j in 0..32 {
            let mut acc = c[i * 32 + j];
            for p in 0..32 {
                acc -= a[i * 32 + p] * a[j * 32 + p];
            }
            assert!((got[i * 32 + j] - acc).abs() < 1e-9);
        }
    }
}

#[test]
fn full_cholesky_execution_verifies() {
    require_artifacts!();
    let rt = load(&[64], "f32");
    let r = executor::run_cholesky(&rt, 256, 64, 42).unwrap();
    assert!(r.max_err < 1e-3, "numerics: {}", r.max_err);
    assert_eq!(r.timings.len(), hesp::coordinator::partitioners::cholesky::task_count(4) as usize);
    assert!(r.total_s > 0.0);
    assert!(r.gflops() > 0.0);
}

#[test]
fn execution_is_deterministic_in_values() {
    require_artifacts!();
    let rt = load(&[64], "f32");
    let a = executor::run_cholesky(&rt, 128, 64, 9).unwrap();
    let b = executor::run_cholesky(&rt, 128, 64, 9).unwrap();
    assert_eq!(a.max_err, b.max_err, "same input -> bitwise same factor");
}

#[test]
fn measured_models_are_sane() {
    require_artifacts!();
    let rt = load(&[32, 64], "f32");
    let ms = executor::measure_models(&rt, &[32, 64], 3, 1).unwrap();
    assert_eq!(ms.len(), 8, "4 kinds x 2 tiles");
    for (kind, tile, gflops) in ms {
        assert!(gflops > 1e-3 && gflops < 1e3, "{kind:?} {tile}: {gflops} GFLOPS");
    }
}

#[test]
fn kernel_rejects_wrong_arity() {
    require_artifacts!();
    let rt = load(&[32], "f32");
    let k = rt.kernel(TaskKind::Gemm, DType::F32, 32).unwrap();
    let t = tile_literal_f32(&vec![0f32; 32 * 32], 32).unwrap();
    assert!(k.execute(&[t]).is_err());
}

#[test]
fn runtime_tile_listing() {
    require_artifacts!();
    let rt = load(&[32, 64], "f32");
    assert_eq!(rt.tiles_for(DType::F32), vec![32, 64]);
    assert!(rt.tiles_for(DType::F64).is_empty());
    assert!(rt.kernel(TaskKind::Gemm, DType::F32, 128).is_err());
}
