//! Service-layer determinism contract: a parallel serve run is
//! byte-identical to the single-threaded run on the same grid, arrival
//! streams are pure functions of (label, seed) and shared across
//! policies, and admission control conserves jobs — a full queue rejects
//! loudly, never drops silently.

use hesp::coordinator::coherence::CachePolicy;
use hesp::coordinator::perfmodel::{PerfCurve, PerfDb};
use hesp::coordinator::platform::MachineBuilder;
use hesp::coordinator::service::{self, Admission, ArrivalSpec, ServeGrid};
use hesp::coordinator::sweep::SweepPlatform;

/// A small in-memory platform (no config files in unit tests).
fn platform(name: &str, ncpu: usize, peak: f64) -> SweepPlatform {
    let mut b = MachineBuilder::new(name);
    let h = b.space("host", u64::MAX);
    b.main(h);
    let t = b.proc_type("cpu", 1.0, 0.1);
    b.processors(ncpu, "c", t, h);
    let mut db = PerfDb::new();
    db.set_fallback(0, PerfCurve::Saturating { peak, half: 64.0, exponent: 2.0 });
    SweepPlatform::new(name, b.build(), db, 8)
}

fn grid() -> ServeGrid {
    ServeGrid {
        platforms: vec![platform("alpha", 4, 20.0), platform("beta", 2, 35.0)],
        arrivals: vec![
            ArrivalSpec::Poisson { rate: 6.0 },
            ArrivalSpec::Bursty { lo: 2.0, hi: 20.0, dwell: 0.2 },
        ],
        policies: vec!["pl/eft-p".into(), "pl/edf-p".into(), "pl/sjf-p".into()],
        duration: 1.0,
        queue_cap: 64,
        admission: Admission::Defer,
        cache: CachePolicy::WriteBack,
        seed: 0,
        max_defer: None,
        faults: None,
    }
}

#[test]
fn serve_bundle_is_byte_identical_across_thread_counts() {
    let g = grid();
    let serial = service::run_serve(&g, 1).unwrap();
    let parallel = service::run_serve(&g, 4).unwrap();
    assert_eq!(serial.len(), 12, "2 platforms x 2 arrivals x 3 policies");
    assert!(serial.iter().any(|r| r.completed > 0), "streams must carry jobs");
    assert_eq!(
        service::to_csv(&serial, false),
        service::to_csv(&parallel, false),
        "serve CSV must not depend on the thread count"
    );
    assert_eq!(service::to_json(&serial, false), service::to_json(&parallel, false));
}

#[test]
fn zero_completions_scenario_summarizes_without_panicking() {
    // queue capacity 0 + reject admission: every job in every stream is
    // turned away, so metrics summarize zero completions — the path that
    // used to die in `stats::percentile` on an empty sojourn sample
    let mut g = grid();
    g.queue_cap = 0;
    g.admission = Admission::Reject;
    let rows = service::run_serve(&g, 2).unwrap();
    assert_eq!(rows.len(), 12);
    assert!(rows.iter().map(|r| r.submitted).sum::<usize>() > 0, "streams must still carry jobs");
    for r in &rows {
        assert_eq!(r.completed, 0);
        assert_eq!(r.rejected, r.submitted, "every submitted job is rejected at cap 0");
        assert_eq!(r.p99_sojourn, 0.0);
        assert_eq!(r.throughput_jps, 0.0);
    }
    // the bundle serializers must accept the degenerate rows byte-stably
    assert_eq!(
        service::to_csv(&rows, false),
        service::to_csv(&service::run_serve(&g, 1).unwrap(), false)
    );
}

#[test]
fn arrival_streams_are_deterministic_and_shared_across_policies() {
    // pure function of (label, seed)
    for spec in [ArrivalSpec::Poisson { rate: 6.0 }, ArrivalSpec::Bursty { lo: 2.0, hi: 20.0, dwell: 0.2 }] {
        assert_eq!(spec.generate(1.0, 0).unwrap(), spec.generate(1.0, 0).unwrap(), "{}", spec.label());
        assert_ne!(spec.generate(1.0, 0).unwrap(), spec.generate(1.0, 1).unwrap(), "{}", spec.label());
    }
    // within one grid, every policy on one platform faces the identical
    // stream: submitted counts agree row-for-row per (platform, arrivals)
    let results = service::run_serve(&grid(), 2).unwrap();
    for r in &results {
        let twin = results
            .iter()
            .find(|o| o.platform == r.platform && o.arrivals == r.arrivals && o.policy != r.policy)
            .expect("multi-policy grid");
        assert_eq!(r.submitted, twin.submitted, "{}/{}: policies saw different streams", r.platform, r.arrivals);
        assert_eq!(r.seed, twin.seed);
        assert_ne!(r.scenario_seed, twin.scenario_seed, "scheduler seeds still key on the policy");
    }
}

#[test]
fn scenario_rows_are_stable_under_grid_reordering() {
    let forward = service::run_serve(&grid(), 2).unwrap();
    let mut rev = grid();
    rev.platforms.reverse();
    rev.arrivals.reverse();
    rev.policies.reverse();
    let backward = service::run_serve(&rev, 2).unwrap();
    assert_eq!(forward.len(), backward.len());
    for f in &forward {
        let b = backward
            .iter()
            .find(|b| b.platform == f.platform && b.arrivals == f.arrivals && b.policy == f.policy)
            .unwrap_or_else(|| panic!("{}/{}/{} missing from reordered run", f.platform, f.arrivals, f.policy));
        assert_eq!(f, b, "scenario outcome must derive from coordinates, not grid position");
    }
}

#[test]
fn full_queue_rejects_loudly_and_conserves_jobs() {
    let mut g = grid();
    g.platforms.truncate(1);
    g.arrivals = vec![ArrivalSpec::Poisson { rate: 40.0 }];
    g.policies = vec!["pl/eft-p".into()];
    g.queue_cap = 1;
    g.admission = Admission::Reject;
    let results = service::run_serve(&g, 1).unwrap();
    assert_eq!(results.len(), 1);
    let r = &results[0];
    assert!(r.submitted > 2, "40 jobs/s over 1 s must submit plenty");
    assert!(r.rejected > 0, "cap 1 under that load must reject");
    assert_eq!(
        r.submitted,
        r.completed + r.rejected,
        "every submitted job is either completed or loudly rejected — none vanish"
    );
}

#[test]
fn deferred_backlog_drains_completely() {
    let mut g = grid();
    g.platforms.truncate(1);
    g.arrivals = vec![ArrivalSpec::Poisson { rate: 40.0 }];
    g.policies = vec!["pl/sjf-p".into()];
    g.queue_cap = 1;
    g.admission = Admission::Defer;
    let results = service::run_serve(&g, 1).unwrap();
    let r = &results[0];
    assert_eq!(r.rejected, 0, "defer never rejects");
    assert_eq!(r.completed, r.submitted, "the run drains the whole backlog");
    assert!(r.drain > g.duration, "cap 1 under overload must drain past the horizon");
    assert!(r.p99_sojourn >= r.p50_sojourn);
    assert!(r.fairness > 0.0 && r.fairness <= 1.0 + 1e-12);
}

#[test]
fn trace_replay_round_trips_through_the_grid() {
    let dir = std::env::temp_dir().join(format!("hesp_serve_trace_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.jsonl");
    std::fs::write(
        &path,
        "{\"t_arrival\": 0.0, \"workload\": \"cholesky:512\", \"tile\": 128, \"deadline\": 1e9, \"priority\": 2}\n\
         {\"t_arrival\": 0.01, \"workload\": \"stencil:4x2\", \"tile\": 64}\n",
    )
    .unwrap();
    let mut g = grid();
    g.platforms.truncate(1);
    g.arrivals = vec![ArrivalSpec::Trace { path: path.to_string_lossy().into_owned() }];
    g.policies = vec!["pl/edf-p".into()];
    let results = service::run_serve(&g, 1).unwrap();
    let r = &results[0];
    assert_eq!(r.submitted, 2);
    assert_eq!(r.completed, 2);
    assert_eq!(r.deadline_miss_pct, 0.0, "1e9 s is generous");
    std::fs::remove_file(&path).ok();
    std::fs::remove_dir(&dir).ok();
}
