//! Golden-trace pin for the portfolio solver: one bujaruelo Cholesky cell
//! whose canonical solver output (`solver::result_json` — costs as exact
//! f64 bit patterns, full action log) must stay **byte-stable across
//! refactors**. Any change to candidate scoring, sampling order, seeding,
//! the event core or the acceptance rule shows up here as a diff.
//!
//! ## Updating the golden (intended-change workflow)
//!
//! 1. Re-materialize: `UPDATE_GOLDEN=1 cargo test --test golden_solve`
//!    (or delete `bench_out/golden_solve.json` and run the test once —
//!    a missing golden is materialized, not failed, so a fresh checkout
//!    bootstraps itself).
//! 2. Inspect the diff of `bench_out/golden_solve.json` — every changed
//!    `*_bits` field is a changed trajectory; make sure the change is the
//!    one you intended.
//! 3. Commit the new file together with the code change that moved it.
//!
//! Until the golden is committed, CI still enforces byte-stability
//! *within* every job: the debug `cargo test` run materializes the file
//! and a later `cargo test --release --test golden_solve` step must
//! reproduce it byte-for-byte (debug and release must take the same
//! trajectory). The in-test thread-count comparison below runs
//! unconditionally either way.

use std::path::Path;

use hesp::config::Platform;
use hesp::coordinator::partitioners::PartitionerSet;
use hesp::coordinator::policies::{Ordering, ProcSelect, SchedConfig};
use hesp::coordinator::policy::PolicyRegistry;
use hesp::coordinator::engine::SimConfig;
use hesp::coordinator::solver::{result_json, solve_portfolio, PortfolioConfig, SolverConfig};
use hesp::coordinator::sweep::{cell_seed, workload_seed, Workload};

#[test]
fn bujaruelo_cholesky_solve_output_is_byte_stable() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let p = Platform::from_file(manifest.join("configs/bujaruelo.toml")).expect("bujaruelo config ships with the repo");

    // one small solve cell, addressed exactly like a sweep cell so the
    // golden pins the seeding chain too
    let workload = Workload::Cholesky { n: 4096 };
    let (tile, policy, mode, seed) = (1024u32, "pl/eft-p", "solve:12:256", 0u64);
    let wl = workload.label();
    let cseed = cell_seed(&p.machine.name, &wl, policy, tile, mode, seed);
    let dag = workload.build(tile, workload_seed(&wl, tile, seed)).expect("1024 divides 4096");

    let sim = SimConfig::new(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish))
        .with_elem_bytes(p.elem_bytes)
        .with_seed(cseed);
    let mut base = SolverConfig::all_soft(sim, 12, 256);
    base.seed = cseed;
    let mut pcfg = PortfolioConfig::new(base);
    pcfg.lanes = 2;
    pcfg.batch = 2;
    pcfg.threads = 2;

    let parts = PartitionerSet::standard();
    let reg = PolicyRegistry::standard();
    let res = solve_portfolio(&dag, &p.machine, &p.db, &parts, &reg, policy, &pcfg);
    let js = result_json(&res);

    // determinism before byte-stability: the same cell at another thread
    // count must already serialize identically
    let mut serial = pcfg.clone();
    serial.threads = 1;
    let res1 = solve_portfolio(&dag, &p.machine, &p.db, &parts, &reg, policy, &serial);
    assert_eq!(js, result_json(&res1), "thread count changed the canonical bytes");

    let golden_path = manifest.join("bench_out/golden_solve.json");
    if std::env::var_os("UPDATE_GOLDEN").is_some() || !golden_path.exists() {
        std::fs::create_dir_all(golden_path.parent().unwrap()).expect("create bench_out/");
        std::fs::write(&golden_path, &js).expect("write golden");
        eprintln!(
            "golden_solve.json (re)materialized at {} — commit it to pin this trajectory",
            golden_path.display()
        );
        return;
    }
    let golden = std::fs::read_to_string(&golden_path).expect("read committed golden");
    assert_eq!(
        golden, js,
        "solver output drifted from the committed golden trajectory; if this change is \
         intended, re-materialize per the instructions in this test's header"
    );
}
