//! Delta-vs-full equivalence property suite (ISSUE 8 tentpole pin).
//!
//! Incremental re-simulation (`--delta`) is an *execution strategy*: the
//! portfolio solver may restore a checkpoint of the incumbent run and
//! replay only the unverifiable remainder of a candidate, but the
//! resulting trajectory — every candidate cost, every accepted action,
//! every lane winner — must be byte-identical to full re-simulation.
//! This suite drives that claim over randomized workloads, every registry
//! policy (including the replay-ineligible stateful-select ones, which
//! must degrade to counted full runs), both reference machine shapes, and
//! several thread counts, comparing the canonical `result_json` bytes.
//! It also pins that the replay counters are themselves deterministic and
//! that the scratch-schedule pool leaks no state between solves.

use hesp::coordinator::delta::DeltaMode;
use hesp::coordinator::engine::SimConfig;
use hesp::coordinator::partitioners::{cholesky, PartitionerSet};
use hesp::coordinator::perfmodel::{PerfCurve, PerfDb};
use hesp::coordinator::platform::{Machine, MachineBuilder};
use hesp::coordinator::policies::{Ordering, ProcSelect, SchedConfig};
use hesp::coordinator::policy::PolicyRegistry;
use hesp::coordinator::solver::{result_json, solve_portfolio, PortfolioConfig, SolverConfig};
use hesp::coordinator::taskdag::TaskDag;
use hesp::coordinator::workloads;

/// 4 equal CPUs in one space: the contention-free baseline.
fn flat_machine() -> (Machine, PerfDb) {
    let mut b = MachineBuilder::new("flat");
    let h = b.space("host", u64::MAX);
    b.main(h);
    let t = b.proc_type("cpu", 1.0, 0.1);
    b.processors(4, "c", t, h);
    let mut db = PerfDb::new();
    db.set_fallback(0, PerfCurve::Saturating { peak: 20.0, half: 64.0, exponent: 2.0 });
    (b.build(), db)
}

/// CPU + 2 GPUs in separate spaces behind narrow links: transfers, link
/// contention and arrival gates shift candidate timings, so verified-
/// prefix scans see real divergences, not just structural ones.
fn het_machine() -> (Machine, PerfDb) {
    let mut b = MachineBuilder::new("het");
    let h = b.space("host", u64::MAX);
    let g0 = b.space("g0", u64::MAX);
    let g1 = b.space("g1", u64::MAX);
    b.main(h);
    b.connect(h, g0, 1e-6, 5e7);
    b.connect(h, g1, 1e-6, 5e7);
    let cpu = b.proc_type("cpu", 1.0, 0.1);
    let gpu = b.proc_type("gpu", 2.0, 0.2);
    b.processors(2, "c", cpu, h);
    b.processors(1, "a", gpu, g0);
    b.processors(1, "b", gpu, g1);
    let mut db = PerfDb::new();
    db.set_fallback(0, PerfCurve::Const { gflops: 2.0 });
    db.set_fallback(1, PerfCurve::Saturating { peak: 30.0, half: 48.0, exponent: 2.0 });
    (b.build(), db)
}

/// Workloads whose solver moves produce adversarial affected cones: the
/// pre-tiled Cholesky's moves hit interior clusters (mid-trace cones),
/// the untiled root's first move changes *everything* (empty verified
/// prefix — the forced full-fallback path), and the random layered DAGs
/// randomize which part of the decision log survives each move.
fn workload_set() -> Vec<(String, TaskDag)> {
    let mut out = Vec::new();
    let mut chol = cholesky::root(256);
    cholesky::partition_uniform(&mut chol, 64);
    out.push(("cholesky:256/64".to_string(), chol));
    out.push(("cholesky:512-root".to_string(), cholesky::root(512)));
    out.push(("stencil:6x4".to_string(), workloads::stencil(6, 4, 32)));
    for seed in 0..3u64 {
        out.push((format!("random:48#{seed}"), workloads::random_layered(48, 32, seed)));
    }
    out
}

fn pcfg(seed: u64, delta: DeltaMode) -> PortfolioConfig {
    let sim = SimConfig::new(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish))
        .with_seed(seed);
    let mut base = SolverConfig::all_soft(sim, 6, 16);
    base.seed = seed;
    let mut p = PortfolioConfig::new(base);
    p.lanes = 2;
    p.batch = 2;
    p.threads = 2;
    p.delta = delta;
    p
}

#[test]
fn delta_on_is_byte_identical_to_full_for_every_policy_workload_machine() {
    let reg = PolicyRegistry::standard();
    let parts = PartitionerSet::standard();
    let mut pairs = 0usize;
    let mut engaged = 0usize;
    for (m, db) in &[flat_machine(), het_machine()] {
        for (label, dag) in workload_set() {
            for name in reg.names() {
                let seed = 0xde17a ^ pairs as u64;
                let off = solve_portfolio(dag, m, db, &parts, &reg, name, &pcfg(seed, DeltaMode::Off));
                let on = solve_portfolio(dag, m, db, &parts, &reg, name, &pcfg(seed, DeltaMode::On));
                assert_eq!(
                    result_json(&off),
                    result_json(&on),
                    "{}/{label}/{name}: delta changed the canonical solve bytes",
                    m.name
                );
                assert_eq!(off.replay_stats(), Default::default(), "{name}: off mode counted something");
                let st = on.replay_stats();
                assert!(
                    st.events_replayed <= st.events_total,
                    "{}/{label}/{name}: {st:?}",
                    m.name
                );
                if st.events_total > 0 {
                    engaged += 1;
                }
                pairs += 1;
            }
        }
    }
    assert!(pairs >= 2 * 6 * 10, "coverage shrank: {pairs} delta/full pairs compared");
    // the cone machinery must actually run for the replay-eligible
    // majority of the registry — not just silently fall back everywhere
    assert!(
        engaged * 2 > pairs,
        "verified-prefix scans engaged on only {engaged}/{pairs} solves"
    );
}

#[test]
fn replay_counters_are_thread_count_invariant() {
    // the counters live outside result_json, but they still aggregate
    // deterministically: same trajectory, same scans, same sums — no
    // matter how the lanes and batch evaluations spread over workers
    let reg = PolicyRegistry::standard();
    let parts = PartitionerSet::standard();
    let (m, db) = het_machine();
    let dag = {
        let mut d = cholesky::root(256);
        cholesky::partition_uniform(&mut d, 64);
        d
    };
    let mut one = pcfg(11, DeltaMode::On);
    one.threads = 1;
    let mut four = pcfg(11, DeltaMode::On);
    four.threads = 4;
    let r1 = solve_portfolio(&dag, &m, &db, &parts, &reg, "pl/eft-p", &one);
    let r4 = solve_portfolio(&dag, &m, &db, &parts, &reg, "pl/eft-p", &four);
    assert_eq!(result_json(&r1), result_json(&r4));
    assert_eq!(r1.replay_stats(), r4.replay_stats());
    assert!(r1.replay_stats().events_total > 0, "{:?}", r1.replay_stats());
}

#[test]
fn scratch_pool_reuse_leaks_nothing_between_solves() {
    // interleave solves over different DAGs/machines so recycled scratch
    // schedules and checkpoints from one solve are reused by the next; a
    // stale record surviving the reset would shift some candidate's cost
    // and break the byte-equality of the repeat run
    let reg = PolicyRegistry::standard();
    let parts = PartitionerSet::standard();
    let (fm, fdb) = flat_machine();
    let (hm, hdb) = het_machine();
    let dag_a = cholesky::root(512);
    let dag_b = workloads::random_layered(48, 32, 1);

    let first = solve_portfolio(&dag_a, &fm, &fdb, &parts, &reg, "pl/eft-p", &pcfg(3, DeltaMode::On));
    // pollute the pools with unrelated work (different machine, shape,
    // policy — including a replay-ineligible stateful-select one)
    let _ = solve_portfolio(&dag_b, &hm, &hdb, &parts, &reg, "fcfs/r-p", &pcfg(4, DeltaMode::On));
    let _ = solve_portfolio(&dag_b, &fm, &fdb, &parts, &reg, "pl/lookahead", &pcfg(5, DeltaMode::Auto));
    let again = solve_portfolio(&dag_a, &fm, &fdb, &parts, &reg, "pl/eft-p", &pcfg(3, DeltaMode::On));

    assert_eq!(result_json(&first), result_json(&again), "scratch reuse changed a repeat solve");
    assert_eq!(first.replay_stats(), again.replay_stats());
}
