//! XLA/PJRT runtime: loads the AOT-compiled JAX/Pallas tile kernels
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts`) and executes
//! them on the CPU PJRT client. Python never runs here — the HLO text is
//! the only interchange (see DESIGN.md and python/compile/aot.py for why
//! text, not serialized protos).

pub mod artifacts;
pub mod executor;

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::task::TaskKind;
use artifacts::ArtifactEntry;

/// Element dtype of an artifact.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F64,
}

impl DType {
    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F64 => "f64",
        }
    }

    pub fn from_name(s: &str) -> Option<DType> {
        match s {
            "f32" => Some(DType::F32),
            "f64" => Some(DType::F64),
            _ => None,
        }
    }

    pub fn bytes(&self) -> u64 {
        match self {
            DType::F32 => 4,
            DType::F64 => 8,
        }
    }
}

/// A compiled tile kernel.
pub struct Kernel {
    pub meta: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
}

impl Kernel {
    /// Execute with `args` tile literals; returns the single output tile
    /// (artifacts are lowered with `return_tuple=True`, so the raw result
    /// is a 1-tuple).
    pub fn execute(&self, args: &[xla::Literal]) -> Result<xla::Literal> {
        anyhow::ensure!(args.len() == self.meta.num_args, "{} expects {} args, got {}", self.meta.name, self.meta.num_args, args.len());
        let bufs = self.exe.execute::<xla::Literal>(args).map_err(|e| anyhow!("execute {}: {e}", self.meta.name))?;
        let lit = bufs[0][0].to_literal_sync().map_err(|e| anyhow!("sync {}: {e}", self.meta.name))?;
        lit.to_tuple1().map_err(|e| anyhow!("untuple {}: {e}", self.meta.name))
    }
}

/// The loaded runtime: PJRT CPU client + compiled kernel registry keyed by
/// (task kind, dtype, tile edge).
pub struct Runtime {
    pub client: xla::PjRtClient,
    kernels: HashMap<(TaskKind, DType, u32), Kernel>,
}

impl Runtime {
    /// Load and compile every artifact in `dir` matching `pred`.
    /// Compiling all 32 shipped artifacts takes a while; experiments load
    /// only the (dtype, tiles) they use.
    pub fn load_filtered<P: AsRef<Path>, F: Fn(&ArtifactEntry) -> bool>(dir: P, pred: F) -> Result<Runtime> {
        let dir = dir.as_ref();
        let entries = artifacts::read_manifest(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e}"))?;
        let mut kernels = HashMap::new();
        for meta in entries.into_iter().filter(|e| pred(e)) {
            let path = dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .map_err(|e| anyhow!("parse {}: {e}", meta.file))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(|e| anyhow!("compile {}: {e}", meta.file))?;
            let kind = TaskKind::from_name(&meta.task).ok_or_else(|| anyhow!("unknown task '{}' in manifest", meta.task))?;
            let dtype = DType::from_name(&meta.dtype).ok_or_else(|| anyhow!("unknown dtype '{}'", meta.dtype))?;
            kernels.insert((kind, dtype, meta.tile), Kernel { meta, exe });
        }
        Ok(Runtime { client, kernels })
    }

    /// Load every artifact in `dir`.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Runtime> {
        Runtime::load_filtered(dir, |_| true)
    }

    pub fn kernel(&self, kind: TaskKind, dtype: DType, tile: u32) -> Result<&Kernel> {
        self.kernels
            .get(&(kind, dtype, tile))
            .with_context(|| format!("no kernel for {}_{}_{}", kind.name(), dtype.name(), tile))
    }

    pub fn available(&self) -> Vec<(TaskKind, DType, u32)> {
        let mut v: Vec<_> = self.kernels.keys().copied().collect();
        v.sort_by_key(|(k, d, t)| (k.name(), d.name(), *t));
        v
    }

    /// Tile edges available for `dtype` (all four Cholesky kernels present).
    pub fn tiles_for(&self, dtype: DType) -> Vec<u32> {
        let mut tiles: Vec<u32> = self
            .kernels
            .keys()
            .filter(|(k, d, _)| *d == dtype && *k == TaskKind::Potrf)
            .map(|(_, _, t)| *t)
            .filter(|&t| {
                [TaskKind::Trsm, TaskKind::Syrk, TaskKind::Gemm]
                    .iter()
                    .all(|&k| self.kernels.contains_key(&(k, dtype, t)))
            })
            .collect();
        tiles.sort();
        tiles
    }
}

/// Build a `b x b` f32 tile literal from row-major data.
pub fn tile_literal_f32(data: &[f32], b: u32) -> Result<xla::Literal> {
    anyhow::ensure!(data.len() == (b * b) as usize);
    xla::Literal::vec1(data)
        .reshape(&[b as i64, b as i64])
        .map_err(|e| anyhow!("reshape: {e}"))
}

/// Build a `b x b` f64 tile literal from row-major data.
pub fn tile_literal_f64(data: &[f64], b: u32) -> Result<xla::Literal> {
    anyhow::ensure!(data.len() == (b * b) as usize);
    xla::Literal::vec1(data)
        .reshape(&[b as i64, b as i64])
        .map_err(|e| anyhow!("reshape: {e}"))
}

/// Extract row-major f32 data from a tile literal.
pub fn tile_to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))
}
