//! Real execution of scheduled tile-task DAGs on the PJRT CPU client —
//! the validation substrate replacing the paper's OmpSs runs (§3.1).
//!
//! The executor replays the exact task stream the Cholesky partitioner
//! emits (so simulated and real runs cover the same DAG), timing every
//! task. From the timings it can also extract *measured* performance
//! models ([`measure_models`]) that feed the HESP-REPLICA-RD simulation.
//!
//! The CI container exposes a single CPU core, so execution is sequential
//! and validation compares serial makespans; the mechanism is identical
//! for multi-processor PJRT hosts.

use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::coordinator::perfmodel::{PerfCurve, PerfDb};
use crate::coordinator::task::TaskKind;
use crate::util::rng::Rng;

use super::{tile_literal_f32, tile_to_vec_f32, DType, Runtime};

/// Deterministic well-conditioned SPD matrix: `A = G G^T / n + 2 I`
/// (same construction as python/compile/model.py::random_spd).
pub fn random_spd(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let g: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
    let mut a = vec![0f32; n * n];
    // A = G G^T / n + 2I, computed in f64 for accuracy
    for i in 0..n {
        for j in 0..=i {
            let mut acc = 0.0f64;
            for k in 0..n {
                acc += g[i * n + k] * g[j * n + k];
            }
            let v = (acc / n as f64 + if i == j { 2.0 } else { 0.0 }) as f32;
            a[i * n + j] = v;
            a[j * n + i] = v;
        }
    }
    a
}

/// One timed task execution.
#[derive(Debug, Clone, Copy)]
pub struct TaskTiming {
    pub kind: TaskKind,
    pub tile: u32,
    pub seconds: f64,
}

/// Result of a real tiled-Cholesky execution.
#[derive(Debug, Clone)]
pub struct ExecutionResult {
    pub n: u32,
    pub b: u32,
    /// Wall-clock of the full factorization (sequential replay).
    pub total_s: f64,
    pub timings: Vec<TaskTiming>,
    /// `max |L L^T - A|` over the lower triangle — the correctness check.
    pub max_err: f64,
    /// Useful flops (n^3/3 + symmetric-update convention, summed per task).
    pub flops: f64,
}

impl ExecutionResult {
    pub fn gflops(&self) -> f64 {
        self.flops / self.total_s / 1e9
    }
}

/// Execute a full tiled Cholesky factorization of a synthetic SPD matrix
/// for real: n x n matrix, b x b tiles, f32 kernels from `rt`.
pub fn run_cholesky(rt: &Runtime, n: u32, b: u32, seed: u64) -> Result<ExecutionResult> {
    anyhow::ensure!(n % b == 0 && n / b >= 1, "b={b} must divide n={n}");
    let s = (n / b) as usize;
    let bb = b as usize;
    let a = random_spd(n as usize, seed);

    // slice into row-major tiles
    let tile_of = |i: usize, j: usize| -> Vec<f32> {
        let mut t = vec![0f32; bb * bb];
        for r in 0..bb {
            let src = (i * bb + r) * n as usize + j * bb;
            t[r * bb..(r + 1) * bb].copy_from_slice(&a[src..src + bb]);
        }
        t
    };
    let mut tiles: Vec<Option<xla::Literal>> = Vec::with_capacity(s * s);
    for i in 0..s {
        for j in 0..s {
            tiles.push(if j <= i { Some(tile_literal_f32(&tile_of(i, j), b)?) } else { None });
        }
    }
    let idx = |i: usize, j: usize| i * s + j;

    let potrf = rt.kernel(TaskKind::Potrf, DType::F32, b)?;
    let trsm = rt.kernel(TaskKind::Trsm, DType::F32, b)?;
    let syrk = rt.kernel(TaskKind::Syrk, DType::F32, b)?;
    let gemm = rt.kernel(TaskKind::Gemm, DType::F32, b)?;

    // warm each executable once: the first PJRT dispatch pays a one-time
    // runtime-initialization cost (~tens of ms) that is not task work
    {
        let w = tiles[idx(0, 0)].as_ref().unwrap();
        let _ = potrf.execute(std::slice::from_ref(w))?;
        let _ = trsm.execute(&[w.clone(), w.clone()])?;
        let _ = syrk.execute(&[w.clone(), w.clone()])?;
        let _ = gemm.execute(&[w.clone(), w.clone(), w.clone()])?;
    }

    let mut timings = Vec::new();
    let t_total = Instant::now();
    let mut flops = 0.0f64;
    // the same program order the Cholesky partitioner emits
    for k in 0..s {
        let mut timed = |kern: &super::Kernel, kind: TaskKind, args: &[xla::Literal]| -> Result<xla::Literal> {
            let t0 = Instant::now();
            let out = kern.execute(args)?;
            timings.push(TaskTiming { kind, tile: b, seconds: t0.elapsed().as_secs_f64() });
            flops += kind.flops(b as f64);
            Ok(out)
        };
        let lkk = timed(potrf, TaskKind::Potrf, std::slice::from_ref(tiles[idx(k, k)].as_ref().unwrap()))?;
        tiles[idx(k, k)] = Some(lkk);
        for i in k + 1..s {
            // TRSM args (l, b)
            let out = timed(
                trsm,
                TaskKind::Trsm,
                &[tiles[idx(k, k)].as_ref().unwrap().clone(), tiles[idx(i, k)].take().unwrap()],
            )?;
            tiles[idx(i, k)] = Some(out);
        }
        for i in k + 1..s {
            // SYRK args (c, a)
            let out = timed(
                syrk,
                TaskKind::Syrk,
                &[tiles[idx(i, i)].take().unwrap(), tiles[idx(i, k)].as_ref().unwrap().clone()],
            )?;
            tiles[idx(i, i)] = Some(out);
            for j in k + 1..i {
                // GEMM args (c, a, b)
                let out = timed(
                    gemm,
                    TaskKind::Gemm,
                    &[
                        tiles[idx(i, j)].take().unwrap(),
                        tiles[idx(i, k)].as_ref().unwrap().clone(),
                        tiles[idx(j, k)].as_ref().unwrap().clone(),
                    ],
                )?;
                tiles[idx(i, j)] = Some(out);
            }
        }
    }
    let total_s = t_total.elapsed().as_secs_f64();

    // reconstruct L, verify L L^T == A on the lower triangle
    let nn = n as usize;
    let mut l = vec![0f32; nn * nn];
    for i in 0..s {
        for j in 0..=i {
            let data = tile_to_vec_f32(tiles[idx(i, j)].as_ref().unwrap())?;
            for r in 0..bb {
                for c in 0..bb {
                    let (gr, gc) = (i * bb + r, j * bb + c);
                    if gc <= gr {
                        l[gr * nn + gc] = data[r * bb + c];
                    }
                }
            }
        }
    }
    let mut max_err = 0f64;
    for i in 0..nn {
        for j in 0..=i {
            let mut acc = 0f64;
            for k in 0..=j.min(i) {
                acc += l[i * nn + k] as f64 * l[j * nn + k] as f64;
            }
            max_err = max_err.max((acc - a[i * nn + j] as f64).abs());
        }
    }

    Ok(ExecutionResult { n, b, total_s, timings, max_err, flops })
}

/// Measured GFLOPS per (kind, tile): runs each available f32 kernel `reps`
/// times on random tiles and takes the median — HeSP's "performance models
/// extracted a priori" for the local platform.
pub fn measure_models(rt: &Runtime, tiles: &[u32], reps: usize, seed: u64) -> Result<Vec<(TaskKind, u32, f64)>> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for &b in tiles {
        let bb = (b * b) as usize;
        let mk = |rng: &mut Rng| -> Result<xla::Literal> {
            let v: Vec<f32> = (0..bb).map(|_| rng.normal() as f32).collect();
            tile_literal_f32(&v, b)
        };
        // well-conditioned lower-triangular / SPD inputs where needed
        let spd = {
            let v = random_spd(b as usize, seed ^ b as u64);
            tile_literal_f32(&v, b)?
        };
        let lower = {
            let mut v: Vec<f32> = vec![0.0; bb];
            for i in 0..b as usize {
                for j in 0..=i {
                    v[i * b as usize + j] = if i == j { 4.0 } else { rng.normal() as f32 * 0.1 };
                }
            }
            tile_literal_f32(&v, b)?
        };
        for kind in [TaskKind::Potrf, TaskKind::Trsm, TaskKind::Syrk, TaskKind::Gemm] {
            let Ok(kern) = rt.kernel(kind, DType::F32, b) else { continue };
            let mut samples = Vec::with_capacity(reps);
            // one discarded warmup execution per kernel (first PJRT
            // dispatch pays one-time initialization)
            let _ = kern.execute(&match kind {
                TaskKind::Potrf => vec![spd.clone()],
                TaskKind::Trsm => vec![lower.clone(), mk(&mut rng)?],
                TaskKind::Syrk => vec![mk(&mut rng)?, mk(&mut rng)?],
                TaskKind::Gemm => vec![mk(&mut rng)?, mk(&mut rng)?, mk(&mut rng)?],
                _ => unreachable!(),
            })?;
            for _ in 0..reps.max(1) {
                let args: Vec<xla::Literal> = match kind {
                    TaskKind::Potrf => vec![spd.clone()],
                    TaskKind::Trsm => vec![lower.clone(), mk(&mut rng)?],
                    TaskKind::Syrk => vec![mk(&mut rng)?, mk(&mut rng)?],
                    TaskKind::Gemm => vec![mk(&mut rng)?, mk(&mut rng)?, mk(&mut rng)?],
                    _ => unreachable!(),
                };
                let t0 = Instant::now();
                let _ = kern.execute(&args)?;
                samples.push(t0.elapsed().as_secs_f64());
            }
            samples.sort_by(|a, b| a.total_cmp(b));
            let median = samples[samples.len() / 2];
            let gflops = kind.flops(b as f64) / median / 1e9;
            out.push((kind, b, gflops));
        }
    }
    Ok(out)
}

/// Build a single-proc-type [`PerfDb`] (Table curves) from measurements —
/// the HESP-REPLICA-RD performance model.
pub fn measured_perfdb(measures: &[(TaskKind, u32, f64)]) -> PerfDb {
    let mut db = PerfDb::new();
    let mut by_kind: std::collections::HashMap<TaskKind, Vec<(f64, f64)>> = std::collections::HashMap::new();
    for &(k, b, g) in measures {
        by_kind.entry(k).or_default().push((b as f64, g));
    }
    let mut any: Vec<(f64, f64)> = Vec::new();
    for (k, mut pts) in by_kind {
        pts.sort_by(|a, b| a.0.total_cmp(&b.0));
        any = pts.clone();
        db.set(0, k, PerfCurve::Table { points: pts });
    }
    if !any.is_empty() {
        db.set_fallback(0, PerfCurve::Table { points: any });
    }
    db
}

/// Render measurements as `[perf.pjrt.*]` TOML tables (to refresh
/// configs/local.toml after calibration).
pub fn measurements_to_toml(measures: &[(TaskKind, u32, f64)]) -> String {
    use std::fmt::Write;
    let mut by_kind: std::collections::BTreeMap<&str, Vec<(u32, f64)>> = std::collections::BTreeMap::new();
    for &(k, b, g) in measures {
        by_kind.entry(k.name()).or_default().push((b, g));
    }
    let mut out = String::new();
    for (name, mut pts) in by_kind {
        pts.sort_by(|a, b| a.0.cmp(&b.0));
        let _ = writeln!(out, "[perf.pjrt.{name}]");
        let pstr: Vec<String> = pts.iter().map(|(b, g)| format!("[{b}, {g:.4}]")).collect();
        let _ = writeln!(out, "points = [{}]\n", pstr.join(", "));
    }
    out
}

/// Locate the artifacts directory (env override, then repo default).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(d) = std::env::var("HESP_ARTIFACTS") {
        return d.into();
    }
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// True if AOT artifacts are present (tests skip politely otherwise).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

/// Convenience loader for validation runs: f32 kernels at the given tiles.
pub fn load_f32_runtime(tiles: &[u32]) -> Result<Runtime> {
    let dir = artifacts_dir();
    anyhow::ensure!(dir.join("manifest.json").exists(), "no artifacts at {} — run `make artifacts`", dir.display());
    Runtime::load_filtered(&dir, |e| e.dtype == "f32" && tiles.contains(&e.tile))
        .map_err(|e| anyhow!("loading artifacts: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_spd_is_symmetric_diag_dominantish() {
        let a = random_spd(32, 7);
        for i in 0..32 {
            for j in 0..32 {
                assert_eq!(a[i * 32 + j], a[j * 32 + i]);
            }
            assert!(a[i * 32 + i] > 1.0, "diagonal lifted");
        }
    }

    #[test]
    fn measured_perfdb_builds_tables() {
        let ms = vec![
            (TaskKind::Gemm, 32, 1.0),
            (TaskKind::Gemm, 64, 2.0),
            (TaskKind::Potrf, 32, 0.5),
        ];
        let db = measured_perfdb(&ms);
        assert_eq!(db.curve(0, TaskKind::Gemm).gflops(64.0), 2.0);
        assert_eq!(db.curve(0, TaskKind::Potrf).gflops(32.0), 0.5);
        // fallback exists for unmeasured kinds
        let _ = db.curve(0, TaskKind::Trsm);
    }

    #[test]
    fn toml_rendering() {
        let ms = vec![(TaskKind::Gemm, 64, 2.0), (TaskKind::Gemm, 32, 1.0)];
        let t = measurements_to_toml(&ms);
        assert!(t.contains("[perf.pjrt.gemm]"));
        assert!(t.contains("[32, 1.0000], [64, 2.0000]"));
    }

    // PJRT-dependent tests live in rust/tests/runtime_integration.rs and
    // skip when artifacts are absent.
}
