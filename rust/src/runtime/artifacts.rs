//! Artifact manifest (`artifacts/manifest.json`) parsing.
//!
//! `python/compile/aot.py` writes one HLO-text file per (task, dtype, tile)
//! plus this manifest describing them; the Rust runtime never inspects the
//! HLO itself beyond handing it to the XLA parser.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::{parse, Json};

/// One manifest entry.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    /// Task kind name ("potrf" | "trsm" | "syrk" | "gemm").
    pub task: String,
    /// "f32" | "f64".
    pub dtype: String,
    /// Tile edge.
    pub tile: u32,
    pub num_args: usize,
    /// Flop count of one kernel invocation (matches TaskKind::flops).
    pub flops: f64,
}

/// Read and validate `<dir>/manifest.json`.
pub fn read_manifest<P: AsRef<Path>>(dir: P) -> Result<Vec<ArtifactEntry>> {
    let path = dir.as_ref().join("manifest.json");
    let text = std::fs::read_to_string(&path).with_context(|| format!("reading {}", path.display()))?;
    parse_manifest(&text)
}

/// Parse manifest JSON text.
pub fn parse_manifest(text: &str) -> Result<Vec<ArtifactEntry>> {
    let doc = parse(text).map_err(|e| anyhow!("manifest.json: {e}"))?;
    let fmt = doc.get("format").and_then(Json::as_str).unwrap_or("");
    anyhow::ensure!(fmt == "hlo-text", "unsupported artifact format '{fmt}'");
    let entries = doc.get("entries").and_then(Json::as_arr).ok_or_else(|| anyhow!("manifest has no entries"))?;
    let mut out = Vec::with_capacity(entries.len());
    for e in entries {
        let get_str = |k: &str| e.get(k).and_then(Json::as_str).map(str::to_string).ok_or_else(|| anyhow!("entry missing '{k}'"));
        out.push(ArtifactEntry {
            name: get_str("name")?,
            file: get_str("file")?,
            task: get_str("task")?,
            dtype: get_str("dtype")?,
            tile: e.get("tile").and_then(Json::as_usize).ok_or_else(|| anyhow!("entry missing 'tile'"))? as u32,
            num_args: e.get("num_args").and_then(Json::as_usize).ok_or_else(|| anyhow!("entry missing 'num_args'"))?,
            flops: e.get("flops").and_then(Json::as_f64).unwrap_or(0.0),
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text",
      "entries": [
        {"name": "gemm_f32_64", "file": "gemm_f32_64.hlo.txt", "task": "gemm",
         "dtype": "f32", "tile": 64, "num_args": 3, "flops": 524288.0},
        {"name": "potrf_f64_32", "file": "potrf_f64_32.hlo.txt", "task": "potrf",
         "dtype": "f64", "tile": 32, "num_args": 1, "flops": 10922.67}
      ]
    }"#;

    #[test]
    fn parses_entries() {
        let es = parse_manifest(SAMPLE).unwrap();
        assert_eq!(es.len(), 2);
        assert_eq!(es[0].task, "gemm");
        assert_eq!(es[0].tile, 64);
        assert_eq!(es[0].num_args, 3);
        assert_eq!(es[1].dtype, "f64");
    }

    #[test]
    fn rejects_wrong_format() {
        assert!(parse_manifest(r#"{"format":"proto","entries":[]}"#).is_err());
        assert!(parse_manifest("{}").is_err());
        assert!(parse_manifest("not json").is_err());
    }

    #[test]
    fn rejects_incomplete_entry() {
        let bad = r#"{"format":"hlo-text","entries":[{"name":"x"}]}"#;
        assert!(parse_manifest(bad).is_err());
    }
}
