//! The *constructive* (online) scheduler-partitioner — the paper's §4
//! follow-up to the static iterative solver: "a constructive
//! implementation, in which local information is applied on a per-task
//! basis ... can be applied directly on actual task schedulers".
//!
//! Instead of iterating whole schedule/partition rounds, partitioning
//! decisions are taken **at task arrival to the scheduling queue**: when
//! the event clock reaches a ready task, a local score (projected finish
//! time unsplit vs. split across currently-idle processors at a finer
//! grain) decides whether to dispatch it as-is or replace it, in place,
//! by its blocked sub-task cluster.
//!
//! The simulation itself runs on the engine's shared
//! [`EventCore`](super::engine) — the same typed event queue, global
//! clock, interval timelines, transfer booking and `TaskEnd`-time write
//! effects as the offline engine, rather than a duplicated commit loop.
//! Only the graph bookkeeping differs: tasks are keyed by id (the DAG
//! grows as splits are taken), and a split cluster holds a completion
//! counter that releases the parent's successors once every child is
//! done.
//!
//! Key simplification that keeps the online DAG maintenance exact: a task
//! is only split when it is *ready* (all predecessors finished), so its
//! children can have no unfinished external predecessors — only
//! cluster-internal edges (derived from the children's region accesses)
//! plus the completion counter.

use super::engine::{pick_best, Assignment, EventCore, EventKind, Schedule, SimConfig};
use super::ordering::critical_times;
use super::partitioners::{snap_sub_edge, PartitionerSet};
use super::perfmodel::PerfDb;
use super::platform::Machine;
use super::policies::SchedConfig;
use super::policy::{self, SchedPolicy};
use super::task::{Task, TaskSpec};
use super::taskdag::TaskDag;
use crate::util::fxhash::FxHashMap;

/// Knobs of the online partitioner.
#[derive(Debug, Clone, Copy)]
pub struct OnlineConfig {
    pub sim: SimConfig,
    /// Never split below this tile edge.
    pub min_edge: u32,
    /// Required relative gain (est_split < factor * est_unsplit) before a
    /// split is taken; 1.0 = split on any predicted win.
    pub gain_factor: f64,
    /// Cap on recursive split depth per task.
    pub max_depth: u32,
}

impl OnlineConfig {
    pub fn new(sim: SimConfig, min_edge: u32) -> OnlineConfig {
        OnlineConfig { sim, min_edge, gain_factor: 0.6, max_depth: 4 }
    }
}

/// Result: the schedule plus the final (dynamically partitioned) DAG and
/// how many online splits were taken.
pub struct OnlineResult {
    pub schedule: Schedule,
    pub dag: TaskDag,
    pub splits: usize,
}

/// Run the constructive scheduler-partitioner over (a clone of) `dag0`,
/// under the built-in policy named by `cfg.sim`'s shim fields.
pub fn schedule_online(
    dag0: &TaskDag,
    machine: &Machine,
    db: &PerfDb,
    parts: &PartitionerSet,
    cfg: OnlineConfig,
) -> OnlineResult {
    let mut p = policy::policy_for(SchedConfig::new(cfg.sim.ordering, cfg.sim.select));
    schedule_online_with(dag0, machine, db, parts, cfg, p.as_mut())
}

/// Graph bookkeeping when `id` finishes at `end`: bubble completion up
/// the cluster, decrement successor indegrees, record releases, and
/// collect tasks that became ready (the caller keys + dispatches them, so
/// ordering stays a policy decision).
#[allow(clippy::too_many_arguments)]
fn complete(
    id: usize,
    end: f64,
    succs: &FxHashMap<usize, Vec<usize>>,
    indeg: &mut FxHashMap<usize, usize>,
    release: &mut FxHashMap<usize, f64>,
    cluster_left: &mut FxHashMap<usize, usize>,
    cluster_parent: &FxHashMap<usize, usize>,
    newly_ready: &mut Vec<usize>,
) {
    if let Some(&parent) = cluster_parent.get(&id) {
        let left = cluster_left.get_mut(&parent).expect("cluster counter");
        *left -= 1;
        if *left == 0 {
            complete(parent, end, succs, indeg, release, cluster_left, cluster_parent, newly_ready);
        }
    }
    if let Some(ss) = succs.get(&id) {
        for &s in ss {
            let d = indeg.get_mut(&s).expect("succ indeg");
            *d -= 1;
            let r = release.entry(s).or_insert(0.0);
            *r = r.max(end);
            if *d == 0 {
                newly_ready.push(s);
            }
        }
    }
}

/// [`schedule_online`] under an arbitrary scheduling policy: ready-queue
/// ordering and per-task processor selection both dispatch through
/// `policy`, exactly as in the offline engine (including decision-time
/// key recomputation).
pub fn schedule_online_with(
    dag0: &TaskDag,
    machine: &Machine,
    db: &PerfDb,
    parts: &PartitionerSet,
    cfg: OnlineConfig,
    policy: &mut dyn SchedPolicy,
) -> OnlineResult {
    let mut dag = dag0.clone();
    let flat = dag.flat_dag();

    // --- dynamic DAG state, indexed by task id (not frontier position) ---
    let prio0 = match policy.rank_tasks(&dag, &flat, machine, db, cfg.sim.elem_bytes) {
        Some(r) => {
            debug_assert_eq!(r.len(), flat.len(), "rank_tasks length != frontier size");
            r
        }
        None if policy.wants_critical_times() => critical_times(&dag, &flat, machine, db),
        None => vec![0.0; flat.len()],
    };
    // per-task: remaining predecessor count, successors (task ids),
    // release time, priority, parent cluster (for completion counting)
    let mut indeg: FxHashMap<usize, usize> = FxHashMap::default();
    let mut succs: FxHashMap<usize, Vec<usize>> = FxHashMap::default();
    let mut release: FxHashMap<usize, f64> = FxHashMap::default();
    let mut prio: FxHashMap<usize, f64> = FxHashMap::default();
    let mut cluster_left: FxHashMap<usize, usize> = FxHashMap::default();
    let mut cluster_parent: FxHashMap<usize, usize> = FxHashMap::default();

    for (i, &tid) in flat.tasks.iter().enumerate() {
        indeg.insert(tid, flat.preds[i].len());
        succs.insert(tid, flat.succs[i].iter().map(|&p| flat.tasks[p]).collect());
        release.insert(tid, 0.0);
        prio.insert(tid, prio0[i]);
    }

    let mut core = EventCore::new(machine, db, cfg.sim);
    let mut ready: Vec<usize> =
        flat.tasks.iter().enumerate().filter(|(i, _)| flat.preds[*i].is_empty()).map(|(_, &t)| t).collect();
    let mut batch: Vec<(usize, EventKind)> = Vec::new();
    let mut splits = 0usize;
    // static-key policies are keyed once, when the task is released
    let static_keys = !policy.dynamic_order();
    let mut keys: FxHashMap<usize, f64> = FxHashMap::default();
    if static_keys {
        for &id in &ready {
            let pr = *prio.get(&id).unwrap_or(&0.0);
            let mut ctx = core.ctx(&[]);
            keys.insert(id, policy.order(&mut ctx, dag.task(id), 0.0, pr));
        }
    }

    loop {
        // ---- decision round at `core.now`: dispatch (or split) every
        // ready task, recomputing dynamic ordering keys between picks ----
        loop {
            let Some(i) = pop_best_online(&mut core, policy, &dag, &ready, &release, &prio, &keys) else {
                break;
            };
            let id = ready.swap_remove(i);
            let rel = *release.get(&id).unwrap_or(&0.0);
            let t = dag.task(id).clone();

            // ---- local split decision (the constructive move) ----
            let edge = t.char_edge().round() as u32;
            let mut split_edge = None;
            if t.depth < cfg.max_depth + dag.task(dag.root).depth
                && parts.can_partition(t.kind)
                && edge / 2 >= cfg.min_edge
            {
                let eps = 1e-12;
                let idle: Vec<usize> =
                    (0..machine.n_procs()).filter(|&p| !core.procs[p].busy_after(rel + eps)).collect();
                if idle.len() >= 2 {
                    // projected finish unsplit on the best processor
                    let unsplit = (0..machine.n_procs())
                        .map(|p| {
                            core.procs[p].tail().max(rel)
                                + db.time(machine.procs[p].ptype, t.kind, edge as f64, t.flops)
                        })
                        .fold(f64::INFINITY, f64::min);
                    let s_target = ((idle.len() as f64).sqrt().ceil() as u32).max(2);
                    if let Some(sub) = snap_sub_edge(edge, edge as f64 / s_target as f64, cfg.min_edge) {
                        // projected finish split across the idle processors
                        let rate: f64 = idle
                            .iter()
                            .map(|&p| db.curve(machine.procs[p].ptype, t.kind).gflops(sub as f64))
                            .sum();
                        let est = rel + t.flops / (rate * 1e9);
                        if est < unsplit * cfg.gain_factor {
                            split_edge = Some(sub);
                        }
                    }
                }
            }

            if let Some(sub) = split_edge {
                if let Some(children) = parts.apply(&mut dag, id, sub) {
                    splits += 1;
                    // derive cluster-internal edges from the children's specs
                    let specs: Vec<TaskSpec> = children
                        .iter()
                        .map(|&c| {
                            let ct = dag.task(c);
                            TaskSpec::new(ct.kind, ct.reads.clone(), ct.writes.clone())
                        })
                        .collect();
                    let edges = internal_edges(&specs);
                    cluster_left.insert(id, children.len());
                    // the parent's priority is inherited; FCFS keys use release
                    let p_prio = *prio.get(&id).unwrap_or(&0.0);
                    for (ci, &c) in children.iter().enumerate() {
                        cluster_parent.insert(c, id);
                        indeg.insert(c, edges.preds[ci].len());
                        succs.insert(c, edges.succs[ci].iter().map(|&j| children[j]).collect());
                        release.insert(c, rel);
                        prio.insert(c, p_prio);
                        if edges.preds[ci].is_empty() {
                            if static_keys {
                                let mut ctx = core.ctx(&[]);
                                keys.insert(c, policy.order(&mut ctx, dag.task(c), rel, p_prio));
                            }
                            ready.push(c); // joins the current decision round
                        }
                    }
                    continue; // the parent dispatches via its children
                }
            }

            // ---- dispatch through the shared event core ----
            let proc = {
                // successor tasks materialize only for lookahead-style policies
                let succ_tasks: Vec<&Task> = if policy.wants_successors() {
                    succs
                        .get(&id)
                        .map(|v| v.iter().filter(|&&s| dag.is_live(s)).map(|&s| dag.task(s)).collect())
                        .unwrap_or_default()
                } else {
                    Vec::new()
                };
                let mut ctx = core.ctx(&succ_tasks);
                policy.select(&mut ctx, &t, rel)
            };
            let (start, end) = core.commit(&t, id, proc, rel);
            let pos = core.sched.assignments.len();
            core.sched.assignments.push(Assignment { task: id, pos, proc, release: rel, start, end });
        }

        // ---- advance the clock to the next event batch ----
        if !core.pop_event_batch(&mut batch) {
            break;
        }
        for &(key, kind) in &batch {
            if let EventKind::TaskEnd { proc, .. } = kind {
                let id = key;
                core.apply_writes(dag.task(id), proc, core.now);
                let mut newly_ready = Vec::new();
                complete(id, core.now, &succs, &mut indeg, &mut release, &mut cluster_left, &cluster_parent, &mut newly_ready);
                for s in newly_ready {
                    if static_keys {
                        let rl = *release.get(&s).unwrap_or(&0.0);
                        let pr = *prio.get(&s).unwrap_or(&0.0);
                        let mut ctx = core.ctx(&[]);
                        keys.insert(s, policy.order(&mut ctx, dag.task(s), rl, pr));
                    }
                    ready.push(s);
                }
            }
        }
    }

    OnlineResult { schedule: core.finish(), dag, splits }
}

/// Index into `ready` of the task with the largest decision-time policy
/// key (ties toward the smaller task id — creation order tracks program
/// order for the dynamic DAG). Same selection semantics as the offline
/// engine via [`pick_best`]; static-key policies read the key cached at
/// release time.
#[allow(clippy::too_many_arguments)]
fn pop_best_online(
    core: &mut EventCore<'_>,
    policy: &mut dyn SchedPolicy,
    dag: &TaskDag,
    ready: &[usize],
    release: &FxHashMap<usize, f64>,
    prio: &FxHashMap<usize, f64>,
    keys: &FxHashMap<usize, f64>,
) -> Option<usize> {
    let dynamic = policy.dynamic_order();
    pick_best(
        ready.len(),
        |i| {
            let id = ready[i];
            if dynamic {
                let rl = *release.get(&id).unwrap_or(&0.0);
                let pr = *prio.get(&id).unwrap_or(&0.0);
                let mut ctx = core.ctx(&[]);
                policy.order(&mut ctx, dag.task(id), rl, pr)
            } else {
                *keys.get(&id).unwrap_or(&0.0)
            }
        },
        |i| ready[i],
    )
}

/// Dependence edges among a cluster's children (sequential stream over
/// their region accesses) — same semantics as `TaskDag::flat_dag`, local.
struct Edges {
    preds: Vec<Vec<usize>>,
    succs: Vec<Vec<usize>>,
}

fn internal_edges(specs: &[TaskSpec]) -> Edges {
    let mut tmp = TaskDag::new(TaskSpec::new(
        super::task::TaskKind::Custom(u16::MAX),
        Vec::new(),
        vec![super::region::Region::new(u32::MAX, 0, 1, 0, 1)],
    ));
    let root = tmp.root;
    tmp.partition(root, specs.to_vec(), 1);
    let flat = tmp.flat_dag();
    Edges { preds: flat.preds, succs: flat.succs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::simulate;
    use crate::coordinator::partitioners::cholesky;
    use crate::coordinator::perfmodel::PerfCurve;
    use crate::coordinator::platform::MachineBuilder;
    use crate::coordinator::policies::{Ordering, ProcSelect, SchedConfig};

    fn machine() -> (Machine, PerfDb) {
        let mut b = MachineBuilder::new("m");
        let h = b.space("host", u64::MAX);
        b.main(h);
        let t = b.proc_type("cpu", 1.0, 0.1);
        b.processors(4, "c", t, h);
        let m = b.build();
        let mut db = PerfDb::new();
        db.set_fallback(0, PerfCurve::Saturating { peak: 20.0, half: 64.0, exponent: 2.0 });
        (m, db)
    }

    fn cfg(sim: SimConfig) -> OnlineConfig {
        OnlineConfig::new(sim, 64)
    }

    #[test]
    fn online_schedules_all_tasks_once() {
        let (m, db) = machine();
        let mut dag = cholesky::root(512);
        cholesky::partition_uniform(&mut dag, 128);
        let sim = SimConfig::new(SchedConfig::new(Ordering::Fcfs, ProcSelect::EarliestFinish));
        let res = schedule_online(&dag, &m, &db, &PartitionerSet::standard(), cfg(sim));
        // every *leaf of the final dag* is scheduled exactly once
        assert_eq!(res.schedule.assignments.len(), res.dag.frontier().len());
        // dependence sanity: assignments sorted by start never violate
        // cluster completion (makespan positive, finite)
        assert!(res.schedule.makespan.is_finite() && res.schedule.makespan > 0.0);
    }

    #[test]
    fn online_splits_the_root_task() {
        // a single coarse task on an idle 4-proc machine must be split
        let (m, db) = machine();
        let dag = cholesky::root(512);
        let sim = SimConfig::new(SchedConfig::new(Ordering::Fcfs, ProcSelect::EarliestFinish));
        let res = schedule_online(&dag, &m, &db, &PartitionerSet::standard(), cfg(sim));
        assert!(res.splits >= 1, "no online split taken");
        assert!(res.dag.depth() >= 1);
        // and it beats running the root sequentially
        let seq = simulate(&dag, &m, &db, sim);
        assert!(res.schedule.makespan < seq.makespan, "{} vs {}", res.schedule.makespan, seq.makespan);
    }

    #[test]
    fn online_respects_min_edge() {
        let (m, db) = machine();
        let dag = cholesky::root(512);
        let sim = SimConfig::new(SchedConfig::new(Ordering::Fcfs, ProcSelect::EarliestFinish));
        let mut c = cfg(sim);
        c.min_edge = 256;
        let res = schedule_online(&dag, &m, &db, &PartitionerSet::standard(), c);
        for t in res.dag.frontier() {
            assert!(res.dag.task(t).char_edge() >= 256.0 - 1e-9);
        }
    }

    #[test]
    fn online_beats_or_matches_uniform_on_idle_machines() {
        let (m, db) = machine();
        let mut uni = cholesky::root(1024);
        cholesky::partition_uniform(&mut uni, 256);
        let sim = SimConfig::new(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish));
        let base = simulate(&uni, &m, &db, sim);
        let res = schedule_online(&uni, &m, &db, &PartitionerSet::standard(), cfg(sim));
        // online refinement should not be catastrophically worse (it acts
        // only when it predicts a win) — allow small regressions from the
        // conservative cluster barrier
        assert!(res.schedule.makespan <= base.makespan * 1.15, "{} vs {}", res.schedule.makespan, base.makespan);
    }

    #[test]
    fn online_no_partitioner_is_plain_scheduling() {
        let (m, db) = machine();
        let mut dag = cholesky::root(512);
        cholesky::partition_uniform(&mut dag, 128);
        let sim = SimConfig::new(SchedConfig::new(Ordering::Fcfs, ProcSelect::EarliestIdle));
        let res = schedule_online(&dag, &m, &db, &PartitionerSet::empty(), cfg(sim));
        let base = simulate(&dag, &m, &db, sim);
        assert_eq!(res.splits, 0);
        assert!((res.schedule.makespan - base.makespan).abs() < 1e-9 * base.makespan.max(1.0));
    }

    #[test]
    fn cluster_barrier_orders_dependents() {
        // successor of a split task must start after ALL children finish
        let (m, db) = machine();
        let dag = cholesky::root(512); // root will split; nothing after it
        let sim = SimConfig::new(SchedConfig::new(Ordering::Fcfs, ProcSelect::EarliestFinish));
        let res = schedule_online(&dag, &m, &db, &PartitionerSet::standard(), cfg(sim));
        // internal check: the potrf-chain order is respected in the
        // assignment list (each assignment's release <= start)
        for a in &res.schedule.assignments {
            assert!(a.start >= a.release - 1e-12);
        }
    }

    #[test]
    fn online_emits_the_shared_event_log() {
        // the constructive path runs on the same event core: its schedule
        // carries the typed event log, one TaskStart/TaskEnd pair per
        // dispatched leaf
        let (m, db) = machine();
        let mut dag = cholesky::root(512);
        cholesky::partition_uniform(&mut dag, 128);
        let sim = SimConfig::new(SchedConfig::new(Ordering::Fcfs, ProcSelect::EarliestFinish));
        let res = schedule_online(&dag, &m, &db, &PartitionerSet::standard(), cfg(sim));
        let n = res.schedule.assignments.len();
        let starts = res.schedule.events.iter().filter(|e| matches!(e.kind, EventKind::TaskStart { .. })).count();
        let ends = res.schedule.events.iter().filter(|e| matches!(e.kind, EventKind::TaskEnd { .. })).count();
        assert_eq!(starts, n);
        assert_eq!(ends, n);
        for w in res.schedule.events.windows(2) {
            assert!(w[1].time >= w[0].time - 1e-15, "event log out of order");
        }
    }
}
