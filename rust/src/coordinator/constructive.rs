//! The *constructive* (online) scheduler-partitioner — the paper's §4
//! follow-up to the static iterative solver: "a constructive
//! implementation, in which local information is applied on a per-task
//! basis ... can be applied directly on actual task schedulers".
//!
//! Instead of iterating whole schedule/partition rounds, partitioning
//! decisions are taken **at task arrival to the scheduling queue**: when a
//! ready task is popped, a local score (projected finish time unsplit vs.
//! split across currently-idle processors at a finer grain) decides
//! whether to dispatch it as-is or replace it, in place, by its blocked
//! sub-task cluster.
//!
//! Key simplification that keeps the online DAG maintenance exact: a task
//! is only split when it is *ready* (all predecessors finished), so its
//! children can have no unfinished external predecessors — only
//! cluster-internal edges (derived from the children's region accesses)
//! plus a completion counter that releases the parent's successors once
//! every child is done.

use super::coherence::Coherence;
use super::engine::{Assignment, Schedule, SimConfig, TransferRecord};
use super::ordering::critical_times;
use super::partitioners::{snap_sub_edge, PartitionerSet};
use super::perfmodel::PerfDb;
use super::platform::Machine;
use super::policies::SchedConfig;
use super::policy::{self, SchedContext, SchedPolicy};
use super::task::{Task, TaskSpec};
use super::taskdag::TaskDag;
use crate::util::rng::Rng;

/// Knobs of the online partitioner.
#[derive(Debug, Clone, Copy)]
pub struct OnlineConfig {
    pub sim: SimConfig,
    /// Never split below this tile edge.
    pub min_edge: u32,
    /// Required relative gain (est_split < factor * est_unsplit) before a
    /// split is taken; 1.0 = split on any predicted win.
    pub gain_factor: f64,
    /// Cap on recursive split depth per task.
    pub max_depth: u32,
}

impl OnlineConfig {
    pub fn new(sim: SimConfig, min_edge: u32) -> OnlineConfig {
        OnlineConfig { sim, min_edge, gain_factor: 0.6, max_depth: 4 }
    }
}

/// Result: the schedule plus the final (dynamically partitioned) DAG and
/// how many online splits were taken.
pub struct OnlineResult {
    pub schedule: Schedule,
    pub dag: TaskDag,
    pub splits: usize,
}

/// Run the constructive scheduler-partitioner over (a clone of) `dag0`,
/// under the built-in policy named by `cfg.sim`'s shim fields.
pub fn schedule_online(
    dag0: &TaskDag,
    machine: &Machine,
    db: &PerfDb,
    parts: &PartitionerSet,
    cfg: OnlineConfig,
) -> OnlineResult {
    let mut p = policy::policy_for(SchedConfig::new(cfg.sim.ordering, cfg.sim.select));
    schedule_online_with(dag0, machine, db, parts, cfg, p.as_mut())
}

/// [`schedule_online`] under an arbitrary scheduling policy: ready-queue
/// ordering and per-task processor selection both dispatch through
/// `policy`, exactly as in the offline engine.
pub fn schedule_online_with(
    dag0: &TaskDag,
    machine: &Machine,
    db: &PerfDb,
    parts: &PartitionerSet,
    cfg: OnlineConfig,
    policy: &mut dyn SchedPolicy,
) -> OnlineResult {
    let mut dag = dag0.clone();
    let flat = dag.flat_dag();
    let mut rng = Rng::new(cfg.sim.seed);
    let mut coh = Coherence::new(
        machine.spaces.len(),
        machine.main_space,
        cfg.sim.cache,
        machine.capacities(),
        cfg.sim.elem_bytes,
    );

    // --- dynamic DAG state, indexed by task id (not frontier position) ---
    // base edges from the initial frontier
    let n0 = flat.len();
    let prio0 = if policy.wants_critical_times() {
        critical_times(&dag, &flat, machine, db)
    } else {
        vec![0.0; n0]
    };
    // per-task: remaining predecessor count, successors (task ids),
    // release time, priority, parent cluster (for completion counting)
    use crate::util::fxhash::FxHashMap;
    let mut indeg: FxHashMap<usize, usize> = FxHashMap::default();
    let mut succs: FxHashMap<usize, Vec<usize>> = FxHashMap::default();
    let mut release: FxHashMap<usize, f64> = FxHashMap::default();
    let mut prio: FxHashMap<usize, f64> = FxHashMap::default();
    let mut cluster_left: FxHashMap<usize, usize> = FxHashMap::default();
    let mut cluster_parent: FxHashMap<usize, usize> = FxHashMap::default();

    for (i, &tid) in flat.tasks.iter().enumerate() {
        indeg.insert(tid, flat.preds[i].len());
        succs.insert(tid, flat.succs[i].iter().map(|&p| flat.tasks[p]).collect());
        release.insert(tid, 0.0);
        prio.insert(tid, prio0[i]);
    }

    #[derive(PartialEq)]
    struct HeapItem {
        key: f64,
        id: usize,
    }
    impl Eq for HeapItem {}
    impl PartialOrd for HeapItem {
        fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(other))
        }
    }
    impl Ord for HeapItem {
        fn cmp(&self, other: &Self) -> std::cmp::Ordering {
            self.key.total_cmp(&other.key).then(other.id.cmp(&self.id))
        }
    }
    let mut proc_avail = vec![0.0f64; machine.n_procs()];
    let mut link_busy = vec![0.0f64; machine.links.len()];

    let mut ready: std::collections::BinaryHeap<HeapItem> = std::collections::BinaryHeap::new();
    for (i, &tid) in flat.tasks.iter().enumerate() {
        if flat.preds[i].is_empty() {
            let mut ctx = SchedContext {
                machine,
                db,
                proc_avail: &proc_avail,
                link_busy: &link_busy,
                coh: &mut coh,
                rng: &mut rng,
                successors: &[],
            };
            let key = policy.order(&mut ctx, dag.task(tid), 0.0, prio0[i]);
            ready.push(HeapItem { key, id: tid });
        }
    }

    let mut sched = Schedule { proc_busy: vec![0.0; machine.n_procs()], ..Default::default() };
    let mut splits = 0usize;

    // Graph bookkeeping when `id` finishes at `end`: bubble completion up
    // the cluster, decrement successor indegrees, record releases, and
    // collect tasks that became ready (the caller keys + pushes them, so
    // ordering stays a policy decision).
    #[allow(clippy::too_many_arguments)]
    fn complete(
        id: usize,
        end: f64,
        succs: &FxHashMap<usize, Vec<usize>>,
        indeg: &mut FxHashMap<usize, usize>,
        release: &mut FxHashMap<usize, f64>,
        cluster_left: &mut FxHashMap<usize, usize>,
        cluster_parent: &FxHashMap<usize, usize>,
        newly_ready: &mut Vec<usize>,
    ) {
        if let Some(&parent) = cluster_parent.get(&id) {
            let left = cluster_left.get_mut(&parent).expect("cluster counter");
            *left -= 1;
            if *left == 0 {
                complete(parent, end, succs, indeg, release, cluster_left, cluster_parent, newly_ready);
            }
        }
        if let Some(ss) = succs.get(&id) {
            for &s in ss {
                let d = indeg.get_mut(&s).expect("succ indeg");
                *d -= 1;
                let r = release.entry(s).or_insert(0.0);
                *r = r.max(end);
                if *d == 0 {
                    newly_ready.push(s);
                }
            }
        }
    }

    while let Some(HeapItem { id, .. }) = ready.pop() {
        let rel = *release.get(&id).unwrap_or(&0.0);
        let t = dag.task(id).clone();

        // ---- local split decision (the constructive move) ----
        let edge = t.char_edge().round() as u32;
        let mut split_edge = None;
        if t.depth < cfg.max_depth + dag.task(dag.root).depth
            && parts.can_partition(t.kind)
            && edge / 2 >= cfg.min_edge
        {
            let eps = 1e-12;
            let idle: Vec<usize> = (0..machine.n_procs()).filter(|&p| proc_avail[p] <= rel + eps).collect();
            if idle.len() >= 2 {
                // projected finish unsplit on the best processor
                let unsplit = (0..machine.n_procs())
                    .map(|p| {
                        proc_avail[p].max(rel) + db.time(machine.procs[p].ptype, t.kind, edge as f64, t.flops)
                    })
                    .fold(f64::INFINITY, f64::min);
                let s_target = ((idle.len() as f64).sqrt().ceil() as u32).max(2);
                if let Some(sub) = snap_sub_edge(edge, edge as f64 / s_target as f64, cfg.min_edge) {
                    // projected finish split across the idle processors
                    let rate: f64 =
                        idle.iter().map(|&p| db.curve(machine.procs[p].ptype, t.kind).gflops(sub as f64)).sum();
                    let est = rel + t.flops / (rate * 1e9);
                    if est < unsplit * cfg.gain_factor {
                        split_edge = Some(sub);
                    }
                }
            }
        }

        if let Some(sub) = split_edge {
            if let Some(children) = parts.apply(&mut dag, id, sub) {
                splits += 1;
                // derive cluster-internal edges from the children's specs
                let specs: Vec<TaskSpec> = children
                    .iter()
                    .map(|&c| {
                        let ct = dag.task(c);
                        TaskSpec::new(ct.kind, ct.reads.clone(), ct.writes.clone())
                    })
                    .collect();
                let edges = internal_edges(&specs);
                cluster_left.insert(id, children.len());
                // the parent's priority is inherited; FCFS keys use release
                let p_prio = *prio.get(&id).unwrap_or(&0.0);
                for (ci, &c) in children.iter().enumerate() {
                    cluster_parent.insert(c, id);
                    indeg.insert(c, edges.preds[ci].len());
                    succs.insert(c, edges.succs[ci].iter().map(|&j| children[j]).collect());
                    release.insert(c, rel);
                    prio.insert(c, p_prio);
                    if edges.preds[ci].is_empty() {
                        let mut ctx = SchedContext {
                            machine,
                            db,
                            proc_avail: &proc_avail,
                            link_busy: &link_busy,
                            coh: &mut coh,
                            rng: &mut rng,
                            successors: &[],
                        };
                        let key = policy.order(&mut ctx, dag.task(c), rel, p_prio);
                        ready.push(HeapItem { key, id: c });
                    }
                }
                continue; // the parent dispatches via its children
            }
        }

        // ---- dispatch (same machinery as the engine) ----
        let proc = {
            // successor tasks materialize only for lookahead-style policies
            let succ_tasks: Vec<&Task> = if policy.wants_successors() {
                succs
                    .get(&id)
                    .map(|v| v.iter().filter(|&&s| dag.is_live(s)).map(|&s| dag.task(s)).collect())
                    .unwrap_or_default()
            } else {
                Vec::new()
            };
            let mut ctx = SchedContext {
                machine,
                db,
                proc_avail: &proc_avail,
                link_busy: &link_busy,
                coh: &mut coh,
                rng: &mut rng,
                successors: &succ_tasks,
            };
            policy.select(&mut ctx, &t, rel)
        };
        let space = machine.procs[proc].space;
        let mut data_ready = rel;
        for r in &t.reads {
            let block = coh.register(*r);
            for tr in coh.read_plan(block, space) {
                let mut at = rel;
                let (mut first, mut last) = (f64::INFINITY, rel);
                for lid in machine.route(tr.from, tr.to) {
                    let l = &machine.links[lid];
                    let s = at.max(link_busy[lid]);
                    let e = s + l.latency + tr.bytes as f64 / l.bandwidth;
                    link_busy[lid] = e;
                    first = first.min(s);
                    last = e;
                    at = e;
                }
                data_ready = data_ready.max(last);
                sched.transfers.push(TransferRecord { from: tr.from, to: tr.to, bytes: tr.bytes, start: first, end: last });
                sched.transfer_bytes += tr.bytes;
                coh.complete_read(tr.block, tr.to);
            }
            coh.complete_read(block, space);
        }
        let start = proc_avail[proc].max(data_ready);
        let end = start + db.time(machine.procs[proc].ptype, t.kind, t.char_edge(), t.flops);
        proc_avail[proc] = end;
        sched.proc_busy[proc] += end - start;
        sched.assignments.push(Assignment { task: id, pos: sched.assignments.len(), proc, release: rel, start, end });
        for w in &t.writes {
            let block = coh.register(*w);
            let _ = coh.complete_write(block, space);
        }
        let mut newly_ready = Vec::new();
        complete(id, end, &succs, &mut indeg, &mut release, &mut cluster_left, &cluster_parent, &mut newly_ready);
        for s in newly_ready {
            let rl = *release.get(&s).unwrap_or(&0.0);
            let pr = *prio.get(&s).unwrap_or(&0.0);
            let mut ctx = SchedContext {
                machine,
                db,
                proc_avail: &proc_avail,
                link_busy: &link_busy,
                coh: &mut coh,
                rng: &mut rng,
                successors: &[],
            };
            let key = policy.order(&mut ctx, dag.task(s), rl, pr);
            ready.push(HeapItem { key, id: s });
        }
    }

    let task_end = sched.assignments.iter().map(|a| a.end).fold(0.0f64, f64::max);
    let xfer_end = sched.transfers.iter().map(|t| t.end).fold(0.0f64, f64::max);
    sched.makespan = task_end.max(xfer_end);
    OnlineResult { schedule: sched, dag, splits }
}

/// Dependence edges among a cluster's children (sequential stream over
/// their region accesses) — same semantics as `TaskDag::flat_dag`, local.
struct Edges {
    preds: Vec<Vec<usize>>,
    succs: Vec<Vec<usize>>,
}

fn internal_edges(specs: &[TaskSpec]) -> Edges {
    let mut tmp = TaskDag::new(TaskSpec::new(
        super::task::TaskKind::Custom(u16::MAX),
        Vec::new(),
        vec![super::region::Region::new(u32::MAX, 0, 1, 0, 1)],
    ));
    let root = tmp.root;
    tmp.partition(root, specs.to_vec(), 1);
    let flat = tmp.flat_dag();
    Edges { preds: flat.preds, succs: flat.succs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::simulate;
    use crate::coordinator::partitioners::cholesky;
    use crate::coordinator::perfmodel::PerfCurve;
    use crate::coordinator::platform::MachineBuilder;
    use crate::coordinator::policies::{Ordering, ProcSelect, SchedConfig};

    fn machine() -> (Machine, PerfDb) {
        let mut b = MachineBuilder::new("m");
        let h = b.space("host", u64::MAX);
        b.main(h);
        let t = b.proc_type("cpu", 1.0, 0.1);
        b.processors(4, "c", t, h);
        let m = b.build();
        let mut db = PerfDb::new();
        db.set_fallback(0, PerfCurve::Saturating { peak: 20.0, half: 64.0, exponent: 2.0 });
        (m, db)
    }

    fn cfg(sim: SimConfig) -> OnlineConfig {
        OnlineConfig::new(sim, 64)
    }

    #[test]
    fn online_schedules_all_tasks_once() {
        let (m, db) = machine();
        let mut dag = cholesky::root(512);
        cholesky::partition_uniform(&mut dag, 128);
        let sim = SimConfig::new(SchedConfig::new(Ordering::Fcfs, ProcSelect::EarliestFinish));
        let res = schedule_online(&dag, &m, &db, &PartitionerSet::standard(), cfg(sim));
        // every *leaf of the final dag* is scheduled exactly once
        assert_eq!(res.schedule.assignments.len(), res.dag.frontier().len());
        // dependence sanity: assignments sorted by start never violate
        // cluster completion (makespan positive, finite)
        assert!(res.schedule.makespan.is_finite() && res.schedule.makespan > 0.0);
    }

    #[test]
    fn online_splits_the_root_task() {
        // a single coarse task on an idle 4-proc machine must be split
        let (m, db) = machine();
        let dag = cholesky::root(512);
        let sim = SimConfig::new(SchedConfig::new(Ordering::Fcfs, ProcSelect::EarliestFinish));
        let res = schedule_online(&dag, &m, &db, &PartitionerSet::standard(), cfg(sim));
        assert!(res.splits >= 1, "no online split taken");
        assert!(res.dag.depth() >= 1);
        // and it beats running the root sequentially
        let seq = simulate(&dag, &m, &db, sim);
        assert!(res.schedule.makespan < seq.makespan, "{} vs {}", res.schedule.makespan, seq.makespan);
    }

    #[test]
    fn online_respects_min_edge() {
        let (m, db) = machine();
        let dag = cholesky::root(512);
        let sim = SimConfig::new(SchedConfig::new(Ordering::Fcfs, ProcSelect::EarliestFinish));
        let mut c = cfg(sim);
        c.min_edge = 256;
        let res = schedule_online(&dag, &m, &db, &PartitionerSet::standard(), c);
        for t in res.dag.frontier() {
            assert!(res.dag.task(t).char_edge() >= 256.0 - 1e-9);
        }
    }

    #[test]
    fn online_beats_or_matches_uniform_on_idle_machines() {
        let (m, db) = machine();
        let mut uni = cholesky::root(1024);
        cholesky::partition_uniform(&mut uni, 256);
        let sim = SimConfig::new(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish));
        let base = simulate(&uni, &m, &db, sim);
        let res = schedule_online(&uni, &m, &db, &PartitionerSet::standard(), cfg(sim));
        // online refinement should not be catastrophically worse (it acts
        // only when it predicts a win) — allow small regressions from the
        // conservative cluster barrier
        assert!(res.schedule.makespan <= base.makespan * 1.15, "{} vs {}", res.schedule.makespan, base.makespan);
    }

    #[test]
    fn online_no_partitioner_is_plain_scheduling() {
        let (m, db) = machine();
        let mut dag = cholesky::root(512);
        cholesky::partition_uniform(&mut dag, 128);
        let sim = SimConfig::new(SchedConfig::new(Ordering::Fcfs, ProcSelect::EarliestIdle));
        let res = schedule_online(&dag, &m, &db, &PartitionerSet::empty(), cfg(sim));
        let base = simulate(&dag, &m, &db, sim);
        assert_eq!(res.splits, 0);
        assert!((res.schedule.makespan - base.makespan).abs() < 1e-9 * base.makespan.max(1.0));
    }

    #[test]
    fn cluster_barrier_orders_dependents() {
        // successor of a split task must start after ALL children finish
        let (m, db) = machine();
        let dag = cholesky::root(512); // root will split; nothing after it
        let sim = SimConfig::new(SchedConfig::new(Ordering::Fcfs, ProcSelect::EarliestFinish));
        let res = schedule_online(&dag, &m, &db, &PartitionerSet::standard(), cfg(sim));
        // internal check: the potrf-chain order is respected in the
        // assignment list (each assignment's release <= start)
        for a in &res.schedule.assignments {
            assert!(a.start >= a.release - 1e-12);
        }
    }
}
