//! Synthetic workload generators beyond dense linear algebra — used by the
//! robustness tests and the scheduler ablations ("the extracted insights
//! can be applied to other irregular task-parallel implementations", §4).

use super::region::Region;
use super::task::{TaskKind, TaskSpec};
use super::taskdag::TaskDag;
use crate::util::rng::Rng;

/// A layered fork-join DAG: `layers` stages of `width` independent tasks
/// over disjoint tiles, with a reduction task between stages (classic
/// bulk-synchronous shape). Tile edge = `b`.
pub fn layered(layers: u32, width: u32, b: u32) -> TaskDag {
    assert!(layers >= 1 && width >= 1);
    let total = Region::new(0, 0, width * b, 0, (layers + 1) * b);
    let mut dag = TaskDag::new(TaskSpec::new(TaskKind::Custom(1), vec![total], vec![total]));
    let mut specs = Vec::new();
    for l in 0..layers {
        let col = |i: u32, l: u32| Region::new(0, i * b, (i + 1) * b, l * b, (l + 1) * b);
        // stage tasks read the previous reduction column, write their own
        for i in 0..width {
            specs.push(TaskSpec::new(TaskKind::Gemm, vec![col(0, l)], vec![col(i, l + 1)]));
        }
        // reduction: reads the whole next column band, writes cell (0, l+1)
        let band = Region::new(0, 0, width * b, (l + 1) * b, (l + 2) * b);
        if l + 1 < layers {
            specs.push(TaskSpec::new(TaskKind::Syrk, vec![band], vec![col(0, l + 1)]));
        }
    }
    let root = dag.root;
    dag.partition(root, specs, b);
    dag
}

/// 1-D stencil sweep: `steps` time steps over `cells` tiles; each step's
/// task reads its neighbours from the previous step (wavefront DAG).
pub fn stencil(cells: u32, steps: u32, b: u32) -> TaskDag {
    assert!(cells >= 1 && steps >= 1);
    let total = Region::new(0, 0, cells * b, 0, (steps + 1) * b);
    let mut dag = TaskDag::new(TaskSpec::new(TaskKind::Custom(2), vec![total], vec![total]));
    let cell = |i: u32, t: u32| Region::new(0, i * b, (i + 1) * b, t * b, (t + 1) * b);
    let mut specs = Vec::new();
    for t in 0..steps {
        for i in 0..cells {
            let mut reads = vec![cell(i, t)];
            if i > 0 {
                reads.push(cell(i - 1, t));
            }
            if i + 1 < cells {
                reads.push(cell(i + 1, t));
            }
            specs.push(TaskSpec::new(TaskKind::Trsm, reads, vec![cell(i, t + 1)]));
        }
    }
    let root = dag.root;
    dag.partition(root, specs, b);
    dag
}

/// Random layered DAG (Tobita-Kasahara-style): `n` tasks in random layers,
/// each reading 1..=3 random earlier tiles — a stress shape for the
/// dependence-derivation and scheduling machinery.
pub fn random_layered(n: u32, b: u32, seed: u64) -> TaskDag {
    assert!(n >= 1);
    let mut rng = Rng::new(seed);
    let total = Region::new(0, 0, n * b, 0, b);
    let mut dag = TaskDag::new(TaskSpec::new(TaskKind::Custom(3), vec![total], vec![total]));
    let tile = |i: u32| Region::new(0, i * b, (i + 1) * b, 0, b);
    let mut specs = Vec::new();
    for i in 0..n {
        let mut reads = Vec::new();
        if i > 0 {
            for _ in 0..1 + rng.below(3) {
                reads.push(tile(rng.below(i as usize) as u32));
            }
        }
        specs.push(TaskSpec::new(TaskKind::Gemm, reads, vec![tile(i)]));
    }
    let root = dag.root;
    dag.partition(root, specs, b);
    dag
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layered_shape() {
        let dag = layered(3, 4, 32);
        let flat = dag.flat_dag();
        assert_eq!(flat.len(), 3 * 4 + 2); // 3 stages + 2 reductions
        assert!(flat.width() >= 4, "stage tasks parallel: {}", flat.width());
        // reductions serialize stages: longest path >= 2*layers - 1
        assert!(flat.longest_path_len() >= 5);
    }

    #[test]
    fn stencil_wavefront() {
        let dag = stencil(5, 4, 16);
        let flat = dag.flat_dag();
        assert_eq!(flat.len(), 20);
        assert_eq!(flat.width(), 5, "one wavefront per step");
        assert_eq!(flat.longest_path_len(), 4, "steps chain");
        // middle cell depends on 3 neighbours of the previous step
        let mid = 5 + 2; // step 1, cell 2
        assert_eq!(flat.preds[mid].len(), 3);
    }

    #[test]
    fn random_layered_is_schedulable() {
        use crate::coordinator::engine::{simulate, SimConfig};
        use crate::coordinator::perfmodel::{PerfCurve, PerfDb};
        use crate::coordinator::platform::MachineBuilder;
        use crate::coordinator::policies::{Ordering, ProcSelect, SchedConfig};

        let dag = random_layered(64, 16, 7);
        assert_eq!(dag.flat_dag().len(), 64);
        let mut b = MachineBuilder::new("m");
        let h = b.space("host", u64::MAX);
        b.main(h);
        let t = b.proc_type("cpu", 1.0, 0.1);
        b.processors(3, "c", t, h);
        let m = b.build();
        let mut db = PerfDb::new();
        db.set_fallback(0, PerfCurve::Const { gflops: 5.0 });
        let s = simulate(&dag, &m, &db, SimConfig::new(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish)));
        assert_eq!(s.assignments.len(), 64);
        assert!(s.makespan > 0.0);
    }

    #[test]
    fn generators_are_deterministic() {
        let a = random_layered(32, 16, 3).flat_dag();
        let b = random_layered(32, 16, 3).flat_dag();
        assert_eq!(a.edge_count(), b.edge_count());
        let c = random_layered(32, 16, 4).flat_dag();
        assert_ne!(a.edge_count(), c.edge_count());
    }
}
