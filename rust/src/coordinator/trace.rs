//! Trace generation: CSV load/granularity traces (Figs. 2b and 6),
//! Paraver-compatible `.prv`/`.pcf`/`.row` files (footnote 3 of the
//! paper), and the typed event log the discrete-event core emits
//! ([`event_log_csv`]).

use std::fmt::Write as _;

use super::engine::{EventKind, Schedule};
use super::metrics::load_trace;
use super::platform::Machine;
use super::task::TaskKind;
use super::taskdag::TaskDag;

/// CSV of the engine's typed event log, in simulated-time order:
/// `time_s,event,task,proc,from,to,bytes` (unused columns empty). This is
/// the raw material of every other trace — the rows are emitted by the
/// event queue itself, so transfer and execution intervals appear exactly
/// as the simulation resolved them (queuing and gap backfill included).
pub fn event_log_csv(sched: &Schedule) -> String {
    let mut out = String::from("time_s,event,task,proc,from,to,bytes\n");
    for e in &sched.events {
        let _ = match e.kind {
            EventKind::TaskStart { task, proc } => {
                writeln!(out, "{:.9},task_start,{task},{proc},,,", e.time)
            }
            EventKind::TaskEnd { task, proc } => {
                writeln!(out, "{:.9},task_end,{task},{proc},,,", e.time)
            }
            EventKind::TransferStart { from, to, bytes } => {
                writeln!(out, "{:.9},transfer_start,,,{from},{to},{bytes}", e.time)
            }
            EventKind::TransferEnd { from, to, bytes } => {
                writeln!(out, "{:.9},transfer_end,,,{from},{to},{bytes}", e.time)
            }
            EventKind::ProcIdle { proc } => {
                writeln!(out, "{:.9},proc_idle,,{proc},,,", e.time)
            }
            EventKind::ProcFail { proc } => {
                writeln!(out, "{:.9},proc_fail,,{proc},,,", e.time)
            }
            EventKind::ProcRestore { proc } => {
                writeln!(out, "{:.9},proc_restore,,{proc},,,", e.time)
            }
            EventKind::TaskFault { task, proc } => {
                writeln!(out, "{:.9},task_fault,{task},{proc},,,", e.time)
            }
        };
    }
    out
}

/// CSV of `(time_us, active_processors)` — the Fig. 2b compute-load trace.
pub fn load_trace_csv(sched: &Schedule, samples: usize) -> String {
    let mut out = String::from("time_s,active_procs\n");
    for (t, a) in load_trace(sched, samples) {
        let _ = writeln!(out, "{t:.6},{a}");
    }
    out
}

/// CSV of per-task rows: `proc,start,end,kind,tile_edge` — the Fig. 6 task
/// scheduling + granularity traces (granularity = tile edge, the paper's
/// light-green→dark-blue gradient).
pub fn schedule_csv(dag: &TaskDag, sched: &Schedule, machine: &Machine) -> String {
    let mut out = String::from("proc,proc_name,start_s,end_s,kind,tile_edge\n");
    let mut rows: Vec<_> = sched.assignments.iter().collect();
    rows.sort_by(|a, b| a.proc.cmp(&b.proc).then(a.start.total_cmp(&b.start)));
    for a in rows {
        let t = dag.task(a.task);
        let _ = writeln!(
            out,
            "{},{},{:.6},{:.6},{},{:.0}",
            a.proc,
            machine.procs[a.proc].name,
            a.start,
            a.end,
            t.kind.name(),
            t.char_edge()
        );
    }
    out
}

/// Paraver state value per task kind (colors come from the .pcf).
fn kind_state(kind: TaskKind) -> u32 {
    match kind {
        TaskKind::Potrf => 2,
        TaskKind::Trsm => 3,
        TaskKind::Syrk => 4,
        TaskKind::Gemm => 5,
        TaskKind::Getrf => 6,
        TaskKind::TrsmL => 7,
        TaskKind::TrsmU => 8,
        TaskKind::Geqrt => 9,
        TaskKind::Tsqrt => 10,
        TaskKind::Larfb => 11,
        TaskKind::Ssrfb => 12,
        TaskKind::Custom(_) => 13,
    }
}

/// Paraver `.prv` trace: one application, one task per processor, state
/// records (type 1) for running tasks and communication records (type 3)
/// for transfers. Times in nanoseconds.
pub fn paraver_prv(dag: &TaskDag, sched: &Schedule, machine: &Machine) -> String {
    let ns = |t: f64| (t * 1e9).round() as u64;
    let total = ns(sched.makespan).max(1);
    let nproc = machine.n_procs();
    // header: #Paraver (date):endtime:nNodes(nCpus):nAppl:appl(nTasks(threads:node,...))
    let mut out = format!("#Paraver (10/07/2026 at 12:00):{total}:1({nproc}):1:{nproc}(");
    for i in 0..nproc {
        let _ = write!(out, "{}1:1", if i > 0 { "," } else { "" });
    }
    out.push_str(")\n");
    // state records: 1:cpu:appl:task:thread:begin:end:state
    let mut recs: Vec<(u64, String)> = Vec::new();
    for a in &sched.assignments {
        let t = dag.task(a.task);
        let line = format!(
            "1:{}:1:{}:1:{}:{}:{}",
            a.proc + 1,
            a.proc + 1,
            ns(a.start),
            ns(a.end),
            kind_state(t.kind)
        );
        recs.push((ns(a.start), line));
    }
    for tr in &sched.transfers {
        // 3:cpu_send:...:cpu_recv:...  (simplified logical comm record)
        let line = format!(
            "3:{}:1:{}:1:{}:{}:{}:1:{}:1:{}:{}:{}:{}",
            tr.from + 1,
            tr.from + 1,
            ns(tr.start),
            ns(tr.start),
            tr.to + 1,
            tr.to + 1,
            ns(tr.end),
            ns(tr.end),
            tr.bytes,
            0
        );
        recs.push((ns(tr.start), line));
    }
    recs.sort();
    for (_, l) in recs {
        out.push_str(&l);
        out.push('\n');
    }
    out
}

/// Paraver `.pcf` (semantic/color config) for the task-kind states.
pub fn paraver_pcf() -> String {
    let mut out = String::from(
        "DEFAULT_OPTIONS\n\nLEVEL               THREAD\nUNITS               NANOSEC\n\nSTATES\n0    Idle\n1    Running\n",
    );
    let kinds = [
        TaskKind::Potrf,
        TaskKind::Trsm,
        TaskKind::Syrk,
        TaskKind::Gemm,
        TaskKind::Getrf,
        TaskKind::TrsmL,
        TaskKind::TrsmU,
        TaskKind::Geqrt,
        TaskKind::Tsqrt,
        TaskKind::Larfb,
        TaskKind::Ssrfb,
    ];
    for k in kinds {
        let _ = writeln!(out, "{}    {}", kind_state(k), k.name().to_uppercase());
    }
    out.push_str("\nSTATES_COLOR\n0    {117,195,255}\n1    {0,0,255}\n2    {255,215,0}\n3    {135,206,235}\n4    {250,128,114}\n5    {152,251,152}\n");
    out
}

/// Paraver `.row` (processor names).
pub fn paraver_row(machine: &Machine) -> String {
    let mut out = format!("LEVEL CPU SIZE {}\n", machine.n_procs());
    for p in &machine.procs {
        let _ = writeln!(out, "{}", p.name);
    }
    out
}

/// ASCII Gantt chart of the schedule: one row per processor, time binned
/// into `cols` columns, glyph = dominant task kind in the bin (idle = '.').
/// The terminal rendition of the paper's Fig. 6 trace rows.
pub fn ascii_gantt(dag: &TaskDag, sched: &Schedule, machine: &Machine, cols: usize) -> String {
    let mut out = String::new();
    if sched.makespan <= 0.0 || cols == 0 {
        return out;
    }
    let glyph = |kind: TaskKind| match kind {
        TaskKind::Potrf | TaskKind::Getrf | TaskKind::Geqrt => 'P',
        TaskKind::Trsm | TaskKind::TrsmL | TaskKind::TrsmU => 'T',
        TaskKind::Syrk | TaskKind::Tsqrt => 'S',
        TaskKind::Gemm | TaskKind::Larfb | TaskKind::Ssrfb => 'G',
        TaskKind::Custom(_) => 'C',
    };
    let dt = sched.makespan / cols as f64;
    // per-proc, per-bin busy seconds by kind
    let mut rows: Vec<Vec<(f64, char)>> = vec![vec![(0.0, '.'); cols]; machine.n_procs()];
    for a in &sched.assignments {
        let g = glyph(dag.task(a.task).kind);
        let (c0, c1) = ((a.start / dt) as usize, ((a.end / dt).ceil() as usize).min(cols));
        for c in c0..c1.max(c0 + 1).min(cols) {
            let (bs, be) = (c as f64 * dt, (c + 1) as f64 * dt);
            let overlap = (a.end.min(be) - a.start.max(bs)).max(0.0);
            if overlap > rows[a.proc][c].0 {
                rows[a.proc][c] = (overlap, g);
            }
        }
    }
    let name_w = machine.procs.iter().map(|p| p.name.len()).max().unwrap_or(4);
    for p in &machine.procs {
        let _ = writeln!(
            out,
            "{:>name_w$} |{}|",
            p.name,
            rows[p.id].iter().map(|&(_, g)| g).collect::<String>()
        );
    }
    let _ = writeln!(out, "{:>name_w$}  {}", "", format!("0s .. {:.3}s  (P=potrf T=trsm S=syrk G=gemm .=idle)", sched.makespan));
    out
}

/// Write the full trace bundle `<stem>.prv/.pcf/.row` plus the CSVs
/// (schedule, load, and the raw event log).
pub fn write_bundle(dir: &std::path::Path, stem: &str, dag: &TaskDag, sched: &Schedule, machine: &Machine) -> std::io::Result<()> {
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join(format!("{stem}.prv")), paraver_prv(dag, sched, machine))?;
    std::fs::write(dir.join(format!("{stem}.pcf")), paraver_pcf())?;
    std::fs::write(dir.join(format!("{stem}.row")), paraver_row(machine))?;
    std::fs::write(dir.join(format!("{stem}_schedule.csv")), schedule_csv(dag, sched, machine))?;
    std::fs::write(dir.join(format!("{stem}_load.csv")), load_trace_csv(sched, 200))?;
    std::fs::write(dir.join(format!("{stem}_events.csv")), event_log_csv(sched))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{simulate, SimConfig};
    use crate::coordinator::partitioners::cholesky;
    use crate::coordinator::perfmodel::{PerfCurve, PerfDb};
    use crate::coordinator::platform::MachineBuilder;
    use crate::coordinator::policies::{Ordering, ProcSelect, SchedConfig};

    fn setup() -> (crate::coordinator::platform::Machine, PerfDb, TaskDag, Schedule) {
        let mut b = MachineBuilder::new("m");
        let h = b.space("host", u64::MAX);
        b.main(h);
        let t = b.proc_type("cpu", 1.0, 0.1);
        b.processors(2, "c", t, h);
        let m = b.build();
        let mut db = PerfDb::new();
        db.set_fallback(0, PerfCurve::Const { gflops: 5.0 });
        let mut dag = cholesky::root(256);
        cholesky::partition_uniform(&mut dag, 64);
        let s = simulate(&dag, &m, &db, SimConfig::new(SchedConfig::new(Ordering::Fcfs, ProcSelect::EarliestIdle)));
        (m, db, dag, s)
    }

    #[test]
    fn csv_traces_have_rows() {
        let (m, _, dag, s) = setup();
        let csv = schedule_csv(&dag, &s, &m);
        assert_eq!(csv.lines().count(), 1 + dag.frontier().len());
        assert!(csv.contains("potrf"));
        let load = load_trace_csv(&s, 10);
        assert_eq!(load.lines().count(), 11);
    }

    #[test]
    fn prv_header_and_records() {
        let (m, _, dag, s) = setup();
        let prv = paraver_prv(&dag, &s, &m);
        assert!(prv.starts_with("#Paraver"));
        assert!(prv.contains(":1(2):1:2("));
        let state_recs = prv.lines().filter(|l| l.starts_with("1:")).count();
        assert_eq!(state_recs, dag.frontier().len());
    }

    #[test]
    fn pcf_names_all_kinds() {
        let pcf = paraver_pcf();
        for n in ["POTRF", "TRSM", "SYRK", "GEMM", "GEQRT"] {
            assert!(pcf.contains(n), "{n}");
        }
    }

    #[test]
    fn ascii_gantt_renders_rows() {
        let (m, _, dag, s) = setup();
        let g = ascii_gantt(&dag, &s, &m, 40);
        assert_eq!(g.lines().count(), 3, "2 procs + legend");
        assert!(g.contains('P') && g.contains('|'));
        // idle appears somewhere (cholesky tail)
        assert!(g.contains('.'));
    }

    #[test]
    fn ascii_gantt_empty_schedule() {
        let (m, _, dag, _) = setup();
        let empty = Schedule::default();
        assert!(ascii_gantt(&dag, &empty, &m, 10).is_empty());
    }

    #[test]
    fn bundle_writes_six_files() {
        let (m, _, dag, s) = setup();
        let dir = std::env::temp_dir().join("hesp_trace_test");
        let _ = std::fs::remove_dir_all(&dir);
        write_bundle(&dir, "t", &dag, &s, &m).unwrap();
        for f in ["t.prv", "t.pcf", "t.row", "t_schedule.csv", "t_load.csv", "t_events.csv"] {
            assert!(dir.join(f).exists(), "{f}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn event_log_csv_mirrors_the_event_queue() {
        let (_, _, dag, s) = setup();
        let csv = event_log_csv(&s);
        // header + one row per logged event
        assert_eq!(csv.lines().count(), 1 + s.events.len());
        assert!(csv.starts_with("time_s,event,task,proc,from,to,bytes"));
        let n = dag.frontier().len();
        assert_eq!(csv.matches(",task_start,").count(), n);
        assert_eq!(csv.matches(",task_end,").count(), n);
        // time column is non-decreasing (the queue pops in time order)
        let mut last = -1.0f64;
        for line in csv.lines().skip(1) {
            let t: f64 = line.split(',').next().unwrap().parse().unwrap();
            assert!(t >= last - 1e-15, "{line}");
            last = t;
        }
    }
}
