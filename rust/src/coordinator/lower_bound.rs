//! Makespan lower bounds: the classical critical-path / area argument.
//!
//! Any schedule must (a) execute the longest dependence chain serially,
//! even with every task on its best processor, and (b) fit the total
//! best-case work onto `n` processors. The larger of the two is a valid
//! lower bound on the makespan of *any* schedule of the frontier — it
//! ignores transfer costs and processor-type contention, so it is
//! optimistic, which is exactly what a bound must be. The sweep harness
//! reports `makespan / lb` per cell, and the service layer uses the
//! per-job bound both as a slowdown denominator and to resolve relative
//! deadlines (`deadline = arrival + slack * lb`).

use super::perfmodel::PerfDb;
use super::platform::Machine;
use super::taskdag::{FlatDag, TaskDag};

/// Best-case (min over processor types) execution time of each frontier
/// task. The sibling of [`super::ordering::avg_times`], with `min` where
/// the priority-list heuristic averages.
pub fn min_times(dag: &TaskDag, flat: &FlatDag, machine: &Machine, db: &PerfDb) -> Vec<f64> {
    let mut ptypes: Vec<usize> = machine.procs.iter().map(|p| p.ptype).collect();
    ptypes.sort_unstable();
    ptypes.dedup();
    flat.tasks
        .iter()
        .map(|&tid| {
            let t = dag.task(tid);
            ptypes
                .iter()
                .map(|&ty| db.time(ty, t.kind, t.char_edge(), t.flops))
                .fold(f64::INFINITY, f64::min)
        })
        .collect()
}

/// `max(critical-path bound, area bound)` over the frontier:
///
/// * critical path — backflow of min-times along dependence chains
///   (program order is topological, one reverse sweep suffices);
/// * area — total min-time work spread perfectly over all processors.
///
/// An empty frontier bounds trivially at 0.
pub fn makespan_lower_bound(dag: &TaskDag, flat: &FlatDag, machine: &Machine, db: &PerfDb) -> f64 {
    if flat.is_empty() {
        return 0.0;
    }
    let mt = min_times(dag, flat, machine, db);
    let mut cp = vec![0.0f64; flat.len()];
    for i in (0..flat.len()).rev() {
        let down = flat.succs[i].iter().map(|&s| cp[s]).fold(0.0f64, f64::max);
        cp[i] = mt[i] + down;
    }
    let cp_bound = cp.iter().fold(0.0f64, |a, &b| a.max(b));
    let area_bound = mt.iter().sum::<f64>() / machine.procs.len().max(1) as f64;
    cp_bound.max(area_bound)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{simulate, SimConfig};
    use crate::coordinator::perfmodel::PerfCurve;
    use crate::coordinator::platform::MachineBuilder;
    use crate::coordinator::policies::{Ordering, ProcSelect, SchedConfig};
    use crate::coordinator::region::Region;
    use crate::coordinator::task::{TaskKind, TaskSpec};

    fn machine_two_types() -> Machine {
        let mut b = MachineBuilder::new("m");
        let h = b.space("host", u64::MAX);
        b.main(h);
        let slow = b.proc_type("slow", 1.0, 0.1);
        let fast = b.proc_type("fast", 1.0, 0.1);
        b.processors(1, "s", slow, h);
        b.processors(1, "f", fast, h);
        b.build()
    }

    fn db() -> PerfDb {
        let mut db = PerfDb::new();
        db.set_fallback(0, PerfCurve::Const { gflops: 1.0 });
        db.set_fallback(1, PerfCurve::Const { gflops: 3.0 });
        db
    }

    #[test]
    fn chain_is_bound_by_critical_path() {
        // t0 -> t1 -> t2 over the same region; 2e6 flops each, best rate
        // 3 GFLOPS. CP = 3 * 2e-3/3 = 2e-3 beats area = 3 * (2e-3/3) / 2.
        let r = Region::new(0, 0, 100, 0, 100);
        let mut dag = TaskDag::new(TaskSpec::new(TaskKind::Potrf, vec![r], vec![r]));
        dag.partition(0, vec![TaskSpec::new(TaskKind::Gemm, vec![r], vec![r]); 3], 100);
        let flat = dag.flat_dag();
        let lb = makespan_lower_bound(&dag, &flat, &machine_two_types(), &db());
        assert!((lb - 2e-3).abs() < 1e-12, "{lb}");
    }

    #[test]
    fn independent_tasks_are_bound_by_area() {
        // 4 independent 2e6-flop tasks on disjoint regions, 2 processors:
        // CP = 2e-3/3 (one task), area = 4 * (2e-3/3) / 2 wins.
        let w = Region::new(0, 0, 400, 0, 400);
        let mut dag = TaskDag::new(TaskSpec::new(TaskKind::Potrf, vec![w], vec![w]));
        let specs: Vec<TaskSpec> = (0..4)
            .map(|i| {
                let r = Region::new(0, 100 * i, 100 * (i + 1), 0, 100);
                TaskSpec::new(TaskKind::Gemm, vec![r], vec![r])
            })
            .collect();
        dag.partition(0, specs, 100);
        let flat = dag.flat_dag();
        assert!(flat.preds.iter().all(|p| p.is_empty()), "tasks must be independent");
        let lb = makespan_lower_bound(&dag, &flat, &machine_two_types(), &db());
        assert!((lb - 4.0 * (2e-3 / 3.0) / 2.0).abs() < 1e-12, "{lb}");
    }

    #[test]
    fn bound_never_exceeds_simulated_makespan() {
        let r = Region::new(0, 0, 100, 0, 100);
        let mut dag = TaskDag::new(TaskSpec::new(TaskKind::Potrf, vec![r], vec![r]));
        dag.partition(0, vec![TaskSpec::new(TaskKind::Gemm, vec![r], vec![r]); 5], 100);
        let flat = dag.flat_dag();
        let m = machine_two_types();
        let d = db();
        let lb = makespan_lower_bound(&dag, &flat, &m, &d);
        let cfg = SimConfig::new(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish));
        let sched = simulate(&dag, &m, &d, cfg);
        assert!(lb > 0.0);
        assert!(lb <= sched.makespan + 1e-12, "lb {lb} vs makespan {}", sched.makespan);
    }

    #[test]
    fn single_task_frontier_bounds_positive() {
        let r = Region::new(0, 0, 8, 0, 8);
        let dag = TaskDag::new(TaskSpec::new(TaskKind::Potrf, vec![r], vec![r]));
        let flat = dag.flat_dag();
        // a lone root is a 1-task frontier; the bound must still be positive
        assert!(makespan_lower_bound(&dag, &flat, &machine_two_types(), &db()) > 0.0);
    }

    #[test]
    fn empty_frontier_bounds_at_zero() {
        // the genuinely-empty case: no frontier tasks at all (the old test
        // of this name built a lone root, which is a 1-task frontier and
        // never reached the is_empty branch)
        let r = Region::new(0, 0, 8, 0, 8);
        let dag = TaskDag::new(TaskSpec::new(TaskKind::Potrf, vec![r], vec![r]));
        let flat = FlatDag { tasks: Vec::new(), preds: Vec::new(), succs: Vec::new() };
        assert!(flat.is_empty());
        assert_eq!(makespan_lower_bound(&dag, &flat, &machine_two_types(), &db()), 0.0);
    }
}
