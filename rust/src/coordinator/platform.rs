//! Heterogeneous platform descriptions: memory spaces connected by an
//! interconnect topology, with a (possibly heterogeneous) set of
//! processors tied to them (paper §2: the "hardware platform description"
//! input) — plus the [`Timeline`] booking primitive the event-driven
//! engine uses to model per-processor and per-link occupancy as *bookable
//! intervals* instead of scalar high-water marks.

use super::coherence::SpaceId;

pub type ProcId = usize;
pub type ProcTypeId = usize;
pub type LinkId = usize;

/// A bookable occupancy timeline for one resource (a processor or an
/// interconnect link): a sorted list of disjoint busy intervals
/// `[start, end)`.
///
/// Unlike the scalar availability the engine used to keep (`proc_avail`,
/// `link_busy` high-water marks), a timeline remembers *gaps*: a transfer
/// decided later in simulated time can still occupy an idle link window
/// that an earlier decision left open (`earliest_fit` + `book`), and a
/// task can slot into a processor's idle window before work that was
/// booked further in the future. The estimate paths
/// ([`super::policy::plan_reads`], `SchedContext::placement_estimates`)
/// and the engine's commit path share exactly this arithmetic, so
/// policy-visible predictions match what gets simulated.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    /// Sorted, disjoint busy intervals `(start, end)` with `start < end`.
    busy: Vec<(f64, f64)>,
}

impl Timeline {
    pub fn new() -> Timeline {
        Timeline { busy: Vec::new() }
    }

    /// End of the last booked interval — the legacy "high-water mark"
    /// (0.0 when nothing is booked). After this instant the resource is
    /// free forever.
    pub fn tail(&self) -> f64 {
        self.busy.last().map(|&(_, e)| e).unwrap_or(0.0)
    }

    /// Earliest `start >= ready` such that `[start, start + dur)` lies
    /// entirely in free time. This is the gap-backfill query: it returns
    /// the start of the first idle window at or after `ready` wide enough
    /// for `dur`, falling back to the tail.
    pub fn earliest_fit(&self, ready: f64, dur: f64) -> f64 {
        let mut t = ready;
        // first interval that ends after `t` — everything before is past
        let start_idx = self.busy.partition_point(|&(_, e)| e <= t);
        for &(s, e) in &self.busy[start_idx..] {
            if t + dur <= s {
                break; // fits in the gap before this interval
            }
            t = t.max(e);
        }
        t
    }

    /// Book `[start, start + dur)`. The window must be free (callers
    /// obtain `start` from [`Timeline::earliest_fit`]); zero-duration
    /// bookings are no-ops. Adjacent intervals are merged so the list
    /// stays compact.
    pub fn book(&mut self, start: f64, dur: f64) {
        if dur <= 0.0 {
            return;
        }
        let end = start + dur;
        let i = self.busy.partition_point(|&(s, _)| s < start);
        debug_assert!(i == 0 || self.busy[i - 1].1 <= start, "booking overlaps previous interval");
        debug_assert!(i == self.busy.len() || end <= self.busy[i].0, "booking overlaps next interval");
        let merge_prev = i > 0 && self.busy[i - 1].1 == start;
        let merge_next = i < self.busy.len() && self.busy[i].0 == end;
        match (merge_prev, merge_next) {
            (true, true) => {
                self.busy[i - 1].1 = self.busy[i].1;
                self.busy.remove(i);
            }
            (true, false) => self.busy[i - 1].1 = end,
            (false, true) => self.busy[i].0 = start,
            (false, false) => self.busy.insert(i, (start, end)),
        }
    }

    /// Remove the booked sub-range `[from, to)` — fault cancellation of
    /// work that will never run (a processor died). The range must lie
    /// entirely inside one existing busy interval (bookings merge on
    /// contact, so a killed attempt's window is always covered by a
    /// single interval even when it was booked back-to-back with
    /// neighbours). Shrinks, splits, or removes the covering interval.
    pub fn unbook(&mut self, from: f64, to: f64) {
        if to <= from {
            return;
        }
        // first interval that ends after `from` is the covering one
        let i = self.busy.partition_point(|&(_, e)| e <= from);
        debug_assert!(
            i < self.busy.len() && self.busy[i].0 <= from && to <= self.busy[i].1,
            "unbook range [{from}, {to}) not inside one booked interval"
        );
        let (s, e) = self.busy[i];
        match (s < from, to < e) {
            (true, true) => {
                self.busy[i].1 = from;
                self.busy.insert(i + 1, (to, e));
            }
            (true, false) => self.busy[i].1 = from,
            (false, true) => self.busy[i].0 = to,
            (false, false) => {
                self.busy.remove(i);
            }
        }
    }

    /// Whether the resource has booked work strictly after time `t`
    /// (an idle-from-`t` test; the event core emits `ProcIdle` with it).
    pub fn busy_after(&self, t: f64) -> bool {
        self.tail() > t
    }

    /// The booked intervals, sorted and disjoint (diagnostics/tests).
    pub fn intervals(&self) -> &[(f64, f64)] {
        &self.busy
    }

    /// Total booked seconds.
    pub fn booked(&self) -> f64 {
        self.busy.iter().map(|&(s, e)| e - s).sum()
    }

    /// Forget every booking but keep the interval storage — the scratch-
    /// arena reuse path clears timelines between simulations instead of
    /// reallocating them.
    pub fn reset(&mut self) {
        self.busy.clear();
    }

    /// Whether nothing is booked (the scratch arena asserts this after
    /// [`Timeline::reset`] so a stale interval can never leak into the
    /// next simulation).
    pub fn is_clear(&self) -> bool {
        self.busy.is_empty()
    }
}

/// A finite-size memory space (host DRAM, one GPU's device memory, ...).
#[derive(Debug, Clone)]
pub struct MemSpace {
    pub id: SpaceId,
    pub name: String,
    /// Capacity in bytes (`u64::MAX` = effectively unbounded).
    pub capacity: u64,
}

/// A directed interconnect link between two memory spaces.
#[derive(Debug, Clone)]
pub struct Link {
    pub id: LinkId,
    pub from: SpaceId,
    pub to: SpaceId,
    /// Fixed per-transfer latency in seconds.
    pub latency: f64,
    /// Bandwidth in bytes/second.
    pub bandwidth: f64,
}

/// A processor class sharing one performance model (e.g. "xeon", "gtx980",
/// "a7", "a15").
#[derive(Debug, Clone)]
pub struct ProcType {
    pub id: ProcTypeId,
    pub name: String,
    /// Busy/idle power draw in watts (energy objective, paper §2).
    pub busy_watts: f64,
    pub idle_watts: f64,
}

/// One processor instance.
#[derive(Debug, Clone)]
pub struct Processor {
    pub id: ProcId,
    pub name: String,
    pub ptype: ProcTypeId,
    /// Memory space this processor computes from.
    pub space: SpaceId,
}

/// The machine: spaces + links + processors.
#[derive(Debug, Clone)]
pub struct Machine {
    pub name: String,
    pub spaces: Vec<MemSpace>,
    pub links: Vec<Link>,
    pub proc_types: Vec<ProcType>,
    pub procs: Vec<Processor>,
    /// The main memory space accelerator memories cache (paper §2.1).
    pub main_space: SpaceId,
}

impl Machine {
    /// Collect *all* internal-consistency problems as `(key, message)`
    /// pairs, where `key` points at the offending config entity
    /// (`memory.<name>`, `link.<id>`, `processor.<name>`, `main_space`).
    /// This is the static-analysis hook behind `hesp check`; it never
    /// runs a simulation and never panics.
    pub fn diagnostics(&self) -> Vec<(String, String)> {
        let mut out = Vec::new();
        if self.spaces.is_empty() {
            out.push(("machine".to_string(), "machine has no memory spaces".to_string()));
        }
        if self.procs.is_empty() {
            out.push(("machine".to_string(), "machine has no processors".to_string()));
        }
        if !self.spaces.is_empty() && self.main_space >= self.spaces.len() {
            out.push(("main_space".to_string(), format!("main_space {} out of range", self.main_space)));
        }
        for (i, s) in self.spaces.iter().enumerate() {
            if s.id != i {
                out.push((format!("memory.{}", s.name), format!("space {i} has id {}", s.id)));
            }
        }
        for (i, p) in self.procs.iter().enumerate() {
            if p.id != i {
                out.push((format!("processor.{}", p.name), format!("proc {i} has id {}", p.id)));
            }
            if p.space >= self.spaces.len() {
                out.push((format!("processor.{}", p.name), format!("proc {} in unknown space {}", p.name, p.space)));
            }
            if p.ptype >= self.proc_types.len() {
                out.push((format!("processor.{}", p.name), format!("proc {} of unknown type {}", p.name, p.ptype)));
            }
        }
        for l in &self.links {
            if l.from >= self.spaces.len() || l.to >= self.spaces.len() {
                out.push((format!("link.{}", l.id), format!("link {} connects unknown spaces", l.id)));
                continue;
            }
            if l.from == l.to {
                out.push((format!("link.{}", l.id), format!("link {} is a self-loop on space {}", l.id, l.from)));
            }
            if l.bandwidth <= 0.0 {
                out.push((format!("link.{}", l.id), format!("link {} has non-positive bandwidth", l.id)));
            }
        }
        // every non-main space must reach main (directly) for staging
        if self.main_space < self.spaces.len() {
            for s in &self.spaces {
                if s.id != self.main_space {
                    let up = self.links.iter().any(|l| l.from == s.id && l.to == self.main_space);
                    let down = self.links.iter().any(|l| l.from == self.main_space && l.to == s.id);
                    if !up || !down {
                        out.push((
                            format!("memory.{}", s.name),
                            format!("space {} lacks links to/from main: machine is disconnected", s.name),
                        ));
                    }
                }
            }
        }
        out
    }

    /// Validate internal consistency; returns a human-readable error with
    /// one line per problem found by [`Machine::diagnostics`].
    pub fn validate(&self) -> Result<(), String> {
        let diags = self.diagnostics();
        if diags.is_empty() {
            Ok(())
        } else {
            Err(diags.iter().map(|(k, m)| format!("{k}: {m}")).collect::<Vec<_>>().join("\n"))
        }
    }

    /// Direct link between two spaces, if any.
    pub fn link_between(&self, from: SpaceId, to: SpaceId) -> Option<&Link> {
        self.links.iter().find(|l| l.from == from && l.to == to)
    }

    /// Transfer route `from -> to`: the direct link, or a two-hop staging
    /// through main memory (the common PCIe topology where GPU<->GPU moves
    /// bounce through the host).
    ///
    /// A same-space "route" is explicitly empty — data is already local
    /// and the engine treats it as a no-op, never a free transfer.
    /// *Distinct* spaces with no connecting links are a hard error: a
    /// disconnected machine cannot silently simulate instantaneous
    /// transfers (the old engine pushed `TransferRecord`s with
    /// `start = inf` in that case).
    pub fn route(&self, from: SpaceId, to: SpaceId) -> Vec<LinkId> {
        if from == to {
            return Vec::new();
        }
        if let Some(l) = self.link_between(from, to) {
            return vec![l.id];
        }
        let up = self.link_between(from, self.main_space);
        let down = self.link_between(self.main_space, to);
        match (up, down) {
            (Some(a), Some(b)) if from != self.main_space && to != self.main_space => {
                vec![a.id, b.id]
            }
            _ => panic!(
                "no route between distinct spaces {from} ({}) and {to} ({}): machine '{}' is disconnected",
                self.spaces[from].name, self.spaces[to].name, self.name
            ),
        }
    }

    /// Pure transfer time (seconds) of `bytes` along the route, ignoring
    /// link contention (the engine adds queuing on top).
    pub fn transfer_time(&self, from: SpaceId, to: SpaceId, bytes: u64) -> f64 {
        self.route(from, to)
            .iter()
            .map(|&lid| {
                let l = &self.links[lid];
                l.latency + bytes as f64 / l.bandwidth
            })
            .sum()
    }

    /// Memory-space capacities indexed by space id (coherence input).
    pub fn capacities(&self) -> Vec<u64> {
        self.spaces.iter().map(|s| s.capacity).collect()
    }

    pub fn n_procs(&self) -> usize {
        self.procs.len()
    }

    pub fn proc_type(&self, p: ProcId) -> &ProcType {
        &self.proc_types[self.procs[p].ptype]
    }

    /// Processors grouped by type id (diagnostics / traces).
    pub fn procs_of_type(&self, t: ProcTypeId) -> Vec<ProcId> {
        self.procs.iter().filter(|p| p.ptype == t).map(|p| p.id).collect()
    }
}

/// Convenience builder used by tests and synthetic experiments.
#[derive(Debug, Default)]
pub struct MachineBuilder {
    name: String,
    spaces: Vec<MemSpace>,
    links: Vec<Link>,
    proc_types: Vec<ProcType>,
    procs: Vec<Processor>,
    main_space: SpaceId,
}

impl MachineBuilder {
    pub fn new(name: &str) -> MachineBuilder {
        MachineBuilder { name: name.to_string(), ..Default::default() }
    }

    pub fn space(&mut self, name: &str, capacity: u64) -> SpaceId {
        let id = self.spaces.len();
        self.spaces.push(MemSpace { id, name: name.to_string(), capacity });
        id
    }

    pub fn main(&mut self, s: SpaceId) -> &mut Self {
        self.main_space = s;
        self
    }

    /// Add a symmetric pair of links.
    pub fn connect(&mut self, a: SpaceId, b: SpaceId, latency: f64, bandwidth: f64) -> &mut Self {
        for (f, t) in [(a, b), (b, a)] {
            let id = self.links.len();
            self.links.push(Link { id, from: f, to: t, latency, bandwidth });
        }
        self
    }

    pub fn proc_type(&mut self, name: &str, busy_watts: f64, idle_watts: f64) -> ProcTypeId {
        let id = self.proc_types.len();
        self.proc_types.push(ProcType { id, name: name.to_string(), busy_watts, idle_watts });
        id
    }

    pub fn processors(&mut self, count: usize, prefix: &str, ptype: ProcTypeId, space: SpaceId) -> &mut Self {
        for i in 0..count {
            let id = self.procs.len();
            self.procs.push(Processor { id, name: format!("{prefix}{i}"), ptype, space });
        }
        self
    }

    pub fn build(self) -> Machine {
        let m = Machine {
            name: self.name,
            spaces: self.spaces,
            links: self.links,
            proc_types: self.proc_types,
            procs: self.procs,
            main_space: self.main_space,
        };
        if let Err(e) = m.validate() {
            panic!("invalid machine: {e}");
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// host + 2 GPUs over PCIe-ish links.
    pub fn toy_gpu_machine() -> Machine {
        let mut b = MachineBuilder::new("toy");
        let host = b.space("host", u64::MAX);
        let g0 = b.space("gpu0_mem", 4 << 30);
        let g1 = b.space("gpu1_mem", 4 << 30);
        b.main(host);
        b.connect(host, g0, 10e-6, 12e9);
        b.connect(host, g1, 10e-6, 12e9);
        let cpu = b.proc_type("cpu", 20.0, 5.0);
        let gpu = b.proc_type("gpu", 180.0, 30.0);
        b.processors(4, "cpu", cpu, host);
        b.processors(2, "gpu", gpu, g0); // gpu0 in g0
        // rebind second gpu to its own space
        let mut m = b.build();
        m.procs[5].space = g1;
        m
    }

    #[test]
    fn builder_produces_valid_machine() {
        let m = toy_gpu_machine();
        assert!(m.validate().is_ok());
        assert_eq!(m.n_procs(), 6);
        assert_eq!(m.procs_of_type(0).len(), 4);
        assert_eq!(m.procs_of_type(1).len(), 2);
    }

    #[test]
    fn direct_route_and_time() {
        let m = toy_gpu_machine();
        let r = m.route(0, 1);
        assert_eq!(r.len(), 1);
        // 12 MB over 12 GB/s + 10us latency
        let t = m.transfer_time(0, 1, 12_000_000);
        assert!((t - (10e-6 + 1e-3)).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn two_hop_route_via_main() {
        let m = toy_gpu_machine();
        let r = m.route(1, 2);
        assert_eq!(r.len(), 2);
        let t = m.transfer_time(1, 2, 12_000_000);
        assert!((t - 2.0 * (10e-6 + 1e-3)).abs() < 1e-9);
    }

    #[test]
    fn same_space_is_free() {
        let m = toy_gpu_machine();
        assert!(m.route(1, 1).is_empty());
        assert_eq!(m.transfer_time(1, 1, 1 << 20), 0.0);
    }

    #[test]
    #[should_panic(expected = "disconnected")]
    fn route_between_disconnected_spaces_is_a_hard_error() {
        // hand-built (unvalidated) machine: two spaces, zero links
        let m = Machine {
            name: "island".into(),
            spaces: vec![
                MemSpace { id: 0, name: "a".into(), capacity: u64::MAX },
                MemSpace { id: 1, name: "b".into(), capacity: u64::MAX },
            ],
            links: vec![],
            proc_types: vec![ProcType { id: 0, name: "cpu".into(), busy_watts: 1.0, idle_watts: 0.1 }],
            procs: vec![Processor { id: 0, name: "c0".into(), ptype: 0, space: 0 }],
            main_space: 0,
        };
        let _ = m.route(0, 1);
    }

    #[test]
    fn validate_rejects_self_loop_links() {
        let mut b = MachineBuilder::new("loopy");
        let h = b.space("host", u64::MAX);
        b.main(h);
        let t = b.proc_type("cpu", 1.0, 0.1);
        b.processors(1, "c", t, h);
        let mut m = b.build();
        m.links.push(Link { id: 0, from: h, to: h, latency: 1e-6, bandwidth: 1e9 });
        assert!(m.validate().unwrap_err().contains("self-loop"));
    }

    #[test]
    fn timeline_books_and_backfills_gaps() {
        let mut tl = Timeline::new();
        assert_eq!(tl.tail(), 0.0);
        assert_eq!(tl.earliest_fit(3.0, 2.0), 3.0, "empty timeline starts at ready");
        tl.book(5.0, 5.0); // busy [5,10)
        assert_eq!(tl.tail(), 10.0);
        // a 2s job at ready=1 fits the [_,5) gap
        assert_eq!(tl.earliest_fit(1.0, 2.0), 1.0);
        // a 6s job does not: it goes after the tail
        assert_eq!(tl.earliest_fit(1.0, 6.0), 10.0);
        // book into the gap, then the remaining gap shrinks
        tl.book(1.0, 2.0); // busy [1,3) [5,10)
        assert_eq!(tl.earliest_fit(0.0, 2.0), 3.0, "only [3,5) is left before the tail");
        assert_eq!(tl.earliest_fit(4.0, 2.0), 10.0, "from 4.0 the [4,5) remnant is too small");
        assert_eq!(tl.intervals().len(), 2);
        assert!((tl.booked() - 7.0).abs() < 1e-12);
    }

    #[test]
    fn timeline_merges_adjacent_bookings() {
        let mut tl = Timeline::new();
        tl.book(0.0, 1.0);
        tl.book(2.0, 1.0);
        tl.book(1.0, 1.0); // bridges the two
        assert_eq!(tl.intervals(), &[(0.0, 3.0)][..]);
        tl.book(3.0, 1.0); // extends the tail in place
        assert_eq!(tl.intervals(), &[(0.0, 4.0)][..]);
        assert!(!tl.busy_after(4.0));
        assert!(tl.busy_after(3.5));
        // zero-duration bookings are no-ops
        tl.book(10.0, 0.0);
        assert_eq!(tl.intervals(), &[(0.0, 4.0)][..]);
    }

    #[test]
    fn timeline_unbook_shrinks_splits_and_removes() {
        let mut tl = Timeline::new();
        tl.book(0.0, 10.0); // busy [0,10)
        tl.unbook(4.0, 6.0); // split
        assert_eq!(tl.intervals(), &[(0.0, 4.0), (6.0, 10.0)][..]);
        tl.unbook(0.0, 2.0); // shrink from the left
        assert_eq!(tl.intervals(), &[(2.0, 4.0), (6.0, 10.0)][..]);
        tl.unbook(8.0, 10.0); // shrink from the right
        assert_eq!(tl.intervals(), &[(2.0, 4.0), (6.0, 8.0)][..]);
        tl.unbook(2.0, 4.0); // remove whole interval
        assert_eq!(tl.intervals(), &[(6.0, 8.0)][..]);
        // zero-width is a no-op
        tl.unbook(7.0, 7.0);
        assert_eq!(tl.intervals(), &[(6.0, 8.0)][..]);
        // the freed window is bookable again
        assert_eq!(tl.earliest_fit(0.0, 5.0), 8.0);
        assert_eq!(tl.earliest_fit(0.0, 4.0), 0.0);
        tl.book(0.0, 4.0);
        assert!((tl.booked() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn timeline_unbook_inverts_merged_bookings() {
        // two back-to-back attempts merge into one interval; cancelling
        // the second must recover exactly the first
        let mut tl = Timeline::new();
        tl.book(1.0, 2.0); // attempt A [1,3)
        tl.book(3.0, 2.0); // attempt B [3,5) — merges to [1,5)
        assert_eq!(tl.intervals(), &[(1.0, 5.0)][..]);
        tl.unbook(3.0, 5.0);
        assert_eq!(tl.intervals(), &[(1.0, 3.0)][..]);
        // partial cancellation of in-flight work keeps the executed prefix
        tl.unbook(2.0, 3.0);
        assert_eq!(tl.intervals(), &[(1.0, 2.0)][..]);
    }

    #[test]
    fn validate_rejects_orphan_space() {
        let mut b = MachineBuilder::new("bad");
        let h = b.space("host", u64::MAX);
        let g = b.space("gpu", 1 << 30);
        b.main(h);
        let t = b.proc_type("cpu", 1.0, 0.1);
        b.processors(1, "c", t, h);
        // no links to g
        let m = Machine {
            name: "bad".into(),
            spaces: b.spaces.clone(),
            links: vec![],
            proc_types: b.proc_types.clone(),
            procs: b.procs.clone(),
            main_space: h,
        };
        assert!(m.validate().is_err());
        let _ = g;
    }

    #[test]
    fn validate_rejects_empty() {
        let m = Machine {
            name: "empty".into(),
            spaces: vec![],
            links: vec![],
            proc_types: vec![],
            procs: vec![],
            main_space: 0,
        };
        assert!(m.validate().is_err());
    }
}
