//! Schedule/DAG metrics — the columns of Table 1 and the series of
//! Figs. 2b/5/6.

use super::engine::Schedule;
use super::taskdag::TaskDag;

/// A Table-1-style report for one (platform, config, partition) run.
#[derive(Debug, Clone)]
pub struct Report {
    pub makespan: f64,
    /// Useful throughput: frontier flops / makespan / 1e9.
    pub gflops: f64,
    /// Average processor load in percent.
    pub avg_load_pct: f64,
    /// Flops-weighted mean tile edge over frontier tasks (Table 1's
    /// "Avg. block size": weighting by work matches the paper's averages,
    /// which stay near the dominant-update grain).
    pub avg_block_size: f64,
    /// Max number of nested task clusters (Table 1's "DAG depth").
    pub dag_depth: u32,
    pub n_tasks: usize,
    pub transfer_bytes: u64,
    pub transfer_count: usize,
}

/// Compute the report for a simulated schedule of `dag`'s frontier.
pub fn report(dag: &TaskDag, sched: &Schedule) -> Report {
    let frontier = dag.frontier();
    let total_flops: f64 = frontier.iter().map(|&t| dag.task(t).flops).sum();
    let (mut wsum, mut w) = (0.0f64, 0.0f64);
    for &t in &frontier {
        let task = dag.task(t);
        wsum += task.flops * task.char_edge();
        w += task.flops;
    }
    Report {
        makespan: sched.makespan,
        gflops: if sched.makespan > 0.0 { total_flops / sched.makespan / 1e9 } else { 0.0 },
        avg_load_pct: sched.avg_load() * 100.0,
        avg_block_size: if w > 0.0 { wsum / w } else { 0.0 },
        dag_depth: dag.depth(),
        n_tasks: frontier.len(),
        transfer_bytes: sched.transfer_bytes,
        transfer_count: sched.transfers.len(),
    }
}

/// Discretized compute-load trace (Fig. 2b): number of busy processors at
/// `samples` evenly-spaced instants.
pub fn load_trace(sched: &Schedule, samples: usize) -> Vec<(f64, usize)> {
    if sched.makespan <= 0.0 || samples == 0 {
        return Vec::new();
    }
    // sweep-line over start/end events, sampled on the grid
    let mut events: Vec<(f64, i64)> = Vec::with_capacity(sched.assignments.len() * 2);
    for a in &sched.assignments {
        events.push((a.start, 1));
        events.push((a.end, -1));
    }
    events.sort_by(|a, b| a.0.total_cmp(&b.0));
    let dt = sched.makespan / samples as f64;
    let mut out = Vec::with_capacity(samples);
    let mut active = 0i64;
    let mut ei = 0usize;
    for k in 0..samples {
        let t = (k as f64 + 0.5) * dt;
        while ei < events.len() && events[ei].0 <= t {
            active += events[ei].1;
            ei += 1;
        }
        out.push((t, active.max(0) as usize));
    }
    out
}

/// Maximum number of simultaneously in-flight transfers, computed from
/// the typed event log (`TransferStart`/`TransferEnd`). A link-contention
/// diagnostic the old scalar accounting could not express: under
/// high-water-mark time the engine never knew *when* transfers
/// overlapped, only their queue tails.
pub fn peak_in_flight_transfers(sched: &Schedule) -> usize {
    use super::engine::EventKind;
    let (mut cur, mut peak) = (0usize, 0usize);
    for e in &sched.events {
        match e.kind {
            EventKind::TransferStart { .. } => {
                cur += 1;
                peak = peak.max(cur);
            }
            EventKind::TransferEnd { .. } => cur = cur.saturating_sub(1),
            _ => {}
        }
    }
    peak
}

/// Idle fraction during `[t0, t1)` given per-proc busy intervals — used by
/// the solver to estimate available parallelism around a task.
pub fn idle_procs_during(sched: &Schedule, n_procs: usize, t0: f64, t1: f64) -> usize {
    if t1 <= t0 {
        return 0;
    }
    let mut busy = vec![false; n_procs];
    for a in &sched.assignments {
        if a.start < t1 && t0 < a.end {
            busy[a.proc] = true;
        }
    }
    busy.iter().filter(|&&b| !b).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{simulate, SimConfig};
    use crate::coordinator::partitioners::cholesky;
    use crate::coordinator::perfmodel::{PerfCurve, PerfDb};
    use crate::coordinator::platform::{Machine, MachineBuilder};
    use crate::coordinator::policies::{Ordering, ProcSelect, SchedConfig};

    fn setup() -> (Machine, PerfDb) {
        let mut b = MachineBuilder::new("m");
        let h = b.space("host", u64::MAX);
        b.main(h);
        let t = b.proc_type("cpu", 1.0, 0.1);
        b.processors(4, "c", t, h);
        let m = b.build();
        let mut db = PerfDb::new();
        db.set_fallback(0, PerfCurve::Const { gflops: 10.0 });
        (m, db)
    }

    #[test]
    fn report_basics() {
        let (m, db) = setup();
        let mut dag = cholesky::root(512);
        cholesky::partition_uniform(&mut dag, 128);
        let s = simulate(&dag, &m, &db, SimConfig::new(SchedConfig::new(Ordering::Fcfs, ProcSelect::EarliestFinish)));
        let r = report(&dag, &s);
        assert!(r.makespan > 0.0);
        assert!((r.gflops - dag.total_flops() / r.makespan / 1e9).abs() < 1e-9);
        assert!(r.avg_load_pct > 0.0 && r.avg_load_pct <= 100.0);
        assert_eq!(r.avg_block_size, 128.0, "uniform tiling: all edges equal");
        assert_eq!(r.dag_depth, 1);
        assert_eq!(r.n_tasks, cholesky::task_count(4) as usize);
    }

    #[test]
    fn load_trace_bounds() {
        let (m, db) = setup();
        let mut dag = cholesky::root(512);
        cholesky::partition_uniform(&mut dag, 64);
        let s = simulate(&dag, &m, &db, SimConfig::new(SchedConfig::new(Ordering::Fcfs, ProcSelect::EarliestIdle)));
        let trace = load_trace(&s, 50);
        assert_eq!(trace.len(), 50);
        assert!(trace.iter().all(|&(_, a)| a <= 4));
        assert!(trace.iter().any(|&(_, a)| a > 0));
        // final stage of cholesky is sequential: last sample lightly loaded
        assert!(trace.last().unwrap().1 <= 2);
    }

    #[test]
    fn peak_in_flight_counts_transfer_overlap() {
        use crate::coordinator::engine::simulate_mapped;
        use crate::coordinator::region::Region;
        use crate::coordinator::task::{TaskKind, TaskSpec};
        use crate::coordinator::taskdag::TaskDag;
        // host + two GPU spaces over separate links
        let mut b = MachineBuilder::new("g2");
        let h = b.space("host", u64::MAX);
        let g0 = b.space("g0", u64::MAX);
        let g1 = b.space("g1", u64::MAX);
        b.main(h);
        b.connect(h, g0, 0.0, 1e8);
        b.connect(h, g1, 0.0, 1e8);
        let cpu = b.proc_type("cpu", 1.0, 0.1);
        let gpu = b.proc_type("gpu", 1.0, 0.1);
        b.processors(1, "c", cpu, h);
        b.processors(1, "a", gpu, g0);
        b.processors(1, "b", gpu, g1);
        let m = b.build();
        let mut db = PerfDb::new();
        db.set_fallback(0, PerfCurve::Const { gflops: 1.0 });
        db.set_fallback(1, PerfCurve::Const { gflops: 10.0 });
        // two independent tasks reading disjoint tiles
        let r0 = Region::new(0, 0, 100, 0, 100);
        let w0 = Region::new(0, 100, 200, 0, 100);
        let r1 = Region::new(0, 200, 300, 0, 100);
        let w1 = Region::new(0, 300, 400, 0, 100);
        let root = Region::new(0, 0, 400, 0, 100);
        let mut dag = TaskDag::new(TaskSpec::new(TaskKind::Potrf, vec![root], vec![root]));
        dag.partition(
            0,
            vec![
                TaskSpec::new(TaskKind::Gemm, vec![r0], vec![w0]),
                TaskSpec::new(TaskKind::Gemm, vec![r1], vec![w1]),
            ],
            100,
        );
        let sim = SimConfig::new(SchedConfig::new(Ordering::Fcfs, ProcSelect::EarliestIdle));
        // separate GPUs: both fetches run concurrently over their own links
        let spread = simulate_mapped(&dag, &m, &db, sim, &[1, 2]);
        assert_eq!(peak_in_flight_transfers(&spread), 2);
        // same GPU: the shared link serializes the fetches
        let packed = simulate_mapped(&dag, &m, &db, sim, &[1, 1]);
        assert_eq!(peak_in_flight_transfers(&packed), 1);
    }

    #[test]
    fn idle_procs_counted() {
        let (m, db) = setup();
        let mut dag = cholesky::root(256);
        cholesky::partition_uniform(&mut dag, 128); // s=2: mostly sequential
        let s = simulate(&dag, &m, &db, SimConfig::new(SchedConfig::new(Ordering::Fcfs, ProcSelect::EarliestIdle)));
        // during the first task only 1 of 4 procs is busy
        let a0 = &s.assignments[0];
        assert_eq!(idle_procs_during(&s, 4, a0.start, a0.end), 3);
        assert_eq!(idle_procs_during(&s, 4, 1.0, 1.0), 0, "empty interval");
    }
}
