//! Rectangular data-block regions.
//!
//! A data block in HeSP is a rectangular sub-region of a named matrix,
//! identified by half-open row/column ranges. All containment, overlap and
//! intersection relations of the data DAG (paper §2.1, Figs. 3–4) are
//! geometric predicates on these regions, which makes the coherence
//! machinery exact and property-testable.

/// Identifier of a top-level matrix (HeSP can schedule programs touching
/// several independent matrices).
pub type MatrixId = u32;

/// A rectangular region of a matrix: rows `[r0, r1)`, cols `[c0, c1)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Region {
    pub matrix: MatrixId,
    pub r0: u32,
    pub r1: u32,
    pub c0: u32,
    pub c1: u32,
}

impl Region {
    pub fn new(matrix: MatrixId, r0: u32, r1: u32, c0: u32, c1: u32) -> Region {
        debug_assert!(r0 < r1 && c0 < c1, "degenerate region {r0}..{r1} x {c0}..{c1}");
        Region { matrix, r0, r1, c0, c1 }
    }

    /// Square tile helper: rows/cols `[i*b, (i+1)*b) x [j*b, (j+1)*b)`
    /// offset by the region origin of `within`.
    pub fn tile(within: &Region, b: u32, i: u32, j: u32) -> Region {
        Region::new(
            within.matrix,
            within.r0 + i * b,
            within.r0 + (i + 1) * b,
            within.c0 + j * b,
            within.c0 + (j + 1) * b,
        )
    }

    pub fn rows(&self) -> u32 {
        self.r1 - self.r0
    }

    pub fn cols(&self) -> u32 {
        self.c1 - self.c0
    }

    /// Number of elements.
    pub fn area(&self) -> u64 {
        self.rows() as u64 * self.cols() as u64
    }

    /// Geometric mean edge — the "characteristic size d" used when choosing
    /// a partition parameter p with b = p * d (paper §2.1).
    pub fn char_size(&self) -> f64 {
        (self.rows() as f64 * self.cols() as f64).sqrt()
    }

    pub fn is_square(&self) -> bool {
        self.rows() == self.cols()
    }

    /// `self` fully contains `other` (non-strict).
    pub fn contains(&self, other: &Region) -> bool {
        self.matrix == other.matrix
            && self.r0 <= other.r0
            && other.r1 <= self.r1
            && self.c0 <= other.c0
            && other.c1 <= self.c1
    }

    /// Regions overlap in at least one element.
    pub fn intersects(&self, other: &Region) -> bool {
        self.matrix == other.matrix
            && self.r0 < other.r1
            && other.r0 < self.r1
            && self.c0 < other.c1
            && other.c0 < self.c1
    }

    /// The overlap region, if any. Partial overlaps become the extra data
    /// DAG descriptors of Fig. 4.
    pub fn intersection(&self, other: &Region) -> Option<Region> {
        if !self.intersects(other) {
            return None;
        }
        Some(Region::new(
            self.matrix,
            self.r0.max(other.r0),
            self.r1.min(other.r1),
            self.c0.max(other.c0),
            self.c1.min(other.c1),
        ))
    }

    /// Partial overlap: they intersect but neither contains the other.
    pub fn partially_overlaps(&self, other: &Region) -> bool {
        self.intersects(other) && !self.contains(other) && !other.contains(self)
    }
}

impl std::fmt::Display for Region {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "M{}[{}:{},{}:{}]", self.matrix, self.r0, self.r1, self.c0, self.c1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn r(r0: u32, r1: u32, c0: u32, c1: u32) -> Region {
        Region::new(0, r0, r1, c0, c1)
    }

    #[test]
    fn tile_indexing() {
        let root = r(0, 1024, 0, 1024);
        let t = Region::tile(&root, 256, 1, 3);
        assert_eq!(t, r(256, 512, 768, 1024));
        assert!(root.contains(&t));
    }

    #[test]
    fn tile_respects_origin() {
        let q2 = r(512, 1024, 0, 512);
        let t = Region::tile(&q2, 256, 0, 1);
        assert_eq!(t, r(512, 768, 256, 512));
    }

    #[test]
    fn containment() {
        let a = r(0, 100, 0, 100);
        let b = r(10, 50, 20, 60);
        assert!(a.contains(&b));
        assert!(!b.contains(&a));
        assert!(a.contains(&a));
    }

    #[test]
    fn different_matrices_never_relate() {
        let a = Region::new(0, 0, 10, 0, 10);
        let b = Region::new(1, 0, 10, 0, 10);
        assert!(!a.contains(&b));
        assert!(!a.intersects(&b));
        assert_eq!(a.intersection(&b), None);
    }

    #[test]
    fn intersection_geometry() {
        let a = r(0, 50, 0, 50);
        let b = r(25, 75, 25, 75);
        assert_eq!(a.intersection(&b), Some(r(25, 50, 25, 50)));
        assert!(a.partially_overlaps(&b));
        // adjacent (share an edge) regions do not intersect
        let c = r(50, 60, 0, 50);
        assert!(!a.intersects(&c));
    }

    #[test]
    fn partial_overlap_excludes_nesting() {
        let a = r(0, 100, 0, 100);
        let b = r(10, 20, 10, 20);
        assert!(a.intersects(&b));
        assert!(!a.partially_overlaps(&b));
    }

    #[test]
    fn fig4_two_tilings_intersect() {
        // Quadrant Q2 split by two tilings of non-divisible grains (3 vs 2):
        // a 2x2 tile at (0,0) of a 6x6 block vs a 3x3 tile — partial overlap.
        let yellow = r(0, 2, 0, 2);
        let blue = r(0, 3, 0, 3);
        assert!(blue.contains(&yellow)); // this pair nests...
        let yellow2 = r(2, 4, 2, 4);
        assert!(blue.partially_overlaps(&yellow2)); // ...this one does not
        assert_eq!(blue.intersection(&yellow2), Some(r(2, 3, 2, 3)));
    }

    #[test]
    fn area_and_char_size() {
        let a = r(0, 128, 0, 512);
        assert_eq!(a.area(), 65536);
        assert!((a.char_size() - 256.0).abs() < 1e-12);
        assert!(!a.is_square());
        assert!(r(0, 4, 0, 4).is_square());
    }
}
