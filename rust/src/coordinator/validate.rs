//! The schedule-invariant oracle: an independent checker that proves a
//! simulated [`Schedule`] is *physically realizable* on its [`Machine`],
//! without trusting any of the engine's own booking arithmetic.
//!
//! Checked invariants (each violation reported with context):
//!
//! 1. **Finite, ordered times** — every assignment and transfer has finite
//!    `0 <= release <= start <= end`; no NaN/inf anywhere.
//! 2. **Processor exclusivity** — no two assignments overlap on one
//!    processor.
//! 3. **Link exclusivity** — no two bookings overlap on one interconnect
//!    link (checked on the exact per-hop [`Schedule::link_occupancy`]
//!    records, not the route-spanning transfer records).
//! 4. **Dependences** — a task starts only after every predecessor's write
//!    effects have landed (`start >= pred.end` for every derived edge).
//! 5. **Arrival gate** — a task starts only after every input transfer
//!    booked for it has physically arrived (`start >= transfer.end` for
//!    every transfer with `dst_task == task`).
//! 6. **Makespan** — equals the max end over assignments and transfers,
//!    and no event in the log is stamped later; the event log is
//!    time-ordered and contains exactly one `TaskStart`/`TaskEnd` pair per
//!    assignment, at the assignment's own times.
//! 7. **Busy accounting** — per-processor busy seconds equal the summed
//!    assignment durations.
//! 8. **No stale records** — every task event references a task of *this*
//!    frontier on a processor of *this* machine, and `proc_busy` carries
//!    no extra entries. A recycled scratch `Schedule` whose reset was
//!    skipped would leak records from a previous run here.
//!
//! The portfolio solver runs this oracle on every accepted candidate
//! schedule in debug builds, and the sweep harness on every cell baseline;
//! `rust/tests/schedule_oracle.rs` drives it over randomized workloads for
//! every registry policy (CI also runs that suite under `--release`, so
//! optimized-build arithmetic goes through the same checks).

use super::engine::{EventKind, Schedule};
use super::faults::FaultPlan;
use super::platform::Machine;
use super::task::TaskId;
use super::taskdag::{FlatDag, TaskDag};
use crate::util::fxhash::FxHashMap;

/// Absolute slack for time comparisons. Simulated times are seconds built
/// from f64 sums/divisions; real overlaps in this codebase are whole
/// task/transfer durations (>= microseconds), ten orders above this.
const EPS: f64 = 1e-9;

/// Check every schedule invariant; `Err` carries one line per violation.
pub fn validate_schedule(
    dag: &TaskDag,
    flat: &FlatDag,
    machine: &Machine,
    sched: &Schedule,
) -> Result<(), String> {
    let mut errs: Vec<String> = Vec::new();
    let n = flat.len();

    // ---- shape: one assignment per frontier position, ids consistent ----
    if sched.assignments.len() != n {
        return Err(format!(
            "schedule has {} assignments for a {}-task frontier",
            sched.assignments.len(),
            n
        ));
    }
    for (pos, a) in sched.assignments.iter().enumerate() {
        if a.pos != pos {
            errs.push(format!("assignment at slot {pos} carries pos {}", a.pos));
        }
        if a.task != flat.tasks[pos] {
            errs.push(format!("assignment {pos} schedules task {} but the frontier holds {}", a.task, flat.tasks[pos]));
        }
        if a.proc >= machine.n_procs() {
            errs.push(format!("assignment {pos} placed on unknown processor {}", a.proc));
        }
        if !dag.is_live(a.task) {
            errs.push(format!("assignment {pos} schedules dead task {}", a.task));
        }
    }
    if !errs.is_empty() {
        return Err(errs.join("\n")); // later checks index by these fields
    }

    // ---- 1. finite, ordered times ----
    for a in &sched.assignments {
        let ok = a.release.is_finite() && a.start.is_finite() && a.end.is_finite();
        if !ok {
            errs.push(format!("task {} has non-finite times [{}, {}] release {}", a.task, a.start, a.end, a.release));
            continue;
        }
        if a.release < -EPS || a.start < a.release - EPS || a.end < a.start {
            errs.push(format!(
                "task {} violates 0 <= release <= start <= end: release {} start {} end {}",
                a.task, a.release, a.start, a.end
            ));
        }
    }
    for (i, t) in sched.transfers.iter().enumerate() {
        if !t.start.is_finite() || !t.end.is_finite() {
            errs.push(format!("transfer {i} ({} -> {}) has non-finite times", t.from, t.to));
        } else if t.start < -EPS || t.end < t.start {
            errs.push(format!("transfer {i} runs backwards: [{}, {}]", t.start, t.end));
        }
    }
    for &(lid, s, e) in &sched.link_occupancy {
        if !s.is_finite() || !e.is_finite() || e < s {
            errs.push(format!("link {lid} booking [{s}, {e}] is malformed"));
        }
    }
    if !errs.is_empty() {
        return Err(errs.join("\n")); // interval checks assume finite times
    }

    // ---- 2. processor exclusivity ----
    let mut per_proc: Vec<Vec<(f64, f64, TaskId)>> = vec![Vec::new(); machine.n_procs()];
    for a in &sched.assignments {
        per_proc[a.proc].push((a.start, a.end, a.task));
    }
    for (p, ivs) in per_proc.iter_mut().enumerate() {
        ivs.sort_by(|x, y| x.0.total_cmp(&y.0));
        for w in ivs.windows(2) {
            if w[0].1 > w[1].0 + EPS {
                errs.push(format!(
                    "processor {p}: tasks {} [{}, {}] and {} [{}, {}] overlap",
                    w[0].2, w[0].0, w[0].1, w[1].2, w[1].0, w[1].1
                ));
            }
        }
    }

    // ---- 3. link exclusivity ----
    let mut per_link: Vec<Vec<(f64, f64)>> = vec![Vec::new(); machine.links.len()];
    for &(lid, s, e) in &sched.link_occupancy {
        if lid >= per_link.len() {
            errs.push(format!("booking on unknown link {lid}"));
            continue;
        }
        per_link[lid].push((s, e));
    }
    for (l, ivs) in per_link.iter_mut().enumerate() {
        ivs.sort_by(|x, y| x.0.total_cmp(&y.0));
        for w in ivs.windows(2) {
            if w[0].1 > w[1].0 + EPS {
                errs.push(format!(
                    "link {l}: bookings [{}, {}] and [{}, {}] overlap",
                    w[0].0, w[0].1, w[1].0, w[1].1
                ));
            }
        }
    }

    // ---- 4. dependences ----
    for pos in 0..n {
        let a = &sched.assignments[pos];
        for &p in &flat.preds[pos] {
            let dep = &sched.assignments[p];
            if a.start < dep.end - EPS {
                errs.push(format!(
                    "task {} starts at {} before predecessor {} finishes at {}",
                    a.task, a.start, dep.task, dep.end
                ));
            }
        }
    }

    // ---- 5. arrival gate ----
    let pos_of: FxHashMap<TaskId, usize> =
        flat.tasks.iter().enumerate().map(|(i, &t)| (t, i)).collect();
    for (i, t) in sched.transfers.iter().enumerate() {
        let Some(tid) = t.dst_task else { continue };
        let Some(&pos) = pos_of.get(&tid) else {
            errs.push(format!("transfer {i} fetches input for unknown task {tid}"));
            continue;
        };
        let a = &sched.assignments[pos];
        if a.start < t.end - EPS {
            errs.push(format!(
                "task {tid} starts at {} before its input transfer {i} ({} -> {}) lands at {}",
                a.start, t.from, t.to, t.end
            ));
        }
    }

    // ---- 6. makespan + event log ----
    let task_end = sched.assignments.iter().map(|a| a.end).fold(0.0f64, f64::max);
    let xfer_end = sched.transfers.iter().map(|t| t.end).fold(0.0f64, f64::max);
    let expect = task_end.max(xfer_end);
    if !sched.makespan.is_finite() || (sched.makespan - expect).abs() > EPS {
        errs.push(format!("makespan {} != max event end {}", sched.makespan, expect));
    }
    for w in sched.events.windows(2) {
        if w[1].time < w[0].time - EPS {
            errs.push(format!("event log out of order: {} after {}", w[1].time, w[0].time));
            break;
        }
    }
    for e in &sched.events {
        if !e.time.is_finite() || e.time > sched.makespan + EPS {
            errs.push(format!("event {:?} at {} past the makespan {}", e.kind, e.time, sched.makespan));
        }
    }
    let mut starts: FxHashMap<(TaskId, usize), Vec<f64>> = FxHashMap::default();
    let mut ends: FxHashMap<(TaskId, usize), Vec<f64>> = FxHashMap::default();
    for e in &sched.events {
        match e.kind {
            EventKind::TaskStart { task, proc } => starts.entry((task, proc)).or_default().push(e.time),
            EventKind::TaskEnd { task, proc } => ends.entry((task, proc)).or_default().push(e.time),
            _ => {}
        }
    }
    for a in &sched.assignments {
        let s_ok = starts
            .get(&(a.task, a.proc))
            .map_or(0, |v| v.iter().filter(|&&t| (t - a.start).abs() <= EPS).count());
        let e_ok = ends
            .get(&(a.task, a.proc))
            .map_or(0, |v| v.iter().filter(|&&t| (t - a.end).abs() <= EPS).count());
        if s_ok != 1 || e_ok != 1 {
            errs.push(format!(
                "task {} has {s_ok} TaskStart / {e_ok} TaskEnd events at its assignment times",
                a.task
            ));
        }
    }

    // ---- 7. busy accounting ----
    for (p, ivs) in per_proc.iter().enumerate() {
        let sum: f64 = ivs.iter().map(|&(s, e, _)| e - s).sum();
        let booked = sched.proc_busy.get(p).copied().unwrap_or(0.0);
        // tolerance scales with the number of summed intervals
        if (sum - booked).abs() > EPS * (ivs.len() as f64 + 1.0) {
            errs.push(format!("processor {p}: proc_busy {booked} != summed assignment durations {sum}"));
        }
    }

    // ---- 8. no stale records ----
    // the solver recycles discarded Schedule buffers through a scratch
    // pool; a skipped reset would surface as events referencing another
    // run's tasks, or as proc_busy entries past this machine's width
    for e in &sched.events {
        let (task, proc, what) = match e.kind {
            EventKind::TaskStart { task, proc } => (task, proc, "TaskStart"),
            EventKind::TaskEnd { task, proc } => (task, proc, "TaskEnd"),
            _ => continue,
        };
        if !pos_of.contains_key(&task) {
            errs.push(format!(
                "stale record: {what} event at {} references task {task} outside this frontier",
                e.time
            ));
        }
        if proc >= machine.n_procs() {
            errs.push(format!("stale record: {what} event for task {task} on unknown processor {proc}"));
        }
    }
    if sched.proc_busy.len() > machine.n_procs() {
        errs.push(format!(
            "stale record: proc_busy has {} entries for a {}-processor machine",
            sched.proc_busy.len(),
            machine.n_procs()
        ));
    }

    if errs.is_empty() {
        Ok(())
    } else {
        Err(errs.join("\n"))
    }
}

/// Panic with the full violation list unless `sched` is valid — the
/// debug-build hook the solver and sweep call on every schedule they keep.
pub fn assert_valid(dag: &TaskDag, flat: &FlatDag, machine: &Machine, sched: &Schedule) {
    if let Err(e) = validate_schedule(dag, flat, machine, sched) {
        panic!("schedule failed invariant validation:\n{e}");
    }
}

/// The fault-run oracle: every invariant of [`validate_schedule`] adapted
/// to a schedule produced under a [`FaultPlan`], plus the fault-specific
/// ones the tentpole demands:
///
/// - **No dead-interval execution** — no executed interval (final
///   assignment or killed-attempt prefix) overlaps its processor's dead
///   windows from the plan.
/// - **Re-execution** — every non-final attempt (a `TaskFault` event) is
///   followed by a re-execution; each assigned task ends with exactly one
///   `TaskEnd`, at the final assignment's own time and processor.
/// - **Attempt accounting closes** — faults per task stay strictly below
///   the spec's `max_attempts`, and per-processor busy seconds equal the
///   summed final durations *plus* the executed-then-lost attempt
///   intervals reconstructed from the event log.
///
/// Attempt intervals are reconstructed independently from the log: a
/// `TaskStart` opens an execution on `(task, proc)`; a `TaskFault` closes
/// it as a lost interval (a fault with no open start is a cancelled
/// not-yet-started booking and left no executed work); a `TaskEnd` closes
/// the final one. Only finite (completed) schedules are validatable — an
/// exhausted run's `INFINITY` makespan is rejected outright.
pub fn validate_schedule_faults(
    dag: &TaskDag,
    flat: &FlatDag,
    machine: &Machine,
    sched: &Schedule,
    plan: &FaultPlan,
) -> Result<(), String> {
    if plan.spec.is_empty() {
        return validate_schedule(dag, flat, machine, sched);
    }
    if !sched.makespan.is_finite() {
        return Err(format!("fault run did not complete (makespan {}): nothing to validate", sched.makespan));
    }
    let mut errs: Vec<String> = Vec::new();
    let n = flat.len();

    // ---- shape ----
    if sched.assignments.len() != n {
        return Err(format!("schedule has {} assignments for a {}-task frontier", sched.assignments.len(), n));
    }
    for (pos, a) in sched.assignments.iter().enumerate() {
        if a.pos != pos || a.task != flat.tasks[pos] {
            errs.push(format!("assignment at slot {pos} carries pos {} task {}", a.pos, a.task));
        }
        if !dag.is_live(a.task) {
            errs.push(format!("assignment {pos} schedules non-live task {}", a.task));
        }
        if a.proc >= machine.n_procs() {
            errs.push(format!("assignment {pos} placed on unknown processor {}", a.proc));
        }
        if !(a.release.is_finite() && a.start.is_finite() && a.end.is_finite())
            || a.release < -EPS
            || a.start < a.release - EPS
            || a.end < a.start
        {
            errs.push(format!(
                "task {} violates 0 <= release <= start <= end: release {} start {} end {}",
                a.task, a.release, a.start, a.end
            ));
        }
    }
    for (i, t) in sched.transfers.iter().enumerate() {
        if !t.start.is_finite() || !t.end.is_finite() || t.start < -EPS || t.end < t.start {
            errs.push(format!("transfer {i} ({} -> {}) is malformed: [{}, {}]", t.from, t.to, t.start, t.end));
        }
    }
    if !errs.is_empty() {
        return Err(errs.join("\n")); // later checks index by these fields
    }

    // ---- reconstruct executed attempt intervals from the event log ----
    // `(task, proc) -> open TaskStart time`; lost intervals collected per
    // processor, fault/start/end times per task
    let mut open: FxHashMap<(TaskId, usize), f64> = FxHashMap::default();
    let mut lost: Vec<(usize, f64, f64, TaskId)> = Vec::new(); // (proc, start, end, task)
    let mut fault_times: FxHashMap<TaskId, Vec<f64>> = FxHashMap::default();
    let mut end_events: FxHashMap<TaskId, Vec<(usize, f64)>> = FxHashMap::default();
    let mut start_counts: FxHashMap<TaskId, usize> = FxHashMap::default();
    let pos_of: FxHashMap<TaskId, usize> = flat.tasks.iter().enumerate().map(|(i, &t)| (t, i)).collect();
    for e in &sched.events {
        match e.kind {
            EventKind::TaskStart { task, proc } => {
                if open.insert((task, proc), e.time).is_some() {
                    errs.push(format!("task {task} started twice on processor {proc} without finishing"));
                }
                *start_counts.entry(task).or_insert(0) += 1;
            }
            EventKind::TaskEnd { task, proc } => {
                if open.remove(&(task, proc)).is_none() {
                    errs.push(format!("TaskEnd for task {task} on processor {proc} without a TaskStart"));
                }
                end_events.entry(task).or_default().push((proc, e.time));
            }
            EventKind::TaskFault { task, proc } => {
                // an open start means the attempt executed [start, fault);
                // no open start = a cancelled not-yet-started booking
                if let Some(s) = open.remove(&(task, proc)) {
                    lost.push((proc, s, e.time, task));
                }
                fault_times.entry(task).or_default().push(e.time);
            }
            _ => {}
        }
        if !e.time.is_finite() {
            errs.push(format!("event {:?} has non-finite time", e.kind));
        }
    }
    for e in &sched.events {
        let (task, proc, what) = match e.kind {
            EventKind::TaskStart { task, proc } => (task, proc, "TaskStart"),
            EventKind::TaskEnd { task, proc } => (task, proc, "TaskEnd"),
            EventKind::TaskFault { task, proc } => (task, proc, "TaskFault"),
            EventKind::ProcFail { proc } | EventKind::ProcRestore { proc } => {
                if proc >= machine.n_procs() {
                    errs.push(format!("fault event on unknown processor {proc}"));
                }
                continue;
            }
            _ => continue,
        };
        if !pos_of.contains_key(&task) {
            errs.push(format!("stale record: {what} references task {task} outside this frontier"));
        }
        if proc >= machine.n_procs() {
            errs.push(format!("stale record: {what} for task {task} on unknown processor {proc}"));
        }
    }
    if !errs.is_empty() {
        return Err(errs.join("\n"));
    }

    // ---- processor exclusivity over finals + lost attempt intervals ----
    let mut per_proc: Vec<Vec<(f64, f64, TaskId)>> = vec![Vec::new(); machine.n_procs()];
    for a in &sched.assignments {
        per_proc[a.proc].push((a.start, a.end, a.task));
    }
    let mut lost_per_proc: Vec<f64> = vec![0.0; machine.n_procs()];
    let mut lost_counts: Vec<usize> = vec![0; machine.n_procs()];
    for &(p, s, e, task) in &lost {
        per_proc[p].push((s, e, task));
        lost_per_proc[p] += e - s;
        lost_counts[p] += 1;
    }
    for (p, ivs) in per_proc.iter_mut().enumerate() {
        ivs.sort_by(|x, y| x.0.total_cmp(&y.0));
        for w in ivs.windows(2) {
            if w[0].1 > w[1].0 + EPS {
                errs.push(format!(
                    "processor {p}: executions of tasks {} [{}, {}] and {} [{}, {}] overlap",
                    w[0].2, w[0].0, w[0].1, w[1].2, w[1].0, w[1].1
                ));
            }
        }
        // ---- no executed interval overlaps a dead window ----
        for (ds, de) in plan.dead_windows(p) {
            for &(s, e, task) in ivs.iter() {
                if s < de - EPS && ds < e - EPS {
                    errs.push(format!(
                        "task {task} executes [{s}, {e}] inside processor {p}'s dead window [{ds}, {de}]"
                    ));
                }
            }
        }
    }

    // ---- dependences on final assignments ----
    for pos in 0..n {
        let a = &sched.assignments[pos];
        for &p in &flat.preds[pos] {
            let dep = &sched.assignments[p];
            if a.start < dep.end - EPS {
                errs.push(format!(
                    "task {} starts at {} before predecessor {} finishes at {}",
                    a.task, a.start, dep.task, dep.end
                ));
            }
        }
    }

    // ---- arrival gate: transfers into the *final* placement's space
    // gate its start (a killed attempt's fetches into another space are
    // that attempt's business, already covered by its logged interval) ----
    for (i, t) in sched.transfers.iter().enumerate() {
        let Some(tid) = t.dst_task else { continue };
        let Some(&pos) = pos_of.get(&tid) else {
            errs.push(format!("transfer {i} fetches input for unknown task {tid}"));
            continue;
        };
        let a = &sched.assignments[pos];
        if machine.procs[a.proc].space == t.to && a.start < t.end - EPS {
            errs.push(format!(
                "task {tid} starts at {} before its input transfer {i} ({} -> {}) lands at {}",
                a.start, t.from, t.to, t.end
            ));
        }
    }

    // ---- makespan + event-log order (fail/restore markers may outlive
    // the workload; everything else stays inside the makespan) ----
    let task_end = sched.assignments.iter().map(|a| a.end).fold(0.0f64, f64::max);
    let xfer_end = sched.transfers.iter().map(|t| t.end).fold(0.0f64, f64::max);
    let expect = task_end.max(xfer_end);
    if (sched.makespan - expect).abs() > EPS {
        errs.push(format!("makespan {} != max event end {}", sched.makespan, expect));
    }
    for w in sched.events.windows(2) {
        if w[1].time < w[0].time - EPS {
            errs.push(format!("event log out of order: {} after {}", w[1].time, w[0].time));
            break;
        }
    }
    for e in &sched.events {
        if matches!(e.kind, EventKind::ProcFail { .. } | EventKind::ProcRestore { .. }) {
            continue;
        }
        if e.time > sched.makespan + EPS {
            errs.push(format!("event {:?} at {} past the makespan {}", e.kind, e.time, sched.makespan));
        }
    }

    // ---- attempt accounting ----
    let no_faults: Vec<f64> = Vec::new();
    for a in &sched.assignments {
        let faults = fault_times.get(&a.task).unwrap_or(&no_faults);
        let max = plan.max_attempts() as usize;
        if faults.len() >= max {
            errs.push(format!(
                "task {} logged {} faults with an attempt budget of {max} and still completed",
                a.task,
                faults.len()
            ));
        }
        let ends = end_events.get(&a.task).map(Vec::as_slice).unwrap_or(&[]);
        if ends.len() != 1 {
            errs.push(format!("task {} has {} TaskEnd events; a recovered task completes exactly once", a.task, ends.len()));
            continue;
        }
        let (ep, et) = ends[0];
        if ep != a.proc || (et - a.end).abs() > EPS {
            errs.push(format!(
                "task {} finally ends on processor {ep} at {et}, but its assignment says processor {} at {}",
                a.task, a.proc, a.end
            ));
        }
        // every non-final attempt is followed by a re-execution: the
        // final completion comes after every fault of the task
        for &ft in faults {
            if ft > a.end + EPS {
                errs.push(format!(
                    "task {} faulted at {ft} after its final completion at {} — missing re-execution",
                    a.task, a.end
                ));
            }
        }
        let starts = start_counts.get(&a.task).copied().unwrap_or(0);
        if starts < 1 || starts > faults.len() + 1 {
            errs.push(format!(
                "task {} logged {starts} TaskStart events for {} faults + 1 completion",
                a.task,
                faults.len()
            ));
        }
    }

    // ---- busy accounting: finals + executed-then-lost prefixes ----
    for p in 0..machine.n_procs() {
        let finals: f64 = sched.assignments.iter().filter(|a| a.proc == p).map(|a| a.end - a.start).sum();
        let expect_busy = finals + lost_per_proc[p];
        let booked = sched.proc_busy.get(p).copied().unwrap_or(0.0);
        let terms = sched.assignments.len() + lost_counts[p] + 1;
        if (expect_busy - booked).abs() > EPS * terms as f64 {
            errs.push(format!(
                "processor {p}: proc_busy {booked} != final {finals} + lost {} seconds",
                lost_per_proc[p]
            ));
        }
    }
    if sched.proc_busy.len() > machine.n_procs() {
        errs.push(format!(
            "stale record: proc_busy has {} entries for a {}-processor machine",
            sched.proc_busy.len(),
            machine.n_procs()
        ));
    }

    if errs.is_empty() {
        Ok(())
    } else {
        Err(errs.join("\n"))
    }
}

/// Panic unless the fault-run schedule is valid — the debug-build hook
/// fault-enabled sweep cells and the faults bench call on every schedule
/// they keep.
pub fn assert_valid_faults(dag: &TaskDag, flat: &FlatDag, machine: &Machine, sched: &Schedule, plan: &FaultPlan) {
    if let Err(e) = validate_schedule_faults(dag, flat, machine, sched, plan) {
        panic!("fault schedule failed invariant validation:\n{e}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{simulate, SimConfig};
    use crate::coordinator::partitioners::cholesky;
    use crate::coordinator::perfmodel::{PerfCurve, PerfDb};
    use crate::coordinator::platform::MachineBuilder;
    use crate::coordinator::policies::{Ordering, ProcSelect, SchedConfig};

    fn setup() -> (Machine, PerfDb) {
        let mut b = MachineBuilder::new("m");
        let h = b.space("host", u64::MAX);
        let g = b.space("gpu", u64::MAX);
        b.main(h);
        b.connect(h, g, 1e-5, 1e9);
        let cpu = b.proc_type("cpu", 1.0, 0.1);
        let gpu = b.proc_type("gpu", 1.0, 0.1);
        b.processors(2, "c", cpu, h);
        b.processors(1, "g", gpu, g);
        let m = b.build();
        let mut db = PerfDb::new();
        db.set_fallback(0, PerfCurve::Const { gflops: 2.0 });
        db.set_fallback(1, PerfCurve::Const { gflops: 8.0 });
        (m, db)
    }

    fn sim() -> SimConfig {
        SimConfig::new(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish))
    }

    #[test]
    fn engine_schedules_pass() {
        let (m, db) = setup();
        let mut dag = cholesky::root(256);
        cholesky::partition_uniform(&mut dag, 64);
        let flat = dag.flat_dag();
        let sched = simulate(&dag, &m, &db, sim());
        validate_schedule(&dag, &flat, &m, &sched).expect("engine output must satisfy every invariant");
    }

    #[test]
    fn overlapping_assignments_are_rejected() {
        let (m, db) = setup();
        let mut dag = cholesky::root(256);
        cholesky::partition_uniform(&mut dag, 64);
        let flat = dag.flat_dag();
        let mut sched = simulate(&dag, &m, &db, sim());
        // force two tasks onto one processor at the same instant
        let a0 = sched.assignments[0];
        sched.assignments[1].proc = a0.proc;
        sched.assignments[1].start = a0.start;
        sched.assignments[1].end = a0.end.max(sched.assignments[1].end);
        let err = validate_schedule(&dag, &flat, &m, &sched).unwrap_err();
        assert!(err.contains("overlap"), "{err}");
    }

    #[test]
    fn broken_dependence_is_rejected() {
        let (m, db) = setup();
        let mut dag = cholesky::root(256);
        cholesky::partition_uniform(&mut dag, 64);
        let flat = dag.flat_dag();
        let mut sched = simulate(&dag, &m, &db, sim());
        // pull a dependent task before its predecessor finishes
        let pos = (0..flat.len()).find(|&i| !flat.preds[i].is_empty()).expect("dag has edges");
        sched.assignments[pos].release = 0.0;
        sched.assignments[pos].start = 0.0;
        let err = validate_schedule(&dag, &flat, &m, &sched).unwrap_err();
        assert!(err.contains("before predecessor"), "{err}");
    }

    #[test]
    fn non_finite_time_is_rejected() {
        let (m, db) = setup();
        let mut dag = cholesky::root(256);
        cholesky::partition_uniform(&mut dag, 64);
        let flat = dag.flat_dag();
        let mut sched = simulate(&dag, &m, &db, sim());
        sched.assignments[2].end = f64::INFINITY;
        let err = validate_schedule(&dag, &flat, &m, &sched).unwrap_err();
        assert!(err.contains("non-finite"), "{err}");
    }

    #[test]
    fn wrong_makespan_is_rejected() {
        let (m, db) = setup();
        let mut dag = cholesky::root(256);
        cholesky::partition_uniform(&mut dag, 64);
        let flat = dag.flat_dag();
        let mut sched = simulate(&dag, &m, &db, sim());
        sched.makespan *= 0.5;
        let err = validate_schedule(&dag, &flat, &m, &sched).unwrap_err();
        assert!(err.contains("makespan"), "{err}");
    }

    #[test]
    fn violated_arrival_gate_is_rejected() {
        let (m, db) = setup();
        let mut dag = cholesky::root(256);
        cholesky::partition_uniform(&mut dag, 64);
        let flat = dag.flat_dag();
        let mut sched = simulate(&dag, &m, &db, sim());
        // find a gating input transfer and pretend it lands after its task
        // started (keep it before the makespan so only one check can fire)
        let Some(i) = (0..sched.transfers.len()).find(|&i| sched.transfers[i].dst_task.is_some()) else {
            // every task ran CPU-local — not this machine/db combination
            panic!("the gpu machine must fetch at least one input");
        };
        let tid = sched.transfers[i].dst_task.unwrap();
        let pos = flat.tasks.iter().position(|&t| t == tid).unwrap();
        sched.transfers[i].end = sched.assignments[pos].start + 1e-3;
        let err = validate_schedule(&dag, &flat, &m, &sched).unwrap_err();
        assert!(err.contains("input transfer"), "{err}");
    }

    #[test]
    fn stale_recycled_records_are_rejected() {
        let (m, db) = setup();
        let mut dag = cholesky::root(256);
        cholesky::partition_uniform(&mut dag, 64);
        let flat = dag.flat_dag();
        let mut sched = simulate(&dag, &m, &db, sim());
        // a leaked event from a previous run's DAG: unknown task id,
        // stamped inside the makespan so no other invariant can fire
        let when = sched.makespan * 0.5;
        let stale = TaskId::MAX;
        assert!(!flat.tasks.contains(&stale));
        let at = sched.events.partition_point(|e| e.time <= when);
        sched.events.insert(
            at,
            crate::coordinator::engine::SimEvent {
                time: when,
                kind: EventKind::TaskEnd { task: stale, proc: 0 },
            },
        );
        let err = validate_schedule(&dag, &flat, &m, &sched).unwrap_err();
        assert!(err.contains("stale record"), "{err}");
    }

    // ---- fault-oracle tests: a machine + workload where the fault
    // outcome is exactly predictable (mirrors the engine fault tests) ----

    fn flat_machine() -> (Machine, PerfDb) {
        let mut b = MachineBuilder::new("m");
        let h = b.space("host", u64::MAX);
        b.main(h);
        let fast = b.proc_type("fast", 1.0, 0.1);
        b.processors(2, "f", fast, h);
        let m = b.build();
        let mut db = PerfDb::new();
        db.set_fallback(0, PerfCurve::Const { gflops: 4.0 });
        (m, db)
    }

    /// `k` independent gemm tasks over disjoint 100x100 tiles.
    fn independent(k: u32) -> TaskDag {
        use crate::coordinator::region::Region;
        use crate::coordinator::task::{TaskKind, TaskSpec};
        let root = Region::new(0, 0, 100 * k, 0, 100);
        let mut dag = TaskDag::new(TaskSpec::new(TaskKind::Potrf, vec![root], vec![root]));
        let specs: Vec<TaskSpec> = (0..k)
            .map(|i| {
                let r = Region::new(0, 100 * i, 100 * (i + 1), 0, 100);
                TaskSpec::new(TaskKind::Gemm, vec![r], vec![r])
            })
            .collect();
        dag.partition(0, specs, 100);
        dag
    }

    /// Kill processor 1 mid-first-task, forever: its in-flight task is
    /// re-dispatched to processor 0 and the run stays finite.
    fn faulted_run() -> (Machine, TaskDag, FlatDag, Schedule, FaultPlan) {
        use crate::coordinator::engine::simulate_flat_faults;
        use crate::coordinator::faults::{FailStop, FaultSpec};
        use crate::coordinator::policy::policy_for;
        let (m, db) = flat_machine();
        let dag = independent(4);
        let flat = dag.flat_dag();
        let per = 2e6 / 4e9; // one 100-tile gemm on a 4-gflops proc
        let mut spec = FaultSpec::named("kill-p1");
        spec.fail_stop.push(FailStop { proc: 1, at: per * 0.5, restore: None });
        let plan = FaultPlan::new(&spec, 0);
        let c = SimConfig::new(SchedConfig::new(Ordering::Fcfs, ProcSelect::EarliestIdle));
        let mut p = policy_for(SchedConfig::new(c.ordering, c.select));
        let sched = simulate_flat_faults(&dag, &flat, &m, &db, c, p.as_mut(), &plan);
        (m, dag, flat, sched, plan)
    }

    #[test]
    fn faulted_engine_schedules_pass_the_fault_oracle() {
        let (m, dag, flat, sched, plan) = faulted_run();
        assert!(sched.makespan.is_finite());
        assert!(
            sched.events.iter().any(|e| matches!(e.kind, EventKind::TaskFault { .. })),
            "the kill must actually fault an attempt"
        );
        validate_schedule_faults(&dag, &flat, &m, &sched, &plan)
            .expect("recovered schedule must satisfy every fault invariant");
    }

    #[test]
    fn execution_inside_a_dead_window_is_rejected() {
        let (m, dag, flat, mut sched, plan) = faulted_run();
        // move one completed task onto the dead processor, inside the window
        let dead_at = plan.dead_windows(1)[0].0;
        sched.assignments[0].proc = 1;
        sched.assignments[0].start = dead_at + 1e-4;
        sched.assignments[0].end = dead_at + 2e-4;
        let err = validate_schedule_faults(&dag, &flat, &m, &sched, &plan).unwrap_err();
        assert!(err.contains("dead window"), "{err}");
    }

    #[test]
    fn missing_re_execution_record_is_rejected() {
        let (m, dag, flat, mut sched, plan) = faulted_run();
        // drop the final completion of the task that faulted: its fault
        // is now never followed by a re-execution that finishes
        let victim = sched
            .events
            .iter()
            .find_map(|e| match e.kind {
                EventKind::TaskFault { task, .. } => Some(task),
                _ => None,
            })
            .expect("the kill must fault a task");
        sched.events.retain(|e| !matches!(e.kind, EventKind::TaskEnd { task, .. } if task == victim));
        let err = validate_schedule_faults(&dag, &flat, &m, &sched, &plan).unwrap_err();
        assert!(err.contains("completes exactly once"), "{err}");
    }

    #[test]
    fn fault_after_final_completion_is_rejected() {
        let (m, dag, flat, mut sched, plan) = faulted_run();
        // forge a fault strictly after a task's final completion, with no
        // re-execution behind it
        let a = sched.assignments[2];
        let when = sched.makespan - 1e-6;
        assert!(when > a.end + EPS, "forged fault must land after the task's end");
        let at = sched.events.partition_point(|e| e.time <= when);
        sched.events.insert(
            at,
            crate::coordinator::engine::SimEvent {
                time: when,
                kind: EventKind::TaskFault { task: a.task, proc: a.proc },
            },
        );
        let err = validate_schedule_faults(&dag, &flat, &m, &sched, &plan).unwrap_err();
        assert!(err.contains("missing re-execution"), "{err}");
    }

    #[test]
    fn empty_fault_plan_oracle_matches_the_plain_oracle() {
        use crate::coordinator::faults::FaultSpec;
        let (m, db) = setup();
        let mut dag = cholesky::root(256);
        cholesky::partition_uniform(&mut dag, 64);
        let flat = dag.flat_dag();
        let sched = simulate(&dag, &m, &db, sim());
        let plan = FaultPlan::new(&FaultSpec::named("off"), 0);
        validate_schedule_faults(&dag, &flat, &m, &sched, &plan)
            .expect("an empty plan must delegate to the plain oracle");
    }

    #[test]
    fn overlapping_link_bookings_are_rejected() {
        let (m, db) = setup();
        let mut dag = cholesky::root(256);
        cholesky::partition_uniform(&mut dag, 64);
        let flat = dag.flat_dag();
        let mut sched = simulate(&dag, &m, &db, sim());
        let Some(&(lid, s, e)) = sched.link_occupancy.first() else {
            panic!("the gpu machine must book at least one link window");
        };
        // duplicate a booking shifted half a width into itself
        sched.link_occupancy.push((lid, s + (e - s) * 0.5, e + (e - s) * 0.5));
        let err = validate_schedule(&dag, &flat, &m, &sched).unwrap_err();
        assert!(err.contains("link"), "{err}");
    }
}
