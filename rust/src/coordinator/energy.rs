//! Energy model + objective (paper §2: "energy consumption minimization is
//! also supported").
//!
//! Per-processor power is two-state (busy/idle watts, from the platform's
//! processor types); interconnect energy is charged per byte moved.

use super::engine::Schedule;
use super::platform::Machine;

/// Energy accounting for one schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyReport {
    /// Joules burnt by busy processors.
    pub busy_j: f64,
    /// Joules burnt idling (until the makespan).
    pub idle_j: f64,
    /// Joules spent moving data.
    pub transfer_j: f64,
}

impl EnergyReport {
    pub fn total(&self) -> f64 {
        self.busy_j + self.idle_j + self.transfer_j
    }

    /// Energy-delay product (a common combined objective).
    pub fn edp(&self, makespan: f64) -> f64 {
        self.total() * makespan
    }
}

/// Default interconnect energy cost (J/byte): ~20 pJ/bit DRAM+link class.
pub const DEFAULT_J_PER_BYTE: f64 = 2.5e-9;

/// Compute the energy report for `sched` on `machine`.
pub fn energy(sched: &Schedule, machine: &Machine, j_per_byte: f64) -> EnergyReport {
    let mut busy_j = 0.0;
    let mut idle_j = 0.0;
    for p in &machine.procs {
        let t = &machine.proc_types[p.ptype];
        let busy = sched.proc_busy.get(p.id).copied().unwrap_or(0.0);
        busy_j += busy * t.busy_watts;
        idle_j += (sched.makespan - busy).max(0.0) * t.idle_watts;
    }
    EnergyReport { busy_j, idle_j, transfer_j: sched.transfer_bytes as f64 * j_per_byte }
}

/// Optimization objective for the iterative solver.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Minimize makespan (the paper's default).
    Makespan,
    /// Minimize total energy.
    Energy,
    /// Minimize energy-delay product.
    Edp,
}

impl Objective {
    pub fn from_name(s: &str) -> Option<Objective> {
        Some(match s.to_ascii_lowercase().as_str() {
            "makespan" | "perf" | "performance" => Objective::Makespan,
            "energy" => Objective::Energy,
            "edp" => Objective::Edp,
            _ => return None,
        })
    }

    /// Scalar cost of a schedule (lower is better).
    pub fn cost(&self, sched: &Schedule, machine: &Machine) -> f64 {
        match self {
            Objective::Makespan => sched.makespan,
            Objective::Energy => energy(sched, machine, DEFAULT_J_PER_BYTE).total(),
            Objective::Edp => energy(sched, machine, DEFAULT_J_PER_BYTE).edp(sched.makespan),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{Assignment, Schedule};
    use crate::coordinator::platform::MachineBuilder;

    fn machine() -> Machine {
        let mut b = MachineBuilder::new("m");
        let h = b.space("host", u64::MAX);
        b.main(h);
        let cpu = b.proc_type("cpu", 100.0, 10.0);
        b.processors(2, "c", cpu, h);
        b.build()
    }

    fn sched(busy0: f64, busy1: f64, makespan: f64, bytes: u64) -> Schedule {
        Schedule {
            assignments: vec![Assignment { task: 0, pos: 0, proc: 0, release: 0.0, start: 0.0, end: busy0 }],
            transfers: vec![],
            makespan,
            proc_busy: vec![busy0, busy1],
            transfer_bytes: bytes,
            ..Default::default()
        }
    }

    #[test]
    fn two_state_power_accounting() {
        let m = machine();
        let s = sched(2.0, 1.0, 2.0, 0);
        let e = energy(&s, &m, 0.0);
        assert!((e.busy_j - 300.0).abs() < 1e-9); // (2+1)*100
        assert!((e.idle_j - 10.0).abs() < 1e-9); // proc1 idle 1s * 10W
        assert!((e.total() - 310.0).abs() < 1e-9);
    }

    #[test]
    fn transfer_energy_counted() {
        let m = machine();
        let s = sched(1.0, 1.0, 1.0, 1_000_000);
        let e = energy(&s, &m, 2.5e-9);
        assert!((e.transfer_j - 2.5e-3).abs() < 1e-12);
    }

    #[test]
    fn objectives_order_differently() {
        let m = machine();
        // fast but power-hungry vs slow but efficient
        let fast = sched(1.0, 1.0, 1.0, 0);
        let slow = sched(1.5, 0.0, 1.5, 0);
        assert!(Objective::Makespan.cost(&fast, &m) < Objective::Makespan.cost(&slow, &m));
        // energy: fast = 200 J; slow = 150*1 busy + idle 10*1.5+... =
        let ef = Objective::Energy.cost(&fast, &m);
        let es = Objective::Energy.cost(&slow, &m);
        assert!(es < ef, "slow run uses less energy ({es} vs {ef})");
        assert_eq!(Objective::from_name("edp"), Some(Objective::Edp));
    }

    #[test]
    fn edp_is_product() {
        let m = machine();
        let s = sched(1.0, 1.0, 2.0, 0);
        let e = energy(&s, &m, DEFAULT_J_PER_BYTE);
        assert!((e.edp(2.0) - e.total() * 2.0).abs() < 1e-9);
    }
}
