//! Incremental re-simulation for the portfolio solver.
//!
//! The solver evaluates thousands of candidate frontiers per run, and
//! neighbouring candidates share almost their entire dispatch history
//! with the schedule they were derived from. This module proves how much
//! of a base run a candidate shares — decision by decision, bitwise —
//! and packages the proven prefix into a [`ReplayPlan`] the event core
//! can restore-and-replay instead of simulating from scratch.
//!
//! The pipeline per candidate:
//!
//! 1. [`changed_span`] diffs the base and candidate frontier id
//!    sequences; [`affected_cone`] closes the changed span over the
//!    candidate's successor edges. The cone is a *conservative extra*
//!    stop — the scan below re-derives every fact it needs and is
//!    correct without it — but it cuts scans short near the mutation
//!    and feeds the solver's replay statistics.
//! 2. [`plan_candidate`] runs an abstract scan of the candidate frontier
//!    against the base run's decision log: it maintains the candidate's
//!    own indegree/release/ready bookkeeping, drives it with the base
//!    run's task-end stream, and checks at every base decision that the
//!    candidate would have made the *same* choice — same argmax over the
//!    ready set (candidate keys, candidate tie-break positions), same
//!    bitwise release time, and (for lookahead-style policies) the same
//!    successor set. The first failed check fixes the divergence time
//!    `stop` and the verified prefix length `d_star`. The scan also
//!    proves the candidate's ready set drains exactly as the base's
//!    rounds do: work still ready at a round where the base dispatched
//!    nothing — including work released by a batch cut at a checkpoint
//!    boundary, which skips the ordinary drain check — ends the prefix
//!    with `stop` at that work's *release* round, so no checkpoint
//!    snapshotted after the candidate truly diverged is ever eligible.
//! 3. The latest base [`Checkpoint`] with `n_decisions <= d_star &&
//!    now <= stop` is provably a pure function of the shared prefix, so
//!    the plan restores it, force-replays decisions `[n_decisions,
//!    d_star)` without invoking selection, and hands control back to the
//!    live engine exactly at the divergence point.
//!
//! Only policies whose ordering key is a pure function of
//! `(release, critical_time)` and whose selection is stateless are
//! eligible ([`policy_eligible`]); everything else — and every scan that
//! cannot prove a non-empty prefix — falls back to a full simulation.
//! Replayed results are bitwise identical to full re-simulation by
//! construction; `tests/delta_eval.rs` pins this property across the
//! whole policy registry.
//!
//! [`CostCache`] is the third layer: candidates whose *entire* frontier
//! signature was already evaluated under this lane's fixed
//! (machine, policy, seed) skip simulation altogether.

use std::sync::Arc;

use super::engine::{pick_best, ReplayPlan, Schedule, SimTrace};
use super::policy::SchedPolicy;
use super::task::{TaskId, TaskKind};
use super::taskdag::{FlatDag, TaskDag};
use crate::util::fxhash::FxHashMap;

/// Delta-evaluation switch, threaded from the CLI through
/// [`super::solver::PortfolioConfig`]. `On` and `Auto` behave
/// identically today (the scan falls back per candidate on its own);
/// the distinction is reserved for future cost models that may disable
/// delta evaluation wholesale on small frontiers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DeltaMode {
    /// Delta evaluation wherever the policy is eligible.
    On,
    /// Always full re-simulation (the pre-delta behaviour).
    #[default]
    Off,
    /// Like `On`; the engine decides per candidate (default).
    Auto,
}

impl DeltaMode {
    pub fn from_name(s: &str) -> Option<DeltaMode> {
        match s {
            "on" => Some(DeltaMode::On),
            "off" => Some(DeltaMode::Off),
            "auto" => Some(DeltaMode::Auto),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DeltaMode::On => "on",
            DeltaMode::Off => "off",
            DeltaMode::Auto => "auto",
        }
    }

    /// Whether the solver should attempt delta evaluation at all.
    pub fn enabled(self) -> bool {
        !matches!(self, DeltaMode::Off)
    }
}

/// A policy qualifies for delta evaluation when its ordering key is the
/// declared pure function of `(release, critical_time)` and its
/// selection touches no mutable policy state. The scan recomputes keys
/// via [`SchedPolicy::static_key`], so a policy whose `order` disagrees
/// with its `static_key` must simply not declare one.
pub(crate) fn policy_eligible(policy: &dyn SchedPolicy) -> bool {
    policy.static_key(0.0, 0.0).is_some() && !policy.dynamic_order() && policy.select_stateless()
}

/// The base run a lane verifies candidates against: its trace (decision
/// log + checkpoints), its frontier id sequence, its task-end stream in
/// event order, and — for successor-aware policies — each task's
/// successor id sequence at dispatch time.
pub(crate) struct DeltaBase {
    pub trace: SimTrace,
    /// Base frontier task ids, in frontier (program) order.
    ids: Vec<TaskId>,
    /// `(end_time, decision_index)` per dispatched task, sorted by
    /// `(end, index)` — exactly the order the event core pops `TaskEnd`s
    /// (seq order within a batch is dispatch order).
    ends: Vec<(f64, usize)>,
    /// Successor id sequences keyed by task id; empty unless the policy
    /// reads [`super::policy::SchedContext::successors`] in `select`.
    succ_ids: FxHashMap<TaskId, Vec<TaskId>>,
}

impl DeltaBase {
    pub(crate) fn new(trace: SimTrace, sched: &Schedule, flat: &FlatDag, want_succs: bool) -> DeltaBase {
        let end_of: FxHashMap<TaskId, f64> =
            sched.assignments.iter().map(|a| (a.task, a.end)).collect();
        let mut ends: Vec<(f64, usize)> =
            trace.decisions.iter().enumerate().map(|(i, d)| (end_of[&d.task], i)).collect();
        ends.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let succ_ids = if want_succs {
            (0..flat.len())
                .map(|p| (flat.tasks[p], flat.succs[p].iter().map(|&s| flat.tasks[s]).collect()))
                .collect()
        } else {
            FxHashMap::default()
        };
        DeltaBase { trace, ids: flat.tasks.clone(), ends, succ_ids }
    }
}

/// Diff two frontier id sequences: `None` when identical, otherwise the
/// candidate-side span `lo..hi` covering every inserted/replaced
/// position (common prefix + common suffix stripped). A pure deletion
/// yields an empty span — harmless, since the scan itself catches every
/// behavioural consequence; the span only scopes the conservative cone.
pub(crate) fn changed_span(a: &[TaskId], b: &[TaskId]) -> Option<(usize, usize)> {
    let mut lo = 0;
    while lo < a.len() && lo < b.len() && a[lo] == b[lo] {
        lo += 1;
    }
    if lo == a.len() && lo == b.len() {
        return None;
    }
    let (mut ha, mut hb) = (a.len(), b.len());
    while ha > lo && hb > lo && a[ha - 1] == b[hb - 1] {
        ha -= 1;
        hb -= 1;
    }
    Some((lo, hb))
}

/// Close `span` over the candidate's successor edges: every position
/// whose schedule can transitively depend on a changed task.
pub(crate) fn affected_cone(flat: &FlatDag, lo: usize, hi: usize) -> Vec<bool> {
    let mut affected = vec![false; flat.len()];
    let mut stack: Vec<usize> = (lo..hi).collect();
    for &p in &stack {
        affected[p] = true;
    }
    while let Some(p) = stack.pop() {
        for &s in &flat.succs[p] {
            if !affected[s] {
                affected[s] = true;
                stack.push(s);
            }
        }
    }
    affected
}

fn static_key_of(policy: &dyn SchedPolicy, release: f64, prio: f64) -> f64 {
    policy.static_key(release, prio).expect("delta scan requires a static-key policy")
}

/// The abstract dispatch state of the candidate frontier during a scan:
/// the same indegree/release/key/ready bookkeeping `run_core` keeps,
/// minus timelines and coherence (those are base-determined for the
/// verified prefix and come back via checkpoint restore).
struct ScanState<'a> {
    flat: &'a FlatDag,
    indeg: Vec<usize>,
    release: Vec<f64>,
    keys: Vec<f64>,
    ready: Vec<usize>,
}

impl<'a> ScanState<'a> {
    fn new(flat: &'a FlatDag, policy: &dyn SchedPolicy, prio: &[f64]) -> ScanState<'a> {
        let n = flat.len();
        let indeg: Vec<usize> = flat.preds.iter().map(|p| p.len()).collect();
        let mut st =
            ScanState { flat, indeg, release: vec![0.0; n], keys: vec![0.0; n], ready: Vec::new() };
        for i in 0..n {
            if st.indeg[i] == 0 {
                st.keys[i] = static_key_of(policy, 0.0, prio[i]);
                st.ready.push(i);
            }
        }
        st
    }

    /// Mirror of the engine's end-batch bookkeeping: decrement successor
    /// indegrees, fold the release time, key-and-ready on zero.
    fn release_succs(&mut self, policy: &dyn SchedPolicy, prio: &[f64], pos: usize, at: f64) {
        let flat = self.flat;
        for &s in &flat.succs[pos] {
            self.indeg[s] -= 1;
            self.release[s] = self.release[s].max(at);
            if self.indeg[s] == 0 {
                self.keys[s] = static_key_of(policy, self.release[s], prio[s]);
                self.ready.push(s);
            }
        }
    }
}

/// Replay the base end-event stream into the candidate's abstract state
/// up to (but not past) `(limit_t, limit_j)`: an end at time `e` from
/// base decision `j` applies iff `e < limit_t || (e == limit_t && j <
/// limit_j)`. Returns `Err(t)` at the first provable divergence: a
/// fully-processed batch strictly before the limit that leaves the
/// candidate with ready work (the candidate would dispatch at `t`; the
/// base round there dispatched nothing more), or an ended task missing
/// from the candidate frontier (unreachable for verified decisions, kept
/// as a conservative guard). The divergence time is the *earliest
/// undispatched release* among the ready set, not this batch's time:
/// ready work can leak past an earlier checkpoint-boundary cut (a batch
/// consumed at `e == limit_t` skips the drain check below), and the
/// candidate truly dispatched at that earlier silent round.
#[allow(clippy::too_many_arguments)]
fn process_ends(
    st: &mut ScanState<'_>,
    base: &DeltaBase,
    policy: &dyn SchedPolicy,
    prio: &[f64],
    pos_of: &FxHashMap<TaskId, usize>,
    ep: &mut usize,
    limit_t: f64,
    limit_j: usize,
) -> Result<(), f64> {
    let ends = &base.ends;
    while *ep < ends.len() {
        let (batch_t, j0) = ends[*ep];
        if !(batch_t < limit_t || (batch_t == limit_t && j0 < limit_j)) {
            break;
        }
        while *ep < ends.len() {
            let (e, j) = ends[*ep];
            if e != batch_t || !(e < limit_t || (e == limit_t && j < limit_j)) {
                break;
            }
            let id = base.trace.decisions[j].task;
            let Some(&pos) = pos_of.get(&id) else {
                return Err(batch_t.min(min_ready_release(st)));
            };
            st.release_succs(policy, prio, pos, batch_t);
            *ep += 1;
        }
        // a batch strictly before the limit is always fully consumed
        // (the partial-batch cut can only happen at e == limit_t), so
        // this is a completed decision-round boundary
        if batch_t < limit_t && !st.ready.is_empty() {
            return Err(min_ready_release(st));
        }
    }
    Ok(())
}

/// Earliest release among the candidate's ready set — the first round at
/// which undispatched ready work would actually run (`INFINITY` when
/// nothing is ready).
fn min_ready_release(st: &ScanState<'_>) -> f64 {
    let mut t = f64::INFINITY;
    for &q in &st.ready {
        if st.release[q] < t {
            t = st.release[q];
        }
    }
    t
}

/// The candidate's abstract bookkeeping cloned at a base checkpoint
/// boundary — the arrays a [`ReplayPlan`] needs to resume from that
/// checkpoint under the *candidate* frontier's indexing.
struct AbstractSnap {
    indeg: Vec<usize>,
    release: Vec<f64>,
    ready: Vec<usize>,
}

struct ScanOut {
    /// Base decisions proven to replay identically on the candidate.
    d_star: usize,
    /// Earliest simulated time at which the candidate may diverge;
    /// `INFINITY` when the whole base run verified.
    stop: f64,
    /// One snapshot per base checkpoint reached before divergence,
    /// parallel to the `trace.checkpoints` prefix.
    snaps: Vec<AbstractSnap>,
}

/// Verify the base decision log against the candidate frontier. See the
/// module docs for the per-decision checks; every early return fixes
/// `(d_star, stop)` at the first check that could not be proven.
fn scan(
    base: &DeltaBase,
    policy: &dyn SchedPolicy,
    flat: &FlatDag,
    prio: &[f64],
    affected: &[bool],
    pos_of: &FxHashMap<TaskId, usize>,
) -> ScanOut {
    let mut st = ScanState::new(flat, policy, prio);
    let decisions = &base.trace.decisions;
    let cks = &base.trace.checkpoints;
    let mut snaps: Vec<AbstractSnap> = Vec::new();
    let mut ck_i = 0usize;
    let mut ep = 0usize;
    let mut t_prev = 0.0f64;

    for (d_idx, d) in decisions.iter().enumerate() {
        // (1) round-end drain: the base round at t_prev dispatched its
        // last decision with candidate work still ready — the candidate
        // dispatches at t_prev, the base moved on
        if d_idx > 0 && d.time > t_prev && !st.ready.is_empty() {
            return ScanOut { d_star: d_idx, stop: t_prev, snaps };
        }
        // (2) checkpoint boundaries crossed by this decision: advance the
        // end stream to the checkpoint's loop top and snapshot the
        // candidate arrays there (restore needs them in candidate space)
        while ck_i < cks.len() && cks[ck_i].n_decisions <= d_idx {
            let ck = &cks[ck_i];
            if let Err(e) = process_ends(&mut st, base, policy, prio, pos_of, &mut ep, ck.now, ck.n_decisions) {
                return ScanOut { d_star: d_idx, stop: e, snaps };
            }
            snaps.push(AbstractSnap {
                indeg: st.indeg.clone(),
                release: st.release.clone(),
                ready: st.ready.clone(),
            });
            ck_i += 1;
        }
        // (3) ends up to this decision's round
        if let Err(e) = process_ends(&mut st, base, policy, prio, pos_of, &mut ep, d.time, d_idx) {
            return ScanOut { d_star: d_idx, stop: e, snaps };
        }
        // (3b) checkpoint-boundary leftovers: a batch cut at a
        // checkpoint's loop top (stage 2 consumes it at `e == ck.now`,
        // past process_ends' full-batch drain check) may have released
        // candidate work at a round where the base dispatched nothing —
        // the candidate dispatches there, so the shared prefix ends at
        // that round's loop top
        let lag = min_ready_release(&st);
        if lag < d.time {
            return ScanOut { d_star: d_idx, stop: lag, snaps };
        }
        // (4) the dispatched task must exist in the candidate and sit
        // outside the affected cone
        let Some(&pos) = pos_of.get(&d.task) else {
            return ScanOut { d_star: d_idx, stop: d.time, snaps };
        };
        if affected[pos] {
            return ScanOut { d_star: d_idx, stop: d.time, snaps };
        }
        // (5) the candidate's own argmax (candidate keys, candidate
        // tie-break positions) must pick the same task
        let got = pick_best(st.ready.len(), |i| st.keys[st.ready[i]], |i| st.ready[i]);
        let picked = match got {
            Some(i) if st.ready[i] == pos => i,
            _ => return ScanOut { d_star: d_idx, stop: d.time, snaps },
        };
        // (6) bitwise-identical release (selection sees it)
        if st.release[pos].to_bits() != d.time.to_bits() {
            return ScanOut { d_star: d_idx, stop: d.time, snaps };
        }
        // (7) successor-aware selection also sees the successor tasks
        if let Some(base_succs) = base.succ_ids.get(&d.task) {
            let same = flat.succs[pos].len() == base_succs.len()
                && flat.succs[pos].iter().zip(base_succs).all(|(&s, &id)| flat.tasks[s] == id);
            if !same {
                return ScanOut { d_star: d_idx, stop: d.time, snaps };
            }
        }
        // (8) dispatch
        st.ready.swap_remove(picked);
        t_prev = d.time;
    }

    // whole log verified; anything still ready (or released by the tail
    // of the end stream) dispatches after the base's last round
    let l = decisions.len();
    if !st.ready.is_empty() {
        return ScanOut { d_star: l, stop: min_ready_release(&st), snaps };
    }
    // trailing checkpoints (captured at or after the last decision) are
    // reachable too when everything verified
    while ck_i < cks.len() && cks[ck_i].n_decisions <= l {
        let ck = &cks[ck_i];
        if let Err(e) = process_ends(&mut st, base, policy, prio, pos_of, &mut ep, ck.now, ck.n_decisions) {
            return ScanOut { d_star: l, stop: e, snaps };
        }
        snaps.push(AbstractSnap {
            indeg: st.indeg.clone(),
            release: st.release.clone(),
            ready: st.ready.clone(),
        });
        ck_i += 1;
    }
    if let Err(e) = process_ends(&mut st, base, policy, prio, pos_of, &mut ep, f64::INFINITY, usize::MAX) {
        return ScanOut { d_star: l, stop: e, snaps };
    }
    // work released by the final batches (or a trailing checkpoint's
    // partial batch) that the base never dispatched: the candidate runs
    // past the base's last decision starting at its release round
    if !st.ready.is_empty() {
        return ScanOut { d_star: l, stop: min_ready_release(&st), snaps };
    }
    ScanOut { d_star: l, stop: f64::INFINITY, snaps }
}

/// A ready-to-run incremental evaluation: the engine plan plus the seed
/// trace (verified decision prefix + inherited checkpoints) and the
/// replay statistics the solver aggregates.
pub(crate) struct DeltaPlan<'p> {
    pub plan: ReplayPlan<'p>,
    pub seed: SimTrace,
    /// Decisions proven shared with the base (skipped selection work).
    pub d_star: usize,
    /// Candidate frontier size (total decisions a full run would make).
    pub total: usize,
    /// Decisions recovered by checkpoint restore (no replay loop at all).
    pub restored: usize,
}

/// Scan `flat` against `base` and, if a non-empty prefix verifies, build
/// the [`ReplayPlan`] that restores the latest eligible checkpoint and
/// force-replays the rest of the prefix. `prio` is the candidate's
/// ordering priority vector (critical times for the PL family, zeros
/// otherwise); it moves into the plan so the engine skips its own
/// backflow pass. Returns `None` when nothing verified — the caller
/// falls back to a full simulation.
pub(crate) fn plan_candidate<'p>(
    base: &'p DeltaBase,
    policy: &dyn SchedPolicy,
    flat: &FlatDag,
    prio: Vec<f64>,
) -> Option<DeltaPlan<'p>> {
    debug_assert!(policy_eligible(policy), "delta planning for an ineligible policy");
    let n = flat.len();
    let pos_of: FxHashMap<TaskId, usize> =
        flat.tasks.iter().enumerate().map(|(i, &t)| (t, i)).collect();
    let affected = match changed_span(&base.ids, &flat.tasks) {
        None => vec![false; n],
        Some((lo, hi)) => affected_cone(flat, lo, hi),
    };
    let out = scan(base, policy, flat, &prio, &affected, &pos_of);
    if out.d_star == 0 {
        return None;
    }

    // latest checkpoint whose restore state is a pure function of the
    // verified prefix: captured before d_star decisions, at or before
    // the divergence time
    let eligible =
        |ck: &super::engine::Checkpoint| ck.n_decisions <= out.d_star && ck.now <= out.stop;
    let mut chosen: Option<usize> = None;
    for (i, _) in out.snaps.iter().enumerate() {
        if eligible(&base.trace.checkpoints[i]) {
            chosen = Some(i);
        }
    }
    let inherited: Vec<Arc<super::engine::Checkpoint>> = base
        .trace
        .checkpoints
        .iter()
        .take(out.snaps.len())
        .filter(|ck| eligible(ck))
        .cloned()
        .collect();

    let (ckpt, from, indeg, release, ready) = match chosen {
        Some(i) => {
            let snap = &out.snaps[i];
            let ck = base.trace.checkpoints[i].as_ref();
            (Some(ck), ck.n_decisions, snap.indeg.clone(), snap.release.clone(), snap.ready.clone())
        }
        None => {
            let indeg: Vec<usize> = flat.preds.iter().map(|p| p.len()).collect();
            let ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
            (None, 0, indeg, vec![0.0; n], ready)
        }
    };

    let seed = SimTrace {
        decisions: base.trace.decisions[..from].to_vec(),
        checkpoints: inherited,
    };
    let plan = ReplayPlan {
        ckpt,
        prio,
        indeg,
        release,
        ready,
        forced: &base.trace.decisions[from..out.d_star],
    };
    Some(DeltaPlan { plan, seed, d_star: out.d_star, total: n, restored: from })
}

/// Per-lane completion-state cache: candidates whose whole frontier
/// signature was already simulated under this lane's fixed
/// (machine, policy, seed) reuse the recorded cost without running the
/// engine. Get/insert only — no iteration, so determinism is safe (the
/// `det/map-iteration` lint family) — and unbounded: a lane touches at
/// most `iterations × batch` distinct frontiers, each key a few hundred
/// words.
#[derive(Default)]
pub(crate) struct CostCache {
    map: FxHashMap<Vec<u64>, f64>,
    pub hits: u64,
    pub misses: u64,
}

impl CostCache {
    pub(crate) fn new() -> CostCache {
        CostCache::default()
    }

    pub(crate) fn get(&mut self, key: &[u64]) -> Option<f64> {
        match self.map.get(key) {
            Some(&c) => {
                self.hits += 1;
                Some(c)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    pub(crate) fn insert(&mut self, key: Vec<u64>, cost: f64) {
        self.map.insert(key, cost);
    }

    pub(crate) fn len(&self) -> usize {
        self.map.len()
    }
}

fn kind_code(k: TaskKind) -> u64 {
    match k {
        TaskKind::Potrf => 1,
        TaskKind::Trsm => 2,
        TaskKind::Syrk => 3,
        TaskKind::Gemm => 4,
        TaskKind::Getrf => 5,
        TaskKind::TrsmL => 6,
        TaskKind::TrsmU => 7,
        TaskKind::Geqrt => 8,
        TaskKind::Tsqrt => 9,
        TaskKind::Larfb => 10,
        TaskKind::Ssrfb => 11,
        TaskKind::Custom(x) => 0x100 + x as u64,
    }
}

/// Canonical signature of a frontier: per task, its id, kind, flops and
/// full read/write region lists in frontier order. Two frontiers with
/// equal signatures describe the same computation on the same data
/// blocks, so under a fixed (machine, policy, seed) they simulate to the
/// same schedule. Ids are included — stricter than strictly necessary,
/// but id assignment is itself deterministic (arena order), so re-visits
/// of a frontier on the same base still hit.
pub(crate) fn frontier_signature(dag: &TaskDag, flat: &FlatDag) -> Vec<u64> {
    let mut sig = Vec::with_capacity(flat.len() * 6);
    for &id in &flat.tasks {
        let t = dag.task(id);
        sig.push(id as u64);
        sig.push(kind_code(t.kind));
        sig.push(t.flops.to_bits());
        sig.push(t.reads.len() as u64);
        for r in t.reads.iter().chain(t.writes.iter()) {
            sig.push(r.matrix as u64);
            sig.push(((r.r0 as u64) << 32) | r.r1 as u64);
            sig.push(((r.c0 as u64) << 32) | r.c1 as u64);
        }
    }
    sig
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{
        simulate_flat, simulate_flat_replay, simulate_flat_traced, SimConfig,
    };
    use crate::coordinator::perfmodel::{PerfCurve, PerfDb};
    use crate::coordinator::platform::{Machine, MachineBuilder};
    use crate::coordinator::policies::{Ordering, ProcSelect, SchedConfig};
    use crate::coordinator::policy::policy_for;
    use crate::coordinator::region::Region;
    use crate::coordinator::task::TaskSpec;

    fn machine() -> (Machine, PerfDb) {
        let mut b = MachineBuilder::new("m");
        let h = b.space("host", u64::MAX);
        b.main(h);
        let slow = b.proc_type("slow", 1.0, 0.1);
        let fast = b.proc_type("fast", 1.0, 0.1);
        b.processors(1, "s", slow, h);
        b.processors(2, "f", fast, h);
        let m = b.build();
        let mut db = PerfDb::new();
        db.set_fallback(0, PerfCurve::Const { gflops: 1.0 });
        db.set_fallback(1, PerfCurve::Const { gflops: 4.0 });
        (m, db)
    }

    fn reg(r0: u32, r1: u32) -> Region {
        Region::new(0, r0, r1, 0, 100)
    }

    /// A chain of `k` dependent gemms over one region — every decision
    /// round dispatches exactly one task, so `every = 2` checkpoints
    /// land mid-run.
    fn chain(k: usize) -> TaskDag {
        let r = reg(0, 100);
        let mut dag = TaskDag::new(TaskSpec::new(TaskKind::Potrf, vec![r], vec![r]));
        dag.partition(0, vec![TaskSpec::new(TaskKind::Gemm, vec![r], vec![r]); k], 100);
        dag
    }

    fn pl_eft() -> SimConfig {
        SimConfig::new(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish))
    }

    fn prio_for(dag: &TaskDag, flat: &FlatDag, m: &Machine, db: &PerfDb) -> Vec<f64> {
        crate::coordinator::ordering::critical_times(dag, flat, m, db)
    }

    fn assert_same(a: &Schedule, b: &Schedule, what: &str) {
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{what}: makespan");
        assert_eq!(format!("{:?}", a.assignments), format!("{:?}", b.assignments), "{what}: assignments");
        assert_eq!(format!("{:?}", a.events), format!("{:?}", b.events), "{what}: events");
        assert_eq!(format!("{:?}", a.transfers), format!("{:?}", b.transfers), "{what}: transfers");
    }

    #[test]
    fn changed_span_cases() {
        assert_eq!(changed_span(&[1, 2, 3], &[1, 2, 3]), None);
        // replacement in the middle
        assert_eq!(changed_span(&[1, 2, 3], &[1, 9, 3]), Some((1, 2)));
        // one id expanded into two (partition)
        assert_eq!(changed_span(&[1, 2, 3], &[1, 8, 9, 3]), Some((1, 3)));
        // suffix change
        assert_eq!(changed_span(&[1, 2, 3], &[1, 2, 7, 8]), Some((2, 4)));
        // prefix change
        assert_eq!(changed_span(&[1, 2, 3], &[9, 2, 3]), Some((0, 1)));
        // pure deletion: empty candidate span at the cut point
        assert_eq!(changed_span(&[1, 2, 3], &[1, 3]), Some((1, 1)));
    }

    #[test]
    fn cone_closes_over_successors() {
        let dag = chain(4);
        let flat = dag.flat_dag();
        let affected = affected_cone(&flat, 1, 2);
        assert_eq!(affected, vec![false, true, true, true], "everything downstream of link 1");
    }

    #[test]
    fn delta_mode_parses_and_roundtrips() {
        for m in [DeltaMode::On, DeltaMode::Off, DeltaMode::Auto] {
            assert_eq!(DeltaMode::from_name(m.name()), Some(m));
        }
        assert_eq!(DeltaMode::from_name("bogus"), None);
        assert!(DeltaMode::On.enabled());
        assert!(DeltaMode::Auto.enabled());
        assert!(!DeltaMode::Off.enabled());
    }

    #[test]
    fn identity_candidate_verifies_fully_and_replays_bitwise() {
        let (m, db) = machine();
        let dag = chain(6);
        let flat = dag.flat_dag();
        let mut p = policy_for(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish));
        assert!(policy_eligible(p.as_ref()));
        let (sched, trace) = simulate_flat_traced(&dag, &flat, &m, &db, pl_eft(), p.as_mut(), 2);
        assert!(!trace.checkpoints.is_empty(), "every=2 over a 6-chain must checkpoint");
        let base = DeltaBase::new(trace, &sched, &flat, p.wants_successors());

        let prio = prio_for(&dag, &flat, &m, &db);
        let dp = plan_candidate(&base, p.as_ref(), &flat, prio).expect("identical frontier must verify");
        assert_eq!(dp.d_star, flat.len(), "every decision verifies");
        assert!(dp.restored > 0, "a checkpoint must be eligible");
        assert_eq!(dp.plan.forced.len(), dp.d_star - dp.restored);

        let mut p2 = policy_for(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish));
        let (replayed, tr2) =
            simulate_flat_replay(&dag, &flat, &m, &db, pl_eft(), p2.as_mut(), dp.plan, dp.seed, 0);
        assert_same(&sched, &replayed, "identity replay");
        assert_eq!(tr2.decisions.len(), flat.len());
    }

    #[test]
    fn partitioned_suffix_replays_bitwise_from_a_checkpoint() {
        let (m, db) = machine();
        let dag = chain(6);
        let flat = dag.flat_dag();
        let mut p = policy_for(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish));
        let (sched, trace) = simulate_flat_traced(&dag, &flat, &m, &db, pl_eft(), p.as_mut(), 2);
        let base = DeltaBase::new(trace, &sched, &flat, p.wants_successors());

        // split the last chain link into two independent half-tiles
        let mut dag2 = dag.clone();
        let last = *flat.tasks.last().unwrap();
        dag2.partition(
            last,
            vec![
                TaskSpec::new(TaskKind::Gemm, vec![reg(0, 50)], vec![reg(0, 50)]),
                TaskSpec::new(TaskKind::Gemm, vec![reg(50, 100)], vec![reg(50, 100)]),
            ],
            50,
        );
        let flat2 = dag2.flat_dag();
        assert_eq!(flat2.len(), flat.len() + 1);
        let span = changed_span(&base.ids, &flat2.tasks).expect("frontier changed");
        assert_eq!(span, (flat.len() - 1, flat2.len()), "suffix span");

        let prio2 = prio_for(&dag2, &flat2, &m, &db);
        let dp = plan_candidate(&base, p.as_ref(), &flat2, prio2).expect("shared prefix must verify");
        assert!(dp.d_star >= flat.len() - 1, "all untouched links verify");
        assert!(dp.restored > 0, "mid-run checkpoint must be eligible");

        let mut pa = policy_for(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish));
        let (replayed, _) =
            simulate_flat_replay(&dag2, &flat2, &m, &db, pl_eft(), pa.as_mut(), dp.plan, dp.seed, 0);
        let full = simulate_flat(&dag2, &flat2, &m, &db, pl_eft());
        assert_same(&full, &replayed, "partitioned-suffix replay");
    }

    #[test]
    fn prefix_change_falls_back_to_full_simulation() {
        let (m, db) = machine();
        let dag = chain(4);
        let flat = dag.flat_dag();
        let mut p = policy_for(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish));
        let (sched, trace) = simulate_flat_traced(&dag, &flat, &m, &db, pl_eft(), p.as_mut(), 2);
        let base = DeltaBase::new(trace, &sched, &flat, p.wants_successors());

        // split the FIRST link: the very first decision is in the cone
        let mut dag2 = dag.clone();
        let first = flat.tasks[0];
        dag2.partition(
            first,
            vec![
                TaskSpec::new(TaskKind::Gemm, vec![reg(0, 50)], vec![reg(0, 50)]),
                TaskSpec::new(TaskKind::Gemm, vec![reg(50, 100)], vec![reg(50, 100)]),
            ],
            50,
        );
        let flat2 = dag2.flat_dag();
        let prio2 = prio_for(&dag2, &flat2, &m, &db);
        assert!(
            plan_candidate(&base, p.as_ref(), &flat2, prio2).is_none(),
            "nothing verifiable: caller must run a full simulation"
        );
    }

    #[test]
    fn cost_cache_discriminates_frontiers() {
        let dag = chain(3);
        let flat = dag.flat_dag();
        let sig = frontier_signature(&dag, &flat);
        assert_eq!(sig, frontier_signature(&dag, &flat), "signature is deterministic");

        let mut dag2 = dag.clone();
        let last = *flat.tasks.last().unwrap();
        dag2.partition(
            last,
            vec![
                TaskSpec::new(TaskKind::Gemm, vec![reg(0, 50)], vec![reg(0, 50)]),
                TaskSpec::new(TaskKind::Gemm, vec![reg(50, 100)], vec![reg(50, 100)]),
            ],
            50,
        );
        let flat2 = dag2.flat_dag();
        let sig2 = frontier_signature(&dag2, &flat2);
        assert_ne!(sig, sig2);

        let mut cache = CostCache::new();
        assert_eq!(cache.get(&sig), None);
        cache.insert(sig.clone(), 7.5);
        assert_eq!(cache.get(&sig), Some(7.5));
        assert_eq!(cache.get(&sig2), None);
        assert_eq!((cache.hits, cache.misses), (1, 2));
        assert_eq!(cache.len(), 1);
    }
}
