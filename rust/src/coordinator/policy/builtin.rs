//! The eight Table-1 policies as one parametric [`SchedPolicy`] impl.
//!
//! This is the only place in the crate that still branches on the legacy
//! [`Ordering`]/[`ProcSelect`] enums — the engine, solver and constructive
//! paths all dispatch through the trait. Semantics are bit-identical to
//! the pre-trait enum dispatch (same tie-breaks, same memoization, same
//! PRNG draw sequence), which the determinism tests in
//! `rust/tests/policy_api.rs` pin down.

use crate::coordinator::platform::ProcId;
use crate::coordinator::policies::{Ordering, ProcSelect, SchedConfig};
use crate::coordinator::task::Task;

use super::{SchedContext, SchedPolicy};

/// A Table-1 row: `ordering` picks the ready-queue key, `select` the
/// processor heuristic (paper §2.1).
pub struct BuiltinPolicy {
    cfg: SchedConfig,
    name: String,
}

impl BuiltinPolicy {
    pub fn new(cfg: SchedConfig) -> BuiltinPolicy {
        BuiltinPolicy { name: cfg.name().to_ascii_lowercase(), cfg }
    }

    pub fn config(&self) -> SchedConfig {
        self.cfg
    }
}

impl SchedPolicy for BuiltinPolicy {
    fn name(&self) -> &str {
        &self.name
    }

    fn wants_critical_times(&self) -> bool {
        self.cfg.ordering == Ordering::PriorityList
    }

    // both built-in keys are pure functions of (release, critical_time)
    fn dynamic_order(&self) -> bool {
        false
    }

    // both keys are re-derivable without an event core, so delta replay
    // can verify a recorded decision prefix against them
    fn static_key(&self, release: f64, critical_time: f64) -> Option<f64> {
        Some(match self.cfg.ordering {
            // earliest release pops first (max-heap → negate)
            Ordering::Fcfs => -release,
            // decreasing critical time (backflow upward rank)
            Ordering::PriorityList => critical_time,
        })
    }

    // selection is a pure function of the context except for R-P's draw
    fn select_stateless(&self) -> bool {
        self.cfg.select != ProcSelect::Random
    }

    fn order(&mut self, _ctx: &mut SchedContext<'_>, _task: &Task, release: f64, critical_time: f64) -> f64 {
        self.static_key(release, critical_time).expect("builtin keys are static")
    }

    fn select(&mut self, ctx: &mut SchedContext<'_>, task: &Task, release: f64) -> ProcId {
        match self.cfg.select {
            ProcSelect::Random | ProcSelect::Fastest => {
                // choose among processors idle at the task's release time
                // (paper §2.1). When none is idle the task is bound eagerly
                // anyway — R-P queues on a uniformly random processor and
                // F-P on the one fastest for the task, which is what
                // produces the low processor loads of the R-P/F-P rows in
                // Table 1 (work piling up on the fast processors while the
                // rest drain).
                let idle = ctx.idle_procs(release);
                let cands: Vec<ProcId> = if idle.is_empty() { (0..ctx.n_procs()).collect() } else { idle };
                match self.cfg.select {
                    ProcSelect::Random => *ctx.rng.choose(&cands),
                    _ => *cands
                        .iter()
                        .min_by(|&&a, &&b| {
                            ctx.exec_time(task, a).total_cmp(&ctx.exec_time(task, b)).then(a.cmp(&b))
                        })
                        .unwrap(),
                }
            }
            ProcSelect::EarliestIdle => (0..ctx.n_procs())
                .min_by(|&a, &b| ctx.proc_avail(a).total_cmp(&ctx.proc_avail(b)).then(a.cmp(&b)))
                .unwrap(),
            ProcSelect::EarliestFinish => ctx.earliest_finish(task, release).1,
        }
    }
}
