//! One-step lookahead EFT (`pl/lookahead`).
//!
//! Plain EFT-P is myopic: it minimizes the popped task's own finish time,
//! even when a marginally later finish on a faster processor would leave
//! the task's critical successor far better placed. This policy extends
//! the EFT estimate one dependence edge forward — the second policy the
//! old enum API could not express, because selection needs visibility into
//! the task's successor set ([`super::SchedContext::successors`]).
//!
//! Selection key, minimized: `finish(task, p) + exec_time(heaviest
//! immediate successor, p)` — the finish of the chain's next link if it
//! stayed on the same processor. `finish` comes from the shared
//! timeline-aware [`super::SchedContext::placement_estimates`] scan
//! (gap backfill and per-link queuing included). Tasks without
//! successors degrade to plain EFT-P exactly.

use crate::coordinator::platform::ProcId;
use crate::coordinator::task::Task;

use super::{SchedContext, SchedPolicy};

/// Priority-list ordering + successor-aware EFT selection.
#[derive(Default)]
pub struct LookaheadEftPolicy;

impl LookaheadEftPolicy {
    pub fn new() -> LookaheadEftPolicy {
        LookaheadEftPolicy
    }
}

impl SchedPolicy for LookaheadEftPolicy {
    fn name(&self) -> &str {
        "pl/lookahead"
    }

    fn wants_critical_times(&self) -> bool {
        true
    }

    fn wants_successors(&self) -> bool {
        true
    }

    // the key is the (static) critical time — no re-keying needed
    fn dynamic_order(&self) -> bool {
        false
    }

    fn static_key(&self, _release: f64, critical_time: f64) -> Option<f64> {
        Some(critical_time)
    }

    // pure function of (ctx, task, successors); the delta verifier
    // additionally checks successor-set equality before skipping it
    fn select_stateless(&self) -> bool {
        true
    }

    fn order(&mut self, _ctx: &mut SchedContext<'_>, _task: &Task, _release: f64, critical_time: f64) -> f64 {
        critical_time
    }

    fn select(&mut self, ctx: &mut SchedContext<'_>, task: &Task, release: f64) -> ProcId {
        // the heaviest immediate successor carries the chain forward;
        // deterministic tie-break by task id
        let heavy: Option<&Task> = ctx
            .successors
            .iter()
            .copied()
            .max_by(|a, b| a.flops.total_cmp(&b.flops).then(b.id.cmp(&a.id)));
        let mut la_time: Vec<f64> = vec![f64::NAN; ctx.machine.proc_types.len()];
        let mut best = (f64::INFINITY, 0usize);
        for (p, fin, _) in ctx.placement_estimates(task, release) {
            let la = match heavy {
                Some(s) => {
                    let ty = ctx.machine.procs[p].ptype;
                    if la_time[ty].is_nan() {
                        la_time[ty] = ctx.exec_time(s, p);
                    }
                    la_time[ty]
                }
                None => 0.0,
            };
            let score = fin + la;
            if score < best.0 {
                best = (score, p);
            }
        }
        best.1
    }
}
