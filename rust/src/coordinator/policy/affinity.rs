//! Transfer-aware affinity policy (`pl/affinity`).
//!
//! XKaapi-style data-aware selection (Bleuse et al., "Scheduling Data Flow
//! Program in XKaapi", arXiv:1402.6601): prefer the processor whose memory
//! space already holds the task's input regions, falling back to finish
//! time only to break affinity ties. This is the first policy the old
//! enum API structurally could not express — it needs the coherence /
//! data-placement state at selection time, which [`super::SchedContext`]
//! now exposes.
//!
//! Selection key, minimized lexicographically:
//! `(pending input bytes into the processor's space, finish time, proc id)`.
//! Both terms come from [`super::SchedContext::placement_estimates`], so
//! under the event core they are timeline-aware: finish times account for
//! link queuing resolved in simulated-time order and for idle windows a
//! task can backfill. On a transfer-heavy DAG this trades some load
//! balance for locality, cutting `Schedule::transfer_bytes` relative to
//! EFT-P (checked in `rust/tests/policy_api.rs`).

use crate::coordinator::platform::ProcId;
use crate::coordinator::task::Task;

use super::{SchedContext, SchedPolicy};

/// Priority-list ordering + affinity-first processor selection.
#[derive(Default)]
pub struct AffinityPolicy;

impl AffinityPolicy {
    pub fn new() -> AffinityPolicy {
        AffinityPolicy
    }
}

impl SchedPolicy for AffinityPolicy {
    fn name(&self) -> &str {
        "pl/affinity"
    }

    fn wants_critical_times(&self) -> bool {
        true
    }

    // the key is the (static) critical time — no re-keying needed
    fn dynamic_order(&self) -> bool {
        false
    }

    fn static_key(&self, _release: f64, critical_time: f64) -> Option<f64> {
        Some(critical_time)
    }

    // selection reads only the context (placement estimates) — no state,
    // no RNG — so delta replay may skip it on a verified prefix
    fn select_stateless(&self) -> bool {
        true
    }

    fn order(&mut self, _ctx: &mut SchedContext<'_>, _task: &Task, _release: f64, critical_time: f64) -> f64 {
        critical_time
    }

    fn select(&mut self, ctx: &mut SchedContext<'_>, task: &Task, release: f64) -> ProcId {
        let mut best: (u64, f64, ProcId) = (u64::MAX, f64::INFINITY, 0);
        for (p, fin, bytes) in ctx.placement_estimates(task, release) {
            if bytes < best.0 || (bytes == best.0 && fin < best.1) {
                best = (bytes, fin, p);
            }
        }
        best.2
    }
}
