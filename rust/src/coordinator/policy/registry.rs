//! String-keyed policy construction.
//!
//! Configs (`policy = "pl/eft-p"` in a platform TOML), the CLI
//! (`--policy pl/affinity`) and the benches all build policies by name, so
//! adding a policy means registering one builder — no call-site edits.

use crate::coordinator::policies::{Ordering, ProcSelect, SchedConfig};

use super::{
    AffinityPolicy, BuiltinPolicy, DeadlinePolicy, DlsPolicy, HeftPolicy, LookaheadEftPolicy, PeftPolicy,
    SchedPolicy, ShortestJobPolicy,
};

type Builder = Box<dyn Fn() -> Box<dyn SchedPolicy> + Send + Sync>;

/// Registry mapping canonical lowercase names to policy builders,
/// preserving registration order for listings.
pub struct PolicyRegistry {
    entries: Vec<(String, Builder)>,
}

impl Default for PolicyRegistry {
    fn default() -> Self {
        PolicyRegistry::standard()
    }
}

impl PolicyRegistry {
    pub fn empty() -> PolicyRegistry {
        PolicyRegistry { entries: Vec::new() }
    }

    /// The built-in set: the eight Table-1 rows (`fcfs/r-p` ... `pl/eft-p`)
    /// plus `pl/affinity`, `pl/lookahead`, the job-aware service-mode
    /// pair `pl/edf-p` / `pl/sjf-p`, and the classic literature baselines
    /// `cls/heft`, `cls/peft`, `cls/dls`.
    pub fn standard() -> PolicyRegistry {
        let mut reg = PolicyRegistry::empty();
        for row in SchedConfig::table1_rows() {
            reg.register(&row.name().to_ascii_lowercase(), move || {
                Box::new(BuiltinPolicy::new(row)) as Box<dyn SchedPolicy>
            });
        }
        reg.register("pl/affinity", || Box::new(AffinityPolicy::new()) as Box<dyn SchedPolicy>);
        reg.register("pl/lookahead", || Box::new(LookaheadEftPolicy::new()) as Box<dyn SchedPolicy>);
        reg.register("pl/edf-p", || Box::new(DeadlinePolicy::new()) as Box<dyn SchedPolicy>);
        reg.register("pl/sjf-p", || Box::new(ShortestJobPolicy::new()) as Box<dyn SchedPolicy>);
        reg.register("cls/heft", || Box::new(HeftPolicy::new()) as Box<dyn SchedPolicy>);
        reg.register("cls/peft", || Box::new(PeftPolicy::new()) as Box<dyn SchedPolicy>);
        reg.register("cls/dls", || Box::new(DlsPolicy::new()) as Box<dyn SchedPolicy>);
        reg
    }

    /// Register (or replace) a builder under `name` (stored lowercase).
    pub fn register<F>(&mut self, name: &str, builder: F)
    where
        F: Fn() -> Box<dyn SchedPolicy> + Send + Sync + 'static,
    {
        let name = name.to_ascii_lowercase();
        self.entries.retain(|(n, _)| *n != name);
        self.entries.push((name, Box::new(builder)));
    }

    /// Construct a fresh policy by name (case-insensitive). Besides exact
    /// registered names, accepts the legacy aliases the CLI always took:
    /// `"<ordering>/<select>"` with the enum spellings (`"pl/eft"`,
    /// `"fcfs/random"`, ...) and bare suffixes (`"affinity"`, `"heft"`,
    /// ...) — but only when the suffix matches exactly one registered
    /// name. An ambiguous bare suffix (`"r-p"` matches both `fcfs/r-p`
    /// and `pl/r-p`) resolves to nothing; [`PolicyRegistry::resolve`]
    /// reports the candidate list.
    pub fn get(&self, name: &str) -> Option<Box<dyn SchedPolicy>> {
        self.resolve(name).ok()
    }

    /// [`PolicyRegistry::get`] with diagnosable failure: `Err` carries
    /// either the candidate list of an ambiguous bare suffix or an
    /// unknown-name message, ready for CLI error output.
    pub fn resolve(&self, name: &str) -> Result<Box<dyn SchedPolicy>, String> {
        let key = name.to_ascii_lowercase();
        if let Some((_, b)) = self.entries.iter().find(|(n, _)| *n == key) {
            return Ok(b());
        }
        // bare suffix: "affinity" == "pl/affinity", "heft" == "cls/heft".
        // Only an unambiguous suffix resolves — "r-p" names both fcfs/r-p
        // and pl/r-p, and silently preferring one of them misreports every
        // comparison that meant the other
        if !key.contains('/') {
            let cands: Vec<&(String, Builder)> = self
                .entries
                .iter()
                .filter(|(n, _)| n.rsplit_once('/').is_some_and(|(_, suffix)| suffix == key))
                .collect();
            match cands.as_slice() {
                [(_, b)] => return Ok(b()),
                [] => {}
                _ => {
                    let names: Vec<&str> = cands.iter().map(|(n, _)| n.as_str()).collect();
                    return Err(format!(
                        "ambiguous policy name '{name}': could be any of {}",
                        names.join(", ")
                    ));
                }
            }
        }
        // legacy enum spellings ("pl/eft", "fcfs/random", ...) resolve to
        // the canonical Table-1 name and re-enter THIS registry's entries,
        // so overrides and removals are honored (an alias must construct
        // the same policy as its canonical name)
        if let Some((ord, sel)) = key.split_once('/') {
            if let (Some(o), Some(s)) = (Ordering::from_name(ord), ProcSelect::from_name(sel)) {
                let canonical = SchedConfig::new(o, s).name().to_ascii_lowercase();
                if canonical != key {
                    if let Some((_, b)) = self.entries.iter().find(|(n, _)| *n == canonical) {
                        return Ok(b());
                    }
                }
            }
        }
        Err(format!("unknown policy '{name}' (`hesp policies` lists the registry)"))
    }

    /// Registered canonical names, in registration order.
    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|(n, _)| n.as_str()).collect()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Construct a policy from the standard registry — the one-liner the CLI
/// and configs use.
pub fn policy_by_name(name: &str) -> Option<Box<dyn SchedPolicy>> {
    PolicyRegistry::standard().get(name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_has_table1_plus_seven() {
        let reg = PolicyRegistry::standard();
        assert_eq!(reg.len(), 15);
        let names = reg.names();
        for expect in [
            "fcfs/r-p",
            "pl/r-p",
            "fcfs/eft-p",
            "pl/eft-p",
            "pl/affinity",
            "pl/lookahead",
            "pl/edf-p",
            "pl/sjf-p",
            "cls/heft",
            "cls/peft",
            "cls/dls",
        ] {
            assert!(names.contains(&expect), "{expect} missing from {names:?}");
        }
    }

    #[test]
    fn lookup_is_case_insensitive_with_aliases() {
        let reg = PolicyRegistry::standard();
        assert_eq!(reg.get("PL/EFT-P").unwrap().name(), "pl/eft-p");
        assert_eq!(reg.get("pl/eft").unwrap().name(), "pl/eft-p");
        assert_eq!(reg.get("fcfs/random").unwrap().name(), "fcfs/r-p");
        assert_eq!(reg.get("affinity").unwrap().name(), "pl/affinity");
        assert_eq!(reg.get("lookahead").unwrap().name(), "pl/lookahead");
        assert_eq!(reg.get("edf-p").unwrap().name(), "pl/edf-p");
        assert_eq!(reg.get("sjf-p").unwrap().name(), "pl/sjf-p");
        assert_eq!(reg.get("HEFT").unwrap().name(), "cls/heft");
        assert_eq!(reg.get("peft").unwrap().name(), "cls/peft");
        assert_eq!(reg.get("dls").unwrap().name(), "cls/dls");
        assert!(reg.get("pl/zzz").is_none());
        assert!(reg.get("zzz").is_none());
    }

    #[test]
    fn ambiguous_bare_suffix_is_an_error_listing_candidates() {
        let reg = PolicyRegistry::standard();
        // "r-p" names both fcfs/r-p and pl/r-p — the old lookup silently
        // handed back the pl/ variant
        assert!(reg.get("r-p").is_none());
        let err = reg.resolve("r-p").unwrap_err();
        assert!(err.contains("fcfs/r-p") && err.contains("pl/r-p"), "candidates missing: {err}");
        assert!(reg.get("eft-p").is_none(), "eft-p is fcfs/eft-p or pl/eft-p");
        // an unambiguous suffix still resolves...
        assert_eq!(reg.resolve("heft").unwrap().name(), "cls/heft");
        // ...and unknown names say so
        let unknown = reg.resolve("zzz").unwrap_err();
        assert!(unknown.contains("unknown policy"), "{unknown}");
    }

    #[test]
    fn aliases_resolve_through_this_registry() {
        // an empty registry resolves nothing, aliases included
        assert!(PolicyRegistry::empty().get("fcfs/random").is_none());
        assert!(PolicyRegistry::empty().get("eft-p").is_none());
        // an alias must construct whatever its canonical name constructs
        let mut reg = PolicyRegistry::standard();
        reg.register("pl/eft-p", || Box::new(AffinityPolicy::new()) as Box<dyn SchedPolicy>);
        assert_eq!(reg.get("pl/eft").unwrap().name(), "pl/affinity", "alias follows the override");
    }

    #[test]
    fn user_registration_and_replacement() {
        use crate::coordinator::policies::{Ordering, ProcSelect};
        let mut reg = PolicyRegistry::empty();
        assert!(reg.is_empty());
        reg.register("mine", || {
            Box::new(BuiltinPolicy::new(SchedConfig::new(Ordering::Fcfs, ProcSelect::EarliestIdle)))
                as Box<dyn SchedPolicy>
        });
        assert_eq!(reg.len(), 1);
        assert!(reg.get("MINE").is_some());
        // replacement keeps a single entry
        reg.register("mine", || Box::new(AffinityPolicy::new()) as Box<dyn SchedPolicy>);
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.get("mine").unwrap().name(), "pl/affinity");
    }
}
