//! The pluggable scheduling-policy layer.
//!
//! HeSP's stated goal (§5) is that "insights extracted from the framework
//! can be further applied to actual runtime task schedulers" — which
//! requires the framework to accept *user-defined* policies, not only the
//! four baked-in heuristics of Table 1. This module turns scheduling into
//! an open trait API (the dslab-dag `Scheduler`-trait idea, adapted to
//! HeSP's eagerly-binding list scheduler):
//!
//! * [`SchedPolicy`] — the trait every policy implements: [`SchedPolicy::order`]
//!   produces the ready-queue priority key of a task, [`SchedPolicy::select`]
//!   maps a popped task to a processor.
//! * [`SchedContext`] — the view of simulator state a policy may consult at
//!   decision time: the event clock (`now`), per-processor and per-link
//!   occupancy timelines (bookable gaps, not scalar availability), the
//!   coherence / data-placement state, the performance model, and the
//!   popped task's successor tasks (for lookahead).
//! * [`PolicyRegistry`] — string-keyed construction (`"pl/eft-p"`,
//!   `"pl/affinity"`, ...) so configs, the CLI and benches build policies
//!   by name; user policies register under new names.
//!
//! Built-ins: the eight Table-1 rows ([`BuiltinPolicy`], the enum shim in
//! [`super::policies`] maps onto them) plus two policies the old
//! enum-dispatched API could not express — [`AffinityPolicy`]
//! (data-placement-aware, XKaapi-style; Bleuse et al., arXiv:1402.6601)
//! and [`LookaheadEftPolicy`] (EFT with one-step successor lookahead) —
//! and two job-aware service-mode policies ([`DeadlinePolicy`],
//! [`ShortestJobPolicy`]) that read the owning job's identity from
//! [`SchedContext::job`] when the service layer attaches one. The
//! `cls/` namespace holds the classic list schedulers of the
//! heterogeneous-scheduling literature ([`HeftPolicy`], [`PeftPolicy`],
//! [`DlsPolicy`]) — the baselines the gauntlet bench measures the solver
//! against.
//!
//! The engine, the iterative solver and the constructive scheduler all
//! dispatch through `&mut dyn SchedPolicy`; no execution path matches on
//! the legacy enums anymore.

mod affinity;
mod builtin;
mod classic;
mod jobaware;
mod lookahead;
mod registry;

pub use affinity::AffinityPolicy;
pub use builtin::BuiltinPolicy;
pub use classic::{DlsPolicy, HeftPolicy, PeftPolicy};
pub use jobaware::{DeadlinePolicy, ShortestJobPolicy};
pub use lookahead::LookaheadEftPolicy;
pub use registry::{policy_by_name, PolicyRegistry};

use super::coherence::{Coherence, SpaceId, Transfer};
use super::datadag::BlockId;
use super::perfmodel::PerfDb;
use super::platform::{Machine, ProcId, Timeline};
use super::policies::SchedConfig;
use super::task::Task;
use super::taskdag::{FlatDag, TaskDag};
use crate::util::fxhash::FxHashMap;
use crate::util::rng::Rng;

/// Physical arrival times of committed-but-in-flight blocks, keyed by
/// `(block, destination space)`. Coherence validity flips at commit time
/// (so a second reader of the same block does not double-fetch it); this
/// table records when the bytes actually land, and both the estimate
/// path ([`plan_reads`]) and the engine's commit gate on it via
/// [`arrival_gate`].
pub type ArrivalTable = FxHashMap<(BlockId, SpaceId), f64>;

/// Latest physical-arrival instant among `task`'s input blocks that are
/// already valid in `space` but still in flight — fetched by an earlier
/// decision, landing later. Checks both containing blocks (an in-flight
/// ancestor covers the read) and contained ones (the read's content may
/// exist only as in-flight fragments that `read_plan` treats as local).
/// Returns `base` raised to the latest such arrival.
pub fn arrival_gate(
    coh: &mut Coherence,
    arrivals: &ArrivalTable,
    task: &Task,
    space: SpaceId,
    base: f64,
) -> f64 {
    let mut ready = base;
    if arrivals.is_empty() {
        return ready;
    }
    for r in task.reads.iter() {
        let b = coh.register(*r);
        let region = coh.dag.block(b).region;
        let candidates = coh.dag.containing(&region).into_iter().chain(coh.dag.contained_in(&region));
        for cand in candidates {
            if let Some(&t) = arrivals.get(&(cand, space)) {
                if t > ready && coh.is_valid(cand, space) {
                    ready = t;
                }
            }
        }
    }
    ready
}

/// The shared transfer-cost model: earliest time `task`'s inputs can be
/// resident in `space` starting transfers at `at` (given the current link
/// timelines and the in-flight [`ArrivalTable`]), plus the planned
/// `(parent block, transfer)` pairs. The engine's commit path books
/// through the same [`Timeline::earliest_fit`] arithmetic and applies the
/// same [`arrival_gate`], so the estimate cannot drift from what gets
/// simulated — including gap backfill, where a transfer slots into an
/// idle link window left open by earlier bookings.
///
/// Each planned transfer is estimated independently against the current
/// timelines (the first one booked matches exactly; later ones may shift
/// once their predecessors occupy the links).
pub fn plan_reads(
    machine: &Machine,
    links: &[Timeline],
    coh: &mut Coherence,
    arrivals: &ArrivalTable,
    task: &Task,
    space: SpaceId,
    at: f64,
) -> (f64, Vec<(BlockId, Transfer)>) {
    let mut ready = at;
    let mut planned = Vec::new();
    for r in task.reads.iter() {
        let block = coh.register(*r);
        for tr in coh.read_plan(block, space) {
            debug_assert_ne!(tr.from, tr.to, "coherence planned a same-space transfer");
            let mut t = at;
            for lid in machine.route(tr.from, tr.to) {
                let l = &machine.links[lid];
                let dur = l.latency + tr.bytes as f64 / l.bandwidth;
                t = links[lid].earliest_fit(t, dur) + dur;
            }
            ready = ready.max(t);
            planned.push((block, tr));
        }
    }
    (arrival_gate(coh, arrivals, task, space, ready), planned)
}

/// Everything the simulator knows at a scheduling decision point.
///
/// Borrowed views of live engine state: a context is constructed per call
/// and must not be stored. `coh` and `rng` are mutable because estimating
/// data-ready times registers read regions in the data DAG, and stochastic
/// policies draw from the simulation's seeded generator (which keeps runs
/// reproducible per seed).
pub struct SchedContext<'a> {
    pub machine: &'a Machine,
    pub db: &'a PerfDb,
    /// The global event clock: the simulated time this decision is taken
    /// at. Ready-queue keys are recomputed at decision time, so a policy
    /// reading `now` (or any timeline) always sees current state, never
    /// the state at push time.
    pub now: f64,
    /// Per-processor booked execution timelines (bookable gaps, not
    /// scalar availability).
    pub procs: &'a [Timeline],
    /// Per-link booked transfer timelines.
    pub links: &'a [Timeline],
    /// In-flight block arrivals — when committed transfers physically
    /// land (estimates gate on this exactly as the engine does).
    pub arrivals: &'a ArrivalTable,
    /// Coherence / data-placement state (which space holds which block).
    pub coh: &'a mut Coherence,
    /// The simulation's seeded PRNG.
    pub rng: &'a mut Rng,
    /// The popped task's immediate successor tasks. Populated only inside
    /// [`SchedPolicy::select`] and only when the policy opts in via
    /// [`SchedPolicy::wants_successors`]; empty otherwise.
    pub successors: &'a [&'a Task],
    /// Identity of the job this task belongs to, attached by the service
    /// layer's multi-job loop ([`super::service`]). `None` in every
    /// single-DAG simulation — job-aware policies must degrade to a
    /// job-oblivious fallback when absent.
    pub job: Option<JobInfo>,
}

/// What a job-aware policy may know about the job that owns the task
/// under decision: its admission order, arrival instant, absolute
/// deadline (`f64::INFINITY` when none was declared) and critical-path /
/// area makespan lower bound ([`super::lower_bound`]) — enough to
/// implement EDF- and shortest-job-style orderings without exposing the
/// service layer's internal queue state.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JobInfo {
    /// Admission-order job id (unique within one stream).
    pub id: usize,
    /// When the job arrived at the cluster.
    pub arrival: f64,
    /// Absolute completion deadline; `f64::INFINITY` if none.
    pub deadline: f64,
    /// The job DAG's makespan lower bound — a size proxy for
    /// shortest-job-first orderings and slowdown metrics.
    pub lower_bound: f64,
}

impl SchedContext<'_> {
    pub fn n_procs(&self) -> usize {
        self.machine.n_procs()
    }

    /// Predicted execution time of `task` on processor `proc`.
    pub fn exec_time(&self, task: &Task, proc: ProcId) -> f64 {
        self.db.time(self.machine.procs[proc].ptype, task.kind, task.char_edge(), task.flops)
    }

    /// Time processor `proc`'s booked work drains (the tail of its
    /// timeline — the quantity the scalar engine called `proc_avail`).
    /// Gap-aware placement goes through [`SchedContext::placement_estimates`],
    /// which can start a task inside an idle window before this instant.
    pub fn proc_avail(&self, proc: ProcId) -> f64 {
        self.procs[proc].tail()
    }

    /// Processors idle at time `release` with no booked work after it
    /// (paper §2.1's "idle at release").
    pub fn idle_procs(&self, release: f64) -> Vec<ProcId> {
        let eps = 1e-12;
        (0..self.n_procs()).filter(|&p| !self.procs[p].busy_after(release + eps)).collect()
    }

    /// Earliest time `task`'s inputs can be resident in `space`, starting
    /// transfers at `release`, accounting for current link bookings
    /// (without committing any transfer).
    pub fn data_ready_at(&mut self, task: &Task, space: SpaceId, release: f64) -> f64 {
        plan_reads(self.machine, self.links, self.coh, self.arrivals, task, space, release).0
    }

    /// Bytes that must move over the interconnect for `task`'s reads to be
    /// resident in `space` (0 = full affinity: every input already there).
    pub fn pending_read_bytes(&mut self, task: &Task, space: SpaceId) -> u64 {
        plan_reads(self.machine, self.links, self.coh, self.arrivals, task, space, 0.0)
            .1
            .iter()
            .map(|(_, tr)| tr.bytes)
            .sum()
    }

    /// Per-processor insertion-based placement details `(proc, start,
    /// finish, pending input bytes)` — `start` is
    /// `earliest_fit(data ready, exec)` on the processor's booked
    /// timeline, so the task can slot into an idle gap *before*
    /// already-booked work (HEFT's "insertion policy"; the commit path
    /// books through the same arithmetic, so estimates cannot drift) —
    /// from ONE shared [`plan_reads`] walk per memory space, memoized per
    /// space and per processor type (28 procs → 4 spaces x 3 types on
    /// BUJARUELO). The shared scan behind every placement-scoring policy.
    pub fn placement_details(&mut self, task: &Task, release: f64) -> Vec<(ProcId, f64, f64, u64)> {
        let mut per_space: Vec<Option<(f64, u64)>> = vec![None; self.machine.spaces.len()];
        let mut type_time: Vec<f64> = vec![f64::NAN; self.machine.proc_types.len()];
        let mut out = Vec::with_capacity(self.n_procs());
        for p in 0..self.n_procs() {
            let sp = self.machine.procs[p].space;
            let (ready, bytes) = match per_space[sp] {
                Some(v) => v,
                None => {
                    let (r, planned) =
                        plan_reads(self.machine, self.links, self.coh, self.arrivals, task, sp, release);
                    let v = (r, planned.iter().map(|(_, tr)| tr.bytes).sum::<u64>());
                    per_space[sp] = Some(v);
                    v
                }
            };
            let ty = self.machine.procs[p].ptype;
            if type_time[ty].is_nan() {
                type_time[ty] = self.exec_time(task, p);
            }
            let start = self.procs[p].earliest_fit(ready, type_time[ty]);
            out.push((p, start, start + type_time[ty], bytes));
        }
        out
    }

    /// [`SchedContext::placement_details`] without the start column:
    /// `(proc, finish, pending input bytes)` per processor.
    pub fn placement_estimates(&mut self, task: &Task, release: f64) -> Vec<(ProcId, f64, u64)> {
        self.placement_details(task, release).into_iter().map(|(p, _, fin, b)| (p, fin, b)).collect()
    }

    /// The EFT-P core: the processor finishing `task` first (transfer- and
    /// queue-aware). Ties break toward the lower processor id.
    pub fn earliest_finish(&mut self, task: &Task, release: f64) -> (f64, ProcId) {
        let mut best = (f64::INFINITY, 0usize);
        for (p, fin, _) in self.placement_estimates(task, release) {
            if fin < best.0 {
                best = (fin, p);
            }
        }
        best
    }
}

/// A scheduling policy: task ordering + processor selection.
///
/// Implementations may keep internal state (`&mut self`); the simulator
/// constructs (or receives) one policy value per run. Determinism contract:
/// for a fixed `SimConfig::seed`, a policy must make identical decisions
/// across runs — draw randomness only from [`SchedContext::rng`].
pub trait SchedPolicy {
    /// Registry-canonical name, e.g. `"pl/eft-p"` (lowercase).
    fn name(&self) -> &str;

    /// Whether [`SchedPolicy::order`] consumes backflow critical times
    /// (upward ranks). The engine computes them only when asked — FCFS-like
    /// orderings skip the O(V+E) pass.
    fn wants_critical_times(&self) -> bool {
        false
    }

    /// Whether [`SchedPolicy::select`] reads [`SchedContext::successors`].
    /// The engine materializes the successor-task list only when asked —
    /// dispatch is a measured hot path, and most policies never look ahead.
    fn wants_successors(&self) -> bool {
        false
    }

    /// One-shot rank pass over the whole frontier, run before the first
    /// decision of a single-DAG simulation. Returning `Some(ranks)`
    /// (one value per frontier position) replaces the priority vector the
    /// engine would otherwise compute — [`super::ordering::critical_times`]
    /// when [`SchedPolicy::wants_critical_times`], zeros otherwise — and
    /// each task's value arrives in [`SchedPolicy::order`] as its
    /// `critical_time` argument. The comm-aware classics hook in here:
    /// `cls/heft` returns upward ranks, `cls/peft` builds its optimistic
    /// cost table and returns the mean-OCT ranks.
    ///
    /// Contract: a policy returning `Some` must keep the default
    /// [`SchedPolicy::static_key`] of `None` — the delta evaluator
    /// re-derives keys from comm-free critical times and would diverge
    /// from a custom rank vector. The streaming service layer never calls
    /// this hook (task ids collide across concurrently-resident jobs, so
    /// id-keyed rank state would be wrong there); policies degrade to
    /// their `wants_critical_times` ordering in serve mode.
    fn rank_tasks(
        &mut self,
        dag: &TaskDag,
        flat: &FlatDag,
        machine: &Machine,
        db: &PerfDb,
        elem_bytes: u64,
    ) -> Option<Vec<f64>> {
        let _ = (dag, flat, machine, db, elem_bytes);
        None
    }

    /// Whether ordering keys depend on mutable simulator state and must
    /// be recomputed at every decision (the default, and always safe).
    /// Policies whose key is a pure function of `(release, critical_time)`
    /// — all the built-ins — return `false`, letting the engine compute
    /// each key once at release instead of re-keying the whole ready set
    /// per pick (an O(ready²) saving on wide frontiers).
    fn dynamic_order(&self) -> bool {
        true
    }

    /// The ordering key as a pure function of `(release, critical_time)`
    /// — bitwise what [`SchedPolicy::order`] returns for this policy when
    /// no job is attached to the context. Returning `Some` opts the
    /// policy into incremental (delta) candidate evaluation in the
    /// portfolio solver ([`super::delta`]): the delta verifier re-derives
    /// ready-queue keys without an event core, so the value must equal
    /// `order`'s result bit for bit — implementations should make `order`
    /// delegate to this. `None` (the default) excludes the policy from
    /// delta replay and the solver falls back to full re-simulation.
    fn static_key(&self, release: f64, critical_time: f64) -> Option<f64> {
        let _ = (release, critical_time);
        None
    }

    /// Whether [`SchedPolicy::select`] is a pure function of the context
    /// and its arguments: no internal mutable state, no RNG draws.
    /// Stateless selection lets the delta evaluator replay a recorded
    /// decision prefix without re-invoking `select` (identical context
    /// state implies the identical processor). Stochastic or stateful
    /// policies (e.g. the `r-p` builtins) must keep the default `false`
    /// and take the full-simulation path.
    fn select_stateless(&self) -> bool {
        false
    }

    /// Priority key of a ready task. The engine dispatches the *largest*
    /// key first, ties broken toward program order. FCFS is `-release`;
    /// priority-list is the critical time.
    ///
    /// For dynamic-order policies (the [`SchedPolicy::dynamic_order`]
    /// default) keys are recomputed **at decision time**: the event core
    /// calls `order` for every still-ready task each time it picks the
    /// next one to dispatch, so the key may consult live state
    /// (`ctx.now`, the processor/link timelines, coherence) and is never
    /// stale. A policy must therefore treat `order` as a pure function
    /// of `ctx` and its own state — it can be called several times per
    /// task per run. Static-key policies (`dynamic_order() == false`)
    /// are called exactly once per task, when it is released.
    fn order(&mut self, ctx: &mut SchedContext<'_>, task: &Task, release: f64, critical_time: f64) -> f64;

    /// Processor for a popped ready task.
    fn select(&mut self, ctx: &mut SchedContext<'_>, task: &Task, release: f64) -> ProcId;
}

/// The enum-shim constructor: a boxed built-in policy for a legacy
/// [`SchedConfig`] pair. `SimConfig::new(...)` paths funnel through this,
/// which is what keeps the old API compiling unchanged.
pub fn policy_for(cfg: SchedConfig) -> Box<dyn SchedPolicy> {
    Box::new(BuiltinPolicy::new(cfg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::coherence::CachePolicy;
    use crate::coordinator::perfmodel::PerfCurve;
    use crate::coordinator::platform::MachineBuilder;
    use crate::coordinator::policies::{Ordering, ProcSelect};
    use crate::coordinator::region::Region;
    use crate::coordinator::task::{TaskKind, TaskSpec};
    use crate::coordinator::taskdag::TaskDag;

    fn gpu_machine() -> (Machine, PerfDb) {
        let mut b = MachineBuilder::new("g");
        let h = b.space("host", u64::MAX);
        let g = b.space("gpu", u64::MAX);
        b.main(h);
        b.connect(h, g, 1e-5, 1e9);
        let cpu = b.proc_type("cpu", 1.0, 0.1);
        let gpu = b.proc_type("gpu", 1.0, 0.1);
        b.processors(1, "c", cpu, h);
        b.processors(1, "g", gpu, g);
        let m = b.build();
        let mut db = PerfDb::new();
        db.set_fallback(0, PerfCurve::Const { gflops: 1.0 });
        db.set_fallback(1, PerfCurve::Const { gflops: 10.0 });
        (m, db)
    }

    fn one_task() -> TaskDag {
        let r = Region::new(0, 0, 100, 0, 100);
        TaskDag::new(TaskSpec::new(TaskKind::Gemm, vec![r], vec![r]))
    }

    #[test]
    fn context_estimates_match_machine_model() {
        let (m, db) = gpu_machine();
        let dag = one_task();
        let task = dag.task(dag.root).clone();
        let mut coh = Coherence::new(m.spaces.len(), m.main_space, CachePolicy::WriteBack, m.capacities(), 4);
        let mut rng = Rng::new(0);
        let procs = vec![Timeline::new(); m.n_procs()];
        let links = vec![Timeline::new(); m.links.len()];
        let arrivals = ArrivalTable::default();
        let mut ctx = SchedContext {
            machine: &m,
            db: &db,
            now: 0.0,
            procs: &procs,
            links: &links,
            arrivals: &arrivals,
            coh: &mut coh,
            rng: &mut rng,
            successors: &[],
            job: None,
        };
        // input starts in main memory: host is data-ready instantly, the
        // GPU space pays one 100x100xf32 transfer
        assert_eq!(ctx.pending_read_bytes(&task, 0), 0);
        assert_eq!(ctx.pending_read_bytes(&task, 1), 100 * 100 * 4);
        assert!((ctx.data_ready_at(&task, 0, 0.0) - 0.0).abs() < 1e-15);
        let expect = 1e-5 + (100.0 * 100.0 * 4.0) / 1e9;
        assert!((ctx.data_ready_at(&task, 1, 0.0) - expect).abs() < 1e-12);
        // EFT: GPU still wins (10x faster, transfer is cheap)
        let (fin, p) = ctx.earliest_finish(&task, 0.0);
        assert_eq!(p, 1);
        assert!((fin - (expect + 2.0 * 100f64.powi(3) / 10e9)).abs() < 1e-12);
        assert_eq!(ctx.idle_procs(0.0), vec![0, 1]);
    }

    #[test]
    fn shim_produces_named_builtin() {
        let p = policy_for(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish));
        assert_eq!(p.name(), "pl/eft-p");
        assert!(p.wants_critical_times());
        let q = policy_for(SchedConfig::new(Ordering::Fcfs, ProcSelect::Random));
        assert_eq!(q.name(), "fcfs/r-p");
        assert!(!q.wants_critical_times());
    }
}
