//! The pluggable scheduling-policy layer.
//!
//! HeSP's stated goal (§5) is that "insights extracted from the framework
//! can be further applied to actual runtime task schedulers" — which
//! requires the framework to accept *user-defined* policies, not only the
//! four baked-in heuristics of Table 1. This module turns scheduling into
//! an open trait API (the dslab-dag `Scheduler`-trait idea, adapted to
//! HeSP's eagerly-binding list scheduler):
//!
//! * [`SchedPolicy`] — the trait every policy implements: [`SchedPolicy::order`]
//!   produces the ready-queue priority key of a task, [`SchedPolicy::select`]
//!   maps a popped task to a processor.
//! * [`SchedContext`] — the view of simulator state a policy may consult at
//!   decision time: per-processor idle times, link queues, the coherence /
//!   data-placement state, the performance model, and the popped task's
//!   successor tasks (for lookahead).
//! * [`PolicyRegistry`] — string-keyed construction (`"pl/eft-p"`,
//!   `"pl/affinity"`, ...) so configs, the CLI and benches build policies
//!   by name; user policies register under new names.
//!
//! Built-ins: the eight Table-1 rows ([`BuiltinPolicy`], the enum shim in
//! [`super::policies`] maps onto them) plus two policies the old
//! enum-dispatched API could not express — [`AffinityPolicy`]
//! (data-placement-aware, XKaapi-style; Bleuse et al., arXiv:1402.6601)
//! and [`LookaheadEftPolicy`] (EFT with one-step successor lookahead).
//!
//! The engine, the iterative solver and the constructive scheduler all
//! dispatch through `&mut dyn SchedPolicy`; no execution path matches on
//! the legacy enums anymore.

mod affinity;
mod builtin;
mod lookahead;
mod registry;

pub use affinity::AffinityPolicy;
pub use builtin::BuiltinPolicy;
pub use lookahead::LookaheadEftPolicy;
pub use registry::{policy_by_name, PolicyRegistry};

use super::coherence::{Coherence, SpaceId, Transfer};
use super::datadag::BlockId;
use super::perfmodel::PerfDb;
use super::platform::{Machine, ProcId};
use super::policies::SchedConfig;
use super::task::Task;
use crate::util::rng::Rng;

/// The shared transfer-cost model: earliest time `task`'s inputs can be
/// resident in `space` starting transfers at `release` (given current link
/// queues), plus the planned `(parent block, transfer)` pairs. The engine's
/// commit path and every [`SchedContext`] estimate go through this one
/// function so the estimate can never drift from what gets simulated.
pub fn plan_reads(
    machine: &Machine,
    link_busy: &[f64],
    coh: &mut Coherence,
    task: &Task,
    space: SpaceId,
    release: f64,
) -> (f64, Vec<(BlockId, Transfer)>) {
    let mut ready = release;
    let mut planned = Vec::new();
    for r in task.reads.iter() {
        let block = coh.register(*r);
        for tr in coh.read_plan(block, space) {
            let mut at = release;
            for lid in machine.route(tr.from, tr.to) {
                let l = &machine.links[lid];
                let s = at.max(link_busy[lid]);
                at = s + l.latency + tr.bytes as f64 / l.bandwidth;
            }
            ready = ready.max(at);
            planned.push((block, tr));
        }
    }
    (ready, planned)
}

/// Everything the simulator knows at a scheduling decision point.
///
/// Borrowed views of live engine state: a context is constructed per call
/// and must not be stored. `coh` and `rng` are mutable because estimating
/// data-ready times registers read regions in the data DAG, and stochastic
/// policies draw from the simulation's seeded generator (which keeps runs
/// reproducible per seed).
pub struct SchedContext<'a> {
    pub machine: &'a Machine,
    pub db: &'a PerfDb,
    /// Per-processor earliest-idle times (seconds).
    pub proc_avail: &'a [f64],
    /// Per-link queue tails (seconds): when each link drains.
    pub link_busy: &'a [f64],
    /// Coherence / data-placement state (which space holds which block).
    pub coh: &'a mut Coherence,
    /// The simulation's seeded PRNG.
    pub rng: &'a mut Rng,
    /// The popped task's immediate successor tasks. Populated only inside
    /// [`SchedPolicy::select`] and only when the policy opts in via
    /// [`SchedPolicy::wants_successors`]; empty otherwise.
    pub successors: &'a [&'a Task],
}

impl SchedContext<'_> {
    pub fn n_procs(&self) -> usize {
        self.machine.n_procs()
    }

    /// Predicted execution time of `task` on processor `proc`.
    pub fn exec_time(&self, task: &Task, proc: ProcId) -> f64 {
        self.db.time(self.machine.procs[proc].ptype, task.kind, task.char_edge(), task.flops)
    }

    /// Processors idle at time `release` (paper §2.1's "idle at release").
    pub fn idle_procs(&self, release: f64) -> Vec<ProcId> {
        let eps = 1e-12;
        (0..self.n_procs()).filter(|&p| self.proc_avail[p] <= release + eps).collect()
    }

    /// Earliest time `task`'s inputs can be resident in `space`, starting
    /// transfers at `release`, accounting for current link queues (without
    /// committing any transfer).
    pub fn data_ready_at(&mut self, task: &Task, space: SpaceId, release: f64) -> f64 {
        plan_reads(self.machine, self.link_busy, self.coh, task, space, release).0
    }

    /// Bytes that must move over the interconnect for `task`'s reads to be
    /// resident in `space` (0 = full affinity: every input already there).
    pub fn pending_read_bytes(&mut self, task: &Task, space: SpaceId) -> u64 {
        plan_reads(self.machine, self.link_busy, self.coh, task, space, 0.0)
            .1
            .iter()
            .map(|(_, tr)| tr.bytes)
            .sum()
    }

    /// Per-processor `(proc, finish, pending input bytes)` estimates —
    /// finish is `max(data ready, idle) + exec` — from ONE shared
    /// [`plan_reads`] walk per memory space, memoized per space and per
    /// processor type (28 procs → 4 spaces x 3 types on BUJARUELO). The
    /// shared scan behind every placement-scoring policy.
    pub fn placement_estimates(&mut self, task: &Task, release: f64) -> Vec<(ProcId, f64, u64)> {
        let mut per_space: Vec<Option<(f64, u64)>> = vec![None; self.machine.spaces.len()];
        let mut type_time: Vec<f64> = vec![f64::NAN; self.machine.proc_types.len()];
        let mut out = Vec::with_capacity(self.n_procs());
        for p in 0..self.n_procs() {
            let sp = self.machine.procs[p].space;
            let (ready, bytes) = match per_space[sp] {
                Some(v) => v,
                None => {
                    let (r, planned) =
                        plan_reads(self.machine, self.link_busy, self.coh, task, sp, release);
                    let v = (r, planned.iter().map(|(_, tr)| tr.bytes).sum::<u64>());
                    per_space[sp] = Some(v);
                    v
                }
            };
            let ty = self.machine.procs[p].ptype;
            if type_time[ty].is_nan() {
                type_time[ty] = self.exec_time(task, p);
            }
            out.push((p, ready.max(self.proc_avail[p]) + type_time[ty], bytes));
        }
        out
    }

    /// The EFT-P core: the processor finishing `task` first (transfer- and
    /// queue-aware). Ties break toward the lower processor id.
    pub fn earliest_finish(&mut self, task: &Task, release: f64) -> (f64, ProcId) {
        let mut best = (f64::INFINITY, 0usize);
        for (p, fin, _) in self.placement_estimates(task, release) {
            if fin < best.0 {
                best = (fin, p);
            }
        }
        best
    }
}

/// A scheduling policy: task ordering + processor selection.
///
/// Implementations may keep internal state (`&mut self`); the simulator
/// constructs (or receives) one policy value per run. Determinism contract:
/// for a fixed `SimConfig::seed`, a policy must make identical decisions
/// across runs — draw randomness only from [`SchedContext::rng`].
pub trait SchedPolicy {
    /// Registry-canonical name, e.g. `"pl/eft-p"` (lowercase).
    fn name(&self) -> &str;

    /// Whether [`SchedPolicy::order`] consumes backflow critical times
    /// (upward ranks). The engine computes them only when asked — FCFS-like
    /// orderings skip the O(V+E) pass.
    fn wants_critical_times(&self) -> bool {
        false
    }

    /// Whether [`SchedPolicy::select`] reads [`SchedContext::successors`].
    /// The engine materializes the successor-task list only when asked —
    /// dispatch is a measured hot path, and most policies never look ahead.
    fn wants_successors(&self) -> bool {
        false
    }

    /// Priority key of a task entering the ready queue. The engine pops
    /// the *largest* key first, ties broken toward program order. FCFS is
    /// `-release`; priority-list is the critical time.
    fn order(&mut self, ctx: &mut SchedContext<'_>, task: &Task, release: f64, critical_time: f64) -> f64;

    /// Processor for a popped ready task.
    fn select(&mut self, ctx: &mut SchedContext<'_>, task: &Task, release: f64) -> ProcId;
}

/// The enum-shim constructor: a boxed built-in policy for a legacy
/// [`SchedConfig`] pair. `SimConfig::new(...)` paths funnel through this,
/// which is what keeps the old API compiling unchanged.
pub fn policy_for(cfg: SchedConfig) -> Box<dyn SchedPolicy> {
    Box::new(BuiltinPolicy::new(cfg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::coherence::CachePolicy;
    use crate::coordinator::perfmodel::PerfCurve;
    use crate::coordinator::platform::MachineBuilder;
    use crate::coordinator::policies::{Ordering, ProcSelect};
    use crate::coordinator::region::Region;
    use crate::coordinator::task::{TaskKind, TaskSpec};
    use crate::coordinator::taskdag::TaskDag;

    fn gpu_machine() -> (Machine, PerfDb) {
        let mut b = MachineBuilder::new("g");
        let h = b.space("host", u64::MAX);
        let g = b.space("gpu", u64::MAX);
        b.main(h);
        b.connect(h, g, 1e-5, 1e9);
        let cpu = b.proc_type("cpu", 1.0, 0.1);
        let gpu = b.proc_type("gpu", 1.0, 0.1);
        b.processors(1, "c", cpu, h);
        b.processors(1, "g", gpu, g);
        let m = b.build();
        let mut db = PerfDb::new();
        db.set_fallback(0, PerfCurve::Const { gflops: 1.0 });
        db.set_fallback(1, PerfCurve::Const { gflops: 10.0 });
        (m, db)
    }

    fn one_task() -> TaskDag {
        let r = Region::new(0, 0, 100, 0, 100);
        TaskDag::new(TaskSpec::new(TaskKind::Gemm, vec![r], vec![r]))
    }

    #[test]
    fn context_estimates_match_machine_model() {
        let (m, db) = gpu_machine();
        let dag = one_task();
        let task = dag.task(dag.root).clone();
        let mut coh = Coherence::new(m.spaces.len(), m.main_space, CachePolicy::WriteBack, m.capacities(), 4);
        let mut rng = Rng::new(0);
        let proc_avail = vec![0.0; m.n_procs()];
        let link_busy = vec![0.0; m.links.len()];
        let mut ctx = SchedContext {
            machine: &m,
            db: &db,
            proc_avail: &proc_avail,
            link_busy: &link_busy,
            coh: &mut coh,
            rng: &mut rng,
            successors: &[],
        };
        // input starts in main memory: host is data-ready instantly, the
        // GPU space pays one 100x100xf32 transfer
        assert_eq!(ctx.pending_read_bytes(&task, 0), 0);
        assert_eq!(ctx.pending_read_bytes(&task, 1), 100 * 100 * 4);
        assert!((ctx.data_ready_at(&task, 0, 0.0) - 0.0).abs() < 1e-15);
        let expect = 1e-5 + (100.0 * 100.0 * 4.0) / 1e9;
        assert!((ctx.data_ready_at(&task, 1, 0.0) - expect).abs() < 1e-12);
        // EFT: GPU still wins (10x faster, transfer is cheap)
        let (fin, p) = ctx.earliest_finish(&task, 0.0);
        assert_eq!(p, 1);
        assert!((fin - (expect + 2.0 * 100f64.powi(3) / 10e9)).abs() < 1e-12);
        assert_eq!(ctx.idle_procs(0.0), vec![0, 1]);
    }

    #[test]
    fn shim_produces_named_builtin() {
        let p = policy_for(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish));
        assert_eq!(p.name(), "pl/eft-p");
        assert!(p.wants_critical_times());
        let q = policy_for(SchedConfig::new(Ordering::Fcfs, ProcSelect::Random));
        assert_eq!(q.name(), "fcfs/r-p");
        assert!(!q.wants_critical_times());
    }
}
