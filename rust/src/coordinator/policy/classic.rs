//! The classic list schedulers of the heterogeneous-scheduling
//! literature, as first-class [`SchedPolicy`] implementations: the
//! baselines every related framework positions against (Bleuse et al.,
//! arXiv:1402.6601, benchmark against HEFT-style EFT; Wu et al.,
//! arXiv:1502.07451, against classic list scheduling), and the gauntlet
//! HeSP's "joint scheduling + partitioning wins" claim is measured on.
//!
//! * [`HeftPolicy`] (`cls/heft`) — Topcuoglu et al. 2002: upward ranks
//!   with mean edge-communication costs ([`ordering::upward_ranks`]),
//!   insertion-based earliest-finish placement.
//! * [`PeftPolicy`] (`cls/peft`) — Arabnejad & Barbosa 2014: ranks from
//!   the Optimistic Cost Table ([`ordering::oct_table`]); selection
//!   minimizes `EFT(t, p) + OCT(t, type(p))`, looking one optimistic
//!   step past the local finish time.
//! * [`DlsPolicy`] (`cls/dls`) — Sih & Lee 1993: dynamic levels
//!   `DL(t, p) = sl*(t) − EST(t, p) + Δ(t, p)`, re-keyed at every
//!   decision (a true `dynamic_order` policy).
//!
//! All three rank whole DAGs up front via [`SchedPolicy::rank_tasks`]
//! (HEFT/PEFT) or order dynamically off comm-free static levels (DLS),
//! and none declares [`SchedPolicy::static_key`]: the delta evaluator
//! re-derives keys from comm-free critical times, which would diverge
//! from comm-aware ranks, so these policies always take the
//! full-simulation path in the portfolio solver. In serve mode (where
//! `rank_tasks` is never called — task ids collide across resident jobs)
//! HEFT and PEFT degrade gracefully: ordering falls back to the comm-free
//! critical times they request via `wants_critical_times`, and PEFT's
//! empty OCT lookup turns its selection into plain insertion-based EFT.

use crate::coordinator::ordering;
use crate::coordinator::perfmodel::PerfDb;
use crate::coordinator::platform::{Machine, ProcId};
use crate::coordinator::task::{Task, TaskId};
use crate::coordinator::taskdag::{FlatDag, TaskDag};
use crate::util::fxhash::FxHashMap;

use super::{SchedContext, SchedPolicy};

/// HEFT: communication-aware upward ranks + insertion-based EFT.
#[derive(Default)]
pub struct HeftPolicy;

impl HeftPolicy {
    pub fn new() -> HeftPolicy {
        HeftPolicy
    }
}

impl SchedPolicy for HeftPolicy {
    fn name(&self) -> &str {
        "cls/heft"
    }

    // serve-mode fallback ordering; single-DAG runs override the vector
    // through rank_tasks below
    fn wants_critical_times(&self) -> bool {
        true
    }

    // rank_u is fixed at rank time — keys never depend on live state
    fn dynamic_order(&self) -> bool {
        false
    }

    fn select_stateless(&self) -> bool {
        true
    }

    fn rank_tasks(
        &mut self,
        dag: &TaskDag,
        flat: &FlatDag,
        machine: &Machine,
        db: &PerfDb,
        elem_bytes: u64,
    ) -> Option<Vec<f64>> {
        Some(ordering::upward_ranks(dag, flat, machine, db, elem_bytes))
    }

    fn order(&mut self, _ctx: &mut SchedContext<'_>, _task: &Task, _release: f64, critical_time: f64) -> f64 {
        critical_time
    }

    /// Insertion-based earliest finish: every processor's estimate goes
    /// through [`SchedContext::placement_details`], whose start time is
    /// `Timeline::earliest_fit` — a gap before already-booked work wins
    /// over the queue tail. Ties break toward the lower processor id.
    fn select(&mut self, ctx: &mut SchedContext<'_>, task: &Task, release: f64) -> ProcId {
        ctx.earliest_finish(task, release).1
    }
}

/// PEFT: optimistic-cost-table ranks + OCT-lookahead EFT selection.
#[derive(Default)]
pub struct PeftPolicy {
    /// Per-task OCT rows (indexed by processor type), filled by
    /// [`SchedPolicy::rank_tasks`] and cleared on every new DAG — the
    /// portfolio solver reuses one policy value across candidate
    /// partitions whose task ids overlap.
    oct: FxHashMap<TaskId, Vec<f64>>,
}

impl PeftPolicy {
    pub fn new() -> PeftPolicy {
        PeftPolicy::default()
    }
}

impl SchedPolicy for PeftPolicy {
    fn name(&self) -> &str {
        "cls/peft"
    }

    fn wants_critical_times(&self) -> bool {
        true
    }

    fn dynamic_order(&self) -> bool {
        false
    }

    fn rank_tasks(
        &mut self,
        dag: &TaskDag,
        flat: &FlatDag,
        machine: &Machine,
        db: &PerfDb,
        elem_bytes: u64,
    ) -> Option<Vec<f64>> {
        let oct = ordering::oct_table(dag, flat, machine, db, elem_bytes);
        let ranks = ordering::oct_ranks(machine, &oct);
        self.oct.clear();
        for (i, &tid) in flat.tasks.iter().enumerate() {
            self.oct.insert(tid, oct[i].clone());
        }
        Some(ranks)
    }

    fn order(&mut self, _ctx: &mut SchedContext<'_>, _task: &Task, _release: f64, critical_time: f64) -> f64 {
        critical_time
    }

    /// Minimize `O_EFT(t, p) = EFT(t, p) + OCT(t, type(p))` over insertion
    /// -based placements; a task with no OCT row (serve mode, or split
    /// children the rank pass never saw) degrades to plain EFT. Ties
    /// break toward the lower processor id.
    fn select(&mut self, ctx: &mut SchedContext<'_>, task: &Task, release: f64) -> ProcId {
        let row = self.oct.get(&task.id);
        let mut best = (f64::INFINITY, 0usize);
        for (p, _start, fin, _bytes) in ctx.placement_details(task, release) {
            let opt = row.map_or(0.0, |r| r[ctx.machine.procs[p].ptype]);
            if fin + opt < best.0 {
                best = (fin + opt, p);
            }
        }
        best.1
    }
}

/// DLS: dynamic levels, re-keyed at every decision point.
#[derive(Default)]
pub struct DlsPolicy;

impl DlsPolicy {
    pub fn new() -> DlsPolicy {
        DlsPolicy
    }
}

impl DlsPolicy {
    /// `max over p of DL(t, p)` and its argmax, where
    /// `DL(t, p) = sl*(t) − EST(t, p) + Δ(t, p)`, `sl*` is the comm-free
    /// static level (exactly [`ordering::critical_times`], delivered as
    /// the `critical_time` argument), `EST` the insertion-based start and
    /// `Δ(t, p) = w̄(t) − w(t, p)` the speed preference. Since `sl*` and
    /// `w̄` are constant across processors, the argmax is the insertion
    /// -based earliest-*finish* processor — but the max *value* moves
    /// with the clock, which is what makes the ordering dynamic.
    fn best_level(ctx: &mut SchedContext<'_>, task: &Task, release: f64, sl: f64) -> (f64, ProcId) {
        let placements = ctx.placement_details(task, release);
        let n = placements.len().max(1) as f64;
        let mean_exec: f64 = placements.iter().map(|(_, start, fin, _)| fin - start).sum::<f64>() / n;
        let mut best = (f64::NEG_INFINITY, 0usize);
        for (p, start, fin, _bytes) in placements {
            let dl = sl - start + (mean_exec - (fin - start));
            if dl > best.0 {
                best = (dl, p);
            }
        }
        best
    }
}

impl SchedPolicy for DlsPolicy {
    fn name(&self) -> &str {
        "cls/dls"
    }

    // sl* is the comm-free static level — the one rank the engine
    // already knows how to compute
    fn wants_critical_times(&self) -> bool {
        true
    }

    // dynamic_order() stays at the default `true`: the ready queue is
    // re-keyed between picks, so every dispatched task was the
    // (task, proc) pair with the highest dynamic level at that instant
    fn order(&mut self, ctx: &mut SchedContext<'_>, task: &Task, release: f64, critical_time: f64) -> f64 {
        DlsPolicy::best_level(ctx, task, release, critical_time).0
    }

    /// The processor achieving the popped task's maximal dynamic level.
    /// `sl*` shifts the level uniformly across processors, so passing 0
    /// here picks the same argmax [`DlsPolicy::order`] keyed on.
    fn select(&mut self, ctx: &mut SchedContext<'_>, task: &Task, release: f64) -> ProcId {
        DlsPolicy::best_level(ctx, task, release, 0.0).1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::coherence::{CachePolicy, Coherence};
    use crate::coordinator::perfmodel::PerfCurve;
    use crate::coordinator::platform::{MachineBuilder, Timeline};
    use crate::coordinator::policy::ArrivalTable;
    use crate::coordinator::region::Region;
    use crate::coordinator::task::{TaskKind, TaskSpec};
    use crate::util::rng::Rng;

    /// CPU in host memory + GPU behind a link, GPU 10x faster.
    fn gpu_machine() -> (Machine, PerfDb) {
        let mut b = MachineBuilder::new("g");
        let h = b.space("host", u64::MAX);
        let g = b.space("gpu", u64::MAX);
        b.main(h);
        b.connect(h, g, 1e-5, 1e9);
        let cpu = b.proc_type("cpu", 1.0, 0.1);
        let gpu = b.proc_type("gpu", 1.0, 0.1);
        b.processors(1, "c", cpu, h);
        b.processors(1, "g", gpu, g);
        let m = b.build();
        let mut db = PerfDb::new();
        db.set_fallback(0, PerfCurve::Const { gflops: 1.0 });
        db.set_fallback(1, PerfCurve::Const { gflops: 10.0 });
        (m, db)
    }

    fn with_ctx<R>(m: &Machine, db: &PerfDb, f: impl FnOnce(&mut SchedContext<'_>) -> R) -> R {
        let mut coh = Coherence::new(m.spaces.len(), m.main_space, CachePolicy::WriteBack, m.capacities(), 4);
        let mut rng = Rng::new(0);
        let procs = vec![Timeline::new(); m.n_procs()];
        let links = vec![Timeline::new(); m.links.len()];
        let arrivals = ArrivalTable::default();
        let mut ctx = SchedContext {
            machine: m,
            db,
            now: 0.0,
            procs: &procs,
            links: &links,
            arrivals: &arrivals,
            coh: &mut coh,
            rng: &mut rng,
            successors: &[],
            job: None,
        };
        f(&mut ctx)
    }

    fn one_task() -> TaskDag {
        let r = Region::new(0, 0, 100, 0, 100);
        TaskDag::new(TaskSpec::new(TaskKind::Gemm, vec![r], vec![r]))
    }

    #[test]
    fn heft_ranks_and_selects_insertion_eft() {
        let (m, db) = gpu_machine();
        let dag = one_task();
        let flat = dag.flat_dag();
        let mut pol = HeftPolicy::new();
        let ranks = pol.rank_tasks(&dag, &flat, &m, &db, 4).expect("heft ranks");
        assert_eq!(ranks.len(), 1);
        // lone task: rank_u = mean exec = (t_cpu + t_gpu) / 2
        let flops = 2.0 * 100f64.powi(3);
        let want = (flops / 1e9 + flops / 10e9) / 2.0;
        assert!((ranks[0] - want).abs() < 1e-15);
        // GPU wins EFT despite paying the input transfer
        let task = dag.task(dag.root).clone();
        let p = with_ctx(&m, &db, |ctx| pol.select(ctx, &task, 0.0));
        assert_eq!(p, 1);
        assert!(!pol.dynamic_order());
        assert!(pol.static_key(0.0, 1.0).is_none(), "comm-aware ranks must stay delta-ineligible");
    }

    #[test]
    fn peft_select_degrades_to_eft_without_a_table() {
        let (m, db) = gpu_machine();
        let dag = one_task();
        let task = dag.task(dag.root).clone();
        let mut pol = PeftPolicy::new();
        // no rank_tasks call (the serve-mode situation): selection must
        // still work, as plain insertion-based EFT
        let p = with_ctx(&m, &db, |ctx| pol.select(ctx, &task, 0.0));
        let eft = with_ctx(&m, &db, |ctx| ctx.earliest_finish(&task, 0.0).1);
        assert_eq!(p, eft);
    }

    #[test]
    fn peft_oct_steers_off_the_myopic_choice() {
        let (m, db) = gpu_machine();
        let dag = one_task();
        let task = dag.task(dag.root).clone();
        let mut pol = PeftPolicy::new();
        // plant an OCT row that punishes the GPU's downstream prospects
        // hard enough to overturn its EFT win
        pol.oct.insert(task.id, vec![0.0, 1.0]);
        let p = with_ctx(&m, &db, |ctx| pol.select(ctx, &task, 0.0));
        assert_eq!(p, 0, "OCT penalty must overturn the myopic EFT pick");
    }

    #[test]
    fn dls_order_and_select_agree_on_the_argmax() {
        let (m, db) = gpu_machine();
        let dag = one_task();
        let task = dag.task(dag.root).clone();
        let mut pol = DlsPolicy::new();
        assert!(pol.dynamic_order());
        assert!(pol.wants_critical_times());
        // the selected processor is the argmax of the dynamic level the
        // ordering keyed on (sl* only shifts the level uniformly)
        let (dl, argmax) = with_ctx(&m, &db, |ctx| DlsPolicy::best_level(ctx, &task, 0.0, 5.0));
        let picked = with_ctx(&m, &db, |ctx| pol.select(ctx, &task, 0.0));
        assert_eq!(picked, argmax);
        // DL = sl* − EST + Δ: for the GPU (EST = transfer time) with the
        // 10x speedup, Δ = mean − exec is positive and EST small
        let flops = 2.0 * 100f64.powi(3);
        let (t_cpu, t_gpu) = (flops / 1e9, flops / 10e9);
        let est_gpu = 1e-5 + (100.0 * 100.0 * 4.0) / 1e9;
        let want = 5.0 - est_gpu + (t_cpu + t_gpu) / 2.0 - t_gpu;
        assert!((dl - want).abs() < 1e-12, "DL = {dl}, want {want}");
        assert_eq!(picked, 1, "GPU has the higher dynamic level here");
    }
}
