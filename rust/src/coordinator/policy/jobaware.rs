//! Job-aware service-mode policies: EDF and shortest-job-first orderings
//! over transfer-aware EFT selection.
//!
//! In single-DAG simulation, `pl/eft-p`'s critical-time ordering is the
//! paper's best heuristic. Under a *stream* of concurrent jobs it turns
//! into longest-job-first: a freshly admitted large DAG out-prioritizes
//! every task of a nearly finished small one, so small jobs starve and
//! the p99 sojourn blows up. These two policies order by *job*-level
//! urgency instead, read from [`SchedContext::job`]:
//!
//! * [`DeadlinePolicy`] (`pl/edf-p`) — earliest absolute deadline first,
//!   the classic result for bounding lateness;
//! * [`ShortestJobPolicy`] (`pl/sjf-p`) — smallest makespan lower bound
//!   first, the sojourn-time optimizer.
//!
//! Both keys are constants of the owning job, so `dynamic_order` stays
//! `false` (one key per task at release). When no job is attached —
//! every single-DAG code path — both degrade to FCFS ordering, keeping
//! them well-defined (if uninteresting) in `hesp sweep` grids.

use crate::coordinator::platform::ProcId;
use crate::coordinator::task::Task;

use super::{SchedContext, SchedPolicy};

/// `pl/edf-p`: earliest-deadline-first ordering, EFT-P selection. Jobs
/// without a declared deadline (`deadline == INFINITY`) sort behind every
/// deadline-carrying job, tie-broken FCFS by arrival.
pub struct DeadlinePolicy;

impl DeadlinePolicy {
    pub fn new() -> DeadlinePolicy {
        DeadlinePolicy
    }
}

impl Default for DeadlinePolicy {
    fn default() -> Self {
        Self::new()
    }
}

impl SchedPolicy for DeadlinePolicy {
    fn name(&self) -> &str {
        "pl/edf-p"
    }

    // the key is a constant of the owning job (or of the task's release)
    fn dynamic_order(&self) -> bool {
        false
    }

    // in every job-less context (all solver/sweep paths) the key below
    // degrades to FCFS on release — which is exactly this static form
    fn static_key(&self, release: f64, _critical_time: f64) -> Option<f64> {
        Some(-release)
    }

    fn select_stateless(&self) -> bool {
        true
    }

    fn order(&mut self, ctx: &mut SchedContext<'_>, _task: &Task, release: f64, _critical_time: f64) -> f64 {
        match ctx.job {
            // max-heap → negate: the earliest deadline pops first
            Some(j) if j.deadline.is_finite() => -j.deadline,
            // no declared deadline: behind every finite deadline, FCFS by
            // arrival among themselves (finite, so arrival still orders —
            // -INF would collapse all such jobs onto one key)
            Some(j) => -1e30 - j.arrival,
            None => -release,
        }
    }

    fn select(&mut self, ctx: &mut SchedContext<'_>, task: &Task, release: f64) -> ProcId {
        ctx.earliest_finish(task, release).1
    }
}

/// `pl/sjf-p`: shortest-job-first by makespan lower bound, EFT-P
/// selection — the mean/percentile-sojourn optimizer under contention.
pub struct ShortestJobPolicy;

impl ShortestJobPolicy {
    pub fn new() -> ShortestJobPolicy {
        ShortestJobPolicy
    }
}

impl Default for ShortestJobPolicy {
    fn default() -> Self {
        Self::new()
    }
}

impl SchedPolicy for ShortestJobPolicy {
    fn name(&self) -> &str {
        "pl/sjf-p"
    }

    fn dynamic_order(&self) -> bool {
        false
    }

    // job-less contexts degrade to FCFS on release (see DeadlinePolicy)
    fn static_key(&self, release: f64, _critical_time: f64) -> Option<f64> {
        Some(-release)
    }

    fn select_stateless(&self) -> bool {
        true
    }

    fn order(&mut self, ctx: &mut SchedContext<'_>, _task: &Task, release: f64, _critical_time: f64) -> f64 {
        match ctx.job {
            // smallest lower bound pops first; equal-size jobs fall back
            // to the engine's program-order tie-break (admission order)
            Some(j) => -j.lower_bound,
            None => -release,
        }
    }

    fn select(&mut self, ctx: &mut SchedContext<'_>, task: &Task, release: f64) -> ProcId {
        ctx.earliest_finish(task, release).1
    }
}

#[cfg(test)]
mod tests {
    use super::super::JobInfo;
    use super::*;
    use crate::coordinator::coherence::{CachePolicy, Coherence};
    use crate::coordinator::perfmodel::{PerfCurve, PerfDb};
    use crate::coordinator::platform::{MachineBuilder, Timeline};
    use crate::coordinator::policy::ArrivalTable;
    use crate::coordinator::region::Region;
    use crate::coordinator::task::{TaskKind, TaskSpec};
    use crate::coordinator::taskdag::TaskDag;
    use crate::util::rng::Rng;

    fn with_ctx<R>(job: Option<JobInfo>, f: impl FnOnce(&mut SchedContext<'_>) -> R) -> R {
        let mut b = MachineBuilder::new("m");
        let h = b.space("host", u64::MAX);
        b.main(h);
        let t = b.proc_type("cpu", 1.0, 0.1);
        b.processors(2, "c", t, h);
        let m = b.build();
        let mut db = PerfDb::new();
        db.set_fallback(0, PerfCurve::Const { gflops: 1.0 });
        let mut coh = Coherence::new(m.spaces.len(), m.main_space, CachePolicy::WriteBack, m.capacities(), 4);
        let mut rng = Rng::new(0);
        let procs = vec![Timeline::new(); m.n_procs()];
        let links: Vec<Timeline> = Vec::new();
        let arrivals = ArrivalTable::default();
        let mut ctx = SchedContext {
            machine: &m,
            db: &db,
            now: 0.0,
            procs: &procs,
            links: &links,
            arrivals: &arrivals,
            coh: &mut coh,
            rng: &mut rng,
            successors: &[],
            job,
        };
        f(&mut ctx)
    }

    fn task() -> Task {
        let r = Region::new(0, 0, 8, 0, 8);
        let dag = TaskDag::new(TaskSpec::new(TaskKind::Gemm, vec![r], vec![r]));
        dag.task(dag.root).clone()
    }

    fn job(id: usize, arrival: f64, deadline: f64, lb: f64) -> JobInfo {
        JobInfo { id, arrival, deadline, lower_bound: lb }
    }

    #[test]
    fn edf_orders_by_deadline_then_degrades_to_fcfs() {
        let t = task();
        let mut p = DeadlinePolicy::new();
        let tight = with_ctx(Some(job(0, 0.0, 1.0, 0.5)), |c| p.order(c, &t, 0.0, 0.0));
        let loose = with_ctx(Some(job(1, 0.0, 5.0, 0.5)), |c| p.order(c, &t, 0.0, 0.0));
        let none = with_ctx(Some(job(2, 0.0, f64::INFINITY, 0.5)), |c| p.order(c, &t, 0.0, 0.0));
        assert!(tight > loose, "tighter deadline pops first");
        assert!(loose > none, "deadline-free jobs sort last");
        // no job attached: FCFS on release
        let a = with_ctx(None, |c| p.order(c, &t, 1.0, 9.9));
        let b = with_ctx(None, |c| p.order(c, &t, 2.0, 9.9));
        assert!(a > b);
        assert!(!p.dynamic_order());
    }

    #[test]
    fn sjf_orders_by_lower_bound() {
        let t = task();
        let mut p = ShortestJobPolicy::new();
        let small = with_ctx(Some(job(0, 0.0, f64::INFINITY, 0.1)), |c| p.order(c, &t, 0.0, 0.0));
        let big = with_ctx(Some(job(1, 0.0, f64::INFINITY, 7.0)), |c| p.order(c, &t, 0.0, 0.0));
        assert!(small > big, "smaller job pops first");
        let a = with_ctx(None, |c| p.order(c, &t, 1.0, 9.9));
        let b = with_ctx(None, |c| p.order(c, &t, 2.0, 9.9));
        assert!(a > b, "degrades to FCFS without a job");
    }

    #[test]
    fn both_select_earliest_finish() {
        let t = task();
        let sel_edf = with_ctx(None, |c| DeadlinePolicy::new().select(c, &t, 0.0));
        let sel_sjf = with_ctx(None, |c| ShortestJobPolicy::new().select(c, &t, 0.0));
        // empty timelines, equal processors: EFT tie-breaks to proc 0
        assert_eq!(sel_edf, 0);
        assert_eq!(sel_sjf, 0);
    }
}
