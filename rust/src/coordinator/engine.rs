//! The discrete-event schedule simulator.
//!
//! A list scheduler over the frontier DAG: tasks are released when all
//! predecessors are scheduled, ordered and mapped to processors by a
//! [`SchedPolicy`] (the pluggable policy layer — see
//! [`super::policy`]). Data movement is simulated explicitly: reads that
//! miss in the processor's memory space issue (pre)fetch transfers over
//! the interconnect with per-link queuing, and writes update the coherence
//! state per the caching policy (WB/WT/WA), possibly generating
//! write-through/write-back traffic.
//!
//! Entry points come in pairs: the legacy enum-configured ones
//! ([`simulate`], [`simulate_flat`], [`simulate_mapped`]) construct the
//! matching built-in policy from [`SimConfig`]'s shim fields, and the
//! `_policy` variants take any `&mut dyn SchedPolicy`.

use super::coherence::{CachePolicy, Coherence, SpaceId, Transfer};
use super::ordering::critical_times;
use super::perfmodel::PerfDb;
use super::platform::{Machine, ProcId};
use super::policies::{Ordering, ProcSelect, SchedConfig};
use super::policy::{self, SchedContext, SchedPolicy};
use super::task::{Task, TaskId};
use super::taskdag::{FlatDag, TaskDag};
use crate::util::rng::Rng;

/// Simulation knobs beyond the platform itself.
#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    /// Legacy ordering shim — used only to construct the matching built-in
    /// policy when an enum-configured entry point is called. Prefer the
    /// `_policy` entry points with a [`SchedPolicy`] value.
    pub ordering: Ordering,
    /// Legacy selection shim (see `ordering`).
    pub select: ProcSelect,
    pub cache: CachePolicy,
    /// Bytes per matrix element (4 = f32, 8 = f64).
    pub elem_bytes: u64,
    pub seed: u64,
}

impl SimConfig {
    pub fn new(cfg: SchedConfig) -> SimConfig {
        SimConfig {
            ordering: cfg.ordering,
            select: cfg.select,
            cache: CachePolicy::WriteBack,
            elem_bytes: 4,
            seed: 0,
        }
    }

    pub fn with_cache(mut self, c: CachePolicy) -> Self {
        self.cache = c;
        self
    }

    pub fn with_elem_bytes(mut self, b: u64) -> Self {
        self.elem_bytes = b;
        self
    }

    pub fn with_seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// A simulated data transfer (for traces and transfer accounting).
#[derive(Debug, Clone, Copy)]
pub struct TransferRecord {
    pub from: SpaceId,
    pub to: SpaceId,
    pub bytes: u64,
    pub start: f64,
    pub end: f64,
}

/// One task placement in the simulated schedule.
#[derive(Debug, Clone, Copy)]
pub struct Assignment {
    pub task: TaskId,
    /// Position in the frontier (program order).
    pub pos: usize,
    pub proc: ProcId,
    /// Time all predecessors were finished.
    pub release: f64,
    pub start: f64,
    pub end: f64,
}

/// The simulation result.
#[derive(Debug, Clone, Default)]
pub struct Schedule {
    /// Assignments indexed by frontier position.
    pub assignments: Vec<Assignment>,
    pub transfers: Vec<TransferRecord>,
    pub makespan: f64,
    /// Busy seconds per processor.
    pub proc_busy: Vec<f64>,
    /// Total bytes moved between memory spaces.
    pub transfer_bytes: u64,
}

impl Schedule {
    /// Average processor load: mean over processors of busy/makespan
    /// (Table 1's "Avg. load" column).
    pub fn avg_load(&self) -> f64 {
        if self.makespan <= 0.0 || self.proc_busy.is_empty() {
            return 0.0;
        }
        self.proc_busy.iter().map(|b| b / self.makespan).sum::<f64>() / self.proc_busy.len() as f64
    }

    /// Processor -> task→proc mapping vector (for schedule replay).
    pub fn mapping(&self) -> Vec<ProcId> {
        self.assignments.iter().map(|a| a.proc).collect()
    }

    /// Number of processors busy at time `t` (Fig. 2b load traces).
    pub fn active_at(&self, t: f64) -> usize {
        self.assignments.iter().filter(|a| a.start <= t && t < a.end).count()
    }
}

/// Simulate scheduling `dag`'s frontier on `machine` under the built-in
/// policy named by `cfg`'s shim fields.
pub fn simulate(dag: &TaskDag, machine: &Machine, db: &PerfDb, cfg: SimConfig) -> Schedule {
    let mut p = policy::policy_for(SchedConfig::new(cfg.ordering, cfg.select));
    run(dag, machine, db, cfg, None, None, p.as_mut())
}

/// Simulate under an arbitrary scheduling policy.
pub fn simulate_policy(
    dag: &TaskDag,
    machine: &Machine,
    db: &PerfDb,
    cfg: SimConfig,
    policy: &mut dyn SchedPolicy,
) -> Schedule {
    run(dag, machine, db, cfg, None, None, policy)
}

/// Like [`simulate`], reusing an already-derived [`FlatDag`] (the solver
/// needs the same frontier for candidate collection; deriving it twice per
/// iteration was a measured hot spot — §Perf optimization 3).
pub fn simulate_flat(dag: &TaskDag, flat: &FlatDag, machine: &Machine, db: &PerfDb, cfg: SimConfig) -> Schedule {
    let mut p = policy::policy_for(SchedConfig::new(cfg.ordering, cfg.select));
    run(dag, machine, db, cfg, None, Some(flat), p.as_mut())
}

/// [`simulate_flat`] under an arbitrary scheduling policy.
pub fn simulate_flat_policy(
    dag: &TaskDag,
    flat: &FlatDag,
    machine: &Machine,
    db: &PerfDb,
    cfg: SimConfig,
    policy: &mut dyn SchedPolicy,
) -> Schedule {
    run(dag, machine, db, cfg, None, Some(flat), policy)
}

/// Replay a fixed task→processor mapping (positions in frontier order) —
/// the HESP-REPLICA mode used for framework validation (§3.1). The policy
/// still orders the ready queue; selection is forced by `mapping`.
pub fn simulate_mapped(dag: &TaskDag, machine: &Machine, db: &PerfDb, cfg: SimConfig, mapping: &[ProcId]) -> Schedule {
    let mut p = policy::policy_for(SchedConfig::new(cfg.ordering, cfg.select));
    run(dag, machine, db, cfg, Some(mapping), None, p.as_mut())
}

fn run(
    dag: &TaskDag,
    machine: &Machine,
    db: &PerfDb,
    cfg: SimConfig,
    forced: Option<&[ProcId]>,
    flat_in: Option<&FlatDag>,
    policy: &mut dyn SchedPolicy,
) -> Schedule {
    let flat_owned;
    let flat: &FlatDag = match flat_in {
        Some(f) => f,
        None => {
            flat_owned = dag.flat_dag();
            &flat_owned
        }
    };
    let n = flat.len();
    if let Some(m) = forced {
        assert_eq!(m.len(), n, "mapping length != frontier size");
    }
    let mut rng = Rng::new(cfg.seed);
    let mut coh = Coherence::new(machine.spaces.len(), machine.main_space, cfg.cache, machine.capacities(), cfg.elem_bytes);

    // backflow critical times, computed only for policies that order by
    // them (the PL family); FCFS-like policies skip the O(V+E) pass
    let prio = if policy.wants_critical_times() {
        critical_times(dag, flat, machine, db)
    } else {
        vec![0.0; n]
    };

    // max-heap over policy-provided ordering keys (FCFS pushes -release so
    // the earliest release pops first, PL pushes the critical time); ties
    // break toward the smaller frontier position (program order).
    #[derive(PartialEq)]
    struct HeapItem {
        key: f64,
        pos: usize,
    }
    impl Eq for HeapItem {}
    impl PartialOrd for HeapItem {
        fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(other))
        }
    }
    impl Ord for HeapItem {
        fn cmp(&self, other: &Self) -> std::cmp::Ordering {
            self.key.total_cmp(&other.key).then(other.pos.cmp(&self.pos))
        }
    }

    let mut indeg: Vec<usize> = flat.preds.iter().map(|p| p.len()).collect();
    let mut release = vec![0.0f64; n];

    let mut proc_avail = vec![0.0f64; machine.n_procs()];
    let mut link_busy = vec![0.0f64; machine.links.len()];
    let mut done_at = vec![0.0f64; n];

    let mut ready: std::collections::BinaryHeap<HeapItem> = std::collections::BinaryHeap::new();
    for i in 0..n {
        if indeg[i] == 0 {
            let mut ctx = SchedContext {
                machine,
                db,
                proc_avail: &proc_avail,
                link_busy: &link_busy,
                coh: &mut coh,
                rng: &mut rng,
                successors: &[],
            };
            let key = policy.order(&mut ctx, dag.task(flat.tasks[i]), 0.0, prio[i]);
            ready.push(HeapItem { key, pos: i });
        }
    }

    let mut sched = Schedule {
        assignments: vec![
            Assignment { task: 0, pos: 0, proc: 0, release: 0.0, start: 0.0, end: 0.0 };
            n
        ],
        proc_busy: vec![0.0; machine.n_procs()],
        ..Default::default()
    };

    let exec_time = |pos: usize, proc: ProcId| -> f64 {
        let t = dag.task(flat.tasks[pos]);
        db.time(machine.procs[proc].ptype, t.kind, t.char_edge(), t.flops)
    };

    while let Some(HeapItem { pos, .. }) = ready.pop() {
        let rel = release[pos];

        // ---- choose a processor (policy dispatch) ----
        let proc: ProcId = if let Some(m) = forced {
            m[pos]
        } else {
            // successor tasks materialize only for lookahead-style
            // policies — dispatch is a hot path
            let succ_tasks: Vec<&Task> = if policy.wants_successors() {
                flat.succs[pos].iter().map(|&s| dag.task(flat.tasks[s])).collect()
            } else {
                Vec::new()
            };
            let mut ctx = SchedContext {
                machine,
                db,
                proc_avail: &proc_avail,
                link_busy: &link_busy,
                coh: &mut coh,
                rng: &mut rng,
                successors: &succ_tasks,
            };
            policy.select(&mut ctx, dag.task(flat.tasks[pos]), rel)
        };

        // ---- commit transfers + execution ----
        // plan through the same shared model the policy estimates used
        let space = machine.procs[proc].space;
        let (_, planned) =
            policy::plan_reads(machine, &link_busy, &mut coh, dag.task(flat.tasks[pos]), space, rel);
        let mut data_ready = rel;
        let mut fetched_parents: Vec<usize> = Vec::new();
        for (parent, tr) in planned {
            let mut at = rel;
            let route = machine.route(tr.from, tr.to);
            let (mut first_start, mut last_end) = (f64::INFINITY, rel);
            for lid in route {
                let l = &machine.links[lid];
                let s = at.max(link_busy[lid]);
                let e = s + l.latency + tr.bytes as f64 / l.bandwidth;
                link_busy[lid] = e;
                first_start = first_start.min(s);
                last_end = e;
                at = e;
            }
            data_ready = data_ready.max(last_end);
            sched.transfers.push(TransferRecord { from: tr.from, to: tr.to, bytes: tr.bytes, start: first_start, end: last_end });
            sched.transfer_bytes += tr.bytes;
            let evict = coh.complete_read(tr.block, tr.to);
            charge_background(machine, &mut link_busy, &mut sched, last_end, &evict);
            if tr.block != parent && !fetched_parents.contains(&parent) {
                fetched_parents.push(parent);
            }
        }
        // a reassembled coarse block is now fully present in `space`
        for parent in fetched_parents {
            let evict = coh.complete_read(parent, space);
            charge_background(machine, &mut link_busy, &mut sched, data_ready, &evict);
        }

        let start = proc_avail[proc].max(data_ready);
        let end = start + exec_time(pos, proc);
        proc_avail[proc] = end;
        done_at[pos] = end;
        sched.proc_busy[proc] += end - start;
        sched.assignments[pos] = Assignment { task: flat.tasks[pos], pos, proc, release: rel, start, end };

        // write effects at task end
        let t = dag.task(flat.tasks[pos]);
        let writes: Vec<_> = t.writes.clone();
        for w in writes {
            let block = coh.register(w);
            let extra = coh.complete_write(block, space);
            charge_background(machine, &mut link_busy, &mut sched, end, &extra);
        }

        // release successors
        for &s in &flat.succs[pos] {
            indeg[s] -= 1;
            release[s] = release[s].max(end);
            if indeg[s] == 0 {
                let mut ctx = SchedContext {
                    machine,
                    db,
                    proc_avail: &proc_avail,
                    link_busy: &link_busy,
                    coh: &mut coh,
                    rng: &mut rng,
                    successors: &[],
                };
                let key = policy.order(&mut ctx, dag.task(flat.tasks[s]), release[s], prio[s]);
                ready.push(HeapItem { key, pos: s });
            }
        }
    }

    let task_end = sched.assignments.iter().map(|a| a.end).fold(0.0f64, f64::max);
    let xfer_end = sched.transfers.iter().map(|t| t.end).fold(0.0f64, f64::max);
    sched.makespan = task_end.max(xfer_end);
    sched
}

/// Charge write-through/write-back/eviction traffic on the interconnect
/// (it does not delay the issuing task, but occupies links and counts
/// toward transfer volume).
fn charge_background(machine: &Machine, link_busy: &mut [f64], sched: &mut Schedule, at: f64, transfers: &[Transfer]) {
    for tr in transfers {
        let mut t = at;
        let (mut first_start, mut last_end) = (f64::INFINITY, at);
        for lid in machine.route(tr.from, tr.to) {
            let l = &machine.links[lid];
            let s = t.max(link_busy[lid]);
            let e = s + l.latency + tr.bytes as f64 / l.bandwidth;
            link_busy[lid] = e;
            first_start = first_start.min(s);
            last_end = e;
            t = e;
        }
        if last_end > at {
            sched.transfers.push(TransferRecord { from: tr.from, to: tr.to, bytes: tr.bytes, start: first_start, end: last_end });
            sched.transfer_bytes += tr.bytes;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::perfmodel::PerfCurve;
    use crate::coordinator::platform::MachineBuilder;
    use crate::coordinator::region::Region;
    use crate::coordinator::task::{TaskKind, TaskSpec};

    fn single_space_machine(n_fast: usize, n_slow: usize) -> (Machine, PerfDb) {
        let mut b = MachineBuilder::new("m");
        let h = b.space("host", u64::MAX);
        b.main(h);
        let slow = b.proc_type("slow", 1.0, 0.1);
        let fast = b.proc_type("fast", 1.0, 0.1);
        b.processors(n_slow, "s", slow, h);
        b.processors(n_fast, "f", fast, h);
        let m = b.build();
        let mut db = PerfDb::new();
        db.set_fallback(0, PerfCurve::Const { gflops: 1.0 });
        db.set_fallback(1, PerfCurve::Const { gflops: 4.0 });
        (m, db)
    }

    fn gpu_machine() -> (Machine, PerfDb) {
        let mut b = MachineBuilder::new("g");
        let h = b.space("host", u64::MAX);
        let g = b.space("gpu", u64::MAX);
        b.main(h);
        b.connect(h, g, 1e-5, 1e9);
        let cpu = b.proc_type("cpu", 1.0, 0.1);
        let gpu = b.proc_type("gpu", 1.0, 0.1);
        b.processors(1, "c", cpu, h);
        b.processors(1, "g", gpu, g);
        let m = b.build();
        let mut db = PerfDb::new();
        db.set_fallback(0, PerfCurve::Const { gflops: 1.0 });
        db.set_fallback(1, PerfCurve::Const { gflops: 10.0 });
        (m, db)
    }

    fn reg(r0: u32, r1: u32, c0: u32, c1: u32) -> Region {
        Region::new(0, r0, r1, c0, c1)
    }

    /// `k` independent gemm tasks over disjoint 100x100 tiles.
    fn independent(k: u32) -> TaskDag {
        let root = reg(0, 100 * k, 0, 100);
        let mut dag = TaskDag::new(TaskSpec::new(TaskKind::Potrf, vec![root], vec![root]));
        let specs: Vec<TaskSpec> = (0..k)
            .map(|i| {
                let r = reg(100 * i, 100 * (i + 1), 0, 100);
                TaskSpec::new(TaskKind::Gemm, vec![r], vec![r])
            })
            .collect();
        dag.partition(0, specs, 100);
        dag
    }

    /// A chain of `k` dependent tasks over one region.
    fn chain(k: usize) -> TaskDag {
        let r = reg(0, 100, 0, 100);
        let mut dag = TaskDag::new(TaskSpec::new(TaskKind::Potrf, vec![r], vec![r]));
        dag.partition(0, vec![TaskSpec::new(TaskKind::Gemm, vec![r], vec![r]); k], 100);
        dag
    }

    fn cfg(o: Ordering, s: ProcSelect) -> SimConfig {
        SimConfig::new(SchedConfig::new(o, s))
    }

    const GEMM100: f64 = 2.0 * 100.0 * 100.0 * 100.0; // flops of a 100-tile

    #[test]
    fn independent_tasks_run_in_parallel() {
        let (m, db) = single_space_machine(2, 0);
        let dag = independent(4);
        let s = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestIdle));
        // 4 tasks, 2 equal fast procs, each task 2e6/4e9 = 0.5ms
        let per = GEMM100 / 4e9;
        assert!((s.makespan - 2.0 * per).abs() < 1e-9, "makespan={}", s.makespan);
        assert!((s.avg_load() - 1.0).abs() < 1e-9);
        assert_eq!(s.transfer_bytes, 0, "single space: no transfers");
    }

    #[test]
    fn chain_serializes() {
        let (m, db) = single_space_machine(2, 0);
        let dag = chain(3);
        let s = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestFinish));
        let per = GEMM100 / 4e9;
        assert!((s.makespan - 3.0 * per).abs() < 1e-9);
        for w in s.assignments.windows(2) {
            assert!(w[1].start >= w[0].end - 1e-12);
        }
    }

    #[test]
    fn fastest_picks_fast_proc() {
        let (m, db) = single_space_machine(1, 1);
        let dag = chain(1);
        let s = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::Fastest));
        assert_eq!(m.procs[s.assignments[0].proc].ptype, 1, "fast proc chosen");
    }

    #[test]
    fn eft_beats_eit_when_types_differ() {
        // EIT picks proc 0 (slow, idle first by tie-break); EFT picks fast.
        let (m, db) = single_space_machine(1, 1);
        let dag = independent(2);
        let eit = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestIdle));
        let eft = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestFinish));
        assert!(eft.makespan <= eit.makespan + 1e-12);
        // EFT serializes both tasks on the fast proc (0.5ms each) instead
        // of putting one on the slow (2ms)
        assert!((eft.makespan - 2.0 * GEMM100 / 4e9).abs() < 1e-9, "{}", eft.makespan);
        assert!((eit.makespan - GEMM100 / 1e9).abs() < 1e-9, "{}", eit.makespan);
    }

    #[test]
    fn random_is_deterministic_per_seed() {
        let (m, db) = single_space_machine(2, 2);
        let dag = independent(8);
        let a = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::Random).with_seed(7));
        let b = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::Random).with_seed(7));
        assert_eq!(a.mapping(), b.mapping());
        let c = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::Random).with_seed(8));
        // almost surely a different mapping with 4 procs and 8 tasks
        assert_ne!(a.mapping(), c.mapping());
    }

    #[test]
    fn transfers_charged_for_remote_reads() {
        let (m, db) = gpu_machine();
        let dag = chain(1);
        let s = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::Fastest));
        // fastest proc is the GPU; input block (100x100 f32) must move
        assert_eq!(m.procs[s.assignments[0].proc].ptype, 1);
        assert_eq!(s.transfer_bytes, 100 * 100 * 4);
        assert!(!s.transfers.is_empty());
        let tr = s.transfers[0];
        let expected = 1e-5 + (100.0 * 100.0 * 4.0) / 1e9;
        assert!((tr.end - tr.start - expected).abs() < 1e-12);
        assert!(s.assignments[0].start >= tr.end - 1e-12, "task waits for data");
    }

    #[test]
    fn cached_data_is_not_refetched() {
        let (m, db) = gpu_machine();
        let dag = chain(3); // same region read+written 3x
        let s = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::Fastest));
        // all 3 run on GPU; only the first fetches
        assert_eq!(s.transfer_bytes, 100 * 100 * 4);
    }

    #[test]
    fn write_through_generates_backflow_traffic() {
        let (m, db) = gpu_machine();
        let dag = chain(2);
        let base = cfg(Ordering::Fcfs, ProcSelect::Fastest);
        let wb = simulate(&dag, &m, &db, base.with_cache(CachePolicy::WriteBack));
        let wt = simulate(&dag, &m, &db, base.with_cache(CachePolicy::WriteThrough));
        // WT pushes each of the two writes back to main
        assert_eq!(wt.transfer_bytes, wb.transfer_bytes + 2 * 100 * 100 * 4);
    }

    #[test]
    fn write_around_refetches_every_round() {
        let (m, db) = gpu_machine();
        let dag = chain(2);
        let base = cfg(Ordering::Fcfs, ProcSelect::Fastest);
        let wa = simulate(&dag, &m, &db, base.with_cache(CachePolicy::WriteAround));
        // WA: fetch, write lands in main (1 push), second task re-fetches,
        // pushes again: 4 block moves total
        assert_eq!(wa.transfer_bytes, 4 * 100 * 100 * 4);
    }

    #[test]
    fn replay_forces_mapping() {
        let (m, db) = single_space_machine(1, 1);
        let dag = independent(4);
        let mapping = vec![0, 0, 1, 1];
        let s = simulate_mapped(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestFinish), &mapping);
        assert_eq!(s.mapping(), mapping);
    }

    #[test]
    fn pl_prioritizes_critical_chain() {
        // one long chain + independent fillers: PL must start the chain
        // head first even though fillers were released equally at t=0.
        let root = reg(0, 400, 0, 400);
        let mut dag = TaskDag::new(TaskSpec::new(TaskKind::Potrf, vec![root], vec![root]));
        let c = reg(0, 100, 0, 100);
        let mut specs = vec![];
        // fillers first in program order
        for i in 1..4 {
            let r = reg(100 * i, 100 * (i + 1), 0, 100);
            specs.push(TaskSpec::new(TaskKind::Gemm, vec![r], vec![r]));
        }
        for _ in 0..3 {
            specs.push(TaskSpec::new(TaskKind::Gemm, vec![c], vec![c]));
        }
        dag.partition(0, specs, 100);
        let (m, db) = single_space_machine(1, 0);
        let s = simulate(&dag, &m, &db, cfg(Ordering::PriorityList, ProcSelect::EarliestIdle));
        // chain head (pos 3) must be scheduled before the fillers
        let chain_start = s.assignments[3].start;
        for pos in 0..3 {
            assert!(s.assignments[pos].start >= chain_start - 1e-12, "filler {pos} before chain head");
        }
    }

    #[test]
    fn active_at_counts_running_tasks() {
        let (m, db) = single_space_machine(2, 0);
        let dag = independent(2);
        let s = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestIdle));
        let mid = s.makespan / 2.0;
        assert_eq!(s.active_at(mid), 2);
        assert_eq!(s.active_at(s.makespan + 1.0), 0);
    }

    #[test]
    fn makespan_covers_trailing_writeback() {
        let (m, db) = gpu_machine();
        let dag = chain(1);
        let s = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::Fastest).with_cache(CachePolicy::WriteThrough));
        let last_transfer = s.transfers.iter().map(|t| t.end).fold(0.0f64, f64::max);
        assert!(s.makespan >= last_transfer - 1e-12);
    }
}
