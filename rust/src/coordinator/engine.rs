//! The discrete-event schedule simulator.
//!
//! A list scheduler over the frontier DAG, driven by a **typed event
//! queue** and a global clock. Scheduling decisions happen in simulated
//! -time order: when the clock reaches a task's release (the `TaskEnd`
//! of its last predecessor), the ready set is dispatched by a
//! [`SchedPolicy`] with ordering keys **recomputed at decision time** —
//! a policy always sees current processor/link occupancy, never the
//! state at push time.
//!
//! Resources are modeled as [`Timeline`]s — bookable interval sets, not
//! scalar high-water marks. Data movement is simulated explicitly: reads
//! that miss in the processor's memory space issue fetch transfers over
//! the interconnect with per-link queuing resolved in simulated-time
//! order, and transfers may *backfill* idle link windows left open by
//! earlier bookings. Write effects (coherence updates per the WB/WT/WA
//! caching policy, plus their backflow traffic) are applied when the
//! `TaskEnd` event fires, not when the decision is taken.
//!
//! The same event core ([`EventCore`]) also powers schedule replay
//! ([`simulate_mapped`]) and the constructive online scheduler
//! ([`super::constructive`]), so all three paths share one clock and one
//! commit path.
//!
//! Entry points come in pairs: the legacy enum-configured ones
//! ([`simulate`], [`simulate_flat`], [`simulate_mapped`]) construct the
//! matching built-in policy from [`SimConfig`]'s shim fields, and the
//! `_policy` variants take any `&mut dyn SchedPolicy`.

use super::coherence::{CachePolicy, Coherence, SpaceId, Transfer};
use super::datadag::BlockId;
use super::faults::FaultPlan;
use super::ordering::critical_times;
use super::perfmodel::PerfDb;
use super::platform::{LinkId, Machine, ProcId, Timeline};
use super::policies::{Ordering, ProcSelect, SchedConfig};
use super::policy::{self, ArrivalTable, JobInfo, SchedContext, SchedPolicy};
use super::task::{Task, TaskId};
use super::taskdag::{FlatDag, TaskDag};
use crate::util::fxhash::FxHashMap;
use crate::util::rng::Rng;

/// Simulation knobs beyond the platform itself.
#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    /// Legacy ordering shim — used only to construct the matching built-in
    /// policy when an enum-configured entry point is called. Prefer the
    /// `_policy` entry points with a [`SchedPolicy`] value.
    pub ordering: Ordering,
    /// Legacy selection shim (see `ordering`).
    pub select: ProcSelect,
    pub cache: CachePolicy,
    /// Bytes per matrix element (4 = f32, 8 = f64).
    pub elem_bytes: u64,
    pub seed: u64,
}

impl SimConfig {
    pub fn new(cfg: SchedConfig) -> SimConfig {
        SimConfig {
            ordering: cfg.ordering,
            select: cfg.select,
            cache: CachePolicy::WriteBack,
            elem_bytes: 4,
            seed: 0,
        }
    }

    pub fn with_cache(mut self, c: CachePolicy) -> Self {
        self.cache = c;
        self
    }

    pub fn with_elem_bytes(mut self, b: u64) -> Self {
        self.elem_bytes = b;
        self
    }

    pub fn with_seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// A simulated data transfer (for traces and transfer accounting).
#[derive(Debug, Clone, Copy)]
pub struct TransferRecord {
    pub from: SpaceId,
    pub to: SpaceId,
    pub bytes: u64,
    pub start: f64,
    pub end: f64,
    /// Task whose dispatch booked this transfer as an input fetch — its
    /// execution must not start before `end` (the arrival gate the
    /// [`super::validate`] oracle checks). `None` for background traffic
    /// (write-through pushes, write-back evictions, write-around streams),
    /// which occupies links but gates no task.
    pub dst_task: Option<TaskId>,
}

/// One task placement in the simulated schedule.
#[derive(Debug, Clone, Copy)]
pub struct Assignment {
    pub task: TaskId,
    /// Position in the frontier (program order).
    pub pos: usize,
    pub proc: ProcId,
    /// Time all predecessors were finished.
    pub release: f64,
    pub start: f64,
    pub end: f64,
}

/// A typed occurrence in simulated time — the currency of the event
/// queue, and (via [`Schedule::events`]) the time-ordered trace the
/// simulation emits.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    /// A transfer began occupying its first link.
    TransferStart { from: SpaceId, to: SpaceId, bytes: u64 },
    /// A transfer's payload arrived in the destination space.
    TransferEnd { from: SpaceId, to: SpaceId, bytes: u64 },
    /// A task began executing.
    TaskStart { task: TaskId, proc: ProcId },
    /// A task finished; its write effects apply at this instant.
    TaskEnd { task: TaskId, proc: ProcId },
    /// A processor ran out of booked work.
    ProcIdle { proc: ProcId },
    /// A processor died (fail-stop): its in-flight work is lost past this
    /// instant and everything booked later is cancelled and re-dispatched.
    ProcFail { proc: ProcId },
    /// A dead processor came back (end of its dead window).
    ProcRestore { proc: ProcId },
    /// An execution attempt of `task` faulted (transient fault, or its
    /// processor died mid-flight); its writes are discarded and the task
    /// re-enters the ready queue if attempts remain.
    TaskFault { task: TaskId, proc: ProcId },
}

/// An [`EventKind`] stamped with its simulated time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimEvent {
    pub time: f64,
    pub kind: EventKind,
}

/// The simulation result.
#[derive(Debug, Clone, Default)]
pub struct Schedule {
    /// Assignments indexed by frontier position.
    pub assignments: Vec<Assignment>,
    pub transfers: Vec<TransferRecord>,
    pub makespan: f64,
    /// Busy seconds per processor.
    pub proc_busy: Vec<f64>,
    /// Total bytes moved between memory spaces.
    pub transfer_bytes: u64,
    /// The full time-ordered event log the run emitted
    /// (`TaskStart`/`TaskEnd`/`TransferStart`/`TransferEnd`/`ProcIdle`).
    pub events: Vec<SimEvent>,
    /// Per-hop link bookings `(link, start, end)`, one entry per link a
    /// transfer occupied, in booking order. A [`TransferRecord`] spans its
    /// whole route (first-hop start to last-hop end, with possible idle
    /// gaps between hops); this list is the exact occupancy, which is what
    /// lets the [`super::validate`] oracle prove no two transfers ever
    /// overlap on one link without trusting [`Timeline`]'s own arithmetic.
    pub link_occupancy: Vec<(LinkId, f64, f64)>,
}

impl Schedule {
    /// Average processor load: mean over processors of busy/makespan
    /// (Table 1's "Avg. load" column).
    pub fn avg_load(&self) -> f64 {
        if self.makespan <= 0.0 || self.proc_busy.is_empty() {
            return 0.0;
        }
        self.proc_busy.iter().map(|b| b / self.makespan).sum::<f64>() / self.proc_busy.len() as f64
    }

    /// Processor -> task→proc mapping vector (for schedule replay).
    pub fn mapping(&self) -> Vec<ProcId> {
        self.assignments.iter().map(|a| a.proc).collect()
    }

    /// Number of processors busy at time `t` (Fig. 2b load traces).
    pub fn active_at(&self, t: f64) -> usize {
        self.assignments.iter().filter(|a| a.start <= t && t < a.end).count()
    }
}

/// Simulate scheduling `dag`'s frontier on `machine` under the built-in
/// policy named by `cfg`'s shim fields.
pub fn simulate(dag: &TaskDag, machine: &Machine, db: &PerfDb, cfg: SimConfig) -> Schedule {
    let mut p = policy::policy_for(SchedConfig::new(cfg.ordering, cfg.select));
    run(dag, machine, db, cfg, None, None, p.as_mut())
}

/// Simulate under an arbitrary scheduling policy.
pub fn simulate_policy(
    dag: &TaskDag,
    machine: &Machine,
    db: &PerfDb,
    cfg: SimConfig,
    policy: &mut dyn SchedPolicy,
) -> Schedule {
    run(dag, machine, db, cfg, None, None, policy)
}

/// Like [`simulate`], reusing an already-derived [`FlatDag`] (the solver
/// needs the same frontier for candidate collection; deriving it twice per
/// iteration was a measured hot spot — §Perf optimization 3).
pub fn simulate_flat(dag: &TaskDag, flat: &FlatDag, machine: &Machine, db: &PerfDb, cfg: SimConfig) -> Schedule {
    let mut p = policy::policy_for(SchedConfig::new(cfg.ordering, cfg.select));
    run(dag, machine, db, cfg, None, Some(flat), p.as_mut())
}

/// [`simulate_flat`] under an arbitrary scheduling policy.
pub fn simulate_flat_policy(
    dag: &TaskDag,
    flat: &FlatDag,
    machine: &Machine,
    db: &PerfDb,
    cfg: SimConfig,
    policy: &mut dyn SchedPolicy,
) -> Schedule {
    run(dag, machine, db, cfg, None, Some(flat), policy)
}

/// Replay a fixed task→processor mapping (positions in frontier order) —
/// the HESP-REPLICA mode used for framework validation (§3.1). The policy
/// still orders the ready queue; selection is forced by `mapping`.
pub fn simulate_mapped(dag: &TaskDag, machine: &Machine, db: &PerfDb, cfg: SimConfig, mapping: &[ProcId]) -> Schedule {
    let mut p = policy::policy_for(SchedConfig::new(cfg.ordering, cfg.select));
    run(dag, machine, db, cfg, Some(mapping), None, p.as_mut())
}

/// A queued event: `(time, seq)` orders the queue (seq = push order, so
/// simultaneous events pop FIFO and runs are deterministic). `key` is the
/// caller's task handle (frontier position offline, task id online),
/// meaningful only for `TaskEnd`.
#[derive(Debug, Clone, Copy)]
struct QEvent {
    time: f64,
    seq: u64,
    key: usize,
    kind: EventKind,
}

impl PartialEq for QEvent {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for QEvent {}
impl PartialOrd for QEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for QEvent {
    // reversed: BinaryHeap is a max-heap, we want the earliest event first
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other.time.total_cmp(&self.time).then(other.seq.cmp(&self.seq))
    }
}

/// One dispatch decision of a simulation run, recorded in **task-id
/// space** so a log survives frontier re-indexing when the solver
/// mutates the DAG between iterations. `time` is the decision round's
/// clock value (== the task's release in the offline engine, since a
/// round drains everything ready at its timestamp).
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) struct Decision {
    pub task: TaskId,
    pub proc: ProcId,
    pub time: f64,
}

/// A copy-on-write snapshot of the event core at a decision-round
/// boundary (loop top: the previous event batch is fully processed, the
/// round at `now` has not dispatched yet). Everything positional is
/// stored in task-id space — `TaskEnd` queue keys and the dispatched
/// [`Assignment`]s — so a checkpoint taken under one frontier can be
/// restored under any frontier whose verified decision prefix matches
/// (the delta evaluator's contract, [`super::delta`]). Checkpoints are
/// shared via `Arc` across candidate evaluations and inherited by
/// accepted candidates; restoring clones only the state that replay will
/// mutate.
#[derive(Debug, Clone)]
pub(crate) struct Checkpoint {
    /// Decisions dispatched before this snapshot.
    pub n_decisions: usize,
    /// Clock at the snapshot (the upcoming round's timestamp).
    pub now: f64,
    seq: u64,
    /// Pending events; `TaskEnd` keys remapped position → task id.
    queue: Vec<QEvent>,
    procs: Vec<Timeline>,
    links: Vec<Timeline>,
    coh: Coherence,
    rng: Rng,
    /// Schedule so far, with `assignments` holding only the dispatched
    /// tasks (dense, dispatch order); positions re-derived at restore.
    sched: Schedule,
    arrivals: ArrivalTable,
    idle_candidates: Vec<(f64, ProcId)>,
}

/// A recorded simulation trajectory: the dispatch log plus periodic
/// [`Checkpoint`]s. Produced by [`simulate_flat_traced`] /
/// [`simulate_flat_replay`]; consumed by the delta evaluator.
#[derive(Default, Clone)]
pub(crate) struct SimTrace {
    pub decisions: Vec<Decision>,
    pub checkpoints: Vec<std::sync::Arc<Checkpoint>>,
}

impl Checkpoint {
    /// Snapshot `core` at a decision-round boundary: `decisions` have been
    /// dispatched, the round at `core.now` has not run yet. `pos_of` maps
    /// the capturing frontier's task ids to positions — the queue and the
    /// dispatched assignments leave position space here so a restore under
    /// a mutated frontier can re-derive positions from its own id map.
    fn capture(core: &EventCore<'_>, decisions: &[Decision], pos_of: &FxHashMap<TaskId, usize>) -> Checkpoint {
        let queue = core
            .queue
            .iter()
            .map(|e| match e.kind {
                EventKind::TaskEnd { task, .. } => QEvent { key: task, ..*e },
                _ => *e,
            })
            .collect();
        let assignments = decisions.iter().map(|d| core.sched.assignments[pos_of[&d.task]]).collect();
        let s = &core.sched;
        Checkpoint {
            n_decisions: decisions.len(),
            now: core.now,
            seq: core.seq,
            queue,
            procs: core.procs.clone(),
            links: core.links.clone(),
            coh: core.coh.clone(),
            rng: core.rng.clone(),
            sched: Schedule {
                assignments,
                transfers: s.transfers.clone(),
                makespan: 0.0,
                proc_busy: s.proc_busy.clone(),
                transfer_bytes: s.transfer_bytes,
                events: s.events.clone(),
                link_occupancy: s.link_occupancy.clone(),
            },
            arrivals: core.arrivals.clone(),
            idle_candidates: core.idle_candidates.clone(),
        }
    }
}

/// Instructions for an incremental re-simulation: restore `ckpt` (or
/// start fresh when `None`), seed the ready-set bookkeeping with the
/// given arrays (indexed by the *candidate* frontier, produced by the
/// delta verifier's abstract scan), replay `forced` decisions without
/// invoking [`SchedPolicy::select`], then continue live.
pub(crate) struct ReplayPlan<'p> {
    pub ckpt: Option<&'p Checkpoint>,
    /// Ordering priorities (critical times) for the candidate frontier —
    /// the verifier already computed them for its scan, so the engine
    /// does not run the O(V+E) backflow pass again.
    pub prio: Vec<f64>,
    pub indeg: Vec<usize>,
    pub release: Vec<f64>,
    pub ready: Vec<usize>,
    pub forced: &'p [Decision],
}

/// Reusable per-thread simulation buffers: the event-loop bookkeeping
/// arrays, the resource timelines, and a recycled [`Schedule`] shell
/// whose record vectors keep their capacity between runs (the batched
/// evaluator used to allocate all of these fresh per candidate). Taken
/// from / returned to a thread-local pool by [`run_core`]; every field
/// is clear-and-refilled before use, and the timelines assert
/// [`Timeline::is_clear`] so a stale booking can never leak across
/// simulations (the oracle would catch the resulting shifted schedule,
/// but this fails at the source).
#[derive(Default)]
struct SimScratch {
    procs: Vec<Timeline>,
    links: Vec<Timeline>,
    indeg: Vec<usize>,
    release: Vec<f64>,
    keys: Vec<f64>,
    ready: Vec<usize>,
    batch: Vec<(usize, EventKind)>,
    spare: Schedule,
}

thread_local! {
    static SCRATCH_POOL: std::cell::RefCell<Option<Box<SimScratch>>> =
        const { std::cell::RefCell::new(None) };
}

/// Take the thread's scratch arena (a fresh one if the pool is empty or
/// a re-entrant simulation — e.g. a user policy simulating inside
/// `select` — already holds it).
fn scratch_take() -> Box<SimScratch> {
    SCRATCH_POOL.with(|p| p.borrow_mut().take()).unwrap_or_default()
}

fn scratch_put(s: Box<SimScratch>) {
    SCRATCH_POOL.with(|p| *p.borrow_mut() = Some(s));
}

/// Return a dead [`Schedule`]'s record vectors to this thread's scratch
/// pool (clear-and-refill reuse). The solver feeds discarded batch
/// evaluations through this instead of dropping them.
pub(crate) fn recycle_schedule(mut s: Schedule) {
    s.assignments.clear();
    s.transfers.clear();
    s.events.clear();
    s.link_occupancy.clear();
    s.proc_busy.clear();
    s.transfer_bytes = 0;
    s.makespan = 0.0;
    SCRATCH_POOL.with(|p| {
        if let Some(pool) = p.borrow_mut().as_mut() {
            // keep the larger allocation of the two
            if s.assignments.capacity() + s.events.capacity()
                > pool.spare.assignments.capacity() + pool.spare.events.capacity()
            {
                pool.spare = s;
            }
        }
    });
}

/// Clear-and-resize a timeline vector from the scratch pool, asserting
/// no booking survives the reset.
fn prepare_timelines(v: &mut Vec<Timeline>, n: usize) {
    v.truncate(n);
    for t in v.iter_mut() {
        t.reset();
        debug_assert!(t.is_clear(), "stale booking leaked through Timeline::reset");
    }
    v.resize_with(n, Timeline::new);
}

/// Event keys carry the attempt number of the execution they belong to
/// in their high bits once faults are active, so a retried task's events
/// are distinguishable from its killed attempt's. Attempt 0 encodes to
/// the bare key — with faults off (or before the first fault) keys are
/// bit-identical to the fault-free engine's.
pub(crate) const FAULT_ATTEMPT_SHIFT: u32 = 48;
pub(crate) const FAULT_KEY_MASK: usize = (1 << FAULT_ATTEMPT_SHIFT) - 1;

/// One booked-but-unfinished execution attempt (fault bookkeeping).
#[derive(Debug, Clone, Copy)]
struct LiveAttempt {
    /// Attempt-encoded event key.
    ekey: usize,
    task: TaskId,
    proc: ProcId,
    start: f64,
    end: f64,
    /// Whether the transient roll already doomed this attempt (a
    /// `TaskFault` is queued at `end` instead of a `TaskEnd`).
    doomed: bool,
}

/// Live fault-injection state of one run (present only when a
/// [`FaultPlan`] is installed; `None` keeps the fault-free engine
/// bit-identical to before this subsystem existed).
struct FaultRt {
    plan: FaultPlan,
    /// Next attempt number per base key (missing = 0). Lookup-only — the
    /// map is never iterated, so determinism is unaffected.
    attempts: FxHashMap<usize, u32>,
    /// Encoded keys of killed attempts whose already-queued events must
    /// be swallowed instead of delivered.
    stale: Vec<usize>,
    /// Attempts booked but not yet completed/faulted, in dispatch order.
    live: Vec<LiveAttempt>,
    /// A task ran out of attempts: the run can never complete.
    exhausted: bool,
    /// Faults injected so far (transient dooms + fail-stop kills).
    injected: usize,
    /// Seconds of work that executed and was then lost to a fault.
    wasted: f64,
}

/// Earliest placement of `nominal` seconds of work on a possibly
/// throttled processor. The fit and the stretched duration are mutually
/// dependent (the duration depends on where the booking lands relative
/// to the throttle windows), so iterate to a fixed point, falling back
/// to a tail placement — which is always self-consistent, since nothing
/// is booked after the tail.
fn fit_throttled(tl: &Timeline, plan: &FaultPlan, proc: ProcId, ready: f64, nominal: f64) -> (f64, f64) {
    let mut dur = plan.exec_duration(proc, ready, nominal);
    for _ in 0..8 {
        let start = tl.earliest_fit(ready, dur);
        if !start.is_finite() {
            return (start, dur);
        }
        let again = plan.exec_duration(proc, start, nominal);
        if again.to_bits() == dur.to_bits() {
            return (start, dur);
        }
        dur = again;
    }
    let start = tl.tail().max(ready);
    (start, plan.exec_duration(proc, start, nominal))
}

/// The shared discrete-event core: global clock, typed event queue,
/// per-processor and per-link [`Timeline`]s, coherence state and the
/// schedule under construction. The offline engine, replay and the
/// constructive online scheduler are all loops over this one struct —
/// they differ only in graph bookkeeping (who becomes ready when).
pub(crate) struct EventCore<'a> {
    pub machine: &'a Machine,
    pub db: &'a PerfDb,
    /// The global clock: the time of the event batch being processed
    /// (and of every scheduling decision taken in the current round).
    pub now: f64,
    queue: std::collections::BinaryHeap<QEvent>,
    seq: u64,
    /// Per-processor booked execution windows.
    pub procs: Vec<Timeline>,
    /// Per-link booked transfer windows.
    pub links: Vec<Timeline>,
    pub coh: Coherence,
    pub rng: Rng,
    pub sched: Schedule,
    /// Physical arrival time of committed-but-in-flight blocks per
    /// destination space. Coherence validity flips at commit time (so a
    /// second reader of the same block does not double-fetch it), but a
    /// task reading a block another decision is still transferring must
    /// wait for the bytes, not the bookkeeping. Estimates see the same
    /// table through [`SchedContext::arrivals`].
    arrivals: ArrivalTable,
    /// `(went-idle-at, proc)` candidates from popped `TaskEnd` events.
    /// `ProcIdle` emission is deferred until after the decision round at
    /// that timestamp, so a processor immediately re-booked at the same
    /// instant does not log a spurious idle transition.
    idle_candidates: Vec<(f64, ProcId)>,
    /// Fault-injection state; `None` (the default) is the fault-free
    /// engine, bit-identical to before faults existed.
    faults: Option<FaultRt>,
}

impl<'a> EventCore<'a> {
    pub fn new(machine: &'a Machine, db: &'a PerfDb, cfg: SimConfig) -> EventCore<'a> {
        EventCore {
            machine,
            db,
            now: 0.0,
            queue: std::collections::BinaryHeap::new(),
            seq: 0,
            procs: vec![Timeline::new(); machine.n_procs()],
            links: vec![Timeline::new(); machine.links.len()],
            coh: Coherence::new(machine.spaces.len(), machine.main_space, cfg.cache, machine.capacities(), cfg.elem_bytes),
            rng: Rng::new(cfg.seed),
            sched: Schedule { proc_busy: vec![0.0; machine.n_procs()], ..Default::default() },
            arrivals: ArrivalTable::default(),
            idle_candidates: Vec::new(),
            faults: None,
        }
    }

    /// [`EventCore::new`] drawing its timelines and schedule shell from
    /// the thread's scratch arena instead of allocating fresh — the
    /// offline engine's constructor. Every buffer is clear-and-refilled,
    /// so scratch contents can never influence the run.
    fn new_with(machine: &'a Machine, db: &'a PerfDb, cfg: SimConfig, scratch: &mut SimScratch) -> EventCore<'a> {
        prepare_timelines(&mut scratch.procs, machine.n_procs());
        prepare_timelines(&mut scratch.links, machine.links.len());
        let mut sched = std::mem::take(&mut scratch.spare);
        sched.assignments.clear();
        sched.transfers.clear();
        sched.events.clear();
        sched.link_occupancy.clear();
        sched.proc_busy.clear();
        sched.proc_busy.resize(machine.n_procs(), 0.0);
        sched.transfer_bytes = 0;
        sched.makespan = 0.0;
        EventCore {
            machine,
            db,
            now: 0.0,
            queue: std::collections::BinaryHeap::new(),
            seq: 0,
            procs: std::mem::take(&mut scratch.procs),
            links: std::mem::take(&mut scratch.links),
            coh: Coherence::new(machine.spaces.len(), machine.main_space, cfg.cache, machine.capacities(), cfg.elem_bytes),
            rng: Rng::new(cfg.seed),
            sched,
            arrivals: ArrivalTable::default(),
            idle_candidates: Vec::new(),
            faults: None,
        }
    }

    /// Rebuild a core from a [`Checkpoint`] under the (possibly mutated)
    /// frontier described by `pos_of` / `n`. The event queue is rebuilt
    /// from the snapshot vector; the heap's internal layout may differ
    /// from the original run's, but pop order is fully determined by the
    /// unique `(time, seq)` pairs, so no downstream state can observe
    /// the difference.
    fn restore(
        machine: &'a Machine,
        db: &'a PerfDb,
        ck: &Checkpoint,
        pos_of: &FxHashMap<TaskId, usize>,
        n: usize,
    ) -> EventCore<'a> {
        let queue: Vec<QEvent> = ck
            .queue
            .iter()
            .map(|e| match e.kind {
                EventKind::TaskEnd { task, .. } => QEvent { key: pos_of[&task], ..*e },
                _ => *e,
            })
            .collect();
        let mut assignments =
            vec![Assignment { task: 0, pos: 0, proc: 0, release: 0.0, start: 0.0, end: 0.0 }; n];
        for a in &ck.sched.assignments {
            let p = pos_of[&a.task];
            assignments[p] = Assignment { pos: p, ..*a };
        }
        EventCore {
            machine,
            db,
            now: ck.now,
            queue: std::collections::BinaryHeap::from(queue),
            seq: ck.seq,
            procs: ck.procs.clone(),
            links: ck.links.clone(),
            coh: ck.coh.clone(),
            rng: ck.rng.clone(),
            sched: Schedule {
                assignments,
                transfers: ck.sched.transfers.clone(),
                makespan: 0.0,
                proc_busy: ck.sched.proc_busy.clone(),
                transfer_bytes: ck.sched.transfer_bytes,
                events: ck.sched.events.clone(),
                link_occupancy: ck.sched.link_occupancy.clone(),
            },
            arrivals: ck.arrivals.clone(),
            idle_candidates: ck.idle_candidates.clone(),
            faults: None,
        }
    }

    /// A decision-time view for policy dispatch. Constructed fresh per
    /// call; never stored.
    pub fn ctx<'s>(&'s mut self, successors: &'s [&'s Task]) -> SchedContext<'s> {
        self.ctx_job(successors, None)
    }

    /// [`EventCore::ctx`] with the owning job's identity attached — the
    /// service layer's multi-job loop exposes job id / deadline slack to
    /// job-aware policies this way. Single-DAG callers pass `None` and
    /// those policies degrade to their job-oblivious fallbacks.
    pub fn ctx_job<'s>(&'s mut self, successors: &'s [&'s Task], job: Option<JobInfo>) -> SchedContext<'s> {
        SchedContext {
            machine: self.machine,
            db: self.db,
            now: self.now,
            procs: &self.procs,
            links: &self.links,
            arrivals: &self.arrivals,
            coh: &mut self.coh,
            rng: &mut self.rng,
            successors,
            job,
        }
    }

    /// Time of the earliest pending event, if any — the service layer
    /// interleaves job arrivals with the event stream by comparing the
    /// next arrival against this before popping a batch.
    pub fn next_event_time(&self) -> Option<f64> {
        self.queue.peek().map(|e| e.time)
    }

    fn push_event(&mut self, time: f64, key: usize, kind: EventKind) {
        self.seq += 1;
        self.queue.push(QEvent { time, seq: self.seq, key, kind });
    }

    /// Arm fault injection: queue the plan's fail-stop/restore markers
    /// (ahead of every task event, so they pop first within their
    /// timestamp batch) and pre-book link-outage blackouts. Entries
    /// referencing processors or links the machine does not have are
    /// skipped — a spec file is platform-independent.
    pub(crate) fn install_faults(&mut self, plan: &FaultPlan) {
        for f in &plan.spec.fail_stop {
            if f.proc >= self.machine.n_procs() {
                continue;
            }
            self.push_event(f.at, usize::MAX, EventKind::ProcFail { proc: f.proc });
            if let Some(r) = f.restore {
                self.push_event(r, usize::MAX, EventKind::ProcRestore { proc: f.proc });
            }
        }
        // a degraded link keeps `factor` of its window: model the lost
        // fraction as one blackout booking at the window start, which
        // every transfer then deterministically routes around via the
        // normal earliest-fit arithmetic. Booked into the link timeline
        // only — not `link_occupancy` — so the oracle's link-exclusivity
        // check stays a transfer-vs-transfer property.
        for o in &plan.spec.link_outage {
            if o.link >= self.links.len() {
                continue;
            }
            let span = (o.to - o.from) * (1.0 - o.factor.clamp(0.0, 1.0));
            let fit = self.links[o.link].earliest_fit(o.from, span);
            self.links[o.link].book(fit, span);
        }
        self.faults = Some(FaultRt {
            plan: plan.clone(),
            attempts: FxHashMap::default(),
            stale: Vec::new(),
            live: Vec::new(),
            exhausted: false,
            injected: 0,
            wasted: 0.0,
        });
    }

    /// Whether `ekey` belongs to a killed attempt (its queued events are
    /// swallowed instead of delivered).
    fn fault_stale(&self, ekey: usize) -> bool {
        self.faults.as_ref().is_some_and(|rt| rt.stale.contains(&ekey))
    }

    /// After a delivered `TaskFault`: may the task at base key `base` be
    /// re-dispatched? Exhausting the attempt budget poisons the run
    /// ([`EventCore::finish`] reports an `INFINITY` makespan).
    pub(crate) fn fault_retry(&mut self, base: usize) -> bool {
        let Some(rt) = self.faults.as_mut() else {
            return false;
        };
        let next = rt.attempts.get(&base).copied().unwrap_or(0);
        if next < rt.plan.max_attempts() {
            true
        } else {
            rt.exhausted = true;
            false
        }
    }

    /// Fault accounting of the run so far: `(faults injected, attempt
    /// budget exhausted, seconds of executed-then-lost work)`.
    pub fn fault_stats(&self) -> (usize, bool, f64) {
        match self.faults.as_ref() {
            Some(rt) => (rt.injected, rt.exhausted, rt.wasted),
            None => (0, false, 0.0),
        }
    }

    /// Fail-stop death of `proc` at the current clock: kill every attempt
    /// on it that has not finished (keeping the executed prefix booked,
    /// unbooking the rest and refunding busy time), queue replacement
    /// `TaskFault`s at the death instant, and book the dead window so
    /// every placement path — `commit`'s earliest-fit and the policies'
    /// placement estimates alike — routes around the death.
    fn on_proc_fail(&mut self, proc: ProcId) {
        let now = self.now;
        let Some(mut rt) = self.faults.take() else {
            return;
        };
        let mut killed: Vec<LiveAttempt> = Vec::new();
        rt.live.retain(|l| {
            if l.proc == proc && l.end > now && l.start.is_finite() {
                killed.push(*l);
                false
            } else {
                true
            }
        });
        for l in killed {
            // the executed prefix [start, now) stays booked and billed;
            // everything past the death instant is lost
            let cut = l.start.max(now);
            self.procs[proc].unbook(cut, l.end);
            self.sched.proc_busy[proc] -= l.end - cut;
            if l.doomed {
                // its transient doom already billed the full duration
                rt.wasted -= l.end - cut;
            } else {
                rt.wasted += cut - l.start;
                rt.injected += 1;
            }
            let attempt = (l.ekey >> FAULT_ATTEMPT_SHIFT) as u32;
            rt.attempts.insert(l.ekey & FAULT_KEY_MASK, attempt + 1);
            rt.stale.push(l.ekey);
            // replacement fault event at the death instant, encoded with
            // the *next* attempt so the stale filter does not swallow it
            let fkey = (l.ekey & FAULT_KEY_MASK) | (((attempt + 1) as usize) << FAULT_ATTEMPT_SHIFT);
            self.push_event(now, fkey, EventKind::TaskFault { task: l.task, proc });
        }
        for (at, until) in rt.plan.dead_windows(proc) {
            if at <= now && now < until {
                let span = if until.is_finite() { until - now } else { f64::INFINITY };
                self.procs[proc].book(now, span);
            }
        }
        self.faults = Some(rt);
    }

    /// Book `bytes` along the route `from -> to`, each hop in the
    /// earliest fitting window at or after `at` (gap backfill). Returns
    /// `(start of first hop, end of last hop)`. Panics — via
    /// [`Machine::route`] — if the spaces are distinct but disconnected;
    /// callers must never pass `from == to`.
    fn book_route(&mut self, from: SpaceId, to: SpaceId, bytes: u64, at: f64) -> (f64, f64) {
        debug_assert_ne!(from, to, "same-space transfers are no-ops, not bookings");
        let route = self.machine.route(from, to);
        assert!(!route.is_empty(), "empty route between distinct spaces {from} and {to}");
        let mut t = at;
        let mut first = f64::INFINITY;
        for lid in route {
            let l = &self.machine.links[lid];
            let dur = l.latency + bytes as f64 / l.bandwidth;
            let s = self.links[lid].earliest_fit(t, dur);
            self.links[lid].book(s, dur);
            self.sched.link_occupancy.push((lid, s, s + dur));
            if first.is_infinite() {
                first = s;
            }
            t = s + dur;
        }
        (first, t)
    }

    fn record_transfer(
        &mut self,
        from: SpaceId,
        to: SpaceId,
        bytes: u64,
        start: f64,
        end: f64,
        dst_task: Option<TaskId>,
    ) {
        debug_assert!(start.is_finite() && end >= start, "malformed transfer record");
        self.sched.transfers.push(TransferRecord { from, to, bytes, start, end, dst_task });
        self.sched.transfer_bytes += bytes;
        self.push_event(start, usize::MAX, EventKind::TransferStart { from, to, bytes });
        self.push_event(end, usize::MAX, EventKind::TransferEnd { from, to, bytes });
    }

    fn note_arrival(&mut self, block: BlockId, space: SpaceId, at: f64) {
        let slot = self.arrivals.entry((block, space)).or_insert(at);
        *slot = slot.max(at);
    }

    /// Charge write-through/write-back/eviction traffic on the
    /// interconnect starting at `at` (it does not delay the issuing task,
    /// but occupies link windows and counts toward transfer volume).
    fn charge_background(&mut self, at: f64, transfers: &[Transfer]) {
        for tr in transfers {
            if tr.from == tr.to {
                continue; // same-space: explicit no-op
            }
            let (start, end) = self.book_route(tr.from, tr.to, tr.bytes, at);
            self.record_transfer(tr.from, tr.to, tr.bytes, start, end, None);
            self.note_arrival(tr.block, tr.to, end);
        }
    }

    /// Commit a dispatch decision taken at time `rel` (== `self.now`):
    /// book the task's input transfers (backfilling idle link windows),
    /// book execution in the earliest fitting window of `proc`, and push
    /// the `TransferStart`/`TransferEnd`/`TaskStart`/`TaskEnd` events.
    /// `key` is the caller's handle, returned with the `TaskEnd` event.
    /// Write effects are NOT applied here — they happen when `TaskEnd`
    /// fires (see [`EventCore::apply_writes`]). Returns `(start, end)`.
    pub fn commit(&mut self, task: &Task, key: usize, proc: ProcId, rel: f64) -> (f64, f64) {
        let space = self.machine.procs[proc].space;
        let (_, planned) =
            policy::plan_reads(self.machine, &self.links, &mut self.coh, &self.arrivals, task, space, rel);
        let mut data_ready = rel;
        let mut fetched_parents: Vec<BlockId> = Vec::new();
        for (parent, tr) in planned {
            if tr.from == tr.to {
                continue; // data already local: explicit no-op
            }
            let (start, end) = self.book_route(tr.from, tr.to, tr.bytes, rel);
            data_ready = data_ready.max(end);
            self.record_transfer(tr.from, tr.to, tr.bytes, start, end, Some(task.id));
            self.note_arrival(tr.block, tr.to, end);
            let evict = self.coh.complete_read(tr.block, tr.to);
            self.charge_background(end, &evict);
            if tr.block != parent && !fetched_parents.contains(&parent) {
                fetched_parents.push(parent);
            }
        }
        // a reassembled coarse block is fully present once all fragments land
        for parent in fetched_parents {
            let evict = self.coh.complete_read(parent, space);
            self.note_arrival(parent, space, data_ready);
            self.charge_background(data_ready, &evict);
        }
        // blocks already valid here but still physically in flight (fetched
        // by an earlier decision, arriving later) gate the start too — the
        // same gate the estimate path applies inside plan_reads
        data_ready = policy::arrival_gate(&mut self.coh, &self.arrivals, task, space, data_ready);
        let nominal = self.db.time(self.machine.procs[proc].ptype, task.kind, task.char_edge(), task.flops);
        // fault path: attempt-encoded event key + throttle-stretched
        // duration; attempt 0 encodes to the bare key, so a fault-free
        // run is bit-identical to the plain path below
        let (ekey, attempt, start, dur) = match self.faults.as_ref() {
            None => (key, 0u32, self.procs[proc].earliest_fit(data_ready, nominal), nominal),
            Some(rt) => {
                let attempt = rt.attempts.get(&key).copied().unwrap_or(0);
                let (start, dur) = fit_throttled(&self.procs[proc], &rt.plan, proc, data_ready, nominal);
                (key | ((attempt as usize) << FAULT_ATTEMPT_SHIFT), attempt, start, dur)
            }
        };
        self.procs[proc].book(start, dur);
        let end = start + dur;
        if end.is_finite() {
            self.sched.proc_busy[proc] += end - start;
        }
        let skey = if self.faults.is_some() { ekey } else { usize::MAX };
        self.push_event(start, skey, EventKind::TaskStart { task: task.id, proc });
        // transient roll: a doomed attempt runs to completion but its
        // results are lost — a TaskFault fires at `end` instead of the
        // TaskEnd, so no successor releases and no writes apply
        let doomed = match self.faults.as_ref() {
            Some(rt) => rt.plan.transient_hits(task.id, attempt),
            None => false,
        };
        if doomed {
            self.push_event(end, ekey, EventKind::TaskFault { task: task.id, proc });
        } else {
            self.push_event(end, ekey, EventKind::TaskEnd { task: task.id, proc });
        }
        if let Some(rt) = self.faults.as_mut() {
            if doomed {
                rt.attempts.insert(key, attempt + 1);
                rt.injected += 1;
                if end.is_finite() {
                    rt.wasted += end - start;
                }
            }
            rt.live.push(LiveAttempt { ekey, task: task.id, proc, start, end, doomed });
        }
        (start, end)
    }

    /// Apply `task`'s write effects at its `TaskEnd` time `end`:
    /// coherence invalidation/validation per the caching policy, plus
    /// any backflow traffic (write-through pushes, write-around streams,
    /// evictions) charged on the interconnect from `end`.
    pub fn apply_writes(&mut self, task: &Task, proc: ProcId, end: f64) {
        let space = self.machine.procs[proc].space;
        for w in task.writes.iter() {
            let block = self.coh.register(*w);
            let extra = self.coh.complete_write(block, space);
            self.charge_background(end, &extra);
        }
    }

    /// Advance the clock to the next pending event and drain every event
    /// at that timestamp into `batch` (in push order). A `TaskEnd` whose
    /// processor has no further booked work marks an idle *candidate*;
    /// the `ProcIdle` event is emitted on the next call — i.e. after the
    /// decision round at that timestamp — and only if the processor was
    /// not re-booked in the meantime, so a busy chain does not log
    /// spurious idle transitions. Returns `false` when the queue is
    /// empty (the simulation is over).
    pub fn pop_event_batch(&mut self, batch: &mut Vec<(usize, EventKind)>) -> bool {
        batch.clear();
        // flush idle candidates from the previous batch: still nothing
        // booked after their idle instant means the processor truly idled
        for (at, proc) in std::mem::take(&mut self.idle_candidates) {
            if !self.procs[proc].busy_after(at) {
                self.push_event(at, usize::MAX, EventKind::ProcIdle { proc });
            }
        }
        let Some(head) = self.queue.peek() else {
            return false;
        };
        let t = head.time;
        debug_assert!(t >= self.now, "event clock went backwards");
        self.now = t;
        while let Some(head) = self.queue.peek() {
            if head.time > t {
                break;
            }
            let ev = self.queue.pop().expect("peeked");
            if self.faults.is_some() {
                match ev.kind {
                    // cancel the dying processor's booked work *before*
                    // later events at this instant are delivered
                    EventKind::ProcFail { proc } => self.on_proc_fail(proc),
                    EventKind::TaskStart { .. } | EventKind::TaskEnd { .. } | EventKind::TaskFault { .. } => {
                        if self.fault_stale(ev.key) {
                            continue; // a killed attempt's event: swallowed
                        }
                        if matches!(ev.kind, EventKind::TaskEnd { .. } | EventKind::TaskFault { .. }) {
                            if let Some(rt) = self.faults.as_mut() {
                                rt.live.retain(|l| l.ekey != ev.key);
                            }
                        }
                    }
                    _ => {}
                }
            }
            self.sched.events.push(SimEvent { time: ev.time, kind: ev.kind });
            if let EventKind::TaskEnd { proc, .. } = ev.kind {
                if !self.procs[proc].busy_after(t) {
                    self.idle_candidates.push((t, proc));
                }
            }
            batch.push((ev.key, ev.kind));
        }
        true
    }

    /// Close out: compute the makespan (tasks and trailing transfers both
    /// count) and hand over the schedule.
    pub fn finish(mut self) -> Schedule {
        let task_end = self.sched.assignments.iter().map(|a| a.end).fold(0.0f64, f64::max);
        let xfer_end = self.sched.transfers.iter().map(|t| t.end).fold(0.0f64, f64::max);
        self.sched.makespan = task_end.max(xfer_end);
        if self.faults.as_ref().is_some_and(|rt| rt.exhausted) {
            // a task ran out of attempts: the workload never completes
            self.sched.makespan = f64::INFINITY;
        }
        self.sched
    }
}

/// The decision-time selection scan shared by the offline and online
/// loops: index of the entry (of `n`) with the largest key, ties broken
/// toward the smaller `ord_of` value (frontier position offline, task id
/// online — both track program order). `key_of` is consulted fresh for
/// every entry on every pick, which is what makes ordering keys
/// decision-time state for dynamic policies.
pub(crate) fn pick_best(
    n: usize,
    mut key_of: impl FnMut(usize) -> f64,
    ord_of: impl Fn(usize) -> usize,
) -> Option<usize> {
    let mut best: Option<(usize, f64, usize)> = None; // (index, key, ord)
    for i in 0..n {
        let key = key_of(i);
        let o = ord_of(i);
        let better = match best {
            None => true,
            Some((_, bk, bo)) => match key.total_cmp(&bk) {
                std::cmp::Ordering::Greater => true,
                std::cmp::Ordering::Equal => o < bo,
                std::cmp::Ordering::Less => false,
            },
        };
        if better {
            best = Some((i, key, o));
        }
    }
    best.map(|(i, _, _)| i)
}

/// Pick the ready task with the largest policy key (ties toward the
/// smaller frontier position, i.e. program order) and remove it.
/// Dynamic-order policies are re-keyed against live state on every pick;
/// static-key policies use the key cached when the task was released.
#[allow(clippy::too_many_arguments)]
fn pop_best(
    core: &mut EventCore<'_>,
    policy: &mut dyn SchedPolicy,
    dag: &TaskDag,
    flat: &FlatDag,
    ready: &[usize],
    release: &[f64],
    prio: &[f64],
    keys: &[f64],
) -> Option<usize> {
    let dynamic = policy.dynamic_order();
    pick_best(
        ready.len(),
        |i| {
            let pos = ready[i];
            if dynamic {
                let mut ctx = core.ctx(&[]);
                policy.order(&mut ctx, dag.task(flat.tasks[pos]), release[pos], prio[pos])
            } else {
                keys[pos]
            }
        },
        |i| ready[i],
    )
}

fn run(
    dag: &TaskDag,
    machine: &Machine,
    db: &PerfDb,
    cfg: SimConfig,
    forced: Option<&[ProcId]>,
    flat_in: Option<&FlatDag>,
    policy: &mut dyn SchedPolicy,
) -> Schedule {
    run_core(dag, machine, db, cfg, forced, flat_in, policy, None, None, 0, None)
}

/// Simulate under a deterministic fault plan: fail-stop deaths cancel
/// booked work and re-dispatch it, transient attempt faults send tasks
/// back to the ready queue for policy-driven rescheduling (bounded by
/// the spec's `max_attempts`), throttle windows stretch execution, and
/// link outages occupy interconnect windows. An exhausted attempt budget
/// yields `makespan = INFINITY`. Incompatible with mapping replay and
/// with the delta evaluator's tracing.
pub fn simulate_flat_faults(
    dag: &TaskDag,
    flat: &FlatDag,
    machine: &Machine,
    db: &PerfDb,
    cfg: SimConfig,
    policy: &mut dyn SchedPolicy,
    plan: &FaultPlan,
) -> Schedule {
    run_core(dag, machine, db, cfg, None, Some(flat), policy, None, None, 0, Some(plan))
}

/// Trace a full simulation: the schedule plus its decision log and
/// periodic [`Checkpoint`]s (`every` decisions apart; 0 = log only). The
/// returned trace is what the delta evaluator verifies candidates
/// against.
pub(crate) fn simulate_flat_traced(
    dag: &TaskDag,
    flat: &FlatDag,
    machine: &Machine,
    db: &PerfDb,
    cfg: SimConfig,
    policy: &mut dyn SchedPolicy,
    every: usize,
) -> (Schedule, SimTrace) {
    let mut trace = SimTrace::default();
    let sched = run_core(dag, machine, db, cfg, None, Some(flat), policy, None, Some(&mut trace), every, None);
    (sched, trace)
}

/// Incrementally re-simulate a candidate frontier from a [`ReplayPlan`]:
/// restore the plan's checkpoint (or start fresh), force-replay its
/// verified decisions without invoking selection, then continue live.
/// `seed` must already hold the decisions (and any inherited checkpoints)
/// preceding the restore point; it grows into the candidate's own full
/// trace. The result is bitwise identical to a from-scratch simulation of
/// the same frontier — the delta evaluator only hands over plans whose
/// prefix it has proven equivalent.
#[allow(clippy::too_many_arguments)]
pub(crate) fn simulate_flat_replay(
    dag: &TaskDag,
    flat: &FlatDag,
    machine: &Machine,
    db: &PerfDb,
    cfg: SimConfig,
    policy: &mut dyn SchedPolicy,
    plan: ReplayPlan<'_>,
    mut seed: SimTrace,
    every: usize,
) -> (Schedule, SimTrace) {
    let sched = run_core(dag, machine, db, cfg, None, Some(flat), policy, Some(plan), Some(&mut seed), every, None);
    (sched, seed)
}

#[allow(clippy::too_many_arguments)]
fn run_core(
    dag: &TaskDag,
    machine: &Machine,
    db: &PerfDb,
    cfg: SimConfig,
    forced: Option<&[ProcId]>,
    flat_in: Option<&FlatDag>,
    policy: &mut dyn SchedPolicy,
    plan: Option<ReplayPlan<'_>>,
    mut trace: Option<&mut SimTrace>,
    ckpt_every: usize,
    faults: Option<&FaultPlan>,
) -> Schedule {
    assert!(
        faults.is_none() || (forced.is_none() && plan.is_none() && trace.is_none()),
        "fault injection cannot be combined with mapping replay or tracing"
    );
    let flat_owned;
    let flat: &FlatDag = match flat_in {
        Some(f) => f,
        None => {
            flat_owned = dag.flat_dag();
            &flat_owned
        }
    };
    let n = flat.len();
    if let Some(m) = forced {
        assert_eq!(m.len(), n, "mapping length != frontier size");
    }

    let mut scratch = scratch_take();

    // task-id → frontier-position map, needed whenever decisions or
    // checkpoints cross frontier re-indexings (tracing or restoring)
    let pos_of: Option<FxHashMap<TaskId, usize>> = if trace.is_some() || plan.is_some() {
        Some(flat.tasks.iter().enumerate().map(|(i, &t)| (t, i)).collect())
    } else {
        None
    };

    let placeholder = Assignment { task: 0, pos: 0, proc: 0, release: 0.0, start: 0.0, end: 0.0 };
    let prio: Vec<f64>;
    let mut indeg: Vec<usize>;
    let mut release: Vec<f64>;
    let mut ready: Vec<usize>;
    let forced_log: &[Decision];
    let mut last_ckpt: usize;
    let mut core = match plan {
        Some(p) => {
            let core = match p.ckpt {
                Some(ck) => EventCore::restore(machine, db, ck, pos_of.as_ref().expect("plan implies id map"), n),
                None => {
                    let mut c = EventCore::new_with(machine, db, cfg, &mut scratch);
                    c.sched.assignments.resize(n, placeholder);
                    c
                }
            };
            prio = p.prio;
            indeg = p.indeg;
            release = p.release;
            ready = p.ready;
            forced_log = p.forced;
            last_ckpt = p.ckpt.map_or(0, |ck| ck.n_decisions);
            core
        }
        None => {
            // priority vector: a whole-DAG rank pass if the policy ships
            // one (the comm-aware classics), else backflow critical
            // times, computed only for policies that order by them (the
            // PL family); FCFS-like policies skip the O(V+E) pass
            prio = match policy.rank_tasks(dag, flat, machine, db, cfg.elem_bytes) {
                Some(r) => {
                    debug_assert_eq!(r.len(), n, "rank_tasks length != frontier size");
                    r
                }
                None if policy.wants_critical_times() => critical_times(dag, flat, machine, db),
                None => vec![0.0; n],
            };
            let mut c = EventCore::new_with(machine, db, cfg, &mut scratch);
            c.sched.assignments.resize(n, placeholder);
            indeg = std::mem::take(&mut scratch.indeg);
            indeg.clear();
            indeg.extend(flat.preds.iter().map(|p| p.len()));
            release = std::mem::take(&mut scratch.release);
            release.clear();
            release.resize(n, 0.0);
            ready = std::mem::take(&mut scratch.ready);
            ready.clear();
            ready.extend((0..n).filter(|&i| indeg[i] == 0));
            forced_log = &[];
            last_ckpt = 0;
            c
        }
    };

    if let Some(fp) = faults {
        core.install_faults(fp);
    }

    let mut batch = std::mem::take(&mut scratch.batch);
    batch.clear();
    // static-key policies are keyed once, when the task is released; a
    // restored ready set is re-keyed here (static keys ignore live state,
    // so these are bitwise the keys the original run computed)
    let static_keys = !policy.dynamic_order();
    let mut keys = std::mem::take(&mut scratch.keys);
    keys.clear();
    keys.resize(n, 0.0);
    if static_keys {
        for i in 0..ready.len() {
            let pos = ready[i];
            let mut ctx = core.ctx(&[]);
            keys[pos] = policy.order(&mut ctx, dag.task(flat.tasks[pos]), release[pos], prio[pos]);
        }
    }

    let mut fi = 0usize; // forced decisions replayed so far

    loop {
        // ---- periodic checkpoint: the loop top is a decision-round
        // boundary (previous batch fully processed, the round at `now`
        // not yet run) ----
        if ckpt_every > 0 {
            if let Some(tr) = trace.as_deref_mut() {
                let nd = tr.decisions.len();
                if nd > 0 && nd - last_ckpt >= ckpt_every {
                    let map = pos_of.as_ref().expect("tracing implies id map");
                    tr.checkpoints.push(std::sync::Arc::new(Checkpoint::capture(&core, &tr.decisions, map)));
                    last_ckpt = nd;
                }
            }
        }

        // ---- decision round: dispatch everything ready at `core.now`,
        // recomputing dynamic ordering keys between picks ----
        loop {
            let Some(i) = pop_best(&mut core, policy, dag, flat, &ready, &release, &prio, &keys) else {
                break;
            };
            let pos = ready.swap_remove(i);
            let rel = release[pos];
            let task = dag.task(flat.tasks[pos]);
            let proc: ProcId = if let Some(m) = forced {
                m[pos]
            } else if fi < forced_log.len() {
                // verified-prefix replay: the delta scan proved this round
                // picks this task with this release; skip selection (and
                // successor materialization) and reuse the logged decision
                let d = forced_log[fi];
                fi += 1;
                debug_assert_eq!(d.task, flat.tasks[pos], "replay diverged from the verified prefix");
                debug_assert_eq!(d.time.to_bits(), rel.to_bits(), "replayed decision at a different release");
                d.proc
            } else {
                // successor tasks materialize only for lookahead-style
                // policies — dispatch is a hot path
                let succ_tasks: Vec<&Task> = if policy.wants_successors() {
                    flat.succs[pos].iter().map(|&s| dag.task(flat.tasks[s])).collect()
                } else {
                    Vec::new()
                };
                let mut ctx = core.ctx(&succ_tasks);
                policy.select(&mut ctx, task, rel)
            };
            let (start, end) = core.commit(task, pos, proc, rel);
            core.sched.assignments[pos] =
                Assignment { task: flat.tasks[pos], pos, proc, release: rel, start, end };
            if let Some(tr) = trace.as_deref_mut() {
                tr.decisions.push(Decision { task: flat.tasks[pos], proc, time: rel });
            }
        }

        // ---- advance the clock to the next event batch ----
        if !core.pop_event_batch(&mut batch) {
            break;
        }
        for &(key, kind) in &batch {
            match kind {
                EventKind::TaskEnd { proc, .. } => {
                    let pos = key & FAULT_KEY_MASK;
                    core.apply_writes(dag.task(flat.tasks[pos]), proc, core.now);
                    for &s in &flat.succs[pos] {
                        indeg[s] -= 1;
                        release[s] = release[s].max(core.now);
                        if indeg[s] == 0 {
                            if static_keys {
                                let mut ctx = core.ctx(&[]);
                                keys[s] = policy.order(&mut ctx, dag.task(flat.tasks[s]), release[s], prio[s]);
                            }
                            ready.push(s);
                        }
                    }
                }
                EventKind::TaskFault { .. } => {
                    // a faulted attempt applied no writes and released no
                    // successors; the task re-enters the ready queue for a
                    // fresh policy decision if attempts remain
                    let pos = key & FAULT_KEY_MASK;
                    if core.fault_retry(pos) {
                        release[pos] = release[pos].max(core.now);
                        if static_keys {
                            let mut ctx = core.ctx(&[]);
                            keys[pos] = policy.order(&mut ctx, dag.task(flat.tasks[pos]), release[pos], prio[pos]);
                        }
                        ready.push(pos);
                    }
                }
                _ => {}
            }
        }
    }
    debug_assert_eq!(fi, forced_log.len(), "verified-prefix decisions left unreplayed");

    // return the loop buffers and timelines to the thread's arena;
    // `finish` only needs the schedule
    scratch.procs = std::mem::take(&mut core.procs);
    scratch.links = std::mem::take(&mut core.links);
    scratch.indeg = indeg;
    scratch.release = release;
    scratch.keys = keys;
    scratch.ready = ready;
    scratch.batch = batch;
    scratch_put(scratch);
    core.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::perfmodel::PerfCurve;
    use crate::coordinator::platform::MachineBuilder;
    use crate::coordinator::region::Region;
    use crate::coordinator::task::{TaskKind, TaskSpec};

    fn single_space_machine(n_fast: usize, n_slow: usize) -> (Machine, PerfDb) {
        let mut b = MachineBuilder::new("m");
        let h = b.space("host", u64::MAX);
        b.main(h);
        let slow = b.proc_type("slow", 1.0, 0.1);
        let fast = b.proc_type("fast", 1.0, 0.1);
        b.processors(n_slow, "s", slow, h);
        b.processors(n_fast, "f", fast, h);
        let m = b.build();
        let mut db = PerfDb::new();
        db.set_fallback(0, PerfCurve::Const { gflops: 1.0 });
        db.set_fallback(1, PerfCurve::Const { gflops: 4.0 });
        (m, db)
    }

    fn gpu_machine() -> (Machine, PerfDb) {
        let mut b = MachineBuilder::new("g");
        let h = b.space("host", u64::MAX);
        let g = b.space("gpu", u64::MAX);
        b.main(h);
        b.connect(h, g, 1e-5, 1e9);
        let cpu = b.proc_type("cpu", 1.0, 0.1);
        let gpu = b.proc_type("gpu", 1.0, 0.1);
        b.processors(1, "c", cpu, h);
        b.processors(1, "g", gpu, g);
        let m = b.build();
        let mut db = PerfDb::new();
        db.set_fallback(0, PerfCurve::Const { gflops: 1.0 });
        db.set_fallback(1, PerfCurve::Const { gflops: 10.0 });
        (m, db)
    }

    fn reg(r0: u32, r1: u32, c0: u32, c1: u32) -> Region {
        Region::new(0, r0, r1, c0, c1)
    }

    /// `k` independent gemm tasks over disjoint 100x100 tiles.
    fn independent(k: u32) -> TaskDag {
        let root = reg(0, 100 * k, 0, 100);
        let mut dag = TaskDag::new(TaskSpec::new(TaskKind::Potrf, vec![root], vec![root]));
        let specs: Vec<TaskSpec> = (0..k)
            .map(|i| {
                let r = reg(100 * i, 100 * (i + 1), 0, 100);
                TaskSpec::new(TaskKind::Gemm, vec![r], vec![r])
            })
            .collect();
        dag.partition(0, specs, 100);
        dag
    }

    /// A chain of `k` dependent tasks over one region.
    fn chain(k: usize) -> TaskDag {
        let r = reg(0, 100, 0, 100);
        let mut dag = TaskDag::new(TaskSpec::new(TaskKind::Potrf, vec![r], vec![r]));
        dag.partition(0, vec![TaskSpec::new(TaskKind::Gemm, vec![r], vec![r]); k], 100);
        dag
    }

    fn cfg(o: Ordering, s: ProcSelect) -> SimConfig {
        SimConfig::new(SchedConfig::new(o, s))
    }

    const GEMM100: f64 = 2.0 * 100.0 * 100.0 * 100.0; // flops of a 100-tile

    #[test]
    fn independent_tasks_run_in_parallel() {
        let (m, db) = single_space_machine(2, 0);
        let dag = independent(4);
        let s = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestIdle));
        // 4 tasks, 2 equal fast procs, each task 2e6/4e9 = 0.5ms
        let per = GEMM100 / 4e9;
        assert!((s.makespan - 2.0 * per).abs() < 1e-9, "makespan={}", s.makespan);
        assert!((s.avg_load() - 1.0).abs() < 1e-9);
        assert_eq!(s.transfer_bytes, 0, "single space: no transfers");
    }

    #[test]
    fn chain_serializes() {
        let (m, db) = single_space_machine(2, 0);
        let dag = chain(3);
        let s = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestFinish));
        let per = GEMM100 / 4e9;
        assert!((s.makespan - 3.0 * per).abs() < 1e-9);
        for w in s.assignments.windows(2) {
            assert!(w[1].start >= w[0].end - 1e-12);
        }
    }

    #[test]
    fn fastest_picks_fast_proc() {
        let (m, db) = single_space_machine(1, 1);
        let dag = chain(1);
        let s = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::Fastest));
        assert_eq!(m.procs[s.assignments[0].proc].ptype, 1, "fast proc chosen");
    }

    #[test]
    fn eft_beats_eit_when_types_differ() {
        // EIT picks proc 0 (slow, idle first by tie-break); EFT picks fast.
        let (m, db) = single_space_machine(1, 1);
        let dag = independent(2);
        let eit = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestIdle));
        let eft = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestFinish));
        assert!(eft.makespan <= eit.makespan + 1e-12);
        // EFT serializes both tasks on the fast proc (0.5ms each) instead
        // of putting one on the slow (2ms)
        assert!((eft.makespan - 2.0 * GEMM100 / 4e9).abs() < 1e-9, "{}", eft.makespan);
        assert!((eit.makespan - GEMM100 / 1e9).abs() < 1e-9, "{}", eit.makespan);
    }

    #[test]
    fn random_is_deterministic_per_seed() {
        let (m, db) = single_space_machine(2, 2);
        let dag = independent(8);
        let a = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::Random).with_seed(7));
        let b = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::Random).with_seed(7));
        assert_eq!(a.mapping(), b.mapping());
        let c = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::Random).with_seed(8));
        // almost surely a different mapping with 4 procs and 8 tasks
        assert_ne!(a.mapping(), c.mapping());
    }

    use crate::coordinator::faults::{FailStop, FaultPlan, FaultSpec, ThrottleWindow};

    fn faulted(dag: &TaskDag, m: &Machine, db: &PerfDb, c: SimConfig, spec: &FaultSpec) -> Schedule {
        let flat = dag.flat_dag();
        let mut p = policy::policy_for(SchedConfig::new(c.ordering, c.select));
        simulate_flat_faults(dag, &flat, m, db, c, p.as_mut(), &FaultPlan::new(spec, 0))
    }

    fn count_kind(s: &Schedule, pred: impl Fn(&EventKind) -> bool) -> usize {
        s.events.iter().filter(|e| pred(&e.kind)).count()
    }

    #[test]
    fn empty_fault_plan_is_byte_identical_to_fault_free() {
        let (m, db) = single_space_machine(2, 1);
        let dag = independent(6);
        for c in [cfg(Ordering::Fcfs, ProcSelect::EarliestIdle), cfg(Ordering::CriticalTime, ProcSelect::EarliestFinish)] {
            let base = simulate(&dag, &m, &db, c);
            let off = faulted(&dag, &m, &db, c, &FaultSpec::named("off"));
            assert_eq!(base.mapping(), off.mapping());
            assert_eq!(base.makespan.to_bits(), off.makespan.to_bits());
            assert_eq!(base.events, off.events);
            for (a, b) in base.assignments.iter().zip(off.assignments.iter()) {
                assert_eq!(a.start.to_bits(), b.start.to_bits());
                assert_eq!(a.end.to_bits(), b.end.to_bits());
            }
        }
    }

    #[test]
    fn fail_stop_cancels_and_redispatches_booked_work() {
        let (m, db) = single_space_machine(2, 0);
        let dag = independent(4);
        let per = GEMM100 / 4e9;
        let mut spec = FaultSpec::named("kill1");
        spec.fail_stop.push(FailStop { proc: 1, at: per / 2.0, restore: None });
        let s = faulted(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestIdle), &spec);
        // proc 1 had one task in flight and one booked behind it — both
        // are killed, re-enter the ready queue, and land on proc 0
        assert_eq!(count_kind(&s, |k| matches!(k, EventKind::ProcFail { .. })), 1);
        assert_eq!(count_kind(&s, |k| matches!(k, EventKind::TaskFault { .. })), 2);
        assert!(s.assignments.iter().all(|a| a.proc == 0), "dead processor must be routed around");
        assert!((s.makespan - 4.0 * per).abs() < 1e-12, "makespan={}", s.makespan);
        // only the executed prefix of the in-flight attempt is billed to
        // the dead processor
        assert!((s.proc_busy[1] - per / 2.0).abs() < 1e-15, "proc_busy[1]={}", s.proc_busy[1]);
        assert_eq!(count_kind(&s, |k| matches!(k, EventKind::TaskEnd { .. })), 4);
    }

    #[test]
    fn restored_processor_takes_work_again() {
        let (m, db) = single_space_machine(2, 0);
        let dag = independent(2);
        let per = GEMM100 / 4e9;
        let mut spec = FaultSpec::named("blip");
        spec.fail_stop.push(FailStop { proc: 1, at: 0.2 * per, restore: Some(0.6 * per) });
        let s = faulted(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestIdle), &spec);
        assert_eq!(count_kind(&s, |k| matches!(k, EventKind::ProcRestore { .. })), 1);
        // the killed task goes back to proc 1 *after* its dead window
        // (earliest idle: restore at 0.6*per beats proc 0's tail at per)
        let retried = s.assignments.iter().find(|a| a.proc == 1).expect("proc 1 reused after restore");
        assert!((retried.start - 0.6 * per).abs() < 1e-15, "start={}", retried.start);
        assert!((s.makespan - 1.6 * per).abs() < 1e-12, "makespan={}", s.makespan);
    }

    #[test]
    fn transient_faults_retry_until_the_attempt_budget_exhausts() {
        let (m, db) = single_space_machine(1, 0);
        let dag = chain(1);
        let per = GEMM100 / 4e9;
        let mut spec = FaultSpec::named("always");
        spec.transient_rate = 1.0;
        spec.max_attempts = 3;
        let s = faulted(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestIdle), &spec);
        // every attempt is doomed: 3 starts, 3 faults, no end, no finish
        assert_eq!(count_kind(&s, |k| matches!(k, EventKind::TaskStart { .. })), 3);
        assert_eq!(count_kind(&s, |k| matches!(k, EventKind::TaskFault { .. })), 3);
        assert_eq!(count_kind(&s, |k| matches!(k, EventKind::TaskEnd { .. })), 0);
        assert!(s.makespan.is_infinite(), "exhausted budget must poison the makespan");
        // all three attempts executed (and were billed) before being lost
        assert!((s.proc_busy[0] - 3.0 * per).abs() < 1e-12);
    }

    #[test]
    fn moderate_transient_rate_recovers_to_a_finite_schedule() {
        let (m, db) = single_space_machine(2, 0);
        let dag = independent(8);
        let base = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestIdle));
        let mut spec = FaultSpec::named("flaky");
        spec.transient_rate = 0.2;
        spec.max_attempts = 8;
        spec.seed = 11;
        let s = faulted(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestIdle), &spec);
        assert!(s.makespan.is_finite());
        assert!(s.makespan >= base.makespan - 1e-12, "retries cannot speed a schedule up");
        assert_eq!(count_kind(&s, |k| matches!(k, EventKind::TaskEnd { .. })), 8, "every task completes once");
    }

    #[test]
    fn throttle_window_stretches_execution() {
        let (m, db) = single_space_machine(1, 0);
        let dag = chain(1);
        let per = GEMM100 / 4e9;
        let mut spec = FaultSpec::named("hot");
        spec.throttle.push(ThrottleWindow { proc: 0, from: 0.0, to: 1.0, factor: 0.5 });
        let s = faulted(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestIdle), &spec);
        assert!((s.makespan - 2.0 * per).abs() < 1e-12, "half speed doubles the duration");
    }

    #[test]
    fn fault_runs_replay_bit_identically() {
        let (m, db) = single_space_machine(2, 1);
        let dag = independent(6);
        let mut spec = FaultSpec::named("mix");
        spec.transient_rate = 0.3;
        spec.max_attempts = 6;
        spec.fail_stop.push(FailStop { proc: 0, at: 2e-4, restore: Some(9e-4) });
        let c = cfg(Ordering::CriticalTime, ProcSelect::EarliestFinish);
        let a = faulted(&dag, &m, &db, c, &spec);
        let b = faulted(&dag, &m, &db, c, &spec);
        assert_eq!(a.events, b.events);
        assert_eq!(a.mapping(), b.mapping());
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        // a different ensemble member draws a different transient pattern
        let flat = dag.flat_dag();
        let mut p = policy::policy_for(SchedConfig::new(c.ordering, c.select));
        let other = simulate_flat_faults(&dag, &flat, &m, &db, c, p.as_mut(), &FaultPlan::new(&spec, 1));
        assert_ne!(a.events, other.events, "members must differ");
    }

    #[test]
    fn transfers_charged_for_remote_reads() {
        let (m, db) = gpu_machine();
        let dag = chain(1);
        let s = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::Fastest));
        // fastest proc is the GPU; input block (100x100 f32) must move
        assert_eq!(m.procs[s.assignments[0].proc].ptype, 1);
        assert_eq!(s.transfer_bytes, 100 * 100 * 4);
        assert!(!s.transfers.is_empty());
        let tr = s.transfers[0];
        let expected = 1e-5 + (100.0 * 100.0 * 4.0) / 1e9;
        assert!((tr.end - tr.start - expected).abs() < 1e-12);
        assert!(s.assignments[0].start >= tr.end - 1e-12, "task waits for data");
    }

    #[test]
    fn cached_data_is_not_refetched() {
        let (m, db) = gpu_machine();
        let dag = chain(3); // same region read+written 3x
        let s = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::Fastest));
        // all 3 run on GPU; only the first fetches
        assert_eq!(s.transfer_bytes, 100 * 100 * 4);
    }

    #[test]
    fn write_through_generates_backflow_traffic() {
        let (m, db) = gpu_machine();
        let dag = chain(2);
        let base = cfg(Ordering::Fcfs, ProcSelect::Fastest);
        let wb = simulate(&dag, &m, &db, base.with_cache(CachePolicy::WriteBack));
        let wt = simulate(&dag, &m, &db, base.with_cache(CachePolicy::WriteThrough));
        // WT pushes each of the two writes back to main
        assert_eq!(wt.transfer_bytes, wb.transfer_bytes + 2 * 100 * 100 * 4);
    }

    #[test]
    fn write_around_refetches_every_round() {
        let (m, db) = gpu_machine();
        let dag = chain(2);
        let base = cfg(Ordering::Fcfs, ProcSelect::Fastest);
        let wa = simulate(&dag, &m, &db, base.with_cache(CachePolicy::WriteAround));
        // WA: fetch, write lands in main (1 push), second task re-fetches,
        // pushes again: 4 block moves total
        assert_eq!(wa.transfer_bytes, 4 * 100 * 100 * 4);
    }

    #[test]
    fn replay_forces_mapping() {
        let (m, db) = single_space_machine(1, 1);
        let dag = independent(4);
        let mapping = vec![0, 0, 1, 1];
        let s = simulate_mapped(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestFinish), &mapping);
        assert_eq!(s.mapping(), mapping);
    }

    #[test]
    fn pl_prioritizes_critical_chain() {
        // one long chain + independent fillers: PL must start the chain
        // head first even though fillers were released equally at t=0.
        let root = reg(0, 400, 0, 400);
        let mut dag = TaskDag::new(TaskSpec::new(TaskKind::Potrf, vec![root], vec![root]));
        let c = reg(0, 100, 0, 100);
        let mut specs = vec![];
        // fillers first in program order
        for i in 1..4 {
            let r = reg(100 * i, 100 * (i + 1), 0, 100);
            specs.push(TaskSpec::new(TaskKind::Gemm, vec![r], vec![r]));
        }
        for _ in 0..3 {
            specs.push(TaskSpec::new(TaskKind::Gemm, vec![c], vec![c]));
        }
        dag.partition(0, specs, 100);
        let (m, db) = single_space_machine(1, 0);
        let s = simulate(&dag, &m, &db, cfg(Ordering::PriorityList, ProcSelect::EarliestIdle));
        // chain head (pos 3) must be scheduled before the fillers
        let chain_start = s.assignments[3].start;
        for pos in 0..3 {
            assert!(s.assignments[pos].start >= chain_start - 1e-12, "filler {pos} before chain head");
        }
    }

    #[test]
    fn active_at_counts_running_tasks() {
        let (m, db) = single_space_machine(2, 0);
        let dag = independent(2);
        let s = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestIdle));
        let mid = s.makespan / 2.0;
        assert_eq!(s.active_at(mid), 2);
        assert_eq!(s.active_at(s.makespan + 1.0), 0);
    }

    #[test]
    fn makespan_covers_trailing_writeback() {
        let (m, db) = gpu_machine();
        let dag = chain(1);
        let s = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::Fastest).with_cache(CachePolicy::WriteThrough));
        let last_transfer = s.transfers.iter().map(|t| t.end).fold(0.0f64, f64::max);
        assert!(s.makespan >= last_transfer - 1e-12);
    }

    // ---- event-core-specific behavior ----

    /// host(1 cpu, 2 GFLOPS) + two GPU spaces (1 proc each, 4 GFLOPS),
    /// zero-latency 40 MB/s links — transfer of a 100x100 f32 tile takes
    /// exactly 1 ms per hop, a 50x50 tile 0.25 ms.
    fn three_space_machine() -> (Machine, PerfDb) {
        let mut b = MachineBuilder::new("t");
        let h = b.space("host", u64::MAX);
        let g0 = b.space("g0", u64::MAX);
        let g1 = b.space("g1", u64::MAX);
        b.main(h);
        b.connect(h, g0, 0.0, 4e7);
        b.connect(h, g1, 0.0, 4e7);
        let cpu = b.proc_type("cpu", 1.0, 0.1);
        let gpu = b.proc_type("gpu", 1.0, 0.1);
        b.processors(1, "c", cpu, h);
        b.processors(1, "a", gpu, g0);
        b.processors(1, "b", gpu, g1);
        let m = b.build();
        let mut db = PerfDb::new();
        db.set_fallback(0, PerfCurve::Const { gflops: 2.0 });
        db.set_fallback(1, PerfCurve::Const { gflops: 4.0 });
        (m, db)
    }

    #[test]
    fn link_contention_serializes_transfers_in_time_order() {
        // Two independent tasks forced onto the same GPU, each fetching
        // its own 100x100 tile over the single host->g0 link: the second
        // transfer queues behind the first with exactly 1 ms of delay.
        let (m, db) = three_space_machine();
        let a = reg(0, 100, 0, 100);
        let a2 = reg(100, 200, 0, 100);
        let bb = reg(200, 300, 0, 100);
        let b2 = reg(300, 400, 0, 100);
        let root = reg(0, 400, 0, 100);
        let mut dag = TaskDag::new(TaskSpec::new(TaskKind::Potrf, vec![root], vec![root]));
        dag.partition(
            0,
            vec![
                TaskSpec::new(TaskKind::Gemm, vec![a], vec![a2]),
                TaskSpec::new(TaskKind::Gemm, vec![bb], vec![b2]),
            ],
            100,
        );
        let s = simulate_mapped(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestIdle), &[1, 1]);
        let hop = 100.0 * 100.0 * 4.0 / 4e7; // 1 ms
        let exec = GEMM100 / 4e9; // 0.5 ms
        assert_eq!(s.transfers.len(), 2);
        let (t0, t1) = (s.transfers[0], s.transfers[1]);
        assert!((t0.start - 0.0).abs() < 1e-12 && (t0.end - hop).abs() < 1e-12);
        assert!((t1.start - hop).abs() < 1e-12, "second transfer queues at {}, want {hop}", t1.start);
        assert!((t1.end - 2.0 * hop).abs() < 1e-12, "queuing delay must be exactly one hop");
        // each task starts when ITS data is in, not at ready-pop order time
        assert!((s.assignments[0].start - hop).abs() < 1e-12);
        assert!((s.assignments[1].start - 2.0 * hop).abs() < 1e-12);
        assert!((s.makespan - (2.0 * hop + exec)).abs() < 1e-12);
    }

    #[test]
    fn transfers_backfill_idle_link_gaps() {
        // A two-hop g0->host->g1 transfer decided at t=0.5ms books the
        // host->g1 link for [1.5ms, 2.5ms). A later decision (t=1.0ms)
        // moving a small 50x50 tile host->g1 must slot into the idle
        // [1.0ms, 1.5ms) window — the old high-water-mark accounting
        // would queue it at 2.5ms and idle the link for 1.5ms.
        let (m, db) = three_space_machine();
        let r0 = reg(0, 100, 0, 100);
        let r1o = reg(100, 200, 0, 100);
        let rf = reg(200, 300, 0, 100);
        let rf_sub = reg(200, 250, 0, 50);
        let r2o = reg(300, 350, 0, 50);
        let root = reg(0, 350, 0, 100);
        let mut dag = TaskDag::new(TaskSpec::new(TaskKind::Potrf, vec![root], vec![root]));
        dag.partition(
            0,
            vec![
                // producer on g0: writes r0 there (0.5 ms exec)
                TaskSpec::new(TaskKind::Gemm, vec![], vec![r0]),
                // consumer on g1: two-hop fetch of r0 after the producer
                TaskSpec::new(TaskKind::Gemm, vec![r0], vec![r1o]),
                // filler on the host cpu: writes rf in main (1.0 ms exec)
                TaskSpec::new(TaskKind::Gemm, vec![], vec![rf]),
                // late consumer on g1: fetches the 50x50 sub-tile of rf
                TaskSpec::new(TaskKind::Gemm, vec![rf_sub], vec![r2o]),
            ],
            100,
        );
        let s = simulate_mapped(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestIdle), &[1, 2, 0, 2]);
        let ms = 1e-3;
        // producer [0, 0.5ms); two-hop transfer books g0->h [0.5, 1.5),
        // h->g1 [1.5, 2.5); consumer runs [2.5, 3.0)
        let big = s.transfers.iter().find(|t| t.bytes == 40_000).expect("two-hop transfer");
        assert!((big.start - 0.5 * ms).abs() < 1e-12 && (big.end - 2.5 * ms).abs() < 1e-12);
        assert!((s.assignments[1].start - 2.5 * ms).abs() < 1e-12);
        assert!((s.assignments[1].end - 3.0 * ms).abs() < 1e-12);
        // the 50x50 fetch (decided at 1.0ms) backfills h->g1's idle
        // [1.0, 1.5) window: 10 KB over 40 MB/s = 0.25 ms
        let small = s.transfers.iter().find(|t| t.bytes == 10_000).expect("small transfer");
        assert!(
            (small.start - 1.0 * ms).abs() < 1e-12 && (small.end - 1.25 * ms).abs() < 1e-12,
            "small transfer [{}, {}] did not backfill the gap",
            small.start,
            small.end
        );
        // and its task slots into g1's idle window before the consumer
        assert!((s.assignments[3].start - 1.25 * ms).abs() < 1e-12);
        assert!((s.assignments[3].end - (1.25 * ms + 2.0 * 50f64.powi(3) / 4e9)).abs() < 1e-12);
        assert!((s.makespan - 3.0 * ms).abs() < 1e-12);
    }

    #[test]
    fn same_space_reads_are_noops_not_transfers() {
        // A task running in main memory reading main-resident data must
        // produce zero transfers and zero transfer events (same-space
        // movement is an explicit no-op, never a free "transfer").
        let (m, db) = gpu_machine();
        let dag = chain(2);
        let s = simulate_mapped(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestIdle), &[0, 0]);
        assert_eq!(s.transfer_bytes, 0);
        assert!(s.transfers.is_empty());
        assert!(!s
            .events
            .iter()
            .any(|e| matches!(e.kind, EventKind::TransferStart { .. } | EventKind::TransferEnd { .. })));
        // every transfer record the engine ever emits has finite times
        let (m, db) = three_space_machine();
        let s = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestFinish));
        assert!(s.transfers.iter().all(|t| t.start.is_finite() && t.end.is_finite()));
    }

    /// An EFT-*ordering* policy (highest priority = earliest finish) that
    /// records what it observes at key-computation time. Under push-time
    /// keying it would only ever see empty processors (all tasks are
    /// released at t=0); decision-time recomputation shows it the
    /// bookings of earlier picks.
    struct EftOrdering {
        order_calls: usize,
        max_tail_seen: f64,
    }

    impl SchedPolicy for EftOrdering {
        fn name(&self) -> &str {
            "test/eft-ordering"
        }

        fn order(&mut self, ctx: &mut SchedContext<'_>, task: &Task, release: f64, _ct: f64) -> f64 {
            self.order_calls += 1;
            self.max_tail_seen = self.max_tail_seen.max(ctx.proc_avail(0));
            let (fin, _) = ctx.earliest_finish(task, release);
            -fin
        }

        fn select(&mut self, ctx: &mut SchedContext<'_>, task: &Task, release: f64) -> ProcId {
            ctx.earliest_finish(task, release).1
        }
    }

    #[test]
    fn ready_keys_are_recomputed_at_decision_time() {
        // 3 equal independent tasks, 1 processor (1 GFLOPS → 2 ms each).
        // The old engine computed each key once, at push time, when
        // proc_avail[0] was still 0 for all three; the event core re-keys
        // the remaining ready set after every pick, so the policy observes
        // the growing booking tail (2 ms, then 4 ms).
        let mut b = MachineBuilder::new("m");
        let h = b.space("host", u64::MAX);
        b.main(h);
        let t = b.proc_type("cpu", 1.0, 0.1);
        b.processors(1, "c", t, h);
        let m = b.build();
        let mut db = PerfDb::new();
        db.set_fallback(0, PerfCurve::Const { gflops: 1.0 });
        let dag = independent(3);
        let mut pol = EftOrdering { order_calls: 0, max_tail_seen: 0.0 };
        let s = simulate_policy(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestIdle), &mut pol);
        let per = GEMM100 / 1e9; // 2 ms
        // re-keying: 3 + 2 + 1 calls, not one per task
        assert_eq!(pol.order_calls, 6, "keys must be recomputed for the remaining ready set");
        // at the last pick the policy saw 4 ms of booked work on proc 0
        assert!(
            (pol.max_tail_seen - 2.0 * per).abs() < 1e-12,
            "decision-time proc_avail observed {} (stale push-time state would be 0)",
            pol.max_tail_seen
        );
        assert!((s.makespan - 3.0 * per).abs() < 1e-12);
    }

    #[test]
    fn scratch_reuse_never_leaks_between_runs() {
        // Run A dirties this thread's scratch arena; run B here must be
        // byte-identical to the same run on a fresh thread (empty pool).
        // Any stale booking or array content surviving reuse would shift
        // something observable.
        fn go() -> Schedule {
            let (m, db) = three_space_machine();
            let dag = independent(6);
            simulate(&dag, &m, &db, cfg(Ordering::PriorityList, ProcSelect::EarliestFinish))
        }
        {
            let (m, db) = gpu_machine();
            let dag = chain(4);
            simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::Fastest));
        }
        let warm = go();
        let fresh = std::thread::spawn(go).join().expect("sim thread");
        assert_eq!(warm.mapping(), fresh.mapping());
        assert_eq!(warm.makespan.to_bits(), fresh.makespan.to_bits());
        assert_eq!(warm.transfer_bytes, fresh.transfer_bytes);
        assert_eq!(warm.events.len(), fresh.events.len());
    }

    #[test]
    fn traced_run_matches_untraced() {
        let (m, db) = three_space_machine();
        let dag = independent(6);
        let flat = dag.flat_dag();
        let c = cfg(Ordering::PriorityList, ProcSelect::EarliestFinish);
        let plain = simulate_flat(&dag, &flat, &m, &db, c);
        let mut pol = policy::policy_for(SchedConfig::new(c.ordering, c.select));
        let (traced, tr) = simulate_flat_traced(&dag, &flat, &m, &db, c, pol.as_mut(), 2);
        assert_eq!(plain.mapping(), traced.mapping());
        assert_eq!(plain.makespan.to_bits(), traced.makespan.to_bits());
        assert_eq!(tr.decisions.len(), flat.len(), "one decision per task");
        assert!(!tr.checkpoints.is_empty(), "every=2 over 6 tasks must checkpoint");
        for w in tr.decisions.windows(2) {
            assert!(w[1].time >= w[0].time, "decision log out of time order");
        }
        for ck in &tr.checkpoints {
            assert!(ck.n_decisions > 0 && ck.n_decisions <= flat.len());
        }
    }

    #[test]
    fn checkpoint_restore_replays_to_an_identical_schedule() {
        // Full-prefix replay from every checkpoint of a traced run must
        // reproduce the base schedule bit for bit — the foundation the
        // delta evaluator's equivalence argument rests on.
        let (m, db) = three_space_machine();
        let dag = independent(6);
        let flat = dag.flat_dag();
        let c = cfg(Ordering::PriorityList, ProcSelect::EarliestFinish);
        let mut pol = policy::policy_for(SchedConfig::new(c.ordering, c.select));
        let (base, tr) = simulate_flat_traced(&dag, &flat, &m, &db, c, pol.as_mut(), 2);
        assert!(!tr.checkpoints.is_empty());
        for ck in &tr.checkpoints {
            // rebuild the ready-set bookkeeping at the snapshot the way
            // the delta verifier's scan does (identity candidate here)
            let mut ended: FxHashMap<TaskId, f64> = FxHashMap::default();
            for e in &ck.sched.events {
                if let EventKind::TaskEnd { task, .. } = e.kind {
                    ended.insert(task, e.time);
                }
            }
            let dispatched: Vec<TaskId> = tr.decisions[..ck.n_decisions].iter().map(|d| d.task).collect();
            let n = flat.len();
            let mut indeg = vec![0usize; n];
            let mut release = vec![0.0f64; n];
            for i in 0..n {
                for &p in &flat.preds[i] {
                    match ended.get(&flat.tasks[p]) {
                        Some(&t) => release[i] = release[i].max(t),
                        None => indeg[i] += 1,
                    }
                }
            }
            let ready: Vec<usize> =
                (0..n).filter(|&i| indeg[i] == 0 && !dispatched.contains(&flat.tasks[i])).collect();
            let plan = ReplayPlan {
                ckpt: Some(ck.as_ref()),
                prio: critical_times(&dag, &flat, &m, &db),
                indeg,
                release,
                ready,
                forced: &tr.decisions[ck.n_decisions..],
            };
            let seed = SimTrace { decisions: tr.decisions[..ck.n_decisions].to_vec(), checkpoints: Vec::new() };
            let mut pol2 = policy::policy_for(SchedConfig::new(c.ordering, c.select));
            let (re, tr2) = simulate_flat_replay(&dag, &flat, &m, &db, c, pol2.as_mut(), plan, seed, 0);
            assert_eq!(re.mapping(), base.mapping());
            assert_eq!(re.makespan.to_bits(), base.makespan.to_bits());
            assert_eq!(re.transfer_bytes, base.transfer_bytes);
            assert_eq!(re.events.len(), base.events.len(), "replay from ckpt@{}", ck.n_decisions);
            for (a, b) in re.events.iter().zip(base.events.iter()) {
                assert_eq!(a.time.to_bits(), b.time.to_bits());
                assert_eq!(a.kind, b.kind);
            }
            for (a, b) in re.assignments.iter().zip(base.assignments.iter()) {
                assert_eq!(a.proc, b.proc);
                assert_eq!(a.start.to_bits(), b.start.to_bits());
                assert_eq!(a.end.to_bits(), b.end.to_bits());
            }
            assert_eq!(tr2.decisions.len(), tr.decisions.len());
        }
    }

    #[test]
    fn event_log_is_time_ordered_and_complete() {
        let (m, db) = gpu_machine();
        let dag = chain(3);
        let s = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::Fastest).with_cache(CachePolicy::WriteThrough));
        // monotone non-decreasing times
        for w in s.events.windows(2) {
            assert!(w[1].time >= w[0].time - 1e-15, "event log out of order");
        }
        let count = |f: fn(&EventKind) -> bool| s.events.iter().filter(|e| f(&e.kind)).count();
        assert_eq!(count(|k| matches!(k, EventKind::TaskStart { .. })), 3);
        assert_eq!(count(|k| matches!(k, EventKind::TaskEnd { .. })), 3);
        assert_eq!(count(|k| matches!(k, EventKind::TransferStart { .. })), s.transfers.len());
        assert_eq!(count(|k| matches!(k, EventKind::TransferEnd { .. })), s.transfers.len());
        assert!(count(|k| matches!(k, EventKind::ProcIdle { .. })) >= 1, "the GPU must go idle at the end");
        // every TaskStart/TaskEnd pair brackets the matching assignment
        for a in &s.assignments {
            assert!(s.events.iter().any(|e| e.kind == EventKind::TaskStart { task: a.task, proc: a.proc }
                && (e.time - a.start).abs() < 1e-15));
            assert!(s.events.iter().any(|e| e.kind == EventKind::TaskEnd { task: a.task, proc: a.proc }
                && (e.time - a.end).abs() < 1e-15));
        }
    }
}
