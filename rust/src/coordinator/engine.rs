//! The discrete-event schedule simulator.
//!
//! A list scheduler over the frontier DAG, driven by a **typed event
//! queue** and a global clock. Scheduling decisions happen in simulated
//! -time order: when the clock reaches a task's release (the `TaskEnd`
//! of its last predecessor), the ready set is dispatched by a
//! [`SchedPolicy`] with ordering keys **recomputed at decision time** —
//! a policy always sees current processor/link occupancy, never the
//! state at push time.
//!
//! Resources are modeled as [`Timeline`]s — bookable interval sets, not
//! scalar high-water marks. Data movement is simulated explicitly: reads
//! that miss in the processor's memory space issue fetch transfers over
//! the interconnect with per-link queuing resolved in simulated-time
//! order, and transfers may *backfill* idle link windows left open by
//! earlier bookings. Write effects (coherence updates per the WB/WT/WA
//! caching policy, plus their backflow traffic) are applied when the
//! `TaskEnd` event fires, not when the decision is taken.
//!
//! The same event core ([`EventCore`]) also powers schedule replay
//! ([`simulate_mapped`]) and the constructive online scheduler
//! ([`super::constructive`]), so all three paths share one clock and one
//! commit path.
//!
//! Entry points come in pairs: the legacy enum-configured ones
//! ([`simulate`], [`simulate_flat`], [`simulate_mapped`]) construct the
//! matching built-in policy from [`SimConfig`]'s shim fields, and the
//! `_policy` variants take any `&mut dyn SchedPolicy`.

use super::coherence::{CachePolicy, Coherence, SpaceId, Transfer};
use super::datadag::BlockId;
use super::ordering::critical_times;
use super::perfmodel::PerfDb;
use super::platform::{LinkId, Machine, ProcId, Timeline};
use super::policies::{Ordering, ProcSelect, SchedConfig};
use super::policy::{self, ArrivalTable, JobInfo, SchedContext, SchedPolicy};
use super::task::{Task, TaskId};
use super::taskdag::{FlatDag, TaskDag};
use crate::util::rng::Rng;

/// Simulation knobs beyond the platform itself.
#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    /// Legacy ordering shim — used only to construct the matching built-in
    /// policy when an enum-configured entry point is called. Prefer the
    /// `_policy` entry points with a [`SchedPolicy`] value.
    pub ordering: Ordering,
    /// Legacy selection shim (see `ordering`).
    pub select: ProcSelect,
    pub cache: CachePolicy,
    /// Bytes per matrix element (4 = f32, 8 = f64).
    pub elem_bytes: u64,
    pub seed: u64,
}

impl SimConfig {
    pub fn new(cfg: SchedConfig) -> SimConfig {
        SimConfig {
            ordering: cfg.ordering,
            select: cfg.select,
            cache: CachePolicy::WriteBack,
            elem_bytes: 4,
            seed: 0,
        }
    }

    pub fn with_cache(mut self, c: CachePolicy) -> Self {
        self.cache = c;
        self
    }

    pub fn with_elem_bytes(mut self, b: u64) -> Self {
        self.elem_bytes = b;
        self
    }

    pub fn with_seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// A simulated data transfer (for traces and transfer accounting).
#[derive(Debug, Clone, Copy)]
pub struct TransferRecord {
    pub from: SpaceId,
    pub to: SpaceId,
    pub bytes: u64,
    pub start: f64,
    pub end: f64,
    /// Task whose dispatch booked this transfer as an input fetch — its
    /// execution must not start before `end` (the arrival gate the
    /// [`super::validate`] oracle checks). `None` for background traffic
    /// (write-through pushes, write-back evictions, write-around streams),
    /// which occupies links but gates no task.
    pub dst_task: Option<TaskId>,
}

/// One task placement in the simulated schedule.
#[derive(Debug, Clone, Copy)]
pub struct Assignment {
    pub task: TaskId,
    /// Position in the frontier (program order).
    pub pos: usize,
    pub proc: ProcId,
    /// Time all predecessors were finished.
    pub release: f64,
    pub start: f64,
    pub end: f64,
}

/// A typed occurrence in simulated time — the currency of the event
/// queue, and (via [`Schedule::events`]) the time-ordered trace the
/// simulation emits.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    /// A transfer began occupying its first link.
    TransferStart { from: SpaceId, to: SpaceId, bytes: u64 },
    /// A transfer's payload arrived in the destination space.
    TransferEnd { from: SpaceId, to: SpaceId, bytes: u64 },
    /// A task began executing.
    TaskStart { task: TaskId, proc: ProcId },
    /// A task finished; its write effects apply at this instant.
    TaskEnd { task: TaskId, proc: ProcId },
    /// A processor ran out of booked work.
    ProcIdle { proc: ProcId },
}

/// An [`EventKind`] stamped with its simulated time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimEvent {
    pub time: f64,
    pub kind: EventKind,
}

/// The simulation result.
#[derive(Debug, Clone, Default)]
pub struct Schedule {
    /// Assignments indexed by frontier position.
    pub assignments: Vec<Assignment>,
    pub transfers: Vec<TransferRecord>,
    pub makespan: f64,
    /// Busy seconds per processor.
    pub proc_busy: Vec<f64>,
    /// Total bytes moved between memory spaces.
    pub transfer_bytes: u64,
    /// The full time-ordered event log the run emitted
    /// (`TaskStart`/`TaskEnd`/`TransferStart`/`TransferEnd`/`ProcIdle`).
    pub events: Vec<SimEvent>,
    /// Per-hop link bookings `(link, start, end)`, one entry per link a
    /// transfer occupied, in booking order. A [`TransferRecord`] spans its
    /// whole route (first-hop start to last-hop end, with possible idle
    /// gaps between hops); this list is the exact occupancy, which is what
    /// lets the [`super::validate`] oracle prove no two transfers ever
    /// overlap on one link without trusting [`Timeline`]'s own arithmetic.
    pub link_occupancy: Vec<(LinkId, f64, f64)>,
}

impl Schedule {
    /// Average processor load: mean over processors of busy/makespan
    /// (Table 1's "Avg. load" column).
    pub fn avg_load(&self) -> f64 {
        if self.makespan <= 0.0 || self.proc_busy.is_empty() {
            return 0.0;
        }
        self.proc_busy.iter().map(|b| b / self.makespan).sum::<f64>() / self.proc_busy.len() as f64
    }

    /// Processor -> task→proc mapping vector (for schedule replay).
    pub fn mapping(&self) -> Vec<ProcId> {
        self.assignments.iter().map(|a| a.proc).collect()
    }

    /// Number of processors busy at time `t` (Fig. 2b load traces).
    pub fn active_at(&self, t: f64) -> usize {
        self.assignments.iter().filter(|a| a.start <= t && t < a.end).count()
    }
}

/// Simulate scheduling `dag`'s frontier on `machine` under the built-in
/// policy named by `cfg`'s shim fields.
pub fn simulate(dag: &TaskDag, machine: &Machine, db: &PerfDb, cfg: SimConfig) -> Schedule {
    let mut p = policy::policy_for(SchedConfig::new(cfg.ordering, cfg.select));
    run(dag, machine, db, cfg, None, None, p.as_mut())
}

/// Simulate under an arbitrary scheduling policy.
pub fn simulate_policy(
    dag: &TaskDag,
    machine: &Machine,
    db: &PerfDb,
    cfg: SimConfig,
    policy: &mut dyn SchedPolicy,
) -> Schedule {
    run(dag, machine, db, cfg, None, None, policy)
}

/// Like [`simulate`], reusing an already-derived [`FlatDag`] (the solver
/// needs the same frontier for candidate collection; deriving it twice per
/// iteration was a measured hot spot — §Perf optimization 3).
pub fn simulate_flat(dag: &TaskDag, flat: &FlatDag, machine: &Machine, db: &PerfDb, cfg: SimConfig) -> Schedule {
    let mut p = policy::policy_for(SchedConfig::new(cfg.ordering, cfg.select));
    run(dag, machine, db, cfg, None, Some(flat), p.as_mut())
}

/// [`simulate_flat`] under an arbitrary scheduling policy.
pub fn simulate_flat_policy(
    dag: &TaskDag,
    flat: &FlatDag,
    machine: &Machine,
    db: &PerfDb,
    cfg: SimConfig,
    policy: &mut dyn SchedPolicy,
) -> Schedule {
    run(dag, machine, db, cfg, None, Some(flat), policy)
}

/// Replay a fixed task→processor mapping (positions in frontier order) —
/// the HESP-REPLICA mode used for framework validation (§3.1). The policy
/// still orders the ready queue; selection is forced by `mapping`.
pub fn simulate_mapped(dag: &TaskDag, machine: &Machine, db: &PerfDb, cfg: SimConfig, mapping: &[ProcId]) -> Schedule {
    let mut p = policy::policy_for(SchedConfig::new(cfg.ordering, cfg.select));
    run(dag, machine, db, cfg, Some(mapping), None, p.as_mut())
}

/// A queued event: `(time, seq)` orders the queue (seq = push order, so
/// simultaneous events pop FIFO and runs are deterministic). `key` is the
/// caller's task handle (frontier position offline, task id online),
/// meaningful only for `TaskEnd`.
#[derive(Debug, Clone, Copy)]
struct QEvent {
    time: f64,
    seq: u64,
    key: usize,
    kind: EventKind,
}

impl PartialEq for QEvent {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for QEvent {}
impl PartialOrd for QEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for QEvent {
    // reversed: BinaryHeap is a max-heap, we want the earliest event first
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other.time.total_cmp(&self.time).then(other.seq.cmp(&self.seq))
    }
}

/// The shared discrete-event core: global clock, typed event queue,
/// per-processor and per-link [`Timeline`]s, coherence state and the
/// schedule under construction. The offline engine, replay and the
/// constructive online scheduler are all loops over this one struct —
/// they differ only in graph bookkeeping (who becomes ready when).
pub(crate) struct EventCore<'a> {
    pub machine: &'a Machine,
    pub db: &'a PerfDb,
    /// The global clock: the time of the event batch being processed
    /// (and of every scheduling decision taken in the current round).
    pub now: f64,
    queue: std::collections::BinaryHeap<QEvent>,
    seq: u64,
    /// Per-processor booked execution windows.
    pub procs: Vec<Timeline>,
    /// Per-link booked transfer windows.
    pub links: Vec<Timeline>,
    pub coh: Coherence,
    pub rng: Rng,
    pub sched: Schedule,
    /// Physical arrival time of committed-but-in-flight blocks per
    /// destination space. Coherence validity flips at commit time (so a
    /// second reader of the same block does not double-fetch it), but a
    /// task reading a block another decision is still transferring must
    /// wait for the bytes, not the bookkeeping. Estimates see the same
    /// table through [`SchedContext::arrivals`].
    arrivals: ArrivalTable,
    /// `(went-idle-at, proc)` candidates from popped `TaskEnd` events.
    /// `ProcIdle` emission is deferred until after the decision round at
    /// that timestamp, so a processor immediately re-booked at the same
    /// instant does not log a spurious idle transition.
    idle_candidates: Vec<(f64, ProcId)>,
}

impl<'a> EventCore<'a> {
    pub fn new(machine: &'a Machine, db: &'a PerfDb, cfg: SimConfig) -> EventCore<'a> {
        EventCore {
            machine,
            db,
            now: 0.0,
            queue: std::collections::BinaryHeap::new(),
            seq: 0,
            procs: vec![Timeline::new(); machine.n_procs()],
            links: vec![Timeline::new(); machine.links.len()],
            coh: Coherence::new(machine.spaces.len(), machine.main_space, cfg.cache, machine.capacities(), cfg.elem_bytes),
            rng: Rng::new(cfg.seed),
            sched: Schedule { proc_busy: vec![0.0; machine.n_procs()], ..Default::default() },
            arrivals: ArrivalTable::default(),
            idle_candidates: Vec::new(),
        }
    }

    /// A decision-time view for policy dispatch. Constructed fresh per
    /// call; never stored.
    pub fn ctx<'s>(&'s mut self, successors: &'s [&'s Task]) -> SchedContext<'s> {
        self.ctx_job(successors, None)
    }

    /// [`EventCore::ctx`] with the owning job's identity attached — the
    /// service layer's multi-job loop exposes job id / deadline slack to
    /// job-aware policies this way. Single-DAG callers pass `None` and
    /// those policies degrade to their job-oblivious fallbacks.
    pub fn ctx_job<'s>(&'s mut self, successors: &'s [&'s Task], job: Option<JobInfo>) -> SchedContext<'s> {
        SchedContext {
            machine: self.machine,
            db: self.db,
            now: self.now,
            procs: &self.procs,
            links: &self.links,
            arrivals: &self.arrivals,
            coh: &mut self.coh,
            rng: &mut self.rng,
            successors,
            job,
        }
    }

    /// Time of the earliest pending event, if any — the service layer
    /// interleaves job arrivals with the event stream by comparing the
    /// next arrival against this before popping a batch.
    pub fn next_event_time(&self) -> Option<f64> {
        self.queue.peek().map(|e| e.time)
    }

    fn push_event(&mut self, time: f64, key: usize, kind: EventKind) {
        self.seq += 1;
        self.queue.push(QEvent { time, seq: self.seq, key, kind });
    }

    /// Book `bytes` along the route `from -> to`, each hop in the
    /// earliest fitting window at or after `at` (gap backfill). Returns
    /// `(start of first hop, end of last hop)`. Panics — via
    /// [`Machine::route`] — if the spaces are distinct but disconnected;
    /// callers must never pass `from == to`.
    fn book_route(&mut self, from: SpaceId, to: SpaceId, bytes: u64, at: f64) -> (f64, f64) {
        debug_assert_ne!(from, to, "same-space transfers are no-ops, not bookings");
        let route = self.machine.route(from, to);
        assert!(!route.is_empty(), "empty route between distinct spaces {from} and {to}");
        let mut t = at;
        let mut first = f64::INFINITY;
        for lid in route {
            let l = &self.machine.links[lid];
            let dur = l.latency + bytes as f64 / l.bandwidth;
            let s = self.links[lid].earliest_fit(t, dur);
            self.links[lid].book(s, dur);
            self.sched.link_occupancy.push((lid, s, s + dur));
            if first.is_infinite() {
                first = s;
            }
            t = s + dur;
        }
        (first, t)
    }

    fn record_transfer(
        &mut self,
        from: SpaceId,
        to: SpaceId,
        bytes: u64,
        start: f64,
        end: f64,
        dst_task: Option<TaskId>,
    ) {
        debug_assert!(start.is_finite() && end >= start, "malformed transfer record");
        self.sched.transfers.push(TransferRecord { from, to, bytes, start, end, dst_task });
        self.sched.transfer_bytes += bytes;
        self.push_event(start, usize::MAX, EventKind::TransferStart { from, to, bytes });
        self.push_event(end, usize::MAX, EventKind::TransferEnd { from, to, bytes });
    }

    fn note_arrival(&mut self, block: BlockId, space: SpaceId, at: f64) {
        let slot = self.arrivals.entry((block, space)).or_insert(at);
        *slot = slot.max(at);
    }

    /// Charge write-through/write-back/eviction traffic on the
    /// interconnect starting at `at` (it does not delay the issuing task,
    /// but occupies link windows and counts toward transfer volume).
    fn charge_background(&mut self, at: f64, transfers: &[Transfer]) {
        for tr in transfers {
            if tr.from == tr.to {
                continue; // same-space: explicit no-op
            }
            let (start, end) = self.book_route(tr.from, tr.to, tr.bytes, at);
            self.record_transfer(tr.from, tr.to, tr.bytes, start, end, None);
            self.note_arrival(tr.block, tr.to, end);
        }
    }

    /// Commit a dispatch decision taken at time `rel` (== `self.now`):
    /// book the task's input transfers (backfilling idle link windows),
    /// book execution in the earliest fitting window of `proc`, and push
    /// the `TransferStart`/`TransferEnd`/`TaskStart`/`TaskEnd` events.
    /// `key` is the caller's handle, returned with the `TaskEnd` event.
    /// Write effects are NOT applied here — they happen when `TaskEnd`
    /// fires (see [`EventCore::apply_writes`]). Returns `(start, end)`.
    pub fn commit(&mut self, task: &Task, key: usize, proc: ProcId, rel: f64) -> (f64, f64) {
        let space = self.machine.procs[proc].space;
        let (_, planned) =
            policy::plan_reads(self.machine, &self.links, &mut self.coh, &self.arrivals, task, space, rel);
        let mut data_ready = rel;
        let mut fetched_parents: Vec<BlockId> = Vec::new();
        for (parent, tr) in planned {
            if tr.from == tr.to {
                continue; // data already local: explicit no-op
            }
            let (start, end) = self.book_route(tr.from, tr.to, tr.bytes, rel);
            data_ready = data_ready.max(end);
            self.record_transfer(tr.from, tr.to, tr.bytes, start, end, Some(task.id));
            self.note_arrival(tr.block, tr.to, end);
            let evict = self.coh.complete_read(tr.block, tr.to);
            self.charge_background(end, &evict);
            if tr.block != parent && !fetched_parents.contains(&parent) {
                fetched_parents.push(parent);
            }
        }
        // a reassembled coarse block is fully present once all fragments land
        for parent in fetched_parents {
            let evict = self.coh.complete_read(parent, space);
            self.note_arrival(parent, space, data_ready);
            self.charge_background(data_ready, &evict);
        }
        // blocks already valid here but still physically in flight (fetched
        // by an earlier decision, arriving later) gate the start too — the
        // same gate the estimate path applies inside plan_reads
        data_ready = policy::arrival_gate(&mut self.coh, &self.arrivals, task, space, data_ready);
        let dur = self.db.time(self.machine.procs[proc].ptype, task.kind, task.char_edge(), task.flops);
        let start = self.procs[proc].earliest_fit(data_ready, dur);
        self.procs[proc].book(start, dur);
        let end = start + dur;
        self.sched.proc_busy[proc] += end - start;
        self.push_event(start, usize::MAX, EventKind::TaskStart { task: task.id, proc });
        self.push_event(end, key, EventKind::TaskEnd { task: task.id, proc });
        (start, end)
    }

    /// Apply `task`'s write effects at its `TaskEnd` time `end`:
    /// coherence invalidation/validation per the caching policy, plus
    /// any backflow traffic (write-through pushes, write-around streams,
    /// evictions) charged on the interconnect from `end`.
    pub fn apply_writes(&mut self, task: &Task, proc: ProcId, end: f64) {
        let space = self.machine.procs[proc].space;
        for w in task.writes.iter() {
            let block = self.coh.register(*w);
            let extra = self.coh.complete_write(block, space);
            self.charge_background(end, &extra);
        }
    }

    /// Advance the clock to the next pending event and drain every event
    /// at that timestamp into `batch` (in push order). A `TaskEnd` whose
    /// processor has no further booked work marks an idle *candidate*;
    /// the `ProcIdle` event is emitted on the next call — i.e. after the
    /// decision round at that timestamp — and only if the processor was
    /// not re-booked in the meantime, so a busy chain does not log
    /// spurious idle transitions. Returns `false` when the queue is
    /// empty (the simulation is over).
    pub fn pop_event_batch(&mut self, batch: &mut Vec<(usize, EventKind)>) -> bool {
        batch.clear();
        // flush idle candidates from the previous batch: still nothing
        // booked after their idle instant means the processor truly idled
        for (at, proc) in std::mem::take(&mut self.idle_candidates) {
            if !self.procs[proc].busy_after(at) {
                self.push_event(at, usize::MAX, EventKind::ProcIdle { proc });
            }
        }
        let Some(head) = self.queue.peek() else {
            return false;
        };
        let t = head.time;
        debug_assert!(t >= self.now, "event clock went backwards");
        self.now = t;
        while let Some(head) = self.queue.peek() {
            if head.time > t {
                break;
            }
            let ev = self.queue.pop().expect("peeked");
            self.sched.events.push(SimEvent { time: ev.time, kind: ev.kind });
            if let EventKind::TaskEnd { proc, .. } = ev.kind {
                if !self.procs[proc].busy_after(t) {
                    self.idle_candidates.push((t, proc));
                }
            }
            batch.push((ev.key, ev.kind));
        }
        true
    }

    /// Close out: compute the makespan (tasks and trailing transfers both
    /// count) and hand over the schedule.
    pub fn finish(mut self) -> Schedule {
        let task_end = self.sched.assignments.iter().map(|a| a.end).fold(0.0f64, f64::max);
        let xfer_end = self.sched.transfers.iter().map(|t| t.end).fold(0.0f64, f64::max);
        self.sched.makespan = task_end.max(xfer_end);
        self.sched
    }
}

/// The decision-time selection scan shared by the offline and online
/// loops: index of the entry (of `n`) with the largest key, ties broken
/// toward the smaller `ord_of` value (frontier position offline, task id
/// online — both track program order). `key_of` is consulted fresh for
/// every entry on every pick, which is what makes ordering keys
/// decision-time state for dynamic policies.
pub(crate) fn pick_best(
    n: usize,
    mut key_of: impl FnMut(usize) -> f64,
    ord_of: impl Fn(usize) -> usize,
) -> Option<usize> {
    let mut best: Option<(usize, f64, usize)> = None; // (index, key, ord)
    for i in 0..n {
        let key = key_of(i);
        let o = ord_of(i);
        let better = match best {
            None => true,
            Some((_, bk, bo)) => match key.total_cmp(&bk) {
                std::cmp::Ordering::Greater => true,
                std::cmp::Ordering::Equal => o < bo,
                std::cmp::Ordering::Less => false,
            },
        };
        if better {
            best = Some((i, key, o));
        }
    }
    best.map(|(i, _, _)| i)
}

/// Pick the ready task with the largest policy key (ties toward the
/// smaller frontier position, i.e. program order) and remove it.
/// Dynamic-order policies are re-keyed against live state on every pick;
/// static-key policies use the key cached when the task was released.
#[allow(clippy::too_many_arguments)]
fn pop_best(
    core: &mut EventCore<'_>,
    policy: &mut dyn SchedPolicy,
    dag: &TaskDag,
    flat: &FlatDag,
    ready: &[usize],
    release: &[f64],
    prio: &[f64],
    keys: &[f64],
) -> Option<usize> {
    let dynamic = policy.dynamic_order();
    pick_best(
        ready.len(),
        |i| {
            let pos = ready[i];
            if dynamic {
                let mut ctx = core.ctx(&[]);
                policy.order(&mut ctx, dag.task(flat.tasks[pos]), release[pos], prio[pos])
            } else {
                keys[pos]
            }
        },
        |i| ready[i],
    )
}

fn run(
    dag: &TaskDag,
    machine: &Machine,
    db: &PerfDb,
    cfg: SimConfig,
    forced: Option<&[ProcId]>,
    flat_in: Option<&FlatDag>,
    policy: &mut dyn SchedPolicy,
) -> Schedule {
    let flat_owned;
    let flat: &FlatDag = match flat_in {
        Some(f) => f,
        None => {
            flat_owned = dag.flat_dag();
            &flat_owned
        }
    };
    let n = flat.len();
    if let Some(m) = forced {
        assert_eq!(m.len(), n, "mapping length != frontier size");
    }

    // backflow critical times, computed only for policies that order by
    // them (the PL family); FCFS-like policies skip the O(V+E) pass
    let prio = if policy.wants_critical_times() {
        critical_times(dag, flat, machine, db)
    } else {
        vec![0.0; n]
    };

    let mut core = EventCore::new(machine, db, cfg);
    core.sched.assignments = vec![
        Assignment { task: 0, pos: 0, proc: 0, release: 0.0, start: 0.0, end: 0.0 };
        n
    ];

    let mut indeg: Vec<usize> = flat.preds.iter().map(|p| p.len()).collect();
    let mut release = vec![0.0f64; n];
    let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut batch: Vec<(usize, EventKind)> = Vec::new();
    // static-key policies are keyed once, when the task is released
    let static_keys = !policy.dynamic_order();
    let mut keys = vec![0.0f64; n];
    if static_keys {
        for &pos in &ready {
            let mut ctx = core.ctx(&[]);
            keys[pos] = policy.order(&mut ctx, dag.task(flat.tasks[pos]), release[pos], prio[pos]);
        }
    }

    loop {
        // ---- decision round: dispatch everything ready at `core.now`,
        // recomputing dynamic ordering keys between picks ----
        loop {
            let Some(i) = pop_best(&mut core, policy, dag, flat, &ready, &release, &prio, &keys) else {
                break;
            };
            let pos = ready.swap_remove(i);
            let rel = release[pos];
            let task = dag.task(flat.tasks[pos]);
            let proc: ProcId = if let Some(m) = forced {
                m[pos]
            } else {
                // successor tasks materialize only for lookahead-style
                // policies — dispatch is a hot path
                let succ_tasks: Vec<&Task> = if policy.wants_successors() {
                    flat.succs[pos].iter().map(|&s| dag.task(flat.tasks[s])).collect()
                } else {
                    Vec::new()
                };
                let mut ctx = core.ctx(&succ_tasks);
                policy.select(&mut ctx, task, rel)
            };
            let (start, end) = core.commit(task, pos, proc, rel);
            core.sched.assignments[pos] =
                Assignment { task: flat.tasks[pos], pos, proc, release: rel, start, end };
        }

        // ---- advance the clock to the next event batch ----
        if !core.pop_event_batch(&mut batch) {
            break;
        }
        for &(key, kind) in &batch {
            if let EventKind::TaskEnd { proc, .. } = kind {
                let pos = key;
                core.apply_writes(dag.task(flat.tasks[pos]), proc, core.now);
                for &s in &flat.succs[pos] {
                    indeg[s] -= 1;
                    release[s] = release[s].max(core.now);
                    if indeg[s] == 0 {
                        if static_keys {
                            let mut ctx = core.ctx(&[]);
                            keys[s] = policy.order(&mut ctx, dag.task(flat.tasks[s]), release[s], prio[s]);
                        }
                        ready.push(s);
                    }
                }
            }
        }
    }

    core.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::perfmodel::PerfCurve;
    use crate::coordinator::platform::MachineBuilder;
    use crate::coordinator::region::Region;
    use crate::coordinator::task::{TaskKind, TaskSpec};

    fn single_space_machine(n_fast: usize, n_slow: usize) -> (Machine, PerfDb) {
        let mut b = MachineBuilder::new("m");
        let h = b.space("host", u64::MAX);
        b.main(h);
        let slow = b.proc_type("slow", 1.0, 0.1);
        let fast = b.proc_type("fast", 1.0, 0.1);
        b.processors(n_slow, "s", slow, h);
        b.processors(n_fast, "f", fast, h);
        let m = b.build();
        let mut db = PerfDb::new();
        db.set_fallback(0, PerfCurve::Const { gflops: 1.0 });
        db.set_fallback(1, PerfCurve::Const { gflops: 4.0 });
        (m, db)
    }

    fn gpu_machine() -> (Machine, PerfDb) {
        let mut b = MachineBuilder::new("g");
        let h = b.space("host", u64::MAX);
        let g = b.space("gpu", u64::MAX);
        b.main(h);
        b.connect(h, g, 1e-5, 1e9);
        let cpu = b.proc_type("cpu", 1.0, 0.1);
        let gpu = b.proc_type("gpu", 1.0, 0.1);
        b.processors(1, "c", cpu, h);
        b.processors(1, "g", gpu, g);
        let m = b.build();
        let mut db = PerfDb::new();
        db.set_fallback(0, PerfCurve::Const { gflops: 1.0 });
        db.set_fallback(1, PerfCurve::Const { gflops: 10.0 });
        (m, db)
    }

    fn reg(r0: u32, r1: u32, c0: u32, c1: u32) -> Region {
        Region::new(0, r0, r1, c0, c1)
    }

    /// `k` independent gemm tasks over disjoint 100x100 tiles.
    fn independent(k: u32) -> TaskDag {
        let root = reg(0, 100 * k, 0, 100);
        let mut dag = TaskDag::new(TaskSpec::new(TaskKind::Potrf, vec![root], vec![root]));
        let specs: Vec<TaskSpec> = (0..k)
            .map(|i| {
                let r = reg(100 * i, 100 * (i + 1), 0, 100);
                TaskSpec::new(TaskKind::Gemm, vec![r], vec![r])
            })
            .collect();
        dag.partition(0, specs, 100);
        dag
    }

    /// A chain of `k` dependent tasks over one region.
    fn chain(k: usize) -> TaskDag {
        let r = reg(0, 100, 0, 100);
        let mut dag = TaskDag::new(TaskSpec::new(TaskKind::Potrf, vec![r], vec![r]));
        dag.partition(0, vec![TaskSpec::new(TaskKind::Gemm, vec![r], vec![r]); k], 100);
        dag
    }

    fn cfg(o: Ordering, s: ProcSelect) -> SimConfig {
        SimConfig::new(SchedConfig::new(o, s))
    }

    const GEMM100: f64 = 2.0 * 100.0 * 100.0 * 100.0; // flops of a 100-tile

    #[test]
    fn independent_tasks_run_in_parallel() {
        let (m, db) = single_space_machine(2, 0);
        let dag = independent(4);
        let s = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestIdle));
        // 4 tasks, 2 equal fast procs, each task 2e6/4e9 = 0.5ms
        let per = GEMM100 / 4e9;
        assert!((s.makespan - 2.0 * per).abs() < 1e-9, "makespan={}", s.makespan);
        assert!((s.avg_load() - 1.0).abs() < 1e-9);
        assert_eq!(s.transfer_bytes, 0, "single space: no transfers");
    }

    #[test]
    fn chain_serializes() {
        let (m, db) = single_space_machine(2, 0);
        let dag = chain(3);
        let s = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestFinish));
        let per = GEMM100 / 4e9;
        assert!((s.makespan - 3.0 * per).abs() < 1e-9);
        for w in s.assignments.windows(2) {
            assert!(w[1].start >= w[0].end - 1e-12);
        }
    }

    #[test]
    fn fastest_picks_fast_proc() {
        let (m, db) = single_space_machine(1, 1);
        let dag = chain(1);
        let s = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::Fastest));
        assert_eq!(m.procs[s.assignments[0].proc].ptype, 1, "fast proc chosen");
    }

    #[test]
    fn eft_beats_eit_when_types_differ() {
        // EIT picks proc 0 (slow, idle first by tie-break); EFT picks fast.
        let (m, db) = single_space_machine(1, 1);
        let dag = independent(2);
        let eit = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestIdle));
        let eft = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestFinish));
        assert!(eft.makespan <= eit.makespan + 1e-12);
        // EFT serializes both tasks on the fast proc (0.5ms each) instead
        // of putting one on the slow (2ms)
        assert!((eft.makespan - 2.0 * GEMM100 / 4e9).abs() < 1e-9, "{}", eft.makespan);
        assert!((eit.makespan - GEMM100 / 1e9).abs() < 1e-9, "{}", eit.makespan);
    }

    #[test]
    fn random_is_deterministic_per_seed() {
        let (m, db) = single_space_machine(2, 2);
        let dag = independent(8);
        let a = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::Random).with_seed(7));
        let b = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::Random).with_seed(7));
        assert_eq!(a.mapping(), b.mapping());
        let c = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::Random).with_seed(8));
        // almost surely a different mapping with 4 procs and 8 tasks
        assert_ne!(a.mapping(), c.mapping());
    }

    #[test]
    fn transfers_charged_for_remote_reads() {
        let (m, db) = gpu_machine();
        let dag = chain(1);
        let s = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::Fastest));
        // fastest proc is the GPU; input block (100x100 f32) must move
        assert_eq!(m.procs[s.assignments[0].proc].ptype, 1);
        assert_eq!(s.transfer_bytes, 100 * 100 * 4);
        assert!(!s.transfers.is_empty());
        let tr = s.transfers[0];
        let expected = 1e-5 + (100.0 * 100.0 * 4.0) / 1e9;
        assert!((tr.end - tr.start - expected).abs() < 1e-12);
        assert!(s.assignments[0].start >= tr.end - 1e-12, "task waits for data");
    }

    #[test]
    fn cached_data_is_not_refetched() {
        let (m, db) = gpu_machine();
        let dag = chain(3); // same region read+written 3x
        let s = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::Fastest));
        // all 3 run on GPU; only the first fetches
        assert_eq!(s.transfer_bytes, 100 * 100 * 4);
    }

    #[test]
    fn write_through_generates_backflow_traffic() {
        let (m, db) = gpu_machine();
        let dag = chain(2);
        let base = cfg(Ordering::Fcfs, ProcSelect::Fastest);
        let wb = simulate(&dag, &m, &db, base.with_cache(CachePolicy::WriteBack));
        let wt = simulate(&dag, &m, &db, base.with_cache(CachePolicy::WriteThrough));
        // WT pushes each of the two writes back to main
        assert_eq!(wt.transfer_bytes, wb.transfer_bytes + 2 * 100 * 100 * 4);
    }

    #[test]
    fn write_around_refetches_every_round() {
        let (m, db) = gpu_machine();
        let dag = chain(2);
        let base = cfg(Ordering::Fcfs, ProcSelect::Fastest);
        let wa = simulate(&dag, &m, &db, base.with_cache(CachePolicy::WriteAround));
        // WA: fetch, write lands in main (1 push), second task re-fetches,
        // pushes again: 4 block moves total
        assert_eq!(wa.transfer_bytes, 4 * 100 * 100 * 4);
    }

    #[test]
    fn replay_forces_mapping() {
        let (m, db) = single_space_machine(1, 1);
        let dag = independent(4);
        let mapping = vec![0, 0, 1, 1];
        let s = simulate_mapped(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestFinish), &mapping);
        assert_eq!(s.mapping(), mapping);
    }

    #[test]
    fn pl_prioritizes_critical_chain() {
        // one long chain + independent fillers: PL must start the chain
        // head first even though fillers were released equally at t=0.
        let root = reg(0, 400, 0, 400);
        let mut dag = TaskDag::new(TaskSpec::new(TaskKind::Potrf, vec![root], vec![root]));
        let c = reg(0, 100, 0, 100);
        let mut specs = vec![];
        // fillers first in program order
        for i in 1..4 {
            let r = reg(100 * i, 100 * (i + 1), 0, 100);
            specs.push(TaskSpec::new(TaskKind::Gemm, vec![r], vec![r]));
        }
        for _ in 0..3 {
            specs.push(TaskSpec::new(TaskKind::Gemm, vec![c], vec![c]));
        }
        dag.partition(0, specs, 100);
        let (m, db) = single_space_machine(1, 0);
        let s = simulate(&dag, &m, &db, cfg(Ordering::PriorityList, ProcSelect::EarliestIdle));
        // chain head (pos 3) must be scheduled before the fillers
        let chain_start = s.assignments[3].start;
        for pos in 0..3 {
            assert!(s.assignments[pos].start >= chain_start - 1e-12, "filler {pos} before chain head");
        }
    }

    #[test]
    fn active_at_counts_running_tasks() {
        let (m, db) = single_space_machine(2, 0);
        let dag = independent(2);
        let s = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestIdle));
        let mid = s.makespan / 2.0;
        assert_eq!(s.active_at(mid), 2);
        assert_eq!(s.active_at(s.makespan + 1.0), 0);
    }

    #[test]
    fn makespan_covers_trailing_writeback() {
        let (m, db) = gpu_machine();
        let dag = chain(1);
        let s = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::Fastest).with_cache(CachePolicy::WriteThrough));
        let last_transfer = s.transfers.iter().map(|t| t.end).fold(0.0f64, f64::max);
        assert!(s.makespan >= last_transfer - 1e-12);
    }

    // ---- event-core-specific behavior ----

    /// host(1 cpu, 2 GFLOPS) + two GPU spaces (1 proc each, 4 GFLOPS),
    /// zero-latency 40 MB/s links — transfer of a 100x100 f32 tile takes
    /// exactly 1 ms per hop, a 50x50 tile 0.25 ms.
    fn three_space_machine() -> (Machine, PerfDb) {
        let mut b = MachineBuilder::new("t");
        let h = b.space("host", u64::MAX);
        let g0 = b.space("g0", u64::MAX);
        let g1 = b.space("g1", u64::MAX);
        b.main(h);
        b.connect(h, g0, 0.0, 4e7);
        b.connect(h, g1, 0.0, 4e7);
        let cpu = b.proc_type("cpu", 1.0, 0.1);
        let gpu = b.proc_type("gpu", 1.0, 0.1);
        b.processors(1, "c", cpu, h);
        b.processors(1, "a", gpu, g0);
        b.processors(1, "b", gpu, g1);
        let m = b.build();
        let mut db = PerfDb::new();
        db.set_fallback(0, PerfCurve::Const { gflops: 2.0 });
        db.set_fallback(1, PerfCurve::Const { gflops: 4.0 });
        (m, db)
    }

    #[test]
    fn link_contention_serializes_transfers_in_time_order() {
        // Two independent tasks forced onto the same GPU, each fetching
        // its own 100x100 tile over the single host->g0 link: the second
        // transfer queues behind the first with exactly 1 ms of delay.
        let (m, db) = three_space_machine();
        let a = reg(0, 100, 0, 100);
        let a2 = reg(100, 200, 0, 100);
        let bb = reg(200, 300, 0, 100);
        let b2 = reg(300, 400, 0, 100);
        let root = reg(0, 400, 0, 100);
        let mut dag = TaskDag::new(TaskSpec::new(TaskKind::Potrf, vec![root], vec![root]));
        dag.partition(
            0,
            vec![
                TaskSpec::new(TaskKind::Gemm, vec![a], vec![a2]),
                TaskSpec::new(TaskKind::Gemm, vec![bb], vec![b2]),
            ],
            100,
        );
        let s = simulate_mapped(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestIdle), &[1, 1]);
        let hop = 100.0 * 100.0 * 4.0 / 4e7; // 1 ms
        let exec = GEMM100 / 4e9; // 0.5 ms
        assert_eq!(s.transfers.len(), 2);
        let (t0, t1) = (s.transfers[0], s.transfers[1]);
        assert!((t0.start - 0.0).abs() < 1e-12 && (t0.end - hop).abs() < 1e-12);
        assert!((t1.start - hop).abs() < 1e-12, "second transfer queues at {}, want {hop}", t1.start);
        assert!((t1.end - 2.0 * hop).abs() < 1e-12, "queuing delay must be exactly one hop");
        // each task starts when ITS data is in, not at ready-pop order time
        assert!((s.assignments[0].start - hop).abs() < 1e-12);
        assert!((s.assignments[1].start - 2.0 * hop).abs() < 1e-12);
        assert!((s.makespan - (2.0 * hop + exec)).abs() < 1e-12);
    }

    #[test]
    fn transfers_backfill_idle_link_gaps() {
        // A two-hop g0->host->g1 transfer decided at t=0.5ms books the
        // host->g1 link for [1.5ms, 2.5ms). A later decision (t=1.0ms)
        // moving a small 50x50 tile host->g1 must slot into the idle
        // [1.0ms, 1.5ms) window — the old high-water-mark accounting
        // would queue it at 2.5ms and idle the link for 1.5ms.
        let (m, db) = three_space_machine();
        let r0 = reg(0, 100, 0, 100);
        let r1o = reg(100, 200, 0, 100);
        let rf = reg(200, 300, 0, 100);
        let rf_sub = reg(200, 250, 0, 50);
        let r2o = reg(300, 350, 0, 50);
        let root = reg(0, 350, 0, 100);
        let mut dag = TaskDag::new(TaskSpec::new(TaskKind::Potrf, vec![root], vec![root]));
        dag.partition(
            0,
            vec![
                // producer on g0: writes r0 there (0.5 ms exec)
                TaskSpec::new(TaskKind::Gemm, vec![], vec![r0]),
                // consumer on g1: two-hop fetch of r0 after the producer
                TaskSpec::new(TaskKind::Gemm, vec![r0], vec![r1o]),
                // filler on the host cpu: writes rf in main (1.0 ms exec)
                TaskSpec::new(TaskKind::Gemm, vec![], vec![rf]),
                // late consumer on g1: fetches the 50x50 sub-tile of rf
                TaskSpec::new(TaskKind::Gemm, vec![rf_sub], vec![r2o]),
            ],
            100,
        );
        let s = simulate_mapped(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestIdle), &[1, 2, 0, 2]);
        let ms = 1e-3;
        // producer [0, 0.5ms); two-hop transfer books g0->h [0.5, 1.5),
        // h->g1 [1.5, 2.5); consumer runs [2.5, 3.0)
        let big = s.transfers.iter().find(|t| t.bytes == 40_000).expect("two-hop transfer");
        assert!((big.start - 0.5 * ms).abs() < 1e-12 && (big.end - 2.5 * ms).abs() < 1e-12);
        assert!((s.assignments[1].start - 2.5 * ms).abs() < 1e-12);
        assert!((s.assignments[1].end - 3.0 * ms).abs() < 1e-12);
        // the 50x50 fetch (decided at 1.0ms) backfills h->g1's idle
        // [1.0, 1.5) window: 10 KB over 40 MB/s = 0.25 ms
        let small = s.transfers.iter().find(|t| t.bytes == 10_000).expect("small transfer");
        assert!(
            (small.start - 1.0 * ms).abs() < 1e-12 && (small.end - 1.25 * ms).abs() < 1e-12,
            "small transfer [{}, {}] did not backfill the gap",
            small.start,
            small.end
        );
        // and its task slots into g1's idle window before the consumer
        assert!((s.assignments[3].start - 1.25 * ms).abs() < 1e-12);
        assert!((s.assignments[3].end - (1.25 * ms + 2.0 * 50f64.powi(3) / 4e9)).abs() < 1e-12);
        assert!((s.makespan - 3.0 * ms).abs() < 1e-12);
    }

    #[test]
    fn same_space_reads_are_noops_not_transfers() {
        // A task running in main memory reading main-resident data must
        // produce zero transfers and zero transfer events (same-space
        // movement is an explicit no-op, never a free "transfer").
        let (m, db) = gpu_machine();
        let dag = chain(2);
        let s = simulate_mapped(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestIdle), &[0, 0]);
        assert_eq!(s.transfer_bytes, 0);
        assert!(s.transfers.is_empty());
        assert!(!s
            .events
            .iter()
            .any(|e| matches!(e.kind, EventKind::TransferStart { .. } | EventKind::TransferEnd { .. })));
        // every transfer record the engine ever emits has finite times
        let (m, db) = three_space_machine();
        let s = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestFinish));
        assert!(s.transfers.iter().all(|t| t.start.is_finite() && t.end.is_finite()));
    }

    /// An EFT-*ordering* policy (highest priority = earliest finish) that
    /// records what it observes at key-computation time. Under push-time
    /// keying it would only ever see empty processors (all tasks are
    /// released at t=0); decision-time recomputation shows it the
    /// bookings of earlier picks.
    struct EftOrdering {
        order_calls: usize,
        max_tail_seen: f64,
    }

    impl SchedPolicy for EftOrdering {
        fn name(&self) -> &str {
            "test/eft-ordering"
        }

        fn order(&mut self, ctx: &mut SchedContext<'_>, task: &Task, release: f64, _ct: f64) -> f64 {
            self.order_calls += 1;
            self.max_tail_seen = self.max_tail_seen.max(ctx.proc_avail(0));
            let (fin, _) = ctx.earliest_finish(task, release);
            -fin
        }

        fn select(&mut self, ctx: &mut SchedContext<'_>, task: &Task, release: f64) -> ProcId {
            ctx.earliest_finish(task, release).1
        }
    }

    #[test]
    fn ready_keys_are_recomputed_at_decision_time() {
        // 3 equal independent tasks, 1 processor (1 GFLOPS → 2 ms each).
        // The old engine computed each key once, at push time, when
        // proc_avail[0] was still 0 for all three; the event core re-keys
        // the remaining ready set after every pick, so the policy observes
        // the growing booking tail (2 ms, then 4 ms).
        let mut b = MachineBuilder::new("m");
        let h = b.space("host", u64::MAX);
        b.main(h);
        let t = b.proc_type("cpu", 1.0, 0.1);
        b.processors(1, "c", t, h);
        let m = b.build();
        let mut db = PerfDb::new();
        db.set_fallback(0, PerfCurve::Const { gflops: 1.0 });
        let dag = independent(3);
        let mut pol = EftOrdering { order_calls: 0, max_tail_seen: 0.0 };
        let s = simulate_policy(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::EarliestIdle), &mut pol);
        let per = GEMM100 / 1e9; // 2 ms
        // re-keying: 3 + 2 + 1 calls, not one per task
        assert_eq!(pol.order_calls, 6, "keys must be recomputed for the remaining ready set");
        // at the last pick the policy saw 4 ms of booked work on proc 0
        assert!(
            (pol.max_tail_seen - 2.0 * per).abs() < 1e-12,
            "decision-time proc_avail observed {} (stale push-time state would be 0)",
            pol.max_tail_seen
        );
        assert!((s.makespan - 3.0 * per).abs() < 1e-12);
    }

    #[test]
    fn event_log_is_time_ordered_and_complete() {
        let (m, db) = gpu_machine();
        let dag = chain(3);
        let s = simulate(&dag, &m, &db, cfg(Ordering::Fcfs, ProcSelect::Fastest).with_cache(CachePolicy::WriteThrough));
        // monotone non-decreasing times
        for w in s.events.windows(2) {
            assert!(w[1].time >= w[0].time - 1e-15, "event log out of order");
        }
        let count = |f: fn(&EventKind) -> bool| s.events.iter().filter(|e| f(&e.kind)).count();
        assert_eq!(count(|k| matches!(k, EventKind::TaskStart { .. })), 3);
        assert_eq!(count(|k| matches!(k, EventKind::TaskEnd { .. })), 3);
        assert_eq!(count(|k| matches!(k, EventKind::TransferStart { .. })), s.transfers.len());
        assert_eq!(count(|k| matches!(k, EventKind::TransferEnd { .. })), s.transfers.len());
        assert!(count(|k| matches!(k, EventKind::ProcIdle { .. })) >= 1, "the GPU must go idle at the end");
        // every TaskStart/TaskEnd pair brackets the matching assignment
        for a in &s.assignments {
            assert!(s.events.iter().any(|e| e.kind == EventKind::TaskStart { task: a.task, proc: a.proc }
                && (e.time - a.start).abs() < 1e-15));
            assert!(s.events.iter().any(|e| e.kind == EventKind::TaskEnd { task: a.task, proc: a.proc }
                && (e.time - a.end).abs() < 1e-15));
        }
    }
}
