//! The hierarchical task DAG.
//!
//! Tasks live in an arena; *partitioning* a leaf replaces it (logically) by
//! a cluster of children in program order, and *merging* a cluster restores
//! the parent leaf — the two moves of the iterative scheduler-partitioner.
//! Only the **frontier** (the leaves, in program order) is scheduled.
//!
//! Dependence edges are *derived, not declared*: the frontier is a
//! sequential task stream (OmpSs/StarPU semantics) and RaW, WaR and WaW
//! constraints are found by geometric overlap between read/write regions.
//! This stays exact across nested partitions, where a sub-task of one
//! cluster depends on a sub-task of another through regions of different
//! granularity (paper §2.1).

use std::sync::Arc;

use crate::util::fxhash::FxHashMap;

use super::region::{MatrixId, Region};
use super::task::{Task, TaskId, TaskKind, TaskSpec};

/// Hierarchical task DAG (arena + tree structure + derived edges).
///
/// Task storage is **copy-on-write**: the arena holds `Arc<Task>` handles,
/// so cloning a DAG copies only the handle vector (refcount bumps, no
/// per-task region vectors) and a clone deep-copies a task lazily, the
/// first time *that clone* mutates it ([`Arc::make_mut`]). This is what
/// makes the portfolio solver's per-candidate scratch DAGs cheap: a batch
/// of K candidate evaluations takes K handle-vector clones plus at most
/// one deep task copy per mutated cluster, instead of K full deep clones.
#[derive(Debug, Clone)]
pub struct TaskDag {
    tasks: Vec<Arc<Task>>,
    /// Tombstones for tasks removed by merges.
    removed: Vec<bool>,
    pub root: TaskId,
}

/// The schedulable view: frontier tasks in program order plus derived
/// dependence edges (indices are positions in `tasks`).
#[derive(Debug, Clone, Default)]
pub struct FlatDag {
    /// Frontier task ids in program order.
    pub tasks: Vec<TaskId>,
    /// preds[i] / succs[i]: positions of dependence neighbours of tasks[i].
    pub preds: Vec<Vec<usize>>,
    pub succs: Vec<Vec<usize>>,
}

impl FlatDag {
    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// DAG width: maximum number of tasks in one longest-path level — the
    /// paper's "maximum number of tasks that can be run in parallel".
    pub fn width(&self) -> usize {
        // levels are dense in 0..len, so a Vec indexed by level replaces
        // the old hash map (and its iteration-order hazard) outright
        let mut level = vec![0usize; self.len()];
        let mut widths = vec![0usize; self.len()];
        for i in 0..self.len() {
            // program order is a topological order
            let l = self.preds[i].iter().map(|&p| level[p] + 1).max().unwrap_or(0);
            level[i] = l;
            widths[l] += 1;
        }
        widths.into_iter().max().unwrap_or(0)
    }

    /// Length (in tasks) of the longest dependence chain.
    pub fn longest_path_len(&self) -> usize {
        let mut level = vec![0usize; self.len()];
        let mut best = 0;
        for i in 0..self.len() {
            level[i] = self.preds[i].iter().map(|&p| level[p] + 1).max().unwrap_or(0);
            best = best.max(level[i] + 1);
        }
        best
    }

    pub fn edge_count(&self) -> usize {
        self.preds.iter().map(|p| p.len()).sum()
    }
}

impl TaskDag {
    /// Create a DAG holding a single root task.
    pub fn new(root: TaskSpec) -> TaskDag {
        let flops = root.flops();
        TaskDag {
            tasks: vec![Arc::new(Task {
                id: 0,
                kind: root.kind,
                reads: root.reads,
                writes: root.writes,
                flops,
                parent: None,
                children: None,
                depth: 0,
                partition_edge: None,
            })],
            removed: vec![false],
            root: 0,
        }
    }

    pub fn task(&self, id: TaskId) -> &Task {
        debug_assert!(!self.removed[id], "access to merged task {id}");
        &self.tasks[id]
    }

    pub fn is_live(&self, id: TaskId) -> bool {
        id < self.tasks.len() && !self.removed[id]
    }

    /// Number of live tasks (clusters + leaves).
    pub fn live_count(&self) -> usize {
        self.removed.iter().filter(|&&r| !r).count()
    }

    /// Partition a leaf into `specs` children (program order). Returns the
    /// new child ids. `edge` records the sub-tile edge used.
    pub fn partition(&mut self, id: TaskId, specs: Vec<TaskSpec>, edge: u32) -> Vec<TaskId> {
        assert!(self.is_live(id), "partition of dead task {id}");
        assert!(self.tasks[id].is_leaf(), "partition of non-leaf {id}");
        assert!(!specs.is_empty(), "empty partition of task {id}");
        let depth = self.tasks[id].depth + 1;
        let mut ids = Vec::with_capacity(specs.len());
        for s in specs {
            let nid = self.tasks.len();
            let flops = s.flops();
            self.tasks.push(Arc::new(Task {
                id: nid,
                kind: s.kind,
                reads: s.reads,
                writes: s.writes,
                flops,
                parent: Some(id),
                children: None,
                depth,
                partition_edge: None,
            }));
            self.removed.push(false);
            ids.push(nid);
        }
        let t = Arc::make_mut(&mut self.tasks[id]);
        t.children = Some(ids.clone());
        t.partition_edge = Some(edge);
        ids
    }

    /// Merge a cluster back into its parent leaf: removes the whole
    /// descendant subtree. The task becomes schedulable again.
    pub fn merge(&mut self, id: TaskId) {
        assert!(self.is_live(id), "merge of dead task {id}");
        if self.tasks[id].children.is_none() {
            return; // already a leaf
        }
        let t = Arc::make_mut(&mut self.tasks[id]);
        let children = t.children.take().expect("checked above");
        t.partition_edge = None;
        // descendants are only tombstoned, never deep-copied: their stale
        // child lists are unreachable (nothing traverses a removed task)
        let mut stack = children;
        while let Some(c) = stack.pop() {
            if let Some(gc) = &self.tasks[c].children {
                stack.extend(gc.iter().copied());
            }
            self.removed[c] = true;
        }
    }

    /// Leaves in program order (DFS following child order).
    pub fn frontier(&self) -> Vec<TaskId> {
        let mut out = Vec::new();
        let mut stack = vec![self.root];
        while let Some(id) = stack.pop() {
            match &self.tasks[id].children {
                None => out.push(id),
                Some(children) => {
                    // push reversed so children pop in program order
                    for &c in children.iter().rev() {
                        stack.push(c);
                    }
                }
            }
        }
        out
    }

    /// Clusters (live non-leaf tasks), candidates for merge/re-partition.
    pub fn clusters(&self) -> Vec<TaskId> {
        (0..self.tasks.len())
            .filter(|&i| !self.removed[i] && !self.tasks[i].is_leaf())
            .collect()
    }

    /// DAG depth: max number of nested clusters over leaves (paper: root
    /// unpartitioned = 0; one uniform blocking = 1; Table 1 reports 2–5).
    pub fn depth(&self) -> u32 {
        self.frontier().iter().map(|&t| self.tasks[t].depth).max().unwrap_or(0)
    }

    /// Total leaf flops (the workload's useful work).
    pub fn total_flops(&self) -> f64 {
        self.frontier().iter().map(|&t| self.tasks[t].flops).sum()
    }

    /// Relabel every region of every live task onto matrix `m`.
    ///
    /// The workload builders all emit matrix 0; the service layer gives
    /// each admitted job a distinct matrix id so that concurrent jobs'
    /// blocks never alias in the shared data DAG / coherence state —
    /// [`Region`] overlap requires matching matrices, so relabeled jobs
    /// are isolated by construction.
    pub fn set_matrix(&mut self, m: MatrixId) {
        for i in 0..self.tasks.len() {
            if self.removed[i] {
                continue;
            }
            let t = Arc::make_mut(&mut self.tasks[i]);
            for r in t.reads.iter_mut().chain(t.writes.iter_mut()) {
                r.matrix = m;
            }
        }
    }

    /// Build the schedulable view with derived dependence edges.
    ///
    /// Sequential-stream semantics over the frontier: for every pair of
    /// accesses to overlapping regions where at least one is a write, the
    /// later task depends on the earlier. Implemented with a registry of
    /// distinct accessed regions carrying last-writer + readers-since.
    pub fn flat_dag(&self) -> FlatDag {
        let frontier = self.frontier();
        let n = frontier.len();
        let mut preds: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];

        #[derive(Debug)]
        struct Access {
            last_writer: Option<usize>,
            readers_since: Vec<usize>,
        }
        // registry of distinct regions with a grain-grid spatial index
        let mut spatial = super::datadag::GrainIndex::new();
        let mut registry: Vec<Access> = Vec::new();
        let mut exact: FxHashMap<Region, usize> = FxHashMap::default();
        // dedup stamps: stamp[p] == current pos  =>  p already a pred
        let mut stamp: Vec<usize> = vec![usize::MAX; n];

        for (pos, &tid) in frontier.iter().enumerate() {
            let t = &self.tasks[tid];
            {
                let mut add_pred = |p: usize| {
                    if p != pos && stamp[p] != pos {
                        stamp[p] = pos;
                        preds[pos].push(p);
                        succs[p].push(pos);
                    }
                };
                // RaW: reads depend on last writers of overlapping regions
                for r in &t.reads {
                    spatial.visit_intersecting(r, |ai| {
                        if let Some(w) = registry[ai].last_writer {
                            add_pred(w);
                        }
                    });
                }
                // WaW + WaR: writes depend on last writers and on readers
                for w in &t.writes {
                    spatial.visit_intersecting(w, |ai| {
                        let a = &registry[ai];
                        if let Some(lw) = a.last_writer {
                            add_pred(lw);
                        }
                        for &rd in &a.readers_since {
                            add_pred(rd);
                        }
                    });
                }
            }
            // update registry
            let touch = |region: &Region,
                         registry: &mut Vec<Access>,
                         exact: &mut FxHashMap<Region, usize>,
                         spatial: &mut super::datadag::GrainIndex|
             -> usize {
                *exact.entry(*region).or_insert_with(|| {
                    let ai = registry.len();
                    registry.push(Access { last_writer: None, readers_since: Vec::new() });
                    spatial.insert(*region, ai);
                    ai
                })
            };
            for r in &t.reads {
                let ai = touch(r, &mut registry, &mut exact, &mut spatial);
                registry[ai].readers_since.push(pos);
            }
            for w in &t.writes {
                let ai = touch(w, &mut registry, &mut exact, &mut spatial);
                registry[ai].last_writer = Some(pos);
                registry[ai].readers_since.clear();
            }
        }

        FlatDag { tasks: frontier, preds, succs }
    }

    /// Graphviz DOT export of the frontier DAG (Fig. 2a regeneration).
    pub fn to_dot(&self) -> String {
        let flat = self.flat_dag();
        let mut out = String::from("digraph hesp {\n  rankdir=LR;\n");
        for (i, &tid) in flat.tasks.iter().enumerate() {
            let t = &self.tasks[tid];
            let color = match t.kind {
                TaskKind::Potrf => "gold",
                TaskKind::Trsm => "skyblue",
                TaskKind::Syrk => "salmon",
                TaskKind::Gemm => "palegreen",
                _ => "gray",
            };
            out.push_str(&format!(
                "  n{i} [label=\"{}\\n{}\" style=filled fillcolor={color}];\n",
                t.kind.name(),
                t.writes.first().map(|r| r.to_string()).unwrap_or_default()
            ));
        }
        for (i, ps) in flat.preds.iter().enumerate() {
            for &p in ps {
                out.push_str(&format!("  n{p} -> n{i};\n"));
            }
        }
        out.push_str("}\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::region::Region;

    fn reg(r0: u32, r1: u32, c0: u32, c1: u32) -> Region {
        Region::new(0, r0, r1, c0, c1)
    }

    fn spec(kind: TaskKind, reads: Vec<Region>, writes: Vec<Region>) -> TaskSpec {
        TaskSpec::new(kind, reads, writes)
    }

    fn root_chol(n: u32) -> TaskSpec {
        let r = reg(0, n, 0, n);
        spec(TaskKind::Potrf, vec![r], vec![r])
    }

    #[test]
    fn single_task_dag() {
        let dag = TaskDag::new(root_chol(64));
        assert_eq!(dag.frontier(), vec![0]);
        let flat = dag.flat_dag();
        assert_eq!(flat.len(), 1);
        assert!(flat.preds[0].is_empty());
        assert_eq!(dag.depth(), 0);
        assert_eq!(flat.width(), 1);
    }

    #[test]
    fn partition_creates_program_order_frontier() {
        let mut dag = TaskDag::new(root_chol(4));
        // 2x2 blocked cholesky: potrf00, trsm10, syrk11, potrf11
        let b = 2;
        let t00 = reg(0, b, 0, b);
        let t10 = reg(b, 2 * b, 0, b);
        let t11 = reg(b, 2 * b, b, 2 * b);
        let kids = dag.partition(
            0,
            vec![
                spec(TaskKind::Potrf, vec![t00], vec![t00]),
                spec(TaskKind::Trsm, vec![t00, t10], vec![t10]),
                spec(TaskKind::Syrk, vec![t10, t11], vec![t11]),
                spec(TaskKind::Potrf, vec![t11], vec![t11]),
            ],
            b,
        );
        assert_eq!(dag.frontier(), kids);
        assert_eq!(dag.depth(), 1);

        let flat = dag.flat_dag();
        // trsm depends on potrf00 (RaW on t00)
        assert_eq!(flat.preds[1], vec![0]);
        // syrk depends on trsm (RaW t10)
        assert_eq!(flat.preds[2], vec![1]);
        // potrf11 depends on syrk (RaW+WaW t11)
        assert_eq!(flat.preds[3], vec![2]);
        assert_eq!(flat.longest_path_len(), 4);
    }

    #[test]
    fn waw_and_war_edges() {
        let mut dag = TaskDag::new(root_chol(8));
        let a = reg(0, 8, 0, 8);
        let kids = dag.partition(
            0,
            vec![
                spec(TaskKind::Gemm, vec![], vec![a]),  // W
                spec(TaskKind::Gemm, vec![a], vec![]),  // R  -> RaW on 0
                spec(TaskKind::Gemm, vec![], vec![a]),  // W  -> WaW on 0, WaR on 1
            ],
            8,
        );
        assert_eq!(kids.len(), 3);
        let flat = dag.flat_dag();
        assert_eq!(flat.preds[1], vec![0]);
        let mut p2 = flat.preds[2].clone();
        p2.sort();
        assert_eq!(p2, vec![0, 1]);
    }

    #[test]
    fn cross_granularity_dependences() {
        // Writer of a big block, then readers of its quadrants at finer
        // grain: every quadrant reader must depend on the big writer.
        let mut dag = TaskDag::new(root_chol(8));
        let big = reg(0, 8, 0, 8);
        let q = reg(4, 8, 0, 4);
        let other = reg(0, 4, 4, 8);
        dag.partition(
            0,
            vec![
                spec(TaskKind::Gemm, vec![], vec![big]),
                spec(TaskKind::Gemm, vec![q], vec![q]),
                spec(TaskKind::Gemm, vec![other], vec![other]),
                // writes a region overlapping q partially
                spec(TaskKind::Gemm, vec![], vec![reg(2, 6, 0, 6)]),
            ],
            4,
        );
        let flat = dag.flat_dag();
        assert_eq!(flat.preds[1], vec![0]);
        assert_eq!(flat.preds[2], vec![0]);
        // task3 overlaps big (WaW->0), q (WaW/WaR->1) and other? reg(2,6,0,6)
        // cols 0..6 rows 2..6 vs other rows 0..4 cols 4..8: rows 2..4, cols
        // 4..6 overlap -> WaR on 2 as well.
        let mut p3 = flat.preds[3].clone();
        p3.sort();
        assert_eq!(p3, vec![0, 1, 2]);
    }

    #[test]
    fn merge_restores_leaf_and_removes_subtree() {
        let mut dag = TaskDag::new(root_chol(8));
        let a = reg(0, 4, 0, 4);
        let kids = dag.partition(0, vec![spec(TaskKind::Potrf, vec![a], vec![a]); 3], 4);
        let gkids = dag.partition(kids[1], vec![spec(TaskKind::Potrf, vec![a], vec![a]); 2], 2);
        assert_eq!(dag.frontier().len(), 4);
        assert_eq!(dag.depth(), 2);
        dag.merge(kids[1]);
        assert_eq!(dag.frontier(), kids);
        assert!(!dag.is_live(gkids[0]) && !dag.is_live(gkids[1]));
        assert_eq!(dag.depth(), 1);
        // merging the root removes everything below
        dag.merge(0);
        assert_eq!(dag.frontier(), vec![0]);
        assert_eq!(dag.live_count(), 1);
    }

    #[test]
    fn merge_leaf_is_noop() {
        let mut dag = TaskDag::new(root_chol(8));
        dag.merge(0);
        assert_eq!(dag.frontier(), vec![0]);
    }

    #[test]
    #[should_panic]
    fn partition_non_leaf_panics() {
        let mut dag = TaskDag::new(root_chol(8));
        let a = reg(0, 4, 0, 4);
        dag.partition(0, vec![spec(TaskKind::Potrf, vec![a], vec![a])], 4);
        dag.partition(0, vec![spec(TaskKind::Potrf, vec![a], vec![a])], 4);
    }

    #[test]
    fn clusters_listed() {
        let mut dag = TaskDag::new(root_chol(8));
        let a = reg(0, 4, 0, 4);
        let kids = dag.partition(0, vec![spec(TaskKind::Potrf, vec![a], vec![a]); 2], 4);
        dag.partition(kids[0], vec![spec(TaskKind::Potrf, vec![a], vec![a]); 2], 2);
        let mut cs = dag.clusters();
        cs.sort();
        assert_eq!(cs, vec![0, kids[0]]);
    }

    #[test]
    fn width_of_fork_join() {
        let mut dag = TaskDag::new(root_chol(8));
        let w = reg(0, 8, 0, 8);
        let r1 = reg(0, 4, 0, 4);
        let r2 = reg(4, 8, 4, 8);
        dag.partition(
            0,
            vec![
                spec(TaskKind::Gemm, vec![], vec![w]),
                spec(TaskKind::Gemm, vec![r1], vec![r1]),
                spec(TaskKind::Gemm, vec![r2], vec![r2]),
                spec(TaskKind::Gemm, vec![w], vec![w]),
            ],
            4,
        );
        let flat = dag.flat_dag();
        assert_eq!(flat.width(), 2);
        assert_eq!(flat.longest_path_len(), 3);
        assert_eq!(flat.edge_count(), 5); // 0->1, 0->2, 0->3(WaW), 1->3, 2->3
    }

    #[test]
    fn dot_export_mentions_all_tasks() {
        let mut dag = TaskDag::new(root_chol(4));
        let a = reg(0, 2, 0, 2);
        dag.partition(0, vec![spec(TaskKind::Potrf, vec![a], vec![a]); 3], 2);
        let dot = dag.to_dot();
        assert_eq!(dot.matches("fillcolor").count(), 3);
        assert!(dot.contains("digraph"));
    }

    #[test]
    fn clone_is_copy_on_write() {
        use std::sync::Arc;
        let mut dag = TaskDag::new(root_chol(8));
        let a = reg(0, 4, 0, 4);
        dag.partition(0, vec![spec(TaskKind::Potrf, vec![a], vec![a]); 3], 4);
        let snap = dag.clone();
        // a clone shares task storage until one side mutates
        assert!(Arc::ptr_eq(&dag.tasks[1], &snap.tasks[1]));
        assert!(Arc::ptr_eq(&dag.tasks[0], &snap.tasks[0]));
        dag.merge(0);
        // the snapshot kept the pre-merge shape
        assert_eq!(snap.frontier().len(), 3);
        assert_eq!(snap.task(0).partition_edge, Some(4));
        assert_eq!(dag.frontier(), vec![0]);
        // only the mutated root diverged; tombstoned children stay shared
        assert!(!Arc::ptr_eq(&dag.tasks[0], &snap.tasks[0]));
        assert!(Arc::ptr_eq(&dag.tasks[1], &snap.tasks[1]));
        // and the snapshot still schedules independently
        assert_eq!(snap.flat_dag().len(), 3);
    }

    #[test]
    fn set_matrix_relabels_all_live_regions_and_isolates_clones() {
        let mut dag = TaskDag::new(root_chol(8));
        let a = reg(0, 4, 0, 4);
        dag.partition(0, vec![spec(TaskKind::Potrf, vec![a], vec![a]); 2], 4);
        let snap = dag.clone();
        dag.set_matrix(7);
        for &t in &dag.frontier() {
            assert!(dag.task(t).reads.iter().all(|r| r.matrix == 7));
            assert!(dag.task(t).writes.iter().all(|r| r.matrix == 7));
        }
        // copy-on-write: the clone keeps matrix 0 — two jobs built from
        // the same template must not alias after relabeling one of them
        for &t in &snap.frontier() {
            assert!(snap.task(t).writes.iter().all(|r| r.matrix == 0));
        }
        // relabeling preserves the dependence structure (same overlaps)
        assert_eq!(dag.flat_dag().edge_count(), snap.flat_dag().edge_count());
    }

    #[test]
    fn total_flops_sums_frontier() {
        let mut dag = TaskDag::new(root_chol(8));
        let a = reg(0, 4, 0, 4);
        dag.partition(
            0,
            vec![
                spec(TaskKind::Gemm, vec![a], vec![a]),
                spec(TaskKind::Trsm, vec![a], vec![a]),
            ],
            4,
        );
        assert_eq!(dag.total_flops(), 2.0 * 64.0 + 64.0);
    }
}
