//! The data DAG: every distinct data block ever referenced, related by
//! nesting, plus *intersection descriptors* for partially-overlapping
//! blocks (paper Fig. 4: a block simultaneously divided by two tilings of
//! non-divisible grain gets a common child per pairwise overlap).
//!
//! Nodes are created lazily as partitioners reference new regions; the
//! graph is append-only (merging tasks leaves stale blocks in place — they
//! are simply never referenced again, matching the paper's append-only
//! descriptor store).


use crate::util::fxhash::FxHashMap;

use super::region::Region;

pub type BlockId = usize;

/// Spatial index over regions, exploiting that partitioner-emitted tiles
/// are *grain-aligned*: a tile of shape (h, w) sits at offsets that are
/// multiples of (h, w) (divisor-based partitioning guarantees it). Aligned
/// regions live in per-grain grids with O(cells-overlapped) queries;
/// anything irregular (e.g. Fig. 4 intersection descriptors) falls back to
/// a per-matrix linear list. This turns dependence derivation and
/// coherence closure queries from O(#blocks) to near O(#overlaps).
#[derive(Debug, Clone, Default)]
pub struct GrainIndex {
    /// (matrix, h, w) -> (i, j) cell -> payload.
    grids: FxHashMap<(u32, u32, u32), FxHashMap<(u32, u32), usize>>,
    /// Distinct grains per matrix (small: one per partition granularity).
    grains: FxHashMap<u32, Vec<(u32, u32)>>,
    /// Non-grain-aligned regions, scanned linearly.
    irregular: FxHashMap<u32, Vec<(Region, usize)>>,
}

impl GrainIndex {
    pub fn new() -> GrainIndex {
        GrainIndex::default()
    }

    fn aligned(r: &Region) -> bool {
        r.r0 % r.rows() == 0 && r.c0 % r.cols() == 0
    }

    /// Insert `region` with payload `id`. Last insert for a cell wins
    /// (regions are deduplicated by callers).
    pub fn insert(&mut self, region: Region, id: usize) {
        if Self::aligned(&region) {
            let (h, w) = (region.rows(), region.cols());
            let key = (region.matrix, h, w);
            if !self.grids.contains_key(&key) {
                self.grains.entry(region.matrix).or_default().push((h, w));
            }
            self.grids.entry(key).or_default().insert((region.r0 / h, region.c0 / w), id);
        } else {
            self.irregular.entry(region.matrix).or_default().push((region, id));
        }
    }

    /// Visit the payloads of all indexed regions intersecting `region`.
    pub fn visit_intersecting<F: FnMut(usize)>(&self, region: &Region, mut f: F) {
        if let Some(grain_sizes) = self.grains.get(&region.matrix) {
            for &(h, w) in grain_sizes {
                let grid = &self.grids[&(region.matrix, h, w)];
                // cheap path: if the query covers more cells than the grid
                // holds, iterate the grid instead of the cell range
                let cells = ((region.r1 - 1) / h - region.r0 / h + 1) as usize
                    * ((region.c1 - 1) / w - region.c0 / w + 1) as usize;
                if cells > grid.len() {
                    for (&(i, j), &id) in grid {
                        let cell = Region::new(region.matrix, i * h, (i + 1) * h, j * w, (j + 1) * w);
                        if cell.intersects(region) {
                            f(id);
                        }
                    }
                } else {
                    for i in region.r0 / h..=(region.r1 - 1) / h {
                        for j in region.c0 / w..=(region.c1 - 1) / w {
                            if let Some(&id) = grid.get(&(i, j)) {
                                f(id);
                            }
                        }
                    }
                }
            }
        }
        if let Some(list) = self.irregular.get(&region.matrix) {
            for (r, id) in list {
                if r.intersects(region) {
                    f(*id);
                }
            }
        }
    }
}

/// One data-block descriptor.
#[derive(Debug, Clone)]
pub struct BlockNode {
    pub id: BlockId,
    pub region: Region,
    /// Blocks strictly containing this one (bottom-up links).
    pub parents: Vec<BlockId>,
    /// Blocks strictly contained in this one (top-down links).
    pub children: Vec<BlockId>,
    /// True if this node was synthesized as the overlap of two
    /// partially-overlapping blocks (Fig. 4's green descriptors).
    pub is_intersection: bool,
}

/// Append-only registry of data blocks with containment/intersection
/// structure.
#[derive(Debug, Clone, Default)]
pub struct DataDag {
    blocks: Vec<BlockNode>,
    index: FxHashMap<Region, BlockId>,
    spatial: GrainIndex,
}

impl DataDag {
    pub fn new() -> DataDag {
        DataDag::default()
    }

    pub fn len(&self) -> usize {
        self.blocks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    pub fn block(&self, id: BlockId) -> &BlockNode {
        &self.blocks[id]
    }

    pub fn lookup(&self, region: &Region) -> Option<BlockId> {
        self.index.get(region).copied()
    }

    /// Insert (or find) the block for `region`; creates intersection
    /// descriptors against partially-overlapping existing blocks.
    pub fn insert(&mut self, region: Region) -> BlockId {
        if let Some(&id) = self.index.get(&region) {
            return id;
        }
        let id = self.blocks.len();
        self.blocks.push(BlockNode { id, region, parents: Vec::new(), children: Vec::new(), is_intersection: false });
        self.index.insert(region, id);

        // relate against existing blocks intersecting this one
        let mut touching: Vec<BlockId> = Vec::new();
        self.spatial.visit_intersecting(&region, |b| touching.push(b));
        let mut overlaps: Vec<Region> = Vec::new();
        for other in touching {
            let oregion = self.blocks[other].region;
            if oregion == region {
                continue;
            }
            if oregion.contains(&region) {
                self.blocks[other].children.push(id);
                self.blocks[id].parents.push(other);
            } else if region.contains(&oregion) {
                self.blocks[id].children.push(other);
                self.blocks[other].parents.push(id);
            } else if let Some(ix) = region.intersection(&oregion) {
                // partial overlap: synthesize a common child (Fig. 4)
                overlaps.push(ix);
            }
        }
        self.spatial.insert(region, id);
        for ix in overlaps {
            let ix_id = self.insert(ix);
            self.blocks[ix_id].is_intersection = true;
        }
        id
    }

    /// All blocks whose region intersects `region` (including nested and
    /// partially-overlapping ones) — the invalidation closure used by the
    /// coherence machinery.
    pub fn intersecting(&self, region: &Region) -> Vec<BlockId> {
        let mut out = Vec::new();
        self.spatial.visit_intersecting(region, |b| out.push(b));
        out.sort_unstable();
        out
    }

    /// Blocks fully contained in `region` (top-down validation closure).
    pub fn contained_in(&self, region: &Region) -> Vec<BlockId> {
        let mut out = Vec::new();
        self.spatial.visit_intersecting(region, |b| {
            if region.contains(&self.blocks[b].region) {
                out.push(b);
            }
        });
        out.sort_unstable();
        out
    }

    /// Blocks containing `region` (bottom-up propagation closure).
    pub fn containing(&self, region: &Region) -> Vec<BlockId> {
        let mut out = Vec::new();
        self.spatial.visit_intersecting(region, |b| {
            if self.blocks[b].region.contains(region) {
                out.push(b);
            }
        });
        out.sort_unstable();
        out
    }

    /// Longest nesting chain (a depth measure of the data hierarchy).
    pub fn nesting_depth(&self) -> usize {
        let mut memo = vec![0usize; self.blocks.len()];
        let mut order: Vec<BlockId> = (0..self.blocks.len()).collect();
        // sort by area ascending: children before parents
        order.sort_by_key(|&b| self.blocks[b].region.area());
        let mut best = 0;
        for b in order {
            let d = self.blocks[b].children.iter().map(|&c| memo[c] + 1).max().unwrap_or(1);
            memo[b] = d;
            best = best.max(d);
        }
        best
    }

    pub fn intersection_count(&self) -> usize {
        self.blocks.iter().filter(|b| b.is_intersection).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn r(r0: u32, r1: u32, c0: u32, c1: u32) -> Region {
        Region::new(0, r0, r1, c0, c1)
    }

    #[test]
    fn insert_is_idempotent() {
        let mut d = DataDag::new();
        let a = d.insert(r(0, 8, 0, 8));
        let b = d.insert(r(0, 8, 0, 8));
        assert_eq!(a, b);
        assert_eq!(d.len(), 1);
    }

    #[test]
    fn nesting_links() {
        let mut d = DataDag::new();
        let big = d.insert(r(0, 8, 0, 8));
        let small = d.insert(r(0, 4, 0, 4));
        assert_eq!(d.block(big).children, vec![small]);
        assert_eq!(d.block(small).parents, vec![big]);
        assert_eq!(d.nesting_depth(), 2);
    }

    #[test]
    fn insert_parent_after_child() {
        let mut d = DataDag::new();
        let small = d.insert(r(2, 4, 2, 4));
        let big = d.insert(r(0, 8, 0, 8));
        assert_eq!(d.block(big).children, vec![small]);
        assert_eq!(d.block(small).parents, vec![big]);
    }

    #[test]
    fn fig4_intersection_descriptor() {
        // Two tilings of a 6x6 block with grains 2 and 3: tile (2..4,2..4)
        // and tile (0..3,0..3) partially overlap -> descriptor (2..3,2..3).
        let mut d = DataDag::new();
        d.insert(r(0, 6, 0, 6));
        let yellow = d.insert(r(2, 4, 2, 4));
        let blue = d.insert(r(0, 3, 0, 3));
        let ix = d.lookup(&r(2, 3, 2, 3)).expect("intersection descriptor created");
        assert!(d.block(ix).is_intersection);
        assert!(d.block(ix).parents.contains(&yellow));
        assert!(d.block(ix).parents.contains(&blue));
        assert_eq!(d.intersection_count(), 1);
    }

    #[test]
    fn intersection_inserted_recursively() {
        let mut d = DataDag::new();
        d.insert(r(0, 4, 0, 4));
        d.insert(r(2, 6, 2, 6));
        // overlap (2..4,2..4) created; inserting (3..5,3..5) overlaps it too
        d.insert(r(3, 5, 3, 5));
        assert!(d.lookup(&r(2, 4, 2, 4)).is_some());
        assert!(d.lookup(&r(3, 4, 3, 4)).is_some());
    }

    #[test]
    fn closures_are_geometric() {
        let mut d = DataDag::new();
        let big = d.insert(r(0, 8, 0, 8));
        let q1 = d.insert(r(0, 4, 0, 4));
        let q4 = d.insert(r(4, 8, 4, 8));
        let probe = r(0, 4, 0, 4);
        let inter = d.intersecting(&probe);
        assert!(inter.contains(&big) && inter.contains(&q1) && !inter.contains(&q4));
        assert_eq!(d.contained_in(&probe), vec![q1]);
        let cont = d.containing(&probe);
        assert!(cont.contains(&big) && cont.contains(&q1));
    }

    #[test]
    fn matrices_are_disjoint_worlds() {
        let mut d = DataDag::new();
        let a = d.insert(Region::new(0, 0, 8, 0, 8));
        let b = d.insert(Region::new(1, 0, 8, 0, 8));
        assert!(d.block(a).parents.is_empty() && d.block(a).children.is_empty());
        assert!(d.block(b).parents.is_empty() && d.block(b).children.is_empty());
        assert_eq!(d.intersecting(&Region::new(0, 0, 8, 0, 8)), vec![a]);
    }

    #[test]
    fn three_level_nesting_depth() {
        let mut d = DataDag::new();
        d.insert(r(0, 16, 0, 16));
        d.insert(r(0, 8, 0, 8));
        d.insert(r(0, 4, 0, 4));
        d.insert(r(8, 16, 8, 16));
        assert_eq!(d.nesting_depth(), 3);
    }
}
