//! Rank passes over the frontier DAG: the Priority-List critical times and
//! the communication-aware ranks of the classic list schedulers.
//!
//! Paper §2.1: "a priority list is built by sorting tasks by their critical
//! times in decreasing order. Critical times are computed by averaging task
//! processing time for all processors, and propagating them throughout the
//! task DAG by a backflow algorithm" — i.e. the upward rank of HEFT,
//! without transfer terms (HeSP folds transfer awareness into EFT-P).
//!
//! The classic baselines (`cls/heft`, `cls/peft`) put the transfer terms
//! back: [`upward_ranks`] is HEFT's `rank_u` with mean edge-communication
//! costs derived from region-overlap bytes and the machine's average link
//! parameters ([`mean_comm_cost`]), and [`oct_table`] is PEFT's Optimistic
//! Cost Table under the same cost model.

use super::perfmodel::PerfDb;
use super::platform::Machine;
use super::task::Task;
use super::taskdag::{FlatDag, TaskDag};

/// Average execution time of each frontier task across all processors.
pub fn avg_times(dag: &TaskDag, flat: &FlatDag, machine: &Machine, db: &PerfDb) -> Vec<f64> {
    let ptypes: Vec<usize> = machine.procs.iter().map(|p| p.ptype).collect();
    flat.tasks
        .iter()
        .map(|&tid| {
            let t = dag.task(tid);
            db.avg_time(&ptypes, t.kind, t.char_edge(), t.flops)
        })
        .collect()
}

/// Backflow critical times: `ct[i] = avg[i] + max over successors ct[s]`.
/// Program order is a topological order, so one reverse sweep suffices.
pub fn critical_times(dag: &TaskDag, flat: &FlatDag, machine: &Machine, db: &PerfDb) -> Vec<f64> {
    let avg = avg_times(dag, flat, machine, db);
    let mut ct = vec![0.0f64; flat.len()];
    for i in (0..flat.len()).rev() {
        let down = flat.succs[i].iter().map(|&s| ct[s]).fold(0.0f64, f64::max);
        ct[i] = avg[i] + down;
    }
    ct
}

/// Positions (into the frontier) of tasks on a critical path: start from a
/// source with maximal critical time and walk successors greedily.
pub fn critical_path(flat: &FlatDag, ct: &[f64]) -> Vec<usize> {
    if flat.is_empty() {
        return Vec::new();
    }
    let mut cur = (0..flat.len())
        .filter(|&i| flat.preds[i].is_empty())
        .max_by(|&a, &b| ct[a].total_cmp(&ct[b]))
        .unwrap();
    let mut path = vec![cur];
    while let Some(&next) = flat.succs[cur].iter().max_by(|&&a, &&b| ct[a].total_cmp(&ct[b])) {
        path.push(next);
        cur = next;
    }
    path
}

/// Mean per-edge communication-cost factors of `machine`, averaged over
/// all ordered pairs of distinct processors (HEFT's `c̄`): returns
/// `(lat, s_per_byte)` such that moving `b` bytes between two uniformly
/// chosen distinct processors costs `lat + b as f64 * s_per_byte` on
/// average. Same-space pairs contribute zero (no transfer) and multi-hop
/// routes sum latency and inverse bandwidth per hop, mirroring
/// [`Machine::transfer_time`]. A single-space machine (ODROID) yields
/// `(0.0, 0.0)`, so every comm-aware rank degrades to the comm-free
/// critical time there.
pub fn mean_comm_cost(machine: &Machine) -> (f64, f64) {
    let n = machine.procs.len();
    if n < 2 {
        return (0.0, 0.0);
    }
    let mut per_space = vec![0usize; machine.spaces.len()];
    for p in &machine.procs {
        per_space[p.space] += 1;
    }
    let (mut lat, mut spb) = (0.0f64, 0.0f64);
    for a in 0..machine.spaces.len() {
        for b in 0..machine.spaces.len() {
            if a == b || per_space[a] == 0 || per_space[b] == 0 {
                continue;
            }
            let pairs = (per_space[a] * per_space[b]) as f64;
            for lid in machine.route(a, b) {
                let l = &machine.links[lid];
                lat += pairs * l.latency;
                spb += pairs / l.bandwidth;
            }
        }
    }
    let total = (n * (n - 1)) as f64;
    (lat / total, spb / total)
}

/// Bytes `succ` consumes from `pred`'s outputs: the overlap area of every
/// (write, read) region pair times the element size. A read overlapping
/// several of `pred`'s writes counts each overlap once per pair; HeSP's
/// partitioners emit disjoint write sets, so nothing double-counts in
/// practice. Zero means a pure control dependence (no data moves).
pub fn edge_bytes(pred: &Task, succ: &Task, elem_bytes: u64) -> u64 {
    let mut area = 0u64;
    for w in &pred.writes {
        for r in &succ.reads {
            if let Some(x) = w.intersection(r) {
                area += x.area();
            }
        }
    }
    area * elem_bytes
}

/// Mean communication cost of the `pred → succ` edge under the averaged
/// link model: `lat + bytes * s_per_byte`, or 0 for edges that move no
/// bytes (and on machines with no links at all).
fn edge_cost(pred: &Task, succ: &Task, elem_bytes: u64, lat: f64, spb: f64) -> f64 {
    if lat == 0.0 && spb == 0.0 {
        return 0.0;
    }
    let b = edge_bytes(pred, succ, elem_bytes);
    if b == 0 {
        0.0
    } else {
        lat + b as f64 * spb
    }
}

/// HEFT upward ranks (Topcuoglu et al. 2002, eq. 4):
/// `rank_u[i] = w̄_i + max over successors s of (c̄_is + rank_u[s])` —
/// [`critical_times`] plus the mean edge-communication cost on every DAG
/// edge. Program order is topological, so one reverse sweep suffices.
pub fn upward_ranks(dag: &TaskDag, flat: &FlatDag, machine: &Machine, db: &PerfDb, elem_bytes: u64) -> Vec<f64> {
    let avg = avg_times(dag, flat, machine, db);
    let (lat, spb) = mean_comm_cost(machine);
    let mut rank = vec![0.0f64; flat.len()];
    for i in (0..flat.len()).rev() {
        let t = dag.task(flat.tasks[i]);
        let mut down = 0.0f64;
        for &s in &flat.succs[i] {
            let c = edge_cost(t, dag.task(flat.tasks[s]), elem_bytes, lat, spb);
            down = down.max(c + rank[s]);
        }
        rank[i] = avg[i] + down;
    }
    rank
}

/// PEFT's Optimistic Cost Table (Arabnejad & Barbosa 2014), computed per
/// processor *type*: under the averaged communication model, same-type
/// processors are symmetric, so the per-processor table collapses to
/// `machine.proc_types.len()` columns. Exit tasks have all-zero rows;
/// otherwise
/// `OCT[i][k] = max over successors s of min over types w of
///  (OCT[s][w] + w(s, w) + c̄_is·[w ≠ k])`
/// — the optimistic cost of finishing everything downstream of `i` if `i`
/// runs on a type-`k` processor. (Collapsing to types makes two same-type
/// device spaces look transfer-free to each other; an approximation the
/// averaged `c̄` already commits to.)
pub fn oct_table(dag: &TaskDag, flat: &FlatDag, machine: &Machine, db: &PerfDb, elem_bytes: u64) -> Vec<Vec<f64>> {
    let nt = machine.proc_types.len();
    let (lat, spb) = mean_comm_cost(machine);
    let n = flat.len();
    let mut oct = vec![vec![0.0f64; nt]; n];
    for i in (0..n).rev() {
        if flat.succs[i].is_empty() {
            continue; // exit task: optimistically nothing left downstream
        }
        let ti = dag.task(flat.tasks[i]);
        for k in 0..nt {
            let mut worst = 0.0f64;
            for &s in &flat.succs[i] {
                let ts = dag.task(flat.tasks[s]);
                let c = edge_cost(ti, ts, elem_bytes, lat, spb);
                let mut best = f64::INFINITY;
                for (w, row) in oct[s].iter().enumerate() {
                    let wt = db.time(w, ts.kind, ts.char_edge(), ts.flops);
                    best = best.min(row + wt + if w == k { 0.0 } else { c });
                }
                worst = worst.max(best);
            }
            oct[i][k] = worst;
        }
    }
    oct
}

/// PEFT's `rank_oct`: the mean of a task's OCT row over *processors*
/// (each type weighted by its processor count), which is what the
/// per-processor mean of the original formulation collapses to.
pub fn oct_ranks(machine: &Machine, oct: &[Vec<f64>]) -> Vec<f64> {
    let mut count = vec![0usize; machine.proc_types.len()];
    for p in &machine.procs {
        count[p.ptype] += 1;
    }
    let n = machine.procs.len().max(1) as f64;
    oct.iter().map(|row| row.iter().zip(&count).map(|(v, &c)| v * c as f64).sum::<f64>() / n).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::perfmodel::PerfCurve;
    use crate::coordinator::platform::MachineBuilder;
    use crate::coordinator::region::Region;
    use crate::coordinator::task::{TaskKind, TaskSpec};

    fn machine_two_types() -> Machine {
        let mut b = MachineBuilder::new("m");
        let h = b.space("host", u64::MAX);
        b.main(h);
        let slow = b.proc_type("slow", 1.0, 0.1);
        let fast = b.proc_type("fast", 1.0, 0.1);
        b.processors(1, "s", slow, h);
        b.processors(1, "f", fast, h);
        b.build()
    }

    fn db() -> PerfDb {
        let mut db = PerfDb::new();
        db.set_fallback(0, PerfCurve::Const { gflops: 1.0 });
        db.set_fallback(1, PerfCurve::Const { gflops: 3.0 });
        db
    }

    fn chain_dag() -> TaskDag {
        // t0 -> t1 -> t2 over the same region
        let r = Region::new(0, 0, 100, 0, 100);
        let mut dag = TaskDag::new(TaskSpec::new(TaskKind::Potrf, vec![r], vec![r]));
        dag.partition(0, vec![TaskSpec::new(TaskKind::Gemm, vec![r], vec![r]); 3], 100);
        dag
    }

    #[test]
    fn critical_times_accumulate_backwards() {
        let dag = chain_dag();
        let flat = dag.flat_dag();
        let m = machine_two_types();
        let ct = critical_times(&dag, &flat, &m, &db());
        // per-task avg time: flops = 2*100^3 = 2e6 flops; rates 1 and 3
        // GFLOPS -> times 2e-3 and 2e-3/3; avg = (2e-3 + 6.667e-4)/2
        let avg = (2e-3 + 2e-3 / 3.0) / 2.0;
        assert!((ct[2] - avg).abs() < 1e-12);
        assert!((ct[1] - 2.0 * avg).abs() < 1e-12);
        assert!((ct[0] - 3.0 * avg).abs() < 1e-12);
        // decreasing along the chain => PL order is program order here
        assert!(ct[0] > ct[1] && ct[1] > ct[2]);
    }

    #[test]
    fn critical_path_follows_heavy_branch() {
        // diamond: t0 -> {t1 heavy, t2 light} -> t3
        let w = Region::new(0, 0, 8, 0, 8);
        let heavy = Region::new(0, 0, 4, 0, 4);
        let light = Region::new(0, 4, 8, 4, 8);
        let mut dag = TaskDag::new(TaskSpec::new(TaskKind::Potrf, vec![w], vec![w]));
        dag.partition(
            0,
            vec![
                TaskSpec::new(TaskKind::Gemm, vec![], vec![w]),
                TaskSpec::new(TaskKind::Gemm, vec![heavy], vec![heavy]), // 2*64 flops
                TaskSpec::new(TaskKind::Trsm, vec![light], vec![light]), // 64 flops
                TaskSpec::new(TaskKind::Gemm, vec![w], vec![w]),
            ],
            4,
        );
        let flat = dag.flat_dag();
        let m = machine_two_types();
        let ct = critical_times(&dag, &flat, &m, &db());
        let path = critical_path(&flat, &ct);
        assert_eq!(path.first(), Some(&0));
        assert_eq!(path.last(), Some(&3));
        assert!(path.contains(&1), "heavy branch on critical path: {path:?}");
        assert!(!path.contains(&2));
    }

    /// Two spaces over one symmetric 1 µs / 1 GB/s link, one slow (1
    /// GFLOPS) processor on the host side and one fast (3 GFLOPS) on the
    /// device side — every distinct processor pair crosses the link.
    fn het_machine_two_spaces() -> Machine {
        let mut b = MachineBuilder::new("het");
        let h = b.space("host", u64::MAX);
        let g = b.space("dev", u64::MAX);
        b.main(h);
        b.connect(h, g, 1e-6, 1e9);
        let slow = b.proc_type("slow", 1.0, 0.1);
        let fast = b.proc_type("fast", 1.0, 0.1);
        b.processors(1, "s", slow, h);
        b.processors(1, "f", fast, g);
        b.build()
    }

    /// The canonical 10-task HEFT example topology (Topcuoglu et al. 2002,
    /// Fig. 2), rebuilt from region overlaps: task i writes its own band
    /// `r[i]` and an edge i → j exists iff j reads `r[i]`. Band edges vary
    /// per task, so execution times and edge bytes differ across the DAG.
    ///
    /// Edges: 0→{1..5}, 1→{7,8}, 2→6, 3→{7,8}, 4→8, 5→7, {6,7,8}→9.
    fn topcuoglu_dag() -> TaskDag {
        let e: [u32; 10] = [40, 35, 30, 25, 20, 15, 30, 25, 20, 35];
        let r: Vec<Region> =
            e.iter().enumerate().map(|(i, &ei)| Region::new(0, 100 * i as u32, 100 * i as u32 + ei, 0, ei)).collect();
        let big = Region::new(0, 0, 1000, 0, 1000);
        let mut dag = TaskDag::new(TaskSpec::new(TaskKind::Gemm, vec![big], vec![big]));
        let spec = |reads: Vec<Region>, w: usize| TaskSpec::new(TaskKind::Gemm, reads, vec![r[w]]);
        dag.partition(
            0,
            vec![
                spec(vec![], 0),
                spec(vec![r[0]], 1),
                spec(vec![r[0]], 2),
                spec(vec![r[0]], 3),
                spec(vec![r[0]], 4),
                spec(vec![r[0]], 5),
                spec(vec![r[2]], 6),
                spec(vec![r[1], r[3], r[5]], 7),
                spec(vec![r[1], r[3], r[4]], 8),
                spec(vec![r[6], r[7], r[8]], 9),
            ],
            100,
        );
        dag
    }

    #[test]
    fn mean_comm_cost_averages_over_processor_pairs() {
        // single-space machine: no links, no communication term at all
        assert_eq!(mean_comm_cost(&machine_two_types()), (0.0, 0.0));
        // 1+1 procs across one link: both ordered pairs cross it
        let (lat, spb) = mean_comm_cost(&het_machine_two_spaces());
        assert!((lat - 1e-6).abs() < 1e-18);
        assert!((spb - 1e-9).abs() < 1e-21);
        // 2 host + 1 device procs: 4 of the 6 ordered pairs cross
        let mut b = MachineBuilder::new("w");
        let h = b.space("host", u64::MAX);
        let g = b.space("dev", u64::MAX);
        b.main(h);
        b.connect(h, g, 3e-6, 2e9);
        let t = b.proc_type("t", 1.0, 0.1);
        b.processors(2, "h", t, h);
        b.processors(1, "d", t, g);
        let (lat, spb) = mean_comm_cost(&b.build());
        assert!((lat - 4.0 * 3e-6 / 6.0).abs() < 1e-18);
        assert!((spb - 4.0 / 2e9 / 6.0).abs() < 1e-21);
    }

    #[test]
    fn edge_bytes_is_write_read_overlap_area() {
        let dag = topcuoglu_dag();
        let flat = dag.flat_dag();
        // edge 0 → 1 carries r[0] (40x40 elements) at 4 B/elem
        let (t0, t1) = (dag.task(flat.tasks[0]), dag.task(flat.tasks[1]));
        assert_eq!(edge_bytes(t0, t1, 4), 40 * 40 * 4);
        // no edge 1 → 2: disjoint bands share no bytes
        assert_eq!(edge_bytes(dag.task(flat.tasks[1]), dag.task(flat.tasks[2]), 4), 0);
    }

    #[test]
    fn upward_ranks_without_links_equal_critical_times() {
        let dag = chain_dag();
        let flat = dag.flat_dag();
        let m = machine_two_types();
        let ct = critical_times(&dag, &flat, &m, &db());
        let ru = upward_ranks(&dag, &flat, &m, &db(), 8);
        for (a, b) in ru.iter().zip(&ct) {
            assert_eq!(a, b, "single-space machine: comm terms must vanish");
        }
    }

    #[test]
    fn upward_ranks_match_hand_computed_topcuoglu_dag() {
        // Hand computation: w̄_i = 2e_i³·(1/1 + 1/3)/2 ns, edge cost
        // c̄_ij = 1 µs + 4e_i²·(1 ns/B... 1/1e9 s/B), rank_u backflow.
        let dag = topcuoglu_dag();
        let flat = dag.flat_dag();
        let m = het_machine_two_spaces();
        let ranks = upward_ranks(&dag, &flat, &m, &db(), 4);
        let expect = [
            2.373000000000e-4,
            1.445666666667e-4,
            1.383666666667e-4,
            1.058333333333e-4,
            8.370000000000e-5,
            8.790000000000e-5,
            9.776666666667e-5,
            8.150000000000e-5,
            7.043333333333e-5,
            5.716666666667e-5,
        ];
        for (i, (got, want)) in ranks.iter().zip(&expect).enumerate() {
            assert!((got - want).abs() < 1e-12, "rank_u[{i}] = {got}, want {want}");
        }
        // the classic HEFT ordering for this instance
        let mut order: Vec<usize> = (0..10).collect();
        order.sort_by(|&a, &b| ranks[b].total_cmp(&ranks[a]));
        assert_eq!(order, [0, 1, 2, 3, 6, 5, 4, 7, 8, 9]);
    }

    #[test]
    fn oct_matches_hand_computed_topcuoglu_dag() {
        let dag = topcuoglu_dag();
        let flat = dag.flat_dag();
        let m = het_machine_two_spaces();
        let oct = oct_table(&dag, &flat, &m, &db(), 4);
        let expect0 = [
            7.498333333333e-5,
            4.490000000000e-5,
            5.118333333333e-5,
            4.250000000000e-5,
            3.651666666667e-5,
            4.090000000000e-5,
            3.318333333333e-5,
            3.208333333333e-5,
            3.118333333333e-5,
            0.0,
        ];
        let expect1 = [
            6.758333333333e-5,
            3.900000000000e-5,
            4.658333333333e-5,
            3.900000000000e-5,
            3.391666666667e-5,
            3.900000000000e-5,
            2.858333333333e-5,
            2.858333333333e-5,
            2.858333333333e-5,
            0.0,
        ];
        for i in 0..10 {
            assert!((oct[i][0] - expect0[i]).abs() < 1e-12, "OCT[{i}][slow] = {}, want {}", oct[i][0], expect0[i]);
            assert!((oct[i][1] - expect1[i]).abs() < 1e-12, "OCT[{i}][fast] = {}, want {}", oct[i][1], expect1[i]);
        }
        // rank_oct = processor-count-weighted mean of the row (1+1 procs)
        let ranks = oct_ranks(&m, &oct);
        for i in 0..10 {
            let want = (expect0[i] + expect1[i]) / 2.0;
            assert!((ranks[i] - want).abs() < 1e-12, "rank_oct[{i}] = {}, want {want}", ranks[i]);
        }
        // exit task is optimistically free everywhere
        assert_eq!(oct[9], vec![0.0, 0.0]);
    }
}
