//! Critical-time computation for the Priority-List ordering.
//!
//! Paper §2.1: "a priority list is built by sorting tasks by their critical
//! times in decreasing order. Critical times are computed by averaging task
//! processing time for all processors, and propagating them throughout the
//! task DAG by a backflow algorithm" — i.e. the upward rank of HEFT,
//! without transfer terms (HeSP folds transfer awareness into EFT-P).

use super::perfmodel::PerfDb;
use super::platform::Machine;
use super::taskdag::{FlatDag, TaskDag};

/// Average execution time of each frontier task across all processors.
pub fn avg_times(dag: &TaskDag, flat: &FlatDag, machine: &Machine, db: &PerfDb) -> Vec<f64> {
    let ptypes: Vec<usize> = machine.procs.iter().map(|p| p.ptype).collect();
    flat.tasks
        .iter()
        .map(|&tid| {
            let t = dag.task(tid);
            db.avg_time(&ptypes, t.kind, t.char_edge(), t.flops)
        })
        .collect()
}

/// Backflow critical times: `ct[i] = avg[i] + max over successors ct[s]`.
/// Program order is a topological order, so one reverse sweep suffices.
pub fn critical_times(dag: &TaskDag, flat: &FlatDag, machine: &Machine, db: &PerfDb) -> Vec<f64> {
    let avg = avg_times(dag, flat, machine, db);
    let mut ct = vec![0.0f64; flat.len()];
    for i in (0..flat.len()).rev() {
        let down = flat.succs[i].iter().map(|&s| ct[s]).fold(0.0f64, f64::max);
        ct[i] = avg[i] + down;
    }
    ct
}

/// Positions (into the frontier) of tasks on a critical path: start from a
/// source with maximal critical time and walk successors greedily.
pub fn critical_path(flat: &FlatDag, ct: &[f64]) -> Vec<usize> {
    if flat.is_empty() {
        return Vec::new();
    }
    let mut cur = (0..flat.len())
        .filter(|&i| flat.preds[i].is_empty())
        .max_by(|&a, &b| ct[a].total_cmp(&ct[b]))
        .unwrap();
    let mut path = vec![cur];
    while let Some(&next) = flat.succs[cur].iter().max_by(|&&a, &&b| ct[a].total_cmp(&ct[b])) {
        path.push(next);
        cur = next;
    }
    path
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::perfmodel::PerfCurve;
    use crate::coordinator::platform::MachineBuilder;
    use crate::coordinator::region::Region;
    use crate::coordinator::task::{TaskKind, TaskSpec};

    fn machine_two_types() -> Machine {
        let mut b = MachineBuilder::new("m");
        let h = b.space("host", u64::MAX);
        b.main(h);
        let slow = b.proc_type("slow", 1.0, 0.1);
        let fast = b.proc_type("fast", 1.0, 0.1);
        b.processors(1, "s", slow, h);
        b.processors(1, "f", fast, h);
        b.build()
    }

    fn db() -> PerfDb {
        let mut db = PerfDb::new();
        db.set_fallback(0, PerfCurve::Const { gflops: 1.0 });
        db.set_fallback(1, PerfCurve::Const { gflops: 3.0 });
        db
    }

    fn chain_dag() -> TaskDag {
        // t0 -> t1 -> t2 over the same region
        let r = Region::new(0, 0, 100, 0, 100);
        let mut dag = TaskDag::new(TaskSpec::new(TaskKind::Potrf, vec![r], vec![r]));
        dag.partition(0, vec![TaskSpec::new(TaskKind::Gemm, vec![r], vec![r]); 3], 100);
        dag
    }

    #[test]
    fn critical_times_accumulate_backwards() {
        let dag = chain_dag();
        let flat = dag.flat_dag();
        let m = machine_two_types();
        let ct = critical_times(&dag, &flat, &m, &db());
        // per-task avg time: flops = 2*100^3 = 2e6 flops; rates 1 and 3
        // GFLOPS -> times 2e-3 and 2e-3/3; avg = (2e-3 + 6.667e-4)/2
        let avg = (2e-3 + 2e-3 / 3.0) / 2.0;
        assert!((ct[2] - avg).abs() < 1e-12);
        assert!((ct[1] - 2.0 * avg).abs() < 1e-12);
        assert!((ct[0] - 3.0 * avg).abs() < 1e-12);
        // decreasing along the chain => PL order is program order here
        assert!(ct[0] > ct[1] && ct[1] > ct[2]);
    }

    #[test]
    fn critical_path_follows_heavy_branch() {
        // diamond: t0 -> {t1 heavy, t2 light} -> t3
        let w = Region::new(0, 0, 8, 0, 8);
        let heavy = Region::new(0, 0, 4, 0, 4);
        let light = Region::new(0, 4, 8, 4, 8);
        let mut dag = TaskDag::new(TaskSpec::new(TaskKind::Potrf, vec![w], vec![w]));
        dag.partition(
            0,
            vec![
                TaskSpec::new(TaskKind::Gemm, vec![], vec![w]),
                TaskSpec::new(TaskKind::Gemm, vec![heavy], vec![heavy]), // 2*64 flops
                TaskSpec::new(TaskKind::Trsm, vec![light], vec![light]), // 64 flops
                TaskSpec::new(TaskKind::Gemm, vec![w], vec![w]),
            ],
            4,
        );
        let flat = dag.flat_dag();
        let m = machine_two_types();
        let ct = critical_times(&dag, &flat, &m, &db());
        let path = critical_path(&flat, &ct);
        assert_eq!(path.first(), Some(&0));
        assert_eq!(path.last(), Some(&3));
        assert!(path.contains(&1), "heavy branch on critical path: {path:?}");
        assert!(!path.contains(&2));
    }
}
