//! Deterministic fault models for the event core: seeded fail-stop
//! processor deaths, transient per-attempt task faults, throttle windows
//! that slow a processor over an interval, and link outage/degradation
//! windows.
//!
//! Every stochastic draw is content-derived ([`content_seed`] over the
//! spec's name/seed plus the drawing coordinates), so a fault trace
//! replays bit-for-bit at any `--threads` count and on any grid axis
//! ordering — the same determinism contract as [`super::sweep::cell_seed`]
//! and the portfolio solver's lane seeds.
//!
//! A [`FaultSpec`] is the declarative description (parsed from a TOML
//! file, `hesp ... --faults SPEC.toml`); a [`FaultPlan`] is one concrete
//! instantiation — an *ensemble member* — whose transient draws depend on
//! the member index. Explicit entries (fail-stop instants, throttle and
//! outage windows) are fixed across members; only the per-attempt
//! transient rolls vary, which is what the solver's expected-makespan
//! pricing ([`super::solver::PortfolioConfig::faults`]) averages over.

use super::platform::ProcId;
use super::task::TaskId;
use crate::util::fxhash::content_seed;
use crate::util::rng::Rng;
use crate::util::toml::{parse as toml_parse, Toml};

/// Default bound on executions per task (1 initial + 2 retries).
pub const DEFAULT_MAX_ATTEMPTS: u32 = 3;

/// A fail-stop processor death at `at`, optionally healed at `restore`.
/// Work in flight at `at` is lost past that instant; work booked later is
/// cancelled and re-dispatched by the policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailStop {
    pub proc: ProcId,
    pub at: f64,
    /// `None` = the processor never comes back.
    pub restore: Option<f64>,
}

/// A rate-multiplier window: over `[from, to)` the processor executes at
/// `factor` of its nominal speed (`0 < factor <= 1`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ThrottleWindow {
    pub proc: ProcId,
    pub from: f64,
    pub to: f64,
    pub factor: f64,
}

/// A link outage/degradation window: over `[from, to)` the link keeps
/// `factor` of its capacity (0 = full blackout). Modeled as a pre-booked
/// blackout of the lost fraction, so transfers deterministically route
/// around it via the normal earliest-fit arithmetic.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkOutage {
    pub link: usize,
    pub from: f64,
    pub to: f64,
    pub factor: f64,
}

/// The declarative fault model (one `--faults SPEC.toml` file).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    /// Label; enters every derived seed and the sweep CSV column.
    pub name: String,
    /// Base seed of the spec's stochastic draws.
    pub seed: u64,
    /// Per-attempt transient fault probability in `[0, 1]`: each attempt
    /// of each task fails independently with this rate (the attempt runs
    /// to completion but its writes are lost).
    pub transient_rate: f64,
    /// Executions allowed per task (first attempt included) before the
    /// run is declared failed (`makespan = INFINITY`).
    pub max_attempts: u32,
    pub fail_stop: Vec<FailStop>,
    pub throttle: Vec<ThrottleWindow>,
    pub link_outage: Vec<LinkOutage>,
}

impl FaultSpec {
    /// An empty (fault-free) spec under `name` — useful as the property-
    /// test identity: simulating with it must be byte-identical to not
    /// simulating with faults at all.
    pub fn named(name: &str) -> FaultSpec {
        FaultSpec {
            name: name.to_string(),
            seed: 0,
            transient_rate: 0.0,
            max_attempts: DEFAULT_MAX_ATTEMPTS,
            fail_stop: Vec::new(),
            throttle: Vec::new(),
            link_outage: Vec::new(),
        }
    }

    /// Whether no fault source is active.
    pub fn is_empty(&self) -> bool {
        self.transient_rate <= 0.0
            && self.fail_stop.is_empty()
            && self.throttle.is_empty()
            && self.link_outage.is_empty()
    }

    /// Parse a fault-spec TOML document:
    ///
    /// ```toml
    /// kind = "faults"        # marker so `hesp check` can sniff the file
    /// name = "quick"
    /// seed = 0               # optional
    ///
    /// [transient]            # optional
    /// rate = 0.05
    /// max_attempts = 4
    ///
    /// [[fail_stop]]
    /// proc = 1
    /// at = 0.004
    /// restore = 0.009        # optional; omitted = dead forever
    ///
    /// [[throttle]]
    /// proc = 0
    /// from = 0.002
    /// to = 0.006
    /// factor = 0.5           # rate multiplier in (0, 1]
    ///
    /// [[link_outage]]
    /// link = 0
    /// from = 0.001
    /// to = 0.003
    /// factor = 0.0           # optional capacity kept; 0 = blackout
    /// ```
    pub fn from_toml(text: &str) -> Result<FaultSpec, String> {
        let doc = toml_parse(text)?;
        let name = match doc.get("name").and_then(|v| v.as_str()) {
            Some(s) => s.to_string(),
            None => return Err("fault spec needs name = \"...\"".to_string()),
        };
        let seed = match doc.get("seed") {
            None => 0,
            Some(v) => match v.as_i64() {
                Some(x) if x >= 0 => x as u64,
                _ => return Err("seed must be a non-negative integer".to_string()),
            },
        };
        let num = |t: &Toml, key: &str, what: &str| -> Result<f64, String> {
            match t.get(key).and_then(|v| v.as_f64()) {
                Some(x) => Ok(x),
                None => Err(format!("{what} needs numeric {key} = ...")),
            }
        };
        let idx = |t: &Toml, key: &str, what: &str| -> Result<usize, String> {
            match t.get(key).and_then(|v| v.as_i64()) {
                Some(x) if x >= 0 => Ok(x as usize),
                _ => Err(format!("{what} needs non-negative integer {key} = ...")),
            }
        };
        let (transient_rate, max_attempts) = match doc.get("transient") {
            None => (0.0, DEFAULT_MAX_ATTEMPTS),
            Some(t) => {
                let rate = num(t, "rate", "[transient]")?;
                let ma = match t.get("max_attempts") {
                    None => DEFAULT_MAX_ATTEMPTS,
                    Some(v) => match v.as_i64() {
                        Some(x) if x >= 1 => x as u32,
                        _ => return Err("[transient] max_attempts must be >= 1".to_string()),
                    },
                };
                (rate, ma)
            }
        };
        let mut fail_stop = Vec::new();
        if let Some(entries) = doc.get("fail_stop").and_then(|v| v.as_table_arr()) {
            for t in entries {
                let restore = match t.get("restore") {
                    None => None,
                    Some(v) => match v.as_f64() {
                        Some(x) => Some(x),
                        None => return Err("[[fail_stop]] restore must be numeric".to_string()),
                    },
                };
                fail_stop.push(FailStop {
                    proc: idx(t, "proc", "[[fail_stop]]")?,
                    at: num(t, "at", "[[fail_stop]]")?,
                    restore,
                });
            }
        }
        let mut throttle = Vec::new();
        if let Some(entries) = doc.get("throttle").and_then(|v| v.as_table_arr()) {
            for t in entries {
                throttle.push(ThrottleWindow {
                    proc: idx(t, "proc", "[[throttle]]")?,
                    from: num(t, "from", "[[throttle]]")?,
                    to: num(t, "to", "[[throttle]]")?,
                    factor: num(t, "factor", "[[throttle]]")?,
                });
            }
        }
        let mut link_outage = Vec::new();
        if let Some(entries) = doc.get("link_outage").and_then(|v| v.as_table_arr()) {
            for t in entries {
                let factor = match t.get("factor") {
                    None => 0.0,
                    Some(v) => match v.as_f64() {
                        Some(x) => x,
                        None => return Err("[[link_outage]] factor must be numeric".to_string()),
                    },
                };
                link_outage.push(LinkOutage {
                    link: idx(t, "link", "[[link_outage]]")?,
                    from: num(t, "from", "[[link_outage]]")?,
                    to: num(t, "to", "[[link_outage]]")?,
                    factor,
                });
            }
        }
        let spec = FaultSpec { name, seed, transient_rate, max_attempts, fail_stop, throttle, link_outage };
        let errs: Vec<String> =
            spec.diagnostics().into_iter().map(|(k, m)| format!("{k}: {m}")).collect();
        if errs.is_empty() {
            Ok(spec)
        } else {
            Err(errs.join("\n"))
        }
    }

    /// [`FaultSpec::from_toml`] on a file.
    pub fn from_file(path: &str) -> Result<FaultSpec, String> {
        match std::fs::read_to_string(path) {
            Ok(text) => FaultSpec::from_toml(&text).map_err(|e| format!("{path}: {e}")),
            Err(e) => Err(format!("{path}: {e}")),
        }
    }

    /// Collect every internal-consistency problem as `(key, message)`
    /// pairs — the `hesp check` hook. Processor/link indices are range-
    /// checked against a machine only at install time (a spec file is
    /// platform-independent), so only shape problems surface here.
    pub fn diagnostics(&self) -> Vec<(String, String)> {
        let mut out = Vec::new();
        if self.name.is_empty() {
            out.push(("name".to_string(), "fault spec name must be non-empty".to_string()));
        }
        if !(0.0..=1.0).contains(&self.transient_rate) {
            out.push((
                "transient.rate".to_string(),
                format!("transient rate {} outside [0, 1]", self.transient_rate),
            ));
        }
        if self.max_attempts < 1 {
            out.push(("transient.max_attempts".to_string(), "max_attempts must be >= 1".to_string()));
        }
        for (i, f) in self.fail_stop.iter().enumerate() {
            if !f.at.is_finite() || f.at < 0.0 {
                out.push((format!("fail_stop.{i}"), format!("death instant {} must be finite and >= 0", f.at)));
            }
            if let Some(r) = f.restore {
                if !r.is_finite() || r <= f.at {
                    out.push((format!("fail_stop.{i}"), format!("restore {} must be finite and after at {}", r, f.at)));
                }
            }
        }
        // a processor may die at most once: overlapping dead windows have
        // no sensible kill/restore semantics
        for (i, a) in self.fail_stop.iter().enumerate() {
            for b in self.fail_stop.iter().skip(i + 1) {
                if a.proc == b.proc {
                    let a_end = a.restore.unwrap_or(f64::INFINITY);
                    let b_end = b.restore.unwrap_or(f64::INFINITY);
                    if a.at < b_end && b.at < a_end {
                        out.push((
                            format!("fail_stop.{i}"),
                            format!("dead windows of processor {} overlap", a.proc),
                        ));
                    }
                }
            }
        }
        for (i, w) in self.throttle.iter().enumerate() {
            if !w.from.is_finite() || !w.to.is_finite() || w.from < 0.0 || w.to <= w.from {
                out.push((format!("throttle.{i}"), format!("window [{}, {}] is malformed", w.from, w.to)));
            }
            if !(w.factor > 0.0 && w.factor <= 1.0) {
                out.push((
                    format!("throttle.{i}"),
                    format!("factor {} outside (0, 1] — 0 would stall work forever; use [[fail_stop]] for death", w.factor),
                ));
            }
        }
        // the duration walk assumes per-processor throttle windows are
        // disjoint (overlapping multipliers are ambiguous anyway)
        for (i, a) in self.throttle.iter().enumerate() {
            for b in self.throttle.iter().skip(i + 1) {
                if a.proc == b.proc && a.from < b.to && b.from < a.to {
                    out.push((
                        format!("throttle.{i}"),
                        format!("throttle windows of processor {} overlap", a.proc),
                    ));
                }
            }
        }
        for (i, o) in self.link_outage.iter().enumerate() {
            if !o.from.is_finite() || !o.to.is_finite() || o.from < 0.0 || o.to <= o.from {
                out.push((format!("link_outage.{i}"), format!("window [{}, {}] is malformed", o.from, o.to)));
            }
            if !(0.0..=1.0).contains(&o.factor) {
                out.push((format!("link_outage.{i}"), format!("factor {} outside [0, 1]", o.factor)));
            }
        }
        out
    }
}

/// One concrete instantiation of a [`FaultSpec`]: ensemble member
/// `member`'s transient draws, plus the spec's explicit windows. Cheap to
/// clone (the spec's vectors are small).
#[derive(Debug, Clone)]
pub struct FaultPlan {
    pub spec: FaultSpec,
    /// Content-derived seed of this member's stochastic draws.
    pub draw_seed: u64,
}

impl FaultPlan {
    pub fn new(spec: &FaultSpec, member: u64) -> FaultPlan {
        let draw_seed = content_seed(&["fault-ensemble", &spec.name], &[spec.seed, member]);
        FaultPlan { spec: spec.clone(), draw_seed }
    }

    pub fn max_attempts(&self) -> u32 {
        self.spec.max_attempts.max(1)
    }

    /// Deterministic transient roll: does attempt `attempt` of `task`
    /// fault? A pure function of (plan seed, task id, attempt) — thread
    /// count, dispatch order and wall clock never enter.
    pub fn transient_hits(&self, task: TaskId, attempt: u32) -> bool {
        if self.spec.transient_rate <= 0.0 {
            return false;
        }
        let draw = Rng::new(content_seed(&["transient-fault"], &[self.draw_seed, task as u64, attempt as u64]))
            .next_f64();
        draw < self.spec.transient_rate
    }

    /// Dead windows `[at, restore)` of `proc`, sorted by start
    /// (`INFINITY` end = never restored).
    pub fn dead_windows(&self, proc: ProcId) -> Vec<(f64, f64)> {
        let mut v: Vec<(f64, f64)> = self
            .spec
            .fail_stop
            .iter()
            .filter(|f| f.proc == proc)
            .map(|f| (f.at, f.restore.unwrap_or(f64::INFINITY)))
            .collect();
        v.sort_by(|a, b| a.0.total_cmp(&b.0));
        v
    }

    /// Wall-clock duration of `nominal` seconds of nominal-speed work
    /// started at `start` on `proc`, walking the processor's throttle
    /// windows (inside a window, work proceeds at `factor` speed).
    pub fn exec_duration(&self, proc: ProcId, start: f64, nominal: f64) -> f64 {
        if !start.is_finite() || nominal <= 0.0 {
            return nominal;
        }
        let mut wins: Vec<(f64, f64, f64)> = self
            .spec
            .throttle
            .iter()
            .filter(|w| w.proc == proc)
            .map(|w| (w.from, w.to, w.factor))
            .collect();
        if wins.is_empty() {
            return nominal;
        }
        wins.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut t = start;
        let mut work = nominal;
        for (from, to, factor) in wins {
            if work <= 0.0 || to <= t {
                continue;
            }
            if t < from {
                // full speed up to the window
                let span = (from - t).min(work);
                work -= span;
                t += span;
                if work <= 0.0 {
                    break;
                }
            }
            if t < to {
                // inside the window: `factor` seconds of work per second
                let capacity = (to - t) * factor;
                if work <= capacity {
                    t += work / factor;
                    work = 0.0;
                    break;
                }
                work -= capacity;
                t = to;
            }
        }
        if work > 0.0 {
            t += work;
        }
        t - start
    }
}

/// The solver's fault-aware objective configuration: average candidate
/// makespans over `members` independent [`FaultPlan`]s of one spec.
#[derive(Debug, Clone)]
pub struct FaultEnsemble {
    pub spec: FaultSpec,
    pub members: u64,
}

impl FaultEnsemble {
    pub fn new(spec: FaultSpec, members: u64) -> FaultEnsemble {
        FaultEnsemble { spec, members: members.max(1) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: &str = r#"
kind = "faults"
name = "quick"
seed = 7

[transient]
rate = 0.05
max_attempts = 4

[[fail_stop]]
proc = 1
at = 0.004
restore = 0.009

[[fail_stop]]
proc = 2
at = 0.5

[[throttle]]
proc = 0
from = 0.002
to = 0.006
factor = 0.5

[[link_outage]]
link = 0
from = 0.001
to = 0.003
"#;

    #[test]
    fn spec_round_trips_from_toml() {
        let s = FaultSpec::from_toml(SPEC).unwrap();
        assert_eq!(s.name, "quick");
        assert_eq!(s.seed, 7);
        assert_eq!(s.transient_rate, 0.05);
        assert_eq!(s.max_attempts, 4);
        assert_eq!(s.fail_stop.len(), 2);
        assert_eq!(s.fail_stop[0], FailStop { proc: 1, at: 0.004, restore: Some(0.009) });
        assert_eq!(s.fail_stop[1].restore, None);
        assert_eq!(s.throttle.len(), 1);
        assert_eq!(s.link_outage, vec![LinkOutage { link: 0, from: 0.001, to: 0.003, factor: 0.0 }]);
        assert!(!s.is_empty());
        assert!(FaultSpec::named("x").is_empty());
    }

    #[test]
    fn malformed_specs_are_rejected_with_keys() {
        assert!(FaultSpec::from_toml("seed = 1\n").unwrap_err().contains("name"));
        let bad_rate = SPEC.replace("rate = 0.05", "rate = 1.5");
        assert!(FaultSpec::from_toml(&bad_rate).unwrap_err().contains("transient.rate"));
        let bad_restore = SPEC.replace("restore = 0.009", "restore = 0.001");
        assert!(FaultSpec::from_toml(&bad_restore).unwrap_err().contains("fail_stop.0"));
        let bad_factor = SPEC.replace("factor = 0.5", "factor = 0.0");
        assert!(FaultSpec::from_toml(&bad_factor).unwrap_err().contains("throttle.0"));
        let overlap = format!("{SPEC}\n[[throttle]]\nproc = 0\nfrom = 0.003\nto = 0.004\nfactor = 0.9\n");
        assert!(FaultSpec::from_toml(&overlap).unwrap_err().contains("overlap"));
        let double_death = format!("{SPEC}\n[[fail_stop]]\nproc = 1\nat = 0.005\n");
        assert!(FaultSpec::from_toml(&double_death).unwrap_err().contains("overlap"));
    }

    #[test]
    fn transient_draws_are_deterministic_and_member_dependent() {
        let s = FaultSpec::from_toml(SPEC).unwrap();
        let p0 = FaultPlan::new(&s, 0);
        let p0b = FaultPlan::new(&s, 0);
        let p1 = FaultPlan::new(&s, 1);
        assert_eq!(p0.draw_seed, p0b.draw_seed);
        assert_ne!(p0.draw_seed, p1.draw_seed);
        let mut differs = false;
        for task in 0..2000u64 {
            let t = task as TaskId;
            assert_eq!(p0.transient_hits(t, 0), p0b.transient_hits(t, 0), "task {task}");
            if p0.transient_hits(t, 0) != p1.transient_hits(t, 0) {
                differs = true;
            }
        }
        assert!(differs, "two ensemble members must draw different fault sets");
        // rate 0 never fires
        let calm = FaultSpec::named("calm");
        let p = FaultPlan::new(&calm, 0);
        assert!((0..100).all(|t| !p.transient_hits(t as TaskId, 0)));
    }

    #[test]
    fn transient_rate_is_roughly_respected() {
        let s = FaultSpec::from_toml(SPEC).unwrap();
        let p = FaultPlan::new(&s, 3);
        let hits = (0..10_000u64).filter(|&t| p.transient_hits(t as TaskId, 0)).count();
        let rate = hits as f64 / 10_000.0;
        assert!((rate - 0.05).abs() < 0.01, "empirical rate {rate} far from 0.05");
    }

    #[test]
    fn dead_windows_sorted_per_proc() {
        let s = FaultSpec::from_toml(SPEC).unwrap();
        let p = FaultPlan::new(&s, 0);
        assert_eq!(p.dead_windows(1), vec![(0.004, 0.009)]);
        assert_eq!(p.dead_windows(2), vec![(0.5, f64::INFINITY)]);
        assert!(p.dead_windows(0).is_empty());
    }

    #[test]
    fn exec_duration_walks_throttle_windows() {
        let s = FaultSpec::from_toml(SPEC).unwrap();
        let p = FaultPlan::new(&s, 0);
        // untouched processor: nominal
        assert_eq!(p.exec_duration(3, 0.0, 1e-3), 1e-3);
        // fully inside the half-speed window [0.002, 0.006): doubles
        assert!((p.exec_duration(0, 0.003, 1e-3) - 2e-3).abs() < 1e-15);
        // straddling the window end: 1 ms of work at half speed covers
        // only 0.5 ms of it by 0.006, the rest runs at full speed
        let d = p.exec_duration(0, 0.0055, 1e-3);
        assert!((d - (0.5e-3 + 0.75e-3)).abs() < 1e-12, "{d}");
        // starting before the window: full speed until 0.002
        let d = p.exec_duration(0, 0.0015, 1e-3);
        assert!((d - (0.5e-3 + 1.0e-3)).abs() < 1e-12, "{d}");
        // after the window: nominal again
        assert_eq!(p.exec_duration(0, 0.007, 1e-3), 1e-3);
    }

    #[test]
    fn ensemble_clamps_members() {
        let fe = FaultEnsemble::new(FaultSpec::named("x"), 0);
        assert_eq!(fe.members, 1);
    }
}
