//! Legacy scheduling-policy knobs (paper §2.1, "Task and data scheduling
//! heuristics"): processor-selection heuristics and task-ordering choices.
//! `PriorityList` + `EarliestFinish` is practically identical to HEFT
//! (Topcuoglu et al., 2002).
//!
//! **Deprecated shim.** These closed enums predate the pluggable policy
//! layer; they are kept so `SimConfig::new(SchedConfig::new(..))` call
//! sites keep compiling, and they now only *name* built-in trait impls:
//! every execution path dispatches through
//! [`super::policy::SchedPolicy`]. New code should construct policies via
//! [`super::policy::PolicyRegistry`] (e.g. `registry.get("pl/eft-p")`);
//! new heuristics should implement the trait rather than extend these
//! enums.

/// Processor-selection heuristics.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ProcSelect {
    /// R-P: random among processors idle at the task's release time.
    Random,
    /// F-P: fastest (for this task) among idle processors at release time.
    Fastest,
    /// EIT-P: the processor becoming idle first.
    EarliestIdle,
    /// EFT-P: the processor finishing this task first, accounting for
    /// eventual data transfers.
    EarliestFinish,
}

impl ProcSelect {
    pub fn name(&self) -> &'static str {
        match self {
            ProcSelect::Random => "R-P",
            ProcSelect::Fastest => "F-P",
            ProcSelect::EarliestIdle => "EIT-P",
            ProcSelect::EarliestFinish => "EFT-P",
        }
    }

    pub fn from_name(s: &str) -> Option<ProcSelect> {
        Some(match s.to_ascii_lowercase().as_str() {
            "r-p" | "rp" | "random" => ProcSelect::Random,
            "f-p" | "fp" | "fastest" => ProcSelect::Fastest,
            "eit-p" | "eit" | "earliest-idle" => ProcSelect::EarliestIdle,
            "eft-p" | "eft" | "earliest-finish" => ProcSelect::EarliestFinish,
            _ => return None,
        })
    }

    pub const ALL: [ProcSelect; 4] =
        [ProcSelect::Random, ProcSelect::Fastest, ProcSelect::EarliestIdle, ProcSelect::EarliestFinish];
}

/// Task scheduling order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Ordering {
    /// First-come, first-served (release-time order).
    Fcfs,
    /// Priority list by decreasing critical time (backflow upward rank).
    PriorityList,
}

impl Ordering {
    pub fn name(&self) -> &'static str {
        match self {
            Ordering::Fcfs => "FCFS",
            Ordering::PriorityList => "PL",
        }
    }

    pub fn from_name(s: &str) -> Option<Ordering> {
        Some(match s.to_ascii_lowercase().as_str() {
            "fcfs" => Ordering::Fcfs,
            "pl" | "priority-list" | "priority" => Ordering::PriorityList,
            _ => return None,
        })
    }

    pub const ALL: [Ordering; 2] = [Ordering::Fcfs, Ordering::PriorityList];
}

/// One scheduling configuration row of Table 1, e.g. "PL/EFT-P".
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SchedConfig {
    pub ordering: Ordering,
    pub select: ProcSelect,
}

impl SchedConfig {
    pub fn new(ordering: Ordering, select: ProcSelect) -> SchedConfig {
        SchedConfig { ordering, select }
    }

    pub fn name(&self) -> String {
        format!("{}/{}", self.ordering.name(), self.select.name())
    }

    /// The eight rows of Table 1, in the paper's order.
    pub fn table1_rows() -> Vec<SchedConfig> {
        let mut out = Vec::new();
        for select in [ProcSelect::Random, ProcSelect::Fastest, ProcSelect::EarliestIdle, ProcSelect::EarliestFinish] {
            for ordering in [Ordering::Fcfs, Ordering::PriorityList] {
                out.push(SchedConfig::new(ordering, select));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for s in ProcSelect::ALL {
            assert_eq!(ProcSelect::from_name(s.name()), Some(s));
        }
        for o in Ordering::ALL {
            assert_eq!(Ordering::from_name(o.name()), Some(o));
        }
        assert_eq!(ProcSelect::from_name("zzz"), None);
    }

    #[test]
    fn table1_has_eight_rows() {
        let rows = SchedConfig::table1_rows();
        assert_eq!(rows.len(), 8);
        assert_eq!(rows[0].name(), "FCFS/R-P");
        assert_eq!(rows[7].name(), "PL/EFT-P");
    }
}
