//! Data coherence across memory spaces.
//!
//! Accelerator memories act as software caches of a main memory space
//! (paper §2.1). Validity is tracked per (block, memory-space) with
//! *geometric* validate/invalidate propagation over the data DAG:
//!
//! * before a task writes block `OB` in space `s`, every block intersecting
//!   `OB` is invalidated in every other space (stale), and blocks *strictly
//!   containing or partially overlapping* `OB` are invalidated in `s` too
//!   unless they were already valid there (a valid container stays valid —
//!   the new content lands inside it);
//! * after the write, `OB` and everything nested inside it are validated
//!   in `s` (top-down validation);
//! * a read of `B` in `s` hits if `B` is valid in `s`; otherwise a transfer
//!   is issued from a space holding a valid copy.
//!
//! Caching policies WT / WB / WA decide where written data additionally
//! lands. Finite space capacities are modeled with LRU eviction
//! (write-back of dirty victims).

use crate::util::fxhash::FxHashMap;

use super::datadag::{BlockId, DataDag};
use super::region::Region;

/// Caching policy for writes into non-main memory spaces (paper §2.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CachePolicy {
    /// Write-back: data stays in the writer's space, pushed out on demand.
    WriteBack,
    /// Write-through: every write is also propagated to main memory.
    WriteThrough,
    /// Write-around: the result bypasses the local cache, landing only in
    /// main memory.
    WriteAround,
}

impl CachePolicy {
    pub fn from_name(s: &str) -> Option<CachePolicy> {
        Some(match s {
            "wb" | "write-back" => CachePolicy::WriteBack,
            "wt" | "write-through" => CachePolicy::WriteThrough,
            "wa" | "write-around" => CachePolicy::WriteAround,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            CachePolicy::WriteBack => "wb",
            CachePolicy::WriteThrough => "wt",
            CachePolicy::WriteAround => "wa",
        }
    }
}

pub type SpaceId = usize;

/// A transfer the engine must account for (and time on the interconnect).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Transfer {
    pub block: BlockId,
    pub from: SpaceId,
    pub to: SpaceId,
    pub bytes: u64,
}

/// Coherence state: data DAG + per-space validity/dirty bitmasks + LRU.
#[derive(Debug, Clone)]
pub struct Coherence {
    pub dag: DataDag,
    /// valid[b] bit `s` set  =>  block b valid in space s.
    valid: Vec<u64>,
    /// dirty[b] bit `s`: block modified in s and not yet in main (WB).
    dirty: Vec<u64>,
    /// LRU clock per (space) and last-touch per (block, space).
    clock: u64,
    last_touch: Vec<FxHashMap<SpaceId, u64>>,
    /// Bytes currently accounted against each space.
    used: Vec<u64>,
    capacity: Vec<u64>,
    pub main: SpaceId,
    pub policy: CachePolicy,
    pub elem_bytes: u64,
    n_spaces: usize,
}

impl Coherence {
    /// `capacities[s]` in bytes (use `u64::MAX` for effectively-infinite
    /// spaces, e.g. host memory).
    pub fn new(n_spaces: usize, main: SpaceId, policy: CachePolicy, capacities: Vec<u64>, elem_bytes: u64) -> Coherence {
        assert!(n_spaces <= 64, "bitmask coherence supports <= 64 spaces");
        assert!(main < n_spaces);
        assert_eq!(capacities.len(), n_spaces);
        Coherence {
            dag: DataDag::new(),
            valid: Vec::new(),
            dirty: Vec::new(),
            clock: 0,
            last_touch: Vec::new(),
            used: vec![0; n_spaces],
            capacity: capacities,
            main,
            policy,
            elem_bytes,
            n_spaces,
        }
    }

    fn bytes_of(&self, b: BlockId) -> u64 {
        self.dag.block(b).region.area() * self.elem_bytes
    }

    /// Register a region, inheriting validity from covering blocks (a
    /// freshly-referenced sub-block is valid wherever some container is).
    pub fn register(&mut self, region: Region) -> BlockId {
        let before = self.dag.len();
        let id = self.dag.insert(region);
        // the insert may have created several nodes (intersections)
        for b in before..self.dag.len() {
            let mut mask = 0u64;
            for anc in self.dag.containing(&self.dag.block(b).region) {
                if anc != b && anc < self.valid.len() {
                    mask |= self.valid[anc];
                }
            }
            if mask == 0 {
                // initial data lives in main memory
                mask = 1 << self.main;
            }
            self.valid.push(mask);
            self.dirty.push(0);
            self.last_touch.push(FxHashMap::default());
        }
        id
    }

    pub fn is_valid(&self, b: BlockId, s: SpaceId) -> bool {
        self.valid[b] & (1 << s) != 0
    }

    pub fn is_dirty(&self, b: BlockId, s: SpaceId) -> bool {
        self.dirty[b] & (1 << s) != 0
    }

    /// Spaces holding a valid copy of `b`.
    pub fn holders(&self, b: BlockId) -> Vec<SpaceId> {
        (0..self.n_spaces).filter(|&s| self.is_valid(b, s)).collect()
    }

    /// Transfer needed (if any) so that `b` is readable in `s`, assuming a
    /// whole valid copy exists somewhere. Prefers main memory as source,
    /// then the lowest-id holder. Panics when the block only exists as
    /// scattered fragments — use [`Coherence::read_plan`] in that case.
    pub fn read_needs(&self, b: BlockId, s: SpaceId) -> Option<Transfer> {
        if self.is_valid(b, s) {
            return None;
        }
        let from = if self.is_valid(b, self.main) {
            self.main
        } else {
            self.holders(b).into_iter().next().unwrap_or_else(|| {
                panic!("block {b} ({}) valid nowhere", self.dag.block(b).region)
            })
        };
        Some(Transfer { block: b, from, to: s, bytes: self.bytes_of(b) })
    }

    /// Transfers needed so that the *content* of `b` is readable in `s`.
    ///
    /// Recursive partitioning can leave a coarse block valid nowhere as a
    /// whole — its content scattered over finer valid blocks in several
    /// spaces (the write-back of a sub-tile invalidates every ancestor
    /// elsewhere). The plan reassembles: greedily pick maximal valid
    /// fragments nested in `b`, transfer each missing one, and fetch any
    /// residual (area not covered by fragments — still the initial data)
    /// from main memory.
    pub fn read_plan(&self, b: BlockId, s: SpaceId) -> Vec<Transfer> {
        if self.is_valid(b, s) {
            return Vec::new();
        }
        if self.is_valid(b, self.main) || !self.holders(b).is_empty() {
            return vec![self.read_needs(b, s).unwrap()];
        }
        let region = self.dag.block(b).region;
        // maximal valid fragments, largest-first greedy cover
        let mut frags: Vec<BlockId> = self
            .dag
            .contained_in(&region)
            .into_iter()
            .filter(|&d| d != b && self.valid[d] != 0)
            .collect();
        frags.sort_by_key(|&d| std::cmp::Reverse(self.dag.block(d).region.area()));
        let mut chosen: Vec<BlockId> = Vec::new();
        let mut covered: u64 = 0;
        for d in frags {
            let dr = self.dag.block(d).region;
            if chosen.iter().any(|&c| self.dag.block(c).region.contains(&dr)) {
                continue;
            }
            chosen.push(d);
            covered += dr.area();
        }
        let mut out = Vec::new();
        for d in chosen {
            if self.is_valid(d, s) {
                continue; // fragment already local
            }
            let from = if self.is_valid(d, self.main) {
                self.main
            } else {
                self.holders(d)[0]
            };
            out.push(Transfer { block: d, from, to: s, bytes: self.bytes_of(d) });
        }
        // residual area untouched since initialization still lives in main
        let resid = region.area().saturating_sub(covered.min(region.area()));
        if resid > 0 && s != self.main {
            out.push(Transfer { block: b, from: self.main, to: s, bytes: resid * self.elem_bytes });
        }
        out
    }

    fn touch(&mut self, b: BlockId, s: SpaceId) {
        self.clock += 1;
        let c = self.clock;
        self.last_touch[b].insert(s, c);
    }

    fn set_valid(&mut self, b: BlockId, s: SpaceId) {
        if !self.is_valid(b, s) {
            self.valid[b] |= 1 << s;
            self.used[s] = self.used[s].saturating_add(self.bytes_of(b));
        }
        self.touch(b, s);
    }

    fn clear_valid(&mut self, b: BlockId, s: SpaceId) {
        if self.is_valid(b, s) {
            self.valid[b] &= !(1 << s);
            self.used[s] = self.used[s].saturating_sub(self.bytes_of(b));
        }
        self.dirty[b] &= !(1 << s);
    }

    /// Record completion of a read-transfer of `b` into `s`: `b` and all
    /// blocks nested inside it become valid in `s` (top-down validation).
    /// Returns eviction write-backs the engine must charge.
    pub fn complete_read(&mut self, b: BlockId, s: SpaceId) -> Vec<Transfer> {
        let region = self.dag.block(b).region;
        self.set_valid(b, s);
        for d in self.dag.contained_in(&region) {
            if d != b {
                self.set_valid(d, s);
            }
        }
        self.enforce_capacity(s, b)
    }

    /// Record that a task wrote block `b` while running in space `s`.
    /// Applies invalidation closure + policy, returning extra transfers
    /// (write-through pushes, write-around placement, evictions).
    pub fn complete_write(&mut self, b: BlockId, s: SpaceId) -> Vec<Transfer> {
        let region = self.dag.block(b).region;
        let mut out = Vec::new();

        // Invalidate every intersecting block everywhere else; in `s`,
        // invalidate overlapping-but-not-covering blocks that were not
        // already valid (a valid container absorbs the new content).
        for ob in self.dag.intersecting(&region) {
            for sp in 0..self.n_spaces {
                if sp != s && self.is_valid(ob, sp) {
                    self.clear_valid(ob, sp);
                }
            }
        }

        match self.policy {
            CachePolicy::WriteBack => {
                self.set_valid(b, s);
                if s != self.main {
                    self.dirty[b] |= 1 << s;
                }
                for d in self.dag.contained_in(&region) {
                    if d != b {
                        self.set_valid(d, s);
                        if s != self.main {
                            self.dirty[d] |= 1 << s;
                        }
                    }
                }
            }
            CachePolicy::WriteThrough => {
                self.set_valid(b, s);
                for d in self.dag.contained_in(&region) {
                    if d != b {
                        self.set_valid(d, s);
                    }
                }
                if s != self.main {
                    out.push(Transfer { block: b, from: s, to: self.main, bytes: self.bytes_of(b) });
                    self.set_valid(b, self.main);
                    for d in self.dag.contained_in(&region) {
                        if d != b {
                            self.set_valid(d, self.main);
                        }
                    }
                }
            }
            CachePolicy::WriteAround => {
                // the local cached copy (the stale input) is bypassed, not
                // updated — drop it so later local reads re-fetch from main
                for ob in self.dag.intersecting(&region) {
                    self.clear_valid(ob, s);
                }
                if s != self.main {
                    // result is streamed to main memory, local copy dropped
                    out.push(Transfer { block: b, from: s, to: self.main, bytes: self.bytes_of(b) });
                }
                self.set_valid(b, self.main);
                for d in self.dag.contained_in(&region) {
                    if d != b {
                        self.set_valid(d, self.main);
                    }
                }
            }
        }
        out.extend(self.enforce_capacity(s, b));
        out
    }

    /// LRU-evict valid blocks from `s` until usage fits capacity, never
    /// evicting `protect` (the block just used). Dirty victims generate
    /// write-back transfers and validate in main.
    fn enforce_capacity(&mut self, s: SpaceId, protect: BlockId) -> Vec<Transfer> {
        let mut out = Vec::new();
        if s == self.main {
            return out;
        }
        while self.used[s] > self.capacity[s] {
            // find LRU valid block in s
            let victim = (0..self.valid.len())
                .filter(|&b| b != protect && self.is_valid(b, s))
                .min_by_key(|&b| self.last_touch[b].get(&s).copied().unwrap_or(0));
            let Some(v) = victim else { break };
            if self.is_dirty(v, s) && self.holders(v) == vec![s] {
                // last copy is dirty: write back to main
                out.push(Transfer { block: v, from: s, to: self.main, bytes: self.bytes_of(v) });
                self.set_valid(v, self.main);
            }
            self.clear_valid(v, s);
        }
        out
    }

    pub fn used_bytes(&self, s: SpaceId) -> u64 {
        self.used[s]
    }

    pub fn n_spaces(&self) -> usize {
        self.n_spaces
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reg(r0: u32, r1: u32, c0: u32, c1: u32) -> Region {
        Region::new(0, r0, r1, c0, c1)
    }

    /// 3 spaces: 0 = main (infinite), 1 and 2 = accelerator caches.
    fn coh(policy: CachePolicy) -> Coherence {
        Coherence::new(3, 0, policy, vec![u64::MAX, 1 << 30, 1 << 30], 4)
    }

    #[test]
    fn initial_data_in_main() {
        let mut c = coh(CachePolicy::WriteBack);
        let b = c.register(reg(0, 8, 0, 8));
        assert!(c.is_valid(b, 0));
        assert!(!c.is_valid(b, 1));
        let t = c.read_needs(b, 1).unwrap();
        assert_eq!((t.from, t.to, t.bytes), (0, 1, 8 * 8 * 4));
        assert_eq!(c.read_needs(b, 0), None);
    }

    #[test]
    fn read_validates_descendants() {
        let mut c = coh(CachePolicy::WriteBack);
        let big = c.register(reg(0, 8, 0, 8));
        let small = c.register(reg(0, 4, 0, 4));
        c.complete_read(big, 1);
        assert!(c.is_valid(small, 1), "nested block valid after container fetched");
        assert_eq!(c.read_needs(small, 1), None);
    }

    #[test]
    fn late_registration_inherits_validity() {
        let mut c = coh(CachePolicy::WriteBack);
        let big = c.register(reg(0, 8, 0, 8));
        c.complete_read(big, 2);
        let small = c.register(reg(2, 4, 2, 4));
        assert!(c.is_valid(small, 2));
        assert!(c.is_valid(small, 0));
    }

    #[test]
    fn write_back_invalidates_other_spaces() {
        let mut c = coh(CachePolicy::WriteBack);
        let b = c.register(reg(0, 4, 0, 4));
        c.complete_read(b, 1);
        c.complete_read(b, 2);
        let extra = c.complete_write(b, 1);
        assert!(extra.is_empty());
        assert!(c.is_valid(b, 1));
        assert!(!c.is_valid(b, 0), "main copy stale after WB write in 1");
        assert!(!c.is_valid(b, 2));
        assert!(c.is_dirty(b, 1));
        // a read from space 2 must now source from space 1
        let t = c.read_needs(b, 2).unwrap();
        assert_eq!(t.from, 1);
    }

    #[test]
    fn write_invalidates_containers_elsewhere_keeps_own() {
        let mut c = coh(CachePolicy::WriteBack);
        let big = c.register(reg(0, 8, 0, 8));
        let small = c.register(reg(0, 4, 0, 4));
        c.complete_read(big, 1); // big + small valid in 1 (and main)
        c.complete_write(small, 1);
        assert!(c.is_valid(big, 1), "container in writer's space still valid");
        assert!(!c.is_valid(big, 0), "container stale in main");
        assert!(c.is_valid(small, 1));
        assert!(!c.is_valid(small, 0));
    }

    #[test]
    fn write_invalidates_partial_overlaps() {
        let mut c = coh(CachePolicy::WriteBack);
        let a = c.register(reg(0, 4, 0, 4));
        let b = c.register(reg(2, 6, 2, 6)); // partial overlap with a
        c.complete_read(a, 1);
        c.complete_read(b, 2);
        c.complete_write(a, 1);
        assert!(!c.is_valid(b, 2), "partially-overlapping block stale");
        assert!(!c.is_valid(b, 0));
    }

    #[test]
    fn write_validates_nested_blocks_top_down() {
        let mut c = coh(CachePolicy::WriteBack);
        let big = c.register(reg(0, 8, 0, 8));
        let small = c.register(reg(4, 8, 4, 8));
        c.complete_write(big, 1);
        assert!(c.is_valid(small, 1), "sub-block of written block valid in writer space");
        assert!(c.is_dirty(small, 1));
        assert!(!c.is_valid(small, 0));
        assert!(c.is_valid(big, 1));
    }

    #[test]
    fn write_through_pushes_to_main() {
        let mut c = coh(CachePolicy::WriteThrough);
        let b = c.register(reg(0, 4, 0, 4));
        let extra = c.complete_write(b, 1);
        assert_eq!(extra.len(), 1);
        assert_eq!((extra[0].from, extra[0].to), (1, 0));
        assert!(c.is_valid(b, 0) && c.is_valid(b, 1));
        assert!(!c.is_dirty(b, 1));
    }

    #[test]
    fn write_around_bypasses_cache() {
        let mut c = coh(CachePolicy::WriteAround);
        let b = c.register(reg(0, 4, 0, 4));
        let extra = c.complete_write(b, 1);
        assert_eq!(extra.len(), 1);
        assert!(c.is_valid(b, 0));
        assert!(!c.is_valid(b, 1), "WA leaves no local copy");
    }

    #[test]
    fn write_in_main_is_local() {
        for p in [CachePolicy::WriteBack, CachePolicy::WriteThrough, CachePolicy::WriteAround] {
            let mut c = coh(p);
            let b = c.register(reg(0, 4, 0, 4));
            let extra = c.complete_write(b, 0);
            assert!(extra.is_empty());
            assert!(c.is_valid(b, 0));
        }
    }

    #[test]
    fn lru_eviction_writes_back_dirty_last_copy() {
        // space 1 fits exactly one 4x4 block (64 bytes)
        let mut c = Coherence::new(2, 0, CachePolicy::WriteBack, vec![u64::MAX, 64], 4);
        let b1 = c.register(reg(0, 4, 0, 4));
        let b2 = c.register(reg(4, 8, 4, 8));
        c.complete_write(b1, 1); // dirty in 1, sole copy
        let ev = c.complete_read(b2, 1); // evicts b1
        assert_eq!(ev.len(), 1);
        assert_eq!((ev[0].block, ev[0].from, ev[0].to), (b1, 1, 0));
        assert!(c.is_valid(b1, 0), "written back to main");
        assert!(!c.is_valid(b1, 1));
        assert!(c.is_valid(b2, 1));
    }

    #[test]
    fn eviction_of_clean_block_is_silent() {
        let mut c = Coherence::new(2, 0, CachePolicy::WriteBack, vec![u64::MAX, 64], 4);
        let b1 = c.register(reg(0, 4, 0, 4));
        let b2 = c.register(reg(4, 8, 4, 8));
        c.complete_read(b1, 1);
        let ev = c.complete_read(b2, 1);
        assert!(ev.is_empty(), "clean eviction needs no write-back");
        assert!(!c.is_valid(b1, 1));
        assert!(c.is_valid(b1, 0));
    }

    #[test]
    fn rw_sequence_across_three_spaces() {
        // producer in GPU1, consumer in GPU2, verifier in main — the
        // canonical Cholesky panel flow.
        let mut c = coh(CachePolicy::WriteBack);
        let b = c.register(reg(0, 4, 0, 4));
        c.complete_read(b, 1);
        c.complete_write(b, 1);
        let t = c.read_needs(b, 2).unwrap();
        assert_eq!(t.from, 1);
        c.complete_read(b, 2);
        assert!(c.is_valid(b, 2));
        // write in 2, then main needs it from 2
        c.complete_write(b, 2);
        let t = c.read_needs(b, 0).unwrap();
        assert_eq!(t.from, 2);
        c.complete_read(b, 0);
        assert!(c.is_valid(b, 0));
    }

    #[test]
    fn safety_invariant_no_stale_read() {
        // After any write in s, no other space can read without a transfer
        // sourced (transitively) from s's version.
        let mut c = coh(CachePolicy::WriteBack);
        let big = c.register(reg(0, 8, 0, 8));
        let q = c.register(reg(0, 4, 0, 4));
        c.complete_read(big, 2);
        c.complete_write(q, 1);
        // q readable in 2 only via transfer from 1
        let t = c.read_needs(q, 2).unwrap();
        assert_eq!(t.from, 1);
        // big is no longer fully valid anywhere except nowhere — reading it
        // anywhere requires reassembly; HeSP reads it via its sub-blocks, so
        // holders(big) must be empty.
        assert!(c.holders(big).is_empty());
    }
}
