//! Task model: the nodes of the hierarchical task DAG.
//!
//! A task has a kind (the tile-algorithm it runs), a read set and a write
//! set of [`Region`]s, and a flop count derived from its geometry. Tasks
//! are stored in an arena ([`super::taskdag::TaskDag`]); a task is either a
//! *leaf* (schedulable) or *partitioned* into a cluster of children
//! produced by one of the blocked-algorithm partitioners.

use super::region::Region;

pub type TaskId = usize;

/// Tile-algorithm kinds. The first four are the Cholesky tasks of the
/// paper's driving example; LU and QR kinds support the extension
/// workloads; `Custom` lets library users register their own.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum TaskKind {
    /// Cholesky factorization of a diagonal tile (CHOL in the paper).
    Potrf,
    /// Triangular panel solve X L^T = B.
    Trsm,
    /// Symmetric trailing update C -= A A^T.
    Syrk,
    /// General trailing update C -= A B^T.
    Gemm,
    // ---- LU (no pivoting) extension workload ----
    Getrf,
    TrsmL,
    TrsmU,
    // ---- tile-QR extension workload ----
    Geqrt,
    Tsqrt,
    Larfb,
    Ssrfb,
    /// User-defined kind (index into a user registry).
    Custom(u16),
}

impl TaskKind {
    /// Stable short name (trace files, perf-model config keys).
    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::Potrf => "potrf",
            TaskKind::Trsm => "trsm",
            TaskKind::Syrk => "syrk",
            TaskKind::Gemm => "gemm",
            TaskKind::Getrf => "getrf",
            TaskKind::TrsmL => "trsm_l",
            TaskKind::TrsmU => "trsm_u",
            TaskKind::Geqrt => "geqrt",
            TaskKind::Tsqrt => "tsqrt",
            TaskKind::Larfb => "larfb",
            TaskKind::Ssrfb => "ssrfb",
            TaskKind::Custom(_) => "custom",
        }
    }

    pub fn from_name(s: &str) -> Option<TaskKind> {
        Some(match s {
            "potrf" | "chol" => TaskKind::Potrf,
            "trsm" => TaskKind::Trsm,
            "syrk" => TaskKind::Syrk,
            "gemm" => TaskKind::Gemm,
            "getrf" => TaskKind::Getrf,
            "trsm_l" => TaskKind::TrsmL,
            "trsm_u" => TaskKind::TrsmU,
            "geqrt" => TaskKind::Geqrt,
            "tsqrt" => TaskKind::Tsqrt,
            "larfb" => TaskKind::Larfb,
            "ssrfb" => TaskKind::Ssrfb,
            _ => return None,
        })
    }

    /// Flop count for a task of this kind whose characteristic tile edge is
    /// `b` (matches python/compile/aot.py::task_flops so simulated GFLOPS
    /// and real-execution GFLOPS are directly comparable).
    pub fn flops(&self, b: f64) -> f64 {
        let b3 = b * b * b;
        match self {
            TaskKind::Potrf => b3 / 3.0,
            TaskKind::Trsm | TaskKind::TrsmL | TaskKind::TrsmU => b3,
            // full-block symmetric update (kernels update the whole tile)
            TaskKind::Syrk => b3,
            TaskKind::Gemm => 2.0 * b3,
            TaskKind::Getrf => 2.0 * b3 / 3.0,
            TaskKind::Geqrt => 4.0 / 3.0 * b3,
            TaskKind::Tsqrt => 10.0 / 3.0 * b3,
            TaskKind::Larfb => 4.0 * b3,
            TaskKind::Ssrfb => 5.0 * b3,
            TaskKind::Custom(_) => b3,
        }
    }
}

/// Creation-time description of a task (the partitioners emit these; the
/// DAG assigns ids and derives dependence edges).
#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub kind: TaskKind,
    /// Regions read (input dependences).
    pub reads: Vec<Region>,
    /// Regions written (output dependences). A region in both sets is an
    /// in-out dependence (e.g. the C tile of a GEMM update).
    pub writes: Vec<Region>,
}

impl TaskSpec {
    pub fn new(kind: TaskKind, reads: Vec<Region>, writes: Vec<Region>) -> TaskSpec {
        TaskSpec { kind, reads, writes }
    }

    /// Characteristic tile edge: geometric mean edge of the first write
    /// region (every HeSP task has exactly one primary output tile).
    pub fn char_edge(&self) -> f64 {
        self.writes.first().map(|r| r.char_size()).unwrap_or(0.0)
    }

    pub fn flops(&self) -> f64 {
        self.kind.flops(self.char_edge())
    }
}

/// A node of the hierarchical task DAG.
#[derive(Debug, Clone)]
pub struct Task {
    pub id: TaskId,
    pub kind: TaskKind,
    pub reads: Vec<Region>,
    pub writes: Vec<Region>,
    pub flops: f64,
    /// Parent task this one was partitioned out of (None for the root).
    pub parent: Option<TaskId>,
    /// Children, in program order, if this task has been partitioned.
    /// `Some(vec)` makes this node a *cluster*; only leaves are scheduled.
    pub children: Option<Vec<TaskId>>,
    /// Nesting depth: number of task clusters containing this task
    /// (root = 0). Table 1's "DAG depth" is the max over leaves.
    pub depth: u32,
    /// Partition edge used when this cluster was created (diagnostics).
    pub partition_edge: Option<u32>,
}

impl Task {
    pub fn is_leaf(&self) -> bool {
        self.children.is_none()
    }

    /// Characteristic tile edge of this task (primary output geometry).
    pub fn char_edge(&self) -> f64 {
        self.writes.first().map(|r| r.char_size()).unwrap_or(0.0)
    }

    /// Bytes touched (reads + writes, dedup'd by region identity).
    pub fn bytes_touched(&self, elem_bytes: u64) -> u64 {
        let mut total = 0u64;
        let mut seen: Vec<&Region> = Vec::new();
        for r in self.reads.iter().chain(self.writes.iter()) {
            if !seen.contains(&r) {
                total += r.area() * elem_bytes;
                seen.push(r);
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::region::Region;

    fn reg(e: u32) -> Region {
        Region::new(0, 0, e, 0, e)
    }

    #[test]
    fn kind_names_roundtrip() {
        for k in [
            TaskKind::Potrf,
            TaskKind::Trsm,
            TaskKind::Syrk,
            TaskKind::Gemm,
            TaskKind::Getrf,
            TaskKind::TrsmL,
            TaskKind::TrsmU,
            TaskKind::Geqrt,
            TaskKind::Tsqrt,
            TaskKind::Larfb,
            TaskKind::Ssrfb,
        ] {
            assert_eq!(TaskKind::from_name(k.name()), Some(k));
        }
        assert_eq!(TaskKind::from_name("chol"), Some(TaskKind::Potrf));
        assert_eq!(TaskKind::from_name("nope"), None);
    }

    #[test]
    fn flops_match_aot_manifest_convention() {
        // python/compile/aot.py::task_flops for b=10: potrf 1000/3, trsm
        // 1000, syrk 1000, gemm 2000.
        assert!((TaskKind::Potrf.flops(10.0) - 1000.0 / 3.0).abs() < 1e-9);
        assert_eq!(TaskKind::Trsm.flops(10.0), 1000.0);
        assert_eq!(TaskKind::Syrk.flops(10.0), 1000.0);
        assert_eq!(TaskKind::Gemm.flops(10.0), 2000.0);
    }

    #[test]
    fn spec_edge_and_flops() {
        let s = TaskSpec::new(TaskKind::Gemm, vec![reg(64), reg(64)], vec![reg(64)]);
        assert_eq!(s.char_edge(), 64.0);
        assert_eq!(s.flops(), 2.0 * 64f64.powi(3));
    }

    #[test]
    fn bytes_touched_dedups_inout() {
        let t = Task {
            id: 0,
            kind: TaskKind::Syrk,
            reads: vec![reg(32), reg(32)], // duplicate read regions count once
            writes: vec![reg(32)],         // in-out with the read
            flops: 0.0,
            parent: None,
            children: None,
            depth: 0,
            partition_edge: None,
        };
        assert_eq!(t.bytes_touched(4), 32 * 32 * 4);
        let t2 = Task {
            reads: vec![Region::new(0, 0, 32, 32, 64)],
            ..t.clone()
        };
        assert_eq!(t2.bytes_touched(4), 2 * 32 * 32 * 4);
    }
}
