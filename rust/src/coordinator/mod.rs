//! The HeSP coordinator — the paper's contribution (§2).
//!
//! * [`region`] / [`datadag`] / [`coherence`]: recursive data blocks,
//!   nesting + intersection descriptors, validate/invalidate coherence.
//! * [`task`] / [`taskdag`]: the hierarchical task DAG with derived
//!   RaW/WaR/WaW dependences.
//! * [`platform`] / [`perfmodel`]: heterogeneous machine descriptions and
//!   per-(processor, task, size) performance + transfer models.
//! * [`engine`] / [`ordering`]: the discrete-event schedule simulator.
//! * [`lower_bound`]: critical-path / area makespan lower bounds — the
//!   optimality yardstick behind the sweep's `makespan_over_lb` column
//!   and the service layer's slowdown/deadline arithmetic.
//! * [`policy`]: the pluggable scheduling-policy layer — the
//!   [`policy::SchedPolicy`] trait, the [`policy::SchedContext`] decision-time
//!   view, and the string-keyed [`policy::PolicyRegistry`] (Table-1 rows
//!   `fcfs/r-p` ... `pl/eft-p` plus `pl/affinity`, `pl/lookahead`, and
//!   the job-aware `pl/edf-p` / `pl/sjf-p`).
//! * [`policies`]: the legacy `Ordering`/`ProcSelect` enums, kept as thin
//!   shims that map onto built-in `policy` impls.
//! * [`partitioners`]: blocked algorithms emitting sub-task clusters.
//! * [`solver`]: the iterative scheduler-partitioner (All/CP/Shallow x
//!   Hard/Soft), rebuilt as a parallel *portfolio* solver — K-candidate
//!   batched evaluation on cheap copy-on-write scratch DAGs plus M
//!   independent restart lanes with content-derived seeds, byte-identical
//!   output for any thread count.
//! * [`delta`]: incremental re-simulation for the portfolio solver —
//!   verified-prefix scans against the base run's decision log, affected-
//!   cone analysis over the candidate frontier, checkpoint selection for
//!   the event core's restore/replay path, and the frontier-keyed cost
//!   cache. Byte-identical to full re-simulation by construction; falls
//!   back to a full run whenever equivalence cannot be proven.
//! * [`validate`]: the schedule-invariant oracle — an independent checker
//!   (processor/link exclusivity, dependences, arrival gates, makespan)
//!   the solver runs on every accepted schedule in debug builds, plus the
//!   fault-run variant (dead-window exclusion, attempt accounting).
//! * [`faults`]: deterministic fault injection — seeded fail-stop,
//!   transient-attempt, throttle-window, and link-outage models the
//!   engine replays identically at any `--threads` count, with recovery
//!   via policy-driven rescheduling and a bounded attempt budget.
//! * [`constructive`]: the online per-task-arrival scheduler-partitioner
//!   (the paper's §4 follow-up).
//! * [`workloads`]: synthetic DAG generators beyond dense linear algebra.
//! * [`service`]: the streaming multi-DAG service layer — deterministic
//!   arrival processes, admission control, and a multi-job simulator
//!   co-scheduling concurrent `TaskDag`s on the shared event core, with
//!   sojourn/deadline/fairness metrics (the `hesp serve` subcommand).
//! * [`sweep`]: the parallel multi-scenario experiment harness — a
//!   declarative platform x workload x policy x tile x mode x seed grid
//!   expanded into cells and executed across scoped worker threads, with
//!   deterministic per-cell seeds (parallel runs are byte-identical to
//!   serial ones).
//! * [`metrics`] / [`energy`] / [`trace`]: Table-1 metrics, the energy
//!   objective, Paraver traces and ASCII Gantt rendering.

pub mod coherence;
pub mod constructive;
pub mod datadag;
pub mod delta;
pub mod energy;
pub mod engine;
pub mod faults;
pub mod lower_bound;
pub mod metrics;
pub mod ordering;
pub mod partitioners;
pub mod perfmodel;
pub mod platform;
pub mod policies;
pub mod policy;
pub mod region;
pub mod service;
pub mod solver;
pub mod sweep;
pub mod task;
pub mod taskdag;
pub mod trace;
pub mod validate;
pub mod workloads;
