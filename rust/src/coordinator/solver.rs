//! The iterative scheduler-partitioner (paper §2.1, "Iterative solver"),
//! rebuilt as a **parallel portfolio solver**.
//!
//! Each iteration runs a *schedule stage* (full discrete-event simulation
//! of the current hierarchical DAG) followed by a *partition stage*:
//!
//! 1. **Candidate selection** — `All` leaves, `CP` (leaves on the critical
//!    path), or `Shallow` (leaves of minimal cluster depth); every existing
//!    task cluster is additionally a candidate to be merged back (p = 1)
//!    or re-partitioned at a different granularity (p < 1).
//! 2. **Scoring** — each candidate's score is the current cost delay minus
//!    an estimated cost after the move, the estimate driven by the
//!    available parallelism (idle processors) around the candidate's
//!    scheduled interval.
//! 3. **Sampling** — `Hard` takes the max-score candidate; `Soft` samples
//!    with probability proportional to score.
//!
//! The solver keeps the best (dag, schedule) pair seen; the applied moves
//! walk the search space even through locally-worse states (Soft mode).
//!
//! ## Batched candidate evaluation
//!
//! Instead of blindly applying the one sampled move and discovering its
//! cost a full iteration later, each iteration samples a **batch of K
//! candidates** (`Hard`: top-K by score; `Soft`: K weighted draws without
//! replacement), evaluates every one on a scratch copy-on-write DAG clone
//! (apply → re-derive edges → [`simulate_flat_policy`]) and accepts the
//! lowest-finite-cost evaluation; the accepted evaluation *is* the next
//! iteration's schedule stage, so a batch of K costs K simulations, not
//! K + 1. A batch in which every candidate is rejected (partitioner
//! refusal or non-finite cost) leaves the DAG and the incumbent untouched
//! and is recorded in the [`IterLog`] (`evaluated == rejected`). With
//! `K = 1` the walk consumes exactly the classic loop's RNG draws and
//! applies the same actions; the two deliberate differences from the
//! pre-portfolio solver are that the final accepted state is also scored
//! (the old loop never simulated it, so `best` can only improve) and
//! that a non-finite evaluation is rejected instead of walked into.
//!
//! ## Restart portfolio
//!
//! [`solve_portfolio`] runs **M independent lanes** (restart trajectories)
//! concurrently: lane 0 uses the base seed, lanes 1.. derive distinct
//! SplitMix64 streams from *content* (base seed, lane index, policy /
//! sampling / candidate names — [`lane_seed`]), and lanes may override the
//! policy, sampling and candidate selection per [`LaneSpec`]. The best
//! finite-cost lane wins, ties broken toward the lower lane index, so the
//! returned [`SolveResult`] (history included) is **byte-identical for
//! any thread count**. Worker threads come from the same scoped-thread
//! machinery as the sweep harness ([`crate::util::par::par_map`]); the
//! budget is split lanes-first, leftover threads parallelize each lane's
//! batch. In debug builds every accepted schedule passes the
//! [`super::validate`] oracle.

use super::delta::{self, CostCache, DeltaBase, DeltaMode, DeltaPlan};
use super::energy::Objective;
use super::engine::{
    recycle_schedule, simulate_flat_faults, simulate_flat_policy, simulate_flat_replay,
    simulate_flat_traced, simulate_policy, Schedule, SimConfig, SimTrace,
};
use super::faults::{FaultEnsemble, FaultPlan};
use super::ordering::{critical_path, critical_times};
use super::partitioners::{snap_sub_edge, PartitionerSet};
use super::perfmodel::PerfDb;
use super::platform::Machine;
use super::policies::SchedConfig;
use super::policy::{self, PolicyRegistry, SchedPolicy};
use super::task::TaskId;
use super::taskdag::{FlatDag, TaskDag};
use crate::util::fxhash::content_seed;
use crate::util::par::par_map;
use crate::util::rng::Rng;

/// Which tasks enter the partition-candidate list (paper: All/CP/Shallow).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CandidateSelect {
    All,
    CriticalPath,
    Shallow,
}

impl CandidateSelect {
    pub fn from_name(s: &str) -> Option<CandidateSelect> {
        Some(match s.to_ascii_lowercase().as_str() {
            "all" => CandidateSelect::All,
            "cp" | "critical-path" => CandidateSelect::CriticalPath,
            "shallow" => CandidateSelect::Shallow,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            CandidateSelect::All => "All",
            CandidateSelect::CriticalPath => "CP",
            CandidateSelect::Shallow => "Shallow",
        }
    }
}

/// Final candidate choice (paper: Hard/Soft).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sampling {
    /// Take the maximum-score candidate.
    Hard,
    /// Sample proportionally to score.
    Soft,
}

impl Sampling {
    pub fn from_name(s: &str) -> Option<Sampling> {
        Some(match s.to_ascii_lowercase().as_str() {
            "hard" => Sampling::Hard,
            "soft" => Sampling::Soft,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Sampling::Hard => "Hard",
            Sampling::Soft => "Soft",
        }
    }
}

/// Solver configuration.
#[derive(Debug, Clone, Copy)]
pub struct SolverConfig {
    pub candidates: CandidateSelect,
    pub sampling: Sampling,
    /// Number of schedule+partition iterations.
    pub iters: usize,
    /// Never partition below this tile edge.
    pub min_edge: u32,
    pub objective: Objective,
    pub sim: SimConfig,
    pub seed: u64,
    /// Allow merge / re-partition moves on existing clusters.
    pub allow_merge: bool,
}

impl SolverConfig {
    /// The paper's main configuration: All/Soft, makespan objective.
    pub fn all_soft(sim: SimConfig, iters: usize, min_edge: u32) -> SolverConfig {
        SolverConfig {
            candidates: CandidateSelect::All,
            sampling: Sampling::Soft,
            iters,
            min_edge,
            objective: Objective::Makespan,
            sim,
            seed: 0x5e5f,
            allow_merge: true,
        }
    }
}

/// One move of the partition stage.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Action {
    Partition { task: TaskId, sub_edge: u32 },
    Merge { cluster: TaskId },
    Repartition { cluster: TaskId, sub_edge: u32 },
}

impl Action {
    /// Stable text form (iteration logs, the canonical solver JSON).
    pub fn label(&self) -> String {
        match *self {
            Action::Partition { task, sub_edge } => format!("partition:{task}:{sub_edge}"),
            Action::Merge { cluster } => format!("merge:{cluster}"),
            Action::Repartition { cluster, sub_edge } => format!("repartition:{cluster}:{sub_edge}"),
        }
    }
}

/// Per-iteration log entry.
#[derive(Debug, Clone)]
pub struct IterLog {
    pub iter: usize,
    /// Cost of the state this iteration *started* from.
    pub cost: f64,
    pub n_tasks: usize,
    /// The accepted move — or, when the whole batch was rejected, the
    /// primary (first-sampled) move that was attempted.
    pub action: Option<Action>,
    pub score: f64,
    /// Whether any sampled action actually mutated the DAG. A candidate
    /// whose apply step is rejected by the partitioner, or whose
    /// evaluated cost is non-finite, is *not* applied; an iteration whose
    /// entire batch was rejected logs `false` here (the DAG and the
    /// incumbent are left exactly as they were).
    pub applied: bool,
    /// Candidates sampled and evaluated this iteration (0 only when the
    /// candidate list was empty and the search stopped).
    pub evaluated: usize,
    /// Evaluated candidates that were rejected (partitioner refusal or
    /// non-finite evaluated cost).
    pub rejected: usize,
    /// Simulation decisions recovered from the base run by verified
    /// replay this iteration, summed over the batch (0 with delta
    /// evaluation off). Diagnostics only — never part of the canonical
    /// [`result_json`] bytes, which stay identical across delta modes.
    pub events_replayed: usize,
    /// Total simulation decisions the batch's simulated candidates
    /// carried (the denominator of the replay fraction).
    pub events_total: usize,
    /// Candidates answered from the lane's frontier-signature cost cache
    /// without running the engine at all.
    pub cache_hits: usize,
    /// Candidates that fell back to a full simulation while delta
    /// evaluation was requested (ineligible policy, unverifiable prefix).
    pub full_fallbacks: usize,
}

/// Solver output: best state found + full iteration history.
pub struct SolveResult {
    pub best_cost: f64,
    pub best_schedule: Schedule,
    pub best_dag: TaskDag,
    /// Iteration index at which `best_cost` first became the current
    /// state's cost (`cfg.iters` when the final accepted evaluation won).
    pub best_iter: usize,
    /// Portfolio lane that produced this result (0 for single-lane runs).
    pub lane: usize,
    /// Final best cost of every lane, in lane order (length 1 for
    /// [`solve`] / [`solve_with`]).
    pub lane_costs: Vec<f64>,
    /// Iteration history of the winning lane.
    pub history: Vec<IterLog>,
}

/// Aggregated incremental-evaluation counters of a solve (the winning
/// lane's history summed). Deterministic for any thread count, like the
/// history itself — but deliberately kept out of [`result_json`], whose
/// bytes must not depend on the delta mode.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ReplayStats {
    pub events_replayed: u64,
    pub events_total: u64,
    pub cache_hits: u64,
    pub full_fallbacks: u64,
}

impl ReplayStats {
    /// Fraction of candidate-simulation decision work skipped via
    /// verified replay (0.0 when nothing was delta-evaluated).
    pub fn replay_fraction(&self) -> f64 {
        if self.events_total == 0 {
            0.0
        } else {
            self.events_replayed as f64 / self.events_total as f64
        }
    }
}

impl SolveResult {
    /// Sum the per-iteration delta-evaluation counters of the winning
    /// lane (`hesp solve` prints these; the sweep CSV carries the
    /// fraction).
    pub fn replay_stats(&self) -> ReplayStats {
        let mut s = ReplayStats::default();
        for h in &self.history {
            s.events_replayed += h.events_replayed as u64;
            s.events_total += h.events_total as u64;
            s.cache_hits += h.cache_hits as u64;
            s.full_fallbacks += h.full_fallbacks as u64;
        }
        s
    }
}

/// Per-lane override of the portfolio's search knobs: a lane may run a
/// different registry policy and different partition-stage settings than
/// the portfolio's base, diversifying the restart trajectories beyond
/// their seeds.
#[derive(Debug, Clone)]
pub struct LaneSpec {
    /// Registry policy name; `None` = the portfolio's base policy.
    pub policy: Option<String>,
    pub sampling: Sampling,
    pub candidates: CandidateSelect,
}

/// Configuration of [`solve_portfolio`].
#[derive(Debug, Clone)]
pub struct PortfolioConfig {
    pub base: SolverConfig,
    /// Candidate actions sampled and evaluated per iteration (K >= 1;
    /// 1 = the classic single-candidate walk).
    pub batch: usize,
    /// Independent restart trajectories (M >= 1).
    pub lanes: usize,
    /// Total worker-thread budget, split lanes-first: `min(threads,
    /// lanes)` lanes run concurrently and each lane parallelizes its
    /// batch over `max(1, threads / lanes)` workers. The thread count
    /// never changes the result, only the wall-clock.
    pub threads: usize,
    /// Optional per-lane overrides, indexed by lane (cycled when shorter
    /// than `lanes`; empty = every lane runs the base settings).
    pub lane_specs: Vec<LaneSpec>,
    /// Incremental re-simulation of batch candidates ([`DeltaMode`]).
    /// Byte-identical results either way; `On`/`Auto` trade a verified-
    /// prefix scan per candidate for skipping most of its event loop.
    pub delta: DeltaMode,
    /// Fault-aware solving: when set, every candidate is priced by its
    /// *expected* cost over the ensemble's fault plans (mean objective
    /// across members; `INFINITY` as soon as one member fails to
    /// complete) instead of its nominal fault-free cost. The schedules
    /// the solver keeps and returns stay the nominal ones — the ensemble
    /// only steers acceptance. Forces [`DeltaMode::Off`] (replay plans
    /// are proven against fault-free traces only); an empty spec is
    /// exactly `None`, bit for bit.
    pub faults: Option<FaultEnsemble>,
}

impl PortfolioConfig {
    /// Single lane, single candidate, single thread — exactly the classic
    /// solver.
    pub fn new(base: SolverConfig) -> PortfolioConfig {
        PortfolioConfig {
            base,
            batch: 1,
            lanes: 1,
            threads: 1,
            lane_specs: Vec::new(),
            delta: DeltaMode::Off,
            faults: None,
        }
    }

    /// Resolve lane `lane`'s solver config + registry policy name.
    /// Lane 0 keeps the base *seed* verbatim — so with empty `lane_specs`
    /// (no overrides apply to any lane) a 1-lane portfolio is
    /// byte-identical to [`solve_with`]; when `lane_specs` is non-empty,
    /// every lane including lane 0 takes its spec's policy/sampling/
    /// candidates, and only the seeding rule distinguishes lane 0. Lanes
    /// 1.. derive content-based seeds for both the partition-stage RNG
    /// and the simulation RNG.
    fn lane_cfg(&self, lane: usize, base_policy: &str) -> (SolverConfig, String) {
        let mut cfg = self.base;
        let mut pol = base_policy.to_string();
        if !self.lane_specs.is_empty() {
            let spec = &self.lane_specs[lane % self.lane_specs.len()];
            cfg.sampling = spec.sampling;
            cfg.candidates = spec.candidates;
            if let Some(p) = &spec.policy {
                pol = p.clone();
            }
        }
        if lane > 0 {
            let s = lane_seed(self.base.seed, lane, &pol, cfg.sampling, cfg.candidates);
            cfg.seed = s;
            cfg.sim.seed = Rng::new(s).next_u64();
        }
        (cfg, pol)
    }
}

/// Deterministic per-lane RNG seed, derived from the lane's *content*
/// (base seed, lane index, policy/sampling/candidate names) through the
/// same [`content_seed`] recipe the sweep harness uses for
/// [`super::sweep::cell_seed`]: FxHash of the labels, mixed once through
/// SplitMix64 so near-identical lanes do not get correlated streams.
pub fn lane_seed(
    base_seed: u64,
    lane: usize,
    policy: &str,
    sampling: Sampling,
    candidates: CandidateSelect,
) -> u64 {
    content_seed(&[policy, sampling.name(), candidates.name()], &[base_seed, lane as u64])
}

/// Where a lane gets its scheduling policy from.
enum PolicyProvider<'a> {
    /// One caller-owned policy, reused sequentially for every simulation
    /// (the [`solve_with`] contract — supports stateful user policies;
    /// batch evaluation stays serial).
    Shared(&'a mut dyn SchedPolicy),
    /// A fresh policy per simulation. Evaluations become order-independent
    /// pure functions, which is what makes lanes and batches parallel-safe
    /// and thread-count-invariant.
    Factory(&'a (dyn Fn() -> Box<dyn SchedPolicy> + Sync)),
}

/// One evaluated candidate: the scratch state a lane adopts on acceptance.
struct Eval {
    cost: f64,
    sched: Schedule,
    dag: TaskDag,
    flat: FlatDag,
    /// Decision log + checkpoints of the candidate's simulation, present
    /// on the delta path — acceptance promotes it to the lane's next
    /// [`DeltaBase`].
    trace: Option<SimTrace>,
}

/// What one batch slot resolved to before acceptance.
enum CandState {
    /// Apply step refused the move, or the simulated cost is non-finite.
    Rejected,
    /// The frontier signature hit the lane's cost cache: the cost is
    /// known, the schedule was never rebuilt (re-simulated only if this
    /// slot wins the batch).
    Cached(f64),
    /// Fully evaluated.
    Ready(Eval),
}

/// Checkpoint spacing for traced simulations: frequent enough that a
/// verified prefix usually has a nearby restore point, coarse enough
/// that capture cost stays a small fraction of the run. Deterministic in
/// the frontier size only.
fn ckpt_every(n: usize) -> usize {
    (n / 8).clamp(16, 256)
}

/// Expected cost of `(dag, flat)` under a fault ensemble: the mean
/// objective over the ensemble's members, each simulated against its own
/// [`FaultPlan`], `INFINITY` as soon as any member fails to complete (an
/// exhausted attempt budget forces that member's makespan infinite). The
/// member schedules are throwaway — the caller keeps the nominal one;
/// this function only *prices* it.
fn ensemble_cost(
    dag: &TaskDag,
    flat: &FlatDag,
    machine: &Machine,
    db: &PerfDb,
    cfg: &SolverConfig,
    policy: &mut dyn SchedPolicy,
    ens: &FaultEnsemble,
) -> f64 {
    let mut sum = 0.0;
    for member in 0..ens.members {
        let plan = FaultPlan::new(&ens.spec, member);
        let sched = simulate_flat_faults(dag, flat, machine, db, cfg.sim, policy, &plan);
        let c = cfg.objective.cost(&sched, machine);
        if !c.is_finite() {
            return f64::INFINITY;
        }
        sum += c;
    }
    sum / ens.members as f64
}

/// Evaluate one candidate action on a scratch clone of `dag` (cheap:
/// copy-on-write task storage). `None` = rejected — the apply step
/// refused the move or the evaluated cost is non-finite. With `faults`
/// set, the returned cost is the ensemble expectation (the kept schedule
/// stays the nominal simulation).
#[allow(clippy::too_many_arguments)]
fn evaluate(
    dag: &TaskDag,
    action: Action,
    machine: &Machine,
    db: &PerfDb,
    parts: &PartitionerSet,
    cfg: &SolverConfig,
    policy: &mut dyn SchedPolicy,
    faults: Option<&FaultEnsemble>,
) -> Option<Eval> {
    let mut scratch = dag.clone();
    if !apply_action(&mut scratch, parts, action) {
        return None;
    }
    let flat = scratch.flat_dag();
    let sched = simulate_flat_policy(&scratch, &flat, machine, db, cfg.sim, policy);
    let mut cost = cfg.objective.cost(&sched, machine);
    if !cost.is_finite() {
        return None;
    }
    if let Some(ens) = faults {
        cost = ensemble_cost(&scratch, &flat, machine, db, cfg, policy, ens);
        if !cost.is_finite() {
            return None;
        }
    }
    Some(Eval { cost, sched, dag: scratch, flat, trace: None })
}

/// A candidate between the serial apply/signature stage and the parallel
/// simulation stage of a delta batch.
struct Prep {
    /// Index into the iteration's `picked` batch.
    slot: usize,
    dag: TaskDag,
    flat: FlatDag,
    sig: Vec<u64>,
}

/// The delta-evaluation analogue of the plain `par_map(evaluate)` batch:
/// serial stage clones/applies each candidate, derives its frontier and
/// signature and probes the lane cost cache; the parallel stage runs a
/// verified-prefix plan against `base` and either replays from the
/// nearest checkpoint or falls back to a full traced simulation. Costs
/// are bitwise those of full evaluation (the planner only emits proven
/// plans), so acceptance — and the whole trajectory — is independent of
/// the delta mode.
#[allow(clippy::too_many_arguments)]
fn delta_batch(
    dag: &TaskDag,
    picked: &[(Action, f64)],
    machine: &Machine,
    db: &PerfDb,
    parts: &PartitionerSet,
    cfg: &SolverConfig,
    factory: &(dyn Fn() -> Box<dyn SchedPolicy> + Sync),
    eval_threads: usize,
    base: Option<&DeltaBase>,
    wants_ct: bool,
    cache: &mut CostCache,
    entry: &mut IterLog,
) -> Vec<CandState> {
    let mut states: Vec<CandState> = Vec::with_capacity(picked.len());
    let mut preps: Vec<Prep> = Vec::new();
    for (slot, &(action, _)) in picked.iter().enumerate() {
        let mut scratch = dag.clone();
        if !apply_action(&mut scratch, parts, action) {
            states.push(CandState::Rejected);
            continue;
        }
        let flat = scratch.flat_dag();
        let sig = delta::frontier_signature(&scratch, &flat);
        match cache.get(&sig) {
            Some(c) => {
                entry.cache_hits += 1;
                states.push(CandState::Cached(c));
            }
            None => {
                // placeholder; patched from the simulation results below
                states.push(CandState::Rejected);
                preps.push(Prep { slot, dag: scratch, flat, sig });
            }
        }
    }

    let sims: Vec<(Schedule, SimTrace, usize, bool)> = par_map(eval_threads, &preps, |_, p| {
        let mut pol = factory();
        let n = p.flat.len();
        let prio = if wants_ct { critical_times(&p.dag, &p.flat, machine, db) } else { vec![0.0; n] };
        match base.and_then(|b| delta::plan_candidate(b, pol.as_ref(), &p.flat, prio)) {
            Some(dp) => {
                let DeltaPlan { plan, seed, d_star, .. } = dp;
                let (sched, tr) = simulate_flat_replay(
                    &p.dag, &p.flat, machine, db, cfg.sim, pol.as_mut(), plan, seed, ckpt_every(n),
                );
                (sched, tr, d_star, false)
            }
            None => {
                let (sched, tr) =
                    simulate_flat_traced(&p.dag, &p.flat, machine, db, cfg.sim, pol.as_mut(), ckpt_every(n));
                (sched, tr, 0, true)
            }
        }
    });

    for (p, (sched, tr, d_star, full)) in preps.into_iter().zip(sims) {
        let cost = cfg.objective.cost(&sched, machine);
        // non-finite costs are cached too: a re-visit of an infeasible
        // frontier must reject without simulating, like the miss did
        cache.insert(p.sig, cost);
        entry.events_replayed += d_star;
        entry.events_total += p.flat.len();
        if full {
            entry.full_fallbacks += 1;
        }
        states[p.slot] = if cost.is_finite() {
            CandState::Ready(Eval { cost, sched, dag: p.dag, flat: p.flat, trace: Some(tr) })
        } else {
            recycle_schedule(sched);
            CandState::Rejected
        };
    }
    states
}

/// Evaluate a single candidate through the delta machinery — the
/// materialization path for a cache-hit batch winner, whose schedule the
/// original evaluation never built.
#[allow(clippy::too_many_arguments)]
fn eval_one_delta(
    dag: &TaskDag,
    action: Action,
    machine: &Machine,
    db: &PerfDb,
    parts: &PartitionerSet,
    cfg: &SolverConfig,
    factory: &(dyn Fn() -> Box<dyn SchedPolicy> + Sync),
    base: Option<&DeltaBase>,
    wants_ct: bool,
) -> Option<Eval> {
    let mut scratch = dag.clone();
    if !apply_action(&mut scratch, parts, action) {
        return None;
    }
    let flat = scratch.flat_dag();
    let mut pol = factory();
    let n = flat.len();
    let prio = if wants_ct { critical_times(&scratch, &flat, machine, db) } else { vec![0.0; n] };
    let (sched, tr) = match base.and_then(|b| delta::plan_candidate(b, pol.as_ref(), &flat, prio)) {
        Some(dp) => {
            let DeltaPlan { plan, seed, .. } = dp;
            simulate_flat_replay(&scratch, &flat, machine, db, cfg.sim, pol.as_mut(), plan, seed, ckpt_every(n))
        }
        None => simulate_flat_traced(&scratch, &flat, machine, db, cfg.sim, pol.as_mut(), ckpt_every(n)),
    };
    let cost = cfg.objective.cost(&sched, machine);
    if !cost.is_finite() {
        recycle_schedule(sched);
        return None;
    }
    Some(Eval { cost, sched, dag: scratch, flat, trace: Some(tr) })
}

/// Sample the iteration's candidate batch: indices into `cands`, in
/// preference order. `Hard` takes the top-K by score with ties broken
/// toward the higher index — the first element is exactly the classic
/// argmax (`max_by` keeps the *last* maximum). `Soft` makes K weighted
/// draws without replacement, so `K = 1` consumes exactly one RNG draw,
/// identical to the classic walk.
fn sample_batch(cands: &[(Action, f64)], k: usize, sampling: Sampling, rng: &mut Rng) -> Vec<usize> {
    let k = k.max(1).min(cands.len());
    match sampling {
        Sampling::Hard => {
            let mut idx: Vec<usize> = (0..cands.len()).collect();
            idx.sort_by(|&a, &b| cands[b].1.total_cmp(&cands[a].1).then(b.cmp(&a)));
            idx.truncate(k);
            idx
        }
        Sampling::Soft => {
            // collect_candidates only emits finite positive scores, so
            // the weight sum cannot be poisoned by an inf/NaN estimate
            debug_assert!(cands.iter().all(|c| c.1.is_finite() && c.1 > 0.0), "{cands:?}");
            let mut alive: Vec<usize> = (0..cands.len()).collect();
            let mut weights: Vec<f64> = cands.iter().map(|c| c.1).collect();
            let mut out = Vec::with_capacity(k);
            for _ in 0..k {
                let j = rng.weighted(&weights);
                out.push(alive[j]);
                alive.swap_remove(j);
                weights.swap_remove(j);
            }
            out
        }
    }
}

fn lane_simulate(
    prov: &mut PolicyProvider<'_>,
    dag: &TaskDag,
    flat: &FlatDag,
    machine: &Machine,
    db: &PerfDb,
    sim: SimConfig,
) -> Schedule {
    match prov {
        PolicyProvider::Shared(p) => simulate_flat_policy(dag, flat, machine, db, sim, &mut **p),
        PolicyProvider::Factory(f) => {
            let mut p = f();
            simulate_flat_policy(dag, flat, machine, db, sim, p.as_mut())
        }
    }
}

/// One search trajectory: the batched iteration loop. The accepted
/// evaluation of iteration `i` *is* iteration `i + 1`'s schedule stage.
#[allow(clippy::too_many_arguments)]
fn run_lane(
    dag0: &TaskDag,
    machine: &Machine,
    db: &PerfDb,
    parts: &PartitionerSet,
    cfg: &SolverConfig,
    batch: usize,
    eval_threads: usize,
    prov: &mut PolicyProvider<'_>,
    delta: DeltaMode,
    faults: Option<&FaultEnsemble>,
) -> SolveResult {
    let mut rng = Rng::new(cfg.seed);
    let mut history: Vec<IterLog> = Vec::new();

    // an empty fault spec prices nothing in — normalize it to `None` so
    // `--faults off.toml` is bit-identical to no `--faults` at all (a
    // 1-member "mean" would otherwise re-associate the float arithmetic)
    let faults = faults.filter(|e| !e.spec.is_empty());
    // replay plans are proven against fault-free traces only: fault-aware
    // pricing forces full evaluation, bitwise the same trajectory
    let delta = if faults.is_some() { DeltaMode::Off } else { delta };

    // The delta path needs fresh policy instances per candidate (a trace
    // is only reusable against a policy whose decisions are a pure
    // function of the decision-time view), so it requires a factory
    // provider AND an eligible policy. Anything else degrades to full
    // evaluation — bitwise the same trajectory, just slower.
    let (use_delta, wants_ct, wants_succs) = if delta.enabled() {
        match &*prov {
            PolicyProvider::Factory(f) => {
                let p = f();
                (delta::policy_eligible(p.as_ref()), p.wants_critical_times(), p.wants_successors())
            }
            PolicyProvider::Shared(_) => (false, false, false),
        }
    } else {
        (false, false, false)
    };
    let mut cache = CostCache::new();
    let mut base: Option<DeltaBase> = None;

    let mut dag = dag0.clone();
    let mut flat = dag.flat_dag();
    let mut sched = if use_delta {
        let PolicyProvider::Factory(f) = &*prov else { unreachable!("delta requires a factory") };
        let mut p = f();
        let (s, tr) =
            simulate_flat_traced(&dag, &flat, machine, db, cfg.sim, p.as_mut(), ckpt_every(flat.len()));
        base = Some(DeltaBase::new(tr, &s, &flat, wants_succs));
        s
    } else {
        lane_simulate(prov, &dag, &flat, machine, db, cfg.sim)
    };
    let mut cost = cfg.objective.cost(&sched, machine);
    // an infeasible start (zero-rate curve -> inf durations) is a valid
    // inf-cost incumbent, not an invariant violation
    #[cfg(debug_assertions)]
    if cost.is_finite() {
        super::validate::assert_valid(&dag, &flat, machine, &sched);
    }
    if let Some(ens) = faults {
        if cost.is_finite() {
            cost = match prov {
                PolicyProvider::Shared(p) => ensemble_cost(&dag, &flat, machine, db, cfg, &mut **p, ens),
                PolicyProvider::Factory(f) => {
                    let mut p = f();
                    ensemble_cost(&dag, &flat, machine, db, cfg, p.as_mut(), ens)
                }
            };
        }
    }
    let mut best: (f64, Schedule, TaskDag, usize) = (cost, sched.clone(), dag.clone(), 0);

    for iter in 0..cfg.iters.max(1) {
        let cands = collect_candidates(&dag, &flat, &sched, machine, db, parts, cfg);
        let mut entry = IterLog {
            iter,
            cost,
            n_tasks: flat.len(),
            action: None,
            score: 0.0,
            applied: false,
            evaluated: 0,
            rejected: 0,
            events_replayed: 0,
            events_total: 0,
            cache_hits: 0,
            full_fallbacks: 0,
        };
        if cands.is_empty() {
            history.push(entry);
            break;
        }

        let picked: Vec<(Action, f64)> =
            sample_batch(&cands, batch, cfg.sampling, &mut rng).into_iter().map(|i| cands[i]).collect();
        entry.evaluated = picked.len();

        let mut states: Vec<CandState> = if use_delta {
            let PolicyProvider::Factory(f) = &*prov else { unreachable!("delta requires a factory") };
            delta_batch(
                &dag, &picked, machine, db, parts, cfg, *f, eval_threads,
                base.as_ref(), wants_ct, &mut cache, &mut entry,
            )
        } else {
            let evals: Vec<Option<Eval>> = match prov {
                PolicyProvider::Factory(f) => {
                    let f = *f; // reborrow the shared factory out of &mut
                    par_map(eval_threads, &picked, |_, &(action, _)| {
                        let mut p = f();
                        evaluate(&dag, action, machine, db, parts, cfg, p.as_mut(), faults)
                    })
                }
                PolicyProvider::Shared(p) => picked
                    .iter()
                    .map(|&(action, _)| evaluate(&dag, action, machine, db, parts, cfg, &mut **p, faults))
                    .collect(),
            };
            // delta requested but ineligible: every simulated candidate
            // is by definition a full run, so the counters say so
            if delta.enabled() {
                entry.full_fallbacks = evals.iter().filter(|e| e.is_some()).count();
            }
            evals
                .into_iter()
                .map(|e| match e {
                    Some(e) => CandState::Ready(e),
                    None => CandState::Rejected,
                })
                .collect()
        };
        entry.rejected = states
            .iter()
            .filter(|s| match s {
                CandState::Rejected => true,
                CandState::Cached(c) => !c.is_finite(),
                CandState::Ready(_) => false,
            })
            .count();

        // accept the lowest evaluated cost; ties toward sample order
        let mut accepted: Option<(usize, f64)> = None;
        for (j, s) in states.iter().enumerate() {
            let c = match s {
                CandState::Rejected => continue,
                CandState::Cached(c) if !c.is_finite() => continue,
                CandState::Cached(c) => *c,
                CandState::Ready(e) => e.cost,
            };
            let better = match accepted {
                None => true,
                Some((_, acc)) => c < acc,
            };
            if better {
                accepted = Some((j, c));
            }
        }
        match accepted {
            Some((j, _)) => {
                let mut e = match std::mem::replace(&mut states[j], CandState::Rejected) {
                    CandState::Ready(e) => e,
                    CandState::Cached(c) => {
                        // a cache hit skipped simulation, but adoption
                        // needs the schedule: materialize exactly one
                        let PolicyProvider::Factory(f) = &*prov else {
                            unreachable!("cache hits only exist on the delta path")
                        };
                        let e = eval_one_delta(
                            &dag, picked[j].0, machine, db, parts, cfg, *f, base.as_ref(), wants_ct,
                        )
                        .expect("cached-finite candidate re-evaluates finite");
                        debug_assert_eq!(e.cost.to_bits(), c.to_bits(), "cost cache is bit-stable");
                        e
                    }
                    CandState::Rejected => unreachable!("accepted candidate was rejected"),
                };
                // the oracle runs on every ACCEPTED schedule (discarded
                // batch members were simulated by the same engine path;
                // re-validating them would only multiply debug wall-clock)
                #[cfg(debug_assertions)]
                super::validate::assert_valid(&e.dag, &e.flat, machine, &e.sched);
                let (action, score) = picked[j];
                entry.action = Some(action);
                entry.score = score;
                entry.applied = true;
                if e.cost < best.0 {
                    best = (e.cost, e.sched.clone(), e.dag.clone(), iter + 1);
                }
                if use_delta {
                    let tr = e.trace.take().expect("delta evaluations carry traces");
                    base = Some(DeltaBase::new(tr, &e.sched, &e.flat, wants_succs));
                }
                dag = e.dag;
                flat = e.flat;
                sched = e.sched;
                cost = e.cost;
            }
            None => {
                // every candidate rejected: the DAG and incumbent stay
                // untouched; log the primary move that was attempted
                let (action, score) = picked[0];
                entry.action = Some(action);
                entry.score = score;
            }
        }
        // discarded evaluations still hold pooled schedules — return them
        for s in states {
            if let CandState::Ready(e) = s {
                recycle_schedule(e.sched);
            }
        }
        history.push(entry);
    }

    let (best_cost, best_schedule, best_dag, best_iter) = best;
    SolveResult { best_cost, best_schedule, best_dag, best_iter, lane: 0, lane_costs: vec![best_cost], history }
}

/// Run the iterative scheduler-partitioner starting from `dag`, under the
/// built-in policy named by `cfg.sim`'s shim fields.
pub fn solve(
    dag: TaskDag,
    machine: &Machine,
    db: &PerfDb,
    parts: &PartitionerSet,
    cfg: SolverConfig,
) -> SolveResult {
    let mut p = policy::policy_for(SchedConfig::new(cfg.sim.ordering, cfg.sim.select));
    solve_with(dag, machine, db, parts, cfg, p.as_mut())
}

/// [`solve`] under an arbitrary scheduling policy: every schedule stage of
/// the iteration loop dispatches through `policy`. Single lane, batch of
/// one — the classic sequential walk (stateful user policies are safe:
/// the policy value is reused, never cloned or rebuilt).
pub fn solve_with(
    dag: TaskDag,
    machine: &Machine,
    db: &PerfDb,
    parts: &PartitionerSet,
    cfg: SolverConfig,
    policy: &mut dyn SchedPolicy,
) -> SolveResult {
    let mut prov = PolicyProvider::Shared(policy);
    run_lane(&dag, machine, db, parts, &cfg, 1, 1, &mut prov, DeltaMode::Off, None)
}

/// Run the full parallel portfolio: `cfg.lanes` independent trajectories
/// of `cfg.batch`-wide batched search across `cfg.threads` workers. The
/// winner is the lowest-cost lane (ties toward the lower lane index), so
/// the result — history, costs, DAG — is byte-identical for any thread
/// count. `policy` is the base registry policy name; [`LaneSpec`]s may
/// override it per lane.
pub fn solve_portfolio(
    dag: &TaskDag,
    machine: &Machine,
    db: &PerfDb,
    parts: &PartitionerSet,
    reg: &PolicyRegistry,
    policy: &str,
    cfg: &PortfolioConfig,
) -> SolveResult {
    let lanes = cfg.lanes.max(1);
    let batch = cfg.batch.max(1);
    let threads = cfg.threads.max(1);
    // resolve every lane's policy up front: a typo'd registry name must
    // fail fast on the caller's thread, not inside a worker
    let lane_cfgs: Vec<(SolverConfig, String)> = (0..lanes).map(|l| cfg.lane_cfg(l, policy)).collect();
    for (_, name) in &lane_cfgs {
        assert!(reg.get(name).is_some(), "unknown policy '{name}' in portfolio");
    }
    let eval_threads = (threads / lanes).max(1);
    let mut results: Vec<SolveResult> = par_map(threads.min(lanes), &lane_cfgs, |_, (lcfg, name)| {
        let factory = || reg.get(name).expect("validated above");
        let mut prov = PolicyProvider::Factory(&factory);
        run_lane(dag, machine, db, parts, lcfg, batch, eval_threads, &mut prov, cfg.delta, cfg.faults.as_ref())
    });
    let lane_costs: Vec<f64> = results.iter().map(|r| r.best_cost).collect();
    let mut win = 0usize;
    for i in 1..results.len() {
        if results[i].best_cost.total_cmp(&results[win].best_cost).is_lt() {
            win = i;
        }
    }
    let mut out = results.swap_remove(win);
    out.lane = win;
    out.lane_costs = lane_costs;
    out
}

/// Canonical byte-stable JSON of a [`SolveResult`] — what `hesp solve
/// --out` writes, what the CI determinism smoke `cmp`s across thread
/// counts, and what the golden-trace test pins. Float fields carry their
/// exact bit patterns (hex) alongside a human-readable value, so equality
/// of the serialization is equality of the trajectory.
pub fn result_json(res: &SolveResult) -> String {
    use crate::util::json::Json;
    use std::collections::BTreeMap;
    let bits = |x: f64| Json::Str(format!("{:016x}", x.to_bits()));
    let mut o = BTreeMap::new();
    o.insert("best_cost".to_string(), Json::Num(res.best_cost));
    o.insert("best_cost_bits".to_string(), bits(res.best_cost));
    o.insert("best_iter".to_string(), Json::Num(res.best_iter as f64));
    o.insert("lane".to_string(), Json::Num(res.lane as f64));
    o.insert(
        "lane_cost_bits".to_string(),
        Json::Arr(res.lane_costs.iter().map(|&c| bits(c)).collect()),
    );
    o.insert("makespan_bits".to_string(), bits(res.best_schedule.makespan));
    o.insert("n_tasks".to_string(), Json::Num(res.best_dag.frontier().len() as f64));
    o.insert("dag_depth".to_string(), Json::Num(res.best_dag.depth() as f64));
    o.insert("transfer_bytes".to_string(), Json::Num(res.best_schedule.transfer_bytes as f64));
    let hist: Vec<Json> = res
        .history
        .iter()
        .map(|h| {
            let mut e = BTreeMap::new();
            e.insert("iter".to_string(), Json::Num(h.iter as f64));
            e.insert("cost_bits".to_string(), bits(h.cost));
            e.insert("n_tasks".to_string(), Json::Num(h.n_tasks as f64));
            e.insert(
                "action".to_string(),
                match &h.action {
                    Some(a) => Json::Str(a.label()),
                    None => Json::Null,
                },
            );
            e.insert("score_bits".to_string(), bits(h.score));
            e.insert("applied".to_string(), Json::Bool(h.applied));
            e.insert("evaluated".to_string(), Json::Num(h.evaluated as f64));
            e.insert("rejected".to_string(), Json::Num(h.rejected as f64));
            Json::Obj(e)
        })
        .collect();
    o.insert("history".to_string(), Json::Arr(hist));
    Json::Obj(o).to_string()
}

/// Apply one sampled move to the DAG. Returns whether the move actually
/// mutated it.
///
/// A `Repartition` is merge-then-split; the split is *planned first*
/// against the merged task's shape, and if the partitioner rejects the
/// proposed `sub_edge` the cluster is left exactly as it was. (The old
/// code merged unconditionally and ignored the re-partition failure,
/// silently turning the move into an unintended `Merge` — a corrupted
/// search trajectory the iteration log could not even show.) Public for
/// diagnostics and tests, like [`collect_candidates`].
pub fn apply_action(dag: &mut TaskDag, parts: &PartitionerSet, action: Action) -> bool {
    match action {
        Action::Partition { task, sub_edge } => parts.apply(dag, task, sub_edge).is_some(),
        Action::Merge { cluster } => {
            dag.merge(cluster);
            true
        }
        Action::Repartition { cluster, sub_edge } => {
            // plan against the merged shape (the partitioner only reads the
            // task's kind/regions, which merging does not change)
            let before = dag.task(cluster).clone();
            if parts.plan(&before, sub_edge).is_none() {
                return false;
            }
            dag.merge(cluster);
            if parts.apply(dag, cluster, sub_edge).is_some() {
                return true;
            }
            // defensive: the plan succeeded but the apply did not — re-split
            // at the old edge so the DAG shape is preserved
            if let Some(old) = before.partition_edge {
                let restored = parts.apply(dag, cluster, old).is_some();
                debug_assert!(restored, "re-split at the cluster's own edge {old} must succeed");
            }
            false
        }
    }
}

/// Build the scored candidate list for one partition-stage iteration
/// (positive scores only). Public for diagnostics and tests: it exposes
/// exactly what the solver would sample from a given (dag, schedule)
/// state.
pub fn collect_candidates(
    dag: &TaskDag,
    flat: &super::taskdag::FlatDag,
    sched: &Schedule,
    machine: &Machine,
    db: &PerfDb,
    parts: &PartitionerSet,
    cfg: &SolverConfig,
) -> Vec<(Action, f64)> {
    let n_procs = machine.n_procs();
    let mut out = Vec::new();

    // per-proc sorted busy intervals: O(log k) "is p busy during [t0,t1)?"
    let mut proc_ivs: Vec<Vec<(f64, f64)>> = vec![Vec::new(); n_procs];
    for a in &sched.assignments {
        proc_ivs[a.proc].push((a.start, a.end));
    }
    for iv in &mut proc_ivs {
        iv.sort_by(|a, b| a.0.total_cmp(&b.0));
    }
    let busy_during = |p: usize, t0: f64, t1: f64| -> bool {
        let iv = &proc_ivs[p];
        // first interval with end > t0
        let i = iv.partition_point(|&(_, e)| e <= t0);
        i < iv.len() && iv[i].0 < t1
    };

    // ---- select leaf positions per policy ----
    let positions: Vec<usize> = match cfg.candidates {
        CandidateSelect::All => (0..flat.len()).collect(),
        CandidateSelect::CriticalPath => {
            let ct = critical_times(dag, flat, machine, db);
            critical_path(flat, &ct)
        }
        CandidateSelect::Shallow => {
            let min_d = flat.tasks.iter().map(|&t| dag.task(t).depth).min().unwrap_or(0);
            (0..flat.len()).filter(|&i| dag.task(flat.tasks[i]).depth == min_d).collect()
        }
    };

    // ---- partition candidates ----
    for pos in positions {
        let tid = flat.tasks[pos];
        let t = dag.task(tid);
        if !parts.can_partition(t.kind) {
            continue;
        }
        let edge = t.char_edge().round() as u32;
        if edge / 2 < cfg.min_edge {
            continue;
        }
        let a = &sched.assignments[pos];
        let dur = a.end - a.start;
        if dur <= 0.0 {
            continue;
        }
        let idle = (0..n_procs).filter(|&p| !busy_during(p, a.start, a.end)).count();
        let avail = idle + 1;
        // the more available parallelism, the smaller p (paper §2.1):
        // target an s x s sub-grid with roughly `avail` parallel sub-tasks.
        let s_target = ((avail as f64).sqrt().ceil() as u32).max(2);
        let target_edge = edge as f64 / s_target as f64;
        let Some(sub_edge) = snap_sub_edge(edge, target_edge, cfg.min_edge) else {
            continue;
        };
        // estimated post-partition delay: the task's flops spread over the
        // assigned + idle processors at the finer grain's efficiency
        let assigned_type = machine.procs[a.proc].ptype;
        let mut rate = db.curve(assigned_type, t.kind).gflops(sub_edge as f64);
        // processors idle during [start, end) can absorb sub-tasks
        for p in 0..n_procs {
            if p != a.proc && !busy_during(p, a.start, a.end) {
                rate += db.curve(machine.procs[p].ptype, t.kind).gflops(sub_edge as f64);
            }
        }
        let est = t.flops / (rate * 1e9);
        let score = dur - est;
        // finite-only: a zero-rate curve makes `est` (or an inf-duration
        // assignment makes `dur`) non-finite, and one inf/NaN weight
        // poisons the Soft sampling sum downstream
        if score.is_finite() && score > 0.0 {
            out.push((Action::Partition { task: tid, sub_edge }, score));
        }
    }

    // ---- cluster candidates: merge back or re-partition ----
    if cfg.allow_merge {
        // leaf spans per cluster: walk frontier, attribute to ancestors
        let pos_of: crate::util::fxhash::FxHashMap<TaskId, usize> =
            flat.tasks.iter().enumerate().map(|(i, &t)| (t, i)).collect();
        for cluster in dag.clusters() {
            let c = dag.task(cluster);
            // gather descendant leaves
            let mut leaves = Vec::new();
            let mut stack = vec![cluster];
            while let Some(x) = stack.pop() {
                match &dag.task(x).children {
                    None => leaves.push(x),
                    Some(ch) => stack.extend(ch.iter().copied()),
                }
            }
            let (mut t0, mut t1) = (f64::INFINITY, 0.0f64);
            for l in &leaves {
                if let Some(&p) = pos_of.get(l) {
                    let a = &sched.assignments[p];
                    t0 = t0.min(a.start);
                    t1 = t1.max(a.end);
                }
            }
            if !t0.is_finite() || t1 <= t0 {
                continue;
            }
            let span = t1 - t0;
            let edge = c.char_edge().round() as u32;
            // merged estimate: whole cluster as one task on the fastest
            // processor type for it
            let best_rate = (0..machine.proc_types.len())
                .map(|pt| db.curve(pt, c.kind).gflops(edge as f64))
                .fold(0.0f64, f64::max);
            let est_merged = c.flops / (best_rate * 1e9);
            let merge_score = span - est_merged;
            if merge_score.is_finite() && merge_score > 0.0 {
                out.push((Action::Merge { cluster }, merge_score));
            }
            // re-partition at one step coarser granularity than current
            if let Some(cur) = c.partition_edge {
                let idle = (0..n_procs).filter(|&p| !busy_during(p, t0, t1)).count();
                if let Some(coarser) = snap_sub_edge(edge, cur as f64 * 2.0, cfg.min_edge) {
                    if coarser != cur {
                        // fewer, bigger tasks: better per-task efficiency.
                        // Rate the move against the processor types that
                        // actually executed the cluster's leaves in the
                        // current schedule — summed current-grain vs
                        // coarser-grain rates over those same processors,
                        // same parallelism.
                        let (mut rate_now, mut rate_new) = (0.0f64, 0.0f64);
                        for l in &leaves {
                            if let Some(&p) = pos_of.get(l) {
                                let ty = machine.procs[sched.assignments[p].proc].ptype;
                                rate_now += db.curve(ty, c.kind).gflops(cur as f64);
                                rate_new += db.curve(ty, c.kind).gflops(coarser as f64);
                            }
                        }
                        if rate_now > 1e-12 && rate_new > 1e-12 {
                            let est = span * rate_now / rate_new;
                            let score = (span - est) * if idle == 0 { 1.0 } else { 0.1 };
                            if score.is_finite() && score > 0.0 {
                                out.push((Action::Repartition { cluster, sub_edge: coarser }, score));
                            }
                        }
                    }
                }
            }
        }
    }

    out
}

/// Simulate the uniform (homogeneous) tilings of an n x n Cholesky root
/// for each tile edge — the static baseline of Fig. 5 (right) and of the
/// "Best Homogeneous" halves of Table 1 — under the built-in policy named
/// by `sim`'s shim fields.
pub fn homogeneous_sweep(
    n: u32,
    tiles: &[u32],
    machine: &Machine,
    db: &PerfDb,
    sim: SimConfig,
) -> Vec<(u32, TaskDag, Schedule)> {
    let mut p = policy::policy_for(SchedConfig::new(sim.ordering, sim.select));
    homogeneous_sweep_with(n, tiles, machine, db, sim, p.as_mut())
}

/// [`homogeneous_sweep`] under an arbitrary scheduling policy (reused
/// across the tile sizes; built-ins are stateless, custom policies should
/// key any internal state per run off the simulation seed).
pub fn homogeneous_sweep_with(
    n: u32,
    tiles: &[u32],
    machine: &Machine,
    db: &PerfDb,
    sim: SimConfig,
    policy: &mut dyn SchedPolicy,
) -> Vec<(u32, TaskDag, Schedule)> {
    use super::partitioners::cholesky;
    let mut out = Vec::new();
    for &b in tiles {
        if n % b != 0 || n / b < 2 {
            continue;
        }
        let mut dag = cholesky::root(n);
        cholesky::partition_uniform(&mut dag, b);
        let sched = simulate_policy(&dag, machine, db, sim, policy);
        out.push((b, dag, sched));
    }
    out
}

/// Best (lowest-cost) entry of a homogeneous sweep.
pub fn best_homogeneous(
    n: u32,
    tiles: &[u32],
    machine: &Machine,
    db: &PerfDb,
    sim: SimConfig,
    objective: Objective,
) -> Option<(u32, TaskDag, Schedule)> {
    let mut p = policy::policy_for(SchedConfig::new(sim.ordering, sim.select));
    best_homogeneous_with(n, tiles, machine, db, sim, objective, p.as_mut())
}

/// [`best_homogeneous`] under an arbitrary scheduling policy.
pub fn best_homogeneous_with(
    n: u32,
    tiles: &[u32],
    machine: &Machine,
    db: &PerfDb,
    sim: SimConfig,
    objective: Objective,
    policy: &mut dyn SchedPolicy,
) -> Option<(u32, TaskDag, Schedule)> {
    homogeneous_sweep_with(n, tiles, machine, db, sim, policy)
        .into_iter()
        .min_by(|a, b| objective.cost(&a.2, machine).total_cmp(&objective.cost(&b.2, machine)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::simulate;
    use crate::coordinator::partitioners::cholesky;
    use crate::coordinator::perfmodel::{PerfCurve, PerfDb};
    use crate::coordinator::platform::{Machine, MachineBuilder};
    use crate::coordinator::policies::{Ordering, ProcSelect, SchedConfig};

    /// 4 CPUs with saturating curves: small tiles are inefficient, so the
    /// solver has a real granularity trade-off.
    fn setup() -> (Machine, PerfDb) {
        let mut b = MachineBuilder::new("m");
        let h = b.space("host", u64::MAX);
        b.main(h);
        let t = b.proc_type("cpu", 1.0, 0.1);
        b.processors(4, "c", t, h);
        let m = b.build();
        let mut db = PerfDb::new();
        db.set_fallback(0, PerfCurve::Saturating { peak: 20.0, half: 64.0, exponent: 2.0 });
        (m, db)
    }

    fn simcfg() -> SimConfig {
        SimConfig::new(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish))
    }

    #[test]
    fn solver_improves_over_root_task() {
        let (m, db) = setup();
        let dag = cholesky::root(1024);
        let root_sched = simulate(&dag, &m, &db, simcfg());
        let parts = PartitionerSet::standard();
        let cfg = SolverConfig::all_soft(simcfg(), 30, 64);
        let res = solve(dag, &m, &db, &parts, cfg);
        assert!(res.best_cost < root_sched.makespan, "{} < {}", res.best_cost, root_sched.makespan);
        assert!(res.best_dag.depth() >= 1);
        assert!(!res.history.is_empty());
    }

    #[test]
    fn hard_sampling_is_deterministic() {
        let (m, db) = setup();
        let parts = PartitionerSet::standard();
        let mut cfg = SolverConfig::all_soft(simcfg(), 10, 64);
        cfg.sampling = Sampling::Hard;
        let r1 = solve(cholesky::root(512), &m, &db, &parts, cfg);
        let r2 = solve(cholesky::root(512), &m, &db, &parts, cfg);
        assert_eq!(r1.best_cost, r2.best_cost);
        assert_eq!(r1.history.len(), r2.history.len());
    }

    #[test]
    fn soft_sampling_differs_across_seeds_but_is_reproducible() {
        let (m, db) = setup();
        let parts = PartitionerSet::standard();
        let mut cfg = SolverConfig::all_soft(simcfg(), 12, 64);
        cfg.seed = 1;
        let r1 = solve(cholesky::root(512), &m, &db, &parts, cfg);
        let r1b = solve(cholesky::root(512), &m, &db, &parts, cfg);
        assert_eq!(r1.best_cost, r1b.best_cost, "same seed, same trajectory");
        let _ = r1;
    }

    #[test]
    fn candidate_select_modes_run() {
        let (m, db) = setup();
        let parts = PartitionerSet::standard();
        for cs in [CandidateSelect::All, CandidateSelect::CriticalPath, CandidateSelect::Shallow] {
            let mut cfg = SolverConfig::all_soft(simcfg(), 8, 64);
            cfg.candidates = cs;
            let res = solve(cholesky::root(512), &m, &db, &parts, cfg);
            assert!(res.best_cost.is_finite(), "{cs:?}");
        }
    }

    #[test]
    fn min_edge_is_respected() {
        let (m, db) = setup();
        let parts = PartitionerSet::standard();
        let mut cfg = SolverConfig::all_soft(simcfg(), 25, 128);
        cfg.sampling = Sampling::Hard;
        let res = solve(cholesky::root(1024), &m, &db, &parts, cfg);
        let frontier = res.best_dag.frontier();
        for t in frontier {
            assert!(res.best_dag.task(t).char_edge() >= 128.0 - 1e-9);
        }
    }

    #[test]
    fn homogeneous_sweep_has_interior_optimum() {
        let (m, db) = setup();
        let tiles = [64, 128, 256, 512];
        let sweep = homogeneous_sweep(1024, &tiles, &m, &db, simcfg());
        assert_eq!(sweep.len(), 4);
        let (best_b, _, _) =
            best_homogeneous(1024, &tiles, &m, &db, simcfg(), Objective::Makespan).unwrap();
        // trade-off: neither the finest nor the coarsest tile wins
        assert!(best_b == 128 || best_b == 256, "best_b={best_b}");
    }

    #[test]
    fn solver_beats_best_homogeneous() {
        // the paper's headline claim, in miniature
        let (m, db) = setup();
        let parts = PartitionerSet::standard();
        let tiles = [64, 128, 256, 512];
        let (_, hdag, hsched) =
            best_homogeneous(1024, &tiles, &m, &db, simcfg(), Objective::Makespan).unwrap();
        // start the heterogeneous search FROM the best homogeneous tiling
        let cfg = SolverConfig::all_soft(simcfg(), 40, 64);
        let res = solve(hdag, &m, &db, &parts, cfg);
        assert!(
            res.best_cost <= hsched.makespan * 1.0001,
            "heterogeneous {} vs homogeneous {}",
            res.best_cost,
            hsched.makespan
        );
    }

    #[test]
    fn repartition_scores_against_executing_processor_types() {
        // Heterogeneous regression for the old hard-coded `db.curve(0, ..)`
        // scoring: type 0 is a SLOW processor with a flat (grain-
        // independent) curve, type 1 a fast saturating one that strongly
        // prefers coarser tiles. When the cluster's leaves all ran on the
        // fast type, coarsening is a clear win — but scoring it with type
        // 0's flat curve yields est == span, score 0, and the move is
        // never proposed.
        use crate::coordinator::engine::simulate_mapped;
        let mut b = MachineBuilder::new("het");
        let h = b.space("host", u64::MAX);
        b.main(h);
        let slow = b.proc_type("slow", 1.0, 0.1);
        let fast = b.proc_type("fast", 1.0, 0.1);
        b.processors(1, "s", slow, h);
        b.processors(2, "f", fast, h);
        let m = b.build();
        let mut db = PerfDb::new();
        db.set_fallback(0, PerfCurve::Const { gflops: 1.0 }); // flat: same rate at any grain
        db.set_fallback(1, PerfCurve::Saturating { peak: 20.0, half: 64.0, exponent: 2.0 });

        let parts = PartitionerSet::standard();
        let mut dag = cholesky::root(256);
        parts.apply(&mut dag, 0, 64).expect("partition root at 64");
        let flat = dag.flat_dag();
        let n = flat.len();
        let cfg = SolverConfig::all_soft(simcfg(), 1, 32);

        // every leaf executed on the fast type -> coarsening to 128 must
        // be a positively-scored candidate
        let sched = simulate_mapped(&dag, &m, &db, simcfg(), &vec![1; n]);
        let cands = collect_candidates(&dag, &flat, &sched, &m, &db, &parts, &cfg);
        let score = cands
            .iter()
            .find_map(|(a, s)| match a {
                Action::Repartition { cluster, sub_edge } if *cluster == dag.root && *sub_edge == 128 => Some(*s),
                _ => None,
            })
            .expect("repartition move must be proposed when the executing type prefers coarser tiles");
        assert!(score > 0.0, "score={score}");

        // same cluster executed on the flat-curve slow type -> coarsening
        // buys nothing, and no repartition move may be proposed
        let sched0 = simulate_mapped(&dag, &m, &db, simcfg(), &vec![0; n]);
        let cands0 = collect_candidates(&dag, &flat, &sched0, &m, &db, &parts, &cfg);
        assert!(
            !cands0.iter().any(|(a, _)| matches!(a, Action::Repartition { .. })),
            "flat-curve executions must not propose repartitions: {cands0:?}"
        );
    }

    #[test]
    fn history_records_actions() {
        let (m, db) = setup();
        let parts = PartitionerSet::standard();
        let mut cfg = SolverConfig::all_soft(simcfg(), 6, 64);
        cfg.sampling = Sampling::Hard;
        let res = solve(cholesky::root(512), &m, &db, &parts, cfg);
        assert!(res.history.iter().any(|h| h.action.is_some()));
        assert!(res.history.iter().all(|h| h.cost.is_finite()));
        // the standard partitioners accept every snapped sub-edge, so every
        // sampled move must report as applied
        assert!(res.history.iter().filter(|h| h.action.is_some()).all(|h| h.applied));
    }

    /// A POTRF partitioner that refuses every sub-edge except `only` —
    /// the shape of failure a user partitioner (non-divisible constraint,
    /// minimum kernel size, ...) can produce for a solver-proposed edge.
    struct PickyPartitioner {
        only: u32,
    }

    impl crate::coordinator::partitioners::Partitioner for PickyPartitioner {
        fn kinds(&self) -> Vec<crate::coordinator::task::TaskKind> {
            vec![crate::coordinator::task::TaskKind::Potrf]
        }

        fn partition(
            &self,
            task: &crate::coordinator::task::Task,
            sub_edge: u32,
        ) -> Option<Vec<crate::coordinator::task::TaskSpec>> {
            use crate::coordinator::partitioners::Partitioner;
            if sub_edge == self.only {
                cholesky::CholeskyPartitioner.partition(task, sub_edge)
            } else {
                None
            }
        }
    }

    #[test]
    fn rejected_repartition_leaves_cluster_intact() {
        // regression: `apply` used to merge the cluster first and ignore
        // the re-partition failure, silently turning the sampled
        // Repartition into an unintended Merge
        let mut parts = PartitionerSet::empty();
        parts.register(std::sync::Arc::new(PickyPartitioner { only: 64 }));
        let mut dag = cholesky::root(256);
        parts.apply(&mut dag, 0, 64).expect("64 is the allowed edge");
        let root = dag.root;
        let frontier_before = dag.frontier();

        let applied = apply_action(&mut dag, &parts, Action::Repartition { cluster: root, sub_edge: 128 });
        assert!(!applied, "a rejected re-partition must not be applied");
        assert_eq!(dag.frontier(), frontier_before, "the cluster must be left exactly as it was");
        assert_eq!(dag.task(root).partition_edge, Some(64), "still partitioned at the old edge");

        // the allowed edge still re-partitions fine through the same path
        assert!(apply_action(&mut dag, &parts, Action::Repartition { cluster: root, sub_edge: 64 }));
        assert_eq!(dag.task(root).partition_edge, Some(64));
    }

    #[test]
    fn fully_rejected_batch_leaves_state_untouched() {
        // the batched analogue of `rejected_repartition_leaves_cluster_intact`:
        // every candidate of every batch is a Partition the picky
        // partitioner refuses, so no iteration may mutate the DAG or the
        // incumbent, and the rejection must be visible in the IterLog
        let (m, db) = setup();
        let mut parts = PartitionerSet::empty();
        parts.register(std::sync::Arc::new(PickyPartitioner { only: 128 }));
        let mut dag = cholesky::root(512);
        parts.apply(&mut dag, 0, 128).expect("128 is the allowed edge");
        let frontier0 = dag.frontier();
        let base = simulate(&dag, &m, &db, simcfg());

        let mut cfg = SolverConfig::all_soft(simcfg(), 4, 64);
        cfg.allow_merge = false; // leaf Partition moves only
        cfg.seed = 11;
        let reg = crate::coordinator::policy::PolicyRegistry::standard();
        let mut pcfg = PortfolioConfig::new(cfg);
        pcfg.batch = 2;
        pcfg.threads = 2;
        let res = solve_portfolio(&dag, &m, &db, &parts, &reg, "pl/eft-p", &pcfg);

        assert_eq!(res.best_cost.to_bits(), base.makespan.to_bits(), "incumbent is the initial state");
        assert_eq!(res.best_iter, 0);
        assert_eq!(res.best_dag.frontier(), frontier0, "the DAG must be left exactly as it was");
        assert!(!res.history.is_empty());
        for h in &res.history {
            assert!(h.action.is_some(), "the attempted primary move is recorded: {h:?}");
            assert!(!h.applied, "{h:?}");
            assert!(h.evaluated >= 1, "{h:?}");
            assert_eq!(h.rejected, h.evaluated, "every candidate must be rejected: {h:?}");
            assert_eq!(h.cost.to_bits(), base.makespan.to_bits(), "state never changes: {h:?}");
        }
    }

    #[test]
    fn portfolio_single_lane_batch_one_matches_classic_walk() {
        let (m, db) = setup();
        let parts = PartitionerSet::standard();
        let reg = crate::coordinator::policy::PolicyRegistry::standard();
        let mut cfg = SolverConfig::all_soft(simcfg(), 10, 64);
        cfg.seed = 9;
        let legacy = solve(cholesky::root(512), &m, &db, &parts, cfg);
        let port = solve_portfolio(&cholesky::root(512), &m, &db, &parts, &reg, "pl/eft-p", &PortfolioConfig::new(cfg));
        assert_eq!(legacy.best_cost.to_bits(), port.best_cost.to_bits());
        assert_eq!(legacy.best_iter, port.best_iter);
        assert_eq!(legacy.history.len(), port.history.len());
        for (a, b) in legacy.history.iter().zip(&port.history) {
            assert_eq!(a.action, b.action);
            assert_eq!(a.cost.to_bits(), b.cost.to_bits());
            assert_eq!(a.applied, b.applied);
        }
        assert_eq!(port.lane, 0);
        assert_eq!(port.lane_costs.len(), 1);
    }

    #[test]
    fn portfolio_thread_count_never_changes_the_result() {
        let (m, db) = setup();
        let parts = PartitionerSet::standard();
        let reg = crate::coordinator::policy::PolicyRegistry::standard();
        let mut cfg = SolverConfig::all_soft(simcfg(), 8, 64);
        cfg.seed = 21;
        let mut p1 = PortfolioConfig::new(cfg);
        p1.lanes = 3;
        p1.batch = 2;
        p1.threads = 1;
        let mut p4 = p1.clone();
        p4.threads = 4;
        let dag = cholesky::root(512);
        let r1 = solve_portfolio(&dag, &m, &db, &parts, &reg, "pl/eft-p", &p1);
        let r4 = solve_portfolio(&dag, &m, &db, &parts, &reg, "pl/eft-p", &p4);
        assert_eq!(result_json(&r1), result_json(&r4), "canonical bytes must not depend on threads");
        assert_eq!(r1.lane, r4.lane);
        assert_eq!(r1.lane_costs.len(), 3);
        // the winner is the lane minimum
        assert!(r1.lane_costs.iter().all(|&c| r1.best_cost <= c));
        // and the portfolio never loses to its own single-lane prefix
        assert!(r1.best_cost <= r1.lane_costs[0]);
    }

    #[test]
    fn lane_seeds_are_content_derived_and_distinct() {
        let a = lane_seed(7, 1, "pl/eft-p", Sampling::Soft, CandidateSelect::All);
        assert_eq!(a, lane_seed(7, 1, "pl/eft-p", Sampling::Soft, CandidateSelect::All));
        assert_ne!(a, lane_seed(7, 2, "pl/eft-p", Sampling::Soft, CandidateSelect::All));
        assert_ne!(a, lane_seed(8, 1, "pl/eft-p", Sampling::Soft, CandidateSelect::All));
        assert_ne!(a, lane_seed(7, 1, "pl/affinity", Sampling::Soft, CandidateSelect::All));
        assert_ne!(a, lane_seed(7, 1, "pl/eft-p", Sampling::Hard, CandidateSelect::All));
        assert_ne!(a, lane_seed(7, 1, "pl/eft-p", Sampling::Soft, CandidateSelect::Shallow));
    }

    #[test]
    fn hard_batch_matches_the_classic_argmax_and_orders_by_score() {
        let cands = vec![
            (Action::Merge { cluster: 0 }, 1.0),
            (Action::Merge { cluster: 1 }, 3.0),
            (Action::Merge { cluster: 2 }, 3.0),
            (Action::Merge { cluster: 3 }, 2.0),
        ];
        let mut rng = Rng::new(0);
        let legacy = cands
            .iter()
            .enumerate()
            .max_by(|(_, a), (_, b)| a.1.total_cmp(&b.1))
            .map(|(i, _)| i)
            .unwrap();
        let picked = sample_batch(&cands, 3, Sampling::Hard, &mut rng);
        assert_eq!(picked[0], legacy, "first Hard pick is the classic argmax (last max wins ties)");
        assert_eq!(picked, vec![2, 1, 3]);

        // Soft without replacement: k distinct indices
        let mut rng = Rng::new(5);
        let soft = sample_batch(&cands, 4, Sampling::Soft, &mut rng);
        let mut s = soft.clone();
        s.sort();
        s.dedup();
        assert_eq!(s.len(), 4);

        // Soft k=1 consumes exactly the classic single weighted draw
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let w: Vec<f64> = cands.iter().map(|c| c.1).collect();
        assert_eq!(sample_batch(&cands, 1, Sampling::Soft, &mut r1)[0], r2.weighted(&w));
        assert_eq!(r1.next_u64(), r2.next_u64(), "RNG streams stay aligned");
    }

    #[test]
    fn non_finite_scores_are_filtered_at_source() {
        // an inf-duration assignment (what a zero-rate curve produces for
        // any task landed on that processor) used to push a +inf partition
        // score; one inf weight degenerates Soft sampling's weighted draw
        let (m, db) = setup();
        let parts = PartitionerSet::standard();
        let cfg = SolverConfig::all_soft(simcfg(), 1, 64);
        // s = 2: four nearly-serial tasks, so the untouched ones keep
        // plenty of idle parallelism around them (finite positive scores)
        let mut dag = cholesky::root(1024);
        parts.apply(&mut dag, 0, 512).expect("partition root at 512");
        let flat = dag.flat_dag();
        let mut sched = simulate(&dag, &m, &db, simcfg());
        let last = sched.assignments.len() - 1;
        sched.assignments[last].end = f64::INFINITY;

        let cands = collect_candidates(&dag, &flat, &sched, &m, &db, &parts, &cfg);
        assert!(!cands.is_empty(), "finite candidates must survive");
        assert!(cands.iter().all(|(_, s)| s.is_finite() && *s > 0.0), "{cands:?}");
        // and the surviving weights sample without panicking
        let weights: Vec<f64> = cands.iter().map(|c| c.1).collect();
        let idx = Rng::new(1).weighted(&weights);
        assert!(idx < weights.len());
    }

    #[test]
    fn zero_rate_curve_does_not_poison_soft_solve() {
        // a curve that is zero below 256: estimates at finer grains are
        // inf and must never become sampled weights; the solve completes
        let mut b = MachineBuilder::new("z");
        let h = b.space("host", u64::MAX);
        b.main(h);
        let t = b.proc_type("cpu", 1.0, 0.1);
        b.processors(4, "c", t, h);
        let m = b.build();
        let mut db = PerfDb::new();
        db.set_fallback(
            0,
            PerfCurve::Table { points: vec![(64.0, 0.0), (128.0, 0.0), (256.0, 20.0), (512.0, 30.0)] },
        );
        let parts = PartitionerSet::standard();
        let mut dag = cholesky::root(1024);
        parts.apply(&mut dag, 0, 256).expect("partition root at 256");
        let mut cfg = SolverConfig::all_soft(simcfg(), 10, 64);
        cfg.seed = 3;
        let res = solve(dag, &m, &db, &parts, cfg);
        assert!(res.best_cost.is_finite());
        for h in &res.history {
            assert!(h.score.is_finite(), "sampled score must be finite: {h:?}");
        }
        // no leaf may have been split into the zero-rate region
        for t in res.best_dag.frontier() {
            assert!(res.best_dag.task(t).char_edge() >= 256.0 - 1e-9);
        }
    }

    #[test]
    fn delta_mode_on_matches_off_bitwise() {
        // the tentpole invariant: incremental re-simulation may only be
        // an execution strategy — the canonical result bytes (history,
        // costs, winner lane) must be exactly those of full evaluation
        let (m, db) = setup();
        let parts = PartitionerSet::standard();
        let reg = crate::coordinator::policy::PolicyRegistry::standard();
        let mut cfg = SolverConfig::all_soft(simcfg(), 10, 64);
        cfg.seed = 17;
        let mut off = PortfolioConfig::new(cfg);
        off.lanes = 2;
        off.batch = 3;
        off.threads = 2;
        let mut on = off.clone();
        on.delta = DeltaMode::On;
        let dag = cholesky::root(1024);
        let r_off = solve_portfolio(&dag, &m, &db, &parts, &reg, "pl/eft-p", &off);
        let r_on = solve_portfolio(&dag, &m, &db, &parts, &reg, "pl/eft-p", &on);
        assert_eq!(result_json(&r_off), result_json(&r_on), "delta must be invisible in the bytes");

        let s_on = r_on.replay_stats();
        let s_off = r_off.replay_stats();
        assert_eq!(s_off, ReplayStats::default(), "off mode never touches the counters");
        assert!(s_on.events_total > 0, "{s_on:?}");
        assert!(s_on.events_replayed <= s_on.events_total, "{s_on:?}");
        assert!(s_on.replay_fraction() >= 0.0 && s_on.replay_fraction() <= 1.0);
    }

    #[test]
    fn delta_with_ineligible_policy_degrades_to_counted_full_runs() {
        // fcfs/r-p's Random processor select is stateful (it consumes the
        // engine RNG), so no forced-prefix plan can be proven; delta mode
        // must fall back to full evaluation — same bytes, counted as such
        let (m, db) = setup();
        let parts = PartitionerSet::standard();
        let reg = crate::coordinator::policy::PolicyRegistry::standard();
        let mut cfg = SolverConfig::all_soft(simcfg(), 6, 64);
        cfg.seed = 4;
        let mut off = PortfolioConfig::new(cfg);
        off.batch = 2;
        let mut on = off.clone();
        on.delta = DeltaMode::On;
        let dag = cholesky::root(512);
        let r_off = solve_portfolio(&dag, &m, &db, &parts, &reg, "fcfs/r-p", &off);
        let r_on = solve_portfolio(&dag, &m, &db, &parts, &reg, "fcfs/r-p", &on);
        assert_eq!(result_json(&r_off), result_json(&r_on));
        let st = r_on.replay_stats();
        assert_eq!(st.events_total, 0, "the scan never engages: {st:?}");
        assert_eq!(st.events_replayed, 0, "{st:?}");
        let simulated: u64 =
            r_on.history.iter().map(|h| (h.evaluated - h.rejected) as u64).sum();
        assert!(st.full_fallbacks >= simulated, "every simulated candidate is a full run: {st:?}");
    }

    #[test]
    fn empty_fault_ensemble_is_bitwise_the_fault_free_portfolio() {
        // `--faults off.toml` must not perturb a single byte: an empty
        // spec normalizes to no pricing at all (a 1:1 "mean" would
        // re-associate the float arithmetic)
        use crate::coordinator::faults::{FaultEnsemble, FaultSpec};
        let (m, db) = setup();
        let parts = PartitionerSet::standard();
        let reg = crate::coordinator::policy::PolicyRegistry::standard();
        let mut cfg = SolverConfig::all_soft(simcfg(), 8, 64);
        cfg.seed = 13;
        let base = PortfolioConfig::new(cfg);
        let mut off = base.clone();
        off.faults = Some(FaultEnsemble::new(FaultSpec::named("off"), 3));
        let dag = cholesky::root(512);
        let r0 = solve_portfolio(&dag, &m, &db, &parts, &reg, "pl/eft-p", &base);
        let r1 = solve_portfolio(&dag, &m, &db, &parts, &reg, "pl/eft-p", &off);
        assert_eq!(result_json(&r0), result_json(&r1), "an empty spec must price nothing in");
    }

    #[test]
    fn fault_aware_pricing_is_reproducible_and_forces_delta_off() {
        // a permanent half-speed window on every processor: no attempt
        // ever faults, every member completes, so the expectation is
        // finite — and the replay counters must stay zero even with
        // delta requested, because plans are only proven fault-free
        use crate::coordinator::faults::{FaultEnsemble, FaultSpec, ThrottleWindow};
        let (m, db) = setup();
        let parts = PartitionerSet::standard();
        let reg = crate::coordinator::policy::PolicyRegistry::standard();
        let mut cfg = SolverConfig::all_soft(simcfg(), 6, 64);
        cfg.seed = 5;
        let mut spec = FaultSpec::named("half-speed");
        for p in 0..4 {
            spec.throttle.push(ThrottleWindow { proc: p, from: 0.0, to: 1e9, factor: 0.5 });
        }
        let mut pcfg = PortfolioConfig::new(cfg);
        pcfg.faults = Some(FaultEnsemble::new(spec, 3));
        pcfg.delta = DeltaMode::On;
        let dag = cholesky::root(512);
        let r1 = solve_portfolio(&dag, &m, &db, &parts, &reg, "pl/eft-p", &pcfg);
        assert!(r1.best_cost.is_finite(), "throttle-only members always complete");
        assert_eq!(r1.replay_stats(), ReplayStats::default(), "fault pricing forces delta off");
        let r2 = solve_portfolio(&dag, &m, &db, &parts, &reg, "pl/eft-p", &pcfg);
        assert_eq!(result_json(&r1), result_json(&r2), "fault-aware solves replay bit-for-bit");
    }

    #[test]
    fn ensemble_members_that_cannot_complete_price_as_infinite() {
        // rate 1.0 with a 2-attempt budget: every task faults on every
        // attempt, every member exhausts, so every candidate — and the
        // incumbent — prices to INFINITY and nothing is ever accepted
        use crate::coordinator::faults::{FaultEnsemble, FaultSpec};
        let (m, db) = setup();
        let parts = PartitionerSet::standard();
        let reg = crate::coordinator::policy::PolicyRegistry::standard();
        let mut cfg = SolverConfig::all_soft(simcfg(), 4, 64);
        cfg.seed = 2;
        let mut spec = FaultSpec::named("hopeless");
        spec.transient_rate = 1.0;
        spec.max_attempts = 2;
        let mut pcfg = PortfolioConfig::new(cfg);
        pcfg.faults = Some(FaultEnsemble::new(spec, 2));
        let res = solve_portfolio(&cholesky::root(512), &m, &db, &parts, &reg, "pl/eft-p", &pcfg);
        assert!(res.best_cost.is_infinite(), "no member ever completes: {}", res.best_cost);
        assert!(res.history.iter().all(|h| !h.applied), "every candidate must be rejected");
    }
}
