//! Per-(processor-type, task-kind, tile-size) performance models.
//!
//! HeSP estimates task delays from models extracted a priori (paper §2.1,
//! "Performance and data transfer models"). Two model families are
//! supported:
//!
//! * [`PerfCurve::Saturating`] — an analytic efficiency curve
//!   `gflops(b) = peak * b^k / (b^k + h^k)`: performance saturates toward
//!   `peak` as the tile edge grows, with `h` the half-saturation edge.
//!   GPUs get large `h` (need big tiles to fill the device), CPUs small
//!   `h` (near-peak on small tiles) — exactly the shape that creates the
//!   scheduling-partitioning trade-off the paper studies.
//! * [`PerfCurve::Table`] — log-linear interpolation through measured
//!   `(edge, gflops)` samples; the *measured* models the real-execution
//!   validation platform uses (runtime::executor extracts them).

use super::platform::{Machine, ProcTypeId};
use super::task::TaskKind;
use crate::util::fxhash::FxHashMap;

/// GFLOPS as a function of tile edge.
#[derive(Debug, Clone, PartialEq)]
pub enum PerfCurve {
    /// `gflops(b) = peak * b^k / (b^k + h^k)`.
    Saturating { peak: f64, half: f64, exponent: f64 },
    /// Piecewise log-linear through sorted `(edge, gflops)` samples.
    Table { points: Vec<(f64, f64)> },
    /// Size-independent rate (useful in unit tests).
    Const { gflops: f64 },
}

impl PerfCurve {
    pub fn gflops(&self, edge: f64) -> f64 {
        match self {
            PerfCurve::Saturating { peak, half, exponent } => {
                let bk = edge.max(1.0).powf(*exponent);
                let hk = half.powf(*exponent);
                peak * bk / (bk + hk)
            }
            PerfCurve::Table { points } => {
                assert!(!points.is_empty(), "empty perf table");
                if points.len() == 1 {
                    return points[0].1;
                }
                let e = edge.max(1.0);
                // clamp outside range, log-linear inside
                if e <= points[0].0 {
                    return points[0].1;
                }
                if e >= points[points.len() - 1].0 {
                    return points[points.len() - 1].1;
                }
                let i = points.partition_point(|p| p.0 <= e) - 1;
                let (x0, y0) = points[i];
                let (x1, y1) = points[i + 1];
                let t = (e.ln() - x0.ln()) / (x1.ln() - x0.ln());
                y0 + t * (y1 - y0)
            }
            PerfCurve::Const { gflops } => *gflops,
        }
    }

    /// Execution time in seconds for `flops` at tile edge `edge`.
    pub fn time(&self, edge: f64, flops: f64) -> f64 {
        flops / (self.gflops(edge).max(1e-9) * 1e9)
    }
}

/// The performance database: curve per (processor type, task kind), plus a
/// per-type fallback and fixed per-task launch overhead.
#[derive(Debug, Clone, Default)]
pub struct PerfDb {
    curves: FxHashMap<(ProcTypeId, TaskKind), PerfCurve>,
    fallback: FxHashMap<ProcTypeId, PerfCurve>,
    /// Fixed per-task overhead in seconds (kernel launch, runtime cost).
    overhead: FxHashMap<ProcTypeId, f64>,
}

impl PerfDb {
    pub fn new() -> PerfDb {
        PerfDb::default()
    }

    pub fn set(&mut self, ptype: ProcTypeId, kind: TaskKind, curve: PerfCurve) -> &mut Self {
        self.curves.insert((ptype, kind), curve);
        self
    }

    /// Curve used for any task kind without a specific entry.
    pub fn set_fallback(&mut self, ptype: ProcTypeId, curve: PerfCurve) -> &mut Self {
        self.fallback.insert(ptype, curve);
        self
    }

    pub fn set_overhead(&mut self, ptype: ProcTypeId, seconds: f64) -> &mut Self {
        self.overhead.insert(ptype, seconds);
        self
    }

    pub fn curve(&self, ptype: ProcTypeId, kind: TaskKind) -> &PerfCurve {
        self.try_curve(ptype, kind)
            .unwrap_or_else(|| panic!("no perf model for proc type {ptype} task {}", kind.name()))
    }

    /// Non-panicking curve lookup: specific entry, then per-type fallback.
    pub fn try_curve(&self, ptype: ProcTypeId, kind: TaskKind) -> Option<&PerfCurve> {
        self.curves.get(&(ptype, kind)).or_else(|| self.fallback.get(&ptype))
    }

    /// Static sanity diagnostics for this database against a machine, as
    /// `(key, message)` pairs keyed by config entity (`perf.<type>.<kind>`
    /// / `perf.<type>.default`). Probes each curve over a spread of tile
    /// edges and rejects zero/negative/non-finite rates — the class of
    /// silent poison that skews any policy comparison downstream. Never
    /// panics; `hesp check` calls this before any simulation.
    pub fn diagnostics(&self, machine: &Machine) -> Vec<(String, String)> {
        const PROBE_EDGES: [f64; 5] = [32.0, 64.0, 256.0, 1024.0, 4096.0];
        let mut out = Vec::new();
        for pt in &machine.proc_types {
            // detlint: allow(det/hashmap-iter) — kinds are collected and sorted by name before use
            let of_type = self.curves.keys().filter(|(t, _)| *t == pt.id);
            let mut kinds: Vec<TaskKind> = of_type.map(|&(_, k)| k).collect();
            kinds.sort_by_key(|k| k.name());
            if kinds.is_empty() && !self.fallback.contains_key(&pt.id) {
                out.push((
                    format!("perf.{}", pt.name),
                    "no perf model and no default curve for this processor type".to_string(),
                ));
                continue;
            }
            let mut probe = |key: String, curve: &PerfCurve| {
                if matches!(curve, PerfCurve::Table { points } if points.is_empty()) {
                    out.push((key, "perf table has no sample points".to_string()));
                    return;
                }
                for e in PROBE_EDGES {
                    let g = curve.gflops(e);
                    if !g.is_finite() || g <= 0.0 {
                        out.push((key, format!("curve yields non-positive rate {g} at tile edge {e}")));
                        return;
                    }
                }
            };
            for k in kinds {
                if let Some(c) = self.curves.get(&(pt.id, k)) {
                    probe(format!("perf.{}.{}", pt.name, k.name()), c);
                }
            }
            if let Some(c) = self.fallback.get(&pt.id) {
                probe(format!("perf.{}.default", pt.name), c);
            }
            if let Some(&ov) = self.overhead.get(&pt.id) {
                if !ov.is_finite() || ov < 0.0 {
                    out.push((
                        format!("perf.{}.overhead", pt.name),
                        format!("per-task overhead {ov} must be finite and non-negative"),
                    ));
                }
            }
        }
        out
    }

    /// Predicted delay of a task (kind, tile edge, flops) on `ptype`.
    pub fn time(&self, ptype: ProcTypeId, kind: TaskKind, edge: f64, flops: f64) -> f64 {
        self.curve(ptype, kind).time(edge, flops) + self.overhead.get(&ptype).copied().unwrap_or(0.0)
    }

    /// Average delay across the given processor-type multiset — the task
    /// "critical time" basis of the PL ordering (paper §2.1).
    pub fn avg_time(&self, ptypes: &[ProcTypeId], kind: TaskKind, edge: f64, flops: f64) -> f64 {
        assert!(!ptypes.is_empty());
        ptypes.iter().map(|&t| self.time(t, kind, edge, flops)).sum::<f64>() / ptypes.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn saturating_shape() {
        let c = PerfCurve::Saturating { peak: 1000.0, half: 512.0, exponent: 2.0 };
        assert!((c.gflops(512.0) - 500.0).abs() < 1e-9);
        assert!(c.gflops(64.0) < 20.0);
        assert!(c.gflops(4096.0) > 980.0);
        // monotone increasing
        let mut prev = 0.0;
        for b in [16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1024.0, 2048.0] {
            let g = c.gflops(b);
            assert!(g > prev);
            prev = g;
        }
    }

    #[test]
    fn table_interpolation_and_clamping() {
        let c = PerfCurve::Table { points: vec![(64.0, 10.0), (256.0, 40.0), (1024.0, 80.0)] };
        assert_eq!(c.gflops(32.0), 10.0);
        assert_eq!(c.gflops(64.0), 10.0);
        assert_eq!(c.gflops(4096.0), 80.0);
        let mid = c.gflops(128.0); // halfway in log space between 64 and 256
        assert!((mid - 25.0).abs() < 1e-9, "mid={mid}");
        assert!((c.gflops(512.0) - 60.0).abs() < 1e-9);
    }

    #[test]
    fn time_is_flops_over_rate() {
        let c = PerfCurve::Const { gflops: 2.0 };
        assert!((c.time(128.0, 4e9) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn db_lookup_and_fallback() {
        let mut db = PerfDb::new();
        db.set(0, TaskKind::Gemm, PerfCurve::Const { gflops: 100.0 });
        db.set_fallback(0, PerfCurve::Const { gflops: 10.0 });
        assert_eq!(db.curve(0, TaskKind::Gemm).gflops(64.0), 100.0);
        assert_eq!(db.curve(0, TaskKind::Trsm).gflops(64.0), 10.0);
    }

    #[test]
    #[should_panic]
    fn db_missing_model_panics() {
        PerfDb::new().curve(3, TaskKind::Gemm);
    }

    #[test]
    fn overhead_added() {
        let mut db = PerfDb::new();
        db.set(0, TaskKind::Gemm, PerfCurve::Const { gflops: 1.0 });
        db.set_overhead(0, 0.5);
        assert!((db.time(0, TaskKind::Gemm, 64.0, 1e9) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn avg_time_mixes_types() {
        let mut db = PerfDb::new();
        db.set(0, TaskKind::Gemm, PerfCurve::Const { gflops: 1.0 }); // 1s per gflop
        db.set(1, TaskKind::Gemm, PerfCurve::Const { gflops: 3.0 }); // 1/3s
        let avg = db.avg_time(&[0, 1], TaskKind::Gemm, 64.0, 1e9);
        assert!((avg - (1.0 + 1.0 / 3.0) / 2.0).abs() < 1e-12);
    }

    #[test]
    fn gpu_needs_big_tiles_cpu_does_not() {
        // the heterogeneity premise: at small tiles CPU wins, at large GPU
        let cpu = PerfCurve::Saturating { peak: 40.0, half: 64.0, exponent: 2.0 };
        let gpu = PerfCurve::Saturating { peak: 2000.0, half: 1024.0, exponent: 2.0 };
        assert!(cpu.gflops(64.0) > gpu.gflops(64.0) * 0.9 || cpu.gflops(64.0) > 15.0);
        assert!(gpu.gflops(64.0) < cpu.gflops(64.0) * 2.0);
        assert!(gpu.gflops(2048.0) > cpu.gflops(2048.0) * 10.0);
    }
}
