//! Deterministic job-arrival processes for the service layer.
//!
//! A stream is a list of [`JobSpec`]s — *what* arrives *when* — produced
//! by one of three processes: Poisson (memoryless open traffic), bursty
//! (a two-state Markov-modulated Poisson process: quiet vs burst rate
//! with exponentially distributed dwell times), or replay of a JSONL
//! trace file. Generated streams are pure functions of the spec label
//! and the declared seed through the shared [`content_seed`] recipe
//! (FxHash + separators, mixed once through SplitMix64) — deliberately
//! *not* of platform or policy, so every cell of a serve grid schedules
//! the identical stream and cross-policy comparisons never rank whoever
//! drew the lighter traffic.

use crate::coordinator::sweep::Workload;
use crate::util::fxhash::content_seed;
use crate::util::json;
use crate::util::rng::Rng;

/// A job's completion requirement.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Deadline {
    /// No deadline: the job only counts toward sojourn metrics.
    None,
    /// Absolute deadline instant (trace replay declares these).
    At(f64),
    /// Relative: `arrival + slack * makespan_lower_bound(job)` — resolved
    /// at admission, once the job's DAG (and hence its bound) exists.
    Slack(f64),
}

/// One job of a stream: an arrival instant plus everything needed to
/// build its DAG ([`Workload::build`] at `tile`). `id` is the stream
/// position (arrival order), assigned by the generator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JobSpec {
    pub id: usize,
    pub t_arrival: f64,
    pub workload: Workload,
    pub tile: u32,
    pub deadline: Deadline,
    /// Priority class (an index into the generator's class table for
    /// generated streams; free-form for traces). Fairness metrics group
    /// completed jobs by this value.
    pub priority: u8,
}

/// The generated job mix: `(workload, tile, weight)`. Sizes straddle an
/// order of magnitude so job-aware orderings have something to exploit —
/// the 2048 Cholesky is ~8x the work of the 1024 one.
const JOB_MIX: &[(Workload, u32, f64)] = &[
    (Workload::Cholesky { n: 1024 }, 256, 3.0),
    (Workload::Layered { layers: 3, width: 4 }, 256, 2.0),
    (Workload::Cholesky { n: 2048 }, 256, 1.0),
];

/// Priority classes for generated streams: `(weight, deadline slack)`.
/// Class index is the job's `priority`; slack multiplies the job's
/// makespan lower bound into a relative deadline.
const CLASSES: &[(f64, f64)] = &[(1.0, 4.0), (2.0, 8.0), (1.0, 16.0)];

/// An arrival process, parsed from / printed as a stable label (a CSV
/// key, like [`Workload::label`]).
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalSpec {
    /// Memoryless arrivals at `rate` jobs/s.
    Poisson { rate: f64 },
    /// Two-state MMPP: `lo` jobs/s in the quiet state, `hi` in bursts,
    /// exponential state dwell with mean `dwell` seconds.
    Bursty { lo: f64, hi: f64, dwell: f64 },
    /// Replay a JSONL trace file (one job object per line).
    Trace { path: String },
}

impl ArrivalSpec {
    /// Stable label — the spec syntax [`ArrivalSpec::parse`] accepts back.
    pub fn label(&self) -> String {
        match self {
            ArrivalSpec::Poisson { rate } => format!("poisson:{rate}"),
            ArrivalSpec::Bursty { lo, hi, dwell } => format!("bursty:{lo}:{hi}:{dwell}"),
            ArrivalSpec::Trace { path } => format!("trace:{path}"),
        }
    }

    /// Parse `poisson:<rate>`, `bursty:<lo>:<hi>:<dwell>`, `trace:<path>`.
    /// Bare `poisson` / `bursty` take the default parameters. Rates and
    /// dwell must be positive and finite.
    pub fn parse(s: &str) -> Option<ArrivalSpec> {
        let (name, arg) = match s.split_once(':') {
            Some((n, a)) => (n, a),
            None => (s, ""),
        };
        let pos = |x: f64| -> Option<f64> {
            (x.is_finite() && x > 0.0).then_some(x)
        };
        match name.to_ascii_lowercase().as_str() {
            "poisson" => {
                let rate = if arg.is_empty() { 8.0 } else { arg.parse().ok()? };
                Some(ArrivalSpec::Poisson { rate: pos(rate)? })
            }
            "bursty" => {
                if arg.is_empty() {
                    return Some(ArrivalSpec::Bursty { lo: 3.0, hi: 25.0, dwell: 0.15 });
                }
                let mut it = arg.split(':');
                let lo = pos(it.next()?.parse().ok()?)?;
                let hi = pos(it.next()?.parse().ok()?)?;
                let dwell = pos(it.next()?.parse().ok()?)?;
                if it.next().is_some() {
                    return None;
                }
                Some(ArrivalSpec::Bursty { lo, hi, dwell })
            }
            "trace" => {
                if arg.is_empty() {
                    return None;
                }
                Some(ArrivalSpec::Trace { path: arg.to_string() })
            }
            _ => None,
        }
    }

    /// Materialize the stream over `[0, duration)`. Generated processes
    /// derive their RNG from the spec label and `seed` only
    /// ([`stream_seed`]); trace replay reads the file, validates every
    /// job, and ignores `duration`/`seed` (a trace IS the stream).
    pub fn generate(&self, duration: f64, seed: u64) -> anyhow::Result<Vec<JobSpec>> {
        if let ArrivalSpec::Trace { path } = self {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("reading trace '{path}': {e}"))?;
            return parse_trace(&text);
        }
        let mut rng = Rng::new(stream_seed(&self.label(), seed));
        let mut out = Vec::new();
        let mut push = |t: f64, rng: &mut Rng, out: &mut Vec<JobSpec>| {
            let (workload, tile, _) = JOB_MIX[rng.weighted(&mix_weights())];
            let class = rng.weighted(&class_weights());
            out.push(JobSpec {
                id: out.len(),
                t_arrival: t,
                workload,
                tile,
                deadline: Deadline::Slack(CLASSES[class].1),
                priority: class as u8,
            });
        };
        match *self {
            ArrivalSpec::Poisson { rate } => {
                let mut t = exp_draw(&mut rng, rate);
                while t < duration {
                    push(t, &mut rng, &mut out);
                    t += exp_draw(&mut rng, rate);
                }
            }
            ArrivalSpec::Bursty { lo, hi, dwell } => {
                let mut t = 0.0;
                let mut burst = false;
                let mut switch = exp_draw(&mut rng, 1.0 / dwell);
                loop {
                    let rate = if burst { hi } else { lo };
                    let next = t + exp_draw(&mut rng, rate);
                    if next < switch {
                        t = next;
                        if t >= duration {
                            break;
                        }
                        push(t, &mut rng, &mut out);
                    } else {
                        // no arrival before the state flips: jump to
                        // the boundary and redraw at the new rate
                        // (valid by exponential memorylessness)
                        t = switch;
                        burst = !burst;
                        switch = t + exp_draw(&mut rng, 1.0 / dwell);
                        if t >= duration {
                            break;
                        }
                    }
                }
            }
            // handled by the early return above; no arrivals to draw
            ArrivalSpec::Trace { .. } => {}
        }
        Ok(out)
    }
}

fn mix_weights() -> Vec<f64> {
    JOB_MIX.iter().map(|&(_, _, w)| w).collect()
}

fn class_weights() -> Vec<f64> {
    CLASSES.iter().map(|&(w, _)| w).collect()
}

/// Exponential inter-event draw at `rate` events/s: `-ln(1-u)/rate`,
/// `u` uniform in `[0, 1)` so the argument stays in `(0, 1]`.
fn exp_draw(rng: &mut Rng, rate: f64) -> f64 {
    debug_assert!(rate > 0.0);
    -(1.0 - rng.next_f64()).ln() / rate
}

/// Deterministic stream seed: a function of the arrival-spec label and
/// the declared seed only — NOT of platform or policy, so every cell of
/// a serve grid replays the identical stream. One instantiation of the
/// shared [`content_seed`] recipe, like [`crate::coordinator::sweep::cell_seed`].
pub fn stream_seed(arrivals_label: &str, seed: u64) -> u64 {
    content_seed(&["serve-arrivals", arrivals_label], &[seed])
}

/// Parse a JSONL trace: one job object per line, e.g.
///
/// ```json
/// {"t_arrival": 0.05, "workload": "cholesky:1024", "tile": 256, "deadline": 0.8, "priority": 1}
/// ```
///
/// `deadline` is an absolute instant; absent or `null` means none, and a
/// deadline before the job's own arrival is rejected. An optional `id`
/// field is validated for uniqueness across the trace but *not*
/// preserved: stream ids are arrival positions (declared ids exist so a
/// concatenated or hand-merged trace surfaces its duplicates loudly).
/// `priority` defaults to 0. Blank lines are skipped. Jobs are stably
/// sorted by arrival time and re-numbered in that order, so a hand-edited
/// out-of-order trace still replays as a valid stream.
pub fn parse_trace(text: &str) -> anyhow::Result<Vec<JobSpec>> {
    use anyhow::anyhow;
    let mut out = Vec::new();
    let mut declared_ids: Vec<(usize, usize)> = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let lineno = i + 1;
        let Some((job, declared)) = parse_trace_line(lineno, line)? else {
            continue;
        };
        if let Some(id) = declared {
            if let Some(&(_, first)) = declared_ids.iter().find(|&&(d, _)| d == id) {
                return Err(anyhow!(
                    "trace line {lineno}: duplicate job id {id} (first declared on line {first})"
                ));
            }
            declared_ids.push((id, lineno));
        }
        out.push(job);
    }
    out.sort_by(|a, b| a.t_arrival.total_cmp(&b.t_arrival));
    for (i, j) in out.iter_mut().enumerate() {
        j.id = i;
    }
    Ok(out)
}

/// Parse and validate one trace line (`lineno` is 1-based, for
/// diagnostics). Returns `Ok(None)` for blank lines; otherwise the job
/// (with `id` still unassigned — [`parse_trace`] numbers the sorted
/// stream) plus any declared `id` for the caller's uniqueness check.
pub fn parse_trace_line(lineno: usize, line: &str) -> anyhow::Result<Option<(JobSpec, Option<usize>)>> {
    use anyhow::anyhow;
    let line = line.trim();
    if line.is_empty() {
        return Ok(None);
    }
    let v = json::parse(line).map_err(|e| anyhow!("trace line {lineno}: {e}"))?;
    let t_arrival = v
        .get("t_arrival")
        .and_then(|x| x.as_f64())
        .ok_or_else(|| anyhow!("trace line {lineno}: missing t_arrival"))?;
    if !t_arrival.is_finite() || t_arrival < 0.0 {
        return Err(anyhow!("trace line {lineno}: bad t_arrival {t_arrival}"));
    }
    let wl = v
        .get("workload")
        .and_then(|x| x.as_str())
        .ok_or_else(|| anyhow!("trace line {lineno}: missing workload"))?;
    let workload = Workload::parse(wl)
        .ok_or_else(|| anyhow!("trace line {lineno}: bad workload spec '{wl}'"))?;
    let tile = v
        .get("tile")
        .and_then(|x| x.as_f64())
        .ok_or_else(|| anyhow!("trace line {lineno}: missing tile"))? as u32;
    if !workload.feasible(tile) {
        return Err(anyhow!("trace line {lineno}: tile {tile} infeasible for '{wl}'"));
    }
    let deadline = match v.get("deadline") {
        None | Some(json::Json::Null) => Deadline::None,
        Some(d) => {
            let t = d
                .as_f64()
                .ok_or_else(|| anyhow!("trace line {lineno}: deadline must be a number or null"))?;
            if t < t_arrival {
                return Err(anyhow!(
                    "trace line {lineno}: deadline {t} precedes arrival {t_arrival}"
                ));
            }
            Deadline::At(t)
        }
    };
    let declared = match v.get("id") {
        None | Some(json::Json::Null) => None,
        Some(d) => Some(
            d.as_usize()
                .ok_or_else(|| anyhow!("trace line {lineno}: id must be a non-negative integer"))?,
        ),
    };
    let priority = v.get("priority").and_then(|x| x.as_f64()).unwrap_or(0.0) as u8;
    Ok(Some((JobSpec { id: 0, t_arrival, workload, tile, deadline, priority }, declared)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_round_trip() {
        for spec in [
            ArrivalSpec::Poisson { rate: 8.0 },
            ArrivalSpec::Poisson { rate: 2.5 },
            ArrivalSpec::Bursty { lo: 3.0, hi: 25.0, dwell: 0.15 },
            ArrivalSpec::Trace { path: "examples/serve_trace.jsonl".into() },
        ] {
            assert_eq!(ArrivalSpec::parse(&spec.label()), Some(spec.clone()), "{}", spec.label());
        }
        assert_eq!(ArrivalSpec::parse("poisson"), Some(ArrivalSpec::Poisson { rate: 8.0 }));
        assert_eq!(ArrivalSpec::parse("bursty"), Some(ArrivalSpec::Bursty { lo: 3.0, hi: 25.0, dwell: 0.15 }));
        assert!(ArrivalSpec::parse("poisson:0").is_none(), "zero rate rejected");
        assert!(ArrivalSpec::parse("poisson:-1").is_none());
        assert!(ArrivalSpec::parse("bursty:1:2").is_none(), "bursty needs three params");
        assert!(ArrivalSpec::parse("trace").is_none(), "trace needs a path");
        assert!(ArrivalSpec::parse("uniform:1").is_none());
    }

    #[test]
    fn generated_streams_are_deterministic_and_ordered() {
        let spec = ArrivalSpec::Poisson { rate: 50.0 };
        let a = spec.generate(2.0, 7).unwrap();
        let b = spec.generate(2.0, 7).unwrap();
        assert_eq!(a, b, "same label + seed => identical stream");
        assert!(!a.is_empty(), "50 jobs/s over 2 s should produce arrivals");
        assert!(a.windows(2).all(|w| w[0].t_arrival <= w[1].t_arrival), "sorted by arrival");
        assert!(a.iter().all(|j| j.t_arrival >= 0.0 && j.t_arrival < 2.0));
        assert!(a.iter().enumerate().all(|(i, j)| j.id == i), "ids are stream positions");
        assert!(a.iter().all(|j| j.workload.feasible(j.tile)));
        let c = spec.generate(2.0, 8).unwrap();
        assert_ne!(a, c, "different seed => different stream");
    }

    #[test]
    fn stream_is_a_function_of_the_label_not_the_struct() {
        // parse(label) must replay the exact stream of the original spec
        let spec = ArrivalSpec::Bursty { lo: 5.0, hi: 40.0, dwell: 0.1 };
        let reparsed = ArrivalSpec::parse(&spec.label()).unwrap();
        assert_eq!(spec.generate(2.0, 0).unwrap(), reparsed.generate(2.0, 0).unwrap());
    }

    #[test]
    fn bursty_rate_lands_between_the_two_states() {
        // equal expected dwell in each state => expected rate (lo+hi)/2;
        // loose 3x bounds keep this deterministic-seed test robust
        let spec = ArrivalSpec::Bursty { lo: 10.0, hi: 90.0, dwell: 0.2 };
        let n = spec.generate(10.0, 3).unwrap().len() as f64;
        assert!(n > 10.0 * 10.0 / 3.0, "{n} arrivals is below even the quiet state");
        assert!(n < 10.0 * 90.0, "{n} arrivals exceeds the burst state");
    }

    #[test]
    fn deadline_classes_cover_the_table() {
        let spec = ArrivalSpec::Poisson { rate: 100.0 };
        let jobs = spec.generate(3.0, 1).unwrap();
        for j in &jobs {
            assert!((j.priority as usize) < CLASSES.len());
            match j.deadline {
                Deadline::Slack(s) => assert_eq!(s, CLASSES[j.priority as usize].1),
                other => panic!("generated jobs carry slack deadlines, got {other:?}"),
            }
        }
        // with ~300 draws every class should appear
        for c in 0..CLASSES.len() {
            assert!(jobs.iter().any(|j| j.priority as usize == c), "class {c} never drawn");
        }
    }

    #[test]
    fn trace_round_trip_and_validation() {
        let text = r#"
{"t_arrival": 0.5, "workload": "cholesky:1024", "tile": 256, "deadline": 2.0, "priority": 1}

{"t_arrival": 0.1, "workload": "layered:3x4", "tile": 128}
{"t_arrival": 0.1, "workload": "stencil:4x2", "tile": 64, "deadline": null}
"#;
        let jobs = parse_trace(text).unwrap();
        assert_eq!(jobs.len(), 3);
        // stably sorted by arrival, re-numbered
        assert!(jobs.windows(2).all(|w| w[0].t_arrival <= w[1].t_arrival));
        assert_eq!(jobs[0].t_arrival, 0.1);
        assert_eq!(jobs[0].workload, Workload::Layered { layers: 3, width: 4 });
        assert_eq!(jobs[1].workload, Workload::Stencil { cells: 4, steps: 2 });
        assert_eq!(jobs[0].deadline, Deadline::None);
        assert_eq!(jobs[1].deadline, Deadline::None, "null deadline means none");
        assert_eq!(jobs[2].deadline, Deadline::At(2.0));
        assert_eq!(jobs[2].priority, 1);
        assert_eq!(jobs[0].priority, 0, "priority defaults to 0");
        assert_eq!((jobs[0].id, jobs[1].id, jobs[2].id), (0, 1, 2));

        assert!(parse_trace("{\"workload\": \"lu:1024\", \"tile\": 256}").is_err(), "missing t_arrival");
        assert!(parse_trace("{\"t_arrival\": 1, \"workload\": \"zzz\", \"tile\": 2}").is_err());
        assert!(
            parse_trace("{\"t_arrival\": 1, \"workload\": \"cholesky:1024\", \"tile\": 300}").is_err(),
            "infeasible tile rejected"
        );
        assert!(parse_trace("not json").is_err());
    }

    #[test]
    fn trace_rejects_duplicate_ids_and_early_deadlines() {
        let dup = "{\"t_arrival\": 0, \"workload\": \"cholesky:1024\", \"tile\": 256, \"id\": 3}\n{\"t_arrival\": 1, \"workload\": \"cholesky:1024\", \"tile\": 256, \"id\": 3}\n";
        let err = parse_trace(dup).unwrap_err().to_string();
        assert!(err.contains("duplicate job id 3"), "{err}");
        let early = "{\"t_arrival\": 2.0, \"workload\": \"cholesky:1024\", \"tile\": 256, \"deadline\": 1.0}\n";
        let err = parse_trace(early).unwrap_err().to_string();
        assert!(err.contains("precedes arrival"), "{err}");
        let ok = "{\"t_arrival\": 0, \"workload\": \"cholesky:1024\", \"tile\": 256, \"id\": 7}\n";
        assert_eq!(parse_trace(ok).unwrap()[0].id, 0, "stream ids are positions, not declared ids");
    }

    #[test]
    fn stream_seed_separates_labels_and_seeds() {
        let base = stream_seed("poisson:8", 0);
        assert_eq!(base, stream_seed("poisson:8", 0));
        assert_ne!(base, stream_seed("poisson:9", 0));
        assert_ne!(base, stream_seed("poisson:8", 1));
        assert_ne!(base, stream_seed("bursty:3:25:0.15", 0));
    }
}
