//! Service-level objectives of one stream scenario, and their canonical
//! CSV/JSON emission (the byte-stable twin-format bundle, like
//! [`crate::coordinator::sweep`]'s).
//!
//! Sojourn percentiles and means come from [`crate::util::stats`];
//! fairness is Jain's index over per-priority-class mean *slowdown*
//! (sojourn / makespan lower bound — raw sojourns would let one class of
//! intrinsically bigger jobs read as "unfair" on any policy).

use std::path::{Path, PathBuf};

use crate::util::json::Json;
use crate::util::stats::{jain, mean, percentile};

use super::sim::StreamOutcome;

/// One row of a serve bundle: a (platform, arrival process, policy)
/// scenario reduced to its service-level objectives.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeResult {
    pub platform: String,
    pub arrivals: String,
    pub policy: String,
    pub seed: u64,
    pub scenario_seed: u64,
    /// Arrival horizon (seconds); the run itself continues to drain.
    pub duration: f64,
    pub submitted: usize,
    pub completed: usize,
    pub rejected: usize,
    /// Completed jobs per second of drain time.
    pub throughput_jps: f64,
    pub p50_sojourn: f64,
    pub p99_sojourn: f64,
    pub mean_sojourn: f64,
    pub max_sojourn: f64,
    /// Mean sojourn / lower bound over completed jobs — how far service
    /// sits from each job's private best case.
    pub mean_slowdown: f64,
    /// Percent of deadline-carrying completed jobs that missed.
    pub deadline_miss_pct: f64,
    /// Jain's index over per-class mean slowdown: 1 = perfectly even.
    pub fairness: f64,
    pub avg_load_pct: f64,
    pub transfer_bytes: u64,
    /// When the system went empty.
    pub drain: f64,
    /// Deferred jobs that aged out of the backlog (`--max-defer`).
    pub expired: usize,
    /// Jobs that exhausted a fault attempt budget.
    pub failed: usize,
    /// Percent of busy seconds that produced surviving work:
    /// `100 * (busy - wasted) / busy`.
    pub goodput_pct: f64,
    /// Mean fault-to-restart latency over recovered attempts.
    pub mean_recovery_s: f64,
    pub faults_injected: usize,
}

/// Reduce a [`StreamOutcome`] to its scenario row.
pub fn summarize(
    platform: &str,
    arrivals: &str,
    policy: &str,
    seed: u64,
    scenario_seed: u64,
    duration: f64,
    out: &StreamOutcome,
) -> ServeResult {
    let mut sojourns: Vec<f64> = out.jobs.iter().map(|j| j.sojourn).collect();
    sojourns.sort_by(|a, b| a.total_cmp(b));
    let completed = sojourns.len();
    let (p50, p99, mean_s, max_s) = if completed == 0 {
        (0.0, 0.0, 0.0, 0.0)
    } else {
        (percentile(&sojourns, 0.5), percentile(&sojourns, 0.99), mean(&sojourns), sojourns[completed - 1])
    };

    let slowdowns: Vec<f64> =
        out.jobs.iter().filter(|j| j.lower_bound > 0.0).map(|j| j.sojourn / j.lower_bound).collect();
    let mean_slowdown = if slowdowns.is_empty() { 0.0 } else { mean(&slowdowns) };

    let with_deadline = out.jobs.iter().filter(|j| j.deadline.is_finite()).count();
    let missed = out.jobs.iter().filter(|j| j.missed).count();
    let deadline_miss_pct =
        if with_deadline == 0 { 0.0 } else { 100.0 * missed as f64 / with_deadline as f64 };

    // per-class mean slowdown, classes in ascending priority order
    let mut classes: Vec<u8> = out.jobs.iter().map(|j| j.priority).collect();
    classes.sort_unstable();
    classes.dedup();
    let class_means: Vec<f64> = classes
        .iter()
        .filter_map(|&c| {
            let xs: Vec<f64> = out
                .jobs
                .iter()
                .filter(|j| j.priority == c && j.lower_bound > 0.0)
                .map(|j| j.sojourn / j.lower_bound)
                .collect();
            (!xs.is_empty()).then(|| mean(&xs))
        })
        .collect();
    let fairness = jain(&class_means);

    let busy: f64 = out.proc_busy.iter().sum();
    let goodput_pct = if busy > 0.0 { 100.0 * (busy - out.wasted) / busy } else { 100.0 };
    let mean_recovery_s = if out.recovered > 0 { out.recovery_sum / out.recovered as f64 } else { 0.0 };

    let throughput_jps = if out.drain > 0.0 && out.drain.is_finite() { completed as f64 / out.drain } else { 0.0 };
    let avg_load_pct = if out.drain > 0.0 && !out.proc_busy.is_empty() {
        100.0 * out.proc_busy.iter().sum::<f64>() / (out.drain * out.proc_busy.len() as f64)
    } else {
        0.0
    };

    ServeResult {
        platform: platform.to_string(),
        arrivals: arrivals.to_string(),
        policy: policy.to_string(),
        seed,
        scenario_seed,
        duration,
        submitted: out.submitted,
        completed,
        rejected: out.rejected,
        throughput_jps,
        p50_sojourn: p50,
        p99_sojourn: p99,
        mean_sojourn: mean_s,
        max_sojourn: max_s,
        mean_slowdown,
        deadline_miss_pct,
        fairness,
        avg_load_pct,
        transfer_bytes: out.transfer_bytes,
        drain: out.drain,
        expired: out.expired,
        failed: out.failed,
        goodput_pct,
        mean_recovery_s,
        faults_injected: out.faults_injected,
    }
}

/// CSV header of [`to_csv`] rows.
pub const SERVE_CSV_HEADER: &str = "platform,arrivals,policy,seed,scenario_seed,duration_s,\
submitted,completed,rejected,throughput_jps,p50_sojourn_s,p99_sojourn_s,mean_sojourn_s,\
max_sojourn_s,mean_slowdown,deadline_miss_pct,fairness,avg_load_pct,transfer_bytes,drain_s";

/// Extra columns emitted when faults or `--max-defer` are active
/// (`ext = true`). Gated so fault-free bundles stay byte-identical to
/// their pre-fault goldens.
pub const SERVE_CSV_EXT: &str = ",expired,failed,goodput_pct,mean_recovery_s,faults_injected";

/// Serve results as CSV, one row per scenario in grid order. Fixed-width
/// float formatting keeps the output byte-stable across runs and thread
/// counts. `ext` appends the fault/expiry columns ([`SERVE_CSV_EXT`]).
pub fn to_csv(results: &[ServeResult], ext: bool) -> String {
    let mut out = String::with_capacity(160 * (results.len() + 1));
    out.push_str(SERVE_CSV_HEADER);
    if ext {
        out.push_str(SERVE_CSV_EXT);
    }
    out.push('\n');
    for r in results {
        out.push_str(&format!(
            "{},{},{},{},{},{:.3},{},{},{},{:.4},{:.6},{:.6},{:.6},{:.6},{:.4},{:.2},{:.4},{:.2},{},{:.6}",
            r.platform,
            r.arrivals,
            r.policy,
            r.seed,
            r.scenario_seed,
            r.duration,
            r.submitted,
            r.completed,
            r.rejected,
            r.throughput_jps,
            r.p50_sojourn,
            r.p99_sojourn,
            r.mean_sojourn,
            r.max_sojourn,
            r.mean_slowdown,
            r.deadline_miss_pct,
            r.fairness,
            r.avg_load_pct,
            r.transfer_bytes,
            r.drain,
        ));
        if ext {
            out.push_str(&format!(
                ",{},{},{:.2},{:.6},{}",
                r.expired, r.failed, r.goodput_pct, r.mean_recovery_s, r.faults_injected
            ));
        }
        out.push('\n');
    }
    out
}

/// Serve results as a JSON array (machine-readable twin of the CSV).
/// `ext` adds the fault/expiry keys, mirroring [`to_csv`]'s gating.
pub fn to_json(results: &[ServeResult], ext: bool) -> String {
    let arr: Vec<Json> = results
        .iter()
        .map(|r| {
            let mut o = std::collections::BTreeMap::new();
            o.insert("platform".into(), Json::Str(r.platform.clone()));
            o.insert("arrivals".into(), Json::Str(r.arrivals.clone()));
            o.insert("policy".into(), Json::Str(r.policy.clone()));
            o.insert("seed".into(), Json::Num(r.seed as f64));
            o.insert("duration_s".into(), Json::Num(r.duration));
            o.insert("submitted".into(), Json::Num(r.submitted as f64));
            o.insert("completed".into(), Json::Num(r.completed as f64));
            o.insert("rejected".into(), Json::Num(r.rejected as f64));
            o.insert("throughput_jps".into(), Json::Num(r.throughput_jps));
            o.insert("p50_sojourn_s".into(), Json::Num(r.p50_sojourn));
            o.insert("p99_sojourn_s".into(), Json::Num(r.p99_sojourn));
            o.insert("mean_sojourn_s".into(), Json::Num(r.mean_sojourn));
            o.insert("max_sojourn_s".into(), Json::Num(r.max_sojourn));
            o.insert("mean_slowdown".into(), Json::Num(r.mean_slowdown));
            o.insert("deadline_miss_pct".into(), Json::Num(r.deadline_miss_pct));
            o.insert("fairness".into(), Json::Num(r.fairness));
            o.insert("avg_load_pct".into(), Json::Num(r.avg_load_pct));
            o.insert("transfer_bytes".into(), Json::Num(r.transfer_bytes as f64));
            o.insert("drain_s".into(), Json::Num(r.drain));
            if ext {
                o.insert("expired".into(), Json::Num(r.expired as f64));
                o.insert("failed".into(), Json::Num(r.failed as f64));
                o.insert("goodput_pct".into(), Json::Num(r.goodput_pct));
                o.insert("mean_recovery_s".into(), Json::Num(r.mean_recovery_s));
                o.insert("faults_injected".into(), Json::Num(r.faults_injected as f64));
            }
            Json::Obj(o)
        })
        .collect();
    Json::Arr(arr).to_string()
}

/// Write the serve bundle: `out` (CSV) plus its `.json` twin next to it.
/// `ext` gates the fault/expiry columns in both files.
pub fn write_serve_bundle(out: &Path, results: &[ServeResult], ext: bool) -> std::io::Result<(PathBuf, PathBuf)> {
    if let Some(dir) = out.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(out, to_csv(results, ext))?;
    let json = out.with_extension("json");
    std::fs::write(&json, to_json(results, ext))?;
    Ok((out.to_path_buf(), json))
}

#[cfg(test)]
mod tests {
    use super::super::sim::{JobRecord, StreamOutcome};
    use super::*;

    fn rec(id: usize, priority: u8, sojourn: f64, lb: f64, deadline: f64, missed: bool) -> JobRecord {
        JobRecord {
            id,
            workload: "cholesky:1024".into(),
            tile: 256,
            priority,
            t_arrival: id as f64,
            admitted: id as f64,
            finished: id as f64 + sojourn,
            sojourn,
            lower_bound: lb,
            deadline,
            missed,
            n_tasks: 10,
        }
    }

    fn outcome(jobs: Vec<JobRecord>) -> StreamOutcome {
        StreamOutcome {
            jobs,
            submitted: 5,
            admitted: 4,
            rejected: 1,
            expired: 0,
            failed: 0,
            drain: 10.0,
            proc_busy: vec![5.0, 3.0],
            transfer_bytes: 1234,
            faults_injected: 0,
            recovered: 0,
            recovery_sum: 0.0,
            wasted: 0.0,
        }
    }

    #[test]
    fn summarize_closed_form() {
        let out = outcome(vec![
            rec(0, 0, 1.0, 0.5, 2.0, false),
            rec(1, 0, 2.0, 0.5, 2.0, false),
            rec(2, 1, 3.0, 1.0, 4.0, false),
            rec(3, 1, 4.0, 1.0, 4.0, true),
        ]);
        let r = summarize("p", "poisson:8", "pl/edf-p", 7, 99, 3.0, &out);
        assert_eq!((r.submitted, r.completed, r.rejected), (5, 4, 1));
        assert_eq!(r.seed, 7);
        assert_eq!(r.scenario_seed, 99);
        assert_eq!(r.p50_sojourn, 2.5, "median of 1,2,3,4");
        assert_eq!(r.max_sojourn, 4.0);
        assert_eq!(r.mean_sojourn, 2.5);
        // slowdowns: 2, 4, 3, 4 -> mean 3.25
        assert_eq!(r.mean_slowdown, 3.25);
        assert_eq!(r.deadline_miss_pct, 25.0, "1 of 4 deadline-carrying jobs missed");
        // class means: class 0 -> 3, class 1 -> 3.5; jain(3, 3.5)
        let expect = {
            let s = 3.0f64 + 3.5;
            s * s / (2.0 * (3.0f64 * 3.0 + 3.5 * 3.5))
        };
        assert!((r.fairness - expect).abs() < 1e-12);
        assert_eq!(r.throughput_jps, 0.4, "4 jobs over 10 s drain");
        assert_eq!(r.avg_load_pct, 40.0, "(5+3)/(2*10)");
        assert_eq!(r.transfer_bytes, 1234);
    }

    #[test]
    fn empty_outcome_summarizes_to_zeros() {
        let mut out = outcome(vec![]);
        out.submitted = 0;
        out.admitted = 0;
        out.rejected = 0;
        out.drain = 0.0;
        let r = summarize("p", "poisson:8", "pl/eft-p", 0, 1, 3.0, &out);
        assert_eq!(r.completed, 0);
        assert_eq!(r.p99_sojourn, 0.0);
        assert_eq!(r.throughput_jps, 0.0);
        assert_eq!(r.deadline_miss_pct, 0.0);
        assert_eq!(r.fairness, 1.0, "no classes, nothing unfair");
        assert_eq!(r.avg_load_pct, 0.0);
    }

    #[test]
    fn csv_and_json_agree_on_shape() {
        let out = outcome(vec![rec(0, 0, 1.0, 0.5, f64::INFINITY, false)]);
        let r = summarize("p", "bursty:3:25:0.15", "pl/sjf-p", 0, 42, 3.0, &out);
        let csv = to_csv(&[r.clone()], false);
        let mut lines = csv.lines();
        let header = lines.next().unwrap();
        let row = lines.next().unwrap();
        assert_eq!(header, SERVE_CSV_HEADER);
        assert_eq!(
            header.split(',').count(),
            row.split(',').count(),
            "every header column has a value"
        );
        assert!(row.starts_with("p,bursty:3:25:0.15,pl/sjf-p,0,42,"));
        let parsed = crate::util::json::parse(&to_json(&[r], false)).unwrap();
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("policy").and_then(|v| v.as_str()), Some("pl/sjf-p"));
        assert_eq!(arr[0].get("completed").and_then(|v| v.as_f64()), Some(1.0));
        // infinite deadline on the job, but the row itself stays finite
        assert_eq!(arr[0].get("deadline_miss_pct").and_then(|v| v.as_f64()), Some(0.0));
    }

    #[test]
    fn extended_columns_are_gated_and_computed() {
        let mut out = outcome(vec![rec(0, 0, 1.0, 0.5, f64::INFINITY, false)]);
        out.expired = 2;
        out.failed = 1;
        out.faults_injected = 4;
        out.recovered = 4;
        out.recovery_sum = 0.8;
        out.wasted = 2.0; // busy = 8.0 -> goodput 75%
        let r = summarize("p", "poisson:8", "pl/eft-p", 0, 1, 3.0, &out);
        assert_eq!(r.expired, 2);
        assert_eq!(r.failed, 1);
        assert!((r.goodput_pct - 75.0).abs() < 1e-12);
        assert!((r.mean_recovery_s - 0.2).abs() < 1e-12);
        // ext off: the row is byte-identical to the pre-fault layout
        let plain = to_csv(&[r.clone()], false);
        assert!(!plain.contains("goodput"), "gated columns stay out of plain bundles");
        let ext = to_csv(&[r.clone()], true);
        let header = ext.lines().next().unwrap();
        assert_eq!(header, format!("{SERVE_CSV_HEADER}{SERVE_CSV_EXT}"));
        let row = ext.lines().nth(1).unwrap();
        assert_eq!(header.split(',').count(), row.split(',').count());
        assert!(row.ends_with(",2,1,75.00,0.200000,4"), "{row}");
        let parsed = crate::util::json::parse(&to_json(&[r], true)).unwrap();
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr[0].get("faults_injected").and_then(|v| v.as_f64()), Some(4.0));
        assert_eq!(arr[0].get("goodput_pct").and_then(|v| v.as_f64()), Some(75.0));
    }

    #[test]
    fn infinite_deadlines_do_not_count_toward_misses() {
        let out = outcome(vec![
            rec(0, 0, 1.0, 0.5, f64::INFINITY, false),
            rec(1, 0, 2.0, 0.5, 1.5, true),
        ]);
        let r = summarize("p", "poisson:8", "pl/edf-p", 0, 1, 3.0, &out);
        assert_eq!(r.deadline_miss_pct, 100.0, "only the deadline-carrying job is in the base");
    }
}
