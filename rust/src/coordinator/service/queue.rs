//! Admission control for the service layer.
//!
//! The stream simulator holds at most `cap` jobs *resident* (admitted and
//! not yet drained). When a job arrives into a full system the
//! [`Admission`] policy decides its fate: `Reject` turns it away — loudly,
//! into [`JobQueue::rejected`], never silently — while `Defer` parks it in
//! an unbounded FIFO backlog that drains one job per completion. Every
//! submitted job is accounted for exactly once:
//!
//! ```text
//! submitted == admitted + rejected + pending
//! ```
//!
//! and that invariant is `debug_assert`ed on every transition.

use std::collections::VecDeque;

use super::arrivals::JobSpec;

/// What to do with an arrival when the system already holds `cap`
/// resident jobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Turn the job away; it is counted and reported, never scheduled.
    Reject,
    /// Park the job in FIFO backlog; it is admitted when a slot frees.
    Defer,
}

impl Admission {
    pub fn label(&self) -> &'static str {
        match self {
            Admission::Reject => "reject",
            Admission::Defer => "defer",
        }
    }

    pub fn parse(s: &str) -> Option<Admission> {
        match s.to_ascii_lowercase().as_str() {
            "reject" => Some(Admission::Reject),
            "defer" => Some(Admission::Defer),
            _ => None,
        }
    }
}

/// Bounded-residency admission queue. Owns the full accounting of a
/// stream: submissions, rejections, FIFO backlog, and resident count.
#[derive(Debug)]
pub struct JobQueue {
    cap: usize,
    admission: Admission,
    pending: VecDeque<JobSpec>,
    rejected: Vec<JobSpec>,
    submitted: usize,
    admitted: usize,
    resident: usize,
    expired: usize,
}

impl JobQueue {
    /// `cap` is the residency bound (min 1 — a cap of 0 could never admit
    /// anything and would deadlock a `Defer` queue).
    pub fn new(cap: usize, admission: Admission) -> JobQueue {
        JobQueue {
            cap: cap.max(1),
            admission,
            pending: VecDeque::new(),
            rejected: Vec::new(),
            submitted: 0,
            admitted: 0,
            resident: 0,
            expired: 0,
        }
    }

    /// Submit an arrival. Returns `Some(job)` when the job is admitted
    /// immediately; `None` when it was rejected or deferred (check
    /// [`rejected`](Self::rejected) / [`pending`](Self::pending)).
    pub fn offer(&mut self, job: JobSpec) -> Option<JobSpec> {
        self.submitted += 1;
        let out = if self.resident < self.cap {
            self.resident += 1;
            self.admitted += 1;
            Some(job)
        } else {
            match self.admission {
                Admission::Reject => {
                    self.rejected.push(job);
                    None
                }
                Admission::Defer => {
                    self.pending.push_back(job);
                    None
                }
            }
        };
        self.check();
        out
    }

    /// A resident job drained: free its slot and, if backlog is waiting,
    /// admit the head of the FIFO into the freed slot.
    pub fn on_job_done(&mut self) -> Option<JobSpec> {
        debug_assert!(self.resident > 0, "completion without a resident job");
        self.resident -= 1;
        let next = self.pending.pop_front();
        if next.is_some() {
            self.resident += 1;
            self.admitted += 1;
        }
        self.check();
        next
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn submitted(&self) -> usize {
        self.submitted
    }

    pub fn admitted(&self) -> usize {
        self.admitted
    }

    pub fn resident(&self) -> usize {
        self.resident
    }

    /// Expire deferred jobs older than `max_defer` at simulated time
    /// `now`: each timed-out job moves from the FIFO backlog to
    /// [`rejected`](Self::rejected) — loudly, counted in both the
    /// rejection list and [`expired`](Self::expired), never dropped.
    /// Returns how many expired in this call. The backlog is FIFO by
    /// arrival time, so expiry only ever takes a prefix.
    pub fn expire(&mut self, now: f64, max_defer: f64) -> usize {
        let mut n = 0;
        while self.pending.front().is_some_and(|head| head.t_arrival + max_defer < now) {
            if let Some(job) = self.pending.pop_front() {
                self.rejected.push(job);
                self.expired += 1;
                n += 1;
            }
        }
        if n > 0 {
            self.check();
        }
        n
    }

    /// Deferred jobs that timed out of the backlog (a subset of
    /// [`rejected`](Self::rejected)).
    pub fn expired(&self) -> usize {
        self.expired
    }

    /// Jobs currently deferred (FIFO order).
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Every job turned away, in submission order.
    pub fn rejected(&self) -> &[JobSpec] {
        &self.rejected
    }

    fn check(&self) {
        debug_assert!(self.resident <= self.cap);
        debug_assert_eq!(
            self.submitted,
            self.admitted + self.rejected.len() + self.pending.len(),
            "admission accounting must conserve jobs"
        );
        debug_assert!(
            self.expired <= self.rejected.len(),
            "every expired job must sit in the rejection list"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::super::arrivals::{Deadline, JobSpec};
    use super::*;
    use crate::coordinator::sweep::Workload;

    fn job(id: usize) -> JobSpec {
        JobSpec {
            id,
            t_arrival: id as f64 * 0.1,
            workload: Workload::Cholesky { n: 512 },
            tile: 128,
            deadline: Deadline::None,
            priority: 0,
        }
    }

    #[test]
    fn reject_mode_turns_overflow_away_loudly() {
        let mut q = JobQueue::new(2, Admission::Reject);
        assert!(q.offer(job(0)).is_some());
        assert!(q.offer(job(1)).is_some());
        assert!(q.offer(job(2)).is_none(), "third job exceeds cap 2");
        assert_eq!(q.submitted(), 3);
        assert_eq!(q.admitted(), 2);
        assert_eq!(q.resident(), 2);
        assert_eq!(q.pending(), 0);
        assert_eq!(q.rejected().len(), 1, "rejection is recorded, not silent");
        assert_eq!(q.rejected()[0].id, 2);
        // a completion frees a slot but never resurrects a rejected job
        assert!(q.on_job_done().is_none());
        assert_eq!(q.resident(), 1);
        assert!(q.offer(job(3)).is_some(), "freed slot admits new arrivals");
    }

    #[test]
    fn defer_mode_parks_overflow_and_drains_fifo() {
        let mut q = JobQueue::new(1, Admission::Defer);
        assert!(q.offer(job(0)).is_some());
        assert!(q.offer(job(1)).is_none());
        assert!(q.offer(job(2)).is_none());
        assert_eq!((q.resident(), q.pending(), q.rejected().len()), (1, 2, 0));
        let next = q.on_job_done().expect("backlog head admitted on completion");
        assert_eq!(next.id, 1, "FIFO order");
        assert_eq!((q.resident(), q.pending()), (1, 1));
        assert_eq!(q.on_job_done().unwrap().id, 2);
        assert!(q.on_job_done().is_none(), "backlog empty");
        assert_eq!(q.resident(), 0);
        assert_eq!(q.admitted(), 3);
        assert_eq!(q.submitted(), 3);
    }

    #[test]
    fn max_defer_expires_timed_out_backlog_loudly() {
        let mut q = JobQueue::new(1, Admission::Defer);
        assert!(q.offer(job(0)).is_some()); // arrives 0.0, resident
        assert!(q.offer(job(1)).is_none()); // arrives 0.1, deferred
        assert!(q.offer(job(2)).is_none()); // arrives 0.2, deferred
        // at t=0.35 with max_defer=0.2, job 1 (waiting 0.25) times out;
        // job 2 (waiting 0.15) stays
        assert_eq!(q.expire(0.35, 0.2), 1);
        assert_eq!(q.expired(), 1);
        assert_eq!(q.pending(), 1);
        assert_eq!(q.rejected().len(), 1, "expiry is recorded, not silent");
        assert_eq!(q.rejected()[0].id, 1);
        // conservation holds through the new path
        assert_eq!(q.submitted(), q.admitted() + q.rejected().len() + q.pending());
        // the survivor still drains normally
        assert_eq!(q.on_job_done().expect("job 2 admitted").id, 2);
        assert_eq!(q.expire(10.0, 0.2), 0, "nothing pending, nothing expires");
        assert_eq!(q.submitted(), q.admitted() + q.rejected().len() + q.pending());
    }

    #[test]
    fn expiry_never_touches_resident_or_rejected_jobs() {
        let mut q = JobQueue::new(1, Admission::Reject);
        assert!(q.offer(job(0)).is_some());
        assert!(q.offer(job(1)).is_none(), "reject mode: straight to rejected");
        assert_eq!(q.expire(100.0, 0.0), 0, "reject mode has no backlog to expire");
        assert_eq!(q.expired(), 0);
        assert_eq!(q.resident(), 1);
        assert_eq!(q.rejected().len(), 1);
    }

    #[test]
    fn zero_cap_is_clamped_to_one() {
        let mut q = JobQueue::new(0, Admission::Defer);
        assert_eq!(q.cap(), 1);
        assert!(q.offer(job(0)).is_some(), "cap 0 would deadlock; clamp admits");
    }

    #[test]
    fn admission_labels_round_trip() {
        for a in [Admission::Reject, Admission::Defer] {
            assert_eq!(Admission::parse(a.label()), Some(a));
        }
        assert_eq!(Admission::parse("DEFER"), Some(Admission::Defer));
        assert!(Admission::parse("drop").is_none());
    }
}
