//! The service layer: streaming multi-DAG simulation (`hesp serve`).
//!
//! Everything below here turns the single-DAG simulator into a cluster
//! model: jobs *arrive over time*, pass admission control, and are
//! co-scheduled on the shared machine until the system drains. A job's
//! lifecycle is
//!
//! ```text
//! arrival ──► admission (reject / defer / admit)
//!                 │
//!                 ▼
//!          resident: ready tasks join the global decision round,
//!          competing with every other resident job's tasks
//!                 │
//!                 ▼
//!          drained: last task done → sojourn, deadline, slowdown
//!                   recorded; a deferred job takes the freed slot
//! ```
//!
//! * [`arrivals`] — deterministic arrival processes (Poisson, bursty
//!   MMPP, JSONL trace replay) producing [`arrivals::JobSpec`] streams;
//! * [`queue`] — bounded-residency admission control with loud rejection
//!   accounting;
//! * [`sim`] — the multi-job event loop over the shared
//!   [`crate::coordinator::engine`] core, plus the grid runner
//!   ([`sim::run_serve`]);
//! * [`metrics`] — service-level objectives (sojourn percentiles,
//!   throughput, deadline misses, Jain fairness) and the byte-stable
//!   CSV/JSON bundle.
//!
//! Job-aware scheduling plugs in through [`crate::coordinator::policy`]:
//! the loop attaches a [`crate::coordinator::policy::JobInfo`] to every
//! policy call, which `pl/edf-p` / `pl/sjf-p` read and every single-DAG
//! policy safely ignores.

pub mod arrivals;
pub mod metrics;
pub mod queue;
pub mod sim;

pub use arrivals::{parse_trace, stream_seed, ArrivalSpec, Deadline, JobSpec};
pub use metrics::{
    summarize, to_csv, to_json, write_serve_bundle, ServeResult, SERVE_CSV_EXT, SERVE_CSV_HEADER,
};
pub use queue::{Admission, JobQueue};
pub use sim::{run_serve, scenario_seed, simulate_stream, JobRecord, ServeConfig, ServeGrid, StreamOutcome};
