//! The streaming multi-DAG simulator: many jobs, one machine.
//!
//! [`simulate_stream`] layers job bookkeeping over the single shared
//! [`EventCore`]: every resident job is a [`TaskDag`] whose ready tasks
//! feed one global decision round, so concurrent jobs genuinely compete
//! for the same processor and link [`Timeline`]s — queueing delay is
//! *emergent* (backlog on the timelines), not modeled. The clock
//! interleaves two sources: the event queue (task/transfer completions)
//! and the arrival stream. At any instant, completions are processed
//! before arrivals are admitted, then a decision round dispatches every
//! ready task; when the next arrival precedes the next event the clock
//! simply jumps to it (the event queue tolerates forward-set `now`).
//!
//! Determinism is by construction, thread count included: the stream is a
//! pure function of `(arrival label, seed)`, job DAG builds of
//! `(workload, tile, job id, seed)`, the scheduler RNG of the scenario
//! seed, and ties in the ready queue break on global admission order
//! (each job owns a disjoint `ord_base..ord_base+n` range, assigned in
//! admission order).
//!
//! [`Timeline`]: crate::coordinator::platform::Timeline

use crate::coordinator::coherence::CachePolicy;
use crate::coordinator::engine::{pick_best, Assignment, EventCore, EventKind, SimConfig, FAULT_KEY_MASK};
use crate::coordinator::faults::{FaultPlan, FaultSpec};
use crate::coordinator::lower_bound::makespan_lower_bound;
use crate::coordinator::ordering::critical_times;
use crate::coordinator::perfmodel::PerfDb;
use crate::coordinator::platform::Machine;
use crate::coordinator::policies::{Ordering, ProcSelect, SchedConfig};
use crate::coordinator::policy::{JobInfo, PolicyRegistry, SchedPolicy};
use crate::coordinator::sweep::SweepPlatform;
use crate::coordinator::task::Task;
use crate::coordinator::taskdag::{FlatDag, TaskDag};
use crate::util::fxhash::content_seed;
use crate::util::par::par_map;

use super::arrivals::{ArrivalSpec, Deadline, JobSpec};
use super::metrics::{summarize, ServeResult};
use super::queue::{Admission, JobQueue};

/// Knobs of one stream simulation (one grid cell).
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Max resident jobs; arrivals beyond it hit the admission policy.
    pub queue_cap: usize,
    pub admission: Admission,
    pub cache: CachePolicy,
    pub elem_bytes: u64,
    /// Declared (grid) seed: drives job DAG builds. Deliberately
    /// policy-independent so every policy schedules identical DAGs.
    pub job_seed: u64,
    /// Scenario seed: drives the scheduler's tie-break RNG.
    pub rng_seed: u64,
    /// Age bound for the deferred backlog: a job waiting longer than
    /// this is moved to `rejected` (counted as expired). `None` waits
    /// forever — the pre-hardening behavior.
    pub max_defer: Option<f64>,
}

/// Per-job outcome of a completed job.
#[derive(Debug, Clone, PartialEq)]
pub struct JobRecord {
    pub id: usize,
    pub workload: String,
    pub tile: u32,
    pub priority: u8,
    pub t_arrival: f64,
    /// When the job entered the system (later than `t_arrival` when it
    /// sat in deferred backlog).
    pub admitted: f64,
    /// When its last task finished (trailing write-backs excluded — a
    /// job's results exist once its tasks do).
    pub finished: f64,
    /// `finished - t_arrival`: backlog wait included, by design.
    pub sojourn: f64,
    /// The job's makespan lower bound on this machine (critical path vs
    /// aggregate-capacity area), resolved at admission.
    pub lower_bound: f64,
    /// Absolute deadline instant; `INFINITY` when none was declared.
    pub deadline: f64,
    pub missed: bool,
    pub n_tasks: usize,
}

/// Everything one stream simulation produced.
#[derive(Debug, Clone)]
pub struct StreamOutcome {
    /// Completed jobs in stream-id order.
    pub jobs: Vec<JobRecord>,
    pub submitted: usize,
    pub admitted: usize,
    pub rejected: usize,
    /// Deferred jobs that aged out of the backlog (subset of `rejected`).
    pub expired: usize,
    /// Admitted jobs that could not complete: a task exhausted its fault
    /// attempt budget. Counted as deadline misses.
    pub failed: usize,
    /// When the system went empty (last task or transfer end);
    /// `INFINITY` when any job failed under faults.
    pub drain: f64,
    pub proc_busy: Vec<f64>,
    pub transfer_bytes: u64,
    /// Fault attempts injected (transient dooms + fail-stop kills).
    pub faults_injected: usize,
    /// Faulted attempts that were re-dispatched.
    pub recovered: usize,
    /// Summed fault-to-restart latency over recovered attempts.
    pub recovery_sum: f64,
    /// Busy seconds spent on attempts that were later lost to faults.
    pub wasted: f64,
}

/// One admitted, not-yet-drained job.
struct Resident {
    spec: JobSpec,
    dag: TaskDag,
    flat: FlatDag,
    /// Critical times (when the policy wants them), else zeros.
    prio: Vec<f64>,
    indeg: Vec<usize>,
    release: Vec<f64>,
    /// Static ordering keys, filled at release for `!dynamic_order()`.
    keys: Vec<f64>,
    remaining: usize,
    admitted: f64,
    info: JobInfo,
    /// Global program-order base: ready-queue ties break on
    /// `ord_base + pos`, i.e. admission order, then task order.
    ord_base: usize,
    /// Dispatched, not-yet-ended attempts (fault mode only).
    inflight: usize,
    /// A task exhausted its fault attempt budget: the job can never
    /// complete and drains as failed once its in-flight work ends.
    failed: bool,
}

/// Simulate `stream` (sorted by arrival) under `policy` on `machine`.
/// Runs to full drain: past the last arrival, the clock follows the event
/// queue until every admitted job completes (or fails its fault budget).
/// With `faults`, failures interleave with arrivals on the shared clock:
/// faulted attempts re-enter the global ready queue and are re-dispatched
/// by the same policy, under their *original* commit key.
pub fn simulate_stream(
    machine: &Machine,
    db: &PerfDb,
    policy: &mut dyn SchedPolicy,
    stream: &[JobSpec],
    cfg: &ServeConfig,
    faults: Option<&FaultPlan>,
) -> StreamOutcome {
    debug_assert!(stream.windows(2).all(|w| w[0].t_arrival <= w[1].t_arrival));
    let sim_cfg = SimConfig::new(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish))
        .with_cache(cfg.cache)
        .with_elem_bytes(cfg.elem_bytes)
        .with_seed(cfg.rng_seed);
    let mut core = EventCore::new(machine, db, sim_cfg);
    if let Some(plan) = faults {
        core.install_faults(plan);
    }
    let mut queue = JobQueue::new(cfg.queue_cap, cfg.admission);
    let mut jobs: Vec<Resident> = Vec::new();
    // (slot, pos) of every released, not-yet-dispatched task
    let mut ready: Vec<(usize, usize)> = Vec::new();
    // commit key -> (slot, pos); keys are dense dispatch indices
    let mut key_map: Vec<(usize, usize)> = Vec::new();
    // (slot, pos) -> (original commit key, fault time) of faulted tasks
    // awaiting re-dispatch — lookup only, never iterated
    let mut retry_key: crate::util::fxhash::FxHashMap<(usize, usize), (usize, f64)> =
        crate::util::fxhash::FxHashMap::default();
    let mut records: Vec<JobRecord> = Vec::new();
    let mut batch: Vec<(usize, EventKind)> = Vec::new();
    let mut next_ord = 0usize;
    let mut next_arrival = 0usize;
    let mut recovered = 0usize;
    let mut recovery_sum = 0.0f64;
    let mut failed = 0usize;
    let static_keys = !policy.dynamic_order();

    loop {
        // 1. expire aged-out backlog, then admit every arrival due at or
        // before the clock
        if let Some(md) = cfg.max_defer {
            queue.expire(core.now, md);
        }
        while next_arrival < stream.len() && stream[next_arrival].t_arrival <= core.now {
            let spec = stream[next_arrival];
            next_arrival += 1;
            if let Some(spec) = queue.offer(spec) {
                admit(&mut core, policy, &mut jobs, &mut ready, &mut next_ord, spec, cfg.job_seed);
            }
        }

        // 2. decision round: dispatch ALL ready tasks at this instant,
        // best-first — exactly the single-DAG engine's loop, with the
        // owning job's identity attached to each policy call
        while !ready.is_empty() {
            let picked = pick_best(
                ready.len(),
                |i| {
                    let (slot, pos) = ready[i];
                    let j = &jobs[slot];
                    if static_keys {
                        j.keys[pos]
                    } else {
                        let mut ctx = core.ctx_job(&[], Some(j.info));
                        policy.order(&mut ctx, j.dag.task(j.flat.tasks[pos]), j.release[pos], j.prio[pos])
                    }
                },
                |i| {
                    let (slot, pos) = ready[i];
                    jobs[slot].ord_base + pos
                },
            )
            .expect("ready set is non-empty");
            let (slot, pos) = ready.swap_remove(picked);
            let rel = jobs[slot].release[pos];
            let succ_store: Vec<&Task> = if policy.wants_successors() {
                let j = &jobs[slot];
                j.flat.succs[pos].iter().map(|&s| j.dag.task(j.flat.tasks[s])).collect()
            } else {
                Vec::new()
            };
            let proc = {
                let j = &jobs[slot];
                let mut ctx = core.ctx_job(&succ_store, Some(j.info));
                policy.select(&mut ctx, j.dag.task(j.flat.tasks[pos]), rel)
            };
            // a faulted task re-dispatches under its ORIGINAL commit key
            // (attempt bookkeeping in the core is keyed by it); fresh
            // tasks get the next dense index
            let retry = retry_key.remove(&(slot, pos));
            let key = match retry {
                Some((k, _)) => k,
                None => {
                    key_map.push((slot, pos));
                    key_map.len() - 1
                }
            };
            let j = &jobs[slot];
            let task_id = j.flat.tasks[pos];
            let (start, end) = core.commit(j.dag.task(task_id), key, proc, rel);
            let a = Assignment { task: task_id, pos: key, proc, release: rel, start, end };
            match retry {
                Some((_, t_fault)) => {
                    recovered += 1;
                    if start.is_finite() {
                        recovery_sum += start - t_fault;
                    }
                    core.sched.assignments[key] = a;
                }
                None => core.sched.assignments.push(a),
            }
            if faults.is_some() {
                jobs[slot].inflight += 1;
            }
        }

        // 3. advance the clock: next arrival vs next event
        let t_arr = (next_arrival < stream.len()).then(|| stream[next_arrival].t_arrival);
        match (t_arr, core.next_event_time()) {
            // pure arrival: jump the clock (nothing to pop in between)
            (Some(a), Some(e)) if a < e => core.now = a,
            (Some(a), None) => core.now = a,
            (None, None) => break,
            // event first (ties included: completions at t are processed
            // before arrivals at t, then one decision round sees both)
            _ => {
                core.pop_event_batch(&mut batch);
                let mut done_slots: Vec<usize> = Vec::new();
                for k in 0..batch.len() {
                    let (ekey, kind) = batch[k];
                    // fault-mode keys carry the attempt count in the high
                    // bits; the base is the dense dispatch index
                    let base = ekey & FAULT_KEY_MASK;
                    match kind {
                        EventKind::TaskEnd { proc, .. } => {
                            debug_assert!(base < key_map.len());
                            let (slot, pos) = key_map[base];
                            if faults.is_some() {
                                jobs[slot].inflight -= 1;
                            }
                            {
                                let j = &jobs[slot];
                                core.apply_writes(j.dag.task(j.flat.tasks[pos]), proc, core.now);
                            }
                            jobs[slot].remaining -= 1;
                            if jobs[slot].remaining == 0 {
                                done_slots.push(slot);
                            }
                            for si in 0..jobs[slot].flat.succs[pos].len() {
                                let s = jobs[slot].flat.succs[pos][si];
                                jobs[slot].indeg[s] -= 1;
                                let rel = jobs[slot].release[s].max(core.now);
                                jobs[slot].release[s] = rel;
                                if jobs[slot].indeg[s] == 0 {
                                    if static_keys {
                                        let k2 = {
                                            let j = &jobs[slot];
                                            let mut ctx = core.ctx_job(&[], Some(j.info));
                                            policy.order(&mut ctx, j.dag.task(j.flat.tasks[s]), rel, j.prio[s])
                                        };
                                        jobs[slot].keys[s] = k2;
                                    }
                                    ready.push((slot, s));
                                }
                            }
                        }
                        EventKind::TaskFault { .. } => {
                            // a faulted attempt: no writes land, no
                            // successors release — the task re-enters the
                            // ready queue (or fails the job for good)
                            debug_assert!(base < key_map.len());
                            let (slot, pos) = key_map[base];
                            jobs[slot].inflight -= 1;
                            if core.fault_retry(base) {
                                let rel = jobs[slot].release[pos].max(core.now);
                                jobs[slot].release[pos] = rel;
                                retry_key.insert((slot, pos), (base, core.now));
                                if static_keys {
                                    let k2 = {
                                        let j = &jobs[slot];
                                        let mut ctx = core.ctx_job(&[], Some(j.info));
                                        policy.order(&mut ctx, j.dag.task(j.flat.tasks[pos]), rel, j.prio[pos])
                                    };
                                    jobs[slot].keys[pos] = k2;
                                }
                                ready.push((slot, pos));
                            } else if !jobs[slot].failed {
                                jobs[slot].failed = true;
                                failed += 1;
                            }
                        }
                        _ => {}
                    }
                }
                // a failed job drains once its in-flight + ready work is
                // gone: record it (as a miss) and free its residency slot
                if failed > 0 {
                    for slot in 0..jobs.len() {
                        let j = &jobs[slot];
                        if !j.failed || j.remaining == 0 || j.inflight > 0 {
                            continue;
                        }
                        if ready.iter().any(|&(s, _)| s == slot) {
                            continue;
                        }
                        jobs[slot].remaining = 0; // finalized marker
                        let j = &jobs[slot];
                        records.push(JobRecord {
                            id: j.spec.id,
                            workload: j.spec.workload.label(),
                            tile: j.spec.tile,
                            priority: j.spec.priority,
                            t_arrival: j.spec.t_arrival,
                            admitted: j.admitted,
                            finished: core.now,
                            sojourn: core.now - j.spec.t_arrival,
                            lower_bound: j.info.lower_bound,
                            deadline: j.info.deadline,
                            missed: true,
                            n_tasks: j.flat.len(),
                        });
                        if let Some(md) = cfg.max_defer {
                            queue.expire(core.now, md);
                        }
                        if let Some(spec) = queue.on_job_done() {
                            admit(&mut core, policy, &mut jobs, &mut ready, &mut next_ord, spec, cfg.job_seed);
                        }
                    }
                }
                for slot in done_slots {
                    let j = &jobs[slot];
                    records.push(JobRecord {
                        id: j.spec.id,
                        workload: j.spec.workload.label(),
                        tile: j.spec.tile,
                        priority: j.spec.priority,
                        t_arrival: j.spec.t_arrival,
                        admitted: j.admitted,
                        finished: core.now,
                        sojourn: core.now - j.spec.t_arrival,
                        lower_bound: j.info.lower_bound,
                        deadline: j.info.deadline,
                        missed: core.now > j.info.deadline,
                        n_tasks: j.flat.len(),
                    });
                    // a drained job frees a residency slot: the deferred
                    // backlog head (if any — timed-out heads expire
                    // first) is admitted right now and its roots dispatch
                    // in the next decision round
                    if let Some(md) = cfg.max_defer {
                        queue.expire(core.now, md);
                    }
                    if let Some(spec) = queue.on_job_done() {
                        admit(&mut core, policy, &mut jobs, &mut ready, &mut next_ord, spec, cfg.job_seed);
                    }
                }
            }
        }
    }

    debug_assert_eq!(queue.pending(), 0, "drained system cannot hold deferred jobs");
    debug_assert_eq!(records.len(), queue.admitted(), "every admitted job must complete or fail");
    records.sort_by_key(|r| r.id);
    let (submitted, admitted, rejected) = (queue.submitted(), queue.admitted(), queue.rejected().len());
    let expired = queue.expired();
    let (faults_injected, _, wasted) = core.fault_stats();
    let sched = core.finish();
    StreamOutcome {
        jobs: records,
        submitted,
        admitted,
        rejected,
        expired,
        failed,
        drain: sched.makespan,
        proc_busy: sched.proc_busy,
        transfer_bytes: sched.transfer_bytes,
        faults_injected,
        recovered,
        recovery_sum,
        wasted,
    }
}

/// Build, bound, and register one job at the current clock.
fn admit(
    core: &mut EventCore<'_>,
    policy: &mut dyn SchedPolicy,
    jobs: &mut Vec<Resident>,
    ready: &mut Vec<(usize, usize)>,
    next_ord: &mut usize,
    spec: JobSpec,
    job_seed: u64,
) {
    let wl_label = spec.workload.label();
    let wseed = content_seed(&["serve-job", &wl_label], &[spec.tile as u64, spec.id as u64, job_seed]);
    let mut dag = spec
        .workload
        .build(spec.tile, wseed)
        .expect("streams only carry feasible (workload, tile) combos");
    // every workload builder emits matrix 0 and region overlap requires
    // the same matrix — relabeling per job is what keeps concurrent jobs'
    // identically-indexed blocks from falsely aliasing
    dag.set_matrix(spec.id as u32 + 1);
    let flat = dag.flat_dag();
    debug_assert!(!flat.is_empty(), "workload builders never emit empty DAGs");
    let lb = makespan_lower_bound(&dag, &flat, core.machine, core.db);
    let deadline = match spec.deadline {
        Deadline::None => f64::INFINITY,
        Deadline::At(t) => t,
        // relative deadlines scale with job size on THIS machine — the
        // whole point of resolving them at admission
        Deadline::Slack(s) => spec.t_arrival + s * lb,
    };
    let info = JobInfo { id: spec.id, arrival: spec.t_arrival, deadline, lower_bound: lb };
    let prio = if policy.wants_critical_times() {
        critical_times(&dag, &flat, core.machine, core.db)
    } else {
        vec![0.0; flat.len()]
    };
    let n = flat.len();
    let at = core.now;
    let mut res = Resident {
        indeg: flat.preds.iter().map(|p| p.len()).collect(),
        release: vec![at; n],
        keys: vec![0.0; n],
        remaining: n,
        admitted: at,
        info,
        ord_base: *next_ord,
        inflight: 0,
        failed: false,
        spec,
        prio,
        dag,
        flat,
    };
    *next_ord += n;
    let slot = jobs.len();
    let static_keys = !policy.dynamic_order();
    for pos in 0..n {
        if res.indeg[pos] == 0 {
            if static_keys {
                let key = {
                    let mut ctx = core.ctx_job(&[], Some(res.info));
                    policy.order(&mut ctx, res.dag.task(res.flat.tasks[pos]), at, res.prio[pos])
                };
                res.keys[pos] = key;
            }
            ready.push((slot, pos));
        }
    }
    jobs.push(res);
}

/// A serve grid: platforms x arrival processes x policies, one shared
/// stream per arrival process.
#[derive(Debug, Clone)]
pub struct ServeGrid {
    pub platforms: Vec<SweepPlatform>,
    pub arrivals: Vec<ArrivalSpec>,
    /// Policy registry names.
    pub policies: Vec<String>,
    /// Arrival horizon in seconds (each cell then drains fully).
    pub duration: f64,
    pub queue_cap: usize,
    pub admission: Admission,
    pub cache: CachePolicy,
    pub seed: u64,
    /// Deferred-backlog age bound (`--max-defer`); `None` waits forever.
    pub max_defer: Option<f64>,
    /// Fault spec injected into every scenario (`--faults`); `None` runs
    /// the perfect machine.
    pub faults: Option<FaultSpec>,
}

/// Deterministic per-scenario seed for the scheduler RNG — content-derived
/// like [`crate::coordinator::sweep::cell_seed`], so results never depend
/// on grid position or thread count.
pub fn scenario_seed(platform: &str, arrivals: &str, policy: &str, seed: u64) -> u64 {
    content_seed(&["serve", platform, arrivals, policy], &[seed])
}

/// Run every scenario of the grid across `threads` workers. Results come
/// back in grid order (platform-major, then arrivals, then policy) no
/// matter the thread count; each arrival stream is generated once and
/// shared by every (platform, policy) pair so comparisons are paired.
pub fn run_serve(grid: &ServeGrid, threads: usize) -> anyhow::Result<Vec<ServeResult>> {
    let reg = PolicyRegistry::standard();
    for name in &grid.policies {
        if reg.get(name).is_none() {
            anyhow::bail!("unknown policy '{name}' (see `hesp policies`)");
        }
    }
    let mut streams: Vec<Vec<JobSpec>> = Vec::new();
    for a in &grid.arrivals {
        streams.push(a.generate(grid.duration, grid.seed)?);
    }
    let mut cells: Vec<(usize, usize, usize)> = Vec::new();
    for p in 0..grid.platforms.len() {
        for a in 0..grid.arrivals.len() {
            for pol in 0..grid.policies.len() {
                cells.push((p, a, pol));
            }
        }
    }
    let workers = threads.max(1).clamp(1, cells.len().max(1));
    Ok(par_map(workers, &cells, |_, &(p, a, pol)| {
        let platform = &grid.platforms[p];
        let arr_label = grid.arrivals[a].label();
        let pol_name = &grid.policies[pol];
        let mut policy = reg.get(pol_name).expect("validated above");
        let sseed = scenario_seed(&platform.name, &arr_label, pol_name, grid.seed);
        let cfg = ServeConfig {
            queue_cap: grid.queue_cap,
            admission: grid.admission,
            cache: grid.cache,
            elem_bytes: platform.elem_bytes,
            job_seed: grid.seed,
            rng_seed: sseed,
            max_defer: grid.max_defer,
        };
        // one plan member per grid seed, shared by every scenario: the
        // same fault trace hits every (platform, policy) pair, so
        // comparisons stay paired
        let plan = grid.faults.as_ref().map(|s| FaultPlan::new(s, grid.seed));
        let outcome =
            simulate_stream(&platform.machine, &platform.db, policy.as_mut(), &streams[a], &cfg, plan.as_ref());
        summarize(&platform.name, &arr_label, pol_name, grid.seed, sseed, grid.duration, &outcome)
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::perfmodel::{PerfCurve, PerfDb};
    use crate::coordinator::platform::MachineBuilder;
    use crate::coordinator::policy::policy_by_name;
    use crate::coordinator::sweep::Workload;

    fn platform(ncpu: usize, gflops: f64) -> (Machine, PerfDb) {
        let mut b = MachineBuilder::new("t");
        let h = b.space("host", u64::MAX);
        b.main(h);
        let t = b.proc_type("cpu", 1.0, 0.1);
        b.processors(ncpu, "c", t, h);
        let m = b.build();
        let mut db = PerfDb::new();
        db.set_fallback(0, PerfCurve::Const { gflops });
        (m, db)
    }

    fn cfg() -> ServeConfig {
        ServeConfig {
            queue_cap: 64,
            admission: Admission::Defer,
            cache: CachePolicy::WriteBack,
            elem_bytes: 8,
            job_seed: 0,
            rng_seed: 0,
            max_defer: None,
        }
    }

    fn job(id: usize, t: f64) -> JobSpec {
        JobSpec {
            id,
            t_arrival: t,
            workload: Workload::Cholesky { n: 512 },
            tile: 256,
            deadline: Deadline::None,
            priority: 0,
        }
    }

    #[test]
    fn single_job_completes_with_sane_sojourn() {
        let (m, db) = platform(2, 1.0);
        let mut pol = policy_by_name("pl/eft-p").unwrap();
        let stream = [job(0, 0.25)];
        let out = simulate_stream(&m, &db, pol.as_mut(), &stream, &cfg(), None);
        assert_eq!((out.submitted, out.admitted, out.rejected), (1, 1, 0));
        assert_eq!(out.jobs.len(), 1);
        let r = &out.jobs[0];
        assert_eq!(r.admitted, 0.25, "admitted on arrival into an empty system");
        assert!(r.finished > 0.25);
        assert!((r.sojourn - (r.finished - 0.25)).abs() < 1e-12);
        assert!(r.lower_bound > 0.0);
        assert!(r.sojourn >= r.lower_bound, "sojourn {} below lower bound {}", r.sojourn, r.lower_bound);
        assert!(!r.missed, "no deadline, no miss");
        assert!(out.drain >= r.finished);
        // bit-for-bit determinism
        let mut pol2 = policy_by_name("pl/eft-p").unwrap();
        let out2 = simulate_stream(&m, &db, pol2.as_mut(), &stream, &cfg(), None);
        assert_eq!(out.jobs, out2.jobs);
        assert_eq!(out.drain, out2.drain);
    }

    #[test]
    fn concurrent_jobs_do_not_false_share() {
        // two identical jobs arriving together on a machine wide enough
        // for both: matrix relabeling means no cross-job dependencies, so
        // they overlap instead of serializing on write-after-write hazards
        let (m, db) = platform(8, 1.0);
        let mut pol = policy_by_name("pl/eft-p").unwrap();
        let solo = simulate_stream(&m, &db, pol.as_mut(), &[job(0, 0.0)], &cfg(), None);
        let t_solo = solo.jobs[0].finished;
        let mut pol = policy_by_name("pl/eft-p").unwrap();
        let both = simulate_stream(&m, &db, pol.as_mut(), &[job(0, 0.0), job(1, 0.0)], &cfg(), None);
        assert_eq!(both.jobs.len(), 2);
        let worst = both.jobs.iter().map(|r| r.finished).fold(0.0f64, f64::max);
        assert!(
            worst < 1.9 * t_solo,
            "two independent jobs on 8 cores must overlap: worst {worst} vs solo {t_solo}"
        );
    }

    #[test]
    fn defer_cap_one_serializes_jobs() {
        let (m, db) = platform(2, 1.0);
        let mut pol = policy_by_name("pl/eft-p").unwrap();
        let mut c = cfg();
        c.queue_cap = 1;
        let out = simulate_stream(&m, &db, pol.as_mut(), &[job(0, 0.0), job(1, 0.0)], &c, None);
        assert_eq!(out.jobs.len(), 2);
        assert_eq!(out.rejected, 0);
        let (a, b) = (&out.jobs[0], &out.jobs[1]);
        assert_eq!(b.admitted, a.finished, "deferred job admitted exactly when the slot frees");
        assert!(b.sojourn > a.sojourn, "backlog wait counts into sojourn");
    }

    #[test]
    fn reject_overflow_is_counted_never_dropped() {
        let (m, db) = platform(2, 1.0);
        let mut pol = policy_by_name("pl/eft-p").unwrap();
        let mut c = cfg();
        c.queue_cap = 1;
        c.admission = Admission::Reject;
        let out = simulate_stream(&m, &db, pol.as_mut(), &[job(0, 0.0), job(1, 1e-6), job(2, 2e-6)], &c, None);
        assert_eq!(out.submitted, 3);
        assert_eq!(out.jobs.len(), 1, "only the first fits");
        assert_eq!(out.rejected, 2);
        assert_eq!(out.submitted, out.jobs.len() + out.rejected, "accounting conserves jobs");
    }

    #[test]
    fn absolute_deadlines_flag_misses() {
        let (m, db) = platform(2, 1.0);
        let mut pol = policy_by_name("pl/eft-p").unwrap();
        let mut impossible = job(0, 0.0);
        impossible.deadline = Deadline::At(1e-9);
        let mut generous = job(1, 0.0);
        generous.deadline = Deadline::At(1e9);
        let out = simulate_stream(&m, &db, pol.as_mut(), &[impossible, generous], &cfg(), None);
        assert!(out.jobs[0].missed);
        assert!(!out.jobs[1].missed);
        assert_eq!(out.jobs[0].deadline, 1e-9);
    }

    #[test]
    fn quiet_period_jumps_the_clock() {
        // an arrival into a long-idle system must not simulate the gap
        // event by event — the clock jumps straight to it
        let (m, db) = platform(2, 1.0);
        let mut pol = policy_by_name("pl/eft-p").unwrap();
        let out = simulate_stream(&m, &db, pol.as_mut(), &[job(0, 0.0), job(1, 50.0)], &cfg(), None);
        assert_eq!(out.jobs[1].admitted, 50.0);
        assert!(out.jobs[0].finished < 50.0, "first job drains long before the second arrives");
        let (s0, s1) = (out.jobs[0].sojourn, out.jobs[1].sojourn);
        assert!((s0 - s1).abs() < 1e-9, "identical jobs on an idle machine: equal sojourn, got {s0} vs {s1}");
    }

    #[test]
    fn empty_stream_is_benign() {
        let (m, db) = platform(2, 1.0);
        let mut pol = policy_by_name("pl/eft-p").unwrap();
        let out = simulate_stream(&m, &db, pol.as_mut(), &[], &cfg(), None);
        assert!(out.jobs.is_empty());
        assert_eq!(out.drain, 0.0);
        assert_eq!((out.submitted, out.rejected), (0, 0));
    }

    #[test]
    fn max_defer_expires_backlog_into_rejected() {
        let (m, db) = platform(2, 1.0);
        let mut pol = policy_by_name("pl/eft-p").unwrap();
        let mut c = cfg();
        c.queue_cap = 1;
        c.max_defer = Some(1e-3); // far below job 0's runtime
        let out = simulate_stream(&m, &db, pol.as_mut(), &[job(0, 0.0), job(1, 1e-6)], &c, None);
        assert_eq!(out.jobs.len(), 1, "the deferred job times out before a slot frees");
        assert_eq!(out.expired, 1);
        assert_eq!(out.rejected, 1, "expired jobs are rejected, not dropped");
        assert_eq!(out.submitted, out.jobs.len() + out.rejected, "conservation through expiry");
        // a generous bound changes nothing
        c.max_defer = Some(1e9);
        let mut pol2 = policy_by_name("pl/eft-p").unwrap();
        let out2 = simulate_stream(&m, &db, pol2.as_mut(), &[job(0, 0.0), job(1, 1e-6)], &c, None);
        assert_eq!(out2.jobs.len(), 2);
        assert_eq!((out2.expired, out2.rejected), (0, 0));
    }

    #[test]
    fn empty_fault_plan_matches_fault_free_stream() {
        use crate::coordinator::faults::{FaultPlan, FaultSpec};
        let (m, db) = platform(2, 1.0);
        let stream = [job(0, 0.0), job(1, 1e-4)];
        let mut pol = policy_by_name("pl/eft-p").unwrap();
        let base = simulate_stream(&m, &db, pol.as_mut(), &stream, &cfg(), None);
        let plan = FaultPlan::new(&FaultSpec::named("off"), 0);
        let mut pol2 = policy_by_name("pl/eft-p").unwrap();
        let out = simulate_stream(&m, &db, pol2.as_mut(), &stream, &cfg(), Some(&plan));
        assert_eq!(base.jobs, out.jobs);
        assert_eq!(base.drain.to_bits(), out.drain.to_bits());
        assert_eq!((out.faults_injected, out.recovered, out.failed), (0, 0, 0));
    }

    #[test]
    fn transient_faults_recover_within_the_stream() {
        use crate::coordinator::faults::{FaultPlan, FaultSpec};
        let (m, db) = platform(2, 1.0);
        let mut spec = FaultSpec::named("flaky");
        spec.transient_rate = 0.4;
        spec.max_attempts = 20;
        let plan = FaultPlan::new(&spec, 0);
        let mut j0 = job(0, 0.0);
        j0.tile = 128; // 4x4 blocks: enough attempts to see faults
        let mut pol = policy_by_name("pl/eft-p").unwrap();
        let out = simulate_stream(&m, &db, pol.as_mut(), &[j0], &cfg(), Some(&plan));
        assert_eq!(out.failed, 0, "a 20-attempt budget at rate 0.4 never exhausts here");
        assert_eq!(out.jobs.len(), 1);
        assert!(out.drain.is_finite());
        assert!(out.faults_injected > 0, "rate 0.4 over dozens of attempts must fault");
        assert_eq!(out.recovered, out.faults_injected, "every fault is re-dispatched");
        assert!(out.recovery_sum >= 0.0);
        assert!(out.wasted > 0.0, "doomed attempts burn busy time");
        // byte-identical replay
        let mut pol2 = policy_by_name("pl/eft-p").unwrap();
        let out2 = simulate_stream(&m, &db, pol2.as_mut(), &[j0], &cfg(), Some(&plan));
        assert_eq!(out.jobs, out2.jobs);
        assert_eq!(out.drain.to_bits(), out2.drain.to_bits());
        assert_eq!(out.faults_injected, out2.faults_injected);
    }

    #[test]
    fn exhausted_attempt_budget_fails_the_job_loudly() {
        use crate::coordinator::faults::{FaultPlan, FaultSpec};
        let (m, db) = platform(2, 1.0);
        let mut spec = FaultSpec::named("hopeless");
        spec.transient_rate = 1.0;
        spec.max_attempts = 2;
        let plan = FaultPlan::new(&spec, 0);
        let mut pol = policy_by_name("pl/eft-p").unwrap();
        let out = simulate_stream(&m, &db, pol.as_mut(), &[job(0, 0.0)], &cfg(), Some(&plan));
        assert_eq!(out.failed, 1, "rate 1.0 exhausts the budget");
        assert_eq!(out.jobs.len(), 1, "the failed job is recorded, never dropped");
        assert!(out.jobs[0].missed, "a failed job counts as a miss");
        assert!(out.drain.is_infinite(), "an exhausted stream has no finite drain");
        assert_eq!(out.faults_injected, 2, "two attempts, both doomed");
        assert_eq!(out.recovered, 1, "one retry was granted before exhaustion");
    }

    #[test]
    fn scenario_seed_separates_every_axis() {
        let base = scenario_seed("odroid", "poisson:8", "pl/edf-p", 0);
        assert_eq!(base, scenario_seed("odroid", "poisson:8", "pl/edf-p", 0));
        assert_ne!(base, scenario_seed("bujaruelo", "poisson:8", "pl/edf-p", 0));
        assert_ne!(base, scenario_seed("odroid", "bursty:3:25:0.15", "pl/edf-p", 0));
        assert_ne!(base, scenario_seed("odroid", "poisson:8", "pl/sjf-p", 0));
        assert_ne!(base, scenario_seed("odroid", "poisson:8", "pl/edf-p", 1));
    }
}
