//! Blocked LU (no pivoting) partitioner — extension workload showing HeSP
//! generalizes beyond Cholesky ("can be easily applied to other irregular
//! task-parallel implementations", paper §4).
//!
//! ```text
//! for k: GETRF(A[k][k])
//!        for j>k: TRSM_U  A[k][j] = L[k][k]^-1 A[k][j]
//!        for i>k: TRSM_L  A[i][k] = A[i][k] U[k][k]^-1
//!        for i>k, j>k: GEMM  A[i][j] -= A[i][k] A[k][j]
//! ```

use crate::coordinator::region::Region;
use crate::coordinator::task::{Task, TaskKind, TaskSpec};
use crate::coordinator::taskdag::TaskDag;

use super::Partitioner;

pub struct LuPartitioner;

impl Partitioner for LuPartitioner {
    fn kinds(&self) -> Vec<TaskKind> {
        vec![TaskKind::Getrf]
    }

    fn partition(&self, task: &Task, b: u32) -> Option<Vec<TaskSpec>> {
        let a = *task.writes.first()?;
        if !a.is_square() || b == 0 || a.rows() % b != 0 || a.rows() / b < 2 {
            return None;
        }
        let s = a.rows() / b;
        let tile = |i: u32, j: u32| Region::tile(&a, b, i, j);
        let mut out = Vec::new();
        for k in 0..s {
            let akk = tile(k, k);
            out.push(TaskSpec::new(TaskKind::Getrf, vec![akk], vec![akk]));
            for j in k + 1..s {
                let akj = tile(k, j);
                out.push(TaskSpec::new(TaskKind::TrsmU, vec![akk, akj], vec![akj]));
            }
            for i in k + 1..s {
                let aik = tile(i, k);
                out.push(TaskSpec::new(TaskKind::TrsmL, vec![akk, aik], vec![aik]));
            }
            for i in k + 1..s {
                for j in k + 1..s {
                    let (aik, akj, aij) = (tile(i, k), tile(k, j), tile(i, j));
                    out.push(TaskSpec::new(TaskKind::Gemm, vec![aik, akj, aij], vec![aij]));
                }
            }
        }
        Some(out)
    }
}

/// Fresh DAG with one root GETRF task over an n x n matrix.
pub fn root(n: u32) -> TaskDag {
    let a = Region::new(0, 0, n, 0, n);
    TaskDag::new(TaskSpec::new(TaskKind::Getrf, vec![a], vec![a]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::partitioners::PartitionerSet;

    #[test]
    fn task_count() {
        // s=3: 3 getrf + 3+3 trsm_u + trsm_l? per k: (s-k-1) each + (s-k-1)^2 gemm
        let mut dag = root(12);
        let set = PartitionerSet::standard();
        set.apply(&mut dag, 0, 4).unwrap();
        // k=0: 1+2+2+4, k=1: 1+1+1+1, k=2: 1  => 14
        assert_eq!(dag.frontier().len(), 14);
    }

    #[test]
    fn lu_dag_has_wider_trailing_updates_than_cholesky() {
        let mut lu = root(16);
        let set = PartitionerSet::standard();
        set.apply(&mut lu, 0, 4).unwrap();
        let flat = lu.flat_dag();
        // 9 independent gemms in the first trailing update
        assert!(flat.width() >= 9, "width={}", flat.width());
    }

    #[test]
    fn first_trailing_gemm_depends_on_both_panels() {
        let mut dag = root(8);
        let set = PartitionerSet::standard();
        set.apply(&mut dag, 0, 4).unwrap();
        let flat = dag.flat_dag();
        // order: getrf0, trsm_u(0,1), trsm_l(1,0), gemm(1,1), getrf1
        let kinds: Vec<_> = flat.tasks.iter().map(|&t| dag.task(t).kind).collect();
        assert_eq!(
            kinds,
            vec![TaskKind::Getrf, TaskKind::TrsmU, TaskKind::TrsmL, TaskKind::Gemm, TaskKind::Getrf]
        );
        let mut p = flat.preds[3].clone();
        p.sort();
        assert_eq!(p, vec![1, 2]);
    }
}
