//! Blocked right-looking Cholesky partitioner (the paper's Fig. 1
//! algorithm): splits a POTRF task over an n x n tile into the classic
//! POTRF / TRSM / SYRK / GEMM task set over an s x s grid of b x b tiles.

use crate::coordinator::region::Region;
use crate::coordinator::task::{Task, TaskKind, TaskSpec};
use crate::coordinator::taskdag::TaskDag;

use super::Partitioner;

pub struct CholeskyPartitioner;

impl Partitioner for CholeskyPartitioner {
    fn kinds(&self) -> Vec<TaskKind> {
        vec![TaskKind::Potrf]
    }

    fn partition(&self, task: &Task, b: u32) -> Option<Vec<TaskSpec>> {
        let a = *task.writes.first()?;
        if !a.is_square() || b == 0 || a.rows() % b != 0 || a.rows() / b < 2 {
            return None;
        }
        Some(specs(&a, b))
    }
}

/// The blocked-Cholesky task stream over region `a` at tile edge `b`
/// (program order; dependences derive from region overlap).
pub fn specs(a: &Region, b: u32) -> Vec<TaskSpec> {
    let s = a.rows() / b;
    let tile = |i: u32, j: u32| Region::tile(a, b, i, j);
    let mut out = Vec::new();
    for k in 0..s {
        let akk = tile(k, k);
        out.push(TaskSpec::new(TaskKind::Potrf, vec![akk], vec![akk]));
        for i in k + 1..s {
            let aik = tile(i, k);
            out.push(TaskSpec::new(TaskKind::Trsm, vec![akk, aik], vec![aik]));
        }
        for i in k + 1..s {
            let aik = tile(i, k);
            let aii = tile(i, i);
            out.push(TaskSpec::new(TaskKind::Syrk, vec![aik, aii], vec![aii]));
            for j in k + 1..i {
                let ajk = tile(j, k);
                let aij = tile(i, j);
                out.push(TaskSpec::new(TaskKind::Gemm, vec![aik, ajk, aij], vec![aij]));
            }
        }
    }
    out
}

/// Expected task count for an s x s blocking:
/// `s POTRF + s(s-1)/2 TRSM + s(s-1)/2 SYRK + s(s-1)(s-2)/6 GEMM`.
pub fn task_count(s: u64) -> u64 {
    s + s * (s - 1) / 2 + s * (s - 1) / 2 + s * (s - 1) * (s - 2) / 6
}

/// A fresh DAG holding one root CHOL task over an n x n matrix.
pub fn root(n: u32) -> TaskDag {
    let a = Region::new(0, 0, n, 0, n);
    TaskDag::new(TaskSpec::new(TaskKind::Potrf, vec![a], vec![a]))
}

/// Uniform (homogeneous) blocking: partition the root once at tile edge
/// `b` — the static equally-sized tiling every Table-1 row compares
/// against. Panics if `b` does not divide n.
pub fn partition_uniform(dag: &mut TaskDag, b: u32) {
    let specs = {
        let t = dag.task(dag.root);
        let a = *t.writes.first().expect("root has an output region");
        assert_eq!(a.rows() % b, 0, "tile edge {b} must divide {}", a.rows());
        specs(&a, b)
    };
    let root = dag.root;
    dag.partition(root, specs, b);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_counts_match_formula() {
        for s in [2u32, 3, 4, 8, 16] {
            let dag = {
                let mut d = root(64 * s);
                partition_uniform(&mut d, 64);
                d
            };
            assert_eq!(dag.frontier().len() as u64, task_count(s as u64), "s={s}");
        }
    }

    #[test]
    fn two_by_two_structure() {
        let mut dag = root(8);
        partition_uniform(&mut dag, 4);
        let flat = dag.flat_dag();
        let kinds: Vec<_> = flat.tasks.iter().map(|&t| dag.task(t).kind).collect();
        assert_eq!(
            kinds,
            vec![TaskKind::Potrf, TaskKind::Trsm, TaskKind::Syrk, TaskKind::Potrf]
        );
        // chain: potrf -> trsm -> syrk -> potrf
        assert_eq!(flat.preds[1], vec![0]);
        assert_eq!(flat.preds[2], vec![1]);
        assert_eq!(flat.preds[3], vec![2]);
    }

    #[test]
    fn four_by_four_width_grows() {
        let mut dag = root(16);
        partition_uniform(&mut dag, 4);
        let flat = dag.flat_dag();
        assert_eq!(flat.len() as u64, task_count(4));
        assert!(flat.width() >= 3, "width={}", flat.width());
        // longest chain passes through all 4 potrfs
        assert!(flat.longest_path_len() >= 10);
    }

    #[test]
    fn partitioner_rejects_illegal_edges() {
        let p = CholeskyPartitioner;
        let mut dag = root(100);
        let t = dag.task(0).clone();
        assert!(p.partition(&t, 30).is_none(), "non-divisor");
        assert!(p.partition(&t, 100).is_none(), "s=1 is not a partition");
        assert!(p.partition(&t, 50).is_some());
        let _ = &mut dag;
    }

    #[test]
    fn gemm_reads_two_panels_and_c() {
        let mut dag = root(12);
        partition_uniform(&mut dag, 4);
        let flat = dag.flat_dag();
        let gemms: Vec<_> = flat
            .tasks
            .iter()
            .filter(|&&t| dag.task(t).kind == TaskKind::Gemm)
            .collect();
        assert_eq!(gemms.len() as u64, 1); // s=3 -> 1 gemm
        let g = dag.task(*gemms[0]);
        assert_eq!(g.reads.len(), 3);
        assert_eq!(g.writes.len(), 1);
        assert_eq!(g.reads[2], g.writes[0]);
    }

    #[test]
    fn flops_conserved_exactly_per_level() {
        // Sum of sub-task flops equals the root's n^3/3 (with the
        // full-block SYRK convention adding the symmetric half: the sum is
        // n^3/3 only when SYRK counts b^3; see task.rs). We check the total
        // equals s*potrf + ... algebra rather than a magic constant.
        let n = 32u32;
        let b = 8u32;
        let s = (n / b) as f64;
        let bf = b as f64;
        let expect = s * bf.powi(3) / 3.0
            + (s * (s - 1.0) / 2.0) * bf.powi(3)
            + (s * (s - 1.0) / 2.0) * bf.powi(3)
            + (s * (s - 1.0) * (s - 2.0) / 6.0) * 2.0 * bf.powi(3);
        let mut dag = root(n);
        partition_uniform(&mut dag, b);
        assert!((dag.total_flops() - expect).abs() < 1e-6);
    }
}
