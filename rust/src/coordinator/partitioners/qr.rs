//! Tile-QR partitioner (Householder, PLASMA-style) — second extension
//! workload. Reflector/T-factor storage is modeled through the tiles
//! themselves (scheduling studies need the dependence shape and flop
//! weights, not the numerics):
//!
//! ```text
//! for k: GEQRT(A[k][k])
//!        for j>k: LARFB  A[k][j] <- (I - V T V^T) A[k][j]
//!        for i>k: TSQRT  couples A[k][k], A[i][k]
//!                 for j>k: SSRFB  couples A[k][j], A[i][j] with V=A[i][k]
//! ```

use crate::coordinator::region::Region;
use crate::coordinator::task::{Task, TaskKind, TaskSpec};
use crate::coordinator::taskdag::TaskDag;

use super::Partitioner;

pub struct QrPartitioner;

impl Partitioner for QrPartitioner {
    fn kinds(&self) -> Vec<TaskKind> {
        vec![TaskKind::Geqrt]
    }

    fn partition(&self, task: &Task, b: u32) -> Option<Vec<TaskSpec>> {
        let a = *task.writes.first()?;
        if !a.is_square() || b == 0 || a.rows() % b != 0 || a.rows() / b < 2 {
            return None;
        }
        let s = a.rows() / b;
        let tile = |i: u32, j: u32| Region::tile(&a, b, i, j);
        let mut out = Vec::new();
        for k in 0..s {
            let akk = tile(k, k);
            out.push(TaskSpec::new(TaskKind::Geqrt, vec![akk], vec![akk]));
            for j in k + 1..s {
                let akj = tile(k, j);
                out.push(TaskSpec::new(TaskKind::Larfb, vec![akk, akj], vec![akj]));
            }
            for i in k + 1..s {
                let aik = tile(i, k);
                out.push(TaskSpec::new(TaskKind::Tsqrt, vec![akk, aik], vec![akk, aik]));
                for j in k + 1..s {
                    let (akj, aij) = (tile(k, j), tile(i, j));
                    out.push(TaskSpec::new(TaskKind::Ssrfb, vec![aik, akj, aij], vec![akj, aij]));
                }
            }
        }
        Some(out)
    }
}

/// Fresh DAG with one root GEQRT task over an n x n matrix.
pub fn root(n: u32) -> TaskDag {
    let a = Region::new(0, 0, n, 0, n);
    TaskDag::new(TaskSpec::new(TaskKind::Geqrt, vec![a], vec![a]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::partitioners::PartitionerSet;

    #[test]
    fn task_count_s2() {
        let mut dag = root(8);
        PartitionerSet::standard().apply(&mut dag, 0, 4).unwrap();
        // k=0: geqrt + larfb + tsqrt + ssrfb = 4; k=1: geqrt = 1
        assert_eq!(dag.frontier().len(), 5);
    }

    #[test]
    fn tsqrt_couples_diagonal_making_panel_sequential() {
        let mut dag = root(16);
        PartitionerSet::standard().apply(&mut dag, 0, 4).unwrap();
        let flat = dag.flat_dag();
        // all TSQRT tasks of panel k=0 form a chain through A[0][0]
        let tsqrts: Vec<usize> = (0..flat.len())
            .filter(|&i| dag.task(flat.tasks[i]).kind == TaskKind::Tsqrt)
            .take(3)
            .collect();
        assert_eq!(tsqrts.len(), 3);
        assert!(flat.preds[tsqrts[1]].contains(&tsqrts[0]));
        assert!(flat.preds[tsqrts[2]].contains(&tsqrts[1]));
    }

    #[test]
    fn ssrfb_depends_on_tsqrt_and_larfb() {
        let mut dag = root(8);
        PartitionerSet::standard().apply(&mut dag, 0, 4).unwrap();
        let flat = dag.flat_dag();
        let kinds: Vec<_> = flat.tasks.iter().map(|&t| dag.task(t).kind).collect();
        assert_eq!(
            kinds,
            vec![TaskKind::Geqrt, TaskKind::Larfb, TaskKind::Tsqrt, TaskKind::Ssrfb, TaskKind::Geqrt]
        );
        let mut p = flat.preds[3].clone();
        p.sort();
        assert_eq!(p, vec![1, 2]);
        // final geqrt waits for the ssrfb that updated A[1][1]
        assert_eq!(flat.preds[4], vec![3]);
    }
}
