//! Recursive task partitioners: blocked algorithms that replace a task by
//! an equivalent cluster of finer-grained sub-tasks (paper §2.1,
//! "Recursive task partitioners").
//!
//! A partitioner is "just a blocked algorithm with an input parameter that
//! specifies the data granularity of the following partition". Operand
//! conventions (positions in `reads`/`writes`) are fixed per task kind so
//! partitioners can be applied to tasks emitted by other partitioners:
//!
//! | kind   | reads                      | writes  |
//! |--------|----------------------------|---------|
//! | POTRF  | `[A]`                      | `[A]`   |
//! | TRSM   | `[L, B]`                   | `[B]`   |
//! | SYRK   | `[A, C]`                   | `[C]`   |
//! | GEMM   | `[A, B, C]`                | `[C]`   |
//! | GETRF  | `[A]`                      | `[A]`   |
//! | TRSM_L/U | `[L or U, B]`            | `[B]`   |
//! | GEQRT  | `[A]`                      | `[A]`   |
//! | TSQRT  | `[R, A]`                   | `[R, A]`|
//! | LARFB  | `[V, C]`                   | `[C]`   |
//! | SSRFB  | `[V, C1, C2]`              | `[C1, C2]` |

pub mod cholesky;
pub mod gemm;
pub mod lu;
pub mod qr;
pub mod syrk;
pub mod trsm;

use crate::util::fxhash::FxHashMap;

use super::task::{Task, TaskKind, TaskSpec};
use super::taskdag::TaskDag;

/// A recursive task partitioner for one (or more) task kinds.
pub trait Partitioner: Send + Sync {
    /// Task kinds this partitioner can split.
    fn kinds(&self) -> Vec<TaskKind>;

    /// Emit the sub-task cluster for `task` at sub-tile edge `sub_edge`,
    /// in program order. Returns `None` if the task cannot be split at
    /// that edge (e.g. non-divisible).
    fn partition(&self, task: &Task, sub_edge: u32) -> Option<Vec<TaskSpec>>;
}

/// Registry mapping task kinds to partitioners.
pub struct PartitionerSet {
    map: FxHashMap<TaskKind, std::sync::Arc<dyn Partitioner>>,
}

impl PartitionerSet {
    pub fn empty() -> PartitionerSet {
        PartitionerSet { map: FxHashMap::default() }
    }

    /// The dense-linear-algebra set: Cholesky (POTRF/TRSM/SYRK/GEMM),
    /// LU and tile-QR blocked algorithms.
    pub fn standard() -> PartitionerSet {
        let mut s = PartitionerSet::empty();
        s.register(std::sync::Arc::new(cholesky::CholeskyPartitioner));
        s.register(std::sync::Arc::new(trsm::TrsmPartitioner));
        s.register(std::sync::Arc::new(syrk::SyrkPartitioner));
        s.register(std::sync::Arc::new(gemm::GemmPartitioner));
        s.register(std::sync::Arc::new(lu::LuPartitioner));
        s.register(std::sync::Arc::new(qr::QrPartitioner));
        s
    }

    pub fn register(&mut self, p: std::sync::Arc<dyn Partitioner>) {
        for k in p.kinds() {
            self.map.insert(k, p.clone());
        }
    }

    pub fn can_partition(&self, kind: TaskKind) -> bool {
        self.map.contains_key(&kind)
    }

    /// Plan the split of `task` at `sub_edge` without touching any DAG:
    /// the sub-task specs a partitioner would emit, or `None` when no
    /// partitioner applies / the edge is illegal for this task. The
    /// solver uses this to validate a `Repartition` *before* merging the
    /// cluster it would re-split.
    pub fn plan(&self, task: &Task, sub_edge: u32) -> Option<Vec<TaskSpec>> {
        let specs = self.map.get(&task.kind)?.partition(task, sub_edge)?;
        debug_assert!(!specs.is_empty());
        Some(specs)
    }

    /// Split leaf `id` of `dag` at `sub_edge`; returns the new child ids,
    /// or `None` if no partitioner applies / the edge is illegal.
    pub fn apply(&self, dag: &mut TaskDag, id: usize, sub_edge: u32) -> Option<Vec<usize>> {
        let task = dag.task(id).clone();
        let specs = self.plan(&task, sub_edge)?;
        Some(dag.partition(id, specs, sub_edge))
    }
}

/// Sub-edges at which a tile of edge `edge` can legally be split:
/// proper divisors, largest first, bounded below by `min_edge`.
pub fn legal_sub_edges(edge: u32, min_edge: u32) -> Vec<u32> {
    let mut out = Vec::new();
    let mut d = edge / 2;
    while d >= min_edge.max(1) {
        if edge % d == 0 {
            out.push(d);
        }
        d -= 1;
    }
    out
}

/// The sub-edge closest to `target` among the legal ones (used to realize
/// the paper's partition parameter `p` with `b = p * d`).
pub fn snap_sub_edge(edge: u32, target: f64, min_edge: u32) -> Option<u32> {
    legal_sub_edges(edge, min_edge)
        .into_iter()
        .min_by(|&a, &b| {
            let da = (a as f64 - target).abs();
            let db = (b as f64 - target).abs();
            da.total_cmp(&db).then(b.cmp(&a)) // prefer larger on ties
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn legal_edges_are_proper_divisors() {
        assert_eq!(legal_sub_edges(1024, 128), vec![512, 256, 128]);
        assert_eq!(legal_sub_edges(12, 1), vec![6, 4, 3, 2, 1]);
        assert!(legal_sub_edges(7, 1) == vec![1]);
        assert!(legal_sub_edges(64, 64).is_empty());
    }

    #[test]
    fn snap_picks_closest() {
        assert_eq!(snap_sub_edge(1024, 300.0, 64), Some(256));
        assert_eq!(snap_sub_edge(1024, 512.0, 64), Some(512));
        assert_eq!(snap_sub_edge(1024, 1.0, 64), Some(64));
        assert_eq!(snap_sub_edge(64, 32.0, 64), None);
    }

    #[test]
    fn plan_previews_apply_without_mutation() {
        let s = PartitionerSet::standard();
        let dag = cholesky::root(256);
        let task = dag.task(dag.root).clone();
        let specs = s.plan(&task, 64).expect("legal split");
        assert_eq!(specs.len() as u64, cholesky::task_count(4));
        assert!(s.plan(&task, 48).is_none(), "non-divisor rejected");
        assert!(s.plan(&task, 256).is_none(), "trivial blocking rejected");
    }

    #[test]
    fn standard_set_covers_all_la_kinds() {
        let s = PartitionerSet::standard();
        for k in [
            TaskKind::Potrf,
            TaskKind::Trsm,
            TaskKind::Syrk,
            TaskKind::Gemm,
            TaskKind::Getrf,
            TaskKind::Geqrt,
        ] {
            assert!(s.can_partition(k), "{k:?}");
        }
        assert!(!s.can_partition(TaskKind::Custom(0)));
    }
}
