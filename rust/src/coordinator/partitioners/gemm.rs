//! Blocked GEMM partitioner: splits `C -= A B^T` (operands `[A, B, C] ->
//! [C]`) into a 3-D tiling with sequential accumulation along p:
//!
//! ```text
//! for i, j, p:  GEMM  C[i][j] -= A[i][p] B[j][p]^T
//! ```

use crate::coordinator::region::Region;
use crate::coordinator::task::{Task, TaskKind, TaskSpec};

use super::Partitioner;

pub struct GemmPartitioner;

impl Partitioner for GemmPartitioner {
    fn kinds(&self) -> Vec<TaskKind> {
        vec![TaskKind::Gemm]
    }

    fn partition(&self, task: &Task, c: u32) -> Option<Vec<TaskSpec>> {
        if task.reads.len() < 3 {
            return None;
        }
        let a = task.reads[0];
        let b = task.reads[1];
        let cc = *task.writes.first()?;
        if c == 0 || cc.rows() % c != 0 || cc.cols() % c != 0 || a.cols() % c != 0 {
            return None;
        }
        if a.rows() != cc.rows() || b.rows() != cc.cols() || a.cols() != b.cols() {
            return None;
        }
        let (ti, tj, tp) = (cc.rows() / c, cc.cols() / c, a.cols() / c);
        if ti * tj * tp < 2 {
            return None;
        }
        let mut out = Vec::new();
        for i in 0..ti {
            for j in 0..tj {
                let cij = Region::tile(&cc, c, i, j);
                for p in 0..tp {
                    let aip = Region::tile(&a, c, i, p);
                    let bjp = Region::tile(&b, c, j, p);
                    out.push(TaskSpec::new(TaskKind::Gemm, vec![aip, bjp, cij], vec![cij]));
                }
            }
        }
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::taskdag::TaskDag;

    fn gemm_task(edge: u32) -> TaskDag {
        let a = Region::new(0, 0, edge, 0, edge);
        let b = Region::new(1, 0, edge, 0, edge);
        let c = Region::new(2, 0, edge, 0, edge);
        TaskDag::new(TaskSpec::new(TaskKind::Gemm, vec![a, b, c], vec![c]))
    }

    #[test]
    fn produces_t3_tasks() {
        let p = GemmPartitioner;
        let dag = gemm_task(8);
        let specs = p.partition(dag.task(0), 4).unwrap();
        assert_eq!(specs.len(), 8);
        assert!(specs.iter().all(|s| s.kind == TaskKind::Gemm));
    }

    #[test]
    fn flops_preserved() {
        let p = GemmPartitioner;
        let dag = gemm_task(16);
        let specs = p.partition(dag.task(0), 4).unwrap();
        let total: f64 = specs.iter().map(|s| s.flops()).sum();
        assert!((total - dag.task(0).flops).abs() < 1e-9);
    }

    #[test]
    fn k_chain_serializes_same_c_tile() {
        let p = GemmPartitioner;
        let mut dag = gemm_task(8);
        let specs = p.partition(dag.task(0), 4).unwrap();
        dag.partition(0, specs, 4);
        let flat = dag.flat_dag();
        // tasks 0,1 share C[0][0] (p=0,1) -> chain; tasks 2.. other tiles
        assert_eq!(flat.preds[1], vec![0]);
        assert!(flat.preds[2].is_empty());
        assert_eq!(flat.width(), 4, "4 independent C tiles");
    }

    #[test]
    fn rejects_mismatched_shapes() {
        let a = Region::new(0, 0, 8, 0, 4);
        let b = Region::new(1, 0, 8, 0, 8);
        let c = Region::new(2, 0, 8, 0, 8);
        let dag = TaskDag::new(TaskSpec::new(TaskKind::Gemm, vec![a, b, c], vec![c]));
        assert!(GemmPartitioner.partition(dag.task(0), 4).is_none());
    }
}
