//! Blocked TRSM partitioner: splits `X L^T = B` (operands `[L, B] -> [B]`,
//! L lower-triangular b x b, B m x b) into a grid of TRSM + GEMM sub-tasks
//! by column-block forward substitution:
//!
//! ```text
//! for j in 0..t:                       (column blocks of X/L)
//!   for i in 0..rows:
//!     for p in 0..j:  GEMM  B[i][j] -= X[i][p] * L[j][p]^T
//!     TRSM  X[i][j] = B[i][j] * L[j][j]^-T
//! ```

use crate::coordinator::region::Region;
use crate::coordinator::task::{Task, TaskKind, TaskSpec};

use super::Partitioner;

pub struct TrsmPartitioner;

impl Partitioner for TrsmPartitioner {
    fn kinds(&self) -> Vec<TaskKind> {
        vec![TaskKind::Trsm, TaskKind::TrsmL, TaskKind::TrsmU]
    }

    fn partition(&self, task: &Task, c: u32) -> Option<Vec<TaskSpec>> {
        let l = *task.reads.first()?;
        let b = *task.writes.first()?;
        if !l.is_square() || c == 0 {
            return None;
        }
        if l.rows() % c != 0 || b.rows() % c != 0 || l.rows() / c < 2 {
            return None;
        }
        let kind = task.kind;
        let t = l.rows() / c; // column blocks
        let rows = b.rows() / c;
        let ltile = |i: u32, j: u32| Region::tile(&l, c, i, j);
        let btile = |i: u32, j: u32| Region::tile(&b, c, i, j);
        let mut out = Vec::new();
        for j in 0..t {
            let ljj = ltile(j, j);
            for i in 0..rows {
                let bij = btile(i, j);
                for p in 0..j {
                    let xip = btile(i, p); // already-solved block
                    let ljp = ltile(j, p);
                    out.push(TaskSpec::new(TaskKind::Gemm, vec![xip, ljp, bij], vec![bij]));
                }
                out.push(TaskSpec::new(kind, vec![ljj, bij], vec![bij]));
            }
        }
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::taskdag::TaskDag;

    fn trsm_task(ledge: u32, brows: u32) -> TaskDag {
        let l = Region::new(0, 0, ledge, 0, ledge);
        let b = Region::new(1, 0, brows, 0, ledge);
        TaskDag::new(TaskSpec::new(TaskKind::Trsm, vec![l, b], vec![b]))
    }

    #[test]
    fn counts_and_kinds() {
        // t=2 col blocks, rows=2: per j=0: 2 trsm; j=1: 2 gemm + 2 trsm
        let p = TrsmPartitioner;
        let dag = trsm_task(8, 8);
        let specs = p.partition(dag.task(0), 4).unwrap();
        let trsm = specs.iter().filter(|s| s.kind == TaskKind::Trsm).count();
        let gemm = specs.iter().filter(|s| s.kind == TaskKind::Gemm).count();
        assert_eq!((trsm, gemm), (4, 2));
    }

    #[test]
    fn dependences_chain_column_blocks() {
        let p = TrsmPartitioner;
        let mut dag = trsm_task(8, 4);
        let specs = p.partition(dag.task(0), 4).unwrap();
        dag.partition(0, specs, 4);
        let flat = dag.flat_dag();
        // order: trsm(i0,j0), gemm(i0,j1), trsm(i0,j1)
        assert_eq!(flat.len(), 3);
        assert_eq!(flat.preds[1], vec![0], "gemm reads solved X[0][0]");
        assert_eq!(flat.preds[2], vec![1], "second trsm after its gemm");
    }

    #[test]
    fn rejects_illegal() {
        let p = TrsmPartitioner;
        let dag = trsm_task(8, 8);
        assert!(p.partition(dag.task(0), 3).is_none());
        assert!(p.partition(dag.task(0), 8).is_none());
    }

    #[test]
    fn flops_preserved() {
        let p = TrsmPartitioner;
        let dag = trsm_task(16, 16);
        let specs = p.partition(dag.task(0), 4).unwrap();
        let total: f64 = specs.iter().map(|s| s.flops()).sum();
        // b^3 for the 16-edge trsm = 4096; sub-tasks: 16 trsm*64 + gemm
        // chains 2*64 * (#gemms=24) ... just assert conservation:
        // rows*t trsm of c^3 + rows*t(t-1)/2 gemms of 2c^3
        let (c, t, rows) = (4f64, 4f64, 4f64);
        let expect = rows * t * c.powi(3) + rows * (t * (t - 1.0) / 2.0) * 2.0 * c.powi(3);
        assert!((total - expect).abs() < 1e-9);
        // equals parent flops (16^3 = 4096): 16*64 + 24*128 = 1024+3072
        assert!((total - 4096.0).abs() < 1e-9);
    }

    #[test]
    fn handles_trsm_l_kind() {
        let l = Region::new(0, 0, 8, 0, 8);
        let b = Region::new(1, 0, 8, 0, 8);
        let task = TaskDag::new(TaskSpec::new(TaskKind::TrsmL, vec![l, b], vec![b]));
        let p = TrsmPartitioner;
        let specs = p.partition(task.task(0), 4).unwrap();
        assert!(specs.iter().any(|s| s.kind == TaskKind::TrsmL));
        assert!(specs.iter().all(|s| s.kind != TaskKind::Trsm));
    }
}
