//! Blocked SYRK partitioner: splits `C -= A A^T` (operands `[A, C] -> [C]`,
//! both b x b tiles) into a tiled symmetric update:
//!
//! ```text
//! for i in 0..t: for j in 0..=i: for p in 0..t:
//!   i == j:  SYRK  C[i][i] -= A[i][p] A[i][p]^T
//!   i != j:  GEMM  C[i][j] -= A[i][p] A[j][p]^T
//! ```
//!
//! The p-loop forms a sequential accumulation chain on each C tile (WaW),
//! which the derived-dependence machinery captures automatically.

use crate::coordinator::region::Region;
use crate::coordinator::task::{Task, TaskKind, TaskSpec};

use super::Partitioner;

pub struct SyrkPartitioner;

impl Partitioner for SyrkPartitioner {
    fn kinds(&self) -> Vec<TaskKind> {
        vec![TaskKind::Syrk]
    }

    fn partition(&self, task: &Task, c: u32) -> Option<Vec<TaskSpec>> {
        let a = *task.reads.first()?;
        let cc = *task.writes.first()?;
        if !cc.is_square() || c == 0 || cc.rows() % c != 0 || a.rows() % c != 0 || a.cols() % c != 0 {
            return None;
        }
        if cc.rows() / c < 2 && a.cols() / c < 2 {
            return None;
        }
        let t = cc.rows() / c;
        let kp = a.cols() / c;
        let atile = |i: u32, p: u32| Region::tile(&a, c, i, p);
        let ctile = |i: u32, j: u32| Region::tile(&cc, c, i, j);
        let mut out = Vec::new();
        for i in 0..t {
            for j in 0..=i {
                let cij = ctile(i, j);
                for p in 0..kp {
                    if i == j {
                        out.push(TaskSpec::new(TaskKind::Syrk, vec![atile(i, p), cij], vec![cij]));
                    } else {
                        out.push(TaskSpec::new(TaskKind::Gemm, vec![atile(i, p), atile(j, p), cij], vec![cij]));
                    }
                }
            }
        }
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::taskdag::TaskDag;

    fn syrk_task(edge: u32) -> TaskDag {
        let a = Region::new(0, 0, edge, 0, edge);
        let c = Region::new(1, 0, edge, 0, edge);
        TaskDag::new(TaskSpec::new(TaskKind::Syrk, vec![a, c], vec![c]))
    }

    #[test]
    fn counts() {
        let p = SyrkPartitioner;
        let dag = syrk_task(8);
        let specs = p.partition(dag.task(0), 4).unwrap();
        // t=2, kp=2: diag targets 2 * 2 syrk, off-diag 1 * 2 gemm
        let syrk = specs.iter().filter(|s| s.kind == TaskKind::Syrk).count();
        let gemm = specs.iter().filter(|s| s.kind == TaskKind::Gemm).count();
        assert_eq!((syrk, gemm), (4, 2));
    }

    #[test]
    fn accumulation_chains_serialize() {
        let p = SyrkPartitioner;
        let mut dag = syrk_task(8);
        let specs = p.partition(dag.task(0), 4).unwrap();
        dag.partition(0, specs, 4);
        let flat = dag.flat_dag();
        // first two tasks update C[0][0] with p=0,1 -> chain
        assert!(flat.preds[1].contains(&0));
    }

    #[test]
    fn independent_c_tiles_are_parallel() {
        let p = SyrkPartitioner;
        let mut dag = syrk_task(8);
        let specs = p.partition(dag.task(0), 4).unwrap();
        dag.partition(0, specs, 4);
        let flat = dag.flat_dag();
        assert!(flat.width() >= 2, "different C tiles update in parallel");
    }

    #[test]
    fn rejects_illegal() {
        let p = SyrkPartitioner;
        let dag = syrk_task(8);
        assert!(p.partition(dag.task(0), 3).is_none());
        assert!(p.partition(dag.task(0), 8).is_none());
    }
}
