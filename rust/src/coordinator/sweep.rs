//! Parallel multi-scenario experiment harness: grid → cells → workers →
//! aggregate.
//!
//! The paper's claims (Table 1, Fig. 5) come from sweeping policy x
//! platform x granularity grids; this module turns such a sweep into a
//! declarative [`SweepGrid`] — platform x workload x policy x tile edge x
//! mode x seed — that expands into independent [`SweepCell`]s and executes
//! them across `std::thread::scope` workers sharing the immutable
//! `&Machine`/`&PerfDb` platform state (no external thread pool: the
//! workspace is vendored-deps-only).
//!
//! Determinism contract: every cell derives its RNG seed from its grid
//! *coordinates* ([`cell_seed`] — content, not position), so
//!
//! * a parallel run is **byte-identical** to the single-threaded run on
//!   the same grid (results aggregate in grid order, not completion
//!   order), and
//! * reordering the grid axes relabels nothing: the same cell always
//!   simulates the same trajectory.
//!
//! Results aggregate into one CSV/JSON bundle (`bench_out/sweep.csv` via
//! [`write_sweep_bundle`]) with makespan, useful GFLOPS, load, transfer
//! bytes, energy and `peak_in_flight_transfers` per cell. The `hesp
//! sweep` CLI, `benches/table1.rs` and `benches/fig5_policies.rs` all run
//! on this harness.

use std::path::{Path, PathBuf};

use super::coherence::CachePolicy;
use super::delta::DeltaMode;
use super::energy::{energy, DEFAULT_J_PER_BYTE};
use super::engine::{simulate_flat_faults, simulate_policy, SimConfig};
use super::faults::{FaultEnsemble, FaultPlan, FaultSpec};
use super::lower_bound::makespan_lower_bound;
use super::metrics::{peak_in_flight_transfers, report};
use super::partitioners::{cholesky, lu, qr, PartitionerSet};
use super::perfmodel::PerfDb;
use super::platform::Machine;
use super::policies::{Ordering, ProcSelect, SchedConfig};
use super::policy::PolicyRegistry;
use super::solver::{solve_portfolio, PortfolioConfig, SolverConfig};
use super::taskdag::TaskDag;
use super::workloads;
use crate::util::fxhash::content_seed;
use crate::util::json::Json;
use crate::util::par::par_map;

/// One platform axis entry: a loaded machine + performance database.
/// Built from a `configs/*.toml` file ([`SweepPlatform::from_file`]) or
/// assembled in memory (tests, synthetic studies).
pub struct SweepPlatform {
    pub name: String,
    pub machine: Machine,
    pub db: PerfDb,
    pub elem_bytes: u64,
}

impl SweepPlatform {
    pub fn new(name: &str, machine: Machine, db: PerfDb, elem_bytes: u64) -> SweepPlatform {
        SweepPlatform { name: name.to_string(), machine, db, elem_bytes }
    }

    /// Load a platform TOML (same schema as `hesp --platform`); the
    /// machine's own `name =` key labels the axis entry.
    pub fn from_file<P: AsRef<Path>>(path: P) -> anyhow::Result<SweepPlatform> {
        let p = crate::config::Platform::from_file(path)?;
        let name = p.machine.name.clone();
        Ok(SweepPlatform { name, machine: p.machine, db: p.db, elem_bytes: p.elem_bytes })
    }
}

/// The workload axis: dense-linear-algebra roots (uniformly tiled at the
/// cell's tile edge) plus the synthetic [`workloads`] DAG shapes, where
/// the tile edge sets the block size.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Workload {
    Cholesky { n: u32 },
    Lu { n: u32 },
    Qr { n: u32 },
    Layered { layers: u32, width: u32 },
    Stencil { cells: u32, steps: u32 },
    Random { n: u32 },
}

impl Workload {
    /// Stable label — a CSV key and the spec syntax [`Workload::parse`]
    /// accepts back.
    pub fn label(&self) -> String {
        match *self {
            Workload::Cholesky { n } => format!("cholesky:{n}"),
            Workload::Lu { n } => format!("lu:{n}"),
            Workload::Qr { n } => format!("qr:{n}"),
            Workload::Layered { layers, width } => format!("layered:{layers}x{width}"),
            Workload::Stencil { cells, steps } => format!("stencil:{cells}x{steps}"),
            Workload::Random { n } => format!("random:{n}"),
        }
    }

    /// Parse a workload spec: `cholesky:16384`, `lu:8192`, `qr:4096`,
    /// `layered:4x16`, `stencil:32x8`, `random:128`. A bare name takes
    /// the default size.
    pub fn parse(s: &str) -> Option<Workload> {
        let (name, arg) = match s.split_once(':') {
            Some((n, a)) => (n, a),
            None => (s, ""),
        };
        let n_or = |d: u32| -> Option<u32> {
            if arg.is_empty() {
                Some(d)
            } else {
                arg.parse().ok()
            }
        };
        let pair_or = |d: (u32, u32)| -> Option<(u32, u32)> {
            if arg.is_empty() {
                return Some(d);
            }
            let (a, b) = arg.split_once('x')?;
            Some((a.parse().ok()?, b.parse().ok()?))
        };
        Some(match name.to_ascii_lowercase().as_str() {
            "cholesky" | "chol" | "potrf" => Workload::Cholesky { n: n_or(16_384)? },
            "lu" | "getrf" => Workload::Lu { n: n_or(16_384)? },
            "qr" | "geqrt" => Workload::Qr { n: n_or(16_384)? },
            "layered" => {
                let (l, w) = pair_or((4, 16))?;
                Workload::Layered { layers: l, width: w }
            }
            "stencil" => {
                let (c, s) = pair_or((16, 8))?;
                Workload::Stencil { cells: c, steps: s }
            }
            "random" => Workload::Random { n: n_or(128)? },
            _ => return None,
        })
    }

    /// Can this workload be tiled at edge `b`? The LA roots need a proper
    /// divisor; the synthetic shapes take any positive block size.
    pub fn feasible(&self, b: u32) -> bool {
        match *self {
            Workload::Cholesky { n } | Workload::Lu { n } | Workload::Qr { n } => {
                b > 0 && n % b == 0 && n / b >= 2
            }
            _ => b > 0,
        }
    }

    /// Build the tiled frontier DAG at tile edge `b`. `seed` drives only
    /// the random-layered generator.
    pub fn build(&self, b: u32, seed: u64) -> Option<TaskDag> {
        if !self.feasible(b) {
            return None;
        }
        Some(match *self {
            Workload::Cholesky { n } => {
                let mut dag = cholesky::root(n);
                cholesky::partition_uniform(&mut dag, b);
                dag
            }
            Workload::Lu { n } => tiled(lu::root(n), b)?,
            Workload::Qr { n } => tiled(qr::root(n), b)?,
            Workload::Layered { layers, width } => workloads::layered(layers, width, b),
            Workload::Stencil { cells, steps } => workloads::stencil(cells, steps, b),
            Workload::Random { n } => workloads::random_layered(n, b, seed),
        })
    }
}

/// Uniform blocking of an LA root task through its registered partitioner.
fn tiled(mut dag: TaskDag, b: u32) -> Option<TaskDag> {
    let root = dag.root;
    PartitionerSet::standard().apply(&mut dag, root, b)?;
    Some(dag)
}

/// What each cell runs: a plain simulation of the tiling, or the full
/// iterative scheduler-partitioner starting from it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CellMode {
    Simulate,
    Solve { iters: usize, min_edge: u32 },
}

impl CellMode {
    pub fn label(&self) -> String {
        match *self {
            CellMode::Simulate => "sim".to_string(),
            CellMode::Solve { iters, min_edge } => format!("solve:{iters}:{min_edge}"),
        }
    }

    /// Parse `sim` | `solve` | `solve:<iters>` | `solve:<iters>:<min_edge>`.
    pub fn parse(s: &str) -> Option<CellMode> {
        if s == "sim" || s == "simulate" {
            return Some(CellMode::Simulate);
        }
        let rest = s.strip_prefix("solve")?;
        if rest.is_empty() {
            return Some(CellMode::Solve { iters: 100, min_edge: 64 });
        }
        let mut it = rest.strip_prefix(':')?.split(':');
        let iters = it.next()?.parse().ok()?;
        let min_edge = match it.next() {
            Some(x) => x.parse().ok()?,
            None => 64,
        };
        Some(CellMode::Solve { iters, min_edge })
    }
}

/// The declarative scenario grid. [`SweepGrid::expand`] takes the cross
/// product of all six axes, skipping infeasible (workload, tile) pairs.
pub struct SweepGrid {
    pub platforms: Vec<SweepPlatform>,
    pub workloads: Vec<Workload>,
    /// Registry policy names (`PolicyRegistry::standard` resolves them).
    pub policies: Vec<String>,
    pub tiles: Vec<u32>,
    pub modes: Vec<CellMode>,
    pub seeds: Vec<u64>,
    /// Write-caching policy for every cell's simulation (a global grid
    /// knob, like the platform's `elem_bytes` — not a seed coordinate).
    pub cache: CachePolicy,
    /// Portfolio lanes for `solve`-mode cells (grid-level search knob,
    /// like `cache` — not a seed coordinate). 1 = the classic single
    /// trajectory: same seed, same sampling draws, same applied actions
    /// (the batched loop additionally scores the final accepted state and
    /// rejects non-finite evaluations, so a cell's reported best can only
    /// improve on the pre-portfolio solver's).
    pub solve_lanes: usize,
    /// Candidates evaluated per solver iteration in `solve`-mode cells.
    pub solve_batch: usize,
    /// Incremental re-simulation mode for `solve`-mode cells (another
    /// grid-level execution knob: the reported trajectory is byte-
    /// identical whatever the mode — only wall-clock and the
    /// `replay_frac` column react to it).
    pub delta: DeltaMode,
    /// The fault axis: `None` = fault-free (label `off`), `Some(spec)` =
    /// every cell of that slice simulates under one deterministic member
    /// plan of the spec (`sim` mode) or prices candidates over a
    /// [`FaultEnsemble`] (`solve` mode). The member draw is a pure
    /// function of (spec, platform, workload, tile, seed) — policy and
    /// mode deliberately excluded, so every policy faces the *identical*
    /// fault trace and rows compare paired. An all-`off` axis leaves the
    /// CSV/JSON bundle byte-identical to a grid without the axis at all.
    pub faults: Vec<Option<FaultSpec>>,
    /// Ensemble members per fault-aware `solve` cell (min 1).
    pub fault_members: u64,
}

/// One executable point of the grid.
#[derive(Debug, Clone)]
pub struct SweepCell {
    /// Index into [`SweepGrid::platforms`].
    pub platform: usize,
    pub workload: Workload,
    pub policy: String,
    pub tile: u32,
    pub mode: CellMode,
    /// The declared seed-axis value (the derived per-cell RNG seed is
    /// [`cell_seed`]).
    pub seed: u64,
    /// Index into [`SweepGrid::faults`] (0 when the grid has no fault
    /// axis). Deliberately not a [`cell_seed`] coordinate: the scheduler
    /// RNG stays fixed while the fault model varies, so fault columns
    /// compare paired against their `off` twin.
    pub fault: usize,
}

impl SweepGrid {
    /// Expand the grid into cells, platform-major, in deterministic axis
    /// order. Infeasible (workload, tile) pairs are skipped, not errors:
    /// a shared tile axis rarely divides every workload size.
    pub fn expand(&self) -> Vec<SweepCell> {
        let mut out = Vec::new();
        for pi in 0..self.platforms.len() {
            for w in &self.workloads {
                for pol in &self.policies {
                    for &b in &self.tiles {
                        if !w.feasible(b) {
                            continue;
                        }
                        for m in &self.modes {
                            for &s in &self.seeds {
                                // an empty fault axis means "no axis":
                                // one fault-free cell, not zero cells
                                for fi in 0..self.faults.len().max(1) {
                                    out.push(SweepCell {
                                        platform: pi,
                                        workload: *w,
                                        policy: pol.clone(),
                                        tile: b,
                                        mode: *m,
                                        seed: s,
                                        fault: fi,
                                    });
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }
}

/// Deterministic per-cell RNG seed, derived from the cell's grid
/// *coordinates* (labels, not positions): identical across thread counts
/// and stable under any reordering of the grid axes. One instantiation of
/// the shared [`content_seed`] recipe (FxHash + separators, mixed once
/// through SplitMix64), like [`workload_seed`] and the portfolio solver's
/// [`super::solver::lane_seed`].
pub fn cell_seed(platform: &str, workload: &str, policy: &str, tile: u32, mode: &str, seed: u64) -> u64 {
    content_seed(&[platform, workload, policy, mode], &[tile as u64, seed])
}

/// Seed for the workload *generator* (DAG structure) — a function of the
/// structural coordinates only (workload, tile, declared seed). Policy
/// and mode deliberately do not enter: every policy/mode cell of a
/// random workload must schedule the *same* DAG instance, or cross-policy
/// comparisons would rank whoever drew the easiest graph. The scheduler
/// RNG uses [`cell_seed`] instead.
pub fn workload_seed(workload: &str, tile: u32, seed: u64) -> u64 {
    content_seed(&[workload], &[tile as u64, seed])
}

/// Ensemble-member index for a fault-axis cell: a pure function of the
/// spec and the cell's *scenario* coordinates (platform, workload, tile,
/// declared seed). Policy and mode deliberately do not enter — every
/// policy row of one scenario replays the identical fault trace, so the
/// fault columns compare paired, like [`workload_seed`] pins the DAG.
pub fn fault_member_seed(spec: &FaultSpec, platform: &str, workload: &str, tile: u32, seed: u64) -> u64 {
    content_seed(&["sweep-faults", &spec.name, platform, workload], &[tile as u64, seed])
}

/// Everything one cell reports — the columns of `bench_out/sweep.csv`.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub platform: String,
    pub workload: String,
    pub policy: String,
    pub tile: u32,
    pub mode: String,
    pub seed: u64,
    /// Fault-axis label: `off`, or the spec's name. Rows only grow a
    /// `faults` CSV/JSON column when some cell's label is not `off`.
    pub fault: String,
    pub cell_seed: u64,
    pub n_tasks: usize,
    pub dag_depth: u32,
    pub makespan: f64,
    pub gflops: f64,
    pub avg_load_pct: f64,
    pub transfer_bytes: u64,
    pub energy_j: f64,
    pub peak_in_flight: usize,
    /// Baseline (pre-solver) simulation of the uniform tiling; equals
    /// `makespan`/`gflops` for `sim` cells.
    pub hom_makespan: f64,
    pub hom_gflops: f64,
    /// Solver moves that were sampled but not applicable (see
    /// `IterLog::applied`); 0 for `sim` cells.
    pub failed_moves: usize,
    /// Makespan over the critical-path/area lower bound of the *reported*
    /// DAG ([`super::lower_bound`]) — an optimality yardstick: 1.0 means
    /// provably optimal, and the gap is an upper bound on what any
    /// scheduler could still recover at this tiling. 0 when the bound or
    /// makespan is degenerate (empty frontier, infeasible cell).
    pub makespan_over_lb: f64,
    /// Fraction of simulated events the solver *skipped* re-executing
    /// thanks to incremental re-simulation (verified prefix / total
    /// events across every candidate evaluation); 0 for `sim` cells and
    /// for `delta = "off"` grids. An execution diagnostic — it never
    /// feeds back into any reported metric.
    pub replay_frac: f64,
}

impl CellResult {
    /// Solver improvement over the uniform-tiling baseline, in percent.
    pub fn improve_pct(&self) -> f64 {
        if self.hom_gflops > 0.0 {
            100.0 * (self.gflops - self.hom_gflops) / self.hom_gflops
        } else {
            0.0
        }
    }
}

/// Default worker count: one per available hardware thread.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Execute every cell of `grid` across `threads` workers.
pub fn run_sweep(grid: &SweepGrid, threads: usize) -> Vec<CellResult> {
    run_cells(grid, &grid.expand(), threads)
}

/// Execute an explicit cell list (for two-phase experiments like Table 1:
/// sweep homogeneous tilings, pick winners, solve from them). Workers
/// ([`par_map`] — the same scoped-thread machinery the portfolio solver
/// uses) pull cells off a shared atomic cursor; results land in cell-list
/// order, so the aggregate is identical for any thread count.
///
/// Solve-mode cells receive the *leftover* thread budget (`threads /
/// n_cells`, min 1) instead of nesting a second full pool: a grid with
/// fewer cells than workers — a single Table-1 solve cell, say — spends
/// the spare threads inside the portfolio solver, while a wide grid keeps
/// every thread on cells. Either split yields identical bytes; only the
/// wall-clock changes.
pub fn run_cells(grid: &SweepGrid, cells: &[SweepCell], threads: usize) -> Vec<CellResult> {
    let requested = threads.max(1);
    let workers = requested.clamp(1, cells.len().max(1));
    let cell_threads = (requested / cells.len().max(1)).max(1);
    let parts = PartitionerSet::standard();
    let reg = PolicyRegistry::standard();
    par_map(workers, cells, |_, cell| run_cell(grid, cell, &parts, &reg, cell_threads))
}

fn run_cell(
    grid: &SweepGrid,
    cell: &SweepCell,
    parts: &PartitionerSet,
    reg: &PolicyRegistry,
    cell_threads: usize,
) -> CellResult {
    let p = &grid.platforms[cell.platform];
    let wl = cell.workload.label();
    let ml = cell.mode.label();
    let cseed = cell_seed(&p.name, &wl, &cell.policy, cell.tile, &ml, cell.seed);
    let sim = SimConfig::new(SchedConfig::new(Ordering::PriorityList, ProcSelect::EarliestFinish))
        .with_cache(grid.cache)
        .with_elem_bytes(p.elem_bytes)
        .with_seed(cseed);
    let mut pol = reg
        .get(&cell.policy)
        // detlint: allow(safety/panic-in-lib) — policy names are registry-validated by grid_from_toml before any cell runs
        .unwrap_or_else(|| panic!("unknown policy '{}' in sweep grid", cell.policy));
    let dag = cell
        .workload
        .build(cell.tile, workload_seed(&wl, cell.tile, cell.seed))
        // detlint: allow(safety/panic-in-lib) — expand() filters by Workload::feasible, so build cannot fail here
        .expect("expand() emits only feasible cells");

    // the fault-axis entry for this cell; an empty spec IS `off`, down
    // to the label, so an all-empty axis changes no output byte
    let fspec = grid.faults.get(cell.fault).and_then(|o| o.as_ref()).filter(|s| !s.is_empty());
    let fl = match fspec {
        None => "off".to_string(),
        Some(s) => s.name.clone(),
    };
    let plan = fspec
        .map(|s| FaultPlan::new(s, fault_member_seed(s, &p.name, &wl, cell.tile, cell.seed)));

    let flat = dag.flat_dag();
    let base = match &plan {
        None => simulate_policy(&dag, &p.machine, &p.db, sim, pol.as_mut()),
        Some(pl) => simulate_flat_faults(&dag, &flat, &p.machine, &p.db, sim, pol.as_mut(), pl),
    };
    // debug-build oracle pass over every cell baseline (inf-makespan cells
    // — zero-rate curves or exhausted attempt budgets — are infeasible
    // results, not violations); fault cells go through the fault oracle
    #[cfg(debug_assertions)]
    if base.makespan.is_finite() {
        match &plan {
            None => super::validate::assert_valid(&dag, &flat, &p.machine, &base),
            Some(pl) => super::validate::assert_valid_faults(&dag, &flat, &p.machine, &base, pl),
        }
    }
    let base_r = report(&dag, &base);

    let (sched, r, failed, lb, replay_frac) = match cell.mode {
        CellMode::Simulate => {
            let lb = makespan_lower_bound(&dag, &flat, &p.machine, &p.db);
            (base, base_r.clone(), 0, lb, 0.0)
        }
        CellMode::Solve { iters, min_edge } => {
            let mut cfg = SolverConfig::all_soft(sim, iters, min_edge);
            cfg.seed = cseed;
            let pcfg = PortfolioConfig {
                base: cfg,
                batch: grid.solve_batch.max(1),
                lanes: grid.solve_lanes.max(1),
                threads: cell_threads,
                lane_specs: Vec::new(),
                delta: grid.delta,
                faults: fspec.map(|s| FaultEnsemble::new(s.clone(), grid.fault_members)),
            };
            let res = solve_portfolio(&dag, &p.machine, &p.db, parts, reg, &cell.policy, &pcfg);
            let failed = res.history.iter().filter(|h| h.action.is_some() && !h.applied).count();
            let replay_frac = res.replay_stats().replay_fraction();
            // bound the DAG the solver actually reports — repartitioning
            // changes both the makespan and what is achievable
            let lb = makespan_lower_bound(&res.best_dag, &res.best_dag.flat_dag(), &p.machine, &p.db);
            let r = report(&res.best_dag, &res.best_schedule);
            (res.best_schedule, r, failed, lb, replay_frac)
        }
    };
    let e = energy(&sched, &p.machine, DEFAULT_J_PER_BYTE);
    CellResult {
        platform: p.name.clone(),
        workload: wl,
        policy: cell.policy.clone(),
        tile: cell.tile,
        mode: ml,
        seed: cell.seed,
        fault: fl,
        cell_seed: cseed,
        n_tasks: r.n_tasks,
        dag_depth: r.dag_depth,
        makespan: r.makespan,
        gflops: r.gflops,
        avg_load_pct: r.avg_load_pct,
        transfer_bytes: r.transfer_bytes,
        energy_j: e.total(),
        peak_in_flight: peak_in_flight_transfers(&sched),
        hom_makespan: base_r.makespan,
        hom_gflops: base_r.gflops,
        failed_moves: failed,
        makespan_over_lb: if lb > 0.0 && r.makespan.is_finite() { r.makespan / lb } else { 0.0 },
        replay_frac,
    }
}

/// CSV header of [`to_csv`] rows.
pub const CSV_HEADER: &str = "platform,workload,policy,tile,mode,seed,cell_seed,n_tasks,dag_depth,\
makespan_s,gflops,avg_load_pct,transfer_bytes,energy_j,peak_in_flight_transfers,\
hom_makespan_s,hom_gflops,improve_pct,failed_moves,makespan_over_lb,replay_frac";

/// Aggregate results as CSV, one row per cell in grid order. Fixed-width
/// float formatting keeps the output byte-stable across runs and thread
/// counts. A `faults` column appears only when some cell ran under a
/// fault spec — an all-`off` grid keeps the exact pre-fault-axis bytes.
pub fn to_csv(results: &[CellResult]) -> String {
    let ext = results.iter().any(|r| r.fault != "off");
    let mut out = String::with_capacity(128 * (results.len() + 1));
    out.push_str(CSV_HEADER);
    if ext {
        out.push_str(",faults");
    }
    out.push('\n');
    for r in results {
        out.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{:.6},{:.3},{:.2},{},{:.3},{},{:.6},{:.3},{:.2},{},{:.4},{:.4}",
            r.platform,
            r.workload,
            r.policy,
            r.tile,
            r.mode,
            r.seed,
            r.cell_seed,
            r.n_tasks,
            r.dag_depth,
            r.makespan,
            r.gflops,
            r.avg_load_pct,
            r.transfer_bytes,
            r.energy_j,
            r.peak_in_flight,
            r.hom_makespan,
            r.hom_gflops,
            r.improve_pct(),
            r.failed_moves,
            r.makespan_over_lb,
            r.replay_frac,
        ));
        if ext {
            out.push(',');
            out.push_str(&r.fault);
        }
        out.push('\n');
    }
    out
}

/// Aggregate results as a JSON array (machine-readable twin of the CSV,
/// including the gated `faults` key).
pub fn to_json(results: &[CellResult]) -> String {
    let ext = results.iter().any(|r| r.fault != "off");
    let arr: Vec<Json> = results
        .iter()
        .map(|r| {
            let mut o = std::collections::BTreeMap::new();
            if ext {
                o.insert("faults".into(), Json::Str(r.fault.clone()));
            }
            o.insert("platform".into(), Json::Str(r.platform.clone()));
            o.insert("workload".into(), Json::Str(r.workload.clone()));
            o.insert("policy".into(), Json::Str(r.policy.clone()));
            o.insert("tile".into(), Json::Num(r.tile as f64));
            o.insert("mode".into(), Json::Str(r.mode.clone()));
            o.insert("seed".into(), Json::Num(r.seed as f64));
            o.insert("n_tasks".into(), Json::Num(r.n_tasks as f64));
            o.insert("dag_depth".into(), Json::Num(r.dag_depth as f64));
            o.insert("makespan_s".into(), Json::Num(r.makespan));
            o.insert("gflops".into(), Json::Num(r.gflops));
            o.insert("avg_load_pct".into(), Json::Num(r.avg_load_pct));
            o.insert("transfer_bytes".into(), Json::Num(r.transfer_bytes as f64));
            o.insert("energy_j".into(), Json::Num(r.energy_j));
            o.insert("peak_in_flight_transfers".into(), Json::Num(r.peak_in_flight as f64));
            o.insert("hom_makespan_s".into(), Json::Num(r.hom_makespan));
            o.insert("hom_gflops".into(), Json::Num(r.hom_gflops));
            o.insert("improve_pct".into(), Json::Num(r.improve_pct()));
            o.insert("failed_moves".into(), Json::Num(r.failed_moves as f64));
            o.insert("makespan_over_lb".into(), Json::Num(r.makespan_over_lb));
            o.insert("replay_frac".into(), Json::Num(r.replay_frac));
            Json::Obj(o)
        })
        .collect();
    Json::Arr(arr).to_string()
}

/// Write the aggregate bundle (`sweep.csv` + `sweep.json`) into `dir`.
pub fn write_sweep_bundle(dir: &Path, results: &[CellResult]) -> std::io::Result<(PathBuf, PathBuf)> {
    std::fs::create_dir_all(dir)?;
    let csv = dir.join("sweep.csv");
    std::fs::write(&csv, to_csv(results))?;
    let json = dir.join("sweep.json");
    std::fs::write(&json, to_json(results))?;
    Ok((csv, json))
}

/// Load a declarative grid from a TOML file:
///
/// ```toml
/// platforms   = ["configs/bujaruelo.toml", "configs/odroid.toml"]
/// workloads   = ["cholesky:16384", "lu:8192", "stencil:32x8"]
/// policies    = ["all"]            # or explicit registry names
/// tiles       = [512, 1024, 2048]
/// modes       = ["sim", "solve:120:128"]
/// seeds       = [0, 1]
/// cache       = "wb"               # optional: wb | wt | wa
/// solve_lanes = 4                  # optional: portfolio lanes per solve cell
/// solve_batch = 2                  # optional: candidates evaluated per iter
/// delta       = "auto"             # optional: on | off | auto (incremental re-simulation)
/// faults      = ["off", "configs/faults_quick.toml"]  # optional fault axis
/// fault_members = 3                # optional: ensemble members per fault solve cell
/// ```
pub fn grid_from_toml(text: &str) -> anyhow::Result<SweepGrid> {
    use anyhow::anyhow;
    let doc = crate::util::toml::parse(text).map_err(|e| anyhow!(e))?;
    let str_list = |key: &str| -> Option<Vec<String>> {
        doc.get(key)?
            .as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_str().map(|s| s.to_string())).collect())
    };

    let platform_paths =
        str_list("platforms").ok_or_else(|| anyhow!("grid file needs platforms = [\"configs/...\"]"))?;
    let mut platforms = Vec::new();
    for p in &platform_paths {
        platforms.push(SweepPlatform::from_file(p)?);
    }

    let workloads = match str_list("workloads") {
        Some(specs) => {
            let mut out = Vec::new();
            for s in &specs {
                out.push(Workload::parse(s).ok_or_else(|| anyhow!("bad workload spec '{s}'"))?);
            }
            out
        }
        None => vec![Workload::Cholesky { n: 16_384 }],
    };

    let reg = PolicyRegistry::standard();
    let policies = match str_list("policies") {
        Some(names) if names.len() == 1 && names[0].eq_ignore_ascii_case("all") => {
            reg.names().iter().map(|s| s.to_string()).collect()
        }
        Some(names) => {
            let mut out = Vec::new();
            for n in &names {
                let pol = reg.get(n).ok_or_else(|| anyhow!("unknown policy '{n}' in grid file"))?;
                out.push(pol.name().to_string());
            }
            out
        }
        None => reg.names().iter().map(|s| s.to_string()).collect(),
    };

    let tiles: Vec<u32> = match doc.get("tiles").and_then(|v| v.as_arr()) {
        Some(a) => {
            let mut out = Vec::new();
            for v in a {
                let x = v.as_i64().ok_or_else(|| anyhow!("tiles entries must be integers"))?;
                if x <= 0 {
                    return Err(anyhow!("tile edge must be positive, got {x}"));
                }
                out.push(x as u32);
            }
            out
        }
        None => vec![512, 1024, 2048],
    };

    let modes = match str_list("modes") {
        Some(specs) => {
            let mut out = Vec::new();
            for s in &specs {
                out.push(CellMode::parse(s).ok_or_else(|| anyhow!("bad mode spec '{s}'"))?);
            }
            out
        }
        None => vec![CellMode::Simulate],
    };

    let seeds: Vec<u64> = match doc.get("seeds").and_then(|v| v.as_arr()) {
        Some(a) => {
            let mut out = Vec::new();
            for v in a {
                let x = v.as_i64().ok_or_else(|| anyhow!("seeds entries must be integers"))?;
                if x < 0 {
                    return Err(anyhow!("seed must be non-negative, got {x}"));
                }
                out.push(x as u64);
            }
            out
        }
        None => vec![0],
    };

    let cache = match doc.get("cache").and_then(|v| v.as_str()) {
        Some(s) => CachePolicy::from_name(s).ok_or_else(|| anyhow!("bad cache policy '{s}' (wb | wt | wa)"))?,
        None => CachePolicy::WriteBack,
    };

    let pos_int = |key: &str| -> anyhow::Result<usize> {
        match doc.get(key) {
            None => Ok(1),
            Some(v) => {
                let x = v.as_i64().ok_or_else(|| anyhow!("{key} must be an integer"))?;
                if x <= 0 {
                    return Err(anyhow!("{key} must be positive, got {x}"));
                }
                Ok(x as usize)
            }
        }
    };
    let solve_lanes = pos_int("solve_lanes")?;
    let solve_batch = pos_int("solve_batch")?;

    let delta = match doc.get("delta").and_then(|v| v.as_str()) {
        Some(s) => DeltaMode::from_name(s).ok_or_else(|| anyhow!("bad delta mode '{s}' (on | off | auto)"))?,
        None => DeltaMode::Off,
    };

    let faults = match str_list("faults") {
        Some(entries) => {
            let mut out = Vec::new();
            for e in &entries {
                if e.eq_ignore_ascii_case("off") {
                    out.push(None);
                } else {
                    out.push(Some(FaultSpec::from_file(e).map_err(|msg| anyhow!(msg))?));
                }
            }
            if out.is_empty() {
                vec![None]
            } else {
                out
            }
        }
        None => vec![None],
    };
    let fault_members = match doc.get("fault_members") {
        None => 3,
        Some(_) => pos_int("fault_members")? as u64,
    };

    Ok(SweepGrid {
        platforms,
        workloads,
        policies,
        tiles,
        modes,
        seeds,
        cache,
        solve_lanes,
        solve_batch,
        delta,
        faults,
        fault_members,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_labels_round_trip() {
        for w in [
            Workload::Cholesky { n: 4096 },
            Workload::Lu { n: 8192 },
            Workload::Qr { n: 2048 },
            Workload::Layered { layers: 4, width: 16 },
            Workload::Stencil { cells: 32, steps: 8 },
            Workload::Random { n: 128 },
        ] {
            assert_eq!(Workload::parse(&w.label()), Some(w), "{}", w.label());
        }
        assert_eq!(Workload::parse("chol:1024"), Some(Workload::Cholesky { n: 1024 }));
        assert_eq!(Workload::parse("cholesky"), Some(Workload::Cholesky { n: 16_384 }));
        assert!(Workload::parse("fft:1024").is_none());
        assert!(Workload::parse("layered:4").is_none());
    }

    #[test]
    fn mode_labels_round_trip() {
        for m in [CellMode::Simulate, CellMode::Solve { iters: 120, min_edge: 128 }] {
            assert_eq!(CellMode::parse(&m.label()), Some(m), "{}", m.label());
        }
        assert_eq!(CellMode::parse("solve"), Some(CellMode::Solve { iters: 100, min_edge: 64 }));
        assert_eq!(CellMode::parse("solve:50"), Some(CellMode::Solve { iters: 50, min_edge: 64 }));
        assert!(CellMode::parse("train").is_none());
    }

    #[test]
    fn feasibility_rules() {
        let c = Workload::Cholesky { n: 256 };
        assert!(c.feasible(64));
        assert!(!c.feasible(48), "48 does not divide 256");
        assert!(!c.feasible(256), "single-tile grid is not a blocking");
        assert!(!c.feasible(0));
        let s = Workload::Stencil { cells: 4, steps: 2 };
        assert!(s.feasible(48), "synthetic shapes take any positive block edge");
        assert!(!s.feasible(0));
    }

    #[test]
    fn cell_seed_depends_on_every_coordinate() {
        let base = cell_seed("m", "cholesky:256", "pl/eft-p", 64, "sim", 0);
        assert_eq!(base, cell_seed("m", "cholesky:256", "pl/eft-p", 64, "sim", 0), "deterministic");
        assert_ne!(base, cell_seed("m2", "cholesky:256", "pl/eft-p", 64, "sim", 0));
        assert_ne!(base, cell_seed("m", "cholesky:512", "pl/eft-p", 64, "sim", 0));
        assert_ne!(base, cell_seed("m", "cholesky:256", "pl/affinity", 64, "sim", 0));
        assert_ne!(base, cell_seed("m", "cholesky:256", "pl/eft-p", 128, "sim", 0));
        assert_ne!(base, cell_seed("m", "cholesky:256", "pl/eft-p", 64, "solve:10:32", 0));
        assert_ne!(base, cell_seed("m", "cholesky:256", "pl/eft-p", 64, "sim", 1));
        // concatenation ambiguity: field boundaries are separated
        assert_ne!(
            cell_seed("ab", "c", "p", 1, "sim", 0),
            cell_seed("a", "bc", "p", 1, "sim", 0)
        );
    }

    #[test]
    fn expand_skips_infeasible_cells_only() {
        use crate::coordinator::platform::MachineBuilder;
        let mut b = MachineBuilder::new("m");
        let h = b.space("host", u64::MAX);
        b.main(h);
        let t = b.proc_type("cpu", 1.0, 0.1);
        b.processors(2, "c", t, h);
        let grid = SweepGrid {
            platforms: vec![SweepPlatform::new("m", b.build(), PerfDb::new(), 8)],
            workloads: vec![Workload::Cholesky { n: 256 }, Workload::Stencil { cells: 4, steps: 2 }],
            policies: vec!["pl/eft-p".into()],
            tiles: vec![64, 48],
            modes: vec![CellMode::Simulate],
            seeds: vec![0],
            cache: CachePolicy::WriteBack,
            solve_lanes: 1,
            solve_batch: 1,
            delta: DeltaMode::Off,
            faults: vec![None],
            fault_members: 3,
        };
        let cells = grid.expand();
        // cholesky keeps only tile 64; stencil keeps both tiles
        assert_eq!(cells.len(), 3, "{cells:?}");
        assert!(cells
            .iter()
            .all(|c| c.workload.feasible(c.tile)));
        assert!(cells.iter().all(|c| c.fault == 0), "a None-only axis pins index 0");
    }

    #[test]
    fn fault_axis_expands_innermost_and_pairs_scenarios() {
        use crate::coordinator::faults::FaultSpec;
        use crate::coordinator::platform::MachineBuilder;
        let mut b = MachineBuilder::new("m");
        let h = b.space("host", u64::MAX);
        b.main(h);
        let t = b.proc_type("cpu", 1.0, 0.1);
        b.processors(2, "c", t, h);
        let mut spec = FaultSpec::named("quick");
        spec.transient_rate = 0.1;
        let grid = SweepGrid {
            platforms: vec![SweepPlatform::new("m", b.build(), PerfDb::new(), 8)],
            workloads: vec![Workload::Cholesky { n: 256 }],
            policies: vec!["pl/eft-p".into(), "pl/edf-p".into()],
            tiles: vec![64],
            modes: vec![CellMode::Simulate],
            seeds: vec![0],
            cache: CachePolicy::WriteBack,
            solve_lanes: 1,
            solve_batch: 1,
            delta: DeltaMode::Off,
            faults: vec![None, Some(spec.clone())],
            fault_members: 3,
        };
        let cells = grid.expand();
        assert_eq!(cells.len(), 4, "2 policies x 2 fault entries: {cells:?}");
        // the axis is innermost: each policy gets its off/faulted pair
        assert_eq!(
            cells.iter().map(|c| c.fault).collect::<Vec<_>>(),
            vec![0, 1, 0, 1]
        );
        // the member draw ignores policy and mode — both policies of one
        // scenario replay the identical trace — but follows the scenario
        let a = fault_member_seed(&spec, "m", "cholesky:256", 64, 0);
        assert_eq!(a, fault_member_seed(&spec, "m", "cholesky:256", 64, 0));
        assert_ne!(a, fault_member_seed(&spec, "m2", "cholesky:256", 64, 0));
        assert_ne!(a, fault_member_seed(&spec, "m", "cholesky:256", 128, 0));
        assert_ne!(a, fault_member_seed(&spec, "m", "cholesky:256", 64, 1));
    }

    #[test]
    fn faults_column_is_gated_on_a_non_off_label() {
        let row = |fault: &str| CellResult {
            platform: "m".into(),
            workload: "cholesky:256".into(),
            policy: "pl/eft-p".into(),
            tile: 64,
            mode: "sim".into(),
            seed: 0,
            fault: fault.into(),
            cell_seed: 7,
            n_tasks: 10,
            dag_depth: 1,
            makespan: 1.5,
            gflops: 2.0,
            avg_load_pct: 50.0,
            transfer_bytes: 0,
            energy_j: 1.0,
            peak_in_flight: 0,
            hom_makespan: 1.5,
            hom_gflops: 2.0,
            failed_moves: 0,
            makespan_over_lb: 1.0,
            replay_frac: 0.0,
        };
        let plain = to_csv(&[row("off")]);
        assert!(!plain.contains("faults"), "all-off rows keep the pre-axis bytes:\n{plain}");
        assert!(!to_json(&[row("off")]).contains("faults"));
        let ext = to_csv(&[row("off"), row("quick")]);
        let mut lines = ext.lines();
        assert!(lines.next().unwrap().ends_with(",faults"));
        assert!(lines.next().unwrap().ends_with(",off"));
        assert!(lines.next().unwrap().ends_with(",quick"));
        assert!(to_json(&[row("quick")]).contains("\"faults\""));
    }

    #[test]
    fn grid_toml_parses() {
        // no platform files on disk in unit tests: exercise the axis
        // parsing with an empty platform list rejected up front
        let err = grid_from_toml("workloads = [\"cholesky:1024\"]\n").unwrap_err();
        assert!(format!("{err:#}").contains("platforms"), "{err:#}");
        assert!(grid_from_toml("platforms = [\"/nonexistent.toml\"]").is_err());
    }
}
